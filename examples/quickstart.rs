//! Quickstart: end-to-end all-node GNN inference in a dozen lines.
//!
//! Run: `cargo run --release --example quickstart`

use deal::config::DealConfig;
use deal::coordinator::Pipeline;
use deal::util::human_secs;

fn main() -> deal::Result<()> {
    // A small co-purchase-like graph, 4 simulated machines, 3-layer GCN,
    // fanout-50 layerwise sampling — the paper's default setup.
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "products-sim".into();
    cfg.dataset.scale = 1.0 / 16.0; // 4096 nodes for a fast demo
    cfg.cluster.machines = 4;
    cfg.model.kind = "gcn".into();

    let report = Pipeline::new(cfg).run()?;

    println!("end-to-end stages:");
    for s in &report.stages.0 {
        println!("  {:<12} {}", s.name, human_secs(s.sim_secs));
    }
    let e = report.embeddings.expect("embeddings kept by default");
    println!(
        "refreshed embeddings for all {} nodes ({} dims); node 0 starts with {:?}",
        e.rows,
        e.cols,
        &e.row(0)[..4.min(e.cols)]
    );
    Ok(())
}
