//! Daily citation-graph embedding refresh (the ogbn-papers100M use case):
//! run the full end-to-end pipeline on papers-sim with every feature-
//! preparation strategy and print the Fig. 3a-style stage breakdown —
//! showing how the fused first layer moves pre-processing off the
//! critical path.
//!
//! Run: `cargo run --release --example papers_embedding`

use deal::config::DealConfig;
use deal::coordinator::Pipeline;
use deal::util::human_secs;

fn main() -> deal::Result<()> {
    println!("{:<14} {:>12} {:>12} {:>12} {:>12} {:>8}", "prep", "construct", "sampling", "inference", "total", "pre-%");
    for prep in ["scan", "redistribute", "fused"] {
        let mut cfg = DealConfig::default();
        cfg.dataset.name = "papers-sim".into();
        cfg.dataset.scale = 1.0 / 32.0; // 4096 nodes
        cfg.cluster.machines = 4;
        cfg.model.kind = "gcn".into();
        cfg.exec.feature_prep = prep.into();
        let report = Pipeline::new(cfg).run()?;
        println!(
            "{:<14} {:>12} {:>12} {:>12} {:>12} {:>7.1}%",
            prep,
            human_secs(report.stages.sim_of("construct")),
            human_secs(report.stages.sim_of("sampling")),
            human_secs(report.stages.sim_of("inference")),
            human_secs(report.stages.total()),
            report.stages.preprocessing_fraction() * 100.0,
        );
    }
    println!("\n(fused folds feature loading into the first GNN layer — §3.5)");
    Ok(())
}
