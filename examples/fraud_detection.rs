//! Fraud detection on a dense social graph (the paper's §1 motivation:
//! "fraud detection in e-commerce marketplaces views the millions of
//! transactions in the past period as a graph").
//!
//! Runs all-node GAT inference on the spammer-sim graph, then flags
//! anomalies: accounts whose embedding diverges most from the mean of
//! their sampled neighborhood (spammers connect broadly but do not look
//! like their neighbors).
//!
//! Run: `cargo run --release --example fraud_detection`

use deal::config::DealConfig;
use deal::coordinator::Pipeline;
use deal::graph::{datasets, Csr};
use deal::util::human_secs;

fn main() -> deal::Result<()> {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "spammer-sim".into();
    cfg.dataset.scale = 1.0 / 16.0; // 2048 nodes, dense (deg ≈ 153)
    cfg.cluster.machines = 4;
    cfg.model.kind = "gat".into(); // attention highlights odd neighbors
    cfg.model.fanout = 20;

    let scale = cfg.dataset.scale;
    let report = Pipeline::new(cfg).run()?;
    println!(
        "GAT all-node inference over spammer-sim: {} (pre-processing {:.0}%)",
        human_secs(report.stages.total()),
        report.stages.preprocessing_fraction() * 100.0
    );

    // anomaly score: distance between a node's embedding and its
    // neighborhood mean
    let emb = report.embeddings.unwrap();
    let ds = datasets::load("spammer-sim", scale)?;
    let g = Csr::from(&ds.edges);
    let mut scores: Vec<(usize, f32)> = (0..g.n_rows)
        .map(|v| {
            let nbrs = g.row(v);
            if nbrs.is_empty() {
                return (v, 0.0);
            }
            let mut mean = vec![0.0f32; emb.cols];
            for &s in nbrs {
                for (m, &x) in mean.iter_mut().zip(emb.row(s as usize)) {
                    *m += x;
                }
            }
            let inv = 1.0 / nbrs.len() as f32;
            let mut dist = 0.0f32;
            for (j, &x) in emb.row(v).iter().enumerate() {
                let d = x - mean[j] * inv;
                dist += d * d;
            }
            (v, dist.sqrt())
        })
        .collect();
    scores.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top suspicious accounts (embedding vs neighborhood):");
    for (v, s) in scores.iter().take(10) {
        println!("  node {:>6}  anomaly {:.3}  degree {}", v, s, g.degree(*v));
    }
    Ok(())
}
