//! **End-to-end validation driver** (DESIGN.md §End-to-end validation):
//! load a real *trained* GCN (exported by `python/compile/train.py`),
//! refresh all-node embeddings through the full Deal pipeline with the
//! **XLA backend** (every dense tile runs inside an AOT-compiled
//! artifact via PJRT — python never runs here), then serve embedding +
//! similarity traffic two ways — the sequential single-copy baseline and
//! the sharded, batched worker pool — swap in a second epoch mid-load,
//! and report p50/p99 latency and throughput for both.
//!
//! Requires `make artifacts` (HLO artifacts + trained weights) and a
//! build with the `xla` feature.
//! Run: `cargo run --release --features xla --example serve_embeddings`

use std::sync::Arc;
use std::time::Instant;

use deal::cli::read_labelled;
use deal::cluster::{Cluster, NetConfig};
use deal::model::{gcn::gcn_forward, ExecOpts, LayerPart, ModelConfig, ModelWeights};
use deal::partition::PartitionPlan;
use deal::primitives::{gather_tiles, scatter};
use deal::runtime::backend_from_config;
use deal::serve::{
    serve_workload, serve_workload_pooled, synthetic_workload, EmbeddingServer, PoolOpts,
    Request, ServePool, ShardedTable, TableCell,
};
use deal::sampling::sample_all_layers;
use deal::tensor::Matrix;
use deal::util::rng::Rng;
use deal::util::{human_bytes, human_secs};

fn main() -> deal::Result<()> {
    let data = std::path::Path::new("data/labelled");
    let weights_path = std::path::Path::new("artifacts/weights_gcn.bin");
    if !data.join("edges.bin").exists() || !weights_path.exists() {
        anyhow::bail!("run `make artifacts` first (needs data/labelled + trained weights)");
    }

    // ---- load the trained model + its graph
    let ds = read_labelled(data)?;
    let dim = ds.features.cols;
    let cfg = ModelConfig::gcn(3, dim);
    let weights = Arc::new(ModelWeights::load(&cfg, weights_path)?);
    println!(
        "loaded trained GCN ({} layers, dim {}) over {} nodes / {} edges",
        cfg.layers,
        dim,
        ds.edges.n_nodes,
        ds.edges.n_edges()
    );

    // ---- refresh all-node embeddings through the distributed pipeline
    // on the XLA backend (4 machines: P=2 graph parts × M=2 feature parts)
    let backend = backend_from_config("xla", std::path::Path::new("artifacts"))?;
    let plan = PartitionPlan::new(ds.edges.n_nodes, dim, 2, 2);
    let g = deal::graph::Csr::from(&ds.edges);
    let mut parts_by_p = Vec::new();
    for p in 0..plan.p {
        let (lo, hi) = plan.node_range(p);
        let sub = g.slice_rows(lo, hi);
        let lg = sample_all_layers(&sub, cfg.layers, 10, 0x5E11 ^ p as u64);
        parts_by_p.push(lg.layers.into_iter().map(LayerPart::new).collect::<Vec<_>>());
    }
    let parts_by_p = Arc::new(parts_by_p);
    let tiles = Arc::new(scatter(&plan, &ds.features));
    let plan2 = plan.clone();
    let weights2 = Arc::clone(&weights);
    let backend2 = Arc::clone(&backend);

    let t0 = Instant::now();
    let cluster = Cluster::new(plan.world(), NetConfig::default());
    let (outs, report) = cluster.run(move |ctx| {
        let (p_idx, _) = plan2.coords_of(ctx.rank);
        let opts = ExecOpts::default();
        gcn_forward(
            ctx,
            &plan2,
            &parts_by_p[p_idx],
            tiles[ctx.rank].clone(),
            &weights2,
            backend2.as_ref(),
            &opts,
        )
        .unwrap()
    })?;
    let outs: Vec<Matrix> = outs;
    let embeddings = gather_tiles(&plan, dim, &outs);
    println!(
        "embedding refresh: wall {} | simulated cluster {} | comm {} | xla tile calls {}",
        human_secs(t0.elapsed().as_secs_f64()),
        human_secs(report.makespan()),
        human_bytes(report.total_bytes()),
        *deal::runtime::service::XLA_CALLS.lock().unwrap(),
    );

    // ---- quality check: the trained model should classify well even
    // from sampled aggregation (Table 6's point)
    let head = deal::runtime::load_weights(std::path::Path::new("artifacts/head_gcn.bin"))?;
    let logits = embeddings.matmul(&head[0]);
    let acc = deal::model::reference::accuracy(&logits, &ds.labels, |r| !ds.train_mask[r]);
    println!("test accuracy from served embeddings: {:.1}%", acc * 100.0);

    // ---- serve a request workload: sequential baseline first
    let mut rng = Rng::new(7);
    let n = ds.edges.n_nodes;
    let requests: Vec<Request> = synthetic_workload(&mut rng, n, 500, false);
    let table = ShardedTable::from_inference_plan(&plan, &embeddings, 0);
    let server = EmbeddingServer::new(embeddings);
    let stats = serve_workload(&server, &requests, backend.as_ref())?;
    println!(
        "sequential baseline : {} req | p50 {} | p99 {} | {:.0} req/s",
        stats.requests,
        human_secs(stats.latency.p50),
        human_secs(stats.latency.p99),
        stats.throughput
    );

    // ---- sharded batched pool (serving layout = inference layout), with
    // a second epoch swapped in while the workload is in flight
    let cell = Arc::new(TableCell::new(table));
    let opts = PoolOpts { workers: 4, queue_capacity: requests.len(), ..PoolOpts::default() };
    let pool = ServePool::spawn(Arc::clone(&cell), Arc::clone(&backend), opts);
    let next_epoch = ShardedTable::from_inference_plan(&plan, &server.embeddings, 0);
    let (pooled, swapped_at) = std::thread::scope(|scope| {
        let cell2 = Arc::clone(&cell);
        let swap = scope.spawn(move || cell2.publish(next_epoch));
        let pooled = serve_workload_pooled(&pool, &requests);
        (pooled, swap.join().expect("swap thread panicked"))
    });
    let (_responses, pstats) = pooled?;
    println!(
        "sharded batched pool: {} req | p50 {} | p99 {} | {:.0} req/s  ({:.2}x)",
        pstats.requests,
        human_secs(pstats.latency.p50),
        human_secs(pstats.latency.p99),
        pstats.throughput,
        pstats.throughput / stats.throughput.max(1e-12),
    );
    let totals = pool.shutdown();
    println!(
        "epoch swap → {} mid-load: served={} rejected={} failed={} batches={} max_batch={}",
        swapped_at, totals.served, totals.rejected, totals.failed, totals.batches, totals.max_batch_seen,
    );
    anyhow::ensure!(totals.failed == 0, "refresh swap dropped {} requests", totals.failed);
    Ok(())
}
