"""Interchange-format round trips between train.py and the rust runtime."""

import os
import struct
import tempfile

import numpy as np

from compile import train


def test_tensor_roundtrip():
    tensors = [
        np.arange(12, dtype="<f4").reshape(3, 4),
        np.ones((1, 5), dtype="<f4"),
    ]
    path = os.path.join(tempfile.mkdtemp(), "w.bin")
    train.write_tensors(path, tensors)
    back = train.read_tensors(path)
    assert len(back) == 2
    np.testing.assert_array_equal(back[0], tensors[0])
    np.testing.assert_array_equal(back[1], tensors[1])


def test_1d_tensor_written_as_row():
    path = os.path.join(tempfile.mkdtemp(), "v.bin")
    train.write_tensors(path, [np.arange(4, dtype="<f4")])
    back = train.read_tensors(path)
    assert back[0].shape == (1, 4)


def test_edges_reader_matches_rust_writer_format():
    # format: u64 n_nodes, u64 n_edges, then (u32 src, u32 dst) pairs
    path = os.path.join(tempfile.mkdtemp(), "e.bin")
    with open(path, "wb") as f:
        f.write(struct.pack("<QQ", 5, 3))
        for s, d in [(0, 1), (4, 2), (3, 3)]:
            f.write(struct.pack("<II", s, d))
    n, srcs, dsts = train.read_edges(path)
    assert n == 5
    np.testing.assert_array_equal(srcs, [0, 4, 3])
    np.testing.assert_array_equal(dsts, [1, 2, 3])


def test_labels_and_mask_readers():
    d = tempfile.mkdtemp()
    lp = os.path.join(d, "labels.bin")
    with open(lp, "wb") as f:
        f.write(struct.pack("<QQ", 4, 3))
        f.write(np.asarray([0, 2, 1, 2], dtype="<u4").tobytes())
    labels, n_classes = train.read_labels(lp)
    assert n_classes == 3
    np.testing.assert_array_equal(labels, [0, 2, 1, 2])
    mp = os.path.join(d, "mask.bin")
    with open(mp, "wb") as f:
        f.write(struct.pack("<Q", 4))
        f.write(bytes([1, 0, 0, 1]))
    mask = train.read_mask(mp)
    np.testing.assert_array_equal(mask, [True, False, False, True])


def test_init_params_shapes():
    import jax

    key = jax.random.PRNGKey(0)
    gcn = train.init_params("gcn", 3, 16, 16, key)
    assert len(gcn) == 3 and len(gcn[0]) == 2
    gat = train.init_params("gat", 2, 16, 16, key)
    assert len(gat) == 2 and len(gat[0]) == 4
    assert gat[0][2].shape == (16, train.HEADS)
