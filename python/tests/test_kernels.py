"""Layer-1 correctness: every Pallas kernel vs the pure-jnp oracle,
hypothesis-swept over shapes and values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_tile, ref, segment_ops

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rng_arr(seed, shape, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-scale, scale, size=shape).astype(np.float32))


# ------------------------------------------------------------- matmul


@given(
    rows=st.sampled_from([1, 3, 16, 128, 256]),
    k=st.integers(1, 48),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(rows, k, n, seed):
    x = rng_arr(seed, (rows, k))
    w = rng_arr(seed + 1, (k, n))
    got = matmul_tile.matmul(x, w)
    want = ref.matmul(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    rows=st.sampled_from([2, 7, 128]),
    k=st.integers(1, 32),
    n=st.integers(1, 24),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**16),
)
def test_matmul_bias_act_matches_ref(rows, k, n, act, seed):
    x = rng_arr(seed, (rows, k))
    w = rng_arr(seed + 1, (k, n))
    b = rng_arr(seed + 2, (n,))
    got = matmul_tile.matmul_bias_act(x, w, b, act=act)
    want = ref.matmul_bias_act(x, w, b, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_blocked_path_used_for_multiple_blocks():
    # 256 rows = 2 blocks of BLOCK_R; exercises the grid path.
    x = rng_arr(0, (256, 16))
    w = rng_arr(1, (16, 8))
    np.testing.assert_allclose(
        matmul_tile.matmul(x, w), ref.matmul(x, w), rtol=1e-5, atol=1e-5
    )


# ------------------------------------------------------------- spmm


@given(
    e=st.integers(1, 96),
    d=st.integers(1, 32),
    segs=st.integers(1, 24),
    seed=st.integers(0, 2**16),
)
def test_spmm_tile_matches_ref(e, d, segs, seed):
    rng = np.random.default_rng(seed)
    feats = rng_arr(seed, (e, d))
    w = rng_arr(seed + 1, (e,))
    seg = jnp.asarray(rng.integers(0, segs + 1, size=e).astype(np.int32))
    got = segment_ops.spmm_tile(feats, w, seg, segs)
    want = ref.spmm_tile(feats, w, seg, segs)
    assert got.shape == (segs + 1, d)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_spmm_padding_sink_isolated():
    # padding edges (w=0, seg=segs) must leave real segments untouched
    feats = jnp.ones((4, 3), jnp.float32)
    w = jnp.asarray([1.0, 1.0, 0.0, 0.0], jnp.float32)
    seg = jnp.asarray([0, 1, 2, 2], jnp.int32)  # 2 == sink for segs=2
    out = segment_ops.spmm_tile(feats, w, seg, 2)
    np.testing.assert_allclose(out[0], jnp.ones(3))
    np.testing.assert_allclose(out[1], jnp.ones(3))
    np.testing.assert_allclose(out[2], jnp.zeros(3))  # sink got zero weight


# ------------------------------------------------------------- sddmm


@given(e=st.integers(1, 128), d=st.integers(1, 48), seed=st.integers(0, 2**16))
def test_sddmm_tile_matches_ref(e, d, seed):
    a = rng_arr(seed, (e, d))
    b = rng_arr(seed + 1, (e, d))
    np.testing.assert_allclose(
        segment_ops.sddmm_tile(a, b), ref.sddmm_tile(a, b), rtol=1e-4, atol=1e-5
    )


# ------------------------------------------------------------- gat edge


@given(e=st.integers(1, 64), h=st.integers(1, 8), seed=st.integers(0, 2**16))
def test_gat_edge_tile_matches_ref(e, h, seed):
    u = rng_arr(seed, (e, h), scale=3.0)
    v = rng_arr(seed + 1, (e, h), scale=3.0)
    np.testing.assert_allclose(
        segment_ops.gat_edge_tile(u, v), ref.gat_edge_tile(u, v), rtol=1e-5, atol=1e-6
    )


def test_gat_edge_negative_slope():
    u = jnp.asarray([[-1.0]], jnp.float32)
    v = jnp.asarray([[-1.0]], jnp.float32)
    out = segment_ops.gat_edge_tile(u, v)
    np.testing.assert_allclose(out, [[-0.4]], rtol=1e-6)
