"""Layer-2 checks: full-graph model functions are self-consistent and the
AOT lowering produces loadable HLO text."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model, shapes


def toy_graph(n=30, e=120, seed=0):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n, size=e).astype(np.int32)  # dst
    cols = rng.integers(0, n, size=e).astype(np.int32)  # src
    deg = np.zeros(n, dtype=np.float32)
    np.add.at(deg, rows, 1.0)
    adj_w = (1.0 / (deg[rows] + 1.0)).astype(np.float32)
    self_w = (1.0 / (deg + 1.0)).astype(np.float32)
    return map(jnp.asarray, (rows, cols, adj_w, self_w))


def test_gcn_forward_shapes_and_determinism():
    rows, cols, adj_w, self_w = toy_graph()
    h = jnp.asarray(np.random.default_rng(1).normal(size=(30, 8)).astype(np.float32))
    params = [
        (jnp.eye(8, dtype=jnp.float32), jnp.zeros(8, jnp.float32)) for _ in range(2)
    ]
    out1 = model.gcn_forward_full(params, h, rows, cols, adj_w, self_w)
    out2 = model.gcn_forward_full(params, h, rows, cols, adj_w, self_w)
    assert out1.shape == (30, 8)
    np.testing.assert_array_equal(out1, out2)


def test_gat_attention_is_convex_combination():
    # identical node states → attention output equals the shared state
    rows, cols, _, _ = toy_graph()
    d, heads = 8, 4
    h = jnp.ones((30, d), jnp.float32) * 1.5
    params = [
        (
            jnp.eye(d, dtype=jnp.float32),
            jnp.zeros(d, jnp.float32),
            jnp.zeros((d, heads), jnp.float32),
            jnp.zeros((d, heads), jnp.float32),
        )
    ]
    out = model.gat_forward_full(params, h, rows, cols, heads)
    np.testing.assert_allclose(out, h, rtol=1e-5)


def test_cross_entropy_masks():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0]], jnp.float32)
    labels = jnp.asarray([0, 0], jnp.int32)
    full = model.softmax_cross_entropy(logits, labels, jnp.asarray([1.0, 1.0]))
    only_good = model.softmax_cross_entropy(logits, labels, jnp.asarray([1.0, 0.0]))
    assert float(only_good) < float(full)


def test_aot_lowering_produces_hlo_text():
    # lower one small entry of each kernel kind and sanity-check the text
    for kernel, dims in [
        ("gemm", [8, 8, 8]),
        ("gemm_bias_relu", [8, 8, 8]),
        ("spmm", [16, 8, 8]),
        ("sddmm", [16, 8]),
    ]:
        text = aot.lower_entry(kernel, dims)
        assert "HloModule" in text, f"{kernel}: no HloModule header"
        assert "ROOT" in text


def test_manifest_covers_required_dims():
    entries = list(shapes.manifest_entries())
    kernels = {k for k, _, _ in entries}
    assert {"gemm", "gemm_bias", "gemm_bias_relu", "spmm", "sddmm"} <= kernels
    gemm_dims = {(d[1], d[2]) for k, d, _ in entries if k == "gemm"}
    # registry dims and GAT head logits must be covered
    for need in [(100, 100), (128, 128), (100, 4), (128, 4)]:
        assert need in gemm_dims, f"missing gemm dims {need}"


def test_aot_main_writes_manifest(tmp_path=None):
    tmp = tempfile.mkdtemp()
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out", tmp, "--only", "sddmm"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = open(os.path.join(tmp, "manifest.txt")).read()
    assert "kernel=gemm" in manifest  # listed even when not regenerated
    assert any(f.endswith(".hlo.txt") for f in os.listdir(tmp))
