"""Layer-2 JAX model functions — the per-tile dense compute of a GNN layer,
calling the Layer-1 Pallas kernels. These are what `aot.py` lowers to HLO
text for the rust runtime; they are also used directly (jitted) by
`train.py`'s full-graph forward pass, so the trained weights and the rust
inference share one definition of the math.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul_tile, segment_ops

# ---------------------------------------------------------------- tiles


def gemm(x, w):
    """Projection tile: ``x @ w`` (Pallas blocked matmul)."""
    return (matmul_tile.matmul(x, w),)


def gemm_bias(x, w, b):
    return (matmul_tile.matmul_bias_act(x, w, b, act="none"),)


def gemm_bias_relu(x, w, b):
    return (matmul_tile.matmul_bias_act(x, w, b, act="relu"),)


def spmm(feats, w, seg, *, num_segments):
    """Weighted segment-sum aggregation tile (+1 sink row)."""
    return (segment_ops.spmm_tile(feats, w, seg, num_segments),)


def sddmm(dst, src):
    """Row-wise dot scoring tile."""
    return (segment_ops.sddmm_tile(dst, src),)


# ------------------------------------------------- full-graph reference

def gcn_layer_full(h, adj_rows, adj_cols, adj_w, self_w, w, b, act):
    """Full-graph GCN layer (training path): mean aggregation with
    self-loops, matching `rust/src/model/gcn.rs` semantics.

    adj_rows/adj_cols/adj_w: COO edges (dst, src, 1/(deg+1)); self_w:
    per-node 1/(deg+1).
    """
    # NOTE: the *_full training path uses plain jnp (interpret-mode
    # pallas_call does not support reverse-mode autodiff); the AOT tile
    # functions above are the Pallas versions, and pytest asserts both
    # agree numerically.
    hw = jnp.dot(h, w, preferred_element_type=jnp.float32)
    gathered = hw[adj_cols] * adj_w[:, None]
    agg = jnp.zeros_like(hw).at[adj_rows].add(gathered)
    out = agg + hw * self_w[:, None] + b[None, :]
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def gat_layer_full(h, adj_rows, adj_cols, w, b, a_src, a_dst, heads, act):
    """Full-graph GAT layer (training path), matching
    `rust/src/model/gat.rs`: additive attention, LeakyReLU(0.2), self edge
    in the softmax."""
    n = h.shape[0]
    d = w.shape[1]
    head_dim = d // heads
    z = jnp.dot(h, w, preferred_element_type=jnp.float32)
    u = jnp.dot(z, a_dst)  # (n, heads)
    v = jnp.dot(z, a_src)

    def lrelu(x):
        return jnp.where(x >= 0, x, 0.2 * x)

    scores = lrelu(u[adj_rows] + v[adj_cols])  # (E, heads)
    self_scores = lrelu(u + v)  # (n, heads)
    # segment softmax per dst per head (self edge included)
    neg = jnp.float32(-1e30)
    mx = jnp.full((n, heads), neg).at[adj_rows].max(scores)
    mx = jnp.maximum(mx, self_scores)
    ex = jnp.exp(scores - mx[adj_rows])
    ex_self = jnp.exp(self_scores - mx)
    denom = jnp.zeros((n, heads)).at[adj_rows].add(ex) + ex_self
    alpha = ex / denom[adj_rows]
    alpha_self = ex_self / denom
    # aggregate per head
    zh = z.reshape(n, heads, head_dim)
    msg = zh[adj_cols] * alpha[:, :, None]
    agg = jnp.zeros((n, heads, head_dim)).at[adj_rows].add(msg)
    agg = agg + zh * alpha_self[:, :, None]
    out = agg.reshape(n, d) + b[None, :]
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def gcn_forward_full(params, h, adj_rows, adj_cols, adj_w, self_w):
    """k-layer full-graph GCN forward. params = [(w, b), ...]."""
    k = len(params)
    for l, (w, b) in enumerate(params):
        act = "none" if l + 1 == k else "relu"
        h = gcn_layer_full(h, adj_rows, adj_cols, adj_w, self_w, w, b, act)
    return h


def gat_forward_full(params, h, adj_rows, adj_cols, heads):
    """k-layer full-graph GAT forward. params = [(w, b, a_src, a_dst)...]."""
    k = len(params)
    for l, (w, b, a_src, a_dst) in enumerate(params):
        act = "none" if l + 1 == k else "relu"
        h = gat_layer_full(h, adj_rows, adj_cols, w, b, a_src, a_dst, heads, act)
    return h


def softmax_cross_entropy(logits, labels, mask):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
