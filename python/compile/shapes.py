"""The AOT artifact grid: every (kernel, shape) pair the rust runtime may
dispatch (`rust/src/runtime/service.rs` looks artifacts up by these dims).

The ring GEMM multiplies *feature-part slices* against weight row slices,
so `d_in` must cover every part width `d/M` for the supported datasets
(d ∈ {100, 128}), the labelled study set (d = 32) and the test dims, with
M ∈ {1, 2, 4}; `d_out` covers the hidden dim and the GAT head count.

SPMM artifacts have a fixed segment capacity; the rust runtime row-blocks
larger outputs over it (`XlaHandle::spmm_tile`).
"""

ROW_TILE = 256
EDGE_TILE = 1024
SEG_CAP = 256

# hidden dims of the supported models/datasets (+ small test dims)
HIDDEN_DIMS = [32, 100, 128]
TEST_DIMS = [8, 16]
HEADS = 4
PART_FACTORS = [1, 2, 4]


def _gemm_dims():
    dims = set()
    for d in HIDDEN_DIMS:
        for m in PART_FACTORS:
            if d % m == 0:
                w = d // m
                dims.add((w, d))      # projection slice
                dims.add((w, HEADS))  # GAT attention logits slice
    for d in TEST_DIMS:
        dims.add((d, d))
        dims.add((16, 8))
        dims.add((d, HEADS))
    return sorted(dims)


GEMM_DIMS = _gemm_dims()
# bias-fused variants only for the test dims (the distributed models fuse
# bias natively after aggregation; these prove the artifact path)
GEMM_BIAS_DIMS = [(8, 8), (16, 16), (32, 32)]

# feature widths for the SPMM/SDDMM tiles: all part widths + test dims
SPARSE_DIMS = sorted({d // m for d in HIDDEN_DIMS for m in PART_FACTORS if d % m == 0}
                     | set(TEST_DIMS))


def manifest_entries():
    """Yield (kernel, dims, fn_name) for aot.py."""
    for d_in, d_out in GEMM_DIMS:
        yield ("gemm", [ROW_TILE, d_in, d_out], None)
    for d_in, d_out in GEMM_BIAS_DIMS:
        yield ("gemm_bias", [ROW_TILE, d_in, d_out], None)
        yield ("gemm_bias_relu", [ROW_TILE, d_in, d_out], None)
    for d in SPARSE_DIMS:
        yield ("spmm", [EDGE_TILE, SEG_CAP, d], None)
        yield ("sddmm", [EDGE_TILE, d], None)
