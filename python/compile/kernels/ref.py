"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth
pytest sweeps against (and the semantics the rust NativeBackend mirrors)."""

import jax.numpy as jnp


def matmul(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def matmul_bias_act(x, w, b, act="none"):
    out = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def spmm_tile(feats, w, seg, num_segments):
    weighted = feats * w[:, None]
    return jnp.zeros((num_segments + 1, feats.shape[1]), jnp.float32).at[seg].add(
        weighted
    )


def sddmm_tile(dst, src):
    return jnp.sum(dst * src, axis=1)


def gat_edge_tile(u_dst, v_src, slope=0.2):
    x = u_dst + v_src
    return jnp.where(x >= 0, x, slope * x)
