"""Layer-1 Pallas kernels: SPMM aggregation and SDDMM scoring tiles.

TPU-shaped reformulation of the sparse primitives (DESIGN.md
§Hardware-Adaptation): instead of GPU scatter-atomics, the SPMM tile takes
*pre-gathered* edge rows plus a segment-id vector and performs a weighted
segment-sum — no atomics, static shapes, pure VPU reductions. Padding edges
carry weight 0 and segment id ``num_segments`` (a sink row the caller
slices off), so padding never perturbs numerics.

The SDDMM tile takes pre-gathered dst/src rows and emits row-wise dots.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(feats_ref, w_ref, seg_ref, o_ref, *, num_segments):
    feats = feats_ref[...]              # (E, D)
    w = w_ref[...]                      # (E,)
    seg = seg_ref[...]                  # (E,) int32, sink = num_segments
    weighted = feats * w[:, None]
    # one-hot matmul segment-sum: (S+1, E) @ (E, D). Dense, static-shape,
    # MXU-friendly — the TPU idiom for moderate segment counts.
    onehot = (
        seg[None, :] == jnp.arange(num_segments + 1, dtype=jnp.int32)[:, None]
    ).astype(jnp.float32)
    o_ref[...] = jnp.dot(onehot, weighted, preferred_element_type=jnp.float32)


def spmm_tile(feats, w, seg, num_segments):
    """Weighted segment-sum of pre-gathered rows.

    Returns ``(num_segments + 1, D)``; the last row is the padding sink.
    """
    e, d = feats.shape
    assert w.shape == (e,) and seg.shape == (e,)
    kernel = functools.partial(_spmm_kernel, num_segments=num_segments)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((num_segments + 1, d), jnp.float32),
        interpret=True,
    )(feats, w, seg)


def _sddmm_kernel(dst_ref, src_ref, o_ref):
    o_ref[...] = jnp.sum(dst_ref[...] * src_ref[...], axis=1)


def sddmm_tile(dst, src):
    """Row-wise dot products of pre-gathered row blocks → ``(E,)``."""
    assert dst.shape == src.shape
    e, _ = dst.shape
    return pl.pallas_call(
        _sddmm_kernel,
        out_shape=jax.ShapeDtypeStruct((e,), jnp.float32),
        interpret=True,
    )(dst, src)


def _gat_edge_kernel(u_ref, v_ref, o_ref, *, slope):
    x = u_ref[...] + v_ref[...]
    o_ref[...] = jnp.where(x >= 0, x, slope * x)


def gat_edge_tile(u_dst, v_src, slope=0.2):
    """LeakyReLU(u[dst] + v[src]) for pre-gathered per-edge head logits."""
    assert u_dst.shape == v_src.shape
    kernel = functools.partial(_gat_edge_kernel, slope=slope)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(u_dst.shape, jnp.float32),
        interpret=True,
    )(u_dst, v_src)
