"""Layer-1 Pallas kernel: blocked matmul tile (the GNN projection GEMM).

The tile is blocked over the row dimension via ``BlockSpec`` so each grid
step streams one ``(BLOCK_R, K)`` slab from HBM into VMEM, multiplies it
against the resident ``(K, N)`` weight, and writes one ``(BLOCK_R, N)``
output slab — the standard MXU-friendly schedule (see DESIGN.md
§Hardware-Adaptation for the VMEM budget).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom calls; interpret mode lowers to plain HLO, which is exactly what the
AOT artifacts need.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row block per grid step. 128 matches the MXU systolic dimension.
BLOCK_R = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _bias_act_kernel(x_ref, w_ref, b_ref, o_ref, *, act):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def matmul(x, w):
    """``x @ w`` as a row-blocked Pallas call. ``x.shape[0]`` must be a
    multiple of ``BLOCK_R`` or small enough to be a single block."""
    rows, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    block_r = BLOCK_R if rows % BLOCK_R == 0 else rows
    grid = (rows // block_r,)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.float32),
        interpret=True,
    )(x, w)


def matmul_bias_act(x, w, b, act="none"):
    """``act(x @ w + b)`` fused projection tile (GCN layer §2.1)."""
    rows, k = x.shape
    _, n = w.shape
    block_r = BLOCK_R if rows % BLOCK_R == 0 else rows
    grid = (rows // block_r,)
    kernel = functools.partial(_bias_act_kernel, act=act)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.float32),
        interpret=True,
    )(x, w, b)
