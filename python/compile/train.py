"""Train the small GCN / GAT models for the accuracy study (paper Table 6)
and the serving example — build-time only; the trained weights are
exported in the `rust/src/runtime/weights.rs` interchange format and
applied by the rust inference engines.

Reads the labelled SBM study set written by ``deal gen-labelled`` (or
generates it by invoking the deal binary if missing), trains with plain
full-graph gradient descent + Adam on the train mask, and writes
``weights_gcn.bin`` / ``weights_gat.bin`` plus an accuracy log.

Usage: ``python -m compile.train --data ../data/labelled --out ../artifacts``
"""

import argparse
import os
import struct
import subprocess

import jax
import jax.numpy as jnp
import numpy as np

from . import model

HEADS = 4


# ---------------------------------------------------------- interchange IO

def read_edges(path):
    with open(path, "rb") as f:
        n_nodes, n_edges = struct.unpack("<QQ", f.read(16))
        buf = np.frombuffer(f.read(n_edges * 8), dtype="<u4").reshape(n_edges, 2)
    return n_nodes, buf[:, 0].astype(np.int32), buf[:, 1].astype(np.int32)


def read_tensors(path):
    out = []
    with open(path, "rb") as f:
        (n,) = struct.unpack("<Q", f.read(8))
        for _ in range(n):
            rows, cols = struct.unpack("<QQ", f.read(16))
            data = np.frombuffer(f.read(rows * cols * 4), dtype="<f4")
            out.append(data.reshape(rows, cols).copy())
    return out


def write_tensors(path, tensors):
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", len(tensors)))
        for t in tensors:
            t = np.asarray(t, dtype="<f4")
            if t.ndim == 1:
                t = t.reshape(1, -1)
            f.write(struct.pack("<QQ", t.shape[0], t.shape[1]))
            f.write(t.tobytes())


def read_labels(path):
    with open(path, "rb") as f:
        n, n_classes = struct.unpack("<QQ", f.read(16))
        labels = np.frombuffer(f.read(n * 4), dtype="<u4").astype(np.int32)
    return labels, int(n_classes)


def read_mask(path):
    with open(path, "rb") as f:
        (n,) = struct.unpack("<Q", f.read(8))
        return np.frombuffer(f.read(n), dtype=np.uint8).astype(bool)


# ----------------------------------------------------------------- training

def init_params(kind, layers, d_in, d_out, key):
    params = []
    dims = [d_in] + [d_in] * (layers - 1)
    outs = dims[1:] + [d_out]
    for l in range(layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        di, do = dims[l], outs[l]
        scale = (2.0 / di) ** 0.5
        w = jax.random.normal(k1, (di, do)) * scale
        b = jnp.zeros((do,))
        if kind == "gat":
            a_src = jax.random.normal(k2, (do, HEADS)) * scale
            a_dst = jax.random.normal(k3, (do, HEADS)) * scale
            params.append((w, b, a_src, a_dst))
        else:
            params.append((w, b))
    return params


def train(kind, feats, labels, n_classes, train_mask, rows, cols, epochs, seed):
    n = feats.shape[0]
    deg = np.zeros(n, dtype=np.float32)
    np.add.at(deg, rows, 1.0)
    adj_w = jnp.asarray(1.0 / (deg[rows] + 1.0))
    self_w = jnp.asarray(1.0 / (deg + 1.0))
    rows_j = jnp.asarray(rows)
    cols_j = jnp.asarray(cols)
    h = jnp.asarray(feats)
    labels_j = jnp.asarray(labels)
    mask_j = jnp.asarray(train_mask, dtype=jnp.float32)
    # NOTE: the last layer maps hidden → hidden; a trailing linear head
    # maps to classes so the GNN output stays `dim`-wide (the shape the
    # rust engines produce). The head is exported as an extra tensor pair.
    key = jax.random.PRNGKey(seed)
    params = init_params(kind, 3, feats.shape[1], feats.shape[1], key)
    key, hk = jax.random.split(key)
    head_w = jax.random.normal(hk, (feats.shape[1], n_classes)) * 0.1
    head_b = jnp.zeros((n_classes,))

    def forward(params, head_w, head_b):
        if kind == "gat":
            emb = model.gat_forward_full(params, h, rows_j, cols_j, HEADS)
        else:
            emb = model.gcn_forward_full(params, h, rows_j, cols_j, adj_w, self_w)
        return emb @ head_w + head_b[None, :]

    def loss_fn(all_params):
        params, head_w, head_b = all_params
        logits = forward(params, head_w, head_b)
        return model.softmax_cross_entropy(logits, labels_j, mask_j)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    all_params = (params, head_w, head_b)
    m = jax.tree.map(jnp.zeros_like, all_params)
    v = jax.tree.map(jnp.zeros_like, all_params)
    for step in range(1, epochs + 1):
        loss, grads = grad_fn(all_params)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, grads)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, grads)
        mhat = jax.tree.map(lambda a: a / (1 - 0.9**step), m)
        vhat = jax.tree.map(lambda a: a / (1 - 0.999**step), v)
        all_params = jax.tree.map(
            lambda p, mh, vh: p - 1e-2 * mh / (jnp.sqrt(vh) + 1e-8),
            all_params,
            mhat,
            vhat,
        )
        if step % 50 == 0 or step == 1:
            logits = forward(*all_params)
            pred = jnp.argmax(logits, axis=1)
            test = ~np.asarray(train_mask)
            acc = float(jnp.mean((pred == labels_j)[jnp.asarray(test)]))
            print(f"[{kind}] step {step:4d} loss {float(loss):.4f} test-acc {acc:.3f}")
    return all_params


def export(kind, all_params, out_dir):
    params, head_w, head_b = all_params
    tensors = []
    for layer in params:
        for t in layer:
            tensors.append(np.asarray(t))
    path = os.path.join(out_dir, f"weights_{kind}.bin")
    write_tensors(path, tensors)
    write_tensors(
        os.path.join(out_dir, f"head_{kind}.bin"), [np.asarray(head_w), np.asarray(head_b)]
    )
    print(f"exported {path} ({len(tensors)} tensors)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", default="../data/labelled")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--models", default="gcn,gat")
    args = ap.parse_args()

    if not os.path.exists(os.path.join(args.data, "edges.bin")):
        # generate via the deal CLI so rust and python share one dataset
        deal = os.path.join(os.path.dirname(__file__), "../../target/release/deal")
        subprocess.run([deal, "gen-labelled", "--out", args.data], check=True)

    n_nodes, srcs, dsts = read_edges(os.path.join(args.data, "edges.bin"))
    feats = read_tensors(os.path.join(args.data, "features.bin"))[0]
    labels, n_classes = read_labels(os.path.join(args.data, "labels.bin"))
    train_mask = read_mask(os.path.join(args.data, "train_mask.bin"))
    assert feats.shape[0] == n_nodes
    os.makedirs(args.out, exist_ok=True)
    # COO with dst as the segment (row) index, matching the rust CSR.
    for kind in args.models.split(","):
        all_params = train(
            kind, feats, labels, n_classes, train_mask, dsts, srcs, args.epochs, args.seed
        )
        export(kind, all_params, args.out)


if __name__ == "__main__":
    main()
