"""AOT lowering: jit → StableHLO → XlaComputation → **HLO text** under
``artifacts/``, plus ``manifest.txt`` for the rust runtime.

HLO *text* is the interchange format (NOT ``.serialize()``): jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out ../artifacts`` (from ``python/``).
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, shapes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(kernel: str, dims):
    """Lower one manifest entry; returns HLO text."""
    f32 = jnp.float32
    i32 = jnp.int32
    if kernel in ("gemm", "gemm_bias", "gemm_bias_relu"):
        rows, d_in, d_out = dims
        x = jax.ShapeDtypeStruct((rows, d_in), f32)
        w = jax.ShapeDtypeStruct((d_in, d_out), f32)
        if kernel == "gemm":
            lowered = jax.jit(model.gemm).lower(x, w)
        else:
            b = jax.ShapeDtypeStruct((d_out,), f32)
            fn = model.gemm_bias if kernel == "gemm_bias" else model.gemm_bias_relu
            lowered = jax.jit(fn).lower(x, w, b)
    elif kernel == "spmm":
        edges, segs, d = dims
        feats = jax.ShapeDtypeStruct((edges, d), f32)
        w = jax.ShapeDtypeStruct((edges,), f32)
        seg = jax.ShapeDtypeStruct((edges,), i32)
        fn = functools.partial(model.spmm, num_segments=segs)
        lowered = jax.jit(fn).lower(feats, w, seg)
    elif kernel == "sddmm":
        edges, d = dims
        a = jax.ShapeDtypeStruct((edges, d), f32)
        lowered = jax.jit(model.sddmm).lower(a, a)
    else:
        raise ValueError(f"unknown kernel {kernel}")
    return to_hlo_text(lowered)


def entry_filename(kernel: str, dims) -> str:
    return f"{kernel}_{'x'.join(str(d) for d in dims)}.hlo.txt"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--only", default="", help="comma list of kernels to regenerate (default all)"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(filter(None, args.only.split(",")))
    manifest_lines = []
    for kernel, dims, _ in shapes.manifest_entries():
        fname = entry_filename(kernel, dims)
        path = os.path.join(args.out, fname)
        dims_s = ",".join(str(d) for d in dims)
        manifest_lines.append(f"kernel={kernel} file={fname} dims={dims_s}")
        if only and kernel not in only:
            continue
        if os.path.exists(path):
            continue  # make-style: artifacts are immutable per shape
        text = lower_entry(kernel, dims)
        with open(path, "w") as f:
            f.write(text)
        print(f"lowered {kernel} dims=[{dims_s}] -> {fname} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("# kernel artifacts (HLO text) — see python/compile/aot.py\n")
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines)} entries")


if __name__ == "__main__":
    main()
