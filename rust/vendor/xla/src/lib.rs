//! **Offline stub** of the `xla` crate (xla-rs PJRT bindings) API surface
//! used by `deal::runtime::service` — the real crate lives on GitHub, not
//! crates.io, and its native `xla_extension` libraries are not part of
//! this image. This stub lets the `xla` cargo feature *compile* anywhere;
//! every entry point returns an error at runtime, which the service
//! thread reports per job exactly like any other backend failure
//! (DESIGN.md §Runtime).
//!
//! To run on real XLA, point the dependency at the actual bindings, e.g.
//! in `rust/Cargo.toml`:
//!
//! ```toml
//! [patch."crates-io"]            # or replace the path dependency
//! # xla = { git = "https://github.com/LaurentMazare/xla-rs" }
//! ```
//!
//! The stub mirrors only what `service.rs` calls: client construction,
//! HLO-text loading, compilation, literal construction, and execution.

use std::fmt;
use std::path::Path;

/// Stub error: carries a message, `Display`s like the real crate's error.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn stub_err(what: &str) -> Error {
    Error(format!(
        "{}: xla stub build — link the real xla-rs bindings to execute artifacts",
        what
    ))
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the service constructs literals with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_err("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PjRtClient::compile"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(stub_err("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(stub_err("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(stub_err("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(stub_err("Literal::to_vec"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_stub() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nope").is_err());
        let comp = XlaComputation::from_proto(&HloModuleProto);
        let _ = comp; // constructible so compile() call sites typecheck
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8])
            .is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"));
    }
}
