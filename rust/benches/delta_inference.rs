//! Delta inference vs full recompute under streaming churn (EXPERIMENTS.md
//! §Delta): sweep the per-batch edge-churn rate and compare one
//! incremental refresh (`coordinator::delta::DeltaState::apply`) against a
//! from-scratch pipeline run (`Pipeline::run`) on the *same* updated
//! graph.
//!
//! The primary metric is **simulated cluster time** — the repo's currency
//! for every paper-figure bench (construction, sampling, preparation and
//! inference all advance the Lamport clocks; the delta path charges its
//! coordinator-side staging at the same cores-scaled rate). Wall-clock is
//! reported alongside. Acceptance: at 1% edge churn the delta refresh
//! must be ≥ 3× faster (simulated) than the full recompute;
//! `DEAL_DELTA_BENCH_LAX=1` downgrades the assert to a warning for smoke
//! runs on contended machines.
//!
//! Run: `cargo bench --bench delta_inference [-- --full]`

use std::time::Instant;

use deal::config::DealConfig;
use deal::coordinator::delta::DeltaState;
use deal::coordinator::Pipeline;
use deal::util::bench::{BenchArgs, Report, Table};
use deal::util::human_secs;
use deal::util::rng::Rng;

const ACCEPTANCE_CHURN: f64 = 0.01;
const ACCEPTANCE_FLOOR: f64 = 3.0;

fn bench_cfg(scale: f64) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "papers-sim".into();
    cfg.dataset.scale = scale;
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = 2;
    cfg.model.kind = "gcn".into();
    cfg.model.layers = 2;
    cfg.model.fanout = 5;
    cfg
}

fn main() {
    let args = BenchArgs::parse();
    // papers-sim: the paper's lowest-density twin — churn batches touch
    // the smallest row fraction, the regime delta inference targets.
    let scale = args.pick(1.0 / 32.0, 1.0 / 8.0); // 4096 / 16384 nodes
    let churns = [0.001f64, 0.005, 0.01, 0.02];

    let mut report = Report::new("delta_inference");
    let cfg = bench_cfg(scale);
    report.note(format!(
        "dataset={} scale={} machines={} layers={} fanout={} | churn split half adds / half removes",
        cfg.dataset.name,
        cfg.dataset.scale,
        cfg.cluster.machines,
        cfg.model.layers,
        cfg.model.fanout,
    ));

    let mut table = Table::new(
        "delta refresh vs full recompute per churn rate (simulated cluster time)",
        &[
            "churn",
            "dirty rows",
            "frontier",
            "delta sim",
            "full sim",
            "sim speedup",
            "delta wall",
            "full wall",
            "wall speedup",
        ],
    );

    let mut acceptance_speedup = None;
    for (i, &churn) in churns.iter().enumerate() {
        // fresh baseline per churn rate: apples-to-apples single batches
        let mut state = DeltaState::init(bench_cfg(scale)).expect("delta state init");
        let mut rng = Rng::new(0xC0FE + i as u64);
        let half = (state.n_edges() as f64 * churn / 2.0).round() as usize;
        let batch = state.synth_batch(&mut rng, half, half, 0);

        let t0 = Instant::now();
        let rep = state.apply(&batch).expect("delta apply");
        let delta_wall = t0.elapsed().as_secs_f64();
        let delta_sim = rep.sim_secs;

        // full recompute over the *updated* graph
        let tag = format!("delta-bench-{}-{}", std::process::id(), i);
        let pipeline = Pipeline::with_dataset(
            bench_cfg(scale),
            &tag,
            state.edge_list(),
            state.features().clone(),
        );
        let t1 = Instant::now();
        let full = pipeline.run().expect("full pipeline");
        let full_wall = t1.elapsed().as_secs_f64();
        let full_sim = full.stages.total();

        // parity audit: the bench only counts if both paths agree
        let diff = state
            .embeddings()
            .max_abs_diff(full.embeddings.as_ref().expect("embeddings kept"));
        assert!(diff < 5e-3, "delta and full recompute disagree: {}", diff);

        let sim_speedup = full_sim / delta_sim.max(1e-12);
        let wall_speedup = full_wall / delta_wall.max(1e-12);
        if (churn - ACCEPTANCE_CHURN).abs() < 1e-12 {
            acceptance_speedup = Some(sim_speedup);
        }
        table.row(&[
            format!("{:.1}%", churn * 100.0),
            format!("{}", rep.dirty_rows),
            format!("{:?}", rep.frontier),
            human_secs(delta_sim),
            human_secs(full_sim),
            format!("{:.2}x", sim_speedup),
            human_secs(delta_wall),
            human_secs(full_wall),
            format!("{:.2}x", wall_speedup),
        ]);
    }
    report.add_table(table);

    let speedup = acceptance_speedup.expect("1% churn row present");
    report.note(format!(
        "sim speedup at {:.0}% churn: {:.2}x (acceptance floor {:.2}x)",
        ACCEPTANCE_CHURN * 100.0,
        speedup,
        ACCEPTANCE_FLOOR,
    ));
    if std::env::var("DEAL_DELTA_BENCH_LAX").is_ok() {
        if speedup < ACCEPTANCE_FLOOR {
            eprintln!(
                "[lax] below the {:.0}x acceptance floor: {:.2}x (contended runner?)",
                ACCEPTANCE_FLOOR, speedup
            );
        }
    } else {
        assert!(
            speedup >= ACCEPTANCE_FLOOR,
            "delta refresh below the {:.0}x acceptance floor at 1% churn: {:.2}x",
            ACCEPTANCE_FLOOR,
            speedup
        );
    }
    report.finish();
}
