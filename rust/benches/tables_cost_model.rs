//! Tables 1–3: the closed-form per-machine memory/communication models vs
//! the byte counters measured on the simulated cluster.

mod common;

use std::sync::Arc;

use deal::cluster::Cluster;
use deal::partition::PartitionPlan;
use deal::primitives::costs::{self, CostParams};
use deal::primitives::gemm::{cagnet_gemm, deal_gemm};
use deal::primitives::sddmm::{sddmm, SddmmAlgo, SddmmInput};
use deal::primitives::spmm::{deal_spmm, exchange_g0_spmm, spmm_2d, EdgeValues, SpmmInput};
use deal::primitives::{scatter, ExecMode};
use deal::tensor::Matrix;
use deal::util::bench::{BenchArgs, Report, Table};
use deal::util::rng::Rng;

fn payload_sent(rep: &deal::cluster::ClusterReport) -> f64 {
    // strip 64-byte envelopes, average per machine
    let total: u64 = rep
        .machines
        .iter()
        .map(|m| m.bytes_sent.saturating_sub(64 * m.msgs_sent))
        .sum();
    total as f64 / rep.machines.len() as f64
}

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("tables_cost_model");
    let (n, d) = args.pick((1024usize, 32usize), (8192, 128));
    let (p, m) = (2usize, 4usize);
    let plan = PartitionPlan::new(n, d, p, m);
    let mut rng = Rng::new(4);
    let h = Matrix::random(n, d, 1.0, &mut rng);
    let w = Matrix::random(d, d, 1.0, &mut rng);
    let tiles = Arc::new(scatter(&plan, &h));
    // synthetic graph with known Z
    let z_target = 12usize;
    let el = deal::graph::rmat::rmat(n.ilog2(), n * z_target, deal::graph::rmat::RmatParams::paper(), 5);
    let g = deal::graph::Csr::from(&el);
    let vals = deal::primitives::mean_weights(&g);
    let mut subs = Vec::new();
    for pi in 0..p {
        let (lo, hi) = plan.node_range(pi);
        subs.push((
            g.slice_rows(lo, hi),
            vals[g.indptr[lo] as usize..g.indptr[hi] as usize].to_vec(),
        ));
    }
    let subs = Arc::new(subs);
    let c = CostParams::new(n, d, p, m, z_target as f64);

    // ---- Table 1: GEMM
    let mut table = Table::new(
        "Table 1: GEMM per-machine comm + peak memory (measured vs model)",
        &["method", "comm meas", "comm model", "mem meas", "mem model"],
    );
    for (label, deal_algo, comm_f, mem_f) in [
        ("SOTA (CAGNET)", false, costs::gemm_sota_comm(&c), costs::gemm_sota_memory(&c)),
        ("Ours (ring)", true, costs::gemm_ours_comm(&c), costs::gemm_ours_memory(&c)),
    ] {
        let plan2 = plan.clone();
        let tiles2 = Arc::clone(&tiles);
        let w2 = w.clone();
        let cluster = Cluster::new(plan.world(), common::net());
        let (_, rep) = cluster
            .run(move |ctx| {
                let b = deal::runtime::Native;
                if deal_algo {
                    deal_gemm(ctx, &plan2, &tiles2[ctx.rank], &w2, &b, 1).unwrap()
                } else {
                    cagnet_gemm(ctx, &plan2, &tiles2[ctx.rank], &w2, &b, 1).unwrap()
                }
            })
            .unwrap();
        table.row(&[
            label.into(),
            deal::util::human_bytes(payload_sent(&rep) as u64),
            deal::util::human_bytes((comm_f * 4.0) as u64),
            deal::util::human_bytes(rep.max_peak_mem()),
            deal::util::human_bytes((mem_f * 4.0) as u64),
        ]);
    }
    report.add_table(table);

    // ---- Table 2: SPMM
    let mut table = Table::new(
        "Table 2: SPMM per-machine comm (measured vs model)",
        &["method", "comm meas", "comm model"],
    );
    for (label, which, model) in [
        ("Ours (feature exch)", 0, costs::spmm_ours_comm(&c)),
        ("Exchange G0", 1, costs::spmm_exchange_g0_comm(&c)),
        ("2D-based", 2, costs::spmm_2d_comm(&c)),
    ] {
        let plan2 = plan.clone();
        let tiles2 = Arc::clone(&tiles);
        let subs2 = Arc::clone(&subs);
        let cluster = Cluster::new(plan.world(), common::net());
        let (_, rep) = cluster
            .run(move |ctx| {
                let (p_idx, _) = plan2.coords_of(ctx.rank);
                let (sub, svals) = &subs2[p_idx];
                let input = SpmmInput {
                    plan: &plan2,
                    g: sub,
                    vals: EdgeValues::Scalar(svals),
                    h: &tiles2[ctx.rank],
                };
                match which {
                    0 => deal_spmm(ctx, &input, &deal::runtime::Native, ExecMode::Monolithic, 0, 7),
                    1 => exchange_g0_spmm(ctx, &input, 7),
                    _ => spmm_2d(ctx, &input, 7),
                }
            })
            .unwrap();
        table.row(&[
            label.into(),
            deal::util::human_bytes(payload_sent(&rep) as u64),
            deal::util::human_bytes((model * 4.0) as u64),
        ]);
    }
    report.add_table(table);

    // ---- Table 3: SDDMM
    let mut table = Table::new(
        "Table 3: SDDMM per-machine comm (measured vs model)",
        &["method", "comm meas", "comm model"],
    );
    for (label, algo, model) in [
        ("Approach (i) duplicate", SddmmAlgo::Duplicate, costs::sddmm_dup_comm(&c)),
        ("Approach (ii) split", SddmmAlgo::Split, costs::sddmm_split_comm(&c)),
    ] {
        let plan2 = plan.clone();
        let tiles2 = Arc::clone(&tiles);
        let subs2 = Arc::clone(&subs);
        let cluster = Cluster::new(plan.world(), common::net());
        let (_, rep) = cluster
            .run(move |ctx| {
                let (p_idx, _) = plan2.coords_of(ctx.rank);
                let input = SddmmInput { plan: &plan2, g: &subs2[p_idx].0, h: &tiles2[ctx.rank] };
                sddmm(ctx, &input, algo, ExecMode::Monolithic, 0, 11)
            })
            .unwrap();
        table.row(&[
            label.into(),
            deal::util::human_bytes(payload_sent(&rep) as u64),
            deal::util::human_bytes((model * 4.0) as u64),
        ]);
    }
    report.add_table(table);
    report.note(format!("params: N={} D={} P={} M={} Z≈{}", n, d, p, m, z_target));
    report.note("models count unique-column expectations; measured values include duplicate-column effects, so agreement within ~2x validates the shape".to_string());
    report.finish();
}
