//! Fig. 16: distributed GEMM — Deal's ring GEMM vs CAGNET's all-reduce
//! GEMM on products-sim features, hidden dims 256 and 1024, 2–8 machines.

mod common;

use std::sync::Arc;

use deal::cluster::Cluster;
use deal::primitives::gemm::{cagnet_gemm, deal_gemm};
use deal::tensor::Matrix;
use deal::util::bench::{BenchArgs, Report, Table};
use deal::util::rng::Rng;

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("fig16_gemm");
    report.note(format!("profile: {}", if args.quick { "quick" } else { "full" }));
    let dims = args.pick(vec![256usize], vec![256, 1024]);
    let machines = args.pick(vec![2usize, 4, 8], vec![2, 4, 8, 16]);
    let mut table = Table::new(
        "distributed GEMM, products-sim (sim makespan, ms)",
        &["hidden", "machines (P×M)", "CAGNET", "Deal", "speedup", "bytes CAGNET", "bytes Deal"],
    );
    for &d in &dims {
        for &w in &machines {
            // feature-partition heavy split: M = machines/2 (min 2)
            let m = (w / 2).max(2);
            let p = w / m;
            let setup = common::prim_setup("products-sim", args.quick, p, m, Some(d));
            let mut rng = Rng::new(9);
            let weight = Arc::new(Matrix::random(d, d, 0.1, &mut rng));
            let mut times = Vec::new();
            let mut bytes = Vec::new();
            for deal_algo in [false, true] {
                let plan = setup.plan.clone();
                let tiles = Arc::clone(&setup.tiles);
                let weight = Arc::clone(&weight);
                let cluster = Cluster::new(plan.world(), common::net());
                let (_, rep) = cluster
                    .run(move |ctx| {
                        let backend = deal::runtime::Native;
                        if deal_algo {
                            deal_gemm(ctx, &plan, &tiles[ctx.rank], &weight, &backend, 1).unwrap()
                        } else {
                            cagnet_gemm(ctx, &plan, &tiles[ctx.rank], &weight, &backend, 1).unwrap()
                        }
                    })
                    .unwrap();
                times.push(rep.makespan());
                bytes.push(rep.total_bytes());
            }
            table.row(&[
                d.to_string(),
                format!("{} ({}x{})", w, p, m),
                common::fmt_ms(times[0]),
                common::fmt_ms(times[1]),
                common::speedup(times[0], times[1]),
                deal::util::human_bytes(bytes[0]),
                deal::util::human_bytes(bytes[1]),
            ]);
        }
    }
    report.add_table(table);
    report.note("paper: Deal GEMM 1.52x / 1.47x faster than CAGNET on average; gap grows with machines".to_string());
    report.finish();
}
