//! Serving throughput (EXPERIMENTS.md §E2E): the sharded, batched worker
//! pool vs the sequential single-copy baseline on the same workload, with
//! a result-equality audit and a mid-load refresh swap.
//!
//! What the speedup comes from, at equal results:
//! - coalescing: one `rows_s × d @ d × Q` GEMM per shard per batch streams
//!   the table out of memory once per batch instead of once per request;
//! - selection: per-query top-k is O(N + k log k) quickselect instead of
//!   the baseline's O(N log N) full sort;
//! - parallelism: worker threads serve independent batches concurrently.
//!
//! Run: `cargo bench --bench serving_throughput [-- --full]`

use std::sync::Arc;
use std::time::Instant;

use deal::runtime::Native;
use deal::serve::{
    serve_workload, serve_workload_pooled, synthetic_workload, EmbeddingServer, PoolOpts,
    Request, Response, ServePool, ShardedTable, TableCell,
};
use deal::tensor::Matrix;
use deal::util::bench::{BenchArgs, Report, Table};
use deal::util::human_secs;
use deal::util::rng::Rng;

/// Responses must match the sequential reference exactly (ids; scores to
/// float tolerance).
fn assert_equal_results(server: &EmbeddingServer, reqs: &[Request], got: &[Response]) {
    assert_eq!(reqs.len(), got.len(), "response count");
    for (req, g) in reqs.iter().zip(got) {
        let want = server.handle(req, &Native).expect("reference handle");
        match (&want, g) {
            (Response::Embeddings(w), Response::Embeddings(m)) => {
                assert_eq!(w, m, "embed rows differ");
            }
            (Response::Similar(w), Response::Similar(m)) => {
                assert_eq!(w.len(), m.len());
                for (wl, ml) in w.iter().zip(m) {
                    let wi: Vec<u32> = wl.iter().map(|x| x.0).collect();
                    let mi: Vec<u32> = ml.iter().map(|x| x.0).collect();
                    assert_eq!(wi, mi, "ranked ids differ");
                    for (a, b) in wl.iter().zip(ml) {
                        assert!((a.1 - b.1).abs() <= 1e-5, "score {} vs {}", a.1, b.1);
                    }
                }
            }
            _ => panic!("response kind mismatch"),
        }
    }
}

fn main() {
    let args = BenchArgs::parse();
    let (n, d, n_reqs) = args.pick((4096usize, 64usize, 400usize), (30_000, 128, 2000));
    let (shards, workers, max_batch) = (4usize, 4usize, 64usize);
    let mut report = Report::new("serving_throughput");
    report.note(format!(
        "table {} × {} | {} requests | {} shards | {} workers | max_batch {}",
        n, d, n_reqs, shards, workers, max_batch
    ));

    let mut rng = Rng::new(0x5EE1);
    let full = Matrix::random(n, d, 1.0, &mut rng);
    let server = EmbeddingServer::new(full.clone());
    let mut table = Table::new(
        "sequential single-copy vs sharded batched pool (equal results)",
        &["workload", "seq req/s", "pool req/s", "speedup", "pool p50", "pool p99", "max batch"],
    );

    let mut similar_speedup = 0.0;
    for (label, similar_only) in [("similar-only", true), ("mixed 3:1 embed:similar", false)] {
        let reqs = synthetic_workload(&mut rng, n, n_reqs, similar_only);
        let seq = serve_workload(&server, &reqs, &Native).expect("sequential workload");

        let cell = Arc::new(TableCell::new(ShardedTable::from_full(&full, shards, 0)));
        let opts = PoolOpts {
            workers,
            queue_capacity: n_reqs,
            max_batch,
            ..PoolOpts::default()
        };
        let pool = ServePool::spawn(Arc::clone(&cell), Arc::new(Native), opts);
        let (responses, pooled) = serve_workload_pooled(&pool, &reqs).expect("pooled workload");
        let stats = pool.shutdown();
        assert_eq!(stats.rejected, 0, "bench queue sized for the whole workload");
        assert_eq!(stats.failed, 0, "no request may fail");
        assert_equal_results(&server, &reqs, &responses);

        let speedup = pooled.throughput / seq.throughput.max(1e-12);
        if similar_only {
            similar_speedup = speedup;
        }
        table.row(&[
            label.to_string(),
            format!("{:.0}", seq.throughput),
            format!("{:.0}", pooled.throughput),
            format!("{:.2}x", speedup),
            human_secs(pooled.latency.p50),
            human_secs(pooled.latency.p99),
            format!("{}", stats.max_batch_seen),
        ]);
    }
    report.add_table(table);

    // ---- refresh swap under load: publish a new epoch mid-flight; every
    // in-flight request must complete from a consistent snapshot.
    let reqs = synthetic_workload(&mut rng, n, n_reqs / 2, false);
    let cell = Arc::new(TableCell::new(ShardedTable::from_full(&full, shards, 0)));
    let opts = PoolOpts { workers, queue_capacity: reqs.len(), max_batch, ..PoolOpts::default() };
    let pool = ServePool::spawn(Arc::clone(&cell), Arc::new(Native), opts);
    let mut next = full.clone();
    next.map_inplace(|v| v * 0.5);
    let t0 = Instant::now();
    let (pooled, epoch) = std::thread::scope(|scope| {
        let c = Arc::clone(&cell);
        let swap = scope.spawn(move || c.publish(ShardedTable::from_full(&next, shards, 0)));
        let pooled = serve_workload_pooled(&pool, &reqs);
        (pooled, swap.join().expect("swap thread"))
    });
    let (_responses, rstats) = pooled.expect("workload under refresh");
    let stats = pool.shutdown();
    report.note(format!(
        "refresh swap → epoch {} in-flight over {} requests ({}): served={} failed={} rejected={}",
        epoch,
        rstats.requests,
        human_secs(t0.elapsed().as_secs_f64()),
        stats.served,
        stats.failed,
        stats.rejected,
    ));
    assert_eq!(epoch, 1);
    assert_eq!(stats.failed, 0, "refresh swap must not drop in-flight requests");
    assert_eq!(stats.rejected, 0);

    report.note(format!(
        "similar-only speedup {:.2}x (acceptance floor 2.00x)",
        similar_speedup
    ));
    // DEAL_SERVING_BENCH_LAX=1 downgrades the floor to a warning for
    // smoke runs on contended CI runners; acceptance runs leave it unset.
    if std::env::var("DEAL_SERVING_BENCH_LAX").is_ok() {
        if similar_speedup < 2.0 {
            eprintln!(
                "[lax] below the 2x acceptance floor: {:.2}x (contended runner?)",
                similar_speedup
            );
        }
    } else {
        assert!(
            similar_speedup >= 2.0,
            "batched sharded serving below the 2x acceptance floor: {:.2}x",
            similar_speedup
        );
    }
    report.finish();
}
