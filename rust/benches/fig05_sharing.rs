//! Fig. 5: leveraged sharing opportunity vs inference batch size
//! (percentage of all nodes), sparse (products) vs dense (spammer) graphs.

mod common;

use deal::baselines::sharing::fig5_curve;
use deal::util::bench::{BenchArgs, Report, Table};

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("fig05_sharing");
    let fractions = [0.001, 0.01, 0.05, 0.2, 0.5, 1.0];
    let k = 3;
    let fanout = args.pick(5, 10);
    let mut table = Table::new(
        "leveraged sharing vs batch size (3-layer GNN)",
        &["dataset", "batch %", "sharing %"],
    );
    for name in ["products-sim", "spammer-sim"] {
        let (g, _) = common::load(name, true);
        let curve = fig5_curve(&g, &fractions, k, fanout, 3);
        for (f, r) in curve {
            table.row(&[
                name.into(),
                format!("{:.1}%", f * 100.0),
                format!("{:.1}%", r * 100.0),
            ]);
        }
    }
    report.add_table(table);
    report.note("paper: sparse graphs reach full sharing only at batch = all nodes; dense graphs saturate earlier but memory forbids large batches".to_string());
    report.finish();
}
