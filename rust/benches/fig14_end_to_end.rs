//! Fig. 14: end-to-end all-node inference — Deal vs the DGI-style and
//! SALIENT++-style baselines, GCN and GAT, across datasets and machine
//! counts (simulated cluster time).

mod common;

use std::sync::Arc;

use deal::baselines::engines::{run_baseline, Engine};
use deal::baselines::BaselineOpts;
use deal::coordinator::Pipeline;
use deal::graph::Csr;
use deal::model::{ModelConfig, ModelWeights};
use deal::util::bench::{BenchArgs, Report, Table};

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("fig14_end_to_end");
    let machines = args.pick(vec![4usize], vec![2, 4, 8, 16]);
    let fanout = args.pick(10, 50);
    let mut table = Table::new(
        "end-to-end all-node inference (sim ms; speedups = Deal vs baseline)",
        &["model", "dataset", "machines", "DGI", "SALIENT++", "Deal", "vs DGI", "vs SALIENT++"],
    );
    for kind in ["gcn", "gat"] {
        for name in common::DATASETS {
            for &w in &machines {
                // Deal end-to-end (inference path only, to match what the
                // baselines do: they get pre-built graphs for free)
                let mut cfg = common::base_cfg(name, args.quick);
                cfg.cluster.machines = w;
                cfg.cluster.feature_parts = 2.min(w);
                cfg.model.kind = kind.into();
                cfg.model.fanout = fanout;
                let mut pipe = Pipeline::new(cfg.clone());
                pipe.keep_embeddings = false;
                let deal_run = pipe.run().unwrap();
                let deal_time =
                    deal_run.stages.sim_of("sampling") + deal_run.stages.sim_of("inference");

                // baselines on the same graph + weights
                let ds = deal::graph::datasets::load(name, cfg.dataset.scale).unwrap();
                let g = Arc::new(Csr::from(&ds.edges));
                let model_cfg = match kind {
                    "gcn" => ModelConfig::gcn(cfg.model.layers, ds.feature_dim),
                    _ => ModelConfig::gat(cfg.model.layers, ds.feature_dim, 4),
                };
                let weights = ModelWeights::random(&model_cfg, 1);
                let mut base_times = Vec::new();
                // The paper's baselines run memory-bound batches — a tiny
                // fraction of the node set (Fig. 5's point). Keep the
                // fraction, not the absolute count, when scaling down;
                // same for SALIENT++'s cache capacity.
                let batch = (g.n_rows / 256).max(16);
                for engine in [Engine::Dgi, Engine::SalientPlusPlus] {
                    let opts = BaselineOpts {
                        batch_size: batch,
                        fanout,
                        cache_rows: (g.n_rows / 8).max(64),
                        seed: 5,
                    };
                    let (_, rep) = run_baseline(
                        engine,
                        &g,
                        &ds.features,
                        &weights,
                        w,
                        common::net(),
                        Arc::new(deal::runtime::Native),
                        opts,
                    )
                    .unwrap();
                    base_times.push(rep.makespan());
                }
                table.row(&[
                    kind.into(),
                    name.into(),
                    w.to_string(),
                    common::fmt_ms(base_times[0]),
                    common::fmt_ms(base_times[1]),
                    common::fmt_ms(deal_time),
                    common::speedup(base_times[0], deal_time),
                    common::speedup(base_times[1], deal_time),
                ]);
            }
        }
    }
    report.add_table(table);
    report.note("paper: GCN speedups 4.64/2.28/3.25x vs DGI, 4.36/1.82/3.26x vs SALIENT++; GAT up to 7.70x vs DGI".to_string());
    report.finish();
}
