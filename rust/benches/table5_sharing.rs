//! Table 5: the sharing ratio achieved by DGI, P³ and SALIENT++ on the
//! three datasets (3-layer model, fanout 10).

mod common;

use deal::baselines::sharing::{occ_batched, occ_full, occ_no_sharing, occ_p3, occ_salient, sharing_ratio};
use deal::util::bench::{BenchArgs, Report, Table};

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("table5_sharing");
    let k = 3;
    let fanout = args.pick(5, 10);
    let mut table = Table::new(
        "sharing ratio (Deal = 100% by construction)",
        &["approach", "products-sim", "spammer-sim", "papers-sim"],
    );
    let mut rows: Vec<Vec<String>> = vec![
        vec!["DGI".into()],
        vec!["P3".into()],
        vec!["SALIENT++".into()],
    ];
    for name in common::DATASETS {
        let (g, _) = common::load(name, true);
        // memory-bound batch *fraction* (see fig14 note)
        let batch = (g.n_rows / 256).max(16);
        let cache = (g.n_rows / 8).max(64);
        let ns = occ_no_sharing(&g, k, fanout, 3);
        let full = occ_full(&g, k, fanout, 3);
        let dgi = sharing_ratio(ns, full, occ_batched(&g, batch, k, fanout, 3));
        let p3 = sharing_ratio(ns, full, occ_p3(&g, batch, k, fanout, 3));
        let sal = sharing_ratio(ns, full, occ_salient(&g, batch, cache, k, fanout, 3));
        rows[0].push(format!("{:.1}%", dgi * 100.0));
        rows[1].push(format!("{:.1}%", p3 * 100.0));
        rows[2].push(format!("{:.1}%", sal * 100.0));
    }
    for r in rows {
        table.row(&r);
    }
    report.add_table(table);
    report.note("paper: DGI 60.1/87.0/63.9%, P3 33.3/46.1/28.6%, SALIENT++ 66.4/77.9/70.3%".to_string());
    report.finish();
}
