//! Fig. 3: (a) end-to-end time breakdown showing the pre-processing
//! bottleneck under the naive configuration, and (b) peak memory of
//! graph-partition-only (M=1, monolithic fetch) vs Deal's collaborative
//! partition — the two observations motivating the design.

mod common;

use deal::coordinator::Pipeline;
use deal::util::bench::{BenchArgs, Report, Table};
use deal::util::human_bytes;

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("fig03_breakdown");

    // (a) breakdown with the naive strategy (scan + monolithic, like the
    // motivating measurement) vs Deal's (fused + pipelined)
    let mut table = Table::new(
        "Fig 3a: end-to-end breakdown, 4 machines (sim ms)",
        &["dataset", "strategy", "construct", "sampling", "prep+infer", "total", "pre-%"],
    );
    for name in common::DATASETS {
        for (label, prep, mode, construction) in [
            ("naive", "scan", "naive", "single"),
            ("deal", "fused", "pipelined", "distributed"),
        ] {
            let mut cfg = common::base_cfg(name, args.quick);
            cfg.cluster.machines = 4;
            cfg.exec.feature_prep = prep.into();
            cfg.exec.mode = mode.into();
            cfg.exec.construction = construction.into();
            let mut pipe = Pipeline::new(cfg);
            pipe.keep_embeddings = false;
            let r = pipe.run().unwrap();
            table.row(&[
                name.into(),
                label.into(),
                common::fmt_ms(r.stages.sim_of("construct")),
                common::fmt_ms(r.stages.sim_of("sampling")),
                common::fmt_ms(r.stages.sim_of("inference")),
                common::fmt_ms(r.stages.total()),
                format!("{:.0}%", r.stages.preprocessing_fraction() * 100.0),
            ]);
        }
    }
    report.add_table(table);

    // (b) peak memory: graph partition only (M=1, monolithic) vs Deal
    let mut table = Table::new(
        "Fig 3b: peak per-machine memory, 4 machines",
        &["dataset", "graph-part only (M=1, monolithic)", "Deal (M=2, pipelined)", "ratio"],
    );
    for name in common::DATASETS {
        let mut peaks = Vec::new();
        for (m, mode) in [(1usize, "monolithic"), (2, "pipelined")] {
            let mut cfg = common::base_cfg(name, args.quick);
            cfg.cluster.machines = 4;
            cfg.cluster.feature_parts = m;
            cfg.exec.mode = mode.into();
            cfg.exec.group_cols = 1024;
            let mut pipe = Pipeline::new(cfg);
            pipe.keep_embeddings = false;
            peaks.push(pipe.run().unwrap().max_peak_mem);
        }
        table.row(&[
            name.into(),
            human_bytes(peaks[0]),
            human_bytes(peaks[1]),
            format!("{:.2}x", peaks[0] as f64 / peaks[1] as f64),
        ]);
    }
    report.add_table(table);
    report.note("paper: pre-processing is 86% of naive end-to-end time; partition-only memory exceeds machine RAM".to_string());
    report.finish();
}
