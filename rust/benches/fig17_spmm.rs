//! Fig. 17: distributed SPMM — Deal's feature-exchange vs the exchange-G0
//! baseline across the three datasets and machine counts, with the
//! communication/computation split.

mod common;

use std::sync::Arc;

use deal::cluster::Cluster;
use deal::primitives::spmm::{deal_spmm, exchange_g0_spmm, EdgeValues, SpmmInput};
use deal::primitives::ExecMode;
use deal::util::bench::{BenchArgs, Report, Table};

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("fig17_spmm");
    let machines = args.pick(vec![2usize, 4, 8], vec![2, 4, 8]);
    let mut table = Table::new(
        "SPMM: exchange-G0 baseline vs Deal feature-exchange (sim ms)",
        &["dataset", "machines", "xG0 total", "Deal total", "speedup", "xG0 wait", "Deal wait"],
    );
    for name in common::DATASETS {
        for &w in &machines {
            // Collaborative partition: P=2 graph parts, features split
            // across the rest (the paper's deployment shape) — Deal's
            // fetch narrows with M while the baseline's structure tile
            // doesn't, which is what drives its poor scalability.
            let (p, m) = if w == 2 { (2usize, 1usize) } else { (2, w / 2) };
            let setup = common::prim_setup(name, args.quick, p, m, None);
            let mut totals = Vec::new();
            let mut waits = Vec::new();
            for deal_algo in [false, true] {
                let plan = setup.plan.clone();
                let tiles = Arc::clone(&setup.tiles);
                let subs = Arc::clone(&setup.subs);
                let cluster = Cluster::new(plan.world(), common::net());
                let (_, rep) = cluster
                    .run(move |ctx| {
                        let (p_idx, _) = plan.coords_of(ctx.rank);
                        let (sub, svals) = &subs[p_idx];
                        let input = SpmmInput {
                            plan: &plan,
                            g: sub,
                            vals: EdgeValues::Scalar(svals),
                            h: &tiles[ctx.rank],
                        };
                        if deal_algo {
                            deal_spmm(ctx, &input, &deal::runtime::Native, ExecMode::Monolithic, 0, 7)
                        } else {
                            exchange_g0_spmm(ctx, &input, 7)
                        }
                    })
                    .unwrap();
                totals.push(rep.makespan());
                let (wait, _) = common::comm_compute(&rep);
                waits.push(wait);
            }
            table.row(&[
                name.into(),
                w.to_string(),
                common::fmt_ms(totals[0]),
                common::fmt_ms(totals[1]),
                common::speedup(totals[0], totals[1]),
                common::fmt_ms(waits[0]),
                common::fmt_ms(waits[1]),
            ]);
        }
    }
    report.add_table(table);
    report.note("paper: Deal 4.30x / 5.28x / 5.29x over exchange-G0; baseline scales worse".to_string());
    report.finish();
}
