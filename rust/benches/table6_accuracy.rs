//! Table 6: test accuracy — full-neighbor inference vs SALIENT++-style
//! sampled ego-network inference vs Deal's layerwise shared sampling,
//! using the *trained* GCN/GAT study models (python/compile/train.py).
//!
//! Requires `make artifacts` (trained weights + labelled set).

mod common;

use std::sync::Arc;

use deal::baselines::engines::{run_baseline, Engine};
use deal::baselines::BaselineOpts;
use deal::cli::read_labelled;
use deal::graph::Csr;
use deal::model::reference::{accuracy, gat_reference, gcn_reference};
use deal::model::{ModelConfig, ModelWeights};
use deal::runtime::load_weights;
use deal::sampling::sample_all_layers;
use deal::util::bench::{BenchArgs, Report, Table};

fn main() {
    let args = BenchArgs::parse();
    let _ = &args;
    let mut report = Report::new("table6_accuracy");
    let data = std::path::Path::new("data/labelled");
    if !data.join("edges.bin").exists() || !std::path::Path::new("artifacts/weights_gcn.bin").exists() {
        report.note("SKIPPED: run `make artifacts` first (needs trained weights)".to_string());
        report.finish();
        return;
    }
    let ds = read_labelled(data).unwrap();
    let g = Arc::new(Csr::from(&ds.edges));
    let dim = ds.features.cols;
    let fanout = 10;
    let mut table = Table::new(
        "test accuracy on the labelled SBM study set (trained models, fanout 10)",
        &["model", "full neighbor", "SALIENT++ (sampled)", "Deal (layerwise shared)"],
    );
    for kind in ["gcn", "gat"] {
        let cfg = match kind {
            "gcn" => ModelConfig::gcn(3, dim),
            _ => ModelConfig::gat(3, dim, 4),
        };
        let wpath = format!("artifacts/weights_{}.bin", kind);
        let weights = ModelWeights::load(&cfg, std::path::Path::new(&wpath)).unwrap();
        let head = load_weights(std::path::Path::new(&format!("artifacts/head_{}.bin", kind))).unwrap();
        let acc_of = |emb: &deal::tensor::Matrix| {
            let logits = emb.matmul(&head[0]);
            accuracy(&logits, &ds.labels, |r| !ds.train_mask[r])
        };
        // full neighbor
        let full_layers = sample_all_layers(&g, 3, 0, 1);
        let full_emb = match kind {
            "gcn" => gcn_reference(&full_layers, &ds.features, &weights),
            _ => gat_reference(&full_layers, &ds.features, &weights),
        };
        // Deal layerwise shared sampling
        let deal_layers = sample_all_layers(&g, 3, fanout, 7);
        let deal_emb = match kind {
            "gcn" => gcn_reference(&deal_layers, &ds.features, &weights),
            _ => gat_reference(&deal_layers, &ds.features, &weights),
        };
        // SALIENT++-style per-batch ego sampling
        let (sal_emb, _) = run_baseline(
            Engine::SalientPlusPlus,
            &g,
            &ds.features,
            &weights,
            2,
            common::net(),
            Arc::new(deal::runtime::Native),
            BaselineOpts { fanout, batch_size: 256, cache_rows: 1 << 14, seed: 5 },
        )
        .unwrap();
        table.row(&[
            kind.to_uppercase(),
            format!("{:.1}%", acc_of(&full_emb) * 100.0),
            format!("{:.1}%", acc_of(&sal_emb) * 100.0),
            format!("{:.1}%", acc_of(&deal_emb) * 100.0),
        ]);
    }
    report.add_table(table);
    report.note("paper: GCN 76.9% everywhere; GAT 79.4/79.3/79.2% — reused layerwise samples do not hurt accuracy".to_string());
    report.finish();
}
