//! Ablation (DESIGN.md design-choice study): the §3.5 communication-group
//! size knob. Small groups bound peak memory but pay more round trips;
//! large groups approach monolithic behaviour. Sweeps `group_cols` for the
//! pipelined SPMM and reports time + peak memory — the trade-off the
//! paper's partitioned communication balances.

mod common;

use std::sync::Arc;

use deal::cluster::Cluster;
use deal::primitives::spmm::{deal_spmm, EdgeValues, SpmmInput};
use deal::primitives::ExecMode;
use deal::util::bench::{BenchArgs, Report, Table};

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("ablation_group_size");
    let sweeps = [64usize, 256, 1024, 4096, 16384];
    let mut table = Table::new(
        "pipelined SPMM vs group size (products-sim, 4 machines)",
        &["group_cols", "sim ms", "groups/machine (≈)", "peak mem"],
    );
    let setup = common::prim_setup("products-sim", args.quick, 2, 2, Some(128));
    for &gc in &sweeps {
        let plan = setup.plan.clone();
        let tiles = Arc::clone(&setup.tiles);
        let subs = Arc::clone(&setup.subs);
        let cluster = Cluster::new(plan.world(), common::net());
        let (_, rep) = cluster
            .run(move |ctx| {
                let (p_idx, _) = plan.coords_of(ctx.rank);
                let (sub, svals) = &subs[p_idx];
                let input = SpmmInput {
                    plan: &plan,
                    g: sub,
                    vals: EdgeValues::Scalar(svals),
                    h: &tiles[ctx.rank],
                };
                deal_spmm(ctx, &input, &deal::runtime::Native, ExecMode::Pipelined, gc, 7)
            })
            .unwrap();
        let approx_groups =
            (setup.plan.rows_of(0) as f64 / gc as f64).ceil() as usize + 1;
        table.row(&[
            gc.to_string(),
            common::fmt_ms(rep.makespan()),
            approx_groups.to_string(),
            deal::util::human_bytes(rep.max_peak_mem()),
        ]);
    }
    report.add_table(table);
    report.note("small groups bound memory, large groups amortize latency — pick per machine-RAM budget".to_string());
    report.finish();
}
