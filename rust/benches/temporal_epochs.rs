//! Incremental epoch snapshots vs cold recompute (EXPERIMENTS.md
//! §Temporal): sealing an epoch through the temporal engine re-infers
//! only the affected frontier, so it must be measurably cheaper than the
//! cold full-graph rerun that defines its correctness — while staying
//! **bit-identical** to it (a hard assert, even under lax mode: identity
//! is correctness, not performance).
//!
//! The run: build the temporal engine at epoch 0, then seal a few epochs
//! of a deterministic ~1%-churn event stream. Each seal is timed against
//! a cold `DeltaState::init_with` dense recompute of the same graph, and
//! the published snapshot is compared to it bit-for-bit.
//!
//! `DEAL_TEMPORAL_BENCH_LAX=1` downgrades only the incremental<cold
//! speed gate to a warning (CI smoke on contended runners).
//!
//! Emits `target/bench_results/BENCH_temporal.json`.
//!
//! Run: `cargo bench --bench temporal_epochs [-- --full]`

use deal::config::DealConfig;
use deal::temporal::{TemporalEngine, TemporalOpts};
use deal::util::bench::{time_once, BenchArgs, Report, Table};
use deal::util::human_secs;

const EPOCHS: u64 = 4;
const SNAPSHOT_EVERY: u64 = 8;

fn cfg(scale: f64) -> DealConfig {
    let mut c = DealConfig::default();
    c.dataset.name = "products-sim".into();
    c.dataset.scale = scale;
    c.cluster.machines = 4;
    c.cluster.feature_parts = 2;
    c.model.layers = 2;
    c.model.fanout = 5;
    c
}

fn main() {
    let args = BenchArgs::parse();
    let lax = std::env::var("DEAL_TEMPORAL_BENCH_LAX").map_or(false, |v| v != "0");
    // quick: 256-node graph; full: 1024 nodes
    let scale = args.pick(1.0 / 256.0, 1.0 / 64.0);
    let cfg = cfg(scale);

    let mut report = Report::new("temporal_epochs");
    let opts = TemporalOpts {
        snapshot_every: SNAPSHOT_EVERY,
        retain: EPOCHS as usize + 1,
        durable_dir: None,
    };
    let (eng, build_secs) = time_once(|| TemporalEngine::new(cfg.clone(), &opts));
    let mut eng = eng.expect("temporal engine");
    let n = eng.state().n_nodes();
    report.note(format!(
        "epoch 0: {} nodes, {} edges, built in {} (model {})",
        n,
        eng.state().n_edges(),
        human_secs(build_secs),
        cfg.model.kind,
    ));

    let mut t = Table::new(
        "incremental seal vs cold recompute per epoch",
        &["epoch", "events", "rows", "incremental", "cold", "speedup"],
    );
    let mut inc_total = 0.0f64;
    let mut cold_total = 0.0f64;
    let mut rows_json = String::new();
    for _ in 0..EPOCHS {
        // ~1% edge churn + a few feature rewrites, tick-spread over the
        // window (seed-derived: the stream is identical on every run)
        let half = (eng.state().n_edges() / 200).max(4);
        let events = eng.synth_events(half, half, (n / 100).max(1));
        eng.ingest(&events).expect("ingest");
        let (sealed, inc_secs) =
            time_once(|| eng.advance_to((eng.epoch() + 1) * SNAPSHOT_EVERY));
        let sealed = sealed.expect("seal");
        assert_eq!(sealed.len(), 1);
        let rep = &sealed[0];
        let (cold, cold_secs) = time_once(|| eng.cold_oracle());
        let cold = cold.expect("cold oracle");

        // hard assert, no tolerance: the snapshot IS the cold rerun
        let snap = eng.snapshot_at(rep.epoch).expect("snapshot").to_full();
        let a: Vec<u32> = snap.data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = cold.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "epoch {} snapshot is not bit-identical to the cold rerun", rep.epoch);

        inc_total += inc_secs;
        cold_total += cold_secs;
        t.row(&[
            format!("{}", rep.epoch),
            format!("{}", rep.events),
            format!("{}", rep.updated_rows),
            human_secs(inc_secs),
            human_secs(cold_secs),
            format!("{:.2}x", cold_secs / inc_secs.max(1e-12)),
        ]);
        if !rows_json.is_empty() {
            rows_json.push_str(",\n");
        }
        rows_json.push_str(&format!(
            "    {{\"epoch\": {}, \"events\": {}, \"updated_rows\": {}, \"incremental_secs\": {:.6}, \"cold_secs\": {:.6}}}",
            rep.epoch, rep.events, rep.updated_rows, inc_secs, cold_secs
        ));
    }
    report.add_table(t);
    report.note("bit-identity: every published snapshot == cold full-graph rerun (exact)");

    let speedup = cold_total / inc_total.max(1e-12);
    let pass = inc_total < cold_total;
    if !pass {
        let msg = format!(
            "incremental sealing ({}) not cheaper than cold recompute ({}) over {} epochs",
            human_secs(inc_total),
            human_secs(cold_total),
            EPOCHS
        );
        if lax {
            report.note(format!("LAX: {}", msg));
        } else {
            panic!("{}", msg);
        }
    }

    // ---- machine-readable summary (schema: EXPERIMENTS.md §Temporal) ---
    let json = format!(
        "{{\n  \"bench\": \"temporal_epochs\",\n  \"quick\": {},\n  \"nodes\": {},\n  \"epochs\": {},\n  \"snapshot_every\": {},\n  \"epoch_rows\": [\n{}\n  ],\n  \"incremental_secs_total\": {:.6},\n  \"cold_secs_total\": {:.6},\n  \"speedup\": {:.3},\n  \"bit_identical\": true,\n  \"pass\": {},\n  \"lax\": {}\n}}\n",
        args.quick,
        n,
        EPOCHS,
        SNAPSHOT_EVERY,
        rows_json,
        inc_total,
        cold_total,
        speedup,
        pass,
        lax
    );
    let out = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&out);
    let json_path = out.join("BENCH_temporal.json");
    std::fs::write(&json_path, &json).expect("write BENCH_temporal.json");
    report.note(format!("wrote {}", json_path.display()));
    report.finish();
}
