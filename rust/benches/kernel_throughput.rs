//! Intra-rank kernel throughput: parallel-over-scalar speedup for the
//! three hot kernels the `runtime::par` engine sits under — dense matmul,
//! CSR SpMM, and CSR construction — plus a thread sweep (EXPERIMENTS.md
//! §Threads).
//!
//! Acceptance: at `PAR_THREADS` (4) pool threads each kernel must beat the
//! single-thread path by `≥ 2×` when the host has ≥ 4 cores; on smaller
//! hosts the floor scales down to `0.55 × min(4, cores)` (a 4-thread pool
//! cannot speed up past the physical core count). `DEAL_KERNEL_BENCH_LAX=1`
//! (the CI smoke profile) reports without asserting. Besides the human
//! table, the run emits machine-readable
//! `target/bench_results/BENCH_kernels.json` so the perf trajectory is
//! tracked across PRs.
//!
//! Every comparison first asserts the parallel output is **bit-identical**
//! to the scalar one — speed never buys a different answer.

use deal::graph::rmat::{rmat, RmatParams};
use deal::graph::Csr;
use deal::primitives::{mean_weights, spmm::spmm_reference};
use deal::runtime::par;
use deal::tensor::Matrix;
use deal::util::bench::{time_fn, BenchArgs, Report, Table};
use deal::util::rng::Rng;

const PAR_THREADS: usize = 4;

struct KernelResult {
    name: &'static str,
    serial_secs: f64,
    parallel_secs: f64,
}

impl KernelResult {
    fn speedup(&self) -> f64 {
        self.serial_secs / self.parallel_secs.max(1e-12)
    }
}

/// Time `f` at 1 pool thread and at `PAR_THREADS`, returning best-of-reps
/// wall times (min is the standard noise-robust choice for throughput).
fn compare<F: FnMut()>(
    name: &'static str,
    reps: usize,
    mut f: impl FnMut(usize) -> F,
) -> KernelResult {
    let serial = par::with_threads(1, || time_fn(name, 1, reps, f(1)));
    let parallel = par::with_threads(PAR_THREADS, || time_fn(name, 1, reps, f(PAR_THREADS)));
    KernelResult {
        name,
        serial_secs: serial.summary().min,
        parallel_secs: parallel.summary().min,
    }
}

fn main() {
    let args = BenchArgs::parse();
    let lax = std::env::var("DEAL_KERNEL_BENCH_LAX").map_or(false, |v| v != "0");
    let cores = par::available();
    let reps = args.pick(3, 5);

    let mut report = Report::new("kernel_throughput");
    report.note(format!(
        "pool threads {} | host cores {} | profile {}{}",
        PAR_THREADS,
        cores,
        if args.quick { "quick" } else { "full" },
        if lax { " | LAX (report only)" } else { "" },
    ));

    // ---- inputs -----------------------------------------------------------
    let mut rng = Rng::new(0xBE7C);
    let mm = args.pick(192, 384);
    let a = Matrix::random(mm, mm, 1.0, &mut rng);
    let b = Matrix::random(mm, mm, 1.0, &mut rng);

    let scale = args.pick(12u32, 14u32);
    let n_edges = args.pick(300_000, 1_500_000);
    let el = rmat(scale, n_edges, RmatParams::paper(), 7);
    let g = Csr::from(&el);
    let vals = mean_weights(&g);
    let d = 64;
    let h = Matrix::random(g.n_cols, d, 1.0, &mut rng);

    // ---- bit-equality guard ----------------------------------------------
    let mm_ref = par::with_threads(1, || a.matmul(&b));
    let sp_ref = par::with_threads(1, || spmm_reference(&g, &vals, &h));
    let csr_ref = par::with_threads(1, || Csr::from(&el));
    par::with_threads(PAR_THREADS, || {
        assert_eq!(a.matmul(&b), mm_ref, "parallel matmul diverged");
        assert_eq!(spmm_reference(&g, &vals, &h), sp_ref, "parallel spmm diverged");
        assert_eq!(Csr::from(&el), csr_ref, "parallel CSR construction diverged");
    });
    report.note("bit-equality: parallel == scalar for all three kernels");

    // ---- timings ----------------------------------------------------------
    let results = [
        compare("matmul", reps, |_| {
            let (a, b) = (&a, &b);
            move || {
                std::hint::black_box(a.matmul(b));
            }
        }),
        compare("spmm", reps, |_| {
            let (g, vals, h) = (&g, &vals, &h);
            move || {
                std::hint::black_box(spmm_reference(g, vals, h));
            }
        }),
        compare("csr_construction", reps, |_| {
            let el = &el;
            move || {
                std::hint::black_box(Csr::from(el));
            }
        }),
    ];

    let mut table = Table::new(
        &format!("parallel ({} threads) over scalar", PAR_THREADS),
        &["kernel", "serial", "parallel", "speedup"],
    );
    for r in &results {
        table.row(&[
            r.name.to_string(),
            format!("{:.2} ms", r.serial_secs * 1e3),
            format!("{:.2} ms", r.parallel_secs * 1e3),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    report.add_table(table);

    // ---- thread sweep (matmul, EXPERIMENTS.md §Threads) -------------------
    let mut sweep = Table::new("matmul thread sweep", &["threads", "best", "speedup"]);
    let t1 = par::with_threads(1, || time_fn("t1", 1, reps, || {
        std::hint::black_box(a.matmul(&b));
    }))
    .summary()
    .min;
    for t in [1usize, 2, 3, 4, 8] {
        let tt = par::with_threads(t, || time_fn("t", 1, reps, || {
            std::hint::black_box(a.matmul(&b));
        }))
        .summary()
        .min;
        sweep.row(&[
            format!("{}", t),
            format!("{:.2} ms", tt * 1e3),
            format!("{:.2}x", t1 / tt.max(1e-12)),
        ]);
    }
    report.add_table(sweep);

    // ---- machine-readable trajectory --------------------------------------
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str(&format!(
        "  \"bench\": \"kernel_throughput\",\n  \"threads\": {},\n  \"cores\": {},\n  \"quick\": {},\n  \"kernels\": [\n",
        PAR_THREADS, cores, args.quick
    ));
    for (i, r) in results.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"serial_secs\": {:.6}, \"parallel_secs\": {:.6}, \"speedup\": {:.3}}}{}\n",
            r.name,
            r.serial_secs,
            r.parallel_secs,
            r.speedup(),
            if i + 1 == results.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let dir = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let json_path = dir.join("BENCH_kernels.json");
    std::fs::write(&json_path, &json).expect("write BENCH_kernels.json");
    report.note(format!("wrote {}", json_path.display()));

    // ---- acceptance -------------------------------------------------------
    // A 4-thread pool cannot scale past the physical cores, so the floor is
    // 2x on >=4-core hosts and 0.55 x min(4, cores) on smaller ones.
    let ideal = PAR_THREADS.min(cores) as f64;
    let floor = if ideal >= 4.0 { 2.0 } else { 0.55 * ideal };
    report.note(format!("acceptance floor: {:.2}x (ideal {:.0}x)", floor, ideal));
    report.finish();
    if !lax {
        for r in &results {
            assert!(
                r.speedup() >= floor,
                "{}: speedup {:.2}x below floor {:.2}x (serial {:.2} ms, parallel {:.2} ms)",
                r.name,
                r.speedup(),
                floor,
                r.serial_secs * 1e3,
                r.parallel_secs * 1e3
            );
        }
    }
}
