//! Chunked comm/compute overlap on the end-to-end pipeline (EXPERIMENTS.md
//! §Pipeline; paper §4 "partitioned, pipelined communication"): sweep
//! `pipeline.chunk_rows ∈ {0, 64, 256, 1024}` over the 2×2-cluster E2E run
//! and measure the simulated end-to-end inference makespan (the stage the
//! chunked transfers pipeline — every layer's ring GEMM + feature-exchange
//! SPMM).
//!
//! The overlap law only pays where comm and compute are comparable — the
//! paper's testbed regime. Host CPUs vary, so the bench self-calibrates:
//! one probe run measures the inference stage's comm/compute split, then
//! the link bandwidth is scaled so the two sides are matched (clamped to
//! [0.25, 100] Gbps), and the whole sweep runs at that fixed network.
//!
//! Acceptance: the best chunk size must cut simulated inference time
//! ≥ 1.3× vs `chunk_rows = 0`, with **bit-identical** embeddings across
//! the entire sweep. `DEAL_PIPELINE_BENCH_LAX=1` (CI smoke) reports
//! without asserting. Emits `target/bench_results/BENCH_pipeline.json`.
//!
//! Run: `cargo bench --bench pipeline_overlap [-- --full]`

use deal::cluster::net::with_chunk_rows;
use deal::config::DealConfig;
use deal::coordinator::{Pipeline, RunReport};
use deal::primitives::costs;
use deal::util::bench::{BenchArgs, Report, Table};
use deal::util::human_secs;

const SWEEP: [usize; 3] = [64, 256, 1024];
const FLOOR: f64 = 1.3;

fn bench_cfg(scale: f64, bandwidth_gbps: f64) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "products-sim".into();
    cfg.dataset.scale = scale;
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = 2; // the 2×2 grid: P = 2 row groups of M = 2
    cfg.cluster.bandwidth_gbps = bandwidth_gbps;
    // cores = 1 isolates the overlap law from the capacity divisor: the
    // calibration below matches the wire to whatever compute the host
    // actually delivers, so the regime — not absolute speed — is pinned.
    cfg.cluster.cores = 1.0;
    cfg.model.kind = "gcn".into();
    cfg.model.layers = 2;
    cfg.model.fanout = 10;
    cfg.exec.feature_prep = "redistribute".into();
    cfg
}

struct Obs {
    chunk_rows: usize,
    infer_sim: f64,
    total_sim: f64,
    comm_wait: f64,
    compute: f64,
    chunks: u64,
    report: RunReport,
}

fn run_once(scale: f64, bandwidth_gbps: f64, chunk_rows: usize) -> Obs {
    let report = with_chunk_rows(chunk_rows, || {
        Pipeline::new(bench_cfg(scale, bandwidth_gbps)).run().expect("pipeline run failed")
    });
    let stage = report
        .stages
        .0
        .iter()
        .find(|s| s.name == "inference")
        .expect("inference stage present");
    let cluster = stage.cluster.as_ref().expect("inference has a cluster report");
    let compute = cluster
        .machines
        .iter()
        .map(|m| m.sim_compute_secs)
        .fold(0.0, f64::max);
    Obs {
        chunk_rows,
        infer_sim: stage.sim_secs,
        total_sim: report.stages.total(),
        comm_wait: cluster.max_comm_wait(),
        compute,
        chunks: cluster.total_chunks(),
        report,
    }
}

fn main() {
    let args = BenchArgs::parse();
    let lax = std::env::var("DEAL_PIPELINE_BENCH_LAX").map_or(false, |v| v != "0");
    let scale = args.pick(1.0 / 16.0, 1.0 / 4.0); // 4096 / 16384 nodes

    let mut report = Report::new("pipeline_overlap");
    report.note(format!(
        "products-sim scale={} | 2×2 cluster, cores=1, gcn L=2 fanout=10, prep=redistribute{}",
        scale,
        if lax { " | LAX (report only)" } else { "" },
    ));

    // ---- calibration probe: match the wire to the host's compute -------
    let probe = run_once(scale, 25.0, 0);
    let ratio = probe.comm_wait / probe.compute.max(1e-9);
    let bw = (25.0 * ratio).clamp(0.25, 100.0);
    report.note(format!(
        "probe @25 Gbps: comm(max) {} vs compute(max) {} → calibrated bandwidth {:.2} Gbps",
        human_secs(probe.comm_wait),
        human_secs(probe.compute),
        bw,
    ));

    // ---- sweep at the calibrated network -------------------------------
    let mono = run_once(scale, bw, 0);
    let base_emb = mono.report.embeddings.as_ref().expect("embeddings kept");
    let mut rows: Vec<Obs> = vec![];
    for &chunk in &SWEEP {
        let obs = run_once(scale, bw, chunk);
        assert_eq!(
            obs.report.embeddings.as_ref().expect("embeddings kept"),
            base_emb,
            "embeddings diverged at chunk_rows={}",
            chunk
        );
        rows.push(obs);
    }
    report.note("bit-equality: embeddings identical across the whole sweep".to_string());

    let mut table = Table::new(
        "chunk_rows sweep (simulated time; speedup vs monolithic)",
        &["chunk_rows", "inference", "total e2e", "comm(max)", "compute(max)", "chunks", "speedup"],
    );
    let fmt_row = |o: &Obs, speedup: f64| {
        vec![
            o.chunk_rows.to_string(),
            human_secs(o.infer_sim),
            human_secs(o.total_sim),
            human_secs(o.comm_wait),
            human_secs(o.compute),
            o.chunks.to_string(),
            format!("{:.2}x", speedup),
        ]
    };
    table.row(&fmt_row(&mono, 1.0));
    for o in &rows {
        table.row(&fmt_row(o, mono.infer_sim / o.infer_sim.max(1e-12)));
    }
    report.add_table(table);

    // ---- closed-form cross-check ---------------------------------------
    let lat = 100e-6;
    let kstar = costs::optimal_chunks(mono.comm_wait, mono.compute, lat);
    report.note(format!(
        "closed form: T(k) = max(C, X) + min(C, X)/k + (k−1)·lat → ideal {:.2}x at k* = {}",
        (mono.comm_wait + mono.compute)
            / costs::pipelined_step_secs(
                mono.comm_wait + costs::chunking_overhead_secs(lat, kstar),
                mono.compute,
                kstar,
            ),
        kstar,
    ));

    let best = rows
        .iter()
        .min_by(|a, b| a.infer_sim.partial_cmp(&b.infer_sim).unwrap())
        .unwrap();
    let speedup = mono.infer_sim / best.infer_sim.max(1e-12);
    report.note(format!(
        "best: chunk_rows={} → {:.2}x over monolithic (floor {:.1}x)",
        best.chunk_rows, speedup, FLOOR,
    ));

    // ---- machine-readable trajectory -----------------------------------
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str(&format!(
        "  \"bench\": \"pipeline_overlap\",\n  \"scale\": {},\n  \"bandwidth_gbps\": {:.3},\n",
        scale, bw
    ));
    json.push_str(&format!(
        "  \"bit_identical\": true,\n  \"best_chunk_rows\": {},\n  \"best_speedup\": {:.3},\n",
        best.chunk_rows, speedup
    ));
    json.push_str("  \"sweep\": [\n");
    let all: Vec<&Obs> = std::iter::once(&mono).chain(rows.iter()).collect();
    for (i, o) in all.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"chunk_rows\": {}, \"infer_sim_secs\": {:.6}, \"total_sim_secs\": {:.6}, \
             \"chunks\": {}}}{}\n",
            o.chunk_rows,
            o.infer_sim,
            o.total_sim,
            o.chunks,
            if i + 1 == all.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let dir = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let json_path = dir.join("BENCH_pipeline.json");
    std::fs::write(&json_path, &json).expect("write BENCH_pipeline.json");
    report.note(format!("wrote {}", json_path.display()));
    report.finish();

    if !lax {
        assert!(
            speedup >= FLOOR,
            "best chunk size {:.2}x below the {:.1}x floor (mono {}, best {})",
            speedup,
            FLOOR,
            human_secs(mono.infer_sim),
            human_secs(best.infer_sim),
        );
    }
}
