//! Fig. 19: the §3.5 system optimizations — partitioned communication and
//! pipelining — for SPMM and SDDMM (ablation: monolithic → grouped →
//! pipelined).

mod common;

use std::sync::Arc;

use deal::cluster::Cluster;
use deal::primitives::sddmm::{sddmm, SddmmAlgo, SddmmInput};
use deal::primitives::spmm::{deal_spmm, EdgeValues, SpmmInput};
use deal::primitives::ExecMode;
use deal::util::bench::{BenchArgs, Report, Table};

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("fig19_pipeline");
    let machines = args.pick(vec![4usize], vec![2, 4, 8]);
    let group_cols = args.pick(512, 4096);
    for prim in ["spmm", "sddmm"] {
        let mut table = Table::new(
            &format!("{} execution modes (sim ms; speedup vs monolithic)", prim),
            &["dataset", "machines", "naive", "grouped", "pipelined", "grouped ×", "pipelined ×", "peak mem naive", "peak mem piped"],
        );
        for name in common::DATASETS {
            for &w in &machines {
                let m = 2usize.min(w);
                let p = w / m;
                let setup = common::prim_setup(name, args.quick, p, m, Some(128));
                let mut times = Vec::new();
                let mut mems = Vec::new();
                for mode in [ExecMode::Naive, ExecMode::Grouped, ExecMode::Pipelined] {
                    let plan = setup.plan.clone();
                    let tiles = Arc::clone(&setup.tiles);
                    let subs = Arc::clone(&setup.subs);
                    let prim2 = prim.to_string();
                    let cluster = Cluster::new(plan.world(), common::net());
                    let (_, rep) = cluster
                        .run(move |ctx| {
                            let (p_idx, _) = plan.coords_of(ctx.rank);
                            let (sub, svals) = &subs[p_idx];
                            if prim2 == "spmm" {
                                let input = SpmmInput {
                                    plan: &plan,
                                    g: sub,
                                    vals: EdgeValues::Scalar(svals),
                                    h: &tiles[ctx.rank],
                                };
                                deal_spmm(ctx, &input, &deal::runtime::Native, mode, group_cols, 7);
                            } else {
                                let input =
                                    SddmmInput { plan: &plan, g: sub, h: &tiles[ctx.rank] };
                                sddmm(ctx, &input, SddmmAlgo::Split, mode, group_cols, 11);
                            }
                        })
                        .unwrap();
                    times.push(rep.makespan());
                    mems.push(rep.max_peak_mem());
                }
                table.row(&[
                    name.into(),
                    w.to_string(),
                    common::fmt_ms(times[0]),
                    common::fmt_ms(times[1]),
                    common::fmt_ms(times[2]),
                    common::speedup(times[0], times[1]),
                    common::speedup(times[0], times[2]),
                    deal::util::human_bytes(mems[0]),
                    deal::util::human_bytes(mems[2]),
                ]);
            }
        }
        report.add_table(table);
    }
    report.note("paper: partitioned comm 2.15–3.09x (SPMM) and 1.57–2.09x (SDDMM); pipelining adds 1.47–2.15x; combined 3.5–4.7x".to_string());
    report.finish();
}
