//! Out-of-core smoke: a papers-xl workload whose working set exceeds the
//! storage budget completes on the paged tier and matches the unbounded
//! in-memory run bit for bit (EXPERIMENTS.md §Storage; DESIGN.md
//! §Out-of-core-storage).
//!
//! The **in-memory baseline at the constrained budget is skipped by
//! construction** — holding the working set resident is exactly what the
//! budget forbids — and the skip is recorded in the emitted JSON; parity
//! is asserted against the *unbounded* reference run instead, which is
//! the bit-identical ground truth the determinism contract guarantees.
//!
//! Emits `target/bench_results/BENCH_storage.json`.
//!
//! Run: `cargo bench --bench storage_oom [-- --full]`

use deal::config::DealConfig;
use deal::coordinator::{Pipeline, RunReport};
use deal::graph::datasets;
use deal::storage::{with_mem_budget, with_page_rows};
use deal::util::bench::{BenchArgs, Report, Table};
use deal::util::{human_bytes, human_secs};

fn bench_cfg(scale: f64) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "papers-xl".into();
    cfg.dataset.scale = scale;
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = 2;
    cfg.model.kind = "gcn".into();
    cfg.model.layers = 2;
    cfg.model.fanout = 10;
    cfg.exec.feature_prep = "fused".into();
    cfg
}

struct Obs {
    budget: u64,
    report: RunReport,
    faults: u64,
    evictions: u64,
    spill: u64,
    resident: u64,
    wall: f64,
}

fn run_once(cfg: &DealConfig, budget: u64, page_rows: usize) -> Obs {
    let t0 = std::time::Instant::now();
    let report = with_mem_budget(budget, || {
        with_page_rows(page_rows, || Pipeline::new(cfg.clone()).run().expect("pipeline run"))
    });
    let wall = t0.elapsed().as_secs_f64();
    let (mut faults, mut evictions, mut spill, mut resident) = (0u64, 0u64, 0u64, 0u64);
    for stage in &report.stages.0 {
        if let Some(c) = &stage.cluster {
            faults += c.total_page_faults();
            spill += c.total_spill_bytes();
            resident = resident.max(c.max_storage_resident());
            evictions += c.machines.iter().map(|m| m.storage.evictions).sum::<u64>();
        }
    }
    Obs { budget, report, faults, evictions, spill, resident, wall }
}

fn main() {
    let args = BenchArgs::parse();
    // quick: 4096 nodes (feature table 2 MiB); full: 32768 nodes (16 MiB)
    let scale = args.pick(1.0 / 64.0, 1.0 / 8.0);
    let page_rows = 64usize;
    let spec = datasets::spec("papers-xl").expect("papers-xl registered");
    let table_bytes = datasets::feature_table_bytes(spec, scale);
    // the budget undercuts the feature table ~8× — the working set
    // cannot be held resident
    let budget = (table_bytes / 8).max(1);
    let cfg = bench_cfg(scale);

    let mut report = Report::new("storage_oom");
    report.note(format!(
        "papers-xl scale={} | feature table {} | budget {} ({}× under) | page_rows {}",
        scale,
        human_bytes(table_bytes),
        human_bytes(budget),
        table_bytes / budget,
        page_rows,
    ));

    // ---- unbounded reference (the bit-identical ground truth) ----------
    let reference = run_once(&cfg, 0, page_rows);
    // ---- paged run under the constrained budget ------------------------
    let paged = run_once(&cfg, budget, page_rows);

    let ref_emb = reference.report.embeddings.as_ref().expect("embeddings kept");
    let paged_emb = paged.report.embeddings.as_ref().expect("embeddings kept");
    assert_eq!(
        paged_emb, ref_emb,
        "paged embeddings diverged from the unbounded reference"
    );
    report.note("bit-equality: paged run identical to the unbounded reference".to_string());
    assert!(paged.faults > 0, "a working set over budget must fault");
    assert!(paged.evictions > 0, "a working set over budget must evict");
    assert!(
        paged.resident <= budget.max((page_rows * spec.feature_dim * 4) as u64)
            + (page_rows * spec.feature_dim * 4) as u64,
        "cache residency {} blew the budget {}",
        paged.resident,
        budget
    );

    let mut table = Table::new(
        "working set > budget (paged vs unbounded reference)",
        &["run", "budget", "faults", "evictions", "spill traffic", "peak cache", "sim e2e", "wall"],
    );
    let fmt_row = |name: &str, o: &Obs| {
        vec![
            name.to_string(),
            if o.budget == 0 { "unbounded".into() } else { human_bytes(o.budget) },
            o.faults.to_string(),
            o.evictions.to_string(),
            human_bytes(o.spill),
            human_bytes(o.resident),
            human_secs(o.report.stages.total()),
            human_secs(o.wall),
        ]
    };
    table.row(&fmt_row("reference", &reference));
    table.row(&fmt_row("paged", &paged));
    report.add_table(table);
    report.note(format!(
        "in-memory baseline at budget {}: SKIPPED — reason: holding the {} working set \
         resident is precisely what the budget forbids; parity asserted against the \
         unbounded reference instead",
        human_bytes(budget),
        human_bytes(table_bytes),
    ));

    // ---- machine-readable summary --------------------------------------
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str(&format!(
        "  \"bench\": \"storage_oom\",\n  \"dataset\": \"papers-xl\",\n  \"scale\": {},\n",
        scale
    ));
    json.push_str(&format!(
        "  \"feature_table_bytes\": {},\n  \"budget_bytes\": {},\n  \"page_rows\": {},\n",
        table_bytes, budget, page_rows
    ));
    json.push_str("  \"paged_run\": {\n");
    json.push_str(&format!(
        "    \"completed\": true,\n    \"bit_identical_to_unbounded\": true,\n    \"page_faults\": {},\n    \"evictions\": {},\n    \"spill_bytes\": {},\n    \"peak_cache_resident_bytes\": {},\n    \"sim_secs\": {:.6}\n",
        paged.faults,
        paged.evictions,
        paged.spill,
        paged.resident,
        paged.report.stages.total()
    ));
    json.push_str("  },\n");
    json.push_str("  \"in_memory_baseline\": {\n");
    json.push_str("    \"skipped\": true,\n");
    json.push_str(
        "    \"reason\": \"working set exceeds the byte budget by construction; the unbounded reference run provides the bit-identical ground truth\"\n",
    );
    json.push_str("  }\n}\n");
    let dir = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let json_path = dir.join("BENCH_storage.json");
    std::fs::write(&json_path, &json).expect("write BENCH_storage.json");
    report.note(format!("wrote {}", json_path.display()));
    report.finish();
}
