//! Planner-vs-fixed sweep on the end-to-end pipeline (EXPERIMENTS.md
//! §Autotune): on each shape, run every fixed execution variant
//! (mode × chunk size) and then the cost-model-driven planner, and show
//! the planner matching or beating the best fixed configuration's
//! simulated inference time without changing a single output bit.
//!
//! Two shapes cover both cluster grids: products-sim on the 2×2 grid
//! (graph- and feature-parallel) and spammer-sim on the 1×4 grid
//! (feature-parallel only). Host CPUs vary, so each shape self-calibrates
//! like `pipeline_overlap`: a probe run measures the inference stage's
//! comm/compute split at 25 Gbps, then the link bandwidth is scaled so
//! the two sides are matched (clamped to [0.25, 100] Gbps).
//!
//! Acceptance: embeddings **bit-identical** across every fixed variant
//! and the planner run (always asserted — never LAX), and planner sim
//! time ≤ best-fixed × 1.10. `DEAL_AUTOTUNE_BENCH_LAX=1` (CI smoke)
//! relaxes only the time gate. Emits
//! `target/bench_results/BENCH_autotune.json`.
//!
//! Run: `cargo bench --bench autotune_planner [-- --full]`

use deal::cluster::net::with_chunk_rows;
use deal::config::DealConfig;
use deal::coordinator::{Pipeline, RunReport};
use deal::runtime::autotune::with_autotune;
use deal::util::bench::{BenchArgs, Report, Table};
use deal::util::human_secs;

const MODES: [&str; 3] = ["monolithic", "grouped", "pipelined"];
const CHUNKS: [usize; 4] = [0, 64, 256, 1024];

/// Time-gate slack for the planner against the best fixed row: the cost
/// model prices closed forms, not the simulator's exact event schedule.
const SLACK: f64 = 1.10;

struct Shape {
    dataset: &'static str,
    feature_parts: usize,
    grid: &'static str,
}

const SHAPES: [Shape; 2] = [
    Shape { dataset: "products-sim", feature_parts: 2, grid: "2x2" },
    Shape { dataset: "spammer-sim", feature_parts: 4, grid: "1x4" },
];

fn bench_cfg(shape: &Shape, scale: f64, bandwidth_gbps: f64) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = shape.dataset.into();
    cfg.dataset.scale = scale;
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = shape.feature_parts;
    cfg.cluster.bandwidth_gbps = bandwidth_gbps;
    // cores = 1 pins the comm/compute regime rather than absolute speed
    // (the probe calibration matches the wire to the host's compute).
    cfg.cluster.cores = 1.0;
    cfg.model.kind = "gcn".into();
    cfg.model.layers = 2;
    cfg.model.fanout = 10;
    cfg.exec.feature_prep = "redistribute".into();
    cfg
}

struct Obs {
    mode: &'static str,
    chunk_rows: usize,
    infer_sim: f64,
    comm_wait: f64,
    compute: f64,
    report: RunReport,
}

fn observe(mode: &'static str, chunk_rows: usize, report: RunReport) -> Obs {
    let stage = report
        .stages
        .0
        .iter()
        .find(|s| s.name == "inference")
        .expect("inference stage present");
    let cluster = stage.cluster.as_ref().expect("inference has a cluster report");
    let compute = cluster
        .machines
        .iter()
        .map(|m| m.sim_compute_secs)
        .fold(0.0, f64::max);
    let (infer_sim, comm_wait) = (stage.sim_secs, cluster.max_comm_wait());
    Obs { mode, chunk_rows, infer_sim, comm_wait, compute, report }
}

fn run_fixed(
    shape: &Shape,
    scale: f64,
    bandwidth_gbps: f64,
    mode: &'static str,
    chunk_rows: usize,
) -> Obs {
    let mut cfg = bench_cfg(shape, scale, bandwidth_gbps);
    cfg.exec.mode = mode.into();
    // fixed rows stay fixed even under an ambient DEAL_AUTOTUNE=1
    let report = with_autotune(false, || {
        with_chunk_rows(chunk_rows, || {
            Pipeline::new(cfg).run().expect("pipeline run failed")
        })
    });
    observe(mode, chunk_rows, report)
}

fn run_planner(shape: &Shape, scale: f64, bandwidth_gbps: f64) -> Obs {
    let mut cfg = bench_cfg(shape, scale, bandwidth_gbps);
    cfg.exec.autotune = true;
    let report = Pipeline::new(cfg).run().expect("autotuned pipeline run failed");
    observe("planner", 0, report)
}

fn main() {
    let args = BenchArgs::parse();
    let lax = std::env::var("DEAL_AUTOTUNE_BENCH_LAX").map_or(false, |v| v != "0");
    let scale = args.pick(1.0 / 16.0, 1.0 / 4.0);

    let mut report = Report::new("autotune_planner");
    report.note(format!(
        "4 machines, cores=1, gcn L=2 fanout=10, prep=redistribute, scale={}{}",
        scale,
        if lax { " | LAX (time gate report-only)" } else { "" },
    ));

    let mut shape_jsons: Vec<String> = Vec::new();
    for shape in &SHAPES {
        // ---- calibration probe: match the wire to the host's compute ---
        let probe = run_fixed(shape, scale, 25.0, "monolithic", 0);
        let ratio = probe.comm_wait / probe.compute.max(1e-9);
        let bw = (25.0 * ratio).clamp(0.25, 100.0);
        report.note(format!(
            "{} {}: probe @25 Gbps comm(max) {} vs compute(max) {} → {:.2} Gbps",
            shape.dataset,
            shape.grid,
            human_secs(probe.comm_wait),
            human_secs(probe.compute),
            bw,
        ));

        // ---- exhaustive fixed sweep at the calibrated network ----------
        let mut rows: Vec<Obs> = Vec::new();
        for &mode in &MODES {
            for &chunk in &CHUNKS {
                rows.push(run_fixed(shape, scale, bw, mode, chunk));
            }
        }
        let base_emb = rows[0].report.embeddings.as_ref().expect("embeddings kept").clone();
        for o in &rows {
            assert_eq!(
                o.report.embeddings.as_ref().expect("embeddings kept"),
                &base_emb,
                "{} {}: embeddings diverged at mode={} chunk_rows={}",
                shape.dataset,
                shape.grid,
                o.mode,
                o.chunk_rows,
            );
        }

        // ---- the planner ----------------------------------------------
        let tuned = run_planner(shape, scale, bw);
        // Bit-identity is the contract — asserted even under LAX.
        assert_eq!(
            tuned.report.embeddings.as_ref().expect("embeddings kept"),
            &base_emb,
            "{} {}: planner-selected plan changed output values",
            shape.dataset,
            shape.grid,
        );
        let plan = tuned.report.autotune.clone().expect("autotuned run records its plan");

        let best = rows
            .iter()
            .min_by(|a, b| a.infer_sim.partial_cmp(&b.infer_sim).unwrap())
            .unwrap();
        let vs_best = tuned.infer_sim / best.infer_sim.max(1e-12);

        let mut table = Table::new(
            &format!("{} {} (simulated inference time)", shape.dataset, shape.grid),
            &["variant", "chunk_rows", "inference", "comm(max)", "compute(max)", "vs planner"],
        );
        for o in rows.iter().chain(std::iter::once(&tuned)) {
            table.row(&vec![
                o.mode.to_string(),
                if o.mode == "planner" {
                    format!("plan:{}", plan.chunk_rows)
                } else {
                    o.chunk_rows.to_string()
                },
                human_secs(o.infer_sim),
                human_secs(o.comm_wait),
                human_secs(o.compute),
                format!("{:.2}x", o.infer_sim / tuned.infer_sim.max(1e-12)),
            ]);
        }
        report.add_table(table);

        let layer_descs: Vec<String> = plan
            .layers
            .iter()
            .map(|c| {
                format!(
                    "{{\"mode\": \"{:?}\", \"chunk_rows\": {}, \"group_cols\": {}}}",
                    c.mode, c.chunk_rows, c.group_cols
                )
            })
            .collect();
        report.note(format!(
            "{} {}: planner {} vs best fixed {} ({} chunk_rows={}) → {:.3}x; plan threads={} layers={}",
            shape.dataset,
            shape.grid,
            human_secs(tuned.infer_sim),
            human_secs(best.infer_sim),
            best.mode,
            best.chunk_rows,
            vs_best,
            plan.threads,
            layer_descs.join(" "),
        ));

        let mut sweep_json = String::new();
        for (i, o) in rows.iter().enumerate() {
            sweep_json.push_str(&format!(
                "        {{\"mode\": \"{}\", \"chunk_rows\": {}, \"infer_sim_secs\": {:.6}}}{}\n",
                o.mode,
                o.chunk_rows,
                o.infer_sim,
                if i + 1 == rows.len() { "" } else { "," }
            ));
        }
        shape_jsons.push(format!(
            "    {{\n      \"dataset\": \"{}\",\n      \"grid\": \"{}\",\n      \
             \"bandwidth_gbps\": {:.3},\n      \"bit_identical\": true,\n      \
             \"planner_infer_sim_secs\": {:.6},\n      \"planner_predicted_secs\": {:.6},\n      \
             \"planner_threads\": {},\n      \"planner_layers\": [{}],\n      \
             \"best_fixed\": {{\"mode\": \"{}\", \"chunk_rows\": {}, \"infer_sim_secs\": {:.6}}},\n      \
             \"planner_vs_best\": {:.4},\n      \"sweep\": [\n{}      ]\n    }}",
            shape.dataset,
            shape.grid,
            bw,
            tuned.infer_sim,
            plan.predicted_secs,
            plan.threads,
            layer_descs.join(", "),
            best.mode,
            best.chunk_rows,
            best.infer_sim,
            vs_best,
            sweep_json,
        ));

        if !lax {
            assert!(
                tuned.infer_sim <= best.infer_sim * SLACK + 1e-9,
                "{} {}: planner {} exceeds best fixed {} × {:.2} slack",
                shape.dataset,
                shape.grid,
                human_secs(tuned.infer_sim),
                human_secs(best.infer_sim),
                SLACK,
            );
        }
    }

    // ---- machine-readable trajectory -----------------------------------
    let json = format!(
        "{{\n  \"bench\": \"autotune_planner\",\n  \"scale\": {},\n  \"slack\": {},\n  \
         \"shapes\": [\n{}\n  ]\n}}\n",
        scale,
        SLACK,
        shape_jsons.join(",\n"),
    );
    let dir = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let json_path = dir.join("BENCH_autotune.json");
    std::fs::write(&json_path, &json).expect("write BENCH_autotune.json");
    report.note(format!("wrote {}", json_path.display()));
    report.finish();
}
