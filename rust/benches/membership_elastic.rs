//! Elastic membership migration cost (EXPERIMENTS.md §Membership):
//! incremental re-sharding must move **strictly fewer bytes** than a
//! naive full re-shard for the same membership event (a hard assert —
//! it is the tentpole's core claim, not a timing gate), and a killed
//! rank's band must come back from its per-shard durable store instead
//! of being re-shipped over the wire.
//!
//! The run: twin elastic clusters over the same table walk the same
//! shrink event in `Incremental` vs `FullReshard` mode; then a kill is
//! recovered once with durable shard stores and once wire-only. Every
//! resulting table is checked bit-identical to the fixed-world
//! reference before any number is reported.
//!
//! `DEAL_MEMBERSHIP_BENCH_LAX=1` downgrades only the incremental-vs-full
//! *wall-time* gate to a warning (CI smoke on contended runners); the
//! byte and bit-identity gates always hard-fail.
//!
//! Emits `target/bench_results/BENCH_membership.json`.
//!
//! Run: `cargo bench --bench membership_elastic [-- --full]`

use deal::cluster::membership::{ElasticCluster, ElasticOpts, MembershipEvent, MigrationMode};
use deal::tensor::Matrix;
use deal::util::bench::{time_once, BenchArgs, Report, Table};
use deal::util::rng::Rng;
use deal::util::{human_bytes, human_secs};

fn main() {
    let args = BenchArgs::parse();
    let lax = std::env::var("DEAL_MEMBERSHIP_BENCH_LAX").map_or(false, |v| v != "0");
    // quick: 2k × 64 table on 8 ranks; full: 8k × 128 on 12
    let (nodes, dim, world) = if args.quick { (2048, 64, 8) } else { (8192, 128, 12) };

    let mut report = Report::new("membership_elastic");
    let mut rng = Rng::new(0x3_1A57_1C);
    let full_table = Matrix::random(nodes, dim, 1.0, &mut rng);
    report.note(format!("table: {} × {} on {} ranks", nodes, dim, world));

    // ---- shrink: incremental vs naive full re-shard --------------------
    let ev = MembershipEvent::Leave { rank: world - 1 };
    let mut inc =
        ElasticCluster::new(&full_table, world, ElasticOpts::default()).expect("cluster");
    let mut naive =
        ElasticCluster::new(&full_table, world, ElasticOpts::default()).expect("cluster");
    let (s_inc, inc_wall) = time_once(|| inc.apply_mode(ev, MigrationMode::Incremental));
    let s_inc = s_inc.expect("incremental migration");
    let (s_full, full_wall) = time_once(|| naive.apply_mode(ev, MigrationMode::FullReshard));
    let s_full = s_full.expect("full re-shard");
    inc.verify_against(&full_table).expect("incremental table bit-identical");
    naive.verify_against(&full_table).expect("full-reshard table bit-identical");
    report.note("bit-identity: both migration modes reproduce the fixed-world table (exact)");

    // the core claim, hard-asserted: only the bands changing owner move
    assert!(
        s_inc.bytes_on_wire < s_full.bytes_on_wire,
        "incremental migration moved {} >= full re-shard's {}",
        s_inc.bytes_on_wire,
        s_full.bytes_on_wire
    );
    assert!(s_inc.rows_moved < s_full.rows_moved);
    assert_eq!(s_full.rows_moved, nodes, "a full re-shard ships every row");
    let byte_ratio = s_full.bytes_on_wire as f64 / s_inc.bytes_on_wire.max(1) as f64;

    let mut t = Table::new(
        &format!("shrink {} → {} ranks ({})", world, world - 1, ev),
        &["mode", "rows moved", "wire bytes", "msgs", "sim", "wall"],
    );
    t.row(&[
        "incremental".into(),
        s_inc.rows_moved.to_string(),
        human_bytes(s_inc.bytes_on_wire),
        s_inc.msgs.to_string(),
        human_secs(s_inc.sim_secs),
        human_secs(inc_wall),
    ]);
    t.row(&[
        "full re-shard".into(),
        s_full.rows_moved.to_string(),
        human_bytes(s_full.bytes_on_wire),
        s_full.msgs.to_string(),
        human_secs(s_full.sim_secs),
        human_secs(full_wall),
    ]);
    report.add_table(t);
    report.note(format!("incremental moves {:.2}x fewer wire bytes", byte_ratio));

    let wall_pass = inc_wall <= full_wall;
    if !wall_pass {
        let msg = format!(
            "incremental wall time ({}) exceeded full re-shard ({})",
            human_secs(inc_wall),
            human_secs(full_wall)
        );
        if lax {
            report.note(format!("LAX: {}", msg));
        } else {
            panic!("{}", msg);
        }
    }

    // ---- kill: durable shard recovery vs wire-only rebuild -------------
    let dir = std::env::temp_dir().join(format!("deal-member-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let kill = MembershipEvent::Kill { rank: world / 2 };
    let opts = ElasticOpts { durable_root: Some(dir.clone()), ..ElasticOpts::default() };
    let mut durable = ElasticCluster::new(&full_table, world, opts).expect("cluster");
    let mut wire_only =
        ElasticCluster::new(&full_table, world, ElasticOpts::default()).expect("cluster");
    let (s_dur, dur_wall) = time_once(|| durable.apply(kill));
    let s_dur = s_dur.expect("durable kill recovery");
    let (s_wire, wire_wall) = time_once(|| wire_only.apply(kill));
    let s_wire = s_wire.expect("wire kill rebuild");
    durable.verify_against(&full_table).expect("durable recovery bit-identical");
    wire_only.verify_against(&full_table).expect("wire rebuild bit-identical");
    assert!(s_dur.recovered_from_durable, "durable path did not use the shard store");
    assert!(s_dur.rows_recovered > 0);
    assert!(
        s_dur.bytes_on_wire < s_wire.bytes_on_wire,
        "durable recovery moved {} >= wire rebuild's {}",
        s_dur.bytes_on_wire,
        s_wire.bytes_on_wire
    );

    let mut t = Table::new(
        &format!("kill rank {} on {} ranks", world / 2, world),
        &["recovery", "rows recovered", "rows shipped", "wire bytes", "sim", "wall"],
    );
    t.row(&[
        "durable shard store".into(),
        s_dur.rows_recovered.to_string(),
        s_dur.rows_moved.to_string(),
        human_bytes(s_dur.bytes_on_wire),
        human_secs(s_dur.sim_secs),
        human_secs(dur_wall),
    ]);
    t.row(&[
        "wire-only rebuild".into(),
        s_wire.rows_recovered.to_string(),
        s_wire.rows_moved.to_string(),
        human_bytes(s_wire.bytes_on_wire),
        human_secs(s_wire.sim_secs),
        human_secs(wire_wall),
    ]);
    report.add_table(t);

    // ---- machine-readable summary (schema: EXPERIMENTS.md §Membership) -
    let json = format!(
        "{{\n  \"bench\": \"membership_elastic\",\n  \"quick\": {},\n  \"nodes\": {},\n  \"dim\": {},\n  \"world\": {},\n  \"shrink_incremental_bytes\": {},\n  \"shrink_full_bytes\": {},\n  \"shrink_byte_ratio\": {:.3},\n  \"shrink_incremental_rows\": {},\n  \"shrink_full_rows\": {},\n  \"shrink_incremental_sim_secs\": {:.6},\n  \"shrink_full_sim_secs\": {:.6},\n  \"kill_durable_bytes\": {},\n  \"kill_wire_bytes\": {},\n  \"kill_rows_recovered\": {},\n  \"kill_durable_sim_secs\": {:.6},\n  \"kill_wire_sim_secs\": {:.6},\n  \"bit_identical\": true,\n  \"pass\": {},\n  \"lax\": {}\n}}\n",
        args.quick,
        nodes,
        dim,
        world,
        s_inc.bytes_on_wire,
        s_full.bytes_on_wire,
        byte_ratio,
        s_inc.rows_moved,
        s_full.rows_moved,
        s_inc.sim_secs,
        s_full.sim_secs,
        s_dur.bytes_on_wire,
        s_wire.bytes_on_wire,
        s_dur.rows_recovered,
        s_dur.sim_secs,
        s_wire.sim_secs,
        wall_pass,
        lax
    );
    let out = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&out);
    let json_path = out.join("BENCH_membership.json");
    std::fs::write(&json_path, &json).expect("write BENCH_membership.json");
    report.note(format!("wrote {}", json_path.display()));

    let _ = std::fs::remove_dir_all(&dir);
    report.finish();
}
