//! Fig. 15: scalability — (a) weak scaling on RMAT graphs (processed edges
//! per second per machine), (b–d) strong scaling on the three datasets.

mod common;

use deal::coordinator::Pipeline;
use deal::graph::rmat::{rmat, RmatParams};
use deal::util::bench::{BenchArgs, Report, Table};

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("fig15_scalability");

    // ---- (a) weak scaling: graph grows with the cluster
    let machines = args.pick(vec![2usize, 4, 8], vec![2, 4, 8, 16]);
    let base_scale: u32 = args.pick(11, 14); // nodes per 2 machines
    let mut table = Table::new(
        "Fig 15a: weak scaling (RMAT, edges/s/machine, sampling+inference)",
        &["model", "machines", "nodes", "edges", "sim time ms", "edges/s/machine", "efficiency"],
    );
    let dir = std::path::PathBuf::from("data/bench");
    std::fs::create_dir_all(&dir).unwrap();
    for kind in ["gcn", "gat"] {
        let mut base_rate = 0.0;
        for &w in &machines {
            let scale = base_scale + (w as f64 / 2.0).log2() as u32;
            let el = rmat(scale, (1 << scale) * 20, RmatParams::paper(), 3);
            let path = dir.join(format!("weak-{}-{}.edges.bin", scale, args.quick));
            if !path.exists() {
                el.write_binary(&path).unwrap();
            }
            // drive through the primitive-level pipeline via a synthetic
            // registry-free config: reuse products-sim features dim by
            // overriding dataset with file is unsupported; use rmat sizes
            // via papers-sim scaled instead.
            let mut cfg = common::base_cfg("papers-sim", true);
            cfg.dataset.scale = (1u64 << scale) as f64 / (1u64 << 17) as f64;
            cfg.cluster.machines = w;
            cfg.cluster.feature_parts = 2.min(w);
            cfg.model.kind = kind.into();
            cfg.model.layers = 2;
            let mut pipe = Pipeline::new(cfg);
            pipe.keep_embeddings = false;
            let r = pipe.run().unwrap();
            let t = r.stages.sim_of("sampling") + r.stages.sim_of("inference");
            let edges = (1u64 << scale) * 15; // papers-sim avg degree
            let rate = edges as f64 / t / w as f64;
            if w == machines[0] {
                base_rate = rate;
            }
            table.row(&[
                kind.into(),
                w.to_string(),
                (1u64 << scale).to_string(),
                edges.to_string(),
                common::fmt_ms(t),
                format!("{:.2e}", rate),
                format!("{:.1}%", rate / base_rate * 100.0),
            ]);
        }
    }
    report.add_table(table);

    // ---- (b–d) strong scaling on the datasets
    let mut table = Table::new(
        "Fig 15b–d: strong scaling (speedup vs 2 machines)",
        &["model", "dataset", "machines", "sim ms", "speedup"],
    );
    for kind in ["gcn", "gat"] {
        for name in common::DATASETS {
            let mut base = 0.0;
            for &w in &machines {
                let mut cfg = common::base_cfg(name, args.quick);
                cfg.cluster.machines = w;
                cfg.cluster.feature_parts = 2.min(w);
                cfg.model.kind = kind.into();
                let mut pipe = Pipeline::new(cfg);
                pipe.keep_embeddings = false;
                let r = pipe.run().unwrap();
                let t = r.stages.sim_of("sampling") + r.stages.sim_of("inference");
                if w == machines[0] {
                    base = t;
                }
                table.row(&[
                    kind.into(),
                    name.into(),
                    w.to_string(),
                    common::fmt_ms(t),
                    common::speedup(base, t),
                ]);
            }
        }
    }
    report.add_table(table);
    report.note("paper: 48% weak-scaling efficiency at 16 machines; strong scaling 2.28–5.32x at 16; GAT scales better".to_string());
    report.finish();
}
