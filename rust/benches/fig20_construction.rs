//! Fig. 20: graph construction — Deal's fully distributed edge-list →
//! partitioned-CSR build vs the DistDGL-like single-worker pipeline.

mod common;

use deal::graph::builder::{build_distributed, build_single_worker};
use deal::graph::datasets;
use deal::util::bench::{BenchArgs, Report, Table};

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("fig20_construction");
    let machines = args.pick(vec![1usize, 2, 4], vec![1, 2, 4, 8]);
    let dir = std::path::PathBuf::from("data/bench");
    std::fs::create_dir_all(&dir).unwrap();
    let mut table = Table::new(
        "graph construction: DistDGL-like single worker vs Deal (sim ms)",
        &["dataset", "machines", "single-worker", "Deal", "speedup"],
    );
    for name in common::DATASETS {
        let ds = datasets::load(name, common::ds_scale(args.quick)).unwrap();
        let path = dir.join(format!("{}-{}.edges.bin", name, args.quick));
        if !path.exists() {
            ds.edges.write_binary(&path).unwrap();
        }
        for &w in &machines {
            let parts = w;
            let (_, sw) = build_single_worker(&path, w, parts, common::net()).unwrap();
            let (_, dist) = build_distributed(&path, w, parts, common::net()).unwrap();
            table.row(&[
                name.into(),
                w.to_string(),
                common::fmt_ms(sw.makespan()),
                common::fmt_ms(dist.makespan()),
                common::speedup(sw.makespan(), dist.makespan()),
            ]);
        }
    }
    report.add_table(table);
    report.note("paper: 7.92x / 21.05x / 11.99x average speedups; larger graphs gain more".to_string());
    report.finish();
}
