//! Warm restart vs cold recompute (EXPERIMENTS.md §Recovery): after a
//! crash, `Pipeline::warm_restart` must rebuild the serving table from
//! the durable store's log-over-checkpoint **bit-identically** (a hard
//! assert, even under lax mode — it is correctness, not performance) and
//! measurably faster than re-running the inference pipeline.
//!
//! The run: one cold pipeline (the thing restart avoids), a durable
//! store checkpointing its embeddings, a few journaled patch epochs on
//! top (so recovery replays a real log, not just a checkpoint read),
//! then a timed warm restart.
//!
//! `DEAL_RECOVERY_BENCH_LAX=1` downgrades only the warm<cold speed gate
//! to a warning (CI smoke on contended runners).
//!
//! Emits `target/bench_results/BENCH_recovery.json`.
//!
//! Run: `cargo bench --bench recovery_restart [-- --full]`

use deal::config::DealConfig;
use deal::coordinator::Pipeline;
use deal::graph::delta::UpdateBatch;
use deal::storage::{DurableOptions, DurableStore};
use deal::tensor::Matrix;
use deal::util::bench::{time_once, BenchArgs, Report, Table};
use deal::util::human_secs;
use deal::util::rng::Rng;

const JOURNALED_EPOCHS: u64 = 3;

fn cfg(scale: f64) -> DealConfig {
    let mut c = DealConfig::default();
    c.dataset.name = "products-sim".into();
    c.dataset.scale = scale;
    c.cluster.machines = 4;
    c.cluster.feature_parts = 2;
    c.model.layers = 2;
    c.model.fanout = 5;
    c
}

fn main() {
    let args = BenchArgs::parse();
    let lax = std::env::var("DEAL_RECOVERY_BENCH_LAX").map_or(false, |v| v != "0");
    // quick: 256-node graph; full: 1024 nodes
    let scale = args.pick(1.0 / 256.0, 1.0 / 64.0);
    let cfg = cfg(scale);

    let mut report = Report::new("recovery_restart");

    // ---- cold: the full inference pipeline (what restart avoids) -------
    let pipeline = Pipeline::new(cfg.clone());
    let (cold, cold_secs) = time_once(|| pipeline.run());
    let cold = cold.expect("cold pipeline");
    let embeddings = cold.embeddings.clone().expect("embeddings kept");
    let (n, d) = (embeddings.rows, embeddings.cols);
    report.note(format!(
        "cold pipeline: {} × {} embeddings in {}",
        n,
        d,
        human_secs(cold_secs)
    ));

    // ---- durable store: checkpoint + a journaled patch trail -----------
    let dir = std::env::temp_dir().join(format!("deal-recov-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut store =
        DurableStore::create(&dir, cfg.exec.seed, &embeddings, DurableOptions::default())
            .expect("create store");
    let mut expected = embeddings;
    let mut rng = Rng::new(0xBE5C);
    for epoch in 1..=JOURNALED_EPOCHS {
        // a synthetic patch epoch: ~4% of rows get fresh values — what a
        // delta refresh journals, minus the inference that produced it
        let rows: Vec<u32> = (0..(n / 25).max(8)).map(|_| rng.next_below(n) as u32).collect();
        let values = Matrix::random(rows.len(), d, 0.5, &mut rng);
        store
            .journal_delta(epoch, &UpdateBatch::default(), &rows, &values)
            .expect("journal patch");
        for (i, &r) in rows.iter().enumerate() {
            expected.row_mut(r as usize).copy_from_slice(values.row(i));
        }
    }
    let wal_records = store.wal_records();
    report.note(format!(
        "store: gen {} | {} wal records | {} journaled epochs",
        store.generation(),
        wal_records,
        JOURNALED_EPOCHS
    ));
    drop(store);

    // ---- warm: rebuild the serving state from disk ---------------------
    let (warm, warm_secs) = time_once(|| pipeline.warm_restart(&dir));
    let (warm_report, store, rec) = warm.expect("warm restart");
    assert_eq!(rec.epoch, JOURNALED_EPOCHS, "recovered to the journaled tip");
    assert_eq!(store.last_epoch(), JOURNALED_EPOCHS);

    // hard assert, no tolerance: recovery is bit-identical
    let recovered = warm_report.embeddings.as_ref().expect("recovered embeddings");
    assert_eq!((recovered.rows, recovered.cols), (n, d), "recovered shape");
    let a: Vec<u32> = recovered.data.iter().map(|v| v.to_bits()).collect();
    let b: Vec<u32> = expected.data.iter().map(|v| v.to_bits()).collect();
    assert_eq!(a, b, "warm restart is not bit-identical to the pre-crash table");
    report.note("bit-identity: recovered table == checkpoint + replayed patches (exact)");

    let speedup = cold_secs / warm_secs.max(1e-12);
    let mut t = Table::new("warm restart vs cold recompute", &["path", "wall", "speedup"]);
    t.row(&["cold pipeline".into(), human_secs(cold_secs), "1.00x".into()]);
    t.row(&["warm restart".into(), human_secs(warm_secs), format!("{:.2}x", speedup)]);
    report.add_table(t);

    let pass = warm_secs < cold_secs;
    if !pass {
        let msg = format!(
            "warm restart ({}) not faster than cold recompute ({})",
            human_secs(warm_secs),
            human_secs(cold_secs)
        );
        if lax {
            report.note(format!("LAX: {}", msg));
        } else {
            panic!("{}", msg);
        }
    }

    // ---- machine-readable summary (schema: EXPERIMENTS.md §Recovery) ---
    let json = format!(
        "{{\n  \"bench\": \"recovery_restart\",\n  \"quick\": {},\n  \"nodes\": {},\n  \"dim\": {},\n  \"epochs\": {},\n  \"wal_records\": {},\n  \"cold_secs\": {:.6},\n  \"warm_secs\": {:.6},\n  \"speedup\": {:.3},\n  \"bit_identical\": true,\n  \"recovery_sim_secs\": {:.6},\n  \"pass\": {},\n  \"lax\": {}\n}}\n",
        args.quick,
        n,
        d,
        JOURNALED_EPOCHS,
        wal_records,
        cold_secs,
        warm_secs,
        speedup,
        rec.sim_secs,
        pass,
        lax
    );
    let out = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&out);
    let json_path = out.join("BENCH_recovery.json");
    std::fs::write(&json_path, &json).expect("write BENCH_recovery.json");
    report.note(format!("wrote {}", json_path.display()));

    let _ = std::fs::remove_dir_all(&dir);
    report.finish();
}
