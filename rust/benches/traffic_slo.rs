//! Production traffic SLO gates (EXPERIMENTS.md §Traffic): replay a
//! deterministic Zipfian trace **open-loop** against the serving pool —
//! arrivals follow the trace schedule and never wait for completions, so
//! queueing collapse shows up in the tail instead of being absorbed by a
//! self-throttling driver — with delta-churn epochs published mid-flight,
//! and gate the per-class p50/p99/p999 latencies and goodput.
//!
//! Three audits ride along, and stay **hard asserts even under lax
//! mode** (they are correctness, not performance):
//! - determinism: the same seed + config serializes byte-identically,
//!   and a different seed diverges;
//! - conservation: every dispatched request lands in exactly one
//!   counter bucket (served / rejected / failed), per class;
//! - parity: replaying the same trace `Sequenced` under every batch
//!   policy yields identical per-request response digests.
//!
//! `DEAL_TRAFFIC_BENCH_LAX=1` downgrades only the latency/goodput SLO
//! gates to warnings (CI smoke on contended runners).
//!
//! Emits `target/bench_results/BENCH_traffic.json`.
//!
//! Run: `cargo bench --bench traffic_slo [-- --full]`

use std::sync::Arc;

use deal::config::DealConfig;
use deal::coordinator::delta::DeltaState;
use deal::runtime::Native;
use deal::serve::{BatchPolicy, PoolOpts, RequestClass, ServePool, ShardedTable, TableCell};
use deal::traffic::{
    churn_into_cell, replay, ReplayMode, ReplayOpts, ReplayReport, Trace, TraceConfig,
};
use deal::util::bench::{BenchArgs, Report, Table};
use deal::util::human_secs;

fn delta_cfg(scale: f64) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "products-sim".into();
    cfg.dataset.scale = scale;
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = 2;
    cfg.model.layers = 2;
    cfg.model.fanout = 5;
    cfg
}

/// One SLO gate: `value` must stay on the right side of `limit`.
struct Gate {
    name: &'static str,
    value: f64,
    limit: f64,
    /// true: pass iff value <= limit; false: pass iff value >= limit.
    upper_bound: bool,
}

impl Gate {
    fn pass(&self) -> bool {
        if self.upper_bound {
            self.value <= self.limit
        } else {
            self.value >= self.limit
        }
    }
}

fn gate(name: &'static str, value: f64, limit: f64, upper_bound: bool) -> Gate {
    Gate { name, value, limit, upper_bound }
}

fn class_latency(rep: &ReplayReport, class: RequestClass, which: &str) -> f64 {
    let lat = rep.stats.class(class).latency.as_ref();
    match (lat, which) {
        (Some(s), "p50") => s.p50,
        (Some(s), "p99") => s.p99,
        (Some(s), "p999") => s.p999,
        _ => f64::INFINITY, // a class that served nothing fails its gates
    }
}

/// `{:.6}`-formatted, or `null` for a non-finite value (a class that
/// served nothing has no latency summary) — keeps the JSON parseable.
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{:.6}", v)
    } else {
        "null".into()
    }
}

fn main() {
    let args = BenchArgs::parse();
    let lax = std::env::var("DEAL_TRAFFIC_BENCH_LAX").map_or(false, |v| v != "0");
    // quick: 256-node table, 10k requests (the acceptance floor);
    // full: 1024 nodes, 30k requests.
    let (scale, requests, speed) =
        args.pick((1.0 / 256.0, 10_000usize, 25.0), (1.0 / 64.0, 30_000, 20.0));
    let (workers, queue, max_batch) = (4usize, 4096usize, 64usize);
    let churn_batches = 4usize;

    let mut report = Report::new("traffic_slo");

    // ---- the table under test: a delta-capable embedding state ---------
    // (the sweep below inits its own copies — churn mutates the state)
    let mut state = DeltaState::init(delta_cfg(scale)).expect("delta state");
    let n = state.embeddings().rows;
    let d = state.embeddings().cols;

    let tcfg = TraceConfig {
        seed: 0x7F1C,
        n_nodes: n,
        requests,
        base_rate: 2500.0,
        zipf_s: 1.0,
        similar_fraction: 0.25,
        churn_batches,
        ..TraceConfig::default()
    };
    report.note(format!(
        "table {} × {} | {} requests @ {}x replay speed | zipf s={} | burst {}x | {} churn epochs | {} workers | queue {} | lax={}",
        n, d, requests, speed, tcfg.zipf_s, tcfg.burst_factor, churn_batches, workers, queue, lax
    ));

    // ---- determinism audit (hard assert, lax or not) -------------------
    let trace = Trace::generate(&tcfg);
    let bytes = trace.to_bytes();
    assert_eq!(
        Trace::generate(&tcfg).to_bytes(),
        bytes,
        "same seed + config must serialize byte-identically"
    );
    let other = Trace::generate(&TraceConfig { seed: tcfg.seed ^ 1, ..tcfg.clone() });
    assert_ne!(other.to_bytes(), bytes, "a distinct seed must produce a distinct trace");
    assert_eq!(trace.n_requests(), requests);
    assert_eq!(trace.n_churn(), churn_batches);
    report.note(format!(
        "determinism: trace of {} bytes is bit-identical across regeneration; seed^1 diverges",
        bytes.len()
    ));

    // ---- open-loop replay with mid-flight churn ------------------------
    let cell = Arc::new(TableCell::new(ShardedTable::from_inference_plan(
        state.plan(),
        state.embeddings(),
        0,
    )));
    let opts = PoolOpts { workers, queue_capacity: queue, max_batch, ..PoolOpts::default() };
    let pool = ServePool::spawn(Arc::clone(&cell), Arc::new(Native), opts);
    let replay_opts = ReplayOpts { mode: ReplayMode::OpenLoop { speed }, keep_responses: false };
    let rep = replay(&pool, &trace, &replay_opts, churn_into_cell(&mut state, &cell))
        .expect("open-loop replay");
    pool.shutdown();

    // conservation audit (hard assert, lax or not)
    assert_eq!(rep.dispatched, requests as u64);
    assert_eq!(rep.stats.failed, 0, "no request may fail");
    let mut total_submitted = 0u64;
    for c in &rep.stats.per_class {
        total_submitted += c.counters.submitted;
        assert_eq!(
            c.counters.accounted(),
            c.counters.submitted,
            "{} class leaked requests: {:?}",
            c.class.name(),
            c.counters
        );
    }
    assert_eq!(total_submitted, requests as u64);
    assert_eq!(rep.churn_epochs, (1..=churn_batches as u64).collect::<Vec<_>>());

    let mut lat_table = Table::new(
        "open-loop per-class latency (pool-side worker timestamps)",
        &["class", "submitted", "served", "rejected", "p50", "p99", "p999"],
    );
    for class in RequestClass::ALL {
        let c = rep.stats.class(class);
        lat_table.row(&[
            class.name().to_string(),
            c.counters.submitted.to_string(),
            c.counters.served.to_string(),
            c.counters.rejected.to_string(),
            human_secs(class_latency(&rep, class, "p50")),
            human_secs(class_latency(&rep, class, "p99")),
            human_secs(class_latency(&rep, class, "p999")),
        ]);
    }
    report.add_table(lat_table);
    report.note(format!(
        "goodput {:.0} responses/s | wall {} | max dispatch lag {}",
        rep.goodput,
        human_secs(rep.wall_secs),
        human_secs(rep.max_dispatch_lag_secs)
    ));

    // ---- SLO gates (generous absolute bounds; lax downgrades to warn) --
    let served_frac = rep.stats.served as f64 / requests as f64;
    let lat = |class: RequestClass, which: &str| class_latency(&rep, class, which);
    let gates = vec![
        gate("embed_p50_s", lat(RequestClass::Embed, "p50"), 0.010, true),
        gate("embed_p99_s", lat(RequestClass::Embed, "p99"), 0.050, true),
        gate("embed_p999_s", lat(RequestClass::Embed, "p999"), 0.250, true),
        gate("similar_p50_s", lat(RequestClass::Similar, "p50"), 0.020, true),
        gate("similar_p99_s", lat(RequestClass::Similar, "p99"), 0.100, true),
        gate("similar_p999_s", lat(RequestClass::Similar, "p999"), 0.500, true),
        gate("served_fraction", served_frac, 0.95, false),
        gate("goodput_rps", rep.goodput, 1000.0, false),
    ];
    let mut gate_table = Table::new(
        "SLO gates (DEAL_TRAFFIC_BENCH_LAX=1 downgrades failures to warnings)",
        &["gate", "value", "bound", "pass"],
    );
    for g in &gates {
        gate_table.row(&[
            g.name.to_string(),
            format!("{:.6}", g.value),
            format!("{} {:.6}", if g.upper_bound { "<=" } else { ">=" }, g.limit),
            if g.pass() { "yes".into() } else { "NO".into() },
        ]);
    }
    report.add_table(gate_table);
    let failed_gates: Vec<&str> = gates.iter().filter(|g| !g.pass()).map(|g| g.name).collect();
    if !failed_gates.is_empty() {
        if lax {
            eprintln!("[lax] SLO gates failed (contended runner?): {:?}", failed_gates);
        } else {
            panic!("SLO gates failed: {:?}", failed_gates);
        }
    }

    // ---- policy parity sweep (Sequenced; hard assert, lax or not) ------
    let policies = [
        ("depth", BatchPolicy::DepthFirst),
        ("deadline:200", BatchPolicy::Deadline { max_wait_us: 200 }),
        ("size:256", BatchPolicy::SizeCapped { max_ids: 256 }),
    ];
    let mut sweep_table = Table::new(
        "batch-policy parity sweep (Sequenced replay, same trace + initial state)",
        &["policy", "served", "batches", "max batch", "coalesced", "wall"],
    );
    let mut baseline: Option<Vec<u64>> = None;
    let mut violations = 0usize;
    for (label, policy) in policies {
        // a fresh state per policy: churn mutates it during the replay
        let mut st = DeltaState::init(delta_cfg(scale)).expect("delta state");
        let cell = Arc::new(TableCell::new(ShardedTable::from_inference_plan(
            st.plan(),
            st.embeddings(),
            0,
        )));
        let opts = PoolOpts {
            workers,
            queue_capacity: requests,
            max_batch,
            policy,
            ..PoolOpts::default()
        };
        let pool = ServePool::spawn(Arc::clone(&cell), Arc::new(Native), opts);
        let seq = ReplayOpts { mode: ReplayMode::Sequenced, keep_responses: false };
        let r = replay(&pool, &trace, &seq, churn_into_cell(&mut st, &cell))
            .expect("sequenced replay");
        let stats = pool.shutdown();
        assert!(r.digests.iter().all(|&x| x != 0), "{}: queue sized for the whole trace", label);
        match &baseline {
            None => baseline = Some(r.digests),
            Some(base) => {
                violations += base.iter().zip(&r.digests).filter(|(a, b)| a != b).count();
            }
        }
        sweep_table.row(&[
            label.to_string(),
            stats.served.to_string(),
            stats.batches.to_string(),
            stats.max_batch_seen.to_string(),
            stats.coalesced_similar.to_string(),
            human_secs(r.wall_secs),
        ]);
    }
    report.add_table(sweep_table);
    assert_eq!(violations, 0, "batch policies must produce bit-identical responses");
    report.note(format!(
        "parity: {} policies × {} requests, 0 digest violations",
        policies.len(),
        requests
    ));

    // ---- machine-readable summary (schema: EXPERIMENTS.md §Traffic) ----
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"traffic_slo\",\n");
    json.push_str(&format!(
        "  \"trace\": {{\n    \"seed\": {},\n    \"n_nodes\": {},\n    \"requests\": {},\n    \"base_rate\": {},\n    \"zipf_s\": {},\n    \"similar_fraction\": {},\n    \"burst_factor\": {},\n    \"churn_batches\": {},\n    \"duration_secs\": {:.6},\n    \"bytes\": {}\n  }},\n",
        tcfg.seed,
        n,
        requests,
        tcfg.base_rate,
        tcfg.zipf_s,
        tcfg.similar_fraction,
        tcfg.burst_factor,
        churn_batches,
        trace.duration_secs(),
        bytes.len()
    ));
    json.push_str(
        "  \"determinism\": { \"bit_identical\": true, \"distinct_seed_diverges\": true },\n",
    );
    json.push_str(&format!(
        "  \"open_loop\": {{\n    \"speed\": {},\n    \"wall_secs\": {:.6},\n    \"goodput_rps\": {:.1},\n    \"max_dispatch_lag_secs\": {:.6},\n    \"served\": {},\n    \"rejected\": {},\n    \"failed\": {},\n    \"churn_epochs\": {},\n",
        speed,
        rep.wall_secs,
        rep.goodput,
        rep.max_dispatch_lag_secs,
        rep.stats.served,
        rep.stats.rejected,
        rep.stats.failed,
        rep.churn_epochs.len()
    ));
    json.push_str("    \"classes\": {\n");
    for (i, class) in RequestClass::ALL.into_iter().enumerate() {
        let c = rep.stats.class(class);
        json.push_str(&format!(
            "      \"{}\": {{ \"submitted\": {}, \"served\": {}, \"rejected\": {}, \"failed\": {}, \"p50_s\": {}, \"p99_s\": {}, \"p999_s\": {} }}{}\n",
            class.name(),
            c.counters.submitted,
            c.counters.served,
            c.counters.rejected,
            c.counters.failed,
            json_f64(class_latency(&rep, class, "p50")),
            json_f64(class_latency(&rep, class, "p99")),
            json_f64(class_latency(&rep, class, "p999")),
            if i + 1 < RequestClass::ALL.len() { "," } else { "" }
        ));
    }
    json.push_str("    }\n  },\n");
    json.push_str("  \"slo\": [\n");
    for (i, g) in gates.iter().enumerate() {
        json.push_str(&format!(
            "    {{ \"gate\": \"{}\", \"value\": {}, \"bound\": {:.6}, \"upper_bound\": {}, \"pass\": {} }}{}\n",
            g.name,
            json_f64(g.value),
            g.limit,
            g.upper_bound,
            g.pass(),
            if i + 1 < gates.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"parity\": {{ \"policies\": [\"depth\", \"deadline:200\", \"size:256\"], \"requests\": {}, \"violations\": 0 }},\n",
        requests
    ));
    json.push_str(&format!("  \"lax\": {}\n}}\n", lax));
    let dir = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let json_path = dir.join("BENCH_traffic.json");
    std::fs::write(&json_path, &json).expect("write BENCH_traffic.json");
    report.note(format!("wrote {}", json_path.display()));
    report.finish();
}
