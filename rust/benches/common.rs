//! Shared bench helpers (each bench binary does `mod common;`).
#![allow(dead_code)]

use std::sync::Arc;

use deal::cluster::{ClusterReport, NetConfig};
use deal::config::DealConfig;
use deal::graph::{datasets, Csr};
use deal::partition::PartitionPlan;
use deal::primitives::scatter;
use deal::tensor::Matrix;
use deal::util::rng::Rng;

pub const DATASETS: [&str; 3] = ["products-sim", "spammer-sim", "papers-sim"];

/// Dataset scale per profile: quick keeps graphs around 2–8k nodes.
pub fn ds_scale(quick: bool) -> f64 {
    if quick {
        1.0 / 16.0
    } else {
        1.0
    }
}

/// Load a registry dataset and its CSR.
pub fn load(name: &str, quick: bool) -> (Csr, Matrix) {
    let ds = datasets::load(name, ds_scale(quick)).expect("dataset");
    (Csr::from(&ds.edges), ds.features)
}

/// Base config for pipeline benches.
pub fn base_cfg(name: &str, quick: bool) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = name.into();
    cfg.dataset.scale = ds_scale(quick);
    cfg.model.fanout = if quick { 10 } else { 50 };
    cfg
}

/// Scatter features + per-partition sub-CSRs for primitive benches.
pub struct PrimSetup {
    pub plan: PartitionPlan,
    pub tiles: Arc<Vec<Matrix>>,
    pub subs: Arc<Vec<(Csr, Vec<f32>)>>,
    pub g: Csr,
}

pub fn prim_setup(name: &str, quick: bool, p: usize, m: usize, d_override: Option<usize>) -> PrimSetup {
    let (g, mut feats) = load(name, quick);
    if let Some(d) = d_override {
        let mut rng = Rng::new(1);
        feats = Matrix::random(g.n_rows, d, 1.0, &mut rng);
    }
    let plan = PartitionPlan::new(g.n_rows, feats.cols, p, m);
    let tiles = Arc::new(scatter(&plan, &feats));
    let vals = deal::primitives::mean_weights(&g);
    let mut subs = Vec::new();
    for pi in 0..p {
        let (lo, hi) = plan.node_range(pi);
        let sub = g.slice_rows(lo, hi);
        let svals = vals[g.indptr[lo] as usize..g.indptr[hi] as usize].to_vec();
        subs.push((sub, svals));
    }
    PrimSetup { plan, tiles, subs: Arc::new(subs), g }
}

pub fn net() -> NetConfig {
    NetConfig::default()
}

pub fn fmt_ms(secs: f64) -> String {
    format!("{:.2}", secs * 1e3)
}

pub fn speedup(base: f64, new: f64) -> String {
    format!("{:.2}x", base / new.max(1e-12))
}

pub fn comm_compute(rep: &ClusterReport) -> (f64, f64) {
    let comm = rep.max_comm_wait();
    let comp = rep
        .machines
        .iter()
        .map(|m| m.sim_compute_secs)
        .fold(0.0, f64::max);
    (comm, comp)
}
