//! Fig. 21: feature preparation — scan-through loading vs redistribution
//! vs Deal's fused (communication-free) first layer, end to end.

mod common;

use deal::coordinator::Pipeline;
use deal::util::bench::{BenchArgs, Report, Table};

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("fig21_featprep");
    let machines = args.pick(vec![2usize, 4, 8], vec![2, 4, 8]);
    let mut table = Table::new(
        "feature preparation within end-to-end inference (sim ms)",
        &["dataset", "machines", "scan", "redistribute", "fused", "redist ×", "fused ×"],
    );
    for name in common::DATASETS {
        for &w in &machines {
            let mut times = Vec::new();
            for prep in ["scan", "redistribute", "fused"] {
                let mut cfg = common::base_cfg(name, args.quick);
                cfg.cluster.machines = w;
                cfg.cluster.feature_parts = 2.min(w);
                cfg.model.layers = 2;
                cfg.exec.feature_prep = prep.into();
                let mut pipe = Pipeline::new(cfg);
                pipe.keep_embeddings = false;
                let r = pipe.run().unwrap();
                // prep cost is inside the inference stage for fused; compare
                // the full post-construction time (prep + inference)
                times.push(r.stages.sim_of("inference"));
            }
            table.row(&[
                name.into(),
                w.to_string(),
                common::fmt_ms(times[0]),
                common::fmt_ms(times[1]),
                common::fmt_ms(times[2]),
                common::speedup(times[0], times[1]),
                common::speedup(times[0], times[2]),
            ]);
        }
    }
    report.add_table(table);
    report.note("paper: redistribution 1.20–1.39x over scan; fused adds ~1.15x; scan does not scale (shared FS bound)".to_string());
    report.finish();
}
