//! Fig. 18: SDDMM across (graph parts, feature parts) configurations on a
//! fixed machine count — duplicate-computation (approach i) vs Deal's
//! split non-zeros (approach ii).

mod common;

use std::sync::Arc;

use deal::cluster::Cluster;
use deal::primitives::sddmm::{sddmm, SddmmAlgo, SddmmInput};
use deal::primitives::ExecMode;
use deal::util::bench::{BenchArgs, Report, Table};

fn main() {
    let args = BenchArgs::parse();
    let mut report = Report::new("fig18_sddmm");
    let world = 8usize;
    let configs = [(8usize, 1usize), (4, 2), (2, 4), (1, 8)];
    let mut table = Table::new(
        "SDDMM across (graph parts, feature parts), 8 machines (sim ms)",
        &["dataset", "(P,M)", "dup (i)", "split (ii)", "speedup", "bytes dup", "bytes split"],
    );
    for name in common::DATASETS {
        for &(p, m) in &configs {
            assert_eq!(p * m, world);
            // dims must split across m: d=100 needs m|100... use override 128
            let setup = common::prim_setup(name, args.quick, p, m, Some(128));
            let mut times = Vec::new();
            let mut bytes = Vec::new();
            for algo in [SddmmAlgo::Duplicate, SddmmAlgo::Split] {
                let plan = setup.plan.clone();
                let tiles = Arc::clone(&setup.tiles);
                let subs = Arc::clone(&setup.subs);
                let cluster = Cluster::new(plan.world(), common::net());
                let (_, rep) = cluster
                    .run(move |ctx| {
                        let (p_idx, _) = plan.coords_of(ctx.rank);
                        let input = SddmmInput { plan: &plan, g: &subs[p_idx].0, h: &tiles[ctx.rank] };
                        sddmm(ctx, &input, algo, ExecMode::Pipelined, 4096, 11)
                    })
                    .unwrap();
                times.push(rep.makespan());
                bytes.push(rep.total_bytes());
            }
            table.row(&[
                name.into(),
                format!("({},{})", p, m),
                common::fmt_ms(times[0]),
                common::fmt_ms(times[1]),
                common::speedup(times[0], times[1]),
                deal::util::human_bytes(bytes[0]),
                deal::util::human_bytes(bytes[1]),
            ]);
        }
    }
    report.add_table(table);
    report.note("paper: speedups 1.65/1.38/1.15/1.00x as feature parts grow 1→8 (equal at M=1)".to_string());
    report.finish();
}
