//! Delta-parity integration (DESIGN.md §Delta): after replaying an update
//! trace through the incremental path, the state's embeddings must match
//! a from-scratch full-pipeline recompute on the updated graph — for
//! every feature-preparation strategy and both models.
//!
//! Tolerance: the delta state and the distributed pipeline each sit
//! within the end-to-end parity bound (2e-3, see `tests/end_to_end.rs`)
//! of the dense reference on the updated graph — unchanged rows because
//! sampling is per-row deterministic, affected rows because they are
//! recomputed from cached values. The triangle inequality bounds their
//! mutual distance by twice that.

use deal::config::DealConfig;
use deal::coordinator::delta::{DeltaState, UpdateBatch};
use deal::coordinator::Pipeline;
use deal::util::prop::assert_close;
use deal::util::rng::Rng;

/// Twice the end-to-end parity tolerance (triangle inequality; see the
/// module docs).
const DELTA_ATOL: f32 = 4e-3;
const DELTA_RTOL: f32 = 4e-3;

fn stream_cfg(kind: &str, prep: &str) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "products-sim".into();
    cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = 2;
    cfg.model.kind = kind.into();
    cfg.model.layers = 2;
    cfg.model.fanout = 5;
    cfg.exec.feature_prep = prep.into();
    cfg
}

/// Replay `batches` synthetic update batches (edge adds + removes +
/// feature updates), then check the incremental embeddings against a full
/// recompute for every feature-prep strategy.
fn replay_and_check(kind: &str, batches: usize, seed: u64) {
    let mut state = DeltaState::init(stream_cfg(kind, "redistribute")).unwrap();
    let mut rng = Rng::new(seed);
    for _ in 0..batches {
        let batch = state.synth_batch(&mut rng, 35, 35, 3);
        let rep = state.apply(&batch).unwrap();
        assert_eq!(rep.frontier.len(), 3, "2 layers → 3 frontier levels");
    }
    let edges = state.edge_list();
    let features = state.features().clone();
    for prep in ["scan", "redistribute", "fused"] {
        let tag = format!("delta-parity-{}-{}-{}", kind, prep, std::process::id());
        let pipeline =
            Pipeline::with_dataset(stream_cfg(kind, prep), &tag, edges.clone(), features.clone());
        let full = pipeline.run().unwrap().embeddings.unwrap();
        assert_close(&state.embeddings().data, &full.data, DELTA_ATOL, DELTA_RTOL)
            .unwrap_or_else(|e| {
                panic!("{} delta vs full recompute ({} prep): {}", kind, prep, e)
            });
    }
}

#[test]
fn gcn_delta_matches_full_recompute_every_prep() {
    replay_and_check("gcn", 3, 0xD17A);
}

#[test]
fn gat_delta_matches_full_recompute_every_prep() {
    replay_and_check("gat", 2, 0x6A77);
}

#[test]
fn feature_only_trace_matches_full_recompute() {
    // No topology churn: sampling must stay bit-identical, so parity
    // reduces to recomputing the feature-update frontier.
    let mut state = DeltaState::init(stream_cfg("gcn", "fused")).unwrap();
    let dim = state.plan().feature_dim;
    let batch = UpdateBatch {
        feature_updates: (0..6).map(|v| (v * 17, vec![0.1 * v as f32; dim])).collect(),
        ..Default::default()
    };
    let rep = state.apply(&batch).unwrap();
    assert_eq!(rep.dirty_rows, 0);
    assert_eq!(rep.frontier[0], 6);
    let tag = format!("delta-feat-{}", std::process::id());
    let pipeline = Pipeline::with_dataset(
        stream_cfg("gcn", "fused"),
        &tag,
        state.edge_list(),
        state.features().clone(),
    );
    let full = pipeline.run().unwrap().embeddings.unwrap();
    assert_close(&state.embeddings().data, &full.data, DELTA_ATOL, DELTA_RTOL).unwrap();
}

#[test]
fn growing_only_trace_matches_full_recompute() {
    // Insertion-only churn (the common production case: new interactions
    // stream in, nothing is retracted).
    let mut state = DeltaState::init(stream_cfg("gcn", "redistribute")).unwrap();
    let mut rng = Rng::new(0x9);
    let before = state.n_edges();
    for _ in 0..2 {
        let batch = state.synth_batch(&mut rng, 60, 0, 0);
        state.apply(&batch).unwrap();
    }
    assert_eq!(state.n_edges(), before + 120);
    let tag = format!("delta-grow-{}", std::process::id());
    let pipeline = Pipeline::with_dataset(
        stream_cfg("gcn", "redistribute"),
        &tag,
        state.edge_list(),
        state.features().clone(),
    );
    let full = pipeline.run().unwrap().embeddings.unwrap();
    assert_close(&state.embeddings().data, &full.data, DELTA_ATOL, DELTA_RTOL).unwrap();
}
