//! Delta-parity integration (DESIGN.md §Delta): after replaying an update
//! trace through the incremental path, the state's embeddings must match
//! a from-scratch full-pipeline recompute on the updated graph — for
//! every feature-preparation strategy and both models.
//!
//! Tolerance: the delta state and the distributed pipeline each sit
//! within the end-to-end parity bound (2e-3, see `tests/end_to_end.rs`)
//! of the dense reference on the updated graph — unchanged rows because
//! sampling is per-row deterministic, affected rows because they are
//! recomputed from cached values. The triangle inequality bounds their
//! mutual distance by twice that.

use std::sync::Arc;

use deal::config::DealConfig;
use deal::coordinator::delta::{DeltaState, UpdateBatch};
use deal::coordinator::Pipeline;
use deal::runtime::Native;
use deal::serve::{refresh_delta, PoolOpts, Response, ServePool, ShardedTable, TableCell};
use deal::tensor::Matrix;
use deal::traffic::{replay, ReplayMode, ReplayOpts, Trace, TraceConfig, TraceEvent};
use deal::util::prop::assert_close;
use deal::util::rng::Rng;

/// Twice the end-to-end parity tolerance (triangle inequality; see the
/// module docs).
const DELTA_ATOL: f32 = 4e-3;
const DELTA_RTOL: f32 = 4e-3;

fn stream_cfg(kind: &str, prep: &str) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "products-sim".into();
    cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = 2;
    cfg.model.kind = kind.into();
    cfg.model.layers = 2;
    cfg.model.fanout = 5;
    cfg.exec.feature_prep = prep.into();
    cfg
}

/// Replay `batches` synthetic update batches (edge adds + removes +
/// feature updates), then check the incremental embeddings against a full
/// recompute for every feature-prep strategy.
fn replay_and_check(kind: &str, batches: usize, seed: u64) {
    let mut state = DeltaState::init(stream_cfg(kind, "redistribute")).unwrap();
    let mut rng = Rng::new(seed);
    for _ in 0..batches {
        let batch = state.synth_batch(&mut rng, 35, 35, 3);
        let rep = state.apply(&batch).unwrap();
        assert_eq!(rep.frontier.len(), 3, "2 layers → 3 frontier levels");
    }
    let edges = state.edge_list();
    let features = state.features().clone();
    for prep in ["scan", "redistribute", "fused"] {
        let tag = format!("delta-parity-{}-{}-{}", kind, prep, std::process::id());
        let pipeline =
            Pipeline::with_dataset(stream_cfg(kind, prep), &tag, edges.clone(), features.clone());
        let full = pipeline.run().unwrap().embeddings.unwrap();
        assert_close(&state.embeddings().data, &full.data, DELTA_ATOL, DELTA_RTOL)
            .unwrap_or_else(|e| {
                panic!("{} delta vs full recompute ({} prep): {}", kind, prep, e)
            });
    }
}

/// Replay an embed-only trace open-loop while churn events publish delta
/// epochs mid-flight, and assert every response is **tear-free**: all of
/// a response's rows must come from one single published epoch (epochs
/// share unchanged rows, so more than one epoch may match — a torn read
/// mixing rows of two epochs matches none). Runs against a resident
/// table (`spill_budget == 0`) or a paged one.
fn replay_is_tear_free(spill_budget: u64) {
    let mut state = DeltaState::init(stream_cfg("gcn", "redistribute")).unwrap();
    let table = if spill_budget > 0 {
        ShardedTable::from_inference_plan_spilled(state.plan(), state.embeddings(), 0, spill_budget)
            .unwrap()
    } else {
        ShardedTable::from_inference_plan(state.plan(), state.embeddings(), 0)
    };
    assert_eq!(table.is_spilled(), spill_budget > 0);
    let cell = Arc::new(TableCell::new(table));
    let n = cell.load().n_nodes();
    let d = cell.load().dim();

    let trace = Trace::generate(&TraceConfig {
        seed: 0x7EA2,
        n_nodes: n,
        requests: 160,
        base_rate: 50_000.0, // compress simulated time for the test
        similar_fraction: 0.0, // embed-only: rows compare bitwise
        churn_batches: 3,
        ..TraceConfig::default()
    });
    assert_eq!(trace.n_churn(), 3);

    let opts = PoolOpts { workers: 3, queue_capacity: 256, max_batch: 8, ..PoolOpts::default() };
    let pool = ServePool::spawn(Arc::clone(&cell), Arc::new(Native), opts);

    // one full-table snapshot per published epoch, starting at epoch 0
    let mut snaps: Vec<Matrix> = vec![cell.load().to_full()];
    let replay_opts =
        ReplayOpts { mode: ReplayMode::OpenLoop { speed: 100.0 }, keep_responses: true };
    let rep = replay(&pool, &trace, &replay_opts, |ev| {
        let mut rng = Rng::new(ev.seed);
        let batch = state.synth_batch(
            &mut rng,
            ev.edge_adds as usize,
            ev.edge_removes as usize,
            ev.feat_updates as usize,
        );
        let r = refresh_delta(&mut state, &batch, &cell)?;
        snaps.push(cell.load().to_full());
        Ok(r.epoch)
    })
    .unwrap();

    assert_eq!(rep.churn_epochs, vec![1, 2, 3]);
    assert_eq!(snaps.len(), 4);
    assert_eq!(rep.stats.failed, 0);
    assert_eq!(rep.stats.rejected, 0, "queue sized to admit the whole trace");

    // every response's rows must sit inside a single epoch snapshot
    let requests: Vec<&deal::serve::Request> = trace
        .events
        .iter()
        .filter_map(|ev| match ev {
            TraceEvent::Request { req, .. } => Some(req),
            _ => None,
        })
        .collect();
    assert_eq!(requests.len(), rep.responses.len());
    for (i, (req, resp)) in requests.iter().zip(&rep.responses).enumerate() {
        let m = match resp.as_ref().unwrap_or_else(|| panic!("request {} dropped", i)) {
            Response::Embeddings(m) => m,
            _ => panic!("embed-only trace returned a similar response"),
        };
        let ids = req.ids();
        assert_eq!(m.rows, ids.len());
        assert_eq!(m.cols, d);
        let whole_epoch = |s: &Matrix| {
            ids.iter().enumerate().all(|(j, &id)| {
                m.data[j * d..(j + 1) * d] == s.data[id as usize * d..(id as usize + 1) * d]
            })
        };
        assert!(
            snaps.iter().any(whole_epoch),
            "request {} returned a torn response: rows match no single epoch",
            i
        );
    }
    pool.shutdown();
}

#[test]
fn open_loop_churn_epochs_are_tear_free_in_memory() {
    replay_is_tear_free(0);
}

#[test]
fn open_loop_churn_epochs_are_tear_free_spilled() {
    // 8 KiB budget < the 256-row table: the initial epoch serves from the
    // paged tier, and patched epochs promote touched shards on write.
    replay_is_tear_free(8 << 10);
}

#[test]
fn gcn_delta_matches_full_recompute_every_prep() {
    replay_and_check("gcn", 3, 0xD17A);
}

#[test]
fn gat_delta_matches_full_recompute_every_prep() {
    replay_and_check("gat", 2, 0x6A77);
}

#[test]
fn feature_only_trace_matches_full_recompute() {
    // No topology churn: sampling must stay bit-identical, so parity
    // reduces to recomputing the feature-update frontier.
    let mut state = DeltaState::init(stream_cfg("gcn", "fused")).unwrap();
    let dim = state.plan().feature_dim;
    let batch = UpdateBatch {
        feature_updates: (0..6).map(|v| (v * 17, vec![0.1 * v as f32; dim])).collect(),
        ..Default::default()
    };
    let rep = state.apply(&batch).unwrap();
    assert_eq!(rep.dirty_rows, 0);
    assert_eq!(rep.frontier[0], 6);
    let tag = format!("delta-feat-{}", std::process::id());
    let pipeline = Pipeline::with_dataset(
        stream_cfg("gcn", "fused"),
        &tag,
        state.edge_list(),
        state.features().clone(),
    );
    let full = pipeline.run().unwrap().embeddings.unwrap();
    assert_close(&state.embeddings().data, &full.data, DELTA_ATOL, DELTA_RTOL).unwrap();
}

#[test]
fn growing_only_trace_matches_full_recompute() {
    // Insertion-only churn (the common production case: new interactions
    // stream in, nothing is retracted).
    let mut state = DeltaState::init(stream_cfg("gcn", "redistribute")).unwrap();
    let mut rng = Rng::new(0x9);
    let before = state.n_edges();
    for _ in 0..2 {
        let batch = state.synth_batch(&mut rng, 60, 0, 0);
        state.apply(&batch).unwrap();
    }
    assert_eq!(state.n_edges(), before + 120);
    let tag = format!("delta-grow-{}", std::process::id());
    let pipeline = Pipeline::with_dataset(
        stream_cfg("gcn", "redistribute"),
        &tag,
        state.edge_list(),
        state.features().clone(),
    );
    let full = pipeline.run().unwrap().embeddings.unwrap();
    assert_close(&state.embeddings().data, &full.data, DELTA_ATOL, DELTA_RTOL).unwrap();
}
