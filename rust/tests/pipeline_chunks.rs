//! Chunk-size sweep property tests (DESIGN.md §Pipelined-communication):
//! the whole-cluster end-to-end pipeline must produce **bit-identical**
//! embeddings at every `pipeline.chunk_rows` value and every intra-rank
//! thread count, for both models. Chunking and threading change simulated
//! schedules and wall-clock only — never a number.
//!
//! The sweep covers the degenerate extremes: `0` (monolithic fallback),
//! `1` (one row per message — maximal chunk count), a non-divisor (`7`),
//! a mid value (`64`), and one larger than every transfer (`4096`, which
//! must also behave monolithically).

use deal::cluster::net::with_chunk_rows;
use deal::config::DealConfig;
use deal::coordinator::Pipeline;
use deal::runtime::par;
use deal::tensor::Matrix;

const CHUNKS: [usize; 5] = [0, 1, 7, 64, 4096];
const THREADS: [usize; 2] = [1, 4];

fn small_cfg(kind: &str, prep: &str) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "products-sim".into();
    cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = 2;
    cfg.model.kind = kind.into();
    cfg.model.layers = 2;
    cfg.model.fanout = 5;
    cfg.exec.feature_prep = prep.into();
    cfg
}

fn run_once(kind: &str, prep: &str, chunk: usize, threads: usize) -> Matrix {
    with_chunk_rows(chunk, || {
        par::with_threads(threads, || {
            Pipeline::new(small_cfg(kind, prep))
                .run()
                .expect("pipeline run failed")
                .embeddings
                .expect("embeddings kept")
        })
    })
}

fn sweep(kind: &str, prep: &str) {
    let base = run_once(kind, prep, 0, 1);
    assert!(base.data.iter().all(|v| v.is_finite()));
    for &threads in &THREADS {
        for &chunk in &CHUNKS {
            if chunk == 0 && threads == 1 {
                continue; // the baseline itself
            }
            let got = run_once(kind, prep, chunk, threads);
            assert_eq!(
                got, base,
                "{} embeddings diverged at chunk_rows={} threads={}",
                kind, chunk, threads
            );
        }
    }
}

#[test]
fn gcn_bit_identical_across_chunk_sizes_and_threads() {
    // fused prep: covers the fused first layer's streamed loader fetches
    sweep("gcn", "fused");
}

#[test]
fn gat_bit_identical_across_chunk_sizes_and_threads() {
    // GAT covers the per-head SPMM streaming and the attention fetches
    sweep("gat", "redistribute");
}
