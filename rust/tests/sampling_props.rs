//! Property tests for `sample_all_layers` — the invariants the whole
//! pipeline leans on but previously never tested directly:
//!
//! - per-row degree is exactly `min(deg, fanout)` and sampled edges are a
//!   subset of the input CSR;
//! - `fanout == 0` is the identity (every layer is the input graph);
//! - same-seed determinism, including across `P × M` layouts: the
//!   pipeline's "row-group machines derive identical samples without
//!   communicating" assumption (coordinator stage 3) and the delta path's
//!   "re-sampling only dirty rows reproduces a from-scratch pass"
//!   assumption (`sampling::resample_rows`).

use deal::graph::delta::stack_partitions;
use deal::graph::{Csr, NodeId};
use deal::sampling::{resample_rows, sample_all_layers, LayerGraphs};
use deal::util::even_ranges;
use deal::util::prop::{run, Config};
use deal::util::rng::Rng;

/// Random multigraph with `n` nodes and about `m` edges.
fn random_graph(rng: &mut Rng, n: usize, m: usize) -> Csr {
    let edges: Vec<(NodeId, NodeId)> = (0..m)
        .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
        .collect();
    Csr::from_edges(n, &edges)
}

fn is_subgraph(sampled: &Csr, g: &Csr) -> Result<(), String> {
    for v in 0..g.n_rows {
        let orig = g.row(v);
        for &s in sampled.row(v) {
            if orig.binary_search(&s).is_err() {
                return Err(format!("sampled edge {}->{} not in input graph", s, v));
            }
        }
    }
    Ok(())
}

/// Sample each partition slice with the pipeline's per-partition seed and
/// stitch the results back together — exactly what coordinator stage 3
/// materializes across the cluster.
fn pipeline_style_sample(g: &Csr, p: usize, k: usize, fanout: usize, seed: u64) -> LayerGraphs {
    let bounds = even_ranges(g.n_rows, p);
    let per_part: Vec<Vec<Csr>> = (0..p)
        .map(|pi| {
            let sub = g.slice_rows(bounds[pi], bounds[pi + 1]);
            sample_all_layers(&sub, k, fanout, seed ^ pi as u64).layers
        })
        .collect();
    let layers = (0..k)
        .map(|l| {
            let refs: Vec<&Csr> = per_part.iter().map(|ls| &ls[l]).collect();
            stack_partitions(&refs)
        })
        .collect();
    LayerGraphs { layers }
}

#[test]
fn degree_is_min_of_fanout_and_input_degree() {
    run(Config::default().cases(24), |rng| {
        let n = rng.range(2, 120);
        let g = random_graph(rng, n, rng.range(0, n * 8));
        let fanout = rng.range(1, 9);
        let k = rng.range(1, 4);
        let lg = sample_all_layers(&g, k, fanout, rng.next_u64());
        if lg.k() != k {
            return Err(format!("asked for {} layers, got {}", k, lg.k()));
        }
        for layer in &lg.layers {
            layer.validate()?;
            for v in 0..n {
                let expect = g.degree(v).min(fanout);
                if layer.degree(v) != expect {
                    return Err(format!(
                        "row {}: degree {} != min(deg {}, fanout {})",
                        v,
                        layer.degree(v),
                        g.degree(v),
                        fanout
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sampled_edges_are_subset_of_input() {
    run(Config::default().cases(24), |rng| {
        let n = rng.range(2, 100);
        let g = random_graph(rng, n, rng.range(0, n * 6));
        let lg = sample_all_layers(&g, rng.range(1, 4), rng.range(1, 8), rng.next_u64());
        for layer in &lg.layers {
            is_subgraph(layer, &g)?;
        }
        Ok(())
    });
}

#[test]
fn zero_fanout_is_identity() {
    run(Config::default().cases(16), |rng| {
        let n = rng.range(1, 80);
        let g = random_graph(rng, n, rng.range(0, n * 5));
        let k = rng.range(1, 4);
        let lg = sample_all_layers(&g, k, 0, rng.next_u64());
        for layer in &lg.layers {
            if layer != &g {
                return Err("fanout 0 must reproduce the input graph per layer".into());
            }
        }
        Ok(())
    });
}

#[test]
fn same_seed_same_samples() {
    run(Config::default().cases(16), |rng| {
        let n = rng.range(2, 100);
        let g = random_graph(rng, n, rng.range(0, n * 6));
        let (k, fanout, seed) = (rng.range(1, 4), rng.range(1, 6), rng.next_u64());
        let a = sample_all_layers(&g, k, fanout, seed);
        let b = sample_all_layers(&g, k, fanout, seed);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            if la != lb {
                return Err("same seed produced different layer graphs".into());
            }
        }
        Ok(())
    });
}

#[test]
fn different_seeds_differ_on_a_selective_graph() {
    // Dense fixed graph: thousands of rows have degree ≫ fanout, so two
    // seeds agreeing everywhere is astronomically unlikely.
    use deal::graph::rmat::{rmat, RmatParams};
    let g = Csr::from(&rmat(9, 8000, RmatParams::paper(), 21));
    let a = sample_all_layers(&g, 2, 5, 1);
    let b = sample_all_layers(&g, 2, 5, 2);
    let differing = (0..g.n_rows)
        .filter(|&v| a.layers[0].row(v) != b.layers[0].row(v))
        .count();
    assert!(differing > 0, "different seeds produced identical samples");
}

/// The coordinator assumption: every machine of a row group re-derives its
/// partition's samples from `(partition CSR, seed ^ p)` alone, so samples
/// agree across machines *and* across `M` — and for a fixed `P`, stitching
/// per-partition samples is deterministic.
#[test]
fn row_group_machines_derive_identical_samples_across_layouts() {
    run(Config::default().cases(8), |rng| {
        let p = rng.range(1, 5);
        let n = rng.range(p * 3, 150);
        let g = random_graph(rng, n, rng.range(n, n * 6));
        let (k, fanout, seed) = (rng.range(1, 4), rng.range(1, 6), rng.next_u64());
        let bounds = even_ranges(n, p);
        // every "machine" (p_idx, m_idx) of every M-layout derives the
        // partition sample independently; all copies must agree
        for pi in 0..p {
            let sub = g.slice_rows(bounds[pi], bounds[pi + 1]);
            let reference = sample_all_layers(&sub, k, fanout, seed ^ pi as u64);
            for _m_layout in [1usize, 2, 4] {
                let again = sample_all_layers(&sub, k, fanout, seed ^ pi as u64);
                for (la, lb) in reference.layers.iter().zip(&again.layers) {
                    if la != lb {
                        return Err(format!("partition {} machines diverged", pi));
                    }
                }
            }
        }
        // and the stitched whole is reproducible
        let a = pipeline_style_sample(&g, p, k, fanout, seed);
        let b = pipeline_style_sample(&g, p, k, fanout, seed);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            if la != lb {
                return Err("stitched pipeline sampling not deterministic".into());
            }
        }
        Ok(())
    });
}

/// The delta-path assumption: re-drawing any subset of rows reproduces
/// exactly the rows a full sampling pass would give them.
#[test]
fn resample_rows_matches_full_pass_property() {
    run(Config::default().cases(16), |rng| {
        let n = rng.range(2, 100);
        let g = random_graph(rng, n, rng.range(0, n * 6));
        let (k, seed) = (rng.range(1, 4), rng.next_u64());
        let fanout = [0usize, 1, 3, 7][rng.next_below(4)];
        let full = sample_all_layers(&g, k, fanout, seed);
        let mut rows: Vec<usize> = (0..n).filter(|_| rng.next_below(3) == 0).collect();
        if rows.is_empty() {
            rows.push(rng.next_below(n));
        }
        let drawn = resample_rows(&g, &rows, k, fanout, seed);
        for (i, &v) in rows.iter().enumerate() {
            for l in 0..k {
                if drawn[i][l].as_slice() != full.layers[l].row(v) {
                    return Err(format!("row {} layer {}: resample != full pass", v, l));
                }
            }
        }
        Ok(())
    });
}

/// Intra-rank parallelism contract: the band-parallel sampler reproduces
/// the scalar draw bit-for-bit at every pool size (each row's RNG stream
/// is forked from its id alone, so banding cannot change any draw), and
/// re-sampling parity survives at every pool size too.
#[test]
fn sampling_bit_identical_across_thread_counts() {
    use deal::runtime::par;
    run(Config::default().cases(4), |rng| {
        let n = rng.range(50, 4000);
        let g = random_graph(rng, n, rng.range(n, n * 10));
        let k = rng.range(1, 4);
        let fanout = rng.range(1, 8);
        let seed = rng.next_u64();
        let reference = par::with_threads(1, || sample_all_layers(&g, k, fanout, seed));
        for t in [2usize, 3, 8] {
            let got = par::with_threads(t, || sample_all_layers(&g, k, fanout, seed));
            for l in 0..k {
                if got.layers[l] != reference.layers[l] {
                    return Err(format!("layer {} diverged at {} threads", l, t));
                }
            }
            // delta-path parity holds against the parallel sampler as well
            let rows = [0usize, n / 2, n - 1];
            let drawn = par::with_threads(t, || resample_rows(&g, &rows, k, fanout, seed));
            for (i, &v) in rows.iter().enumerate() {
                for l in 0..k {
                    if drawn[i][l].as_slice() != reference.layers[l].row(v) {
                        return Err(format!(
                            "resample row {} layer {} diverged at {} threads",
                            v, l, t
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}
