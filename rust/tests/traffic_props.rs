//! Property tests on the traffic harness (ISSUE: trace determinism, skew
//! and rate tolerances, format integrity). The trace is a *reproducible
//! artifact*: identical seed + config must serialize byte-identically,
//! distinct seeds must diverge, and the generated workload must actually
//! exhibit the configured Zipf skew and aggregate arrival rate.

use std::collections::HashMap;

use deal::traffic::{Trace, TraceConfig, TraceEvent};
use deal::util::prop::{run, Config};

fn cfg_with(seed: u64, requests: usize) -> TraceConfig {
    TraceConfig { seed, requests, n_nodes: 256, ..TraceConfig::default() }
}

#[test]
fn same_seed_and_config_serialize_byte_identically() {
    run(Config::default().cases(8), |rng| {
        let cfg = TraceConfig {
            seed: rng.next_u64(),
            n_nodes: rng.range(4, 512),
            requests: rng.range(1, 400),
            zipf_s: rng.next_f64() * 1.5,
            similar_fraction: rng.next_f64(),
            churn_batches: rng.next_below(4),
            ..TraceConfig::default()
        };
        let a = Trace::generate(&cfg).to_bytes();
        let b = Trace::generate(&cfg).to_bytes();
        if a != b {
            return Err(format!("seed {} generated two different traces", cfg.seed));
        }
        // parse → reserialize is the identity (no information loss)
        let back = Trace::from_bytes(&a).map_err(|e| e.to_string())?;
        if back.to_bytes() != a {
            return Err("roundtrip changed the bytes".into());
        }
        Ok(())
    });
}

#[test]
fn distinct_seeds_produce_distinct_traces() {
    run(Config::default().cases(8), |rng| {
        let seed = rng.next_u64();
        let a = Trace::generate(&cfg_with(seed, 64)).to_bytes();
        let b = Trace::generate(&cfg_with(seed ^ 1, 64)).to_bytes();
        if a == b {
            return Err(format!("seeds {} and {} collided", seed, seed ^ 1));
        }
        Ok(())
    });
}

#[test]
fn zipf_skew_matches_theory_within_tolerance() {
    // s = 1.0 over 256 nodes: the hottest key's theoretical share is
    // 1/H_256 ≈ 0.163. Count ids across all requests and compare.
    let cfg = TraceConfig {
        zipf_s: 1.0,
        similar_fraction: 0.0, // embed-only: 8 ids per request
        ..cfg_with(0xBEEF, 4000)
    };
    let trace = Trace::generate(&cfg);
    let mut counts: HashMap<u32, u64> = HashMap::new();
    let mut total = 0u64;
    for ev in &trace.events {
        if let TraceEvent::Request { req, .. } = ev {
            for &id in req.ids() {
                *counts.entry(id).or_insert(0) += 1;
                total += 1;
            }
        }
    }
    let h256: f64 = (1..=256).map(|k| 1.0 / k as f64).sum();
    let theory = 1.0 / h256;
    let top = *counts.values().max().unwrap() as f64 / total as f64;
    assert!(
        (theory * 0.6..theory * 1.4).contains(&top),
        "top-key share {:.4} vs theoretical {:.4}",
        top,
        theory
    );
    // a mid-tail key is far colder than the head
    let distinct = counts.len();
    assert!(distinct > 64, "skewed draw still covers the universe, got {}", distinct);
}

#[test]
fn zipf_s_zero_is_near_uniform() {
    let cfg = TraceConfig {
        zipf_s: 0.0,
        similar_fraction: 0.0,
        n_nodes: 64,
        ..cfg_with(0xFEED, 3000)
    };
    let trace = Trace::generate(&cfg);
    let mut counts = vec![0u64; 64];
    let mut total = 0u64;
    for ev in &trace.events {
        if let TraceEvent::Request { req, .. } = ev {
            for &id in req.ids() {
                counts[id as usize] += 1;
                total += 1;
            }
        }
    }
    let max_share = *counts.iter().max().unwrap() as f64 / total as f64;
    // uniform share is 1/64 ≈ 0.0156; allow 2x sampling noise
    assert!(max_share < 0.032, "max share {:.4} too skewed for s=0", max_share);
}

#[test]
fn aggregate_rate_tracks_base_rate() {
    // With bursts off, the thinned nonhomogeneous process must average
    // the base rate over whole diurnal periods.
    let cfg = TraceConfig {
        base_rate: 1000.0,
        burst_factor: 1.0,
        diurnal_amplitude: 0.5,
        diurnal_period_secs: 0.25,
        ..cfg_with(0xCAFE, 4000)
    };
    let trace = Trace::generate(&cfg);
    let duration = trace.duration_secs();
    let rate = trace.n_requests() as f64 / duration;
    assert!(
        (850.0..1150.0).contains(&rate),
        "aggregate rate {:.0}/s strays >15% from base 1000/s over {:.2}s",
        rate,
        duration
    );
}

#[test]
fn bursts_raise_local_density() {
    // Same seedled arrivals with an aggressive burst profile: peak
    // short-window arrival counts must exceed the burstless trace's.
    let calm = Trace::generate(&TraceConfig {
        burst_factor: 1.0,
        diurnal_amplitude: 0.0,
        ..cfg_with(0xB00, 3000)
    });
    let bursty = Trace::generate(&TraceConfig {
        burst_factor: 8.0,
        // frequent onsets: the short trace is guaranteed to hold bursts
        burst_rate_hz: 20.0,
        burst_secs: 0.05,
        diurnal_amplitude: 0.0,
        ..cfg_with(0xB00, 3000)
    });
    let peak_window = |t: &Trace| {
        let times: Vec<f64> = t
            .events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Request { .. }))
            .map(|e| e.at_secs())
            .collect();
        let w = 0.02; // 20 ms window
        let mut best = 0usize;
        let mut lo = 0usize;
        for hi in 0..times.len() {
            while times[hi] - times[lo] > w {
                lo += 1;
            }
            best = best.max(hi - lo + 1);
        }
        best
    };
    let calm_peak = peak_window(&calm);
    let bursty_peak = peak_window(&bursty);
    assert!(
        bursty_peak as f64 > calm_peak as f64 * 1.5,
        "burst peak {} not denser than calm peak {}",
        bursty_peak,
        calm_peak
    );
}

#[test]
fn churn_events_interleave_and_order() {
    let cfg = TraceConfig { churn_batches: 4, ..cfg_with(0xD1CE, 1000) };
    let trace = Trace::generate(&cfg);
    assert_eq!(trace.n_churn(), 4);
    assert_eq!(trace.n_requests(), 1000);
    let mut last = 0.0;
    let mut churn_positions = Vec::new();
    for (i, ev) in trace.events.iter().enumerate() {
        assert!(ev.at_secs() >= last, "event {} out of order", i);
        last = ev.at_secs();
        if let TraceEvent::Churn(c) = ev {
            churn_positions.push(i);
            assert!(c.edge_adds > 0);
        }
    }
    // churn spreads across the trace, not clumped at the ends
    assert!(churn_positions[0] > 100);
    assert!(*churn_positions.last().unwrap() < trace.events.len() - 100);
    // the artifact roundtrips through disk
    let path = std::env::temp_dir().join(format!("deal-trace-props-{}.bin", std::process::id()));
    trace.save(&path).unwrap();
    let back = Trace::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(back.to_bytes(), trace.to_bytes());
}

#[test]
fn corruption_version_and_truncation_are_rejected() {
    let bytes = Trace::generate(&cfg_with(3, 50)).to_bytes();
    // flip one payload byte → checksum failure
    let mut corrupt = bytes.clone();
    let mid = corrupt.len() / 2;
    corrupt[mid] ^= 0x01;
    let err = Trace::from_bytes(&corrupt).unwrap_err().to_string();
    assert!(err.contains("checksum"), "unexpected error: {}", err);
    // unknown version → version failure (before the checksum check bytes
    // must be patched so only the version differs)
    let mut vers = bytes.clone();
    vers[8] = 99; // version u32 LE starts at offset 8
    let err = Trace::from_bytes(&vers).unwrap_err().to_string();
    assert!(err.contains("version"), "unexpected error: {}", err);
    // truncation
    assert!(Trace::from_bytes(&bytes[..10]).is_err());
    assert!(Trace::from_bytes(&[]).is_err());
}
