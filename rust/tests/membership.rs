//! Elastic membership integration (DESIGN.md §Membership): epoch-fenced
//! join/leave/kill must extend the repo's bit-identical determinism
//! contract to *any* membership schedule.
//!
//! The centrepiece is a **kill-point sweep** in the style of
//! `tests/recovery.rs`: the transport fault hook (`cluster::net::fault`)
//! first probes how many send/recv boundaries a rank crosses during a
//! migration, then re-runs the migration killing that rank at boundary
//! 1, 2, …, N. After every single injected kill the transition must
//! abort cleanly — the old table keeps serving, bit-identical; the
//! consumed membership epoch never rewinds (fencing out the aborted
//! traffic) — and the schedule must then complete on top of the abort to
//! the exact fixed-world table.
//!
//! Alongside the sweep: a seeded join/leave/kill schedule preserves
//! served-response digests with and without durable shard stores, a
//! killed rank's band is rebuilt from its per-shard durable store (and a
//! rejoiner reuses its own grave) instead of being recomputed or
//! re-shipped, stale-epoch traffic is rejected deterministically, and
//! injected message delays change simulated time but never values.

use std::path::PathBuf;
use std::sync::Arc;

use deal::cluster::membership::{
    fence, parse_schedule, ElasticCluster, ElasticOpts, MembershipEvent, MigrationMode,
};
use deal::cluster::net::fault;
use deal::cluster::RankFailed;
use deal::runtime::Native;
use deal::serve::{
    response_digest, serve_workload_pooled, synthetic_workload, PoolOpts, Request, ServePool,
    ShardedTable, TableCell,
};
use deal::tensor::Matrix;
use deal::util::rng::Rng;

const ROWS: usize = 96;
const DIM: usize = 8;
const WORLD: usize = 4;

/// The fixed-world reference table every schedule is checked against.
fn reference_table() -> Matrix {
    let mut rng = Rng::new(0xE1A5_71C);
    Matrix::random(ROWS, DIM, 1.0, &mut rng)
}

/// The pinned workload replayed after every transition.
fn workload() -> Vec<Request> {
    let mut rng = Rng::new(0xBEEF);
    synthetic_workload(&mut rng, ROWS, 64, false)
}

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("deal-member-{}-{}", std::process::id(), tag));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn opts(durable_root: Option<PathBuf>) -> ElasticOpts {
    ElasticOpts { durable_root, ..ElasticOpts::default() }
}

/// Bit-exact matrix equality — the membership contract has no tolerance.
fn assert_bits_eq(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{}: shape", what);
    let ab: Vec<u32> = a.data.iter().map(|v| v.to_bits()).collect();
    let bb: Vec<u32> = b.data.iter().map(|v| v.to_bits()).collect();
    assert_eq!(ab, bb, "{}: not bit-identical", what);
}

/// Serve `reqs` through a pool over `cell` and fold per-request digests.
fn served_digests(cell: Arc<TableCell>, reqs: &[Request]) -> Vec<u64> {
    let pool = ServePool::spawn(cell, Arc::new(Native), PoolOpts::default());
    let (resp, _) = serve_workload_pooled(&pool, reqs).expect("workload served");
    let digests = resp.iter().map(response_digest).collect();
    pool.shutdown();
    digests
}

/// Reference digests from a plain fixed-world sharded table (no elastic
/// machinery at all).
fn reference_digests(full: &Matrix, reqs: &[Request]) -> Vec<u64> {
    let cell = Arc::new(TableCell::new(ShardedTable::from_full(full, WORLD, 0)));
    served_digests(cell, reqs)
}

/// A seeded schedule with every event kind, including a kill-and-rejoin
/// and a grow past the original world.
fn seeded_schedule() -> Vec<MembershipEvent> {
    parse_schedule("leave:3,kill:2,join:2,join:3,join:4,leave:0").expect("valid schedule")
}

// ---------------------------------------------------------------------
// schedule sweep: embeddings and served responses bit-identical to the
// fixed world, with and without durable shard stores
// ---------------------------------------------------------------------

fn run_schedule(durable_root: Option<PathBuf>) {
    let full = reference_table();
    let reqs = workload();
    let reference = reference_digests(&full, &reqs);
    let durable = durable_root.is_some();

    let mut cluster = ElasticCluster::new(&full, WORLD, opts(durable_root)).expect("cluster");
    assert_eq!(served_digests(cluster.cell(), &reqs), reference, "epoch 0 digests");

    for (i, ev) in seeded_schedule().into_iter().enumerate() {
        let stats = cluster.apply(ev).unwrap_or_else(|e| panic!("apply {}: {:#}", ev, e));
        assert_eq!(stats.epoch, i as u64 + 1, "membership epochs are dense");
        assert_eq!(stats.serving_epoch, cluster.serving_epoch(), "handoff epoch recorded");
        // the full contract, after every single transition: the published
        // table and the served responses match the fixed world bit for bit
        cluster.verify_against(&full).expect("table bit-identical");
        assert_eq!(
            served_digests(cluster.cell(), &reqs),
            reference,
            "served digests diverged after {} (epoch {})",
            ev,
            stats.epoch
        );
        if durable {
            match ev {
                // the killed rank's band comes back from its durable
                // store, not the wire and not a recompute
                MembershipEvent::Kill { .. } => {
                    assert!(stats.recovered_from_durable, "kill should recover from durable");
                    assert!(stats.rows_recovered > 0, "kill recovered no rows");
                }
                // the first rejoin reuses the rejoiner's own grave
                MembershipEvent::Join { rank: 2 } => {
                    assert!(stats.recovered_from_durable, "rejoin should reuse the grave");
                }
                _ => {}
            }
        }
    }
    assert_eq!(cluster.history().len(), 6);
    // world is back to 4 active ranks (0 left at the end, 4 joined)
    assert_eq!(cluster.membership().active().len(), WORLD);
}

#[test]
fn schedule_preserves_bits_without_durable() {
    run_schedule(None);
}

#[test]
fn schedule_preserves_bits_with_durable() {
    let dir = fresh_dir("sched");
    run_schedule(Some(dir.clone()));
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// the kill-point sweep: a rank dies at every armed transport boundary
// ---------------------------------------------------------------------

/// Sweep `victim` through every transport boundary it crosses while the
/// cluster applies `ev` from a fresh world. After each injected kill the
/// transition must abort with a structured, injected `RankFailed`, the
/// serving table must be untouched, the epoch must stay consumed, and the
/// retried event must complete to the fixed-world table.
fn sweep_kills(ev: MembershipEvent, victim: usize, root: &std::path::Path) {
    let full = reference_table();
    let reqs = workload();
    let reference = reference_digests(&full, &reqs);
    let mk = |tag: &str| {
        let dir = root.join(tag);
        let _ = std::fs::remove_dir_all(&dir);
        ElasticCluster::new(&full, WORLD, opts(Some(dir))).expect("cluster")
    };

    // probe run: count the victim's boundaries without firing
    fault::probe(victim);
    let mut scratch = mk("probe");
    scratch.apply(ev).expect("probe run completes");
    let total = fault::count();
    fault::disarm();
    assert!(total >= 1, "victim {} crosses no transport boundary during {}", victim, ev);

    for nth in 1..=total {
        let mut cluster = mk(&format!("kill-{}-{}", victim, nth));
        let before = cluster.table().to_full();
        fault::arm_kill(victim, nth);
        let err = cluster
            .apply(ev)
            .expect_err(&format!("kill {}@{} must fail the transition", victim, nth));
        fault::disarm();

        // structured failure: the injected kill is the root cause
        assert!(fault::is_injected(&err), "boundary {}: not injected: {:#}", nth, err);
        let rf = RankFailed::find(&err).expect("RankFailed in chain");
        assert_eq!(rf.rank, victim, "boundary {}: wrong rank", nth);
        assert_eq!(rf.epoch, 1, "boundary {}: wrong epoch", nth);
        assert!(rf.point.is_some() && rf.ordinal == nth, "boundary {}: {:?}", nth, rf);

        // abort semantics: the old table keeps serving, bit-identical;
        // the consumed epoch never rewinds; nothing was handed off
        assert_bits_eq(&cluster.table().to_full(), &before, "aborted table");
        cluster.verify_against(&full).expect("aborted table matches reference");
        assert_eq!(cluster.epoch(), 1, "fences never rewind");
        assert_eq!(cluster.serving_epoch(), 0, "no handoff on abort");
        assert!(cluster.history().is_empty(), "aborted transition recorded");
        assert!(!cluster.membership().in_transition(), "abort left a pending event");

        // and the cluster is still usable: the retried event completes to
        // the fixed world, serving the exact reference responses
        let stats = cluster.apply(ev).expect("retry after abort");
        assert_eq!(stats.epoch, 2, "retry consumed the next epoch");
        cluster.verify_against(&full).expect("retried table matches reference");
        assert_eq!(
            served_digests(cluster.cell(), &reqs),
            reference,
            "digests diverged after kill@{} + retry",
            nth
        );
    }
}

#[test]
fn kill_sweep_during_kill_migration() {
    // Kill{2} moves one band over the wire (rank 1 → rank 0) and
    // recovers the victim's band from its durable grave. Sweep both the
    // sender and the receiver through every boundary they cross.
    let root = fresh_dir("sweep-kill");
    for victim in [0usize, 1] {
        sweep_kills(MembershipEvent::Kill { rank: 2 }, victim, &root);
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn kill_sweep_during_join_migration() {
    // Join{4} ships band slices from the incumbents to the joiner: the
    // joiner crosses recv boundaries, rank 3 sends. Sweep both.
    let root = fresh_dir("sweep-join");
    for victim in [3usize, 4] {
        sweep_kills(MembershipEvent::Join { rank: 4 }, victim, &root);
    }
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------
// durable recovery: rebuilt, not recomputed and not re-shipped
// ---------------------------------------------------------------------

#[test]
fn kill_recovers_from_durable_not_the_wire() {
    let dir = fresh_dir("durable-kill");
    let full = reference_table();
    let mut with_store =
        ElasticCluster::new(&full, WORLD, opts(Some(dir.clone()))).expect("cluster");
    let mut wire_only = ElasticCluster::new(&full, WORLD, opts(None)).expect("cluster");

    let s_durable = with_store.apply(MembershipEvent::Kill { rank: 2 }).expect("kill");
    let s_wire = wire_only.apply(MembershipEvent::Kill { rank: 2 }).expect("kill");
    with_store.verify_against(&full).expect("durable path bits");
    wire_only.verify_against(&full).expect("wire path bits");

    // same final table, but the durable path moved strictly fewer bytes:
    // the dead rank's rows came off disk, not over the wire
    assert!(s_durable.recovered_from_durable);
    assert!(!s_wire.recovered_from_durable);
    assert!(s_durable.rows_recovered > 0);
    assert_eq!(s_wire.rows_recovered, 0);
    assert!(
        s_durable.bytes_on_wire < s_wire.bytes_on_wire,
        "durable recovery still shipped everything: {} vs {}",
        s_durable.bytes_on_wire,
        s_wire.bytes_on_wire
    );
    assert_eq!(
        s_durable.rows_moved + s_durable.rows_recovered,
        s_wire.rows_moved,
        "the recovered rows are exactly the rows the wire path shipped extra"
    );

    // rejoin: the rank's own grave still covers its band, so the rejoin
    // also recovers from disk
    let s_rejoin = with_store.apply(MembershipEvent::Join { rank: 2 }).expect("rejoin");
    assert!(s_rejoin.recovered_from_durable, "rejoin should reuse the grave");
    with_store.verify_against(&full).expect("rejoined bits");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn incremental_migration_moves_less_than_full_reshard() {
    let full = reference_table();
    let mut inc = ElasticCluster::new(&full, WORLD, opts(None)).expect("cluster");
    let mut naive = ElasticCluster::new(&full, WORLD, opts(None)).expect("cluster");
    let ev = MembershipEvent::Leave { rank: 3 };
    let si = inc.apply_mode(ev, MigrationMode::Incremental).expect("incremental");
    let sf = naive.apply_mode(ev, MigrationMode::FullReshard).expect("full reshard");
    inc.verify_against(&full).expect("incremental bits");
    naive.verify_against(&full).expect("full-reshard bits");
    assert_eq!(sf.rows_moved, ROWS, "a full reshard ships every row");
    assert!(si.rows_moved < sf.rows_moved, "{} vs {}", si.rows_moved, sf.rows_moved);
    assert!(
        si.bytes_on_wire < sf.bytes_on_wire,
        "incremental must move strictly fewer bytes: {} vs {}",
        si.bytes_on_wire,
        sf.bytes_on_wire
    );
}

// ---------------------------------------------------------------------
// fencing and delays
// ---------------------------------------------------------------------

#[test]
fn stale_epoch_traffic_is_rejected_deterministically() {
    assert!(fence(3, 3).is_ok());
    let err = fence(2, 3).expect_err("stale epoch must be rejected");
    assert_eq!((err.got, err.want), (2, 3));
    // newer-than-expected is just as fatal: fences are exact
    assert!(fence(4, 3).is_err());
}

#[test]
fn delays_change_time_never_bits() {
    let full = reference_table();
    let ev = MembershipEvent::Leave { rank: 3 };

    let mut calm = ElasticCluster::new(&full, WORLD, opts(None)).expect("cluster");
    let s_calm = calm.apply(ev).expect("calm run");

    // 5 simulated seconds on the first send of rank 3 (the band source)
    fault::arm_delay(3, 1, 5.0);
    let mut slow = ElasticCluster::new(&full, WORLD, opts(None)).expect("cluster");
    let s_slow = slow.apply(ev).expect("delayed run");
    fault::disarm();

    assert!(
        s_slow.sim_secs > s_calm.sim_secs + 4.0,
        "delay not reflected in simulated time: {} vs {}",
        s_slow.sim_secs,
        s_calm.sim_secs
    );
    assert_eq!(s_slow.bytes_on_wire, s_calm.bytes_on_wire, "delays move no extra bytes");
    assert_bits_eq(
        &slow.table().to_full(),
        &calm.table().to_full(),
        "delayed migration values",
    );
    slow.verify_against(&full).expect("delayed bits");
}
