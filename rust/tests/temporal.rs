//! Temporal-engine integration (DESIGN.md §Temporal): the hard contract
//! is **bit-identity** — every published epoch snapshot must equal a
//! cold full-graph rerun of the graph as of that epoch's boundary tick,
//! for every model in the zoo, resident and spilled, at every thread
//! count. No tolerances anywhere in this file: the delta engine runs in
//! exact mode under the temporal engine, so parity is `assert_eq` on
//! `f32` bits.

use std::sync::Arc;

use deal::config::DealConfig;
use deal::model::ModelKind;
use deal::runtime::{par, Backend, Native};
use deal::serve::response_digest;
use deal::storage::with_mem_budget;
use deal::temporal::{TemporalEngine, TemporalOpts};
use deal::traffic::temporal_probe;

fn temporal_cfg(kind: &str, aggregator: &str) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "products-sim".into();
    cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = 2;
    cfg.model.kind = kind.into();
    cfg.model.aggregator = aggregator.into();
    cfg.model.layers = 2;
    cfg.model.fanout = 5;
    cfg
}

/// Run `epochs` windows of the synthetic stream, hard-asserting after
/// every seal that the published snapshot is bit-identical to a cold
/// full-graph recompute. Returns the per-epoch snapshot digests.
fn run_and_check(cfg: &DealConfig, epochs: u64) -> Vec<u64> {
    let opts = TemporalOpts { snapshot_every: 6, retain: epochs as usize + 1, durable_dir: None };
    let mut eng = TemporalEngine::new(cfg.clone(), &opts).unwrap();
    let mut digests = Vec::new();
    for _ in 0..epochs {
        let events = eng.synth_events(10, 10, 2);
        eng.ingest(&events).unwrap();
        let sealed = eng.advance_to((eng.epoch() + 1) * 6).unwrap();
        assert_eq!(sealed.len(), 1);
        let snap = eng.snapshot_at(eng.epoch()).unwrap().to_full();
        let cold = eng.cold_oracle().unwrap();
        assert_eq!(
            snap, cold,
            "{}/{}: epoch {} snapshot != cold full-graph rerun",
            cfg.model.kind,
            cfg.model.aggregator,
            eng.epoch()
        );
        digests.push(sealed[0].digest);
    }
    digests
}

/// The tentpole sweep: every model in the zoo, resident and spilled,
/// at two thread counts — snapshots bit-identical to cold reruns in
/// every cell, and digests identical across all cells.
fn sweep_model(kind: &str, aggregator: &str) {
    let cfg = temporal_cfg(kind, aggregator);
    let mut baseline: Option<Vec<u64>> = None;
    for threads in [1usize, 3] {
        for budget in [0u64, 48 << 10] {
            let digests =
                par::with_threads(threads, || with_mem_budget(budget, || run_and_check(&cfg, 2)));
            match &baseline {
                None => baseline = Some(digests),
                Some(b) => assert_eq!(
                    &digests, b,
                    "{}/{}: snapshot digests changed at threads={} budget={}",
                    kind, aggregator, threads, budget
                ),
            }
        }
    }
}

#[test]
fn gcn_snapshots_bit_identical_to_cold_rerun_resident_and_spilled() {
    sweep_model("gcn", "mean");
}

#[test]
fn gat_snapshots_bit_identical_to_cold_rerun_resident_and_spilled() {
    sweep_model("gat", "mean");
}

#[test]
fn sage_mean_snapshots_bit_identical_to_cold_rerun_resident_and_spilled() {
    sweep_model("sage", "mean");
}

#[test]
fn sage_pool_snapshots_bit_identical_to_cold_rerun_resident_and_spilled() {
    sweep_model("sage", "pool");
}

/// Trait-coverage guard: the sweep above must exercise every registered
/// `ModelKind` — adding a model to the zoo without extending the parity
/// matrix fails here, not silently.
#[test]
fn parity_matrix_covers_every_model_kind() {
    let exercised = ["gcn", "gat", "sage"];
    for kind in ModelKind::ALL {
        assert!(
            exercised.contains(&kind.name()),
            "ModelKind::{:?} is not exercised by the temporal parity matrix — \
             add a sweep_model case for '{}'",
            kind,
            kind.name()
        );
    }
    assert_eq!(exercised.len(), ModelKind::ALL.len(), "stale kinds in the exercised list");
}

/// Time-travel responses must be bit-stable across retention eviction:
/// the digest of a probe served at epoch 1 while it is resident equals
/// the digest served after eviction, when epoch 1 only exists as a
/// journal replay.
#[test]
fn time_travel_digests_survive_retention_eviction() {
    let dir = std::env::temp_dir().join(format!("deal-temporal-it-evict-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = temporal_cfg("gcn", "mean");
    let opts = TemporalOpts { snapshot_every: 4, retain: 2, durable_dir: Some(dir.clone()) };
    let mut eng = TemporalEngine::new(cfg.clone(), &opts).unwrap();
    let backend: Arc<dyn Backend> = Arc::new(Native);
    let reqs = temporal_probe(cfg.exec.seed, eng.state().n_nodes(), 10);

    let mut seal = |eng: &mut TemporalEngine| {
        let events = eng.synth_events(8, 8, 1);
        eng.ingest(&events).unwrap();
        eng.advance_to((eng.epoch() + 1) * 4).unwrap();
    };
    seal(&mut eng);
    assert!(eng.retained_epochs().contains(&1));
    let resident: Vec<u64> = eng
        .serve_at(1, Arc::clone(&backend), &reqs)
        .unwrap()
        .iter()
        .map(response_digest)
        .collect();

    for _ in 0..3 {
        seal(&mut eng);
    }
    assert!(!eng.retained_epochs().contains(&1), "retain=2 must evict epoch 1");
    let replayed: Vec<u64> = eng
        .serve_at(1, Arc::clone(&backend), &reqs)
        .unwrap()
        .iter()
        .map(response_digest)
        .collect();
    assert_eq!(resident, replayed, "eviction changed time-travel response bits");

    // every retained epoch still answers directly and exactly
    for epoch in eng.retained_epochs() {
        let snap = eng.snapshot_at(epoch).unwrap();
        match &eng.serve_at(epoch, Arc::clone(&backend), &reqs[..1]).unwrap()[0] {
            deal::serve::Response::Embeddings(m) => {
                let id = reqs[0].ids()[0];
                assert_eq!(m.row(0), snap.row(id), "epoch {} row drift", epoch);
            }
            other => panic!("unexpected response {:?}", other),
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--resume` contract: a resumed engine rebuilds the epoch index from
/// the durable generations bit-for-bit — same digests, same retained
/// epochs, same time-travel bits — and keeps sealing on top of it.
#[test]
fn resume_restores_epoch_index_from_durable_generations() {
    let dir = std::env::temp_dir().join(format!("deal-temporal-it-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = temporal_cfg("sage", "mean");
    let opts = TemporalOpts { snapshot_every: 5, retain: 3, durable_dir: Some(dir.clone()) };
    let backend: Arc<dyn Backend> = Arc::new(Native);

    let mut eng = TemporalEngine::new(cfg.clone(), &opts).unwrap();
    let reqs = temporal_probe(cfg.exec.seed, eng.state().n_nodes(), 8);
    for _ in 0..3 {
        let events = eng.synth_events(9, 9, 1);
        eng.ingest(&events).unwrap();
        eng.advance_to((eng.epoch() + 1) * 5).unwrap();
    }
    let digests: Vec<u64> = eng.reports().iter().map(|r| r.digest).collect();
    let retained = eng.retained_epochs();
    let at2: Vec<u64> = eng
        .serve_at(2, Arc::clone(&backend), &reqs)
        .unwrap()
        .iter()
        .map(response_digest)
        .collect();
    drop(eng);

    let mut resumed = TemporalEngine::resume(cfg.clone(), &opts).unwrap();
    assert_eq!(resumed.epoch(), 3);
    assert_eq!(resumed.retained_epochs(), retained);
    assert_eq!(
        resumed.reports().iter().map(|r| r.digest).collect::<Vec<_>>(),
        digests,
        "resume rebuilt different snapshots"
    );
    let at2_resumed: Vec<u64> = resumed
        .serve_at(2, Arc::clone(&backend), &reqs)
        .unwrap()
        .iter()
        .map(response_digest)
        .collect();
    assert_eq!(at2, at2_resumed, "time travel changed bits across the restart");

    // sealing continues exactly where the pre-restart engine would have:
    // the synthesized stream is seed-derived per epoch, so epoch 4 is
    // identical to what an unrestarted engine seals
    let events = resumed.synth_events(9, 9, 1);
    resumed.ingest(&events).unwrap();
    resumed.advance_to(20).unwrap();
    assert_eq!(resumed.epoch(), 4);
    assert_eq!(
        resumed.snapshot_at(4).unwrap().to_full(),
        resumed.cold_oracle().unwrap(),
        "post-resume epoch is not bit-identical to a cold rerun"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Snapshot bits must not depend on how the event stream is chopped
/// across `ingest` calls — one call, per-event calls, and a resumed
/// engine all seal identical epochs (the batching-invariance half of
/// the temporal contract).
#[test]
fn snapshots_never_depend_on_replay_batching() {
    let cfg = temporal_cfg("gat", "mean");
    let opts = TemporalOpts { snapshot_every: 12, retain: 4, durable_dir: None };
    let mut whole = TemporalEngine::new(cfg.clone(), &opts).unwrap();
    let mut split = TemporalEngine::new(cfg, &opts).unwrap();
    for _ in 0..2 {
        let events = whole.synth_events(14, 14, 2);
        whole.ingest(&events).unwrap();
        for chunk in events.chunks(3) {
            split.ingest(chunk).unwrap();
        }
        let a = whole.advance_to((whole.epoch() + 1) * 12).unwrap();
        let b = split.advance_to((split.epoch() + 1) * 12).unwrap();
        assert_eq!(a[0].digest, b[0].digest, "epoch {} depends on ingest chunking", a[0].epoch);
        assert_eq!(
            whole.snapshot_at(whole.epoch()).unwrap().to_full(),
            split.snapshot_at(split.epoch()).unwrap().to_full()
        );
    }
}
