//! The shipped example configs must parse and resolve; the smoke config
//! must run end to end.

use deal::config::DealConfig;
use deal::coordinator::Pipeline;

#[test]
fn shipped_configs_parse_and_resolve() {
    for name in ["products_gcn", "spammer_gat", "smoke"] {
        let path = format!("configs/{}.toml", name);
        let cfg = DealConfig::from_file(std::path::Path::new(&path))
            .unwrap_or_else(|e| panic!("{}: {}", path, e));
        cfg.parts().unwrap();
        cfg.exec_mode().unwrap();
        deal::coordinator::FeaturePrep::parse(&cfg.exec.feature_prep).unwrap();
    }
}

#[test]
fn smoke_config_runs_end_to_end() {
    let cfg = DealConfig::from_file(std::path::Path::new("configs/smoke.toml")).unwrap();
    let report = Pipeline::new(cfg).run().unwrap();
    let e = report.embeddings.unwrap();
    assert_eq!(e.rows, 256);
    assert!(e.data.iter().all(|v| v.is_finite()));
}
