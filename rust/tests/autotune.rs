//! Planner-vs-exhaustive oracle (DESIGN.md §Autotuning): on seeded small
//! clusters the autotuner must (a) never change output values — the
//! embeddings under the planner-selected plan are **bit-identical** to
//! every fixed configuration in an exhaustive sweep over execution mode
//! × chunk size × thread count — and (b) land at or near the best fixed
//! configuration's simulated inference time.
//!
//! Also covers the calibration sidecar lifecycle (reload skips the
//! measurement pass; corrupt / truncated / version-mismatched sidecars
//! are rejected with errors naming the cause, then fall back to a fresh
//! measurement) and end-to-end ring-direction invariance.

use deal::cluster::collectives::{with_ring_dir, RingDir};
use deal::cluster::net::with_chunk_rows;
use deal::config::DealConfig;
use deal::coordinator::Pipeline;
use deal::runtime::autotune::{with_autotune, Calibration, CalibrationSource};
use deal::runtime::par;
use deal::tensor::Matrix;
use std::path::PathBuf;

const MODES: [&str; 3] = ["monolithic", "grouped", "pipelined"];
const CHUNKS: [usize; 4] = [0, 64, 256, 4096];
const THREADS: [usize; 2] = [1, 4];

/// Sim-time slack for the planner against the exhaustively-best fixed
/// configuration. The cost model prices closed forms, not the exact
/// event schedule, so "matches or beats" means within this factor (the
/// bit-identity assertions above it have no slack at all).
const PLANNER_SLACK: f64 = 1.20;

/// 256-node seeded pipeline on 4 simulated machines; `feature_parts`
/// picks the grid: 2 → a 2×2 cluster (P=2 graph × M=2 feature), 4 → a
/// 1×4 cluster (feature-parallel only).
fn small_cfg(kind: &str, prep: &str, feature_parts: usize) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "products-sim".into();
    cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = feature_parts;
    cfg.model.kind = kind.into();
    cfg.model.layers = 2;
    cfg.model.fanout = 5;
    cfg.exec.feature_prep = prep.into();
    cfg
}

/// One fixed-configuration run: returns the embeddings and the
/// simulated inference seconds.
fn run_fixed(
    kind: &str,
    prep: &str,
    feature_parts: usize,
    mode: &str,
    chunk: usize,
    threads: usize,
) -> (Matrix, f64) {
    let mut cfg = small_cfg(kind, prep, feature_parts);
    cfg.exec.mode = mode.into();
    // Pin the tuner off so the fixed rows stay fixed even when the suite
    // runs under `DEAL_AUTOTUNE=1` (the CI sweep that planner-tunes every
    // other test).
    let report = with_autotune(false, || {
        with_chunk_rows(chunk, || {
            par::with_threads(threads, || {
                Pipeline::new(cfg).run().expect("pipeline run failed")
            })
        })
    });
    let sim = report.stages.sim_of("inference");
    (report.embeddings.expect("embeddings kept"), sim)
}

/// The oracle: exhaustive fixed sweep, then the planner, on one shape.
fn oracle(kind: &str, prep: &str, feature_parts: usize) {
    // Baseline: monolithic, unchunked, serial.
    let (base, base_sim) = run_fixed(kind, prep, feature_parts, "monolithic", 0, 1);
    assert!(base.data.iter().all(|v| v.is_finite()));

    let mut best_sim = base_sim;
    for &mode in &MODES {
        for &chunk in &CHUNKS {
            for &threads in &THREADS {
                if mode == "monolithic" && chunk == 0 && threads == 1 {
                    continue; // the baseline itself
                }
                let (got, sim) = run_fixed(kind, prep, feature_parts, mode, chunk, threads);
                assert_eq!(
                    got, base,
                    "{} m={} diverged at mode={} chunk_rows={} threads={}",
                    kind, feature_parts, mode, chunk, threads
                );
                best_sim = best_sim.min(sim);
            }
        }
    }

    // Planner-selected plan: same values, competitive simulated time.
    let mut cfg = small_cfg(kind, prep, feature_parts);
    cfg.exec.autotune = true;
    let report = Pipeline::new(cfg).run().expect("autotuned pipeline run failed");
    let plan = report.autotune.as_ref().expect("autotuned run records its plan");
    assert_eq!(plan.layers.len(), 2, "one choice per layer");
    let tuned = report.embeddings.as_ref().expect("embeddings kept");
    assert_eq!(
        *tuned, base,
        "{} m={}: planner-selected plan changed output values",
        kind, feature_parts
    );
    let tuned_sim = report.stages.sim_of("inference");
    assert!(
        tuned_sim <= best_sim * PLANNER_SLACK + 1e-3,
        "{} m={}: planner sim {:.6}s exceeds best fixed {:.6}s (slack {})",
        kind,
        feature_parts,
        tuned_sim,
        best_sim,
        PLANNER_SLACK
    );
}

#[test]
fn planner_matches_exhaustive_gcn_2x2() {
    // fused prep: covers the fused first layer + `gcn_rest` re-indexing
    oracle("gcn", "fused", 2);
}

#[test]
fn planner_matches_exhaustive_gcn_1x4() {
    oracle("gcn", "redistribute", 4);
}

#[test]
fn planner_matches_exhaustive_gat_2x2() {
    oracle("gat", "redistribute", 2);
}

#[test]
fn planner_matches_exhaustive_gat_1x4() {
    oracle("gat", "redistribute", 4);
}

/// Ring all-to-all direction is part of the plan space, so prove it is
/// value-invariant end-to-end, not just at the collective level.
#[test]
fn ring_direction_invariant_end_to_end() {
    let (base, _) = run_fixed("gcn", "redistribute", 2, "pipelined", 64, 1);
    let (rev, _) = with_ring_dir(RingDir::Reverse, || {
        run_fixed("gcn", "redistribute", 2, "pipelined", 64, 1)
    });
    assert_eq!(rev, base, "ring direction changed output values");
}

// ------------------------------------------------- calibration sidecar

/// Per-test sidecar path under the build directory (unique names keep
/// the parallel test threads off each other's files).
fn test_sidecar(name: &str) -> PathBuf {
    PathBuf::from(format!("target/autotune-test/{}.json", name))
}

#[test]
fn sidecar_reload_skips_measurement() {
    let path = test_sidecar("reload");
    let _ = std::fs::remove_file(&path);
    let (c1, s1) = Calibration::load_or_measure(&path, 42);
    assert_eq!(s1, CalibrationSource::Measured, "cold start must measure");
    let (c2, s2) = Calibration::load_or_measure(&path, 42);
    assert_eq!(s2, CalibrationSource::Loaded, "second run must reuse the sidecar");
    assert_eq!(c2, c1, "loaded constants must equal the saved ones exactly");
    // A different seed invalidates the cache.
    let (_, s3) = Calibration::load_or_measure(&path, 43);
    assert_eq!(s3, CalibrationSource::Measured, "seed change must re-measure");
}

#[test]
fn sidecar_reemit_is_byte_identical() {
    let path = test_sidecar("reemit");
    let _ = std::fs::remove_file(&path);
    let (c, _) = Calibration::load_or_measure(&path, 7);
    let first = std::fs::read_to_string(&path).expect("sidecar written");
    c.save(&path).expect("re-save");
    let second = std::fs::read_to_string(&path).expect("sidecar re-written");
    assert_eq!(second, first, "save → load → save must be byte-identical");
    assert_eq!(Calibration::load(&path).expect("valid sidecar"), c);
}

#[test]
fn sidecar_rejects_corruption_and_falls_back() {
    let path = test_sidecar("corrupt");
    let _ = std::fs::remove_file(&path);
    let (_, _) = Calibration::load_or_measure(&path, 9);
    let good = std::fs::read_to_string(&path).expect("sidecar written");

    // Flipped checksum digit → checksum error.
    let pos = good.find("fnv1a:").expect("checksum line present") + "fnv1a:".len();
    let mut bad = good.clone().into_bytes();
    bad[pos] = if bad[pos] == b'0' { b'1' } else { b'0' };
    std::fs::write(&path, &bad).expect("write corrupt sidecar");
    let err = Calibration::load(&path).unwrap_err().to_string();
    assert!(err.contains("checksum"), "unexpected error: {}", err);

    // load_or_measure falls back to a fresh pass and repairs the file.
    let (_, src) = Calibration::load_or_measure(&path, 9);
    assert_eq!(src, CalibrationSource::Measured, "corrupt sidecar must re-measure");
    assert!(Calibration::load(&path).is_ok(), "fallback must rewrite a valid sidecar");

    // Truncation → missing-field or torn-checksum error.
    let good = std::fs::read_to_string(&path).expect("repaired sidecar");
    std::fs::write(&path, &good[..good.len() / 2]).expect("write truncated sidecar");
    let err = Calibration::load(&path).unwrap_err().to_string();
    assert!(
        err.contains("truncated") || err.contains("checksum"),
        "unexpected error: {}",
        err
    );

    // Version mismatch → version error (named before the checksum check).
    let vbad = good.replace("\"version\": 1,", "\"version\": 999,");
    assert_ne!(vbad, good, "version line must be present to corrupt");
    std::fs::write(&path, &vbad).expect("write version-mismatched sidecar");
    let err = Calibration::load(&path).unwrap_err().to_string();
    assert!(err.contains("version"), "unexpected error: {}", err);

    // Foreign format → format error.
    let fbad = good.replace("deal-autotune-calibration", "some-other-format");
    assert_ne!(fbad, good, "format line must be present to corrupt");
    std::fs::write(&path, &fbad).expect("write foreign sidecar");
    let err = Calibration::load(&path).unwrap_err().to_string();
    assert!(err.contains("not a calibration sidecar"), "unexpected error: {}", err);

    // Missing file → readable error.
    let _ = std::fs::remove_file(&path);
    let err = Calibration::load(&path).unwrap_err().to_string();
    assert!(err.contains("cannot read"), "unexpected error: {}", err);
}
