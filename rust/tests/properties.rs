//! Cross-module property tests on coordinator invariants (DESIGN.md
//! testing strategy): random graphs × random plans ⇒ distributed results
//! equal dense oracles; cost formulas track measured bytes; sampling is a
//! bounded subgraph.

use std::sync::Arc;

use deal::cluster::{Cluster, NetConfig};
use deal::graph::{Csr, NodeId};
use deal::partition::PartitionPlan;
use deal::primitives::costs::{self, CostParams};
use deal::primitives::gemm::deal_gemm;
use deal::primitives::spmm::{deal_spmm, spmm_reference, EdgeValues, SpmmInput};
use deal::primitives::{gather_tiles, mean_weights, scatter, ExecMode};
use deal::tensor::Matrix;
use deal::util::prop::{assert_close, run, Config};
use deal::util::rng::Rng;

#[test]
fn random_pipeline_primitives_match_oracles() {
    run(Config::default().cases(8), |rng| {
        let p = rng.range(1, 4);
        let m = rng.range(1, 4);
        let n = rng.range(p * m * 4, 64);
        let d = rng.range(m * 2, 24);
        let ne = rng.range(1, n * 5);
        let edges: Vec<(NodeId, NodeId)> = (0..ne)
            .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
            .collect();
        let g = Csr::from_edges(n, &edges);
        let h = Matrix::random(n, d, 1.0, rng);
        // GEMM output plan needs w.cols >= m feature parts
        let w = Matrix::random(d, rng.range(m.max(2), 16), 1.0, rng);
        let plan = PartitionPlan::new(n, d, p, m);
        let vals = mean_weights(&g);

        // chained: GEMM then SPMM over the GEMM output, distributed
        let plan2 = plan.clone();
        let tiles = Arc::new(scatter(&plan, &h));
        let g2 = Arc::new(g.clone());
        let w2 = Arc::new(w.clone());
        let vals2 = Arc::new(vals.clone());
        let mode = ExecMode::ALL[rng.next_below(3)];
        let maxc = [0usize, 8, 64][rng.next_below(3)];
        let cluster = Cluster::new(plan.world(), NetConfig::default());
        let (outs, _) = cluster
            .run(move |ctx| {
                let backend = deal::runtime::Native;
                let hw = deal_gemm(ctx, &plan2, &tiles[ctx.rank], &w2, &backend, 3).unwrap();
                // build a plan for the GEMM output width
                let plan_out = PartitionPlan::new(plan2.n_nodes, w2.cols, plan2.p, plan2.m);
                let (p_idx, _) = plan_out.coords_of(ctx.rank);
                let (lo, hi) = plan_out.node_range(p_idx);
                let sub = g2.slice_rows(lo, hi);
                let svals =
                    vals2[g2.indptr[lo] as usize..g2.indptr[hi] as usize].to_vec();
                let input = SpmmInput {
                    plan: &plan_out,
                    g: &sub,
                    vals: EdgeValues::Scalar(&svals),
                    h: &hw,
                };
                deal_spmm(ctx, &input, &backend, mode, maxc, 5)
            })
            .unwrap();
        let plan_out = PartitionPlan::new(plan.n_nodes, w.cols, plan.p, plan.m);
        let got = gather_tiles(&plan_out, w.cols, &outs);
        let expect = spmm_reference(&g, &vals, &h.matmul(&w));
        assert_close(&got.data, &expect.data, 2e-3, 2e-3)
    });
}

#[test]
fn gemm_cost_model_tracks_measured_bytes() {
    // measured sent bytes per machine must match Table 1's formula within
    // the envelope overhead (64 B/message).
    run(Config::default().cases(6), |rng| {
        let p = rng.range(1, 3);
        let m = rng.range(2, 5);
        let n = p * m * rng.range(4, 16);
        let d = m * rng.range(2, 8);
        let plan = PartitionPlan::new(n, d, p, m);
        let h = Matrix::random(n, d, 1.0, rng);
        let w = Matrix::random(d, d, 1.0, rng);
        let tiles = Arc::new(scatter(&plan, &h));
        let plan2 = plan.clone();
        let w2 = Arc::new(w.clone());
        let cluster = Cluster::new(plan.world(), NetConfig::default());
        let (_, report) = cluster
            .run(move |ctx| {
                deal_gemm(ctx, &plan2, &tiles[ctx.rank], &w2, &deal::runtime::Native, 3).unwrap()
            })
            .unwrap();
        let c = CostParams::new(n, d, p, m, 0.0);
        let predicted_elems = costs::gemm_ours_comm(&c); // per machine
        for (rank, mm) in report.machines.iter().enumerate() {
            let payload = mm.bytes_sent.saturating_sub(64 * mm.msgs_sent); // strip envelopes
            let lo = predicted_elems * 4.0 * 0.5;
            let hi = predicted_elems * 4.0 * 1.5 + 64.0;
            let ok = (lo..=hi).contains(&(payload as f64));
            if !ok {
                return Err(format!(
                    "rank {}: measured {} B predicted {} B (n={} d={} p={} m={})",
                    rank,
                    payload,
                    predicted_elems * 4.0,
                    n,
                    d,
                    p,
                    m
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn sampling_layer_graphs_are_bounded_subgraphs() {
    run(Config::default().cases(12), |rng| {
        let n = rng.range(4, 120);
        let e = rng.range(n, n * 6);
        let edges: Vec<(NodeId, NodeId)> = (0..e)
            .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
            .collect();
        let g = Csr::from_edges(n, &edges);
        let k = rng.range(1, 4);
        let fanout = rng.range(1, 6);
        let lg = deal::sampling::sample_all_layers(&g, k, fanout, rng.next_u64());
        for layer in &lg.layers {
            layer.validate()?;
            if layer.n_edges() > g.n_edges() {
                return Err("sampled more edges than exist".into());
            }
            for v in 0..n {
                if layer.degree(v) > fanout.min(g.degree(v)).max(g.degree(v).min(fanout)) {
                    return Err(format!("degree bound violated at {}", v));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn partition_plans_compose_with_rng() {
    // smoke: plans built from random configs always validate
    let mut rng = Rng::new(1);
    for _ in 0..50 {
        let p = rng.range(1, 9);
        let m = rng.range(1, 9);
        let n = rng.range(p.max(m) * 2, 2000);
        let d = rng.range(m, 256).max(m);
        let plan = PartitionPlan::new(n, d, p, m);
        plan.validate().unwrap();
    }
}
