//! Cross-module property tests on coordinator invariants (DESIGN.md
//! testing strategy): random graphs × random plans ⇒ distributed results
//! equal dense oracles; cost formulas track measured bytes; sampling is a
//! bounded subgraph.

use std::sync::Arc;

use deal::cluster::{Cluster, NetConfig};
use deal::graph::{Csr, NodeId};
use deal::partition::PartitionPlan;
use deal::primitives::costs::{self, CostParams};
use deal::primitives::gemm::deal_gemm;
use deal::primitives::spmm::{deal_spmm, spmm_reference, EdgeValues, SpmmInput};
use deal::primitives::{gather_tiles, mean_weights, scatter, ExecMode};
use deal::tensor::Matrix;
use deal::util::prop::{assert_close, run, Config};
use deal::util::rng::Rng;

#[test]
fn random_pipeline_primitives_match_oracles() {
    run(Config::default().cases(8), |rng| {
        let p = rng.range(1, 4);
        let m = rng.range(1, 4);
        let n = rng.range(p * m * 4, 64);
        let d = rng.range(m * 2, 24);
        let ne = rng.range(1, n * 5);
        let edges: Vec<(NodeId, NodeId)> = (0..ne)
            .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
            .collect();
        let g = Csr::from_edges(n, &edges);
        let h = Matrix::random(n, d, 1.0, rng);
        // GEMM output plan needs w.cols >= m feature parts
        let w = Matrix::random(d, rng.range(m.max(2), 16), 1.0, rng);
        let plan = PartitionPlan::new(n, d, p, m);
        let vals = mean_weights(&g);

        // chained: GEMM then SPMM over the GEMM output, distributed
        let plan2 = plan.clone();
        let tiles = Arc::new(scatter(&plan, &h));
        let g2 = Arc::new(g.clone());
        let w2 = Arc::new(w.clone());
        let vals2 = Arc::new(vals.clone());
        let mode = ExecMode::ALL[rng.next_below(3)];
        let maxc = [0usize, 8, 64][rng.next_below(3)];
        let cluster = Cluster::new(plan.world(), NetConfig::default());
        let (outs, _) = cluster
            .run(move |ctx| {
                let backend = deal::runtime::Native;
                let hw = deal_gemm(ctx, &plan2, &tiles[ctx.rank], &w2, &backend, 3).unwrap();
                // build a plan for the GEMM output width
                let plan_out = PartitionPlan::new(plan2.n_nodes, w2.cols, plan2.p, plan2.m);
                let (p_idx, _) = plan_out.coords_of(ctx.rank);
                let (lo, hi) = plan_out.node_range(p_idx);
                let sub = g2.slice_rows(lo, hi);
                let svals =
                    vals2[g2.indptr[lo] as usize..g2.indptr[hi] as usize].to_vec();
                let input = SpmmInput {
                    plan: &plan_out,
                    g: &sub,
                    vals: EdgeValues::Scalar(&svals),
                    h: &hw,
                };
                deal_spmm(ctx, &input, &backend, mode, maxc, 5)
            })
            .unwrap();
        let plan_out = PartitionPlan::new(plan.n_nodes, w.cols, plan.p, plan.m);
        let got = gather_tiles(&plan_out, w.cols, &outs);
        let expect = spmm_reference(&g, &vals, &h.matmul(&w));
        assert_close(&got.data, &expect.data, 2e-3, 2e-3)
    });
}

#[test]
fn gemm_cost_model_tracks_measured_bytes() {
    // measured sent bytes per machine must match Table 1's formula within
    // the envelope overhead (64 B/message).
    run(Config::default().cases(6), |rng| {
        let p = rng.range(1, 3);
        let m = rng.range(2, 5);
        let n = p * m * rng.range(4, 16);
        let d = m * rng.range(2, 8);
        let plan = PartitionPlan::new(n, d, p, m);
        let h = Matrix::random(n, d, 1.0, rng);
        let w = Matrix::random(d, d, 1.0, rng);
        let tiles = Arc::new(scatter(&plan, &h));
        let plan2 = plan.clone();
        let w2 = Arc::new(w.clone());
        let cluster = Cluster::new(plan.world(), NetConfig::default());
        let (_, report) = cluster
            .run(move |ctx| {
                deal_gemm(ctx, &plan2, &tiles[ctx.rank], &w2, &deal::runtime::Native, 3).unwrap()
            })
            .unwrap();
        let c = CostParams::new(n, d, p, m, 0.0);
        let predicted_elems = costs::gemm_ours_comm(&c); // per machine
        for (rank, mm) in report.machines.iter().enumerate() {
            let payload = mm.bytes_sent.saturating_sub(64 * mm.msgs_sent); // strip envelopes
            let lo = predicted_elems * 4.0 * 0.5;
            let hi = predicted_elems * 4.0 * 1.5 + 64.0;
            let ok = (lo..=hi).contains(&(payload as f64));
            if !ok {
                return Err(format!(
                    "rank {}: measured {} B predicted {} B (n={} d={} p={} m={})",
                    rank,
                    payload,
                    predicted_elems * 4.0,
                    n,
                    d,
                    p,
                    m
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn sampling_layer_graphs_are_bounded_subgraphs() {
    run(Config::default().cases(12), |rng| {
        let n = rng.range(4, 120);
        let e = rng.range(n, n * 6);
        let edges: Vec<(NodeId, NodeId)> = (0..e)
            .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
            .collect();
        let g = Csr::from_edges(n, &edges);
        let k = rng.range(1, 4);
        let fanout = rng.range(1, 6);
        let lg = deal::sampling::sample_all_layers(&g, k, fanout, rng.next_u64());
        for layer in &lg.layers {
            layer.validate()?;
            if layer.n_edges() > g.n_edges() {
                return Err("sampled more edges than exist".into());
            }
            for v in 0..n {
                if layer.degree(v) > fanout.min(g.degree(v)).max(g.degree(v).min(fanout)) {
                    return Err(format!("degree bound violated at {}", v));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Intra-rank parallelism: every kernel `runtime::par` sits under must be
// **bit-identical** to its scalar path at every pool size (the determinism
// contract of DESIGN.md §Intra-rank parallelism). Shapes deliberately
// straddle the serial/parallel work thresholds so both scheduling paths run.

const THREAD_SWEEP: [usize; 3] = [2, 3, 8];

#[test]
fn parallel_dense_kernels_bit_identical_across_thread_counts() {
    use deal::runtime::par;
    run(Config::default().cases(6), |rng| {
        let m = rng.range(1, 140);
        let k = rng.range(1, 140);
        let n = rng.range(1, 140);
        let a = Matrix::random(m, k, 1.0, rng);
        let b = Matrix::random(k, n, 1.0, rng);
        let reference = par::with_threads(1, || (a.matmul(&b), a.transpose()));
        for t in THREAD_SWEEP {
            let got = par::with_threads(t, || (a.matmul(&b), a.transpose()));
            if got != reference {
                return Err(format!("matmul/transpose diverged at {} threads", t));
            }
        }
        Ok(())
    });
}

#[test]
fn parallel_sparse_kernels_bit_identical_across_thread_counts() {
    use deal::primitives::sddmm::sddmm_reference;
    use deal::runtime::{par, Backend, Native};
    use deal::tensor::{segment_sum, segment_sum_scaled};
    run(Config::default().cases(6), |rng| {
        let n = rng.range(2, 1200);
        let ne = rng.range(1, n * 12);
        let edges: Vec<(NodeId, NodeId)> = (0..ne)
            .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
            .collect();
        let g = par::with_threads(1, || Csr::from_edges(n, &edges));
        let d = rng.range(1, 80);
        let h = Matrix::random(n, d, 1.0, rng);
        let vals: Vec<f32> = (0..g.n_edges()).map(|_| rng.next_f32() + 0.1).collect();
        // spmm_tile inputs: pre-gathered per-edge rows + destination segments
        let mut seg: Vec<u32> = Vec::with_capacity(g.n_edges());
        let mut gathered: Vec<usize> = Vec::with_capacity(g.n_edges());
        for r in 0..g.n_rows {
            for &s in g.row(r) {
                seg.push(r as u32);
                gathered.push(s as usize);
            }
        }
        let feats = h.gather_rows(&gathered);
        let seg_usize: Vec<usize> = seg.iter().map(|&s| s as usize).collect();
        let snapshot = || -> (Matrix, Vec<f32>, Matrix, Vec<f32>, Matrix, Matrix) {
            (
                spmm_reference(&g, &vals, &h),
                sddmm_reference(&g, &h),
                Native.spmm_tile(&feats, &vals, &seg, g.n_rows).unwrap(),
                Native.sddmm_tile(&feats, &feats).unwrap(),
                segment_sum(&feats, &seg_usize, g.n_rows),
                segment_sum_scaled(&feats, &vals, &seg_usize, g.n_rows),
            )
        };
        let reference = par::with_threads(1, snapshot);
        for t in THREAD_SWEEP {
            let got = par::with_threads(t, snapshot);
            if got != reference {
                return Err(format!("sparse kernel diverged at {} threads", t));
            }
        }
        Ok(())
    });
}

#[test]
fn parallel_csr_build_and_compaction_bit_identical_across_thread_counts() {
    use deal::graph::delta::{PartitionDelta, UpdateBatch};
    use deal::runtime::par;
    run(Config::default().cases(6), |rng| {
        let n = rng.range(2, 2000);
        let ne = rng.range(1, 60_000);
        let edges: Vec<(NodeId, NodeId)> = (0..ne)
            .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
            .collect();
        let reference = par::with_threads(1, || Csr::from_edges(n, &edges));
        reference.validate()?;
        for t in THREAD_SWEEP {
            let got = par::with_threads(t, || Csr::from_edges(n, &edges));
            if got != reference {
                return Err(format!("CSR construction diverged at {} threads", t));
            }
        }
        // delta compaction over the same base
        let n_ops = rng.range(1, 2000);
        let batch = UpdateBatch {
            add_edges: (0..n_ops)
                .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
                .collect(),
            remove_edges: (0..n_ops)
                .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
                .collect(),
            feature_updates: vec![],
        };
        let compact_at = |threads: usize| {
            par::with_threads(threads, || {
                let mut delta = PartitionDelta::new(0, n);
                delta.stage(&batch);
                delta.compact(&reference)
            })
        };
        let (base_csr, base_dirty) = compact_at(1);
        base_csr.validate()?;
        for t in THREAD_SWEEP {
            let (csr, dirty) = compact_at(t);
            if csr != base_csr || dirty != base_dirty {
                return Err(format!("compaction diverged at {} threads", t));
            }
        }
        Ok(())
    });
}

#[test]
fn distributed_pipeline_bit_identical_across_pool_sizes() {
    // End-to-end over the simulated cluster: same chained GEMM → SPMM as
    // `random_pipeline_primitives_match_oracles`, fixed inputs, global pool
    // size swept — results must match **exactly** (the pool is process
    // global here because cluster ranks are their own threads).
    use deal::runtime::par;
    let mut rng = Rng::new(0x7EA1);
    let n = 96;
    let d = 16;
    let edges: Vec<(NodeId, NodeId)> = (0..n * 5)
        .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
        .collect();
    let g = Csr::from_edges(n, &edges);
    let h = Matrix::random(n, d, 1.0, &mut rng);
    let w = Matrix::random(d, 12, 1.0, &mut rng);
    let vals = mean_weights(&g);
    let plan = PartitionPlan::new(n, d, 2, 2);

    let run_once = || {
        let plan2 = plan.clone();
        let tiles = Arc::new(scatter(&plan, &h));
        let g2 = Arc::new(g.clone());
        let w2 = Arc::new(w.clone());
        let vals2 = Arc::new(vals.clone());
        let cluster = Cluster::new(plan.world(), NetConfig::default());
        let (outs, _) = cluster
            .run(move |ctx| {
                let backend = deal::runtime::Native;
                let hw = deal_gemm(ctx, &plan2, &tiles[ctx.rank], &w2, &backend, 3).unwrap();
                let plan_out = PartitionPlan::new(plan2.n_nodes, w2.cols, plan2.p, plan2.m);
                let (p_idx, _) = plan_out.coords_of(ctx.rank);
                let (lo, hi) = plan_out.node_range(p_idx);
                let sub = g2.slice_rows(lo, hi);
                let svals = vals2[g2.indptr[lo] as usize..g2.indptr[hi] as usize].to_vec();
                let input = SpmmInput {
                    plan: &plan_out,
                    g: &sub,
                    vals: EdgeValues::Scalar(&svals),
                    h: &hw,
                };
                deal_spmm(ctx, &input, &backend, ExecMode::Pipelined, 16, 5)
            })
            .unwrap();
        let plan_out = PartitionPlan::new(plan.n_nodes, w.cols, plan.p, plan.m);
        gather_tiles(&plan_out, w.cols, &outs)
    };

    // Restore the auto pool even if an assert below panics.
    struct RestorePool;
    impl Drop for RestorePool {
        fn drop(&mut self) {
            deal::runtime::par::set_threads(0);
        }
    }
    let _restore = RestorePool;
    par::set_threads(1);
    let serial = run_once();
    par::set_threads(4);
    let parallel = run_once();
    assert_eq!(serial, parallel, "cluster pipeline diverged across pool sizes");

    // Planner-selected row: an autotune plan (chunk granularity, ring
    // direction, pool width, per-layer mode) installed around the same
    // run is covered by the same bit-equality contract as fixed configs.
    use deal::runtime::autotune::{Calibration, Planner, ShapeInfo};
    let shape = ShapeInfo {
        n,
        d,
        p: 2,
        m: 2,
        layers: 1,
        z: 5.0,
        cores: 64.0,
        net: NetConfig::default(),
        budget_bytes: 0,
    };
    let tuned_plan = Arc::new(Planner::new(Calibration::assumed(0x7EA1)).plan(&shape));
    let tuned = tuned_plan.apply(run_once);
    assert_eq!(serial, tuned, "cluster pipeline diverged under autotune plan");
}

#[test]
fn batch_policies_bit_identical_on_replayed_traces() {
    // The batch-formation policy seam (serve::BatchPolicy) may only move
    // latency — never change responses. Replay random traces in
    // sequenced mode under every policy and require digest-equal
    // responses, and equality with the sequential EmbeddingServer oracle.
    use deal::runtime::Native;
    use deal::serve::{
        response_digest, BatchPolicy, EmbeddingServer, PoolOpts, ServePool, ShardedTable,
        TableCell,
    };
    use deal::traffic::{replay, ReplayMode, ReplayOpts, Trace, TraceConfig, TraceEvent};

    run(Config::default().cases(4), |rng| {
        let n = rng.range(16, 96);
        let d = rng.range(2, 12);
        let full = Matrix::random(n, d, 1.0, rng);
        let trace = Trace::generate(&TraceConfig {
            seed: rng.next_u64(),
            n_nodes: n,
            requests: rng.range(20, 120),
            zipf_s: rng.next_f64() * 1.5,
            similar_fraction: 0.3 + rng.next_f64() * 0.4,
            churn_batches: 0, // static table: the oracle below has no churn
            ..TraceConfig::default()
        });

        // sequential oracle digests
        let server = EmbeddingServer::new(full.clone());
        let oracle: Vec<u64> = trace
            .events
            .iter()
            .filter_map(|ev| match ev {
                TraceEvent::Request { req, .. } => Some(req),
                _ => None,
            })
            .map(|req| response_digest(&server.handle(req, &Native).unwrap()))
            .collect();

        let policies = [
            BatchPolicy::DepthFirst,
            BatchPolicy::Deadline { max_wait_us: rng.range(1, 500) as u64 },
            BatchPolicy::SizeCapped { max_ids: rng.range(1, 64) },
        ];
        for policy in policies {
            let shards = rng.range(1, 5);
            let cell =
                std::sync::Arc::new(TableCell::new(ShardedTable::from_full(&full, shards, 0)));
            let pool = ServePool::spawn(
                cell,
                std::sync::Arc::new(Native),
                PoolOpts { workers: rng.range(1, 4), policy, ..PoolOpts::default() },
            );
            let opts = ReplayOpts { mode: ReplayMode::Sequenced, ..ReplayOpts::default() };
            let rep = replay(&pool, &trace, &opts, |_| Ok(0)).map_err(|e| e.to_string())?;
            if rep.digests != oracle {
                let diverged = rep.digests.iter().zip(&oracle).filter(|(a, b)| a != b).count();
                return Err(format!(
                    "policy {:?} diverged from the sequential oracle on {}/{} responses (n={} d={} shards={})",
                    policy,
                    diverged,
                    oracle.len(),
                    n,
                    d,
                    shards
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn partition_plans_compose_with_rng() {
    // smoke: plans built from random configs always validate
    let mut rng = Rng::new(1);
    for _ in 0..50 {
        let p = rng.range(1, 9);
        let m = rng.range(1, 9);
        let n = rng.range(p.max(m) * 2, 2000);
        let d = rng.range(m, 256).max(m);
        let plan = PartitionPlan::new(n, d, p, m);
        plan.validate().unwrap();
    }
}
