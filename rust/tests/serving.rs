//! Integration tests for the serving subsystem (DESIGN.md §Serving):
//! the sharded/batched/pooled path is result-identical to the sequential
//! single-copy baseline under concurrent mixed load, refresh swaps never
//! serve a torn table, and admission control sheds overload instead of
//! queueing it.

use std::sync::Arc;

use deal::runtime::Native;
use deal::serve::{
    serve_workload_pooled, EmbeddingServer, PoolOpts, Request, RequestClass, Response, ServePool,
    ShardedTable, TableCell,
};
use deal::tensor::Matrix;
use deal::util::rng::Rng;

fn random_table(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::random(n, d, 1.0, &mut rng)
}

fn mixed_request(rng: &mut Rng, n: usize) -> Request {
    if rng.next_below(4) == 0 {
        Request::Similar {
            ids: (0..rng.range(1, 5)).map(|_| rng.next_below(n) as u32).collect(),
            k: rng.range(1, 12),
        }
    } else {
        Request::Embed((0..rng.range(1, 17)).map(|_| rng.next_below(n) as u32).collect())
    }
}

/// Pooled response == sequential `handle` response (ids exact, scores to
/// float tolerance, embeddings exact).
fn assert_same(want: &Response, got: &Response) {
    match (want, got) {
        (Response::Embeddings(w), Response::Embeddings(g)) => assert_eq!(w, g),
        (Response::Similar(w), Response::Similar(g)) => {
            assert_eq!(w.len(), g.len());
            for (wl, gl) in w.iter().zip(g) {
                let wi: Vec<u32> = wl.iter().map(|x| x.0).collect();
                let gi: Vec<u32> = gl.iter().map(|x| x.0).collect();
                assert_eq!(wi, gi, "ranked ids differ");
                for (a, b) in wl.iter().zip(gl) {
                    assert!((a.1 - b.1).abs() <= 1e-6, "score {} vs {}", a.1, b.1);
                }
            }
        }
        _ => panic!("response kind mismatch"),
    }
}

#[test]
fn concurrent_mixed_load_matches_sequential_handle() {
    let n = 300;
    let full = random_table(n, 16, 11);
    let server = Arc::new(EmbeddingServer::new(full.clone()));
    let cell = Arc::new(TableCell::new(ShardedTable::from_full(&full, 3, 0)));
    let opts = PoolOpts { workers: 3, queue_capacity: 256, max_batch: 32, ..PoolOpts::default() };
    let pool = Arc::new(ServePool::spawn(cell, Arc::new(Native), opts));

    let clients = 6;
    let per_client = 30;
    std::thread::scope(|scope| {
        for c in 0..clients {
            let pool = Arc::clone(&pool);
            let server = Arc::clone(&server);
            scope.spawn(move || {
                let mut rng = Rng::new(1000 + c as u64);
                for _ in 0..per_client {
                    let req = mixed_request(&mut rng, n);
                    let got = pool.call(req.clone()).expect("pooled call");
                    let want = server.handle(&req, &Native).expect("sequential handle");
                    assert_same(&want, &got);
                }
            });
        }
    });
    let stats = pool.stats();
    assert_eq!(stats.served, (clients * per_client) as u64);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.rejected, 0);
}

#[test]
fn coalesced_duplicate_queries_match_sequential_handle() {
    // duplicate query ids within and across coalesced requests exercise
    // the batcher's dedup + per-column top-k cache
    let n = 120;
    let full = random_table(n, 8, 23);
    let server = EmbeddingServer::new(full.clone());
    let cell = Arc::new(TableCell::new(ShardedTable::from_full(&full, 4, 0)));
    let opts = PoolOpts {
        workers: 1,
        queue_capacity: 64,
        max_batch: 64,
        start_paused: true,
        ..PoolOpts::default()
    };
    let pool = ServePool::spawn(cell, Arc::new(Native), opts);

    let reqs: Vec<Request> = vec![
        Request::Similar { ids: vec![7, 7, 30], k: 5 },
        Request::Similar { ids: vec![30, 7], k: 9 },
        Request::Embed(vec![0, 7, 30, 119]),
        Request::Similar { ids: vec![119], k: 200 }, // k > n clamps like the baseline
    ];
    let tickets: Vec<_> = reqs.iter().map(|r| pool.submit(r.clone()).unwrap()).collect();
    pool.resume();
    for (req, t) in reqs.iter().zip(tickets) {
        let got = t.wait().expect("pooled response");
        let want = server.handle(req, &Native).unwrap();
        assert_same(&want, &got);
    }
    let stats = pool.shutdown();
    assert_eq!(stats.batches, 1, "backlog should coalesce into one batch");
    assert_eq!(stats.coalesced_similar, 3);
}

#[test]
fn mid_flight_refresh_never_serves_a_torn_table() {
    // Every epoch's table is a distinct constant, so any mixed-epoch read
    // is detectable: an Embed row must be uniformly one epoch's constant,
    // and a Similar score must be d * c^2 for a published constant c.
    let n = 200;
    let d = 8;
    let epochs = 8u32;
    let constant = |c: f32| Matrix::from_vec(n, d, vec![c; n * d]);
    let cell = Arc::new(TableCell::new(ShardedTable::from_full(&constant(1.0), 4, 0)));
    let opts = PoolOpts { workers: 3, queue_capacity: 512, max_batch: 16, ..PoolOpts::default() };
    let pool = Arc::new(ServePool::spawn(Arc::clone(&cell), Arc::new(Native), opts));

    let valid_constants: Vec<f32> = (1..=epochs).map(|c| c as f32).collect();
    std::thread::scope(|scope| {
        // publisher: epochs 2..=8 swapped in while clients hammer the pool
        let pub_cell = Arc::clone(&cell);
        scope.spawn(move || {
            for c in 2..=epochs {
                std::thread::sleep(std::time::Duration::from_millis(2));
                let e = pub_cell.publish(ShardedTable::from_full(&constant(c as f32), 4, 0));
                assert_eq!(e, (c - 1) as u64);
            }
        });
        for t in 0..4 {
            let pool = Arc::clone(&pool);
            let valid = valid_constants.clone();
            scope.spawn(move || {
                let mut rng = Rng::new(900 + t as u64);
                for i in 0..60 {
                    if i % 3 == 0 {
                        let req = Request::Similar { ids: vec![rng.next_below(n) as u32], k: 4 };
                        match pool.call(req).expect("similar during refresh") {
                            Response::Similar(lists) => {
                                for list in &lists {
                                    assert_eq!(list.len(), 4);
                                    let s0 = list[0].1;
                                    let epoch_ok = valid
                                        .iter()
                                        .any(|&c| (s0 - d as f32 * c * c).abs() < 1e-3);
                                    assert!(epoch_ok, "torn/unknown score {}", s0);
                                    assert!(list.iter().all(|&(_, s)| s == s0), "torn scores");
                                }
                            }
                            _ => panic!("wrong response"),
                        }
                    } else {
                        let ids: Vec<u32> =
                            (0..8).map(|_| rng.next_below(n) as u32).collect();
                        match pool.call(Request::Embed(ids)).expect("embed during refresh") {
                            Response::Embeddings(m) => {
                                let c = m.get(0, 0);
                                assert!(valid.contains(&c), "unknown constant {}", c);
                                assert!(
                                    m.data.iter().all(|&v| v == c),
                                    "torn table: saw {} and {}",
                                    c,
                                    m.data.iter().find(|&&v| v != c).unwrap()
                                );
                            }
                            _ => panic!("wrong response"),
                        }
                    }
                }
            });
        }
    });
    let stats = pool.stats();
    assert_eq!(stats.failed, 0, "refresh swaps must not fail in-flight requests");
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.served, 4 * 60);
    assert_eq!(pool.epoch(), (epochs - 1) as u64);
}

#[test]
fn admission_control_rejects_only_when_queue_is_full() {
    let full = random_table(32, 4, 5);
    let cell = Arc::new(TableCell::new(ShardedTable::from_full(&full, 2, 0)));
    let opts = PoolOpts {
        workers: 1,
        queue_capacity: 4,
        max_batch: 8,
        start_paused: true,
        ..PoolOpts::default()
    };
    let pool = ServePool::spawn(cell, Arc::new(Native), opts);

    // gated workers drain nothing: exactly `queue_capacity` admissions
    let tickets: Vec<_> = (0..4)
        .map(|i| pool.submit(Request::Embed(vec![i as u32])).expect("within capacity"))
        .collect();
    let err = pool.submit(Request::Embed(vec![9])).unwrap_err();
    assert!(err.to_string().contains("queue full"), "got: {}", err);

    pool.resume();
    for t in tickets {
        t.wait().expect("queued requests still complete");
    }
    let stats = pool.shutdown();
    assert_eq!(stats.served, 4);
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.failed, 0);
}

#[test]
fn burst_overload_sheds_load_without_losing_accounting() {
    // A 10x admission burst against a gated single worker: every request
    // must land in exactly one counter bucket (served / rejected /
    // failed) — overload sheds load, it never silently drops requests —
    // and the latency summary over the served survivors stays finite.
    let n = 96;
    let full = random_table(n, 8, 41);
    let cell = Arc::new(TableCell::new(ShardedTable::from_full(&full, 2, 0)));
    let capacity = 16;
    let opts = PoolOpts {
        workers: 1,
        queue_capacity: capacity,
        max_batch: 8,
        start_paused: true, // gate the worker: the burst outruns service
        ..PoolOpts::default()
    };
    let pool = ServePool::spawn(cell, Arc::new(Native), opts);

    // 10x the queue capacity, alternating classes so both service
    // classes see admissions *and* rejections.
    let burst = 10 * capacity;
    let mut tickets = Vec::new();
    let mut admitted = [0u64; 2];
    let mut bounced = [0u64; 2];
    for i in 0..burst {
        let (req, class) = if i % 2 == 0 {
            (Request::Embed(vec![(i % n) as u32]), RequestClass::Embed)
        } else {
            (Request::Similar { ids: vec![(i % n) as u32], k: 3 }, RequestClass::Similar)
        };
        match pool.submit(req) {
            Ok(t) => {
                tickets.push(t);
                admitted[class.index()] += 1;
            }
            Err(e) => {
                assert!(e.to_string().contains("queue full"), "got: {}", e);
                bounced[class.index()] += 1;
            }
        }
    }
    // the gated worker drained nothing, so admission is exact
    assert_eq!(tickets.len(), capacity);
    assert_eq!(bounced[0] + bounced[1], (burst - capacity) as u64);

    pool.resume();
    for t in tickets {
        t.wait().expect("admitted requests still complete under overload");
    }
    let stats = pool.shutdown();

    // conservation: submitted == served + rejected + failed, overall...
    assert_eq!(stats.served + stats.rejected + stats.failed, burst as u64);
    assert_eq!(stats.served, capacity as u64);
    assert_eq!(stats.rejected, (burst - capacity) as u64);
    assert_eq!(stats.failed, 0);
    // ...and per class, with rejects attributed to the right class
    for class in RequestClass::ALL {
        let c = stats.class(class).counters;
        assert_eq!(c.submitted, admitted[class.index()] + bounced[class.index()]);
        assert_eq!(c.accounted(), c.submitted, "{} class leaked requests", class.name());
        assert_eq!(c.rejected, bounced[class.index()]);
        assert_eq!(c.served, admitted[class.index()]);
        assert_eq!(c.failed, 0);
    }

    // the tail over the served survivors is a real, finite number — the
    // overload shows up in admission counters, not in a poisoned summary
    let lat = stats.latency.expect("served requests recorded latency");
    assert_eq!(lat.n, capacity);
    assert!(lat.p50.is_finite() && lat.p99.is_finite() && lat.p999.is_finite());
    assert!(lat.p50 <= lat.p99 && lat.p99 <= lat.p999);
    for class in RequestClass::ALL {
        let cl = stats.class(class).latency.as_ref().expect("per-class latency");
        assert_eq!(cl.n as u64, admitted[class.index()]);
        assert!(cl.p99.is_finite());
    }
}

#[test]
fn pooled_workload_drops_rejected_requests() {
    // serve_workload_pooled must shed what admission control rejects and
    // still return every accepted response. Workers start gated, so the
    // first `queue_capacity` submissions are accepted and the remaining
    // 24 deterministically hit a full queue.
    let full = random_table(64, 4, 6);
    let cell = Arc::new(TableCell::new(ShardedTable::from_full(&full, 2, 0)));
    let opts = PoolOpts {
        workers: 1,
        queue_capacity: 8,
        max_batch: 8,
        start_paused: true,
        ..PoolOpts::default()
    };
    let pool = Arc::new(ServePool::spawn(cell, Arc::new(Native), opts));
    let mut rng = Rng::new(3);
    let reqs: Vec<Request> = (0..32).map(|_| mixed_request(&mut rng, 64)).collect();

    let result = std::thread::scope(|scope| {
        let p = Arc::clone(&pool);
        let reqs2 = reqs.clone();
        let h = scope.spawn(move || serve_workload_pooled(&p, &reqs2));
        // submissions all happen while the workers are gated; resume once
        // the 24 overflow rejections are on the books
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while pool.stats().rejected < 24 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        pool.resume();
        h.join().expect("workload thread panicked")
    });
    let (responses, stats) = result.unwrap();
    assert_eq!(responses.len(), 8, "only admitted requests produce responses");
    assert_eq!(stats.requests, 8);
    assert!(stats.throughput > 0.0);
    let totals = pool.stats();
    assert_eq!(totals.rejected, 24);
    assert_eq!(totals.served, 8);
    assert_eq!(totals.failed, 0);
}
