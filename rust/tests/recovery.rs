//! Crash-recovery integration (DESIGN.md §Durability): the durable store
//! must extend the repo's bit-identical determinism contract across
//! process death.
//!
//! The centrepiece is a **crash-point sweep**: a seeded churn schedule is
//! journaled through the durable store with the deterministic fault hook
//! (`storage::durable::crash`) armed to kill the run at its 1st, 2nd, …,
//! Nth irreversible step — every WAL append (torn mid-record), every
//! checkpoint page write, the checkpoint commit, the WAL rotation, the
//! stale-generation cleanup. After every single injected crash, recovery
//! must rebuild exactly the table of the last *published* epoch —
//! asserted bit-for-bit, no tolerance — and the run must be able to
//! continue on top of the recovered store to the same final table as an
//! uninterrupted run.
//!
//! Alongside the sweep: torn-tail truncation is trimmed (not fatal),
//! bit-flip corruption is rejected with the record's offset, the
//! log-over-checkpoint replay agrees with the in-memory delta path and
//! the Sequenced traffic digests (resident and spilled), and
//! `Pipeline::warm_restart` rebuilds a serving report from disk.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use deal::config::DealConfig;
use deal::coordinator::delta::DeltaState;
use deal::coordinator::Pipeline;
use deal::graph::delta::UpdateBatch;
use deal::runtime::Native;
use deal::serve::{refresh_delta_durable, PoolOpts, ServePool, ShardedTable, TableCell};
use deal::storage::durable::{crash, table_digest, REC_HEADER_LEN, WAL_HEADER_LEN};
use deal::storage::{with_page_rows, DurableOptions, DurableStore};
use deal::tensor::Matrix;
use deal::traffic::{
    churn_into_cell, churn_into_cell_durable, replay, ReplayMode, ReplayOpts, Trace, TraceConfig,
};
use deal::util::rng::Rng;

/// 256-node / 2-layer config shared by every test (and by the truth run
/// and every crash run, so the delta states evolve identically).
fn small_cfg() -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
    cfg.cluster.machines = 4;
    cfg.model.layers = 2;
    cfg.model.fanout = 5;
    cfg
}

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("deal-recov-{}-{}", std::process::id(), tag));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Bit-exact table equality — the recovery contract has no tolerance.
fn assert_bits_eq(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{}: shape", what);
    let ab: Vec<u32> = a.data.iter().map(|v| v.to_bits()).collect();
    let bb: Vec<u32> = b.data.iter().map(|v| v.to_bits()).collect();
    assert_eq!(ab, bb, "{}: not bit-identical", what);
}

fn assert_batch_eq(a: &UpdateBatch, b: &UpdateBatch, what: &str) {
    assert_eq!(a.add_edges, b.add_edges, "{}: add_edges", what);
    assert_eq!(a.remove_edges, b.remove_edges, "{}: remove_edges", what);
    assert_eq!(a.feature_updates.len(), b.feature_updates.len(), "{}: feat count", what);
    for ((na, ra), (nb, rb)) in a.feature_updates.iter().zip(&b.feature_updates) {
        assert_eq!(na, nb, "{}: feat node", what);
        let ba: Vec<u32> = ra.iter().map(|v| v.to_bits()).collect();
        let bbits: Vec<u32> = rb.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bbits, "{}: feat row bits", what);
    }
}

/// The seeded churn schedule: `batches` synthesized sequentially from the
/// evolving state (batch i+1 depends on batch i having been applied) and
/// `snapshots[e]` = the embeddings after epoch `e` (snapshots[0] is the
/// baseline) — the ground truth every crash run is checked against.
struct Schedule {
    batches: Vec<UpdateBatch>,
    snapshots: Vec<Matrix>,
}

const SCHED_BATCHES: usize = 4;
/// Compact after 3 WAL records → the sweep crosses a full compaction
/// (checkpoint pages + commit + rotation + cleanup) mid-schedule.
const COMPACT_EVERY: u64 = 3;

fn build_schedule() -> Schedule {
    let mut state = DeltaState::init(small_cfg()).unwrap();
    let mut rng = Rng::new(0xC0FFEE);
    let mut batches = Vec::new();
    let mut snapshots = vec![state.embeddings().clone()];
    for _ in 0..SCHED_BATCHES {
        let batch = state.synth_batch(&mut rng, 12, 12, 2);
        state.apply(&batch).unwrap();
        batches.push(batch);
        snapshots.push(state.embeddings().clone());
    }
    Schedule { batches, snapshots }
}

/// Journal the schedule through a fresh durable store in `dir`,
/// optionally armed to crash at the `arm`-th crash point (1-based; store
/// creation itself is excluded — `crash::arm` resets the step counter
/// after the store exists). Returns the run outcome, the number of crash
/// points the run stepped through, and the number of epochs that were
/// **published** (became client-visible) before the crash — the state
/// recovery is never allowed to lose.
fn run_schedule(
    dir: &PathBuf,
    sched: &Schedule,
    arm: Option<u64>,
) -> (deal::Result<()>, u64, u64) {
    let mut published = 0u64;
    let out = with_page_rows(64, || {
        let mut state = DeltaState::init(small_cfg())?;
        let store = DurableStore::create(
            dir,
            small_cfg().exec.seed,
            state.embeddings(),
            DurableOptions { compact_every: COMPACT_EVERY },
        )?;
        match arm {
            Some(n) => crash::arm(n),
            None => crash::reset_count(),
        }
        let store = Mutex::new(store);
        let cell = TableCell::new(ShardedTable::from_inference_plan(
            state.plan(),
            state.embeddings(),
            0,
        ));
        for batch in &sched.batches {
            let rep = refresh_delta_durable(&mut state, batch, &cell, &store)?;
            // the publish happened even if the post-publish compaction
            // dies next — the journal already covers this epoch
            published = rep.epoch;
        }
        Ok(())
    });
    let steps = crash::count();
    crash::disarm();
    (out, steps, published)
}

/// Recover `dir`, continue the rest of the schedule on top of the
/// recovered state, and assert bit-identity at every stage. Returns the
/// epoch the store had recovered to.
fn recover_check_and_continue(dir: &PathBuf, sched: &Schedule, what: &str) -> u64 {
    let (store, rec) = with_page_rows(64, || DurableStore::open(dir, DurableOptions::default()))
        .unwrap_or_else(|e| panic!("{}: recovery failed: {:#}", what, e));
    let e = rec.epoch as usize;
    assert!(e <= SCHED_BATCHES, "{}: recovered epoch {} out of range", what, e);
    assert_eq!(store.counters().recoveries, 1, "{}: recovery counted", what);

    // 1) recovered table == the truth snapshot of the recovered epoch
    assert_bits_eq(&rec.table, &sched.snapshots[e], &format!("{}: recovered table", what));

    // 2) the journaled batches are a faithful audit trail: replaying them
    // through a fresh in-memory state reproduces the same table
    let mut state = DeltaState::init(small_cfg()).unwrap();
    for (i, batch) in sched.batches[..e].iter().enumerate() {
        state.apply(batch).unwrap_or_else(|err| {
            panic!("{}: replaying truth batch {}: {:#}", what, i, err)
        });
    }
    assert_bits_eq(state.embeddings(), &rec.table, &format!("{}: audit replay", what));
    for (ep, batch) in &rec.deltas {
        let idx = (*ep - 1) as usize;
        assert!(
            *ep > rec.watermark && idx < e,
            "{}: delta epoch {} outside (watermark {}, recovered {}]",
            what,
            ep,
            rec.watermark,
            e
        );
        assert_batch_eq(batch, &sched.batches[idx], &format!("{}: wal delta {}", what, ep));
    }

    // 3) the run continues on the recovered store to the same final table
    // as an uninterrupted run
    with_page_rows(64, || -> deal::Result<()> {
        let store = Mutex::new(store);
        let cell = TableCell::new(ShardedTable::from_full(&rec.table, 2, rec.epoch));
        for batch in &sched.batches[e..] {
            refresh_delta_durable(&mut state, batch, &cell, &store)?;
        }
        assert_bits_eq(
            &cell.load().to_full(),
            &sched.snapshots[SCHED_BATCHES],
            &format!("{}: continued serving table", what),
        );
        Ok(())
    })
    .unwrap();
    assert_bits_eq(
        state.embeddings(),
        &sched.snapshots[SCHED_BATCHES],
        &format!("{}: continued state", what),
    );

    // 4) ... and that continuation is itself durable
    let (_, rec2) =
        with_page_rows(64, || DurableStore::open(dir, DurableOptions::default())).unwrap();
    assert_eq!(rec2.epoch, SCHED_BATCHES as u64, "{}: reopen after continue", what);
    assert_bits_eq(
        &rec2.table,
        &sched.snapshots[SCHED_BATCHES],
        &format!("{}: reopened table", what),
    );
    rec.epoch
}

/// The tentpole: kill the schedule at every crash point in turn; every
/// single one must recover bit-identically and be able to finish the
/// schedule.
#[test]
fn crash_point_sweep_recovers_bit_identical_tables() {
    let sched = build_schedule();

    // uninterrupted run: counts the crash points and fixes the baseline
    let dir0 = fresh_dir("sweep-base");
    let (ok, total, published) = run_schedule(&dir0, &sched, None);
    ok.unwrap();
    assert_eq!(published, SCHED_BATCHES as u64);
    // 4 WAL appends + one full compaction (4 checkpoint pages at
    // page_rows=64 over 256 rows, commit, rotation, cleanup)
    assert!(
        total >= SCHED_BATCHES as u64 + 4,
        "schedule only crossed {} crash points — sweep would be vacuous",
        total
    );
    let e0 = recover_check_and_continue(&dir0, &sched, "uninterrupted");
    assert_eq!(e0, SCHED_BATCHES as u64);
    let _ = std::fs::remove_dir_all(&dir0);

    let mut recovered_epochs = Vec::new();
    for n in 1..=total {
        let what = format!("crash point {}/{}", n, total);
        let dir = fresh_dir(&format!("sweep-{}", n));
        let (out, steps, published) = run_schedule(&dir, &sched, Some(n));
        let err = out.expect_err(&format!("{}: armed run must die", what));
        assert!(
            crash::is_injected(&err),
            "{}: died of the wrong cause: {:#}",
            what,
            err
        );
        assert_eq!(steps, n, "{}: crashed at the armed step", what);
        let e = recover_check_and_continue(&dir, &sched, &what);
        // the journal-before-publish contract: no client-visible epoch
        // is ever lost; a crash can only leave the store one epoch
        // *ahead* of the caller (journaled, not yet returned)
        assert!(
            e == published || e == published + 1,
            "{}: {} epochs were published but recovery produced epoch {}",
            what,
            published,
            e
        );
        recovered_epochs.push(e);
        let _ = std::fs::remove_dir_all(&dir);
    }
    // the sweep crossed every phase: early crashes lose epochs (recover
    // behind the full schedule), late ones keep them all
    assert!(recovered_epochs.iter().any(|&e| e < SCHED_BATCHES as u64));
    assert!(recovered_epochs.iter().any(|&e| e >= COMPACT_EVERY));
}

#[test]
fn torn_wal_tail_is_trimmed_not_fatal() {
    let sched = build_schedule();
    let dir = fresh_dir("torn");
    // no compaction: both deltas stay in wal-0.log
    with_page_rows(64, || -> deal::Result<()> {
        let mut state = DeltaState::init(small_cfg())?;
        let store = DurableStore::create(
            &dir,
            small_cfg().exec.seed,
            state.embeddings(),
            DurableOptions { compact_every: 1_000_000 },
        )?;
        let store = Mutex::new(store);
        let cell =
            TableCell::new(ShardedTable::from_inference_plan(state.plan(), state.embeddings(), 0));
        for batch in &sched.batches[..2] {
            refresh_delta_durable(&mut state, batch, &cell, &store)?;
        }
        Ok(())
    })
    .unwrap();

    // tear the tail: chop 5 bytes off the last record
    let wal = dir.join("wal-0.log");
    let len = std::fs::metadata(&wal).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&wal).unwrap();
    f.set_len(len - 5).unwrap();
    drop(f);

    let (_, rec) = DurableStore::open(&dir, DurableOptions::default()).unwrap();
    assert_eq!(rec.epoch, 1, "the torn epoch-2 record is lost, epoch 1 survives");
    let trim = rec.trimmed_at.expect("the scan must report the trim");
    assert!(trim >= WAL_HEADER_LEN && trim < len - 5, "trim inside the log body");
    assert_bits_eq(&rec.table, &sched.snapshots[1], "torn-tail recovery");

    // the trim is persistent: a second recovery sees a clean log
    let (_, rec2) = DurableStore::open(&dir, DurableOptions::default()).unwrap();
    assert_eq!(rec2.trimmed_at, None, "second open finds no torn tail");
    assert_eq!(rec2.epoch, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_wal_record_is_rejected_with_offset() {
    let sched = build_schedule();
    let dir = fresh_dir("corrupt");
    with_page_rows(64, || -> deal::Result<()> {
        let mut state = DeltaState::init(small_cfg())?;
        let store = DurableStore::create(
            &dir,
            small_cfg().exec.seed,
            state.embeddings(),
            DurableOptions { compact_every: 1_000_000 },
        )?;
        let store = Mutex::new(store);
        let cell =
            TableCell::new(ShardedTable::from_inference_plan(state.plan(), state.embeddings(), 0));
        refresh_delta_durable(&mut state, &sched.batches[0], &cell, &store)?;
        Ok(())
    })
    .unwrap();

    // flip one bit inside the first record's *body* (not the length
    // field, which would read as a torn tail instead)
    let wal = dir.join("wal-0.log");
    let mut bytes = std::fs::read(&wal).unwrap();
    let at = WAL_HEADER_LEN as usize + REC_HEADER_LEN + 3;
    bytes[at] ^= 0x10;
    std::fs::write(&wal, &bytes).unwrap();

    let err = DurableStore::open(&dir, DurableOptions::default()).unwrap_err();
    let msg = format!("{:#}", err);
    assert!(
        msg.contains(&format!("corrupt record at offset {}", WAL_HEADER_LEN)),
        "corruption must be rejected with the record's offset, got: {}",
        msg
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Property: for random seeds, replaying one traffic trace through the
/// durable churn hook produces (a) the same per-request Sequenced
/// response digests, (b) the same final embeddings, and (c) a store that
/// recovers to exactly those embeddings — resident or spilled.
fn replay_parity(seed: u64, spill_budget: u64, tag: &str) {
    let trace = Trace::generate(&TraceConfig {
        seed,
        n_nodes: 256,
        requests: 100,
        base_rate: 50_000.0,
        churn_batches: 2,
        ..TraceConfig::default()
    });
    let opts = ReplayOpts { mode: ReplayMode::Sequenced, keep_responses: false };
    let pool_opts = PoolOpts { workers: 2, queue_capacity: 256, ..PoolOpts::default() };

    // path A: the PR 2 in-memory delta path
    let mut st_a = DeltaState::init(small_cfg()).unwrap();
    let cell_a = Arc::new(TableCell::new(ShardedTable::from_inference_plan(
        st_a.plan(),
        st_a.embeddings(),
        0,
    )));
    let pool_a = ServePool::spawn(Arc::clone(&cell_a), Arc::new(Native), pool_opts.clone());
    let rep_a = replay(&pool_a, &trace, &opts, churn_into_cell(&mut st_a, &cell_a)).unwrap();
    pool_a.shutdown();

    // path B: journal-before-publish through the durable store,
    // compacting after every record to cross checkpoints mid-trace
    let dir = fresh_dir(tag);
    let mut st_b = DeltaState::init(small_cfg()).unwrap();
    let store = Mutex::new(
        DurableStore::create(
            &dir,
            seed,
            st_b.embeddings(),
            DurableOptions { compact_every: 1 },
        )
        .unwrap(),
    );
    let table_b = if spill_budget > 0 {
        ShardedTable::from_inference_plan_spilled(st_b.plan(), st_b.embeddings(), 0, spill_budget)
            .unwrap()
    } else {
        ShardedTable::from_inference_plan(st_b.plan(), st_b.embeddings(), 0)
    };
    assert_eq!(table_b.is_spilled(), spill_budget > 0);
    let cell_b = Arc::new(TableCell::new(table_b));
    let pool_b = ServePool::spawn(Arc::clone(&cell_b), Arc::new(Native), pool_opts);
    let churn_b = churn_into_cell_durable(&mut st_b, &cell_b, &store);
    let rep_b = replay(&pool_b, &trace, &opts, churn_b).unwrap();
    pool_b.shutdown();

    assert_eq!(rep_a.churn_epochs, rep_b.churn_epochs, "{}: same epochs", tag);
    assert_eq!(
        rep_a.digests, rep_b.digests,
        "{}: durable journaling changed a response digest",
        tag
    );
    assert_bits_eq(st_a.embeddings(), st_b.embeddings(), &format!("{}: final state", tag));
    assert_bits_eq(
        &cell_b.load().to_full(),
        st_b.embeddings(),
        &format!("{}: served table", tag),
    );

    // the store recovers to exactly the traffic run's final table
    drop(store);
    let (_, rec) = DurableStore::open(&dir, DurableOptions::default()).unwrap();
    assert_eq!(rec.epoch, trace.n_churn() as u64, "{}: recovered epoch", tag);
    assert_bits_eq(&rec.table, st_b.embeddings(), &format!("{}: recovered table", tag));
    assert_eq!(
        table_digest(&rec.table),
        table_digest(st_b.embeddings()),
        "{}: digest helper agrees",
        tag
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn durable_replay_matches_in_memory_seed_a1() {
    replay_parity(0xA1, 0, "parity-a1");
}

#[test]
fn durable_replay_matches_in_memory_seed_7e57() {
    replay_parity(0x7E57, 0, "parity-7e57");
}

#[test]
fn durable_replay_matches_in_memory_spilled() {
    // 16 KiB budget < the 256-row table: path B serves from the paged
    // tier while journaling — durability and spill must compose
    replay_parity(0xA1, 16 << 10, "parity-spill");
}

#[test]
fn warm_restart_rebuilds_report_from_disk() {
    let sched = build_schedule();
    let dir = fresh_dir("warm");
    let (ok, _, _) = run_schedule(&dir, &sched, None);
    ok.unwrap();

    let pipeline = Pipeline::new(small_cfg());
    let (report, store, rec) = pipeline.warm_restart(&dir).unwrap();
    assert_eq!(rec.epoch, SCHED_BATCHES as u64);
    assert_eq!(report.stages.0.len(), 1);
    assert_eq!(report.stages.0[0].name, "recovery");
    assert!(report.stages.0[0].sim_secs > 0.0, "recovery charges simulated I/O");
    let summary = report.stages.0[0].cluster.as_ref().unwrap().summary();
    assert!(summary.contains("recov=1"), "summary surfaces the recovery: {}", summary);
    assert_eq!(store.last_epoch(), SCHED_BATCHES as u64);

    let emb = report.embeddings.as_ref().expect("warm restart keeps embeddings");
    assert_bits_eq(emb, &sched.snapshots[SCHED_BATCHES], "warm-restart embeddings");
    let table = report.serving_table().expect("serving table reconstructs");
    assert_bits_eq(&table.to_full(), &sched.snapshots[SCHED_BATCHES], "warm-restart table");
    let _ = std::fs::remove_dir_all(&dir);
}
