//! Out-of-core storage parity suite (DESIGN.md §Out-of-core-storage).
//!
//! The contract under test: at every byte budget, page size, and thread
//! count, the paged tiers produce **bit-identical** results to the
//! in-memory path — eviction order may change page-fault counts and
//! simulated I/O time, never values — and the deterministic logical-clock
//! LRU gives monotone non-increasing fault counts as the budget grows.
//!
//! Budgets and page sizes here are pinned with the thread-local knob
//! scopes (`with_mem_budget` / `with_page_rows`), so the sweep is immune
//! to the process-global and `DEAL_MEM_BUDGET` env settings CI uses.

use deal::config::DealConfig;
use deal::coordinator::{Pipeline, SimFs};
use deal::graph::datasets;
use deal::runtime::par;
use deal::storage::{with_mem_budget, with_page_rows, PageCache, PagedMatrix};
use deal::tensor::Matrix;
use deal::util::rng::Rng;

fn small_cfg(kind: &str, prep: &str) -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "products-sim".into();
    cfg.dataset.scale = 1.0 / 256.0; // 256 nodes, 100-dim features
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = 2;
    cfg.model.kind = kind.into();
    cfg.model.layers = 2;
    cfg.model.fanout = 5;
    cfg.exec.feature_prep = prep.into();
    cfg
}

fn run_pipeline(cfg: &DealConfig, budget: u64, page_rows: usize) -> deal::coordinator::RunReport {
    with_mem_budget(budget, || {
        with_page_rows(page_rows, || Pipeline::new(cfg.clone()).run().unwrap())
    })
}

/// The acceptance sweep: GCN (fused prep) and GAT (redistribute) runs
/// under byte budgets smaller than the dataset's feature table produce
/// embeddings bit-identical to the unbounded in-memory run, at every
/// page granularity.
#[test]
fn e2e_bit_identical_across_budgets_and_page_sizes() {
    // feature table: 256 × 100 × 4 = 100 KiB; budgets sit well below it
    let table_bytes =
        datasets::feature_table_bytes(datasets::spec("products-sim").unwrap(), 1.0 / 256.0);
    let budgets = [table_bytes / 6, table_bytes / 2];
    for (kind, prep) in [("gcn", "fused"), ("gcn", "redistribute"), ("gat", "redistribute")] {
        let cfg = small_cfg(kind, prep);
        let base = run_pipeline(&cfg, 0, 64); // unbounded = in-memory path
        let base_emb = base.embeddings.as_ref().unwrap();
        for &budget in &budgets {
            assert!(budget < table_bytes, "budget must undercut the feature table");
            for page_rows in [1usize, 64, 4096] {
                let report = run_pipeline(&cfg, budget, page_rows);
                assert_eq!(
                    report.embeddings.as_ref().unwrap(),
                    base_emb,
                    "{}/{} diverged at budget {} page_rows {}",
                    kind,
                    prep,
                    budget,
                    page_rows
                );
            }
        }
    }
}

/// Same contract across intra-rank pool sizes: the paged path is
/// bit-identical at every thread count (and to the in-memory run).
#[test]
fn e2e_bit_identical_across_threads() {
    let cfg = small_cfg("gcn", "fused");
    let base = par::with_threads(1, || run_pipeline(&cfg, 0, 64));
    let base_emb = base.embeddings.as_ref().unwrap();
    for threads in [1usize, 4] {
        for budget in [16 << 10, 0u64] {
            let report = par::with_threads(threads, || run_pipeline(&cfg, budget, 64));
            assert_eq!(
                report.embeddings.as_ref().unwrap(),
                base_emb,
                "diverged at threads {} budget {}",
                threads,
                budget
            );
        }
    }
}

/// Storage metrics surface per rank, residency honors the budget (+ one
/// page per active stream), and the unbounded run never evicts.
#[test]
fn budget_bounds_residency_and_metrics_surface() {
    let cfg = small_cfg("gcn", "fused");
    let page_rows = 16usize;
    let budget = 8u64 << 10; // 8 KiB — far below the per-rank tiles
    let report = run_pipeline(&cfg, budget, page_rows);
    let infer = report
        .stages
        .0
        .iter()
        .find(|s| s.name == "inference")
        .and_then(|s| s.cluster.as_ref())
        .expect("inference cluster report");
    assert!(infer.total_page_faults() > 0, "tiny budget must fault");
    assert!(infer.total_spill_bytes() > 0, "tiny budget must move spill bytes");
    // page bytes bound: fused pages are page_rows × 100-dim f32 rows
    let page_bytes = (page_rows * 100 * 4) as u64;
    for (rank, m) in infer.machines.iter().enumerate() {
        assert_eq!(m.storage.budget_bytes, budget, "rank {} budget recorded", rank);
        assert!(
            m.storage.peak_resident_bytes <= budget.max(page_bytes) + page_bytes,
            "rank {} resident {} exceeds budget {} + page {}",
            rank,
            m.storage.peak_resident_bytes,
            budget,
            page_bytes
        );
        assert!(m.storage.evictions > 0, "rank {} must evict under 8 KiB", rank);
    }
    assert_eq!(infer.total_underflows(), 0, "alloc/free ledgers must balance");
    assert!(infer.summary().contains("faults="));

    // unbounded: the engine is bypassed entirely — no paging at all
    let free = run_pipeline(&cfg, 0, page_rows);
    let infer_free = free
        .stages
        .0
        .iter()
        .find(|s| s.name == "inference")
        .and_then(|s| s.cluster.as_ref())
        .unwrap();
    assert_eq!(infer_free.total_page_faults(), 0);
    assert_eq!(infer_free.total_spill_bytes(), 0);
}

/// The named out-of-core dataset: a papers-xl run under a budget smaller
/// than its (scaled) feature table completes and matches the unbounded
/// run bit for bit.
#[test]
fn papers_xl_runs_under_budget() {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "papers-xl".into();
    cfg.dataset.scale = 1.0 / 512.0; // 512 nodes at test scale
    cfg.cluster.machines = 4;
    cfg.cluster.feature_parts = 2;
    cfg.model.layers = 2;
    cfg.model.fanout = 5;
    cfg.exec.feature_prep = "fused".into();
    let table_bytes =
        datasets::feature_table_bytes(datasets::spec("papers-xl").unwrap(), 1.0 / 512.0);
    let base = run_pipeline(&cfg, 0, 64);
    let report = run_pipeline(&cfg, table_bytes / 8, 64);
    assert_eq!(report.embeddings.unwrap(), *base.embeddings.as_ref().unwrap());
}

/// LRU is a stack algorithm: for a fixed access sequence, fault counts
/// are monotone non-increasing as the budget grows — per page size.
#[test]
fn fault_counts_monotone_in_budget() {
    let mut rng = Rng::new(31);
    let m = Matrix::random(512, 8, 1.0, &mut rng);
    // a deterministic, re-visiting access pattern
    let pattern: Vec<usize> = (0..2048).map(|i| (i * 97 + (i * i) % 13) % 512).collect();
    for page_rows in [1usize, 64, 4096] {
        let page_bytes = (page_rows.min(512) * 8 * 4) as u64;
        let mut last_faults = u64::MAX;
        for mult in [1u64, 2, 4, 8, 0] {
            // 0 = unbounded (every page fits)
            let budget = if mult == 0 { 0 } else { mult * page_bytes };
            let mut cache = PageCache::new(budget);
            let fs = SimFs::new(deal::storage::DEFAULT_SPILL_GBPS);
            let pm = PagedMatrix::from_matrix(&mut cache, "mono", &m, page_rows, fs).unwrap();
            cache.flush().unwrap();
            cache.drop_all_frames();
            let _ = cache.take_stats(); // reset staging counters
            let mut buf = vec![0.0f32; 8];
            for &r in &pattern {
                pm.row_copy(&mut cache, r, &mut buf).unwrap();
                assert_eq!(buf, m.row(r), "row {} corrupted", r);
            }
            let faults = cache.stats().page_faults;
            assert!(
                faults <= last_faults,
                "faults {} grew over {} at budget {} (page_rows {})",
                faults,
                last_faults,
                budget,
                page_rows
            );
            last_faults = faults;
        }
        // unbounded: exactly one fault per distinct touched page
        let touched: std::collections::HashSet<usize> =
            pattern.iter().map(|r| r / page_rows).collect();
        assert_eq!(last_faults, touched.len() as u64, "page_rows {}", page_rows);
    }
}

/// The serving spill tier matches resident serving byte-for-byte while
/// keeping the new epoch's residency under budget (double-buffer on
/// disk).
#[test]
fn spilled_serving_epoch_matches_resident() {
    use deal::serve::{ShardedTable, TableCell};
    let mut rng = Rng::new(77);
    let full = Matrix::random(300, 16, 1.0, &mut rng);
    let resident = ShardedTable::from_full(&full, 4, 0);
    let budget = 4 << 10; // 4 KiB of a 18.75 KiB table
    let spilled = with_page_rows(8, || {
        ShardedTable::from_full_spilled(&full, 4, 0, budget).unwrap()
    });
    let ids: Vec<u32> = (0..300u32).rev().step_by(7).collect();
    assert_eq!(
        spilled.try_gather(&ids).unwrap(),
        resident.try_gather(&ids).unwrap(),
        "spilled gathers must be bit-identical"
    );
    assert!(spilled.resident_bytes() <= budget + (8 * 16 * 4) as u64);
    assert!(spilled.storage_counters().page_faults > 0);
    // double-buffered swap: old epoch survives the publish untouched
    let cell = TableCell::new(resident);
    let pinned = cell.load();
    cell.publish(spilled);
    assert_eq!(pinned.to_full(), full);
    assert_eq!(cell.load().to_full(), full);
}
