//! Integration: the XLA backend (AOT HLO artifacts through PJRT) computes
//! exactly what the native backend computes, and the end-to-end pipeline
//! over the XLA backend matches the native pipeline.
//!
//! Requires `make artifacts`; tests self-skip when artifacts are absent so
//! plain `cargo test` works in a fresh checkout.

use deal::config::DealConfig;
use deal::coordinator::Pipeline;
use deal::runtime::{backend_from_config, Act, Backend, Native};
use deal::tensor::Matrix;
use deal::util::prop::assert_close;
use deal::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let p = std::path::PathBuf::from("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/manifest.txt missing — run `make artifacts`");
        None
    }
}

#[test]
fn xla_gemm_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = backend_from_config("xla", &dir).unwrap();
    let mut rng = Rng::new(1);
    // row counts exercise both the pad (<256) and multi-chunk (>256) paths
    for rows in [5usize, 256, 300] {
        for (k, n) in [(8usize, 8usize), (16, 16), (32, 4)] {
            let h = Matrix::random(rows, k, 1.0, &mut rng);
            let w = Matrix::random(k, n, 1.0, &mut rng);
            let got = xla.gemm(&h, &w).unwrap();
            let want = Native.gemm(&h, &w).unwrap();
            assert_close(&got.data, &want.data, 1e-4, 1e-4)
                .unwrap_or_else(|e| panic!("rows={} {}x{}: {}", rows, k, n, e));
        }
    }
}

#[test]
fn xla_gemm_bias_act_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = backend_from_config("xla", &dir).unwrap();
    let mut rng = Rng::new(2);
    for act in [Act::None, Act::Relu] {
        let h = Matrix::random(40, 16, 1.0, &mut rng);
        let w = Matrix::random(16, 16, 1.0, &mut rng);
        let b: Vec<f32> = (0..16).map(|_| rng.next_f32() - 0.5).collect();
        let got = xla.gemm_bias_act(&h, &w, &b, act).unwrap();
        let want = Native.gemm_bias_act(&h, &w, &b, act).unwrap();
        assert_close(&got.data, &want.data, 1e-4, 1e-4).unwrap();
    }
}

#[test]
fn xla_spmm_tile_matches_native_incl_row_blocking() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = backend_from_config("xla", &dir).unwrap();
    let mut rng = Rng::new(3);
    // num_segments > SEG_CAP (256) exercises the row-blocking path;
    // edges > EDGE_TILE (1024) exercises edge chunking.
    for (edges, segs) in [(50usize, 10usize), (1500, 40), (700, 600)] {
        let d = 16;
        let feats = Matrix::random(edges, d, 1.0, &mut rng);
        let w: Vec<f32> = (0..edges).map(|_| rng.next_f32()).collect();
        let seg: Vec<u32> = (0..edges).map(|_| rng.next_below(segs) as u32).collect();
        let got = xla.spmm_tile(&feats, &w, &seg, segs).unwrap();
        let want = Native.spmm_tile(&feats, &w, &seg, segs).unwrap();
        assert_close(&got.data, &want.data, 1e-3, 1e-3)
            .unwrap_or_else(|e| panic!("edges={} segs={}: {}", edges, segs, e));
    }
}

#[test]
fn xla_sddmm_tile_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = backend_from_config("xla", &dir).unwrap();
    let mut rng = Rng::new(4);
    let a = Matrix::random(1300, 8, 1.0, &mut rng);
    let b = Matrix::random(1300, 8, 1.0, &mut rng);
    let got = xla.sddmm_tile(&a, &b).unwrap();
    let want = Native.sddmm_tile(&a, &b).unwrap();
    assert_close(&got, &want, 1e-4, 1e-4).unwrap();
}

#[test]
fn pipeline_xla_matches_native() {
    let Some(_dir) = artifacts_dir() else { return };
    let mut outs = Vec::new();
    for backend in ["native", "xla"] {
        let mut cfg = DealConfig::default();
        cfg.dataset.scale = 1.0 / 256.0;
        cfg.model.layers = 2;
        cfg.model.fanout = 6;
        cfg.exec.backend = backend.into();
        let before = *deal::runtime::service::XLA_CALLS.lock().unwrap();
        outs.push(Pipeline::new(cfg).run().unwrap().embeddings.unwrap());
        if backend == "xla" {
            let after = *deal::runtime::service::XLA_CALLS.lock().unwrap();
            assert!(after > before, "xla path did not execute any artifacts");
        }
    }
    let diff = outs[0].max_abs_diff(&outs[1]);
    assert!(diff < 1e-2, "xla vs native diverged: {}", diff);
}
