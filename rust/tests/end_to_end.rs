//! Integration: the full distributed pipeline reproduces the
//! single-machine dense reference across the whole configuration matrix —
//! both models × every feature-preparation strategy × every execution
//! mode — plus cross-partitioning determinism and baseline agreement.
//!
//! Tolerances are explicit constants: distributed tiles accumulate floats
//! in a different order than the dense oracle, so parity is `PARITY_*`;
//! two *distributed* configurations share arithmetic shape and agree
//! tighter (`CONFIG_*`).

use std::sync::Arc;

use deal::baselines::engines::{run_baseline, Engine};
use deal::baselines::BaselineOpts;
use deal::cluster::NetConfig;
use deal::config::DealConfig;
use deal::coordinator::Pipeline;
use deal::graph::{datasets, Csr};
use deal::model::reference::{gat_reference, gcn_reference, sage_reference};
use deal::model::{Aggregator, ModelConfig, ModelKind, ModelWeights};
use deal::sampling::{sample_all_layers, LayerGraphs};
use deal::tensor::Matrix;
use deal::util::prop::assert_close;

/// Distributed pipeline vs dense reference (absolute / relative): bounds
/// the float-accumulation-order divergence after `layers` GNN layers.
/// `tests/delta_stream.rs` derives its delta-parity tolerance from these.
const PARITY_ATOL: f32 = 2e-3;
const PARITY_RTOL: f32 = 2e-3;

/// Two distributed runs of the same computation under different schedules
/// (exec modes, M splits): same arithmetic, tighter agreement.
const CONFIG_TOL: f32 = 1e-3;

fn small_cfg() -> DealConfig {
    let mut cfg = DealConfig::default();
    cfg.dataset.name = "products-sim".into();
    cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
    cfg.model.layers = 2;
    cfg.model.fanout = 6;
    cfg
}

/// Rebuild the layer graphs exactly as the pipeline's distributed
/// sampling stage does (per-partition seeds over partition row slices).
fn pipeline_layer_graphs(cfg: &DealConfig, g: &Csr) -> LayerGraphs {
    let (p, _m) = cfg.parts().unwrap();
    let bounds = deal::util::even_ranges(g.n_rows, p);
    let mut layers: Vec<Vec<(u32, u32)>> = vec![Vec::new(); cfg.model.layers];
    for pi in 0..p {
        let sub = g.slice_rows(bounds[pi], bounds[pi + 1]);
        let lg =
            sample_all_layers(&sub, cfg.model.layers, cfg.model.fanout, cfg.exec.seed ^ pi as u64);
        for (l, layer) in lg.layers.iter().enumerate() {
            for r in 0..layer.n_rows {
                for &s in layer.row(r) {
                    layers[l].push((s, (bounds[pi] + r) as u32));
                }
            }
        }
    }
    LayerGraphs {
        layers: layers
            .into_iter()
            .map(|e| Csr::from_edges(g.n_rows, &e))
            .collect(),
    }
}

/// The model-zoo parity matrix: every `(model.kind, model.aggregator)`
/// combination the end-to-end tests drive through the trait-dispatched
/// pipeline. `parity_matrix_covers_every_model_kind` guards that this
/// list stays in sync with `ModelKind::ALL`.
const ZOO: [(&str, &str); 4] =
    [("gcn", "mean"), ("gat", "mean"), ("sage", "mean"), ("sage", "pool")];

/// The dense oracle for `small_cfg` under a model kind + aggregator.
fn reference_embeddings(kind: &str, aggregator: &str) -> Matrix {
    let mut cfg = small_cfg();
    cfg.model.kind = kind.into();
    cfg.model.aggregator = aggregator.into();
    let ds = datasets::load(&cfg.dataset.name, cfg.dataset.scale).unwrap();
    let g = Csr::from(&ds.edges);
    let layers = pipeline_layer_graphs(&cfg, &g);
    let model_cfg = match kind {
        "gcn" => ModelConfig::gcn(cfg.model.layers, ds.feature_dim),
        "gat" => ModelConfig::gat(cfg.model.layers, ds.feature_dim, cfg.model.heads),
        _ => ModelConfig::sage(
            cfg.model.layers,
            ds.feature_dim,
            Aggregator::parse(aggregator).unwrap(),
        ),
    };
    let weights = ModelWeights::random(&model_cfg, cfg.exec.seed ^ 0xBEEF);
    match kind {
        "gcn" => gcn_reference(&layers, &ds.features, &weights),
        "gat" => gat_reference(&layers, &ds.features, &weights),
        _ => sage_reference(&layers, &ds.features, &weights),
    }
}

/// The parity matrix: the whole model zoo × {scan, redistribute, fused}
/// × every execution mode, each against the dense reference at
/// `PARITY_*`. (For non-GCN kinds, `fused` exercises the documented
/// silent fallback to redistribute.)
#[test]
fn parity_matrix_pipeline_vs_dense_reference() {
    for (kind, aggregator) in ZOO {
        let expect = reference_embeddings(kind, aggregator);
        for prep in ["scan", "redistribute", "fused"] {
            for mode in ["monolithic", "grouped", "pipelined"] {
                let mut cfg = small_cfg();
                cfg.model.kind = kind.into();
                cfg.model.aggregator = aggregator.into();
                cfg.exec.feature_prep = prep.into();
                cfg.exec.mode = mode.into();
                cfg.exec.group_cols = 16;
                let got = Pipeline::new(cfg).run().unwrap().embeddings.unwrap();
                assert_close(&got.data, &expect.data, PARITY_ATOL, PARITY_RTOL).unwrap_or_else(
                    |e| {
                        panic!(
                            "{}/{} × {} × {} diverged from reference: {}",
                            kind, aggregator, prep, mode, e
                        )
                    },
                );
            }
        }
    }
}

/// Trait-coverage guard: every registered `ModelKind` must appear in the
/// end-to-end parity matrix above. Adding a model to the zoo without
/// wiring it through the full pipeline parity sweep fails here.
#[test]
fn parity_matrix_covers_every_model_kind() {
    for kind in ModelKind::ALL {
        assert!(
            ZOO.iter().any(|(k, _)| *k == kind.name()),
            "ModelKind::{:?} is registered but missing from the end-to-end \
             parity matrix — add it to ZOO",
            kind
        );
    }
    // every aggregator is exercised too
    for agg in ["mean", "pool"] {
        assert!(
            ZOO.iter().any(|(k, a)| *k == "sage" && *a == agg),
            "sage aggregator '{}' missing from the parity matrix",
            agg
        );
    }
}

#[test]
fn pipeline_deterministic_across_partitionings() {
    // Different (P, M) must compute identical embeddings (same per-
    // partition sampling seeds ⇒ same layer graphs only when P is equal,
    // so fix P and vary M).
    let mut outs = Vec::new();
    for m in [1usize, 2] {
        let mut cfg = small_cfg();
        cfg.cluster.machines = 2 * m;
        cfg.cluster.feature_parts = m;
        let r = Pipeline::new(cfg).run().unwrap();
        outs.push(r.embeddings.unwrap());
    }
    let diff = outs[0].max_abs_diff(&outs[1]);
    assert!(diff < CONFIG_TOL, "M=1 vs M=2 diverged: {}", diff);
}

#[test]
fn deal_and_baselines_agree_at_full_fanout() {
    // With full neighborhoods there is no sampling noise: Deal's pipeline
    // and both baselines must produce the same embeddings.
    let mut cfg = small_cfg();
    cfg.model.fanout = 0;
    cfg.model.kind = "gcn".into();
    let ds = datasets::load(&cfg.dataset.name, cfg.dataset.scale).unwrap();
    let g = Arc::new(Csr::from(&ds.edges));
    let model_cfg = ModelConfig::gcn(2, ds.feature_dim);
    let weights = ModelWeights::random(&model_cfg, cfg.exec.seed ^ 0xBEEF);
    let deal_out = Pipeline::new(cfg).run().unwrap().embeddings.unwrap();
    for engine in [Engine::Dgi, Engine::SalientPlusPlus] {
        let (base_out, _) = run_baseline(
            engine,
            &g,
            &ds.features,
            &weights,
            2,
            NetConfig::default(),
            Arc::new(deal::runtime::Native),
            BaselineOpts { fanout: 0, batch_size: 64, ..Default::default() },
        )
        .unwrap();
        assert_close(&base_out.data, &deal_out.data, PARITY_ATOL, PARITY_RTOL)
            .unwrap_or_else(|e| panic!("{:?}: {}", engine, e));
    }
}

#[test]
fn exec_modes_agree_with_each_other() {
    let mut outs = Vec::new();
    for mode in ["monolithic", "grouped", "pipelined"] {
        let mut cfg = small_cfg();
        cfg.exec.mode = mode.into();
        cfg.exec.group_cols = 16;
        outs.push(Pipeline::new(cfg).run().unwrap().embeddings.unwrap());
    }
    for other in &outs[1..] {
        let diff = outs[0].max_abs_diff(other);
        assert!(diff < CONFIG_TOL, "exec modes diverged: {}", diff);
    }
}
