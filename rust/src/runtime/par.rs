//! Intra-rank parallel compute engine: a zero-dependency scoped thread
//! pool (`std::thread::scope`) under every hot kernel (DESIGN.md
//! §Intra-rank parallelism).
//!
//! The simulated cluster parallelizes *across* ranks; this module
//! parallelizes *inside* one rank — the blocked GEMM, row-parallel SpMM /
//! SDDMM, per-row sampling, CSR construction/compaction, and per-shard
//! serving GEMMs all dispatch through it. Three design rules keep the
//! engine safe to drop under the whole pipeline:
//!
//! 1. **Determinism.** Work is split into *statically planned* contiguous
//!    bands ([`plan_bands`] / [`weighted_bands`]) whose boundaries depend
//!    only on the input shape and the thread count, and every kernel
//!    preserves the scalar path's per-element reduction order inside a
//!    band. Because bands write disjoint output ranges and no reduction
//!    crosses a band, results are **bit-identical** to the sequential
//!    kernel at every thread count (enforced by `tests/properties.rs`).
//! 2. **Honest cost accounting.** Each spawned worker measures its own
//!    thread-CPU time; [`run_parts`]/[`map_indexed`] accumulate it into a
//!    caller-thread-local ledger that `cluster::Ctx::compute` drains, so a
//!    kernel that fanned out over T real threads is still charged its
//!    *total* CPU in the simulation (`costs::intra_rank_compute_secs`) —
//!    simulated makespans don't silently deflate.
//! 3. **No nested fan-out.** Workers (and the caller while it executes its
//!    own band) run with an in-pool marker that pins [`num_threads`] to 1,
//!    so a parallel GEMM inside a parallel per-shard map cannot explode
//!    into T² threads.
//!
//! Thread-count resolution: [`with_threads`] override (thread-local, used
//! by tests/benches) → [`set_threads`] override (process-global, set from
//! `DealConfig.exec.threads` / `--threads`) → `DEAL_THREADS` env →
//! `std::thread::available_parallelism`.

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::cluster::thread_cpu_time;
use crate::util::even_ranges;

/// Process-global thread-count override; `usize::MAX` means "unset".
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(usize::MAX);

thread_local! {
    /// Thread-local override (0 = unset); also pinned to 1 inside workers.
    static LOCAL_THREADS: Cell<usize> = const { Cell::new(0) };
    /// CPU seconds consumed by pool workers on behalf of this thread since
    /// the last [`take_child_accounting`] call.
    static CHILD_CPU_SECS: Cell<f64> = const { Cell::new(0.0) };
    /// Workers spawned on behalf of this thread since the last drain.
    static CHILD_FORKS: Cell<u64> = const { Cell::new(0) };
}

/// Physical parallelism of the host (cached `available_parallelism`).
pub fn available() -> usize {
    static AVAIL: OnceLock<usize> = OnceLock::new();
    *AVAIL.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

fn env_default() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        match std::env::var("DEAL_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
            Some(n) if n > 0 => n,
            _ => available(),
        }
    })
}

/// Set the process-global pool size (`0` = back to auto: `DEAL_THREADS`
/// env or `available_parallelism`). Wired to `DealConfig.exec.threads`
/// and the `--threads` CLI flag.
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(if n == 0 { usize::MAX } else { n }, Ordering::Relaxed);
}

/// Run `f` with the pool size pinned to `n` on this thread (`0` = auto).
/// Scoped and race-free — the property tests sweep thread counts with it.
pub fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    let prev = LOCAL_THREADS.with(|c| c.replace(n));
    let out = f();
    LOCAL_THREADS.with(|c| c.set(prev));
    out
}

/// Effective pool size for work issued from the current thread. Inside a
/// pool worker this is pinned to 1 (no nested fan-out).
pub fn num_threads() -> usize {
    let local = LOCAL_THREADS.with(|c| c.get());
    if local > 0 {
        return local;
    }
    let global = GLOBAL_THREADS.load(Ordering::Relaxed);
    if global != usize::MAX {
        return global.max(1);
    }
    env_default()
}

/// Drain the (CPU seconds, spawned workers) consumed by pool workers on
/// behalf of this thread. `cluster::Ctx::compute` calls this around every
/// kernel so the simulation charges total CPU, not just the main thread's.
pub fn take_child_accounting() -> (f64, u64) {
    let secs = CHILD_CPU_SECS.with(|c| c.replace(0.0));
    let forks = CHILD_FORKS.with(|c| c.replace(0));
    (secs, forks)
}

fn record_children(secs: f64, forks: u64) {
    if forks > 0 {
        CHILD_CPU_SECS.with(|c| c.set(c.get() + secs));
        CHILD_FORKS.with(|c| c.set(c.get() + forks));
    }
}

/// Static band plan for `n_items` of uniform cost: `t` contiguous ranges
/// with `t = min(num_threads, n_items, total_work / min_work_per_band)`,
/// so small inputs stay on the calling thread (spawning costs ~tens of
/// microseconds). Returns `t + 1` boundary offsets.
pub fn plan_bands(n_items: usize, total_work: u64, min_work_per_band: u64) -> Vec<usize> {
    let mut t = num_threads().min(n_items.max(1));
    if min_work_per_band > 0 {
        t = t.min((total_work / min_work_per_band).max(1) as usize);
    }
    even_ranges(n_items, t.max(1))
}

/// Static band plan for `n_items` of *non-uniform* cost: boundaries are
/// chosen so each band carries ≈ equal total weight (degree-balanced
/// chunking for CSR kernels). Deterministic in the inputs and thread
/// count; collapses to one band below the work floor.
pub fn weighted_bands(
    n_items: usize,
    weight: impl Fn(usize) -> u64,
    min_work_per_band: u64,
) -> Vec<usize> {
    let total: u128 = (0..n_items).map(|i| weight(i) as u128).sum();
    let mut t = num_threads().min(n_items.max(1));
    if min_work_per_band > 0 {
        t = t.min((total / min_work_per_band.max(1) as u128).max(1) as usize);
    }
    let t = t.max(1);
    if t == 1 {
        return vec![0, n_items];
    }
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0);
    let mut acc: u128 = 0;
    for i in 0..n_items {
        acc += weight(i) as u128;
        let cut = bounds.len(); // next boundary index in 1..t
        if cut < t && acc * t as u128 >= total * cut as u128 {
            bounds.push(i + 1);
        }
    }
    bounds.push(n_items);
    // Back-loaded weight can leave fewer than `t` cuts (a heavy tail item
    // crosses several thresholds at once); dedup rather than padding with
    // zero-width bands, so no worker is ever spawned for an empty band.
    bounds.dedup();
    bounds
}

/// Split `data` at item `bounds` (each item spanning `stride` elements)
/// into per-band `(item_range, band_slice)` parts for [`run_parts`].
pub fn split_rows<'a, T>(
    mut data: &'a mut [T],
    bounds: &[usize],
    stride: usize,
) -> Vec<(Range<usize>, &'a mut [T])> {
    let mut parts = Vec::with_capacity(bounds.len().saturating_sub(1));
    for w in bounds.windows(2) {
        let (band, rest) = std::mem::take(&mut data).split_at_mut((w[1] - w[0]) * stride);
        parts.push((w[0]..w[1], band));
        data = rest;
    }
    parts
}

/// Split `data` at explicit element offsets `cuts` (monotone, starting at
/// the slice origin) into per-band slices — the CSR-shaped variant where
/// band `i` owns elements `cuts[i]..cuts[i+1]`.
pub fn split_at_cuts<'a, T>(mut data: &'a mut [T], cuts: &[usize]) -> Vec<&'a mut [T]> {
    let mut parts = Vec::with_capacity(cuts.len().saturating_sub(1));
    for w in cuts.windows(2) {
        let (band, rest) = std::mem::take(&mut data).split_at_mut(w[1] - w[0]);
        parts.push(band);
        data = rest;
    }
    parts
}

/// Execute `f(band_index, part)` for every part: part 0 on the calling
/// thread, the rest on scoped worker threads. Parts carry whatever a band
/// needs (typically a row range plus its disjoint output slice), so no
/// two bands alias and the borrow checker proves it.
pub fn run_parts<T: Send, F: Fn(usize, T) + Sync>(parts: Vec<T>, f: F) {
    let n = parts.len();
    if n == 0 {
        return;
    }
    let mut iter = parts.into_iter();
    let first = iter.next().unwrap();
    if n == 1 {
        f(0, first);
        return;
    }
    let cpu_ns = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for (off, part) in iter.enumerate() {
            let f = &f;
            let cpu_ns = &cpu_ns;
            scope.spawn(move || {
                let t0 = thread_cpu_time();
                LOCAL_THREADS.with(|c| c.set(1)); // no nested fan-out
                f(off + 1, part);
                let dt = (thread_cpu_time() - t0).max(0.0);
                cpu_ns.fetch_add((dt * 1e9) as u64, Ordering::Relaxed);
            });
        }
        // The caller works its own band while the pool drains the rest.
        let prev = LOCAL_THREADS.with(|c| c.replace(1));
        f(0, first);
        LOCAL_THREADS.with(|c| c.set(prev));
    });
    record_children(cpu_ns.load(Ordering::Relaxed) as f64 * 1e-9, (n - 1) as u64);
}

/// Run `f(i)` for `i in 0..n` through a chunked work queue (one atomic
/// counter, one index per pull) and return the results **in index order**
/// — the load-balancing shape for irregular owned-result tasks (per-shard
/// GEMMs, per-chunk edge bucketing).
pub fn map_indexed<R: Send, F: Fn(usize) -> R + Sync>(n: usize, f: F) -> Vec<R> {
    let t = num_threads().min(n.max(1)).max(1);
    if t == 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let cpu_ns = AtomicU64::new(0);
    let mut tagged: Vec<(usize, R)> = std::thread::scope(|scope| {
        let worker = |measure: bool| {
            let f = &f;
            let next = &next;
            let cpu_ns = &cpu_ns;
            move || {
                let t0 = thread_cpu_time();
                let mut out: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    out.push((i, f(i)));
                }
                if measure {
                    let dt = (thread_cpu_time() - t0).max(0.0);
                    cpu_ns.fetch_add((dt * 1e9) as u64, Ordering::Relaxed);
                }
                out
            }
        };
        let handles: Vec<_> = (1..t)
            .map(|_| {
                let w = worker(true);
                scope.spawn(move || {
                    LOCAL_THREADS.with(|c| c.set(1));
                    w()
                })
            })
            .collect();
        let prev = LOCAL_THREADS.with(|c| c.replace(1));
        let mut all = worker(false)();
        LOCAL_THREADS.with(|c| c.set(prev));
        for h in handles {
            all.extend(h.join().expect("pool worker panicked"));
        }
        all
    });
    record_children(cpu_ns.load(Ordering::Relaxed) as f64 * 1e-9, (t - 1) as u64);
    tagged.sort_unstable_by_key(|&(i, _)| i);
    tagged.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_resolution_order() {
        with_threads(3, || {
            assert_eq!(num_threads(), 3);
            with_threads(0, || assert!(num_threads() >= 1));
        });
    }

    #[test]
    fn plan_bands_respects_work_floor() {
        with_threads(8, || {
            // tiny work → one band regardless of pool size
            assert_eq!(plan_bands(100, 10, 1000), vec![0, 100]);
            // big work → pool-wide bands
            let b = plan_bands(100, 1_000_000, 1000);
            assert_eq!(b.len(), 9);
            assert_eq!((b[0], *b.last().unwrap()), (0, 100));
        });
    }

    #[test]
    fn weighted_bands_balance_skewed_loads() {
        with_threads(4, || {
            // one heavy item at the front, uniform tail
            let w = |i: usize| if i == 0 { 1000u64 } else { 10 };
            let b = weighted_bands(401, w, 1);
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), 401);
            assert_eq!(b.len(), 5);
            // the heavy item sits alone-ish in band 0
            assert!(b[1] <= 110, "heavy band too wide: {:?}", b);
            for win in b.windows(2) {
                assert!(win[0] <= win[1]);
            }
        });
    }

    #[test]
    fn weighted_bands_drop_empty_tail_bands() {
        with_threads(4, || {
            // all weight on the last item: one real band, no zero-width tails
            let b = weighted_bands(4, |i| if i == 3 { 1000 } else { 0 }, 1);
            assert_eq!(*b.last().unwrap(), 4);
            assert!(b.windows(2).all(|w| w[0] < w[1]), "empty band in {:?}", b);
        });
    }

    #[test]
    fn run_parts_covers_all_bands_deterministically() {
        let mut data = vec![0u64; 1000];
        with_threads(4, || {
            let bounds = plan_bands(1000, 1_000_000, 1);
            let parts = split_rows(&mut data, &bounds, 1);
            run_parts(parts, |_, (range, band)| {
                for (off, v) in band.iter_mut().enumerate() {
                    *v = (range.start + off) as u64;
                }
            });
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn map_indexed_returns_index_order() {
        with_threads(4, || {
            let out = map_indexed(37, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>());
        });
    }

    #[test]
    fn split_at_cuts_covers() {
        let mut data = vec![1u8; 10];
        let parts = split_at_cuts(&mut data, &[0, 4, 4, 10]);
        assert_eq!(parts.iter().map(|p| p.len()).collect::<Vec<_>>(), vec![4, 0, 6]);
    }

    #[test]
    fn workers_do_not_nest() {
        with_threads(4, || {
            let mut seen = vec![0usize; 4];
            let parts = split_rows(&mut seen, &[0, 1, 2, 3, 4], 1);
            run_parts(parts, |_, (_, band)| {
                band[0] = num_threads(); // pinned to 1 inside the pool
            });
            assert_eq!(seen, vec![1, 1, 1, 1]);
        });
    }

    #[test]
    fn child_cpu_is_accounted() {
        take_child_accounting(); // clear
        with_threads(4, || {
            let mut out = vec![0.0f64; 4];
            let parts = split_rows(&mut out, &[0, 1, 2, 3, 4], 1);
            run_parts(parts, |_, (_, band)| {
                let mut acc = 0f64;
                for i in 0..200_000 {
                    acc += (i as f64).sqrt();
                }
                band[0] = acc;
            });
        });
        let (secs, forks) = take_child_accounting();
        assert_eq!(forks, 3);
        assert!(secs >= 0.0);
        // drained: second take is empty
        assert_eq!(take_child_accounting(), (0.0, 0));
    }
}
