//! The XLA compute service: one thread owns the PJRT CPU client and all
//! compiled executables; machines submit tile jobs through a channel.
//!
//! Artifacts are HLO *text* (see `/opt/xla-example/README.md`: serialized
//! jax≥0.5 protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids). Each artifact is compiled once
//! on first use and cached.
//!
//! Shape policy: artifacts are fixed-shape (AOT), so callers are padded to
//! the artifact grid — rows up to the row tile for GEMM (extra rows are
//! sliced off), edges up to the edge tile for SPMM/SDDMM (padding edges
//! carry weight 0 and segment id = `num_segments`, a sink row the kernel
//! allocates and the service slices off; see DESIGN.md
//! §Hardware-Adaptation).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Mutex;

use crate::tensor::Matrix;
use crate::Result;

use super::{Act, Backend};

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub kernel: String,
    pub file: PathBuf,
    /// Key dims, kernel-specific:
    /// gemm/gemm_bias_relu/gemm_bias: [rows, d_in, d_out]
    /// spmm: [edges, segments, d]
    /// sddmm: [edges, d]
    pub dims: Vec<usize>,
}

/// Parse `artifacts/manifest.txt`: one `key=value ...` line per artifact.
pub fn parse_manifest(dir: &Path) -> Result<Vec<ManifestEntry>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("cannot read {} (run `make artifacts`): {}", path.display(), e))?;
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut kernel = String::new();
        let mut file = String::new();
        let mut dims = Vec::new();
        for tok in line.split_whitespace() {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad manifest token '{}'", tok))?;
            match k {
                "kernel" => kernel = v.to_string(),
                "file" => file = v.to_string(),
                "dims" => {
                    dims = v
                        .split(',')
                        .map(|x| x.parse::<usize>())
                        .collect::<std::result::Result<_, _>>()?;
                }
                _ => {} // forward-compatible
            }
        }
        anyhow::ensure!(!kernel.is_empty() && !file.is_empty(), "bad manifest line: {}", line);
        out.push(ManifestEntry { kernel, file: dir.join(file), dims });
    }
    Ok(out)
}

enum Job {
    Run {
        /// Manifest index of the artifact to execute.
        entry: usize,
        /// Inputs: (dims, f32 data) for f32 tensors; i32 tensors encoded
        /// separately.
        f32_inputs: Vec<(Vec<usize>, Vec<f32>)>,
        i32_inputs: Vec<(Vec<usize>, Vec<i32>)>,
        reply: Sender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Cloneable handle implementing [`Backend`] over the service thread.
pub struct XlaHandle {
    tx: Sender<Job>,
    manifest: Vec<ManifestEntry>,
    /// (kernel, dims-key) -> manifest index
    index: HashMap<(String, Vec<usize>), usize>,
}

/// The service owner; dropping it shuts the thread down.
pub struct XlaService {
    handle: XlaHandle,
    join: Option<std::thread::JoinHandle<()>>,
    tx: Sender<Job>,
}

impl XlaService {
    /// Start the service thread over the artifacts directory.
    pub fn start(dir: &Path) -> Result<XlaService> {
        let manifest = parse_manifest(dir)?;
        let mut index = HashMap::new();
        for (i, e) in manifest.iter().enumerate() {
            index.insert((e.kernel.clone(), e.dims.clone()), i);
        }
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let thread_manifest = manifest.clone();
        let join = std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || service_main(thread_manifest, rx))?;
        Ok(XlaService {
            handle: XlaHandle { tx: tx.clone(), manifest, index },
            join: Some(join),
            tx,
        })
    }

    pub fn handle(&self) -> XlaHandle {
        self.handle.clone()
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Clone for XlaHandle {
    fn clone(&self) -> Self {
        XlaHandle { tx: self.tx.clone(), manifest: self.manifest.clone(), index: self.index.clone() }
    }
}

/// Without the `xla` cargo feature there is no PJRT client to own; the
/// service thread still runs so the channel protocol is identical, but
/// every job is answered with an error (DESIGN.md §Runtime). The `native`
/// backend is unaffected.
#[cfg(not(feature = "xla"))]
fn service_main(_manifest: Vec<ManifestEntry>, rx: Receiver<Job>) {
    for job in rx {
        match job {
            Job::Run { reply, .. } => {
                let _ = reply.send(Err(anyhow::anyhow!(
                    "this build has no XLA support — rebuild with `cargo build --features xla`"
                )));
            }
            Job::Shutdown => break,
        }
    }
}

#[cfg(feature = "xla")]
fn service_main(manifest: Vec<ManifestEntry>, rx: Receiver<Job>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Drain jobs with errors.
            for job in rx {
                match job {
                    Job::Run { reply, .. } => {
                        let _ = reply.send(Err(anyhow::anyhow!("PJRT client failed: {}", e)));
                    }
                    Job::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut compiled: HashMap<usize, xla::PjRtLoadedExecutable> = HashMap::new();
    for job in rx {
        match job {
            Job::Shutdown => break,
            Job::Run { entry, f32_inputs, i32_inputs, reply } => {
                let result = run_one(&client, &manifest, &mut compiled, entry, f32_inputs, i32_inputs);
                let _ = reply.send(result);
            }
        }
    }
}

#[cfg(feature = "xla")]
fn run_one(
    client: &xla::PjRtClient,
    manifest: &[ManifestEntry],
    compiled: &mut HashMap<usize, xla::PjRtLoadedExecutable>,
    entry: usize,
    f32_inputs: Vec<(Vec<usize>, Vec<f32>)>,
    i32_inputs: Vec<(Vec<usize>, Vec<i32>)>,
) -> Result<Vec<f32>> {
    if !compiled.contains_key(&entry) {
        let e = &manifest[entry];
        let proto = xla::HloModuleProto::from_text_file(&e.file)
            .map_err(|err| anyhow::anyhow!("load {}: {}", e.file.display(), err))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|err| anyhow::anyhow!("compile {}: {}", e.file.display(), err))?;
        compiled.insert(entry, exe);
    }
    let exe = &compiled[&entry];
    let mut literals: Vec<xla::Literal> = Vec::new();
    for (dims, data) in &f32_inputs {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        literals.push(
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
                .map_err(|e| anyhow::anyhow!("literal: {}", e))?,
        );
    }
    for (dims, data) in &i32_inputs {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        literals.push(
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
                .map_err(|e| anyhow::anyhow!("literal: {}", e))?,
        );
    }
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow::anyhow!("execute: {}", e))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {}", e))?;
    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
    let out = lit.to_tuple1().map_err(|e| anyhow::anyhow!("tuple: {}", e))?;
    out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {}", e))
}

impl XlaHandle {
    fn submit(
        &self,
        entry: usize,
        f32_inputs: Vec<(Vec<usize>, Vec<f32>)>,
        i32_inputs: Vec<(Vec<usize>, Vec<i32>)>,
    ) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.tx
            .send(Job::Run { entry, f32_inputs, i32_inputs, reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("xla service is down"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("xla service dropped the job"))?
    }

    /// Find the smallest artifact of `kernel` whose first dim (tile size)
    /// can hold `need` and whose remaining dims equal `rest`.
    fn lookup_tiled(&self, kernel: &str, need: usize, rest: &[usize]) -> Result<(usize, usize)> {
        let mut best: Option<(usize, usize)> = None; // (tile, idx)
        for (i, e) in self.manifest.iter().enumerate() {
            if e.kernel == kernel && e.dims.len() == rest.len() + 1 && e.dims[1..] == *rest {
                let tile = e.dims[0];
                let better = match best {
                    // prefer the smallest tile that fits; if none fits,
                    // keep the largest available (we will chunk).
                    Some((t, _)) => {
                        if t >= need {
                            tile >= need && tile < t
                        } else {
                            tile > t
                        }
                    }
                    None => true,
                };
                if better {
                    best = Some((tile, i));
                }
            }
        }
        best.map(|(t, i)| (i, t)).ok_or_else(|| {
            anyhow::anyhow!(
                "no '{}' artifact for dims {:?} (have: {:?}) — extend python/compile/shapes.py",
                kernel,
                rest,
                self.manifest
                    .iter()
                    .filter(|e| e.kernel == kernel)
                    .map(|e| e.dims.clone())
                    .collect::<Vec<_>>()
            )
        })
    }

    /// Run a GEMM-family artifact over row chunks of `h`.
    fn gemm_family(&self, kernel: &str, h: &Matrix, w: &Matrix, b: Option<&[f32]>) -> Result<Matrix> {
        let (entry, tile) = self.lookup_tiled(kernel, h.rows, &[w.rows, w.cols])?;
        let mut out = Matrix::zeros(h.rows, w.cols);
        let mut r = 0;
        while r < h.rows {
            let hi = (r + tile).min(h.rows);
            let take = hi - r;
            // pad chunk to the tile
            let mut chunk = vec![0.0f32; tile * h.cols];
            chunk[..take * h.cols].copy_from_slice(&h.data[r * h.cols..hi * h.cols]);
            let mut inputs = vec![(vec![tile, h.cols], chunk), (vec![w.rows, w.cols], w.data.clone())];
            if let Some(bias) = b {
                inputs.push((vec![w.cols], bias.to_vec()));
            }
            let res = self.submit(entry, inputs, vec![])?;
            anyhow::ensure!(res.len() == tile * w.cols, "bad output len");
            out.data[r * w.cols..hi * w.cols].copy_from_slice(&res[..take * w.cols]);
            r = hi;
        }
        Ok(out)
    }
}

/// Global gate used by tests to assert the XLA path really ran.
pub static XLA_CALLS: Mutex<u64> = Mutex::new(0);

impl Backend for XlaService {
    fn name(&self) -> &'static str {
        "xla"
    }
    fn gemm(&self, h: &Matrix, w: &Matrix) -> Result<Matrix> {
        self.handle.gemm(h, w)
    }
    fn gemm_bias_act(&self, h: &Matrix, w: &Matrix, b: &[f32], act: Act) -> Result<Matrix> {
        self.handle.gemm_bias_act(h, w, b, act)
    }
    fn spmm_tile(&self, feats: &Matrix, w: &[f32], seg: &[u32], num_segments: usize) -> Result<Matrix> {
        self.handle.spmm_tile(feats, w, seg, num_segments)
    }
    fn sddmm_tile(&self, dst: &Matrix, src: &Matrix) -> Result<Vec<f32>> {
        self.handle.sddmm_tile(dst, src)
    }
}

impl Backend for XlaHandle {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn gemm(&self, h: &Matrix, w: &Matrix) -> Result<Matrix> {
        *XLA_CALLS.lock().unwrap() += 1;
        self.gemm_family("gemm", h, w, None)
    }

    fn gemm_bias_act(&self, h: &Matrix, w: &Matrix, b: &[f32], act: Act) -> Result<Matrix> {
        *XLA_CALLS.lock().unwrap() += 1;
        let kernel = match act {
            Act::None => "gemm_bias",
            Act::Relu => "gemm_bias_relu",
        };
        self.gemm_family(kernel, h, w, Some(b))
    }

    fn spmm_tile(&self, feats: &Matrix, w: &[f32], seg: &[u32], num_segments: usize) -> Result<Matrix> {
        *XLA_CALLS.lock().unwrap() += 1;
        anyhow::ensure!(feats.rows == w.len() && w.len() == seg.len(), "spmm tile arity");
        // Artifact dims: [edge_tile, seg_cap, d]. Outputs larger than the
        // artifact's segment capacity are row-blocked: edges are bucketed
        // by segment block (stable sort by segment), each block runs
        // through the kernel with rebased segment ids, and the block's
        // rows accumulate into the output slice.
        let (entry, edge_tile) = self.lookup_spmm(feats.cols)?;
        let segs_cap = self.manifest[entry].dims[1];
        let d = feats.cols;
        let mut out = Matrix::zeros(num_segments, d);
        if feats.rows == 0 {
            return Ok(out);
        }
        // order edge indices by segment so each block's edges are contiguous
        let mut order: Vec<u32> = (0..feats.rows as u32).collect();
        order.sort_by_key(|&i| seg[i as usize]);
        let mut pos = 0usize;
        let mut block_lo = 0usize;
        while block_lo < num_segments {
            let block_hi = (block_lo + segs_cap).min(num_segments);
            let start = pos;
            while pos < order.len() && (seg[order[pos] as usize] as usize) < block_hi {
                pos += 1;
            }
            let idx = &order[start..pos];
            let mut e0 = 0usize;
            while e0 < idx.len() {
                let e1 = (e0 + edge_tile).min(idx.len());
                let take = e1 - e0;
                let mut f = vec![0.0f32; edge_tile * d];
                let mut ww = vec![0.0f32; edge_tile];
                // padding edges go to the sink segment (index segs_cap)
                let mut ss = vec![segs_cap as i32; edge_tile];
                for (i, &ei) in idx[e0..e1].iter().enumerate() {
                    let ei = ei as usize;
                    f[i * d..(i + 1) * d].copy_from_slice(feats.row(ei));
                    ww[i] = w[ei];
                    ss[i] = (seg[ei] as usize - block_lo) as i32;
                }
                let res = self.submit(
                    entry,
                    vec![(vec![edge_tile, d], f), (vec![edge_tile], ww)],
                    vec![(vec![edge_tile], ss)],
                )?;
                anyhow::ensure!(res.len() == (segs_cap + 1) * d, "bad spmm output len");
                for s in 0..(block_hi - block_lo) {
                    let orow = out.row_mut(block_lo + s);
                    for (o, &v) in orow.iter_mut().zip(&res[s * d..(s + 1) * d]) {
                        *o += v;
                    }
                }
                let _ = take;
                e0 = e1;
            }
            block_lo = block_hi;
        }
        Ok(out)
    }

    fn sddmm_tile(&self, dst: &Matrix, src: &Matrix) -> Result<Vec<f32>> {
        *XLA_CALLS.lock().unwrap() += 1;
        anyhow::ensure!(dst.rows == src.rows && dst.cols == src.cols, "sddmm shape");
        let d = dst.cols;
        let (entry, edge_tile) = self.lookup_tiled("sddmm", dst.rows, &[d])?;
        let mut out = vec![0.0f32; dst.rows];
        let mut e0 = 0;
        while e0 < dst.rows {
            let e1 = (e0 + edge_tile).min(dst.rows);
            let take = e1 - e0;
            let mut a = vec![0.0f32; edge_tile * d];
            a[..take * d].copy_from_slice(&dst.data[e0 * d..e1 * d]);
            let mut b = vec![0.0f32; edge_tile * d];
            b[..take * d].copy_from_slice(&src.data[e0 * d..e1 * d]);
            let res = self.submit(
                entry,
                vec![(vec![edge_tile, d], a), (vec![edge_tile, d], b)],
                vec![],
            )?;
            out[e0..e1].copy_from_slice(&res[..take]);
            e0 = e1;
        }
        Ok(out)
    }
}

impl XlaHandle {
    /// SPMM artifacts are keyed `[edge_tile, seg_cap, d]`; pick the one
    /// matching `d` with the largest segment capacity (outputs beyond it
    /// are row-blocked by the caller).
    fn lookup_spmm(&self, d: usize) -> Result<(usize, usize)> {
        let mut best: Option<(usize, usize, usize)> = None; // (segcap, tile, idx)
        for (i, e) in self.manifest.iter().enumerate() {
            if e.kernel == "spmm" && e.dims.len() == 3 && e.dims[2] == d {
                let (tile, segcap) = (e.dims[0], e.dims[1]);
                if best.map_or(true, |(bs, _, _)| segcap > bs) {
                    best = Some((segcap, tile, i));
                }
            }
        }
        best.map(|(_, t, i)| (i, t)).ok_or_else(|| {
            anyhow::anyhow!(
                "no 'spmm' artifact with d={} — extend python/compile/shapes.py",
                d
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join(format!("deal-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# comment\nkernel=gemm file=g.hlo.txt dims=256,100,100\nkernel=spmm file=s.hlo.txt dims=1024,257,50\n",
        )
        .unwrap();
        let m = parse_manifest(&dir).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].kernel, "gemm");
        assert_eq!(m[0].dims, vec![256, 100, 100]);
        assert_eq!(m[1].dims, vec![1024, 257, 50]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_missing_dir_errors() {
        assert!(parse_manifest(Path::new("/definitely/not/here")).is_err());
    }
}
