//! Runtime: the AOT-compiled XLA compute path and its native reference.
//!
//! `python/compile/aot.py` lowers the Layer-2 JAX functions (which call the
//! Layer-1 Pallas kernels) to **HLO text** under `artifacts/`, with a plain
//! `manifest.txt` index. At startup the coordinator builds an
//! [`XlaService`]: a dedicated thread owning the PJRT CPU client (the `xla`
//! crate's client is `Rc`-based and not `Send`, and a real deployment pins
//! the accelerator runtime to a device thread anyway) plus a compilation
//! cache. Simulated machines talk to it through the cloneable
//! [`XlaHandle`] — so Python never runs at inference time, and the dense
//! tile math on the request path executes inside XLA.
//!
//! [`Backend`] abstracts the tile ops the model layer needs; `Native` is
//! the pure-rust oracle used by tests and as the perf comparison baseline.

pub mod autotune;
pub mod par;
pub mod service;
mod weights;

pub use service::{XlaHandle, XlaService};
pub use weights::{load_weights, save_weights};

use crate::tensor::{self, Matrix};
use crate::Result;

/// Activation applied by fused projection kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
}

/// The dense/segment tile operations the model layer dispatches.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// `h @ w`.
    fn gemm(&self, h: &Matrix, w: &Matrix) -> Result<Matrix>;

    /// `act(h @ w + b)` — the GNN projection (paper §2.1's GEMM step).
    fn gemm_bias_act(&self, h: &Matrix, w: &Matrix, b: &[f32], act: Act) -> Result<Matrix>;

    /// Weighted segment-sum of pre-gathered rows: `out[seg[i]] += w[i] *
    /// feats[i]` with `num_segments` output rows (the SPMM aggregation
    /// tile; `seg` must be in-range).
    fn spmm_tile(&self, feats: &Matrix, w: &[f32], seg: &[u32], num_segments: usize)
        -> Result<Matrix>;

    /// Row-wise dot of two pre-gathered row blocks (the SDDMM tile).
    fn sddmm_tile(&self, dst: &Matrix, src: &Matrix) -> Result<Vec<f32>>;
}

/// Pure-rust reference backend.
#[derive(Debug, Default, Clone)]
pub struct Native;

impl Backend for Native {
    fn name(&self) -> &'static str {
        "native"
    }

    fn gemm(&self, h: &Matrix, w: &Matrix) -> Result<Matrix> {
        Ok(tensor::matmul(h, w))
    }

    fn gemm_bias_act(&self, h: &Matrix, w: &Matrix, b: &[f32], act: Act) -> Result<Matrix> {
        anyhow::ensure!(b.len() == w.cols, "bias width {} != {}", b.len(), w.cols);
        let mut out = tensor::matmul(h, w);
        for r in 0..out.rows {
            let row = out.row_mut(r);
            for (x, &bb) in row.iter_mut().zip(b) {
                let v = *x + bb;
                *x = match act {
                    Act::None => v,
                    Act::Relu => v.max(0.0),
                };
            }
        }
        Ok(out)
    }

    fn spmm_tile(&self, feats: &Matrix, w: &[f32], seg: &[u32], num_segments: usize) -> Result<Matrix> {
        anyhow::ensure!(feats.rows == w.len() && w.len() == seg.len(), "spmm tile arity");
        let seg_usize: Vec<usize> = seg.iter().map(|&s| s as usize).collect();
        Ok(tensor::segment_sum_scaled(feats, w, &seg_usize, num_segments))
    }

    fn sddmm_tile(&self, dst: &Matrix, src: &Matrix) -> Result<Vec<f32>> {
        anyhow::ensure!(
            dst.rows == src.rows && dst.cols == src.cols,
            "sddmm tile shape mismatch"
        );
        // Row-wise independent dots: band-parallel, bit-identical.
        let mut out = vec![0.0f32; dst.rows];
        let work = (dst.rows as u64) * (dst.cols as u64);
        let bounds = par::plan_bands(dst.rows, work, 64 * 1024);
        let parts = par::split_rows(&mut out, &bounds, 1);
        par::run_parts(parts, |_, (rows, band)| {
            for r in rows.clone() {
                let (a, b) = (dst.row(r), src.row(r));
                band[r - rows.start] = a.iter().zip(b).map(|(x, y)| x * y).sum();
            }
        });
        Ok(out)
    }
}

/// Select a backend by name: `native`, or `xla` (requires built artifacts
/// and a binary compiled with the `xla` cargo feature).
pub fn backend_from_config(name: &str, artifacts_dir: &std::path::Path) -> Result<std::sync::Arc<dyn Backend>> {
    match name {
        "native" => Ok(std::sync::Arc::new(Native)),
        #[cfg(feature = "xla")]
        "xla" => {
            let svc = XlaService::start(artifacts_dir)?;
            Ok(std::sync::Arc::new(svc))
        }
        #[cfg(not(feature = "xla"))]
        "xla" => anyhow::bail!(
            "backend 'xla' needs a build with `--features xla` (artifacts dir: {}); \
             see DESIGN.md §Runtime",
            artifacts_dir.display()
        ),
        other => anyhow::bail!("unknown backend '{}' (native|xla)", other),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn native_gemm_bias_act() {
        let h = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let w = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let out = Native.gemm_bias_act(&h, &w, &[-5.0, 1.0], Act::Relu).unwrap();
        assert_eq!(out.data, vec![0.0, 3.0]);
        let out2 = Native.gemm_bias_act(&h, &w, &[-5.0, 1.0], Act::None).unwrap();
        assert_eq!(out2.data, vec![-4.0, 3.0]);
    }

    #[test]
    fn native_spmm_tile() {
        let feats = Matrix::from_vec(3, 2, vec![1.0, 1.0, 2.0, 2.0, 4.0, 4.0]);
        let out = Native
            .spmm_tile(&feats, &[1.0, 0.5, 2.0], &[1, 1, 0], 2)
            .unwrap();
        assert_eq!(out.data, vec![8.0, 8.0, 2.0, 2.0]);
    }

    #[test]
    fn native_sddmm_tile() {
        let mut rng = Rng::new(2);
        let a = Matrix::random(5, 4, 1.0, &mut rng);
        let b = Matrix::random(5, 4, 1.0, &mut rng);
        let out = Native.sddmm_tile(&a, &b).unwrap();
        for r in 0..5 {
            let expect: f32 = a.row(r).iter().zip(b.row(r)).map(|(x, y)| x * y).sum();
            assert!((out[r] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn backend_from_config_native() {
        let b = backend_from_config("native", std::path::Path::new("/nonexistent")).unwrap();
        assert_eq!(b.name(), "native");
        assert!(backend_from_config("bogus", std::path::Path::new("/nonexistent")).is_err());
    }
}
