//! Cost-model-driven runtime autotuner (ROADMAP item 1; DESIGN.md
//! §Autotuning).
//!
//! The repo carries closed-form cost models (`primitives::costs`) and a
//! pile of per-knob execution variants — grouped vs pipelined SPMM, ring
//! direction, chunk size, page size, paged vs resident tiers — that
//! historically nothing chose between at runtime: every run used the
//! hardcoded defaults in `costs.rs` and `net.rs`. This module closes the
//! loop:
//!
//! 1. **[`Calibration`]** replaces the hardcoded constants with *measured*
//!    ones: a short seeded micro-calibration pass times a dense GEMM tile,
//!    a sparse aggregation tile, a staging memcpy, and a fork/join round
//!    trip on the host, yielding throughputs the planner's cost formulas
//!    consume. The result persists to a **versioned, checksummed JSON
//!    sidecar** (no serde offline — the format is hand-rolled like the WAL
//!    and trace artifacts) so repeat runs skip re-measurement; corrupt,
//!    truncated, or version-mismatched sidecars are rejected with a clear
//!    error and fall back to a fresh pass.
//! 2. **[`Planner`]** evaluates the closed forms of `primitives::costs`
//!    under the measured constants for a concrete run shape
//!    ([`ShapeInfo`]) and picks, per layer and per partition, among the
//!    execution variants: `ExecMode::Grouped` vs `Pipelined`, the ring
//!    direction of `cluster::collectives`, `chunk_rows` via
//!    `costs::optimal_chunks`, the SpMM column-group tile size, the
//!    intra-rank pool width, and the paged-vs-resident storage tier.
//! 3. **[`Plan::apply`]** installs the choices through the *existing* knob
//!    chains (`net::chunk_rows`, `par::num_threads`, `storage::page_rows`,
//!    `collectives::ring_dir`) plus a thread-local current-plan slot that
//!    `Cluster::run` and `Ctx::with_server` capture into every simulated
//!    machine, where the model forward loops consult
//!    [`layer_choice`] for their per-layer overrides.
//!
//! **Determinism contract (non-negotiable):** every variant the planner
//! chooses among is schedule-only — chunk size, ring direction, thread
//! count, page size, and exec mode are all proven bit-identical by the
//! sweep suites — so planner choices may change simulated and wall time,
//! never output values. `tests/autotune.rs` re-proves this against an
//! exhaustive fixed-configuration oracle, and `benches/autotune_planner.rs`
//! hard-asserts bit-identity to the fixed-default plan.

use std::cell::{Cell, RefCell};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::cluster::collectives::RingDir;
use crate::cluster::NetConfig;
use crate::primitives::{costs, ExecMode};
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::Result;

// ------------------------------------------------------------ enable knob

/// Sentinel states for the tri-state enable chain (`0` off, `1` on,
/// `2` unset — `bool` can't carry "no override").
const TUNE_UNSET: u8 = 2;

/// Process-global autotune override; `TUNE_UNSET` means "not set".
static GLOBAL_AUTOTUNE: AtomicU8 = AtomicU8::new(TUNE_UNSET);

thread_local! {
    /// Thread-local autotune override (`TUNE_UNSET` = no override).
    static LOCAL_AUTOTUNE: Cell<u8> = const { Cell::new(TUNE_UNSET) };

    /// The plan installed for the current scope (captured into rank and
    /// server threads by `Cluster::run` / `Ctx::with_server`).
    static LOCAL_PLAN: RefCell<Option<Arc<Plan>>> = const { RefCell::new(None) };
}

/// Set the process-global autotune switch. Wired to
/// `DealConfig.exec.autotune` and the `--autotune` CLI flag.
pub fn set_autotune(on: bool) {
    GLOBAL_AUTOTUNE.store(u8::from(on), Ordering::Relaxed);
}

/// Reset the process-global switch to auto (`DEAL_AUTOTUNE` env, else off).
pub fn clear_autotune() {
    GLOBAL_AUTOTUNE.store(TUNE_UNSET, Ordering::Relaxed);
}

/// Run `f` with autotuning pinned on/off on this thread.
pub fn with_autotune<T>(on: bool, f: impl FnOnce() -> T) -> T {
    let prev = LOCAL_AUTOTUNE.with(|c| c.replace(u8::from(on)));
    let out = f();
    LOCAL_AUTOTUNE.with(|c| c.set(prev));
    out
}

fn env_autotune_default() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("DEAL_AUTOTUNE").map_or(false, |v| v != "0" && !v.is_empty())
    })
}

/// Effective autotune switch for this thread: [`with_autotune`] scope →
/// [`set_autotune`] global (config/CLI) → `DEAL_AUTOTUNE` env → off.
pub fn enabled() -> bool {
    let local = LOCAL_AUTOTUNE.with(|c| c.get());
    if local != TUNE_UNSET {
        return local == 1;
    }
    let global = GLOBAL_AUTOTUNE.load(Ordering::Relaxed);
    if global != TUNE_UNSET {
        return global == 1;
    }
    env_autotune_default()
}

// ---------------------------------------------------------- current plan

/// The plan currently installed on this thread, if any.
pub fn current_plan() -> Option<Arc<Plan>> {
    LOCAL_PLAN.with(|p| p.borrow().clone())
}

/// Run `f` with `plan` installed as this thread's current plan (`None`
/// clears it). `Cluster::run` and `Ctx::with_server` capture the caller's
/// current plan, so one [`Plan::apply`] reaches every simulated machine.
pub fn with_plan<T>(plan: Option<Arc<Plan>>, f: impl FnOnce() -> T) -> T {
    let prev = LOCAL_PLAN.with(|p| p.replace(plan));
    let out = f();
    LOCAL_PLAN.with(|p| p.replace(prev));
    out
}

/// The current plan's choice for layer `l` (clamped to the last planned
/// layer, so shifted-weight continuations like `gcn_rest` stay covered).
/// `None` when no plan is installed — callers fall back to their
/// `ExecOpts` / ambient knobs.
pub fn layer_choice(l: usize) -> Option<LayerChoice> {
    LOCAL_PLAN.with(|p| {
        p.borrow().as_ref().and_then(|plan| {
            if plan.layers.is_empty() {
                return None;
            }
            Some(plan.layers[l.min(plan.layers.len() - 1)])
        })
    })
}

// ------------------------------------------------------------ calibration

/// Sidecar format version; bumped on any field or encoding change.
pub const CALIBRATION_VERSION: u32 = 1;

const CALIBRATION_FORMAT: &str = "deal-autotune-calibration";

/// Measured host constants the planner's cost formulas consume, replacing
/// the hardcoded defaults in `primitives::costs` / `cluster::net`. All
/// rates are single-thread (the capacity divisor is applied separately,
/// exactly as the simulator does).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Calibration {
    /// Seed of the micro-calibration workload that produced these numbers.
    pub seed: u64,
    /// Dense projection throughput: f32 multiply-adds per second.
    pub gemm_macs_per_sec: f64,
    /// Sparse aggregation throughput: edge×column multiply-adds per second.
    pub spmm_macs_per_sec: f64,
    /// Row-band staging copy throughput, bytes per second.
    pub copy_bytes_per_sec: f64,
    /// Measured fork + scoped-join cost per spawned pool worker (the
    /// measured twin of `costs::FORK_JOIN_OVERHEAD_SECS`).
    pub fork_join_secs: f64,
}

impl Calibration {
    /// Deterministic assumed constants (no measurement): the hardcoded
    /// model the planner falls back to, and the fixture for tests that
    /// must not depend on host speed.
    pub fn assumed(seed: u64) -> Calibration {
        Calibration {
            seed,
            gemm_macs_per_sec: 2.0e9,
            spmm_macs_per_sec: 5.0e8,
            copy_bytes_per_sec: 8.0e9,
            fork_join_secs: costs::FORK_JOIN_OVERHEAD_SECS,
        }
    }

    /// Short seeded micro-calibration pass (~tens of milliseconds): times
    /// a dense GEMM tile, a sparse aggregation tile, a staging memcpy, and
    /// a fork/join round trip, taking the best of a few reps to shed
    /// scheduler noise. The measured values are wall-clock facts about the
    /// host — they steer *predictions* only, never results.
    pub fn measure(seed: u64) -> Calibration {
        let mut rng = Rng::new(seed ^ 0xCA11_B8A7E);
        let best = |reps: usize, mut f: Box<dyn FnMut()>| -> f64 {
            f(); // warmup
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let t0 = Instant::now();
                f();
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best.max(1e-9)
        };

        // Dense tile: 96×96 by 96×96 → 96³ MACs per run.
        let a = Matrix::random(96, 96, 1.0, &mut rng);
        let b = Matrix::random(96, 96, 1.0, &mut rng);
        let gemm_secs = best(
            3,
            Box::new(move || {
                std::hint::black_box(crate::tensor::matmul(&a, &b));
            }),
        );
        let gemm_macs_per_sec = (96.0f64.powi(3) / gemm_secs).max(1e6);

        // Sparse tile: 8192 seeded edges into 1024 segments at 32 cols →
        // 8192 × 32 MACs per run.
        let (n_seg, n_edges, cols) = (1024usize, 8192usize, 32usize);
        let feats = Matrix::random(n_edges, cols, 1.0, &mut rng);
        let w: Vec<f32> = (0..n_edges).map(|_| rng.next_f32()).collect();
        let seg: Vec<u32> = (0..n_edges).map(|_| rng.next_below(n_seg) as u32).collect();
        let spmm_secs = best(
            3,
            Box::new(move || {
                let seg_usize: Vec<usize> = seg.iter().map(|&s| s as usize).collect();
                std::hint::black_box(crate::tensor::segment_sum_scaled(
                    &feats, &w, &seg_usize, n_seg,
                ));
            }),
        );
        let spmm_macs_per_sec = ((n_edges * cols) as f64 / spmm_secs).max(1e6);

        // Staging copy: 4 MiB buffer.
        let src = vec![1u8; 4 << 20];
        let copy_secs = best(
            3,
            Box::new(move || {
                std::hint::black_box(src.clone());
            }),
        );
        let copy_bytes_per_sec = ((4 << 20) as f64 / copy_secs).max(1e6);

        // Fork/join: spawn 2 trivial pool workers, charge half the round
        // trip to each fork.
        let fork_secs = best(
            5,
            Box::new(|| {
                std::hint::black_box(crate::runtime::par::map_indexed(2, |i| i));
            }),
        );
        let fork_join_secs = (fork_secs / 2.0).clamp(1e-7, 1e-3);

        Calibration {
            seed,
            gemm_macs_per_sec,
            spmm_macs_per_sec,
            copy_bytes_per_sec,
            fork_join_secs,
        }
    }

    /// Canonical JSON payload (everything but the checksum line). Floats
    /// print via `Display`, which emits the shortest exactly-round-tripping
    /// decimal — so save → load → save is byte-identical.
    fn payload_json(&self) -> String {
        format!(
            "{{\n  \"format\": \"{}\",\n  \"version\": {},\n  \"seed\": {},\n  \
             \"gemm_macs_per_sec\": {},\n  \"spmm_macs_per_sec\": {},\n  \
             \"copy_bytes_per_sec\": {},\n  \"fork_join_secs\": {},",
            CALIBRATION_FORMAT,
            CALIBRATION_VERSION,
            self.seed,
            self.gemm_macs_per_sec,
            self.spmm_macs_per_sec,
            self.copy_bytes_per_sec,
            self.fork_join_secs,
        )
    }

    /// Serialize to the versioned, checksummed sidecar JSON.
    pub fn to_json(&self) -> String {
        let payload = self.payload_json();
        format!(
            "{}\n  \"checksum\": \"fnv1a:{:016x}\"\n}}\n",
            payload,
            fnv1a(payload.as_bytes())
        )
    }

    /// Parse and verify a sidecar produced by [`to_json`]. Rejects
    /// truncated files, unknown formats, version mismatches, and checksum
    /// failures with errors naming the cause.
    pub fn from_json(text: &str) -> Result<Calibration> {
        let mut fields = std::collections::BTreeMap::new();
        for line in text.lines() {
            let line = line.trim().trim_end_matches(',');
            let Some((k, v)) = line.split_once(':') else { continue };
            let key = k.trim().trim_matches('"');
            // `fnv1a:<hex>` values contain a colon: re-join the remainder.
            let val = line[line.find(':').unwrap() + 1..].trim().trim_matches('"');
            let _ = v;
            fields.insert(key.to_string(), val.to_string());
        }
        let get = |k: &str| -> Result<&String> {
            fields
                .get(k)
                .ok_or_else(|| anyhow::anyhow!("calibration sidecar truncated: missing '{}'", k))
        };
        let format = get("format")?;
        anyhow::ensure!(
            format == CALIBRATION_FORMAT,
            "not a calibration sidecar (format '{}')",
            format
        );
        let version: u32 = get("version")?
            .parse()
            .map_err(|_| anyhow::anyhow!("calibration sidecar has a non-numeric version"))?;
        anyhow::ensure!(
            version == CALIBRATION_VERSION,
            "calibration sidecar version {} does not match expected version {}",
            version,
            CALIBRATION_VERSION
        );
        let num = |k: &str| -> Result<f64> {
            get(k)?
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("calibration sidecar field '{}' is corrupt", k))
        };
        let calib = Calibration {
            seed: get("seed")?
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("calibration sidecar field 'seed' is corrupt"))?,
            gemm_macs_per_sec: num("gemm_macs_per_sec")?,
            spmm_macs_per_sec: num("spmm_macs_per_sec")?,
            copy_bytes_per_sec: num("copy_bytes_per_sec")?,
            fork_join_secs: num("fork_join_secs")?,
        };
        for (k, v) in [
            ("gemm_macs_per_sec", calib.gemm_macs_per_sec),
            ("spmm_macs_per_sec", calib.spmm_macs_per_sec),
            ("copy_bytes_per_sec", calib.copy_bytes_per_sec),
            ("fork_join_secs", calib.fork_join_secs),
        ] {
            anyhow::ensure!(
                v.is_finite() && v > 0.0,
                "calibration sidecar field '{}' is corrupt (non-positive or non-finite)",
                k
            );
        }
        let stored = get("checksum")?;
        let expect = format!("fnv1a:{:016x}", fnv1a(calib.payload_json().as_bytes()));
        anyhow::ensure!(
            *stored == expect,
            "calibration sidecar checksum mismatch (stored {}, computed {})",
            stored,
            expect
        );
        Ok(calib)
    }

    /// Persist to `path` (atomic: temp file + rename, so concurrent
    /// readers never see a torn sidecar).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // Unique per process *and* per call: parallel test threads may
        // save the same sidecar concurrently.
        static SAVE_SEQ: AtomicU64 = AtomicU64::new(0);
        let n = SAVE_SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp = path.with_extension(format!("tmp.{}.{}", std::process::id(), n));
        std::fs::write(&tmp, self.to_json())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load and verify the sidecar at `path`.
    pub fn load(path: &Path) -> Result<Calibration> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read calibration sidecar {:?}: {}", path, e))?;
        Self::from_json(&text)
    }

    /// Load the sidecar if it is valid and was measured for `seed`;
    /// otherwise run a fresh micro-calibration and (best-effort) persist
    /// it. Returns the calibration and where it came from — repeat runs
    /// with an intact sidecar skip the measurement pass entirely.
    pub fn load_or_measure(path: &Path, seed: u64) -> (Calibration, CalibrationSource) {
        match Self::load(path) {
            Ok(c) if c.seed == seed => (c, CalibrationSource::Loaded),
            Ok(_) | Err(_) => {
                let c = Self::measure(seed);
                let _ = c.save(path);
                (c, CalibrationSource::Measured)
            }
        }
    }
}

/// Whether a calibration came from the sidecar or a fresh pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CalibrationSource {
    Loaded,
    Measured,
}

/// Default sidecar location: `DEAL_AUTOTUNE_CACHE` env, else
/// `target/autotune/calibration.json` (alongside the bench artifacts).
pub fn sidecar_path() -> PathBuf {
    static ENV: OnceLock<PathBuf> = OnceLock::new();
    ENV.get_or_init(|| {
        std::env::var("DEAL_AUTOTUNE_CACHE")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("target/autotune/calibration.json"))
    })
    .clone()
}

/// FNV-1a 64-bit (the same checksum family as the WAL and trace formats).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ----------------------------------------------------------------- shapes

/// The run shape the planner prices: graph size, partition grid, model
/// depth, sampled density, and the simulated machine parameters.
#[derive(Clone, Copy, Debug)]
pub struct ShapeInfo {
    /// Node count `N`.
    pub n: usize,
    /// Feature (= hidden) dimension `D`.
    pub d: usize,
    /// Graph (row) partitions `P`.
    pub p: usize,
    /// Feature (column) partitions `M`.
    pub m: usize,
    /// Model layers.
    pub layers: usize,
    /// Expected non-zeros per sampled-graph column (≈ min(fanout, degree)).
    pub z: f64,
    /// Cores per simulated machine (the compute-capacity divisor).
    pub cores: f64,
    /// The simulated network.
    pub net: NetConfig,
    /// Active storage budget (`0` = unbounded → resident tiers).
    pub budget_bytes: u64,
}

impl ShapeInfo {
    /// Shape for a configured pipeline run over a graph with `n` nodes,
    /// `n_edges` edges, and feature dimension `d`.
    pub fn for_run(
        cfg: &crate::config::DealConfig,
        n: usize,
        n_edges: usize,
        d: usize,
    ) -> Result<ShapeInfo> {
        let (p, m) = cfg.parts()?;
        let avg_deg = n_edges as f64 / (n as f64).max(1.0);
        let z = if cfg.model.fanout == 0 {
            avg_deg
        } else {
            avg_deg.min(cfg.model.fanout as f64)
        };
        Ok(ShapeInfo {
            n,
            d,
            p,
            m,
            layers: cfg.model.layers,
            z: z.max(1.0),
            cores: cfg.cluster.cores,
            net: cfg.net(),
            budget_bytes: crate::storage::mem_budget(),
        })
    }
}

// ------------------------------------------------------------------ plans

/// The planner's per-layer pick among the execution variants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerChoice {
    /// Grouped (lookahead-1) vs pipelined (lookahead-2, local-first) SPMM.
    pub mode: ExecMode,
    /// Pipelined-transfer granularity for this layer's exchanges
    /// (`0` = monolithic).
    pub chunk_rows: usize,
    /// SpMM column-group tile size (§3.5's `group_cols`).
    pub group_cols: usize,
    /// The cost model's predicted simulated seconds for this layer.
    pub predicted_secs: f64,
}

/// Per-partition cost breakdown (the planner prices each row partition
/// separately — uneven splits bottleneck on the largest one).
#[derive(Clone, Copy, Debug)]
pub struct PartitionEstimate {
    /// Rows owned by this partition.
    pub rows: usize,
    /// Predicted per-layer wire seconds for one machine of this partition.
    pub comm_secs: f64,
    /// Predicted per-layer simulated compute seconds.
    pub compute_secs: f64,
}

/// A complete plan: run-level knob settings plus per-layer choices. All
/// choices are schedule-only; applying a plan can never change output
/// values (DESIGN.md §Autotuning).
#[derive(Clone, Debug)]
pub struct Plan {
    /// Ring all-to-all direction (cost-symmetric under the fully-connected
    /// link model; pinned Forward for schedule determinism — the knob
    /// exists so the oracle can prove direction-invariance).
    pub ring_dir: RingDir,
    /// Run-level default chunk granularity (feature prep and any transfer
    /// outside a planned layer).
    pub chunk_rows: usize,
    /// Intra-rank pool width (`0` = inherit the ambient setting).
    pub threads: usize,
    /// Whether the run is expected to page (a storage budget is active).
    pub paged: bool,
    /// Page granularity for the paged tiers (applied only when `paged`).
    pub page_rows: usize,
    /// Per-layer choices, index = layer.
    pub layers: Vec<LayerChoice>,
    /// Per-partition cost breakdown for the bottleneck layer.
    pub partitions: Vec<PartitionEstimate>,
    /// Total predicted simulated seconds for the inference stage.
    pub predicted_secs: f64,
}

impl Plan {
    /// Run `f` with every plan choice installed through the existing knob
    /// chains (chunk rows, ring direction, page rows, pool width) plus the
    /// thread-local plan slot that carries the per-layer choices into the
    /// forward loops. `Cluster::run` captures all of these into rank
    /// threads, so one `apply` around a cluster launch tunes the whole
    /// simulated world.
    pub fn apply<T>(self: &Arc<Self>, f: impl FnOnce() -> T) -> T {
        let plan = Arc::clone(self);
        let body = move || with_plan(Some(plan), f);
        let body = {
            let chunk = self.chunk_rows;
            move || crate::cluster::net::with_chunk_rows(chunk, body)
        };
        let body = {
            let dir = self.ring_dir;
            move || crate::cluster::collectives::with_ring_dir(dir, body)
        };
        if self.paged {
            let rows = self.page_rows;
            let body = move || crate::storage::with_page_rows(rows, body);
            if self.threads > 0 {
                return crate::runtime::par::with_threads(self.threads, body);
            }
            return body();
        }
        if self.threads > 0 {
            return crate::runtime::par::with_threads(self.threads, body);
        }
        body()
    }
}

// ---------------------------------------------------------------- planner

/// Candidate column-group tile sizes for grouped/pipelined SPMM.
const GROUP_COLS_CANDIDATES: [usize; 3] = [1024, 4096, 16384];

/// Wall-clock break-even: forks pay off only when a layer's CPU work per
/// core exceeds this many fork/join overheads (below it the planner pins
/// the pool to 1 — which also minimizes the simulated fork term).
const FORK_BREAK_EVEN: f64 = 1024.0;

/// The cost-model-driven planner: prices execution variants with the
/// closed forms of `primitives::costs` under measured [`Calibration`]
/// constants and returns the argmin [`Plan`].
#[derive(Clone, Debug)]
pub struct Planner {
    pub calib: Calibration,
}

impl Planner {
    pub fn new(calib: Calibration) -> Self {
        Planner { calib }
    }

    /// Price one layer for the bottleneck partition and pick its variant.
    fn plan_layer(&self, s: &ShapeInfo, rows: usize) -> (LayerChoice, PartitionEstimate) {
        let (n, d, p, m) = (s.n as f64, s.d as f64, s.p as f64, s.m as f64);
        let lat = s.net.latency_secs;
        let bytes_per_sec = (s.net.bandwidth_gbps * 1e9 / 8.0).max(1.0);
        let cp = costs::CostParams { n, d, p, m, z: s.z };

        // Wire: ring GEMM + feature-exchange SPMM elements per machine
        // (closed forms of Tables 1–2), plus per-message envelope latency.
        let comm_elems = costs::gemm_ours_comm(&cp) + costs::spmm_ours_comm(&cp);
        let msgs = (s.m.saturating_sub(1) + s.p.saturating_sub(1)) as f64;
        let comm_secs = comm_elems * 4.0 / bytes_per_sec + msgs * lat;

        // Compute: dense projection + sparse aggregation MACs per machine,
        // through the measured single-thread rates, then the simulator's
        // capacity divisor (`costs::intra_rank_compute_secs`).
        let gemm_macs = n * d * d / (p * m);
        let spmm_macs = s.z * n * d / (p * m);
        let cpu_secs =
            gemm_macs / self.calib.gemm_macs_per_sec + spmm_macs / self.calib.spmm_macs_per_sec;
        // Staging copies (scatter/gather of row bands) ride on the copy rate.
        let cpu_secs = cpu_secs + comm_elems * 4.0 / self.calib.copy_bytes_per_sec;
        let compute_secs = costs::intra_rank_compute_secs(cpu_secs, 0, s.cores);

        // Chunk granularity: k* balances fill time against per-chunk
        // latency; expressed in rows of the dominant transfer (a
        // `rows / m`-row ring block).
        let kstar = costs::optimal_chunks(comm_secs, compute_secs, lat);
        let transfer_rows = (rows / s.m.max(1)).max(1);
        let chunk_rows = if kstar <= 1 || transfer_rows <= 1 {
            0 // monolithic: chunking buys nothing at this shape
        } else {
            transfer_rows.div_ceil(kstar as usize).max(16)
        };
        let chunk_comm = comm_secs + costs::chunking_overhead_secs(lat, kstar);

        // Mode: pipelined overlaps at chunk granularity; grouped overlaps
        // only at column-group granularity (lookahead 1).
        let mut best: Option<LayerChoice> = None;
        for &gc in &GROUP_COLS_CANDIDATES {
            let groups = ((s.d / s.m.max(1)).max(1)).div_ceil(gc).max(1) as u64;
            let grouped = costs::pipelined_step_secs(
                comm_secs + costs::chunking_overhead_secs(lat, groups),
                compute_secs,
                groups,
            );
            let pipelined = costs::pipelined_step_secs(chunk_comm, compute_secs, kstar.max(2));
            for (mode, secs) in [(ExecMode::Grouped, grouped), (ExecMode::Pipelined, pipelined)] {
                let cand = LayerChoice { mode, chunk_rows, group_cols: gc, predicted_secs: secs };
                // strict `<` keeps ties on the earlier candidate, and
                // Pipelined at the default group size wins exact ties via
                // candidate order only if strictly better — deterministic
                // either way.
                if best.map_or(true, |b| secs < b.predicted_secs) {
                    best = Some(cand);
                }
            }
        }
        let choice = best.expect("candidate set is non-empty");
        (choice, PartitionEstimate { rows, comm_secs, compute_secs })
    }

    /// Produce the plan for `s`: per-layer variant picks, per-partition
    /// cost breakdown, and run-level knob settings.
    pub fn plan(&self, s: &ShapeInfo) -> Plan {
        // Partition rows mirror `PartitionPlan`'s even split (ceil for the
        // leading partitions); the bottleneck partition prices the layer.
        let base = s.n / s.p.max(1);
        let extra = s.n % s.p.max(1);
        let partitions: Vec<usize> =
            (0..s.p.max(1)).map(|i| base + usize::from(i < extra)).collect();
        let bottleneck = partitions.iter().copied().max().unwrap_or(1);

        let mut layers = Vec::with_capacity(s.layers);
        let mut parts_est = Vec::with_capacity(partitions.len());
        let mut predicted = 0.0;
        for l in 0..s.layers.max(1) {
            let (choice, _) = self.plan_layer(s, bottleneck);
            predicted += choice.predicted_secs;
            if l == 0 {
                for &rows in &partitions {
                    let (_, est) = self.plan_layer(s, rows);
                    parts_est.push(est);
                }
            }
            layers.push(choice);
        }

        // Pool width: the simulated makespan always pays the fork term, so
        // forks are worth it only when the per-core CPU work dwarfs the
        // measured fork/join overhead (then they keep *wall* time sane
        // without moving the simulated needle).
        let cpu_per_layer = parts_est
            .iter()
            .map(|e| e.compute_secs)
            .fold(0.0, f64::max);
        let threads = if cpu_per_layer > FORK_BREAK_EVEN * self.calib.fork_join_secs {
            0 // big enough: inherit the ambient pool (all cores by default)
        } else {
            1 // fork overhead would dominate: stay serial per rank
        };

        let chunk_rows = layers.first().map_or(0, |c| c.chunk_rows);
        let paged = s.budget_bytes > 0;
        let page_rows = if paged {
            // Align page bands with the transfer granularity so a faulted
            // page feeds whole chunks; floor at the storage default.
            chunk_rows.max(64)
        } else {
            crate::storage::DEFAULT_PAGE_ROWS
        };

        Plan {
            ring_dir: RingDir::Forward,
            chunk_rows,
            threads,
            paged,
            page_rows,
            layers,
            partitions: parts_est,
            predicted_secs: predicted,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> ShapeInfo {
        ShapeInfo {
            n: 4096,
            d: 128,
            p: 2,
            m: 2,
            layers: 2,
            z: 10.0,
            cores: 64.0,
            net: NetConfig::default(),
            budget_bytes: 0,
        }
    }

    #[test]
    fn enable_chain_resolves() {
        // CI runs the suite once with DEAL_AUTOTUNE=1, so the unscoped
        // default is the env value, not a constant.
        let env_on = std::env::var("DEAL_AUTOTUNE").map_or(false, |v| v != "0" && !v.is_empty());
        assert_eq!(enabled(), env_on, "default follows DEAL_AUTOTUNE");
        with_autotune(true, || assert!(enabled()));
        with_autotune(false, || assert!(!enabled()));
        assert_eq!(enabled(), env_on);
        set_autotune(true);
        assert!(enabled());
        with_autotune(false, || assert!(!enabled()));
        clear_autotune();
        assert_eq!(enabled(), env_on, "clear restores the env default");
    }

    #[test]
    fn calibration_json_roundtrips_exactly() {
        let c = Calibration {
            seed: 0xDEA1,
            gemm_macs_per_sec: 1.234567890123456e9,
            spmm_macs_per_sec: 9.87654321e8,
            copy_bytes_per_sec: 1.0e10 / 3.0,
            fork_join_secs: 2.5e-5,
        };
        let json = c.to_json();
        let back = Calibration::from_json(&json).unwrap();
        assert_eq!(back, c);
        assert_eq!(back.to_json(), json, "re-emit must be byte-identical");
    }

    #[test]
    fn calibration_rejects_bad_sidecars() {
        let c = Calibration::assumed(7);
        let good = c.to_json();
        // checksum corruption: damage a digit of a measured rate
        let bad = good.replacen("2000000000", "2000000001", 1);
        assert_ne!(bad, good);
        let err = Calibration::from_json(&bad).unwrap_err().to_string();
        assert!(err.contains("checksum"), "got: {}", err);
        // version mismatch
        let vbad = good.replace("\"version\": 1", "\"version\": 999");
        let err = Calibration::from_json(&vbad).unwrap_err().to_string();
        assert!(err.contains("version"), "got: {}", err);
        // truncation
        let half = &good[..good.len() / 2];
        assert!(Calibration::from_json(half).is_err());
        // non-numeric field
        let nbad = good.replacen("2000000000", "fast", 1);
        assert!(Calibration::from_json(&nbad).is_err());
    }

    #[test]
    fn measured_calibration_is_sane() {
        let c = Calibration::measure(1);
        assert!(c.gemm_macs_per_sec >= 1e6);
        assert!(c.spmm_macs_per_sec >= 1e6);
        assert!(c.copy_bytes_per_sec >= 1e6);
        assert!(c.fork_join_secs > 0.0 && c.fork_join_secs <= 1e-3);
        // and it survives its own sidecar round trip
        let back = Calibration::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn planner_produces_consistent_plan() {
        let plan = Planner::new(Calibration::assumed(1)).plan(&shape());
        assert_eq!(plan.layers.len(), 2);
        assert_eq!(plan.partitions.len(), 2);
        assert_eq!(plan.ring_dir, RingDir::Forward);
        assert!(!plan.paged);
        assert!(plan.predicted_secs > 0.0);
        for c in &plan.layers {
            assert!(c.mode == ExecMode::Grouped || c.mode == ExecMode::Pipelined);
            assert!(c.group_cols >= 1024);
            assert!(c.predicted_secs.is_finite());
        }
        // uneven split: bottleneck partition gets the ceil share
        let mut s = shape();
        s.n = 4097;
        let plan = Planner::new(Calibration::assumed(1)).plan(&s);
        assert_eq!(plan.partitions[0].rows, 2049);
        assert_eq!(plan.partitions[1].rows, 2048);
    }

    #[test]
    fn plan_budget_turns_on_paging() {
        let mut s = shape();
        s.budget_bytes = 1 << 20;
        let plan = Planner::new(Calibration::assumed(1)).plan(&s);
        assert!(plan.paged);
        assert!(plan.page_rows >= 64);
    }

    #[test]
    fn layer_choice_visible_under_apply() {
        let plan = Arc::new(Planner::new(Calibration::assumed(1)).plan(&shape()));
        assert!(layer_choice(0).is_none(), "no plan installed yet");
        plan.apply(|| {
            let c0 = layer_choice(0).expect("plan installed");
            assert_eq!(c0, plan.layers[0]);
            // clamped beyond the last layer (gcn_rest continuations)
            assert_eq!(layer_choice(99).unwrap(), plan.layers[plan.layers.len() - 1]);
            assert_eq!(crate::cluster::net::chunk_rows(), plan.chunk_rows);
            assert_eq!(crate::cluster::collectives::ring_dir(), plan.ring_dir);
        });
        assert!(layer_choice(0).is_none(), "plan uninstalled on exit");
    }
}
