//! Trained-weight interchange with the Python training script.
//!
//! `python/compile/train.py` writes `artifacts/weights_<model>.bin` in this
//! format (little-endian): `u64 n_tensors`, then per tensor `u64 rows,
//! u64 cols, rows*cols f32`. Vectors (biases) use `rows = 1`. Tensor order
//! is fixed by the model definition: `[W0, b0, W1, b1, ...]` for GCN;
//! `[W_l, b_l, a_src_l, a_dst_l, ...]` per layer for GAT.

use std::io::{Read, Write};
use std::path::Path;

use crate::tensor::Matrix;
use crate::Result;

/// Read all tensors from a weights file.
pub fn load_weights(path: &Path) -> Result<Vec<Matrix>> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut buf8 = [0u8; 8];
    f.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    anyhow::ensure!(n < 10_000, "implausible tensor count {}", n);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        f.read_exact(&mut buf8)?;
        let rows = u64::from_le_bytes(buf8) as usize;
        f.read_exact(&mut buf8)?;
        let cols = u64::from_le_bytes(buf8) as usize;
        let mut data = vec![0u8; rows * cols * 4];
        f.read_exact(&mut data)?;
        let floats: Vec<f32> = data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.push(Matrix::from_vec(rows, cols, floats));
    }
    Ok(out)
}

/// Write tensors in the interchange format (tests and the rust-side
/// random-init path use this; training uses the python writer).
pub fn save_weights(path: &Path, tensors: &[Matrix]) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(&(tensors.len() as u64).to_le_bytes())?;
    for t in tensors {
        f.write_all(&(t.rows as u64).to_le_bytes())?;
        f.write_all(&(t.cols as u64).to_le_bytes())?;
        for v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    f.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let tensors = vec![
            Matrix::random(3, 4, 1.0, &mut rng),
            Matrix::random(1, 4, 1.0, &mut rng),
        ];
        let p = std::env::temp_dir().join(format!("deal-w-{}.bin", std::process::id()));
        save_weights(&p, &tensors).unwrap();
        let back = load_weights(&p).unwrap();
        assert_eq!(back, tensors);
        std::fs::remove_file(&p).unwrap();
    }
}
