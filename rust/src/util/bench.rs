//! Zero-dependency benchmark harness.
//!
//! The offline build environment has no `criterion`, so the `benches/`
//! binaries (declared with `harness = false`) use this module instead. It
//! provides warmup + repeated timed runs, summary statistics, aligned table
//! printing in the shape of the paper's figures, and writes each bench's
//! output under `target/bench_results/` so EXPERIMENTS.md can quote it.

use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::PathBuf;
use std::time::Instant;

use super::stats::Summary;

/// One timed measurement series.
pub struct Measurement {
    pub label: String,
    pub secs: Vec<f64>,
}

impl Measurement {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.secs).expect("empty measurement")
    }
    pub fn mean(&self) -> f64 {
        self.summary().mean
    }
}

/// Time `f` with `warmup` unmeasured runs followed by `reps` measured runs.
pub fn time_fn<F: FnMut()>(label: &str, warmup: usize, reps: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut secs = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        secs.push(t0.elapsed().as_secs_f64());
    }
    Measurement { label: label.to_string(), secs }
}

/// Time a fallible closure once, returning (value, seconds).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// A bench report: a titled collection of rows that renders as an aligned
/// table and is persisted under `target/bench_results/<name>.txt`.
pub struct Report {
    name: String,
    lines: Vec<String>,
    tables: Vec<Table>,
}

pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for i in 0..ncol {
                let _ = write!(line, "{:width$} | ", cells[i], width = widths[i]);
            }
            line.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "{}", fmt_row(&sep, &widths));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }
}

impl Report {
    pub fn new(name: &str) -> Self {
        Report { name: name.to_string(), lines: Vec::new(), tables: Vec::new() }
    }

    /// Add a free-form note line.
    pub fn note(&mut self, line: impl Into<String>) {
        self.lines.push(line.into());
    }

    pub fn add_table(&mut self, table: Table) {
        self.tables.push(table);
    }

    fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# bench: {}", self.name);
        for l in &self.lines {
            let _ = writeln!(out, "{}", l);
        }
        for t in &self.tables {
            let _ = writeln!(out);
            out.push_str(&t.render());
        }
        out
    }

    /// Print to stdout and persist under `target/bench_results/<name>.txt`.
    pub fn finish(self) {
        let text = self.render();
        println!("{}", text);
        let dir = PathBuf::from("target/bench_results");
        if fs::create_dir_all(&dir).is_ok() {
            let path = dir.join(format!("{}.txt", self.name));
            if let Ok(mut f) = fs::File::create(&path) {
                let _ = f.write_all(text.as_bytes());
                eprintln!("[bench] wrote {}", path.display());
            }
        }
    }
}

/// Parse the standard bench CLI: `--quick` shrinks workloads for smoke runs
/// (`cargo bench` in CI), `--full` restores paper-scale parameters.
pub struct BenchArgs {
    pub quick: bool,
}

impl BenchArgs {
    pub fn parse() -> Self {
        let args: Vec<String> = std::env::args().collect();
        // `cargo bench` passes `--bench`; honor DEAL_BENCH_QUICK too.
        let quick = !args.iter().any(|a| a == "--full")
            && (args.iter().any(|a| a == "--quick")
                || std::env::var("DEAL_BENCH_QUICK").map_or(true, |v| v != "0"));
        BenchArgs { quick }
    }

    /// Pick `q` when quick, else `f`.
    pub fn pick<T>(&self, q: T, f: T) -> T {
        if self.quick { q } else { f }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "speedup"]);
        t.row(&["x".into(), "1.5".into()]);
        t.row(&["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("| longer | 2"));
    }

    #[test]
    fn time_fn_counts_reps() {
        let m = time_fn("noop", 1, 5, || {});
        assert_eq!(m.secs.len(), 5);
        assert!(m.mean() >= 0.0);
    }
}
