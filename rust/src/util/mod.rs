//! Zero-dependency utilities: deterministic RNG, statistics, formatting,
//! a bench harness (used by `benches/`, which run with `harness = false`),
//! and a small property-testing harness (used across unit and integration
//! tests — the offline build environment has no `proptest`).

pub mod bench;
pub mod prop;
pub mod rng;
pub mod stats;

/// Format a byte count as a human-readable string (`1.50 GiB`).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in seconds as a human-readable string.
pub fn human_secs(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2} s", secs)
    } else {
        format!("{:.1} min", secs / 60.0)
    }
}

/// FNV-1a offset basis: the seed for an incremental [`fnv1a_extend`]
/// chain (`fnv1a(b) == fnv1a_extend(FNV_OFFSET, b)`).
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// 64-bit FNV-1a over `bytes` — the checksum used by every versioned
/// on-disk format in the repo (traffic traces, WAL records, checkpoint
/// metadata). Not cryptographic; guards against torn writes and bit
/// flips, not adversaries.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_extend(FNV_OFFSET, bytes)
}

/// Extend an FNV-1a hash state with more bytes (start from
/// [`FNV_OFFSET`]). Lets large payloads be hashed in streamed chunks
/// without materializing one contiguous buffer.
pub fn fnv1a_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Split `n` items into `parts` contiguous ranges as evenly as possible.
/// The first `n % parts` ranges get one extra item. Returns `parts + 1`
/// boundary offsets (`bounds[p]..bounds[p+1]` is range `p`).
pub fn even_ranges(n: usize, parts: usize) -> Vec<usize> {
    assert!(parts > 0, "cannot split into zero parts");
    let base = n / parts;
    let extra = n % parts;
    let mut bounds = Vec::with_capacity(parts + 1);
    let mut acc = 0;
    bounds.push(0);
    for p in 0..parts {
        acc += base + usize::from(p < extra);
        bounds.push(acc);
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_secs_units() {
        assert_eq!(human_secs(0.5e-9 * 20.0), "10.0 ns");
        assert_eq!(human_secs(2.5e-3), "2.50 ms");
        assert_eq!(human_secs(3.0), "3.00 s");
    }

    #[test]
    fn fnv1a_matches_reference_vectors_and_extends() {
        // canonical FNV-1a test vectors
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
        // incremental chaining equals one-shot hashing at any split
        let data = b"deal-durable-wal";
        for split in 0..=data.len() {
            let h = fnv1a_extend(fnv1a(&data[..split]), &data[split..]);
            assert_eq!(h, fnv1a(data), "split {}", split);
        }
    }

    #[test]
    fn div_ceil_basic() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(1, 3), 1);
        assert_eq!(div_ceil(0, 3), 0);
    }

    #[test]
    fn even_ranges_cover_and_balance() {
        for n in [0usize, 1, 7, 16, 100] {
            for parts in 1..=8usize {
                let b = even_ranges(n, parts);
                assert_eq!(b.len(), parts + 1);
                assert_eq!(b[0], 0);
                assert_eq!(*b.last().unwrap(), n);
                let sizes: Vec<usize> = (0..parts).map(|p| b[p + 1] - b[p]).collect();
                let min = sizes.iter().min().unwrap();
                let max = sizes.iter().max().unwrap();
                assert!(max - min <= 1, "unbalanced: {:?}", sizes);
            }
        }
    }
}
