//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` seeds `Xoshiro256StarStar` (the recommended pairing from the
//! xoshiro authors). Everything in the repository that needs randomness —
//! RMAT generation, neighbor sampling, synthetic features, property tests —
//! goes through this module so runs are reproducible from a single seed.

/// SplitMix64: tiny, fast generator used for seeding.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256**: the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator deterministically.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (e.g. one per machine / per node).
    pub fn fork(&self, stream: u64) -> Self {
        // Mix the stream id through splitmix so nearby ids decorrelate.
        let mut sm = SplitMix64::new(self.s[0] ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` using Lemire's rejection method.
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.next_below(hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; cheap enough).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` without replacement.
    ///
    /// Uses Floyd's algorithm: O(k) expected work regardless of `n`, which is
    /// what makes the per-node sampling-structure reuse in `sampling/` cheap.
    /// If `k >= n`, returns all of `[0, n)`.
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        if k >= n {
            return (0..n).collect();
        }
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn fork_decorrelates() {
        let base = Rng::new(7);
        let mut f0 = base.fork(0);
        let mut f1 = base.fork(1);
        let same = (0..64).filter(|_| f0.next_u64() == f1.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn next_below_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = rng.next_below(10);
            assert!(v < 10);
            counts[v] += 1;
        }
        for &c in &counts {
            // expected 10_000, allow generous slack
            assert!((7_000..13_000).contains(&c), "counts={:?}", counts);
        }
    }

    #[test]
    fn floyd_sampling_distinct_and_bounded() {
        let mut rng = Rng::new(3);
        for n in [1usize, 5, 50, 1000] {
            for k in [0usize, 1, 3, n / 2, n, n + 5] {
                let s = rng.sample_without_replacement(n, k);
                assert_eq!(s.len(), k.min(n));
                let mut sorted = s.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), s.len(), "duplicates for n={} k={}", n, k);
                assert!(s.iter().all(|&x| x < n));
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(9);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = rng.next_normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={}", mean);
        assert!((var - 1.0).abs() < 0.05, "var={}", var);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
