//! Small statistics helpers shared by the bench harness and the serving
//! front end (latency percentiles), plus a bounded uniform [`Reservoir`]
//! so long-lived pools report honest percentiles at O(1) memory.

use super::rng::Rng;

/// Summary statistics over a sample of `f64` observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    /// 99.9th percentile — the tail the traffic SLO gates bound. With
    /// fewer than ~1000 samples this interpolates toward `max`, which is
    /// the conservative (pessimistic) direction for a gate.
    pub p999: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / if n > 1 { (n - 1) as f64 } else { 1.0 };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            p999: percentile_sorted(&sorted, 0.999),
            max: sorted[n - 1],
        })
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `q` in `[0, 1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Bounded uniform sample of an unbounded observation stream (Vitter's
/// Algorithm R): after `n` pushes every observation is retained with
/// probability `cap / n`, so percentiles over the sample estimate the
/// whole stream's — not just its first `cap` entries. Each retained
/// sample keeps its arrival sequence number, so a caller can also
/// summarize just the observations after a mark (`ServePool::stats_since`
/// windows).
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    /// `(arrival sequence, value)` pairs, at most `cap` of them.
    samples: Vec<(u64, f64)>,
    rng: Rng,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        assert!(cap >= 1, "reservoir capacity must be >= 1");
        Reservoir { cap, seen: 0, samples: Vec::new(), rng: Rng::new(seed) }
    }

    /// Observe one value.
    pub fn push(&mut self, v: f64) {
        let seq = self.seen;
        if self.samples.len() < self.cap {
            self.samples.push((seq, v));
        } else {
            let j = self.rng.next_below((seq + 1) as usize);
            if j < self.cap {
                self.samples[j] = (seq, v);
            }
        }
        self.seen += 1;
    }

    /// Total observations pushed (not just retained).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Retained sample count.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Retained values whose arrival sequence is `>= mark` (a uniform —
    /// if thinner — sample of the stream after the mark).
    pub fn values_since(&self, mark: u64) -> Vec<f64> {
        self.samples.iter().filter(|&&(s, _)| s >= mark).map(|&(_, v)| v).collect()
    }

    /// Summary over the whole retained sample.
    pub fn summary(&self) -> Option<Summary> {
        self.summary_since(0)
    }

    /// Summary over the retained post-`mark` observations.
    pub fn summary_since(&self, mark: u64) -> Option<Summary> {
        Summary::of(&self.values_since(mark))
    }
}

/// Geometric mean of positive values (used for "average speedup" rows).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(f64::MIN_POSITIVE).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!(s.p999 >= s.p99 && s.p999 <= s.max, "p999={}", s.p999);
    }

    #[test]
    fn p999_orders_between_p99_and_max() {
        let xs: Vec<f64> = (0..10_000).map(|i| i as f64).collect();
        let s = Summary::of(&xs).unwrap();
        assert!(s.p99 <= s.p999 && s.p999 <= s.max);
        assert!((s.p999 - 9989.001).abs() < 1e-6, "p999={}", s.p999);
    }

    #[test]
    fn summary_empty() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn reservoir_caps_and_stays_representative() {
        // Stream 0..10_000 through a 256-slot reservoir: the retained
        // sample must stay capped and its percentiles must describe the
        // WHOLE stream, not its first 256 entries (the bug this replaced).
        let mut r = Reservoir::new(256, 7);
        for i in 0..10_000 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 256);
        assert_eq!(r.seen(), 10_000);
        let s = r.summary().unwrap();
        assert_eq!(s.n, 256);
        // a uniform 256-sample of [0, 10000) concentrates tightly; these
        // bounds hold for any seed with overwhelming probability
        assert!(s.p50 > 3_500.0 && s.p50 < 6_500.0, "p50={}", s.p50);
        assert!(s.max > 7_000.0, "max={}", s.max);
        // the capped-prefix accounting would have reported p50 ≈ 128
        assert!(s.p50 > 1_000.0);
    }

    #[test]
    fn reservoir_windows_by_sequence() {
        let mut r = Reservoir::new(8, 3);
        for i in 0..4 {
            r.push(i as f64);
        }
        let mark = r.seen();
        for i in 100..104 {
            r.push(i as f64);
        }
        // below capacity: everything retained, window filter is exact
        let w = r.values_since(mark);
        assert_eq!(w.len(), 4);
        assert!(w.iter().all(|&v| v >= 100.0));
        assert_eq!(r.summary_since(mark).unwrap().n, 4);
        assert!(r.summary_since(r.seen()).is_none());
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
