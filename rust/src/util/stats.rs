//! Small statistics helpers shared by the bench harness and the serving
//! front end (latency percentiles).

/// Summary statistics over a sample of `f64` observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / if n > 1 { (n - 1) as f64 } else { 1.0 };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[n - 1],
        })
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `q` in `[0, 1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean of positive values (used for "average speedup" rows).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(f64::MIN_POSITIVE).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
