//! Minimal property-testing harness (the offline environment has no
//! `proptest`). Provides seeded case generation, configurable case counts,
//! and shrinking for integer-vector inputs — enough to express the
//! coordinator invariants DESIGN.md calls out: "random graph × random (P, M)
//! ⇒ distributed primitive == dense oracle", CSR well-formedness, partition
//! coverage, pipeline ordering.
//!
//! Usage (`no_run`: doctest binaries lack the xla rpath in this image):
//! ```no_run
//! use deal::util::prop::{Config, run};
//! run(Config::default().cases(64), |rng| {
//!     let n = rng.range(1, 100);
//!     assert!(n < 100);
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Property run configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub seed: u64,
    pub cases: usize,
}

impl Default for Config {
    fn default() -> Self {
        // DEAL_PROP_SEED / DEAL_PROP_CASES let CI shake out flaky seeds.
        let seed = std::env::var("DEAL_PROP_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0xDEA1);
        let cases = std::env::var("DEAL_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(32);
        Config { seed, cases }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` for `cfg.cases` seeded cases. The property receives a fresh
/// RNG per case; it fails by returning `Err(description)` or panicking.
/// On failure the harness reports the case index and per-case seed so the
/// exact case can be replayed with `Config::seed`.
pub fn run<F>(cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut rng = base.fork(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        match result {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!(
                "property failed at case {}/{} (seed={:#x}): {}",
                case, cfg.cases, cfg.seed, msg
            ),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                panic!(
                    "property panicked at case {}/{} (seed={:#x}): {}",
                    case, cfg.cases, cfg.seed, msg
                );
            }
        }
    }
}

/// Assert two f32 slices are element-wise close (absolute + relative).
pub fn assert_close(actual: &[f32], expected: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if actual.len() != expected.len() {
        return Err(format!("length mismatch: {} vs {}", actual.len(), expected.len()));
    }
    for (i, (&a, &e)) in actual.iter().zip(expected.iter()).enumerate() {
        let tol = atol + rtol * e.abs();
        if (a - e).abs() > tol {
            return Err(format!(
                "mismatch at [{}]: actual={} expected={} |diff|={} tol={}",
                i,
                a,
                e,
                (a - e).abs(),
                tol
            ));
        }
        if a.is_nan() != e.is_nan() {
            return Err(format!("NaN mismatch at [{}]: actual={} expected={}", i, a, e));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run(Config::default().cases(10).seed(1), |rng| {
            count += 1;
            let v = rng.range(0, 5);
            if v < 5 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case_info() {
        run(Config::default().cases(10).seed(1), |_rng| Err("boom".into()));
    }

    #[test]
    #[should_panic(expected = "property panicked")]
    fn panicking_property_is_caught() {
        run(Config::default().cases(3).seed(1), |_rng| {
            panic!("inner panic");
        });
    }

    #[test]
    fn assert_close_tolerances() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-6, 1e-6).is_err());
    }
}
