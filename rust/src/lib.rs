//! # Deal — Distributed End-to-End GNN Inference for All Nodes
//!
//! A reproduction of the CS.DC 2025 paper "Deal: Distributed End-to-End GNN
//! Inference for All Nodes" as a three-layer rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the distributed coordinator: graph
//!   construction, 1-D graph + feature collaborative partitioning, layerwise
//!   1-hop all-node sampling, the communication-efficient distributed
//!   primitives (GEMM / SPMM / SDDMM), partitioned + pipelined communication,
//!   fused feature preparation, and the end-to-end inference driver.
//! - **Layer 2** — JAX per-tile model functions (`python/compile/model.py`),
//!   AOT-lowered to HLO text.
//! - **Layer 1** — Pallas kernels (`python/compile/kernels/`) inside those
//!   functions, validated against a pure-jnp oracle.
//!
//! Python never runs on the inference path: `runtime::XlaBackend` loads the
//! AOT artifacts through the PJRT CPU client and the entire request path is
//! rust. See `DESIGN.md` for the architecture and the experiment index.

pub mod baselines;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod graph;
pub mod model;
pub mod partition;
pub mod primitives;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod storage;
pub mod temporal;
pub mod tensor;
pub mod traffic;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
