fn main() { deal::cli::main(); }
