//! Configuration system: a typed `DealConfig` loadable from a TOML-subset
//! file (`[section]` headers, `key = value` pairs, `#` comments — no
//! serde in the offline build environment) with CLI-style `section.key=v`
//! overrides. Every knob the benches and examples sweep lives here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::cluster::NetConfig;
use crate::model::{ModelConfig, ModelKind};
use crate::primitives::ExecMode;
use crate::Result;

/// Dataset selection.
#[derive(Clone, Debug)]
pub struct DatasetCfg {
    /// Registry name (`products-sim`, `spammer-sim`, `papers-sim`) or a
    /// path to an `.edges.bin`/`.edges.txt` file.
    pub name: String,
    /// Size multiplier for registry datasets (power of two recommended).
    pub scale: f64,
}

/// Cluster / partitioning.
#[derive(Clone, Debug)]
pub struct ClusterCfg {
    /// Total simulated machines (`graph_parts * feature_parts`).
    pub machines: usize,
    /// Graph (row) partitions P; 0 = auto (machines / feature_parts).
    pub graph_parts: usize,
    /// Feature (column) partitions M per graph partition.
    pub feature_parts: usize,
    pub bandwidth_gbps: f64,
    pub latency_us: f64,
    /// Cores per simulated machine (compute-time divisor).
    pub cores: f64,
}

/// Model + sampling.
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub kind: String,
    pub layers: usize,
    pub heads: usize,
    /// GraphSAGE neighbor aggregator: `mean` | `pool`. Only meaningful for
    /// `kind = "sage"`; other models must leave it at `mean`.
    pub aggregator: String,
    /// Neighbors sampled per layer; 0 = full neighborhood.
    pub fanout: usize,
    /// Weights file (empty = deterministic random init).
    pub weights: String,
}

/// Execution strategy (§3.5 knobs).
#[derive(Clone, Debug)]
pub struct ExecCfg {
    /// monolithic | grouped | pipelined
    pub mode: String,
    /// Max distinct columns per communication group (0 = unsplit).
    pub group_cols: usize,
    /// native | xla
    pub backend: String,
    pub artifacts_dir: String,
    /// scan | redistribute | fused (Fig. 21 feature preparation)
    pub feature_prep: String,
    /// distributed | single (Fig. 20 graph construction strategy)
    pub construction: String,
    /// Intra-rank pool threads for the parallel kernels (`runtime::par`);
    /// 0 = auto (`DEAL_THREADS` env, else `available_parallelism`).
    /// Applied by the CLI via `runtime::par::set_threads`; results are
    /// bit-identical at every value.
    pub threads: usize,
    /// Cost-model-driven runtime autotuning (`runtime::autotune`;
    /// DESIGN.md §Autotuning): when on, the coordinator plans exec mode,
    /// chunk granularity, ring direction, pool width, and page size per
    /// layer from measured calibration constants instead of the fixed
    /// knobs above. Applied by the CLI via `autotune::set_autotune`
    /// (`--autotune`, or the `DEAL_AUTOTUNE` env for library/test use);
    /// plans change simulated/wall time only, never output values.
    pub autotune: bool,
    pub seed: u64,
}

/// Chunked, pipelined communication (paper §4; DESIGN.md
/// §Pipelined-communication).
#[derive(Clone, Debug)]
pub struct PipelineCfg {
    /// Rows per chunk for large matrix transfers (`Ctx::send_chunked`):
    /// receivers compute on early row bands while later bands are in
    /// flight. `0` = monolithic single-message transfers (the
    /// pre-pipelining behavior). Applied by the CLI via
    /// `cluster::net::set_chunk_rows` (`--chunk-rows`, or the
    /// `DEAL_CHUNK_ROWS` env for library/test use); results are
    /// bit-identical at every value.
    pub chunk_rows: usize,
}

/// Out-of-core tiered storage (`crate::storage`; DESIGN.md
/// §Out-of-core-storage).
#[derive(Clone, Debug)]
pub struct StorageCfg {
    /// Per-rank page-cache byte budget for the paged feature/activation
    /// and adjacency tiers. `0` = unbounded (everything stays RAM-resident
    /// — the pre-storage behavior). Accepts `k`/`m`/`g` suffixes in config
    /// files and `--set` overrides (`storage.budget_bytes=64m`). Applied
    /// by the CLI via `storage::set_mem_budget` (`--mem-budget`, or the
    /// `DEAL_MEM_BUDGET` env for library/test use); results are
    /// bit-identical at every budget — only page-fault counts change.
    pub budget_bytes: u64,
    /// Rows per page for the paged tiers (`storage::page_rows` chain;
    /// `DEAL_PAGE_ROWS` env for library/test use). Must be >= 1.
    pub page_rows: usize,
    /// Durable storage directory (`storage::storage_dir` chain;
    /// `--storage-dir` CLI sugar, `DEAL_STORAGE_DIR` env for
    /// library/test use). Empty = ephemeral: spill files are
    /// per-process tempfiles and nothing survives exit. Non-empty roots
    /// the log-structured store `deal serve --resume` recovers from
    /// (DESIGN.md §Durability).
    pub dir: String,
}

/// Traffic-harness knobs for `deal traffic` (`crate::traffic`;
/// DESIGN.md §Traffic). These parameterize the generated trace and the
/// replay; trace shape details (`zipf_s`, burst windows, …) live in
/// `traffic::TraceConfig` with `exec.seed` as the master seed.
#[derive(Clone, Debug)]
pub struct TrafficCfg {
    /// Requests in the generated trace.
    pub requests: usize,
    /// Base arrival rate, requests per simulated second.
    pub rate: f64,
    /// Zipf exponent of the key-popularity skew (0 = uniform).
    pub zipf_s: f64,
    /// Diurnal modulation amplitude in `[0, 1)`.
    pub diurnal: f64,
    /// Rate multiplier inside burst windows (1 = no bursts).
    pub burst: f64,
    /// Fraction of requests that are `Similar` queries.
    pub similar_frac: f64,
    /// Churn batches interleaved across the trace (0 = static graph).
    pub churn_batches: usize,
    /// Batch-formation policy spec (`depth`, `deadline[:US]`,
    /// `size[:IDS]` — `serve::BatchPolicy::parse`).
    pub policy: String,
    /// Open-loop time compression: simulated seconds replayed per
    /// wall-clock second.
    pub speed: f64,
}

/// Root configuration.
#[derive(Clone, Debug)]
pub struct DealConfig {
    pub dataset: DatasetCfg,
    pub cluster: ClusterCfg,
    pub model: ModelCfg,
    pub exec: ExecCfg,
    pub pipeline: PipelineCfg,
    pub storage: StorageCfg,
    pub traffic: TrafficCfg,
}

impl Default for DealConfig {
    fn default() -> Self {
        DealConfig {
            dataset: DatasetCfg { name: "products-sim".into(), scale: 1.0 },
            cluster: ClusterCfg {
                machines: 4,
                graph_parts: 0,
                feature_parts: 2,
                bandwidth_gbps: 25.0,
                latency_us: 100.0,
                cores: 64.0,
            },
            model: ModelCfg {
                // DEAL_MODEL lets CI re-run the whole suite under another
                // zoo member without touching any test's config
                kind: std::env::var("DEAL_MODEL").unwrap_or_else(|_| "gcn".into()),
                layers: 3,
                heads: 4,
                aggregator: "mean".into(),
                fanout: 50,
                weights: String::new(),
            },
            exec: ExecCfg {
                mode: "pipelined".into(),
                group_cols: 4096,
                backend: "native".into(),
                artifacts_dir: "artifacts".into(),
                feature_prep: "fused".into(),
                construction: "distributed".into(),
                threads: 0,
                autotune: false,
                seed: 0xDEA1,
            },
            pipeline: PipelineCfg { chunk_rows: crate::cluster::net::DEFAULT_CHUNK_ROWS },
            storage: StorageCfg {
                budget_bytes: 0, // unbounded: in-memory tiers, no paging
                page_rows: crate::storage::DEFAULT_PAGE_ROWS,
                dir: String::new(), // ephemeral: no durable store
            },
            traffic: TrafficCfg {
                requests: 4096,
                rate: 2000.0,
                zipf_s: 1.0,
                diurnal: 0.5,
                burst: 4.0,
                similar_frac: 0.25,
                churn_batches: 2,
                policy: "depth".into(),
                speed: 20.0,
            },
        }
    }
}

impl DealConfig {
    /// Load from a TOML-subset file.
    pub fn from_file(path: &Path) -> Result<DealConfig> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = DealConfig::default();
        for (key, value) in parse_toml_subset(&text)? {
            cfg.set(&key, &value)?;
        }
        Ok(cfg)
    }

    /// Apply one `section.key=value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = value.trim().trim_matches('"');
        match key {
            "dataset.name" => self.dataset.name = v.into(),
            "dataset.scale" => self.dataset.scale = v.parse()?,
            "cluster.machines" => self.cluster.machines = v.parse()?,
            "cluster.graph_parts" => self.cluster.graph_parts = v.parse()?,
            "cluster.feature_parts" => self.cluster.feature_parts = v.parse()?,
            "cluster.bandwidth_gbps" => self.cluster.bandwidth_gbps = v.parse()?,
            "cluster.latency_us" => self.cluster.latency_us = v.parse()?,
            "cluster.cores" => self.cluster.cores = v.parse()?,
            "model.kind" => self.model.kind = v.into(),
            "model.layers" => self.model.layers = v.parse()?,
            "model.heads" => self.model.heads = v.parse()?,
            "model.aggregator" => self.model.aggregator = v.into(),
            "model.fanout" => self.model.fanout = v.parse()?,
            "model.weights" => self.model.weights = v.into(),
            "exec.mode" => self.exec.mode = v.into(),
            "exec.group_cols" => self.exec.group_cols = v.parse()?,
            "exec.backend" => self.exec.backend = v.into(),
            "exec.artifacts_dir" => self.exec.artifacts_dir = v.into(),
            "exec.feature_prep" => self.exec.feature_prep = v.into(),
            "exec.construction" => self.exec.construction = v.into(),
            "exec.threads" => self.exec.threads = v.parse()?,
            "exec.autotune" => {
                self.exec.autotune = match v {
                    "1" | "true" | "on" => true,
                    "0" | "false" | "off" => false,
                    other => anyhow::bail!(
                        "exec.autotune must be one of 1/true/on/0/false/off, got '{}'",
                        other
                    ),
                }
            }
            "exec.seed" => self.exec.seed = v.parse()?,
            "pipeline.chunk_rows" => self.pipeline.chunk_rows = v.parse()?,
            "storage.budget_bytes" => self.storage.budget_bytes = crate::storage::parse_bytes(v)?,
            "storage.dir" => self.storage.dir = v.to_string(),
            "storage.page_rows" => {
                self.storage.page_rows = v.parse()?;
                anyhow::ensure!(self.storage.page_rows >= 1, "storage.page_rows must be >= 1");
            }
            "traffic.requests" => self.traffic.requests = v.parse()?,
            "traffic.rate" => self.traffic.rate = v.parse()?,
            "traffic.zipf_s" => self.traffic.zipf_s = v.parse()?,
            "traffic.diurnal" => self.traffic.diurnal = v.parse()?,
            "traffic.burst" => self.traffic.burst = v.parse()?,
            "traffic.similar_frac" => self.traffic.similar_frac = v.parse()?,
            "traffic.churn_batches" => self.traffic.churn_batches = v.parse()?,
            "traffic.policy" => self.traffic.policy = v.into(),
            "traffic.speed" => self.traffic.speed = v.parse()?,
            other => anyhow::bail!("unknown config key '{}'", other),
        }
        Ok(())
    }

    // ---- derived views -------------------------------------------------

    pub fn net(&self) -> NetConfig {
        NetConfig {
            bandwidth_gbps: self.cluster.bandwidth_gbps,
            latency_secs: self.cluster.latency_us * 1e-6,
        }
    }

    /// (P, M) resolved from machines / feature_parts.
    pub fn parts(&self) -> Result<(usize, usize)> {
        let m = self.cluster.feature_parts.max(1);
        let p = if self.cluster.graph_parts > 0 {
            self.cluster.graph_parts
        } else {
            anyhow::ensure!(
                self.cluster.machines % m == 0,
                "machines {} not divisible by feature_parts {}",
                self.cluster.machines,
                m
            );
            self.cluster.machines / m
        };
        Ok((p, m))
    }

    pub fn exec_mode(&self) -> Result<ExecMode> {
        match self.exec.mode.as_str() {
            "naive" => Ok(ExecMode::Naive),
            "monolithic" => Ok(ExecMode::Monolithic),
            "grouped" => Ok(ExecMode::Grouped),
            "pipelined" => Ok(ExecMode::Pipelined),
            other => anyhow::bail!("unknown exec.mode '{}'", other),
        }
    }

    pub fn model_config(&self, dim: usize) -> Result<ModelConfig> {
        let kind = ModelKind::parse(&self.model.kind)?;
        anyhow::ensure!(
            self.model.layers >= 1,
            "model.layers must be >= 1 (got {})",
            self.model.layers
        );
        if kind != ModelKind::Sage {
            anyhow::ensure!(
                self.model.aggregator == "mean",
                "model.aggregator = '{}' only applies to sage (model.kind = '{}')",
                self.model.aggregator,
                self.model.kind
            );
        }
        Ok(match kind {
            ModelKind::Gcn => ModelConfig::gcn(self.model.layers, dim),
            ModelKind::Gat => {
                anyhow::ensure!(
                    self.model.heads >= 1,
                    "model.heads must be >= 1 for gat (got {})",
                    self.model.heads
                );
                anyhow::ensure!(
                    dim % self.model.heads == 0,
                    "feature dim {} is not divisible by model.heads {} — gat splits the \
                     feature window evenly across heads",
                    dim,
                    self.model.heads
                );
                ModelConfig::gat(self.model.layers, dim, self.model.heads)
            }
            ModelKind::Sage => {
                let agg = crate::model::Aggregator::parse(&self.model.aggregator)?;
                ModelConfig::sage(self.model.layers, dim, agg)
            }
        })
    }

    pub fn artifacts_dir(&self) -> PathBuf {
        PathBuf::from(&self.exec.artifacts_dir)
    }
}

/// Parse the TOML subset into flat `section.key -> value` pairs.
pub fn parse_toml_subset(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", ln + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{}.{}", section, k.trim())
        };
        out.insert(key, v.trim().to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_resolve() {
        let cfg = DealConfig::default();
        assert_eq!(cfg.parts().unwrap(), (2, 2));
        assert_eq!(cfg.exec_mode().unwrap(), ExecMode::Pipelined);
        assert!((cfg.net().latency_secs - 100e-6).abs() < 1e-12);
        assert_eq!(cfg.pipeline.chunk_rows, crate::cluster::net::DEFAULT_CHUNK_ROWS);
    }

    #[test]
    fn chunk_rows_key_parses() {
        let mut cfg = DealConfig::default();
        cfg.set("pipeline.chunk_rows", "64").unwrap();
        assert_eq!(cfg.pipeline.chunk_rows, 64);
        cfg.set("pipeline.chunk_rows", "0").unwrap();
        assert_eq!(cfg.pipeline.chunk_rows, 0, "0 = monolithic fallback");
        assert!(cfg.set("pipeline.chunk_rows", "x").is_err());
    }

    #[test]
    fn storage_keys_parse_with_suffixes() {
        let mut cfg = DealConfig::default();
        assert_eq!(cfg.storage.budget_bytes, 0, "default is unbounded");
        cfg.set("storage.budget_bytes", "64m").unwrap();
        assert_eq!(cfg.storage.budget_bytes, 64 << 20);
        cfg.set("storage.budget_bytes", "4096").unwrap();
        assert_eq!(cfg.storage.budget_bytes, 4096);
        cfg.set("storage.page_rows", "64").unwrap();
        assert_eq!(cfg.storage.page_rows, 64);
        assert!(cfg.set("storage.page_rows", "0").is_err());
        assert!(cfg.set("storage.budget_bytes", "lots").is_err());
        assert_eq!(cfg.storage.dir, "", "default is ephemeral");
        cfg.set("storage.dir", "/tmp/deal-store").unwrap();
        assert_eq!(cfg.storage.dir, "/tmp/deal-store");
    }

    #[test]
    fn traffic_keys_parse() {
        let mut cfg = DealConfig::default();
        cfg.set("traffic.requests", "10000").unwrap();
        cfg.set("traffic.rate", "2500").unwrap();
        cfg.set("traffic.zipf_s", "1.2").unwrap();
        cfg.set("traffic.policy", "deadline:500").unwrap();
        cfg.set("traffic.speed", "50").unwrap();
        assert_eq!(cfg.traffic.requests, 10_000);
        assert_eq!(cfg.traffic.rate, 2500.0);
        assert_eq!(cfg.traffic.policy, "deadline:500");
        assert!(cfg.set("traffic.burst", "fast").is_err());
    }

    #[test]
    fn autotune_key_parses() {
        let mut cfg = DealConfig::default();
        assert!(!cfg.exec.autotune, "default off");
        for on in ["1", "true", "on"] {
            cfg.set("exec.autotune", on).unwrap();
            assert!(cfg.exec.autotune, "'{}' enables", on);
        }
        for off in ["0", "false", "off"] {
            cfg.set("exec.autotune", off).unwrap();
            assert!(!cfg.exec.autotune, "'{}' disables", off);
        }
        assert!(cfg.set("exec.autotune", "maybe").is_err());
    }

    #[test]
    fn toml_subset_parses() {
        let text = "
# comment
[dataset]
name = \"spammer-sim\"   # trailing comment
scale = 0.5

[cluster]
machines = 8
feature_parts = 4
";
        let kv = parse_toml_subset(text).unwrap();
        assert_eq!(kv["dataset.name"], "\"spammer-sim\"");
        assert_eq!(kv["cluster.machines"], "8");
        let mut cfg = DealConfig::default();
        for (k, v) in &kv {
            cfg.set(k, v).unwrap();
        }
        assert_eq!(cfg.dataset.name, "spammer-sim");
        assert_eq!(cfg.parts().unwrap(), (2, 4));
    }

    #[test]
    fn file_roundtrip() {
        let p = std::env::temp_dir().join(format!("deal-cfg-{}.toml", std::process::id()));
        std::fs::write(&p, "[model]\nkind = \"gat\"\nfanout = 10\n").unwrap();
        let cfg = DealConfig::from_file(&p).unwrap();
        assert_eq!(cfg.model.kind, "gat");
        assert_eq!(cfg.model.fanout, 10);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn model_config_validates_kind_combos() {
        let dim = 16;
        let mut cfg = DealConfig::default();
        cfg.model.kind = "gcn".into();
        cfg.model.aggregator = "pool".into();
        let err = cfg.model_config(dim).unwrap_err().to_string();
        assert!(err.contains("pool") && err.contains("gcn"), "cause-naming error: {}", err);

        let mut cfg = DealConfig::default();
        cfg.model.kind = "gat".into();
        cfg.model.heads = 0;
        let err = cfg.model_config(dim).unwrap_err().to_string();
        assert!(err.contains("model.heads"), "cause-naming error: {}", err);
        cfg.model.heads = 5; // 16 % 5 != 0
        let err = cfg.model_config(dim).unwrap_err().to_string();
        assert!(err.contains("16") && err.contains('5'), "cause-naming error: {}", err);

        let mut cfg = DealConfig::default();
        cfg.model.kind = "sage".into();
        cfg.model.aggregator = "median".into();
        let err = cfg.model_config(dim).unwrap_err().to_string();
        assert!(err.contains("mean") && err.contains("pool"), "valid kinds named: {}", err);
        cfg.model.aggregator = "pool".into();
        let mc = cfg.model_config(dim).unwrap();
        assert_eq!(mc.aggregator, crate::model::Aggregator::Pool);

        let mut cfg = DealConfig::default();
        cfg.model.kind = "transformer".into();
        let err = cfg.model_config(dim).unwrap_err().to_string();
        assert!(err.contains("gcn") && err.contains("gat") && err.contains("sage"), "{}", err);
    }

    #[test]
    fn aggregator_key_parses() {
        let mut cfg = DealConfig::default();
        assert_eq!(cfg.model.aggregator, "mean");
        cfg.set("model.aggregator", "pool").unwrap();
        assert_eq!(cfg.model.aggregator, "pool");
    }

    #[test]
    fn bad_key_and_indivisible_parts_error() {
        let mut cfg = DealConfig::default();
        assert!(cfg.set("nope.key", "1").is_err());
        cfg.cluster.machines = 5;
        cfg.cluster.feature_parts = 2;
        assert!(cfg.parts().is_err());
    }
}
