//! Dense row-major `f32` matrices and the handful of linear-algebra
//! operations the coordinator needs outside the XLA artifacts: blocked
//! matmul (the `NativeBackend` reference path), row gather/scatter for
//! feature exchange, and segment reductions for aggregation oracles.
//!
//! Kept deliberately small: the *hot* dense math on the request path runs
//! through `runtime::Backend` (AOT-compiled XLA tiles); this module is the
//! substrate + correctness oracle.

use crate::runtime::par;
use crate::util::rng::Rng;

/// Work floors (in element-ops) below which kernels stay on the calling
/// thread — fork/join costs tens of microseconds, so tiny tiles must not
/// fan out. Thresholds only affect scheduling, never results: parallel
/// and serial paths are bit-identical by construction.
const MIN_GEMM_WORK: u64 = 256 * 1024;
const MIN_SEG_WORK: u64 = 64 * 1024;
const MIN_TRANSPOSE_WORK: u64 = 128 * 1024;

/// Dense row-major `rows × cols` matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Deterministic random matrix with entries uniform in `[-scale, scale]`.
    pub fn random(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols)
            .map(|_| (rng.next_f32() * 2.0 - 1.0) * scale)
            .collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Size in bytes of the backing storage (memory accounting).
    pub fn nbytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<f32>()) as u64
    }

    /// Extract rows `[lo, hi)` as a new matrix.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.rows);
        Matrix {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }

    /// Extract columns `[lo, hi)` as a new matrix (the feature partition).
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.cols);
        let w = hi - lo;
        let mut out = Matrix::zeros(self.rows, w);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[lo..hi]);
        }
        out
    }

    /// Gather rows by index into a new matrix (`out[i] = self[idx[i]]`).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            debug_assert!(r < self.rows, "gather index {} out of {} rows", r, self.rows);
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Write `block`'s rows into `self` starting at row `at`.
    pub fn set_rows(&mut self, at: usize, block: &Matrix) {
        assert_eq!(block.cols, self.cols);
        assert!(at + block.rows <= self.rows);
        self.data[at * self.cols..(at + block.rows) * self.cols].copy_from_slice(&block.data);
    }

    /// Write `block` into the column window `[col_lo, col_lo + block.cols)`.
    pub fn set_cols(&mut self, col_lo: usize, block: &Matrix) {
        assert_eq!(block.rows, self.rows);
        assert!(col_lo + block.cols <= self.cols);
        for r in 0..self.rows {
            self.row_mut(r)[col_lo..col_lo + block.cols].copy_from_slice(block.row(r));
        }
    }

    /// Horizontally concatenate column blocks (inverse of the M-way feature
    /// partition).
    pub fn hcat(blocks: &[&Matrix]) -> Matrix {
        assert!(!blocks.is_empty());
        let rows = blocks[0].rows;
        let cols = blocks.iter().map(|b| b.cols).sum();
        let mut out = Matrix::zeros(rows, cols);
        let mut at = 0;
        for b in blocks {
            assert_eq!(b.rows, rows);
            out.set_cols(at, b);
            at += b.cols;
        }
        out
    }

    /// Vertically concatenate row blocks (inverse of the P-way 1-D partition).
    pub fn vcat(blocks: &[&Matrix]) -> Matrix {
        assert!(!blocks.is_empty());
        let cols = blocks[0].cols;
        let rows = blocks.iter().map(|b| b.rows).sum();
        let mut out = Matrix::zeros(rows, cols);
        let mut at = 0;
        for b in blocks {
            assert_eq!(b.cols, cols);
            out.set_rows(at, b);
            at += b.rows;
        }
        out
    }

    /// `self @ other` with a cache-blocked i-k-j loop order.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        matmul(self, other)
    }

    /// Cache-blocked tiled transpose: both matrices are walked one
    /// `TB × TB` tile at a time so reads and writes each stay within a
    /// tile-sized working set instead of striding a full row/column per
    /// element. Output rows (= input columns) are band-parallel.
    pub fn transpose(&self) -> Matrix {
        const TB: usize = 32;
        let (rows, cols) = (self.rows, self.cols);
        let mut out = Matrix::zeros(cols, rows);
        let bounds = par::plan_bands(cols, (rows * cols) as u64, MIN_TRANSPOSE_WORK);
        let parts = par::split_rows(&mut out.data, &bounds, rows);
        par::run_parts(parts, |_, (crange, band)| {
            let (clo, chi) = (crange.start, crange.end);
            for r0 in (0..rows).step_by(TB) {
                let r1 = (r0 + TB).min(rows);
                for c0 in (clo..chi).step_by(TB) {
                    let c1 = (c0 + TB).min(chi);
                    for c in c0..c1 {
                        let orow = &mut band[(c - clo) * rows..(c - clo + 1) * rows];
                        for r in r0..r1 {
                            orow[r] = self.data[r * cols + c];
                        }
                    }
                }
            }
        });
        out
    }

    /// Element-wise maximum absolute difference against another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Apply `f` to every element in place.
    pub fn map_inplace<F: Fn(f32) -> f32>(&mut self, f: F) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }
}

/// Blocked parallel matmul `a @ b`: the output is split into row bands
/// (one per pool thread, `runtime::par`), and each band runs the k-blocked
/// i-k-j loop — a 64-wide k block keeps the inner loop a contiguous FMA
/// over `b`'s (already densely packed row-major) rows, which the compiler
/// auto-vectorizes. Every `out[i][j]` accumulates in ascending-k order in
/// every band layout, so the result is bit-identical at any thread count.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = Matrix::zeros(m, n);
    let flops = (m as u64) * (k as u64) * (n as u64);
    let bounds = par::plan_bands(m, flops, MIN_GEMM_WORK);
    let parts = par::split_rows(&mut out.data, &bounds, n);
    par::run_parts(parts, |_, (rows, out_band)| {
        matmul_rows(a, b, rows, out_band);
    });
    out
}

/// One row band of the blocked matmul; `out_band` holds rows `rows` of the
/// output. No `a == 0` skip in the inner loop: the branch defeats
/// auto-vectorization on dense inputs (sparse aggregation goes through the
/// SpMM kernels, not here).
fn matmul_rows(a: &Matrix, b: &Matrix, rows: std::ops::Range<usize>, out_band: &mut [f32]) {
    const KB: usize = 64;
    let (k, n) = (a.cols, b.cols);
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        for i in rows.clone() {
            let a_row = a.row(i);
            let out_row = &mut out_band[(i - rows.start) * n..(i - rows.start + 1) * n];
            for kk in k0..k1 {
                let av = a_row[kk];
                let b_row = &b.data[kk * n..(kk + 1) * n];
                for (o, &bv) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// Parallel plan for the segment sums: nnz-balanced *output* segment
/// bands plus, per band, the list of input rows that land in it (one
/// O(nnz) histogram + one O(nnz) bucketing pass — no band ever re-scans
/// the whole segment list). Indices stay ascending within each band, so
/// every segment accumulates its rows in the scalar order and results are
/// bit-identical. Returns `None` when the kernel should stay serial.
#[allow(clippy::type_complexity)]
fn segment_plan(
    seg: &[usize],
    num_segments: usize,
    cols: usize,
) -> Option<(Vec<usize>, Vec<Vec<u32>>)> {
    let work = (seg.len() as u64) * (cols as u64);
    if num_segments == 0 || work < MIN_SEG_WORK || par::num_threads() == 1 {
        return None;
    }
    let mut counts = vec![0u32; num_segments];
    for &s in seg {
        // out-of-range ids must panic exactly as the scalar row_mut(s) does
        assert!(s < num_segments, "segment id {} out of range {}", s, num_segments);
        counts[s] += 1;
    }
    let bounds =
        par::weighted_bands(num_segments, |s| counts[s] as u64 * cols as u64 + 1, MIN_SEG_WORK);
    if bounds.len() <= 2 {
        return None;
    }
    let mut idx_by_band: Vec<Vec<u32>> = vec![Vec::new(); bounds.len() - 1];
    for (i, &s) in seg.iter().enumerate() {
        let b = bounds.partition_point(|&x| x <= s) - 1;
        idx_by_band[b].push(i as u32);
    }
    Some((bounds, idx_by_band))
}

/// `out[seg[i]] += x[i]` row-wise segment sum with `num_segments` output
/// rows. The oracle for the SPMM aggregation (and the shape the Pallas
/// kernel implements with a sink row for padding). Parallel over
/// nnz-balanced segment bands.
pub fn segment_sum(x: &Matrix, seg: &[usize], num_segments: usize) -> Matrix {
    assert_eq!(x.rows, seg.len());
    let cols = x.cols;
    let mut out = Matrix::zeros(num_segments, cols);
    let Some((bounds, idx_by_band)) = segment_plan(seg, num_segments, cols) else {
        for (i, &s) in seg.iter().enumerate() {
            let row = x.row(i);
            let orow = out.row_mut(s);
            for (o, &v) in orow.iter_mut().zip(row.iter()) {
                *o += v;
            }
        }
        return out;
    };
    let parts: Vec<_> =
        par::split_rows(&mut out.data, &bounds, cols).into_iter().zip(&idx_by_band).collect();
    par::run_parts(parts, |_, ((srange, band), idx)| {
        for &i in idx {
            let (i, s) = (i as usize, seg[i as usize]);
            let row = x.row(i);
            let at = (s - srange.start) * cols;
            let orow = &mut band[at..at + cols];
            for (o, &v) in orow.iter_mut().zip(row.iter()) {
                *o += v;
            }
        }
    });
    out
}

/// Row-wise scaled segment sum: `out[seg[i]] += w[i] * x[i]`. Parallel
/// over nnz-balanced segment bands (same plan as [`segment_sum`]).
pub fn segment_sum_scaled(x: &Matrix, w: &[f32], seg: &[usize], num_segments: usize) -> Matrix {
    assert_eq!(x.rows, seg.len());
    assert_eq!(x.rows, w.len());
    let cols = x.cols;
    let mut out = Matrix::zeros(num_segments, cols);
    let Some((bounds, idx_by_band)) = segment_plan(seg, num_segments, cols) else {
        for (i, &s) in seg.iter().enumerate() {
            let wi = w[i];
            let row = x.row(i);
            let orow = out.row_mut(s);
            for (o, &v) in orow.iter_mut().zip(row.iter()) {
                *o += wi * v;
            }
        }
        return out;
    };
    let parts: Vec<_> =
        par::split_rows(&mut out.data, &bounds, cols).into_iter().zip(&idx_by_band).collect();
    par::run_parts(parts, |_, ((srange, band), idx)| {
        for &i in idx {
            let (i, s) = (i as usize, seg[i as usize]);
            let wi = w[i];
            let row = x.row(i);
            let at = (s - srange.start) * cols;
            let orow = &mut band[at..at + cols];
            for (o, &v) in orow.iter_mut().zip(row.iter()) {
                *o += wi * v;
            }
        }
    });
    out
}

/// Per-segment max over scalars (used by segment-softmax for GAT).
pub fn segment_max_scalar(x: &[f32], seg: &[usize], num_segments: usize) -> Vec<f32> {
    let mut out = vec![f32::NEG_INFINITY; num_segments];
    for (i, &s) in seg.iter().enumerate() {
        if x[i] > out[s] {
            out[s] = x[i];
        }
    }
    out
}

/// Per-segment sum over scalars.
pub fn segment_sum_scalar(x: &[f32], seg: &[usize], num_segments: usize) -> Vec<f32> {
    let mut out = vec![0.0; num_segments];
    for (i, &s) in seg.iter().enumerate() {
        out[s] += x[i];
    }
    out
}

/// LeakyReLU with the GAT-standard 0.2 negative slope.
#[inline]
pub fn leaky_relu(x: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        0.2 * x
    }
}

/// ReLU.
#[inline]
pub fn relu(x: f32) -> f32 {
    x.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, run, Config};

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive_property() {
        run(Config::default().cases(16), |rng| {
            let m = rng.range(1, 20);
            let k = rng.range(1, 20);
            let n = rng.range(1, 20);
            let a = Matrix::random(m, k, 1.0, rng);
            let b = Matrix::random(k, n, 1.0, rng);
            let fast = a.matmul(&b);
            // naive triple loop oracle
            let mut naive = Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        acc += a.get(i, kk) * b.get(kk, j);
                    }
                    naive.set(i, j, acc);
                }
            }
            assert_close(&fast.data, &naive.data, 1e-4, 1e-4)
        });
    }

    #[test]
    fn slice_and_cat_roundtrip() {
        run(Config::default().cases(16), |rng| {
            let r = rng.range(1, 12);
            let c = rng.range(2, 12);
            let m = Matrix::random(r, c, 1.0, rng);
            let split = rng.range(1, c);
            let left = m.slice_cols(0, split);
            let right = m.slice_cols(split, c);
            let rebuilt = Matrix::hcat(&[&left, &right]);
            if rebuilt != m {
                return Err("hcat(slice_cols) != identity".into());
            }
            let rsplit = rng.range(0, r);
            let top = m.slice_rows(0, rsplit);
            let bottom = m.slice_rows(rsplit, r);
            let rebuilt2 = if rsplit == 0 {
                bottom.clone()
            } else {
                Matrix::vcat(&[&top, &bottom])
            };
            if rebuilt2 != m {
                return Err("vcat(slice_rows) != identity".into());
            }
            Ok(())
        });
    }

    #[test]
    fn gather_rows_matches_manual() {
        let m = Matrix::from_vec(3, 2, vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0]);
        let g = m.gather_rows(&[2, 0, 2]);
        assert_eq!(g.data, vec![20.0, 21.0, 0.0, 1.0, 20.0, 21.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(11);
        let m = Matrix::random(5, 7, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn segment_sum_basic() {
        let x = Matrix::from_vec(3, 2, vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        let out = segment_sum(&x, &[0, 1, 0], 2);
        assert_eq!(out.data, vec![4.0, 4.0, 2.0, 2.0]);
    }

    #[test]
    fn segment_sum_scaled_matches_unscaled_with_unit_weights() {
        let mut rng = Rng::new(3);
        let x = Matrix::random(10, 4, 1.0, &mut rng);
        let seg: Vec<usize> = (0..10).map(|i| i % 3).collect();
        let w = vec![1.0f32; 10];
        assert_eq!(segment_sum(&x, &seg, 3), segment_sum_scaled(&x, &w, &seg, 3));
    }

    #[test]
    fn segment_scalar_ops() {
        let x = [1.0, 5.0, -2.0, 3.0];
        let seg = [0, 0, 1, 1];
        assert_eq!(segment_max_scalar(&x, &seg, 2), vec![5.0, 3.0]);
        assert_eq!(segment_sum_scalar(&x, &seg, 2), vec![6.0, 1.0]);
    }

    #[test]
    fn activations() {
        assert_eq!(leaky_relu(2.0), 2.0);
        assert!((leaky_relu(-1.0) + 0.2).abs() < 1e-7);
        assert_eq!(relu(-3.0), 0.0);
    }
}
