//! All-node layerwise 1-hop sampling (paper §3.2, Fig. 4) — Deal's first
//! contribution.
//!
//! For a `k`-layer GNN, Deal samples `k` independent 1-hop ego networks for
//! *every* node and collects each layer's ego networks into one sampled
//! graph `G_l`. Inference then pushes the full feature tensor through
//! `G_0 … G_{k-1}` — no multi-hop ego network is ever materialized, which
//! removes the pointer-chasing of ego-centric sampling and automatically
//! shares every duplicated node projection/aggregation.
//!
//! **Sampling-structure sharing** ("sampling column-wise" in Fig. 4): the
//! per-node sampling structure — here a mutable pool holding a copy of the
//! node's neighbor list, permuted in place by partial Fisher–Yates — is
//! built once per node and reused for all `k` layers. The baseline
//! (`sample_rebuild_per_layer`) rebuilds it per layer, which is what
//! every ego-centric sampler effectively does per occurrence.
//!
//! Multi-hop ego-network sampling (`sample_ego`) is also provided: the DGI
//! / SALIENT++ / P³ baselines and the sharing-ratio study (Fig. 5, Table 5)
//! are defined over ego networks.

use crate::graph::{Csr, NodeId};
use crate::runtime::par;
use crate::util::rng::Rng;

/// Work floor (Σ degree + per-row constant) below which sampling stays
/// serial; parallelism never changes the output (per-row RNG streams).
const MIN_SAMPLE_WORK: u64 = 32 * 1024;

/// Degree-balanced row bands for the samplers (`k` draws per row, pool
/// copy ∝ degree, plus a constant per-row fork/bookkeeping term).
fn sample_bands(g: &Csr, k: usize) -> Vec<usize> {
    par::weighted_bands(
        g.n_rows,
        |v| (g.indptr[v + 1] - g.indptr[v]) * k.max(1) as u64 + 16,
        MIN_SAMPLE_WORK,
    )
}

/// Concatenate per-band per-layer edge buffers in band order — identical
/// to the row-ascending order the sequential loop emits.
fn merge_band_edges(
    k: usize,
    bands: Vec<Vec<Vec<(NodeId, NodeId)>>>,
) -> Vec<Vec<(NodeId, NodeId)>> {
    let mut layer_edges: Vec<Vec<(NodeId, NodeId)>> = (0..k)
        .map(|l| Vec::with_capacity(bands.iter().map(|b| b[l].len()).sum()))
        .collect();
    for band in bands {
        for (l, edges) in band.into_iter().enumerate() {
            layer_edges[l].extend(edges);
        }
    }
    layer_edges
}

/// The `k` sampled layer graphs. `layers[l]` is `G_l`: row = destination
/// node, columns = its sampled in-neighbors for GNN layer `l`.
/// Layer 0 is applied first (consumes `H^(0)`).
#[derive(Clone, Debug)]
pub struct LayerGraphs {
    pub layers: Vec<Csr>,
}

impl LayerGraphs {
    pub fn k(&self) -> usize {
        self.layers.len()
    }
}

/// Sample all `k` layer graphs for every node, building each node's
/// sampling pool once (column-wise sharing). `fanout == 0` means "full
/// neighborhood" (the complete-graph embedding-update mode: every `G_l`
/// is the input graph).
pub fn sample_all_layers(g: &Csr, k: usize, fanout: usize, seed: u64) -> LayerGraphs {
    if fanout == 0 {
        return LayerGraphs { layers: vec![g.clone(); k] };
    }
    let base = Rng::new(seed);
    // Each row's RNG is forked from the row id alone, so rows are
    // independent draws: degree-balanced row bands sample in parallel and
    // band-order concatenation reproduces the sequential edge order
    // bit-for-bit (the delta path's resample parity also leans on this).
    let bounds = sample_bands(g, k);
    let bands = par::map_indexed(bounds.len() - 1, |bi| {
        let mut layer_edges: Vec<Vec<(NodeId, NodeId)>> = (0..k)
            .map(|_| Vec::with_capacity((bounds[bi + 1] - bounds[bi]) * fanout.min(8)))
            .collect();
        let mut pool: Vec<NodeId> = Vec::new();
        for v in bounds[bi]..bounds[bi + 1] {
            let row = g.row(v);
            if row.is_empty() {
                continue;
            }
            let mut rng = base.fork(v as u64);
            // Build the sampling structure ONCE per node...
            pool.clear();
            pool.extend_from_slice(row);
            let take = fanout.min(pool.len());
            // ...and draw k independent without-replacement samples from it.
            for edges in layer_edges.iter_mut() {
                partial_shuffle(&mut pool, take, &mut rng);
                for &s in &pool[..take] {
                    edges.push((s, v as NodeId));
                }
            }
        }
        layer_edges
    });
    let layers = merge_band_edges(k, bands)
        .into_iter()
        .map(|e| Csr::from_edges_rect(g.n_rows, g.n_cols, &e))
        .collect();
    LayerGraphs { layers }
}

/// Baseline sampler: identical output distribution, but re-copies the
/// neighbor pool for every (node, layer) pair — the construction cost
/// ego-centric samplers pay per occurrence. Used to quantify the
/// sampling-structure sharing benefit.
pub fn sample_rebuild_per_layer(g: &Csr, k: usize, fanout: usize, seed: u64) -> LayerGraphs {
    if fanout == 0 {
        return LayerGraphs { layers: vec![g.clone(); k] };
    }
    let base = Rng::new(seed);
    // Same band-parallel harness as `sample_all_layers` so the comparison
    // isolates structure sharing, not threading.
    let bounds = sample_bands(g, k);
    let bands = par::map_indexed(bounds.len() - 1, |bi| {
        let mut layer_edges: Vec<Vec<(NodeId, NodeId)>> = (0..k)
            .map(|_| Vec::with_capacity((bounds[bi + 1] - bounds[bi]) * fanout.min(8)))
            .collect();
        for v in bounds[bi]..bounds[bi + 1] {
            let row = g.row(v);
            if row.is_empty() {
                continue;
            }
            let mut rng = base.fork(v as u64);
            let take = fanout.min(row.len());
            for edges in layer_edges.iter_mut() {
                // rebuild the pool for every layer — the shared-structure cost
                let mut pool: Vec<NodeId> = row.to_vec();
                partial_shuffle(&mut pool, take, &mut rng);
                for &s in &pool[..take] {
                    edges.push((s, v as NodeId));
                }
            }
        }
        layer_edges
    });
    let layers = merge_band_edges(k, bands)
        .into_iter()
        .map(|e| Csr::from_edges_rect(g.n_rows, g.n_cols, &e))
        .collect();
    LayerGraphs { layers }
}

/// One row's `k` per-layer sampled neighbor lists (sorted, as they appear
/// in the layer CSRs built by [`sample_all_layers`]).
pub type RowSamples = Vec<Vec<NodeId>>;

/// Re-draw the per-layer samples of the given rows of `g` exactly as
/// [`sample_all_layers`]`(g, k, fanout, seed)` would: the per-row RNG is
/// forked from the row id alone, so a row's draw depends only on its own
/// (current) neighbor list. This is what makes incremental re-sampling
/// sound (`graph::delta`): after an update batch, re-drawing just the
/// dirty rows reproduces bit-for-bit the layer graphs a from-scratch
/// sampling pass over the updated CSR would build.
///
/// Returns one [`RowSamples`] per requested row. Each list is sorted —
/// matching the row order `Csr::from_edges_rect` establishes — so the
/// results can be patched into layer CSRs with `graph::delta::replace_rows`.
pub fn resample_rows(
    g: &Csr,
    rows: &[usize],
    k: usize,
    fanout: usize,
    seed: u64,
) -> Vec<RowSamples> {
    let base = Rng::new(seed);
    let mut out = Vec::with_capacity(rows.len());
    for &v in rows {
        let row = g.row(v);
        if row.is_empty() {
            out.push(vec![Vec::new(); k]);
            continue;
        }
        if fanout == 0 {
            // full-neighborhood mode: every layer is the input graph
            out.push(vec![row.to_vec(); k]);
            continue;
        }
        let mut rng = base.fork(v as u64);
        let mut pool: Vec<NodeId> = row.to_vec();
        let take = fanout.min(pool.len());
        let mut per_layer: RowSamples = Vec::with_capacity(k);
        for _ in 0..k {
            partial_shuffle(&mut pool, take, &mut rng);
            let mut sample: Vec<NodeId> = pool[..take].to_vec();
            sample.sort_unstable();
            per_layer.push(sample);
        }
        out.push(per_layer);
    }
    out
}

/// Partial Fisher–Yates: after the call, `pool[..take]` is a uniform
/// without-replacement sample (any starting permutation works).
#[inline]
fn partial_shuffle(pool: &mut [NodeId], take: usize, rng: &mut Rng) {
    let n = pool.len();
    for i in 0..take.min(n.saturating_sub(1)) {
        let j = rng.range(i, n);
        pool.swap(i, j);
    }
}

/// A sampled multi-hop ego network (the baselines' unit of work).
/// `layer_nodes[0]` are the innermost (hop-k) nodes, ...,
/// `layer_nodes[k]` is `[root]`. `layer_edges[l]` connect
/// `layer_nodes[l]` sources to `layer_nodes[l+1]` destinations, in global
/// ids.
#[derive(Clone, Debug)]
pub struct EgoNet {
    pub root: NodeId,
    pub layer_nodes: Vec<Vec<NodeId>>,
    pub layer_edges: Vec<Vec<(NodeId, NodeId)>>,
}

impl EgoNet {
    /// Total node occurrences across layers (the quantity sharing ratios
    /// are computed over: without sharing, each occurrence is one
    /// projection + one aggregation input).
    pub fn node_occurrences(&self) -> usize {
        self.layer_nodes.iter().map(|l| l.len()).sum()
    }
}

/// Sample the k-hop ego network of `root` (fanout per hop, without
/// replacement). Frontier-by-frontier with per-layer dedup of expansion
/// targets *within this ego network only* — matching how DGL-style
/// samplers construct a MFG for one seed.
pub fn sample_ego(g: &Csr, root: NodeId, k: usize, fanout: usize, rng: &mut Rng) -> EgoNet {
    let mut layer_nodes: Vec<Vec<NodeId>> = Vec::with_capacity(k + 1);
    let mut layer_edges: Vec<Vec<(NodeId, NodeId)>> = Vec::with_capacity(k);
    layer_nodes.push(vec![root]);
    let mut frontier = vec![root];
    for _ in 0..k {
        let mut next: Vec<NodeId> = Vec::new();
        let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
        for &dst in &frontier {
            let row = g.row(dst as usize);
            if row.is_empty() {
                continue;
            }
            let take = if fanout == 0 { row.len() } else { fanout.min(row.len()) };
            let mut pool: Vec<NodeId> = row.to_vec();
            partial_shuffle(&mut pool, take, rng);
            for &s in &pool[..take] {
                edges.push((s, dst));
                next.push(s);
            }
        }
        next.sort_unstable();
        next.dedup();
        layer_edges.push(edges);
        layer_nodes.push(next.clone());
        frontier = next;
    }
    // store outermost-first to match the paper's layer-0..k convention
    layer_nodes.reverse();
    layer_edges.reverse();
    EgoNet { root, layer_nodes, layer_edges }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::util::prop::{run, Config};

    fn test_graph() -> Csr {
        Csr::from(&rmat(9, 8000, RmatParams::paper(), 21))
    }

    fn is_subgraph(sampled: &Csr, g: &Csr) -> Result<(), String> {
        for v in 0..g.n_rows {
            let orig = g.row(v);
            for &s in sampled.row(v) {
                if orig.binary_search(&s).is_err() {
                    return Err(format!("sampled edge {}->{} not in graph", s, v));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn layer_graphs_shape_and_subgraph() {
        let g = test_graph();
        let lg = sample_all_layers(&g, 3, 5, 7);
        assert_eq!(lg.k(), 3);
        for layer in &lg.layers {
            layer.validate().unwrap();
            assert_eq!(layer.n_rows, g.n_rows);
            is_subgraph(layer, &g).unwrap();
        }
    }

    #[test]
    fn fanout_bounds_degrees_property() {
        run(Config::default().cases(8), |rng| {
            let n = rng.range(5, 100);
            let m = rng.range(n, n * 8);
            let edges: Vec<(NodeId, NodeId)> = (0..m)
                .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
                .collect();
            let g = Csr::from_edges(n, &edges);
            let fanout = rng.range(1, 8);
            let k = rng.range(1, 4);
            let lg = sample_all_layers(&g, k, fanout, rng.next_u64());
            for layer in &lg.layers {
                layer.validate()?;
                for v in 0..n {
                    let expect = g.degree(v).min(fanout);
                    if layer.degree(v) != expect {
                        return Err(format!(
                            "node {} degree {} != min(deg {}, fanout {})",
                            v,
                            layer.degree(v),
                            g.degree(v),
                            fanout
                        ));
                    }
                    // Without replacement: sampled neighbors distinct —
                    // unless the input row itself has multi-edges (the pool
                    // then legitimately repeats a neighbor).
                    let orig = g.row(v);
                    let mut orig_d = orig.to_vec();
                    orig_d.dedup(); // rows are sorted by construction
                    if orig_d.len() == orig.len() {
                        let row = layer.row(v);
                        let mut d = row.to_vec();
                        d.dedup();
                        if d.len() != row.len() {
                            return Err(format!("duplicate sample in row {}", v));
                        }
                    }
                }
                is_subgraph(layer, &g)?;
            }
            Ok(())
        });
    }

    #[test]
    fn layers_are_independent_samples() {
        let g = test_graph();
        let lg = sample_all_layers(&g, 2, 3, 99);
        // With fanout 3 over larger degrees the two layers should differ
        // for at least some nodes.
        let differing = (0..g.n_rows)
            .filter(|&v| lg.layers[0].row(v) != lg.layers[1].row(v))
            .count();
        assert!(differing > 0, "layers identical — sampling not independent");
    }

    #[test]
    fn full_neighbor_mode() {
        let g = test_graph();
        let lg = sample_all_layers(&g, 2, 0, 1);
        assert_eq!(lg.layers[0], g);
        assert_eq!(lg.layers[1], g);
    }

    #[test]
    fn shared_and_rebuild_same_distribution_shape() {
        // Not bit-identical (different RNG consumption), but same degrees.
        let g = test_graph();
        let a = sample_all_layers(&g, 2, 4, 5);
        let b = sample_rebuild_per_layer(&g, 2, 4, 5);
        for l in 0..2 {
            for v in 0..g.n_rows {
                assert_eq!(a.layers[l].degree(v), b.layers[l].degree(v));
            }
        }
    }

    #[test]
    fn shared_structure_is_faster() {
        // The point of the optimization: building the pool once per node
        // beats rebuilding it per layer. Use a high-degree graph and many
        // layers to make the gap robustly measurable.
        let g = Csr::from(&rmat(12, 300_000, RmatParams::paper(), 33));
        let k = 8;
        let t0 = std::time::Instant::now();
        let _ = sample_all_layers(&g, k, 5, 7);
        let shared = t0.elapsed();
        let t1 = std::time::Instant::now();
        let _ = sample_rebuild_per_layer(&g, k, 5, 7);
        let rebuild = t1.elapsed();
        // Only assert a weak bound — CI machines are noisy.
        assert!(
            shared.as_secs_f64() < rebuild.as_secs_f64() * 1.15,
            "shared {:?} not faster than rebuild {:?}",
            shared,
            rebuild
        );
    }

    #[test]
    fn resample_rows_matches_full_sampling() {
        let g = test_graph();
        let (k, fanout, seed) = (3, 5, 7);
        let lg = sample_all_layers(&g, k, fanout, seed);
        let rows = [0usize, 3, 17, 100, g.n_rows - 1];
        let drawn = resample_rows(&g, &rows, k, fanout, seed);
        for (i, &v) in rows.iter().enumerate() {
            for l in 0..k {
                assert_eq!(
                    drawn[i][l].as_slice(),
                    lg.layers[l].row(v),
                    "row {} layer {} diverged",
                    v,
                    l
                );
            }
        }
        // full-neighborhood mode resamples to the whole (sorted) row
        let full = resample_rows(&g, &rows, 2, 0, seed);
        for (i, &v) in rows.iter().enumerate() {
            assert_eq!(full[i][0].as_slice(), g.row(v));
            assert_eq!(full[i][1].as_slice(), g.row(v));
        }
    }

    #[test]
    fn ego_net_structure() {
        let g = test_graph();
        let mut rng = Rng::new(3);
        let ego = sample_ego(&g, 5, 2, 4, &mut rng);
        assert_eq!(ego.layer_nodes.len(), 3);
        assert_eq!(ego.layer_edges.len(), 2);
        assert_eq!(ego.layer_nodes[2], vec![5]);
        // every edge dst is in the next layer's node set
        for l in 0..2 {
            let dsts: &Vec<NodeId> = &ego.layer_nodes[l + 1];
            for &(s, d) in &ego.layer_edges[l] {
                assert!(dsts.contains(&d), "edge dst {} not in layer {}", d, l + 1);
                assert!(
                    ego.layer_nodes[l].contains(&s),
                    "edge src {} not in layer {}",
                    s,
                    l
                );
            }
        }
        assert!(ego.node_occurrences() >= 1);
    }
}
