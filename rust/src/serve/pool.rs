//! Multi-threaded serving front end (DESIGN.md §Serving).
//!
//! Requests enter a **bounded** queue (`PoolOpts::queue_capacity`);
//! `try_send` admission control sheds load instead of building unbounded
//! latency. Worker threads pop the queue one at a time; the popping
//! worker greedily drains whatever else is already queued (up to
//! `max_batch`), so batches form *exactly when there is queue depth*:
//! under light load every request is its own batch (no added latency),
//! under heavy load `Similar` queries coalesce into full-tile GEMMs
//! (`batch::SimilarBatch`). Each batch pins one epoch snapshot of the
//! table (`refresh::TableCell::load`), which is what makes mid-flight
//! refresh swaps tear-free.

use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::metrics::ServiceClassCounters;
use crate::runtime::Backend;
use crate::util::stats::{Reservoir, Summary};
use crate::Result;

use super::batch::{BatchPolicy, SimilarBatch};
use super::refresh::TableCell;
use super::{Request, RequestClass, Response};

/// Worker-pool configuration.
#[derive(Clone, Copy, Debug)]
pub struct PoolOpts {
    /// Worker threads answering requests.
    pub workers: usize,
    /// Bounded front-end queue; a full queue rejects (`submit` errors).
    pub queue_capacity: usize,
    /// Maximum requests coalesced into one batch.
    pub max_batch: usize,
    /// Start with workers gated; call `ServePool::resume` to begin
    /// draining (deterministic tests, warm-up control).
    pub start_paused: bool,
    /// Latency reservoir slots: percentiles are computed over a uniform
    /// sample of this many replies (memory stays O(1) on a long-lived
    /// pool while p50/p99 keep describing the whole reply stream).
    pub latency_reservoir: usize,
    /// Batch-formation policy ([`BatchPolicy`]): which queued requests
    /// coalesce into one batch. Every policy produces bit-identical
    /// responses — only latency and grouping differ.
    pub policy: BatchPolicy,
}

impl Default for PoolOpts {
    fn default() -> Self {
        PoolOpts {
            workers: 4,
            queue_capacity: 1024,
            max_batch: 64,
            start_paused: false,
            latency_reservoir: 1 << 16,
            policy: BatchPolicy::DepthFirst,
        }
    }
}

struct Job {
    req: Request,
    reply: Sender<Result<Response>>,
    enqueued: Instant,
}

/// In-flight response handle; `wait` blocks for the worker's reply.
pub struct Ticket {
    rx: Receiver<Result<Response>>,
}

impl Ticket {
    pub fn wait(self) -> Result<Response> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("serving pool dropped the request"))?
    }
}

#[derive(Default)]
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn wait_open(&self) {
        let mut g = self.open.lock().unwrap();
        while !*g {
            g = self.cv.wait(g).unwrap();
        }
    }

    fn open(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

/// Seed for the latency reservoir's replacement RNG (sampling noise only;
/// no security or reproducibility contract rides on it).
const LATENCY_RNG_SEED: u64 = 0x1A7E9C1;

struct MetricsInner {
    served: u64,
    failed: u64,
    batches: u64,
    max_batch_seen: u64,
    coalesced_similar: u64,
    /// Uniform reservoir over every reply's enqueue-to-reply latency:
    /// bounded memory, but — unlike the capped prefix this replaced —
    /// percentiles keep describing the *whole* reply stream, however long
    /// the pool lives.
    latencies: Reservoir,
    /// Per-class request counters, indexed by `RequestClass::index`.
    /// Accounted at the pool (not by replay clients), so per-class
    /// latency timestamps are the worker's — a slow trace collector can
    /// never inflate a class's tail.
    class_counts: [ServiceClassCounters; RequestClass::ALL.len()],
    /// Per-class latency reservoirs (same observations as `latencies`,
    /// split by class).
    class_lat: [Reservoir; RequestClass::ALL.len()],
}

impl MetricsInner {
    fn new(reservoir_cap: usize) -> MetricsInner {
        MetricsInner {
            served: 0,
            failed: 0,
            batches: 0,
            max_batch_seen: 0,
            coalesced_similar: 0,
            latencies: Reservoir::new(reservoir_cap, LATENCY_RNG_SEED),
            class_counts: Default::default(),
            class_lat: [
                Reservoir::new(reservoir_cap, LATENCY_RNG_SEED ^ 1),
                Reservoir::new(reservoir_cap, LATENCY_RNG_SEED ^ 2),
            ],
        }
    }
}

/// Counter snapshot delimiting a workload on a long-lived pool (see
/// `ServePool::mark` / `stats_since`).
#[derive(Clone, Copy, Debug)]
pub struct StatsMark {
    served: u64,
    failed: u64,
    rejected: u64,
    batches: u64,
    coalesced_similar: u64,
    /// Reply-stream position: replies observed after the mark carry a
    /// reservoir sequence number `>= latency_seen`.
    latency_seen: u64,
    /// Per-class counter snapshot, indexed by `RequestClass::index`.
    class_counts: [ServiceClassCounters; RequestClass::ALL.len()],
    /// Per-class reply-stream positions.
    class_latency_seen: [u64; RequestClass::ALL.len()],
}

/// Per-class serving statistics (one request class's slice of a
/// [`PoolStats`] window).
#[derive(Clone, Debug)]
pub struct ClassStats {
    pub class: RequestClass,
    /// submitted / served / rejected / failed for this class; on a
    /// drained window `counters.accounted() == counters.submitted`.
    pub counters: ServiceClassCounters,
    /// Enqueue-to-reply latency summary for this class (None before any
    /// reply in the window).
    pub latency: Option<Summary>,
}

/// Serving statistics snapshot.
#[derive(Clone, Debug)]
pub struct PoolStats {
    pub served: u64,
    /// Requests shed by admission control (queue full).
    pub rejected: u64,
    /// Requests answered with an error.
    pub failed: u64,
    /// Batches executed.
    pub batches: u64,
    /// Largest coalesced batch observed.
    pub max_batch_seen: u64,
    /// `Similar` requests that shared a batch with at least one other.
    pub coalesced_similar: u64,
    /// Enqueue-to-reply latency summary (None before any reply).
    pub latency: Option<Summary>,
    /// Per-class breakdown, in `RequestClass::ALL` order.
    pub per_class: Vec<ClassStats>,
}

impl PoolStats {
    /// This window's statistics for one request class.
    pub fn class(&self, class: RequestClass) -> &ClassStats {
        &self.per_class[class.index()]
    }
}

struct Shared {
    table: Arc<TableCell>,
    backend: Arc<dyn Backend>,
    queue: Mutex<Receiver<Job>>,
    gate: Gate,
    metrics: Mutex<MetricsInner>,
    rejected: AtomicU64,
    max_batch: usize,
    policy: BatchPolicy,
}

/// The serving worker pool.
pub struct ServePool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl ServePool {
    /// Spawn `opts.workers` threads serving the table in `cell` through
    /// `backend`.
    pub fn spawn(cell: Arc<TableCell>, backend: Arc<dyn Backend>, opts: PoolOpts) -> ServePool {
        assert!(opts.workers >= 1, "pool needs at least one worker");
        assert!(opts.queue_capacity >= 1, "queue capacity must be >= 1");
        assert!(opts.latency_reservoir >= 1, "latency reservoir needs >= 1 slot");
        let (tx, rx) = std::sync::mpsc::sync_channel::<Job>(opts.queue_capacity);
        let shared = Arc::new(Shared {
            table: cell,
            backend,
            queue: Mutex::new(rx),
            gate: Gate::default(),
            metrics: Mutex::new(MetricsInner::new(opts.latency_reservoir)),
            rejected: AtomicU64::new(0),
            max_batch: opts.max_batch.max(1),
            policy: opts.policy,
        });
        if !opts.start_paused {
            shared.gate.open();
        }
        // Worker-level concurrency IS this pool's parallelism: with more
        // than one worker, pin the intra-rank kernel pool to 1 inside each
        // worker so concurrent batches don't multiply OS threads
        // (workers × cores). A single-worker pool keeps the kernel
        // fan-out (0 = auto).
        let kernel_threads = if opts.workers > 1 { 1 } else { 0 };
        let workers = (0..opts.workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("serve-worker-{}", i))
                    .spawn(move || {
                        crate::runtime::par::with_threads(kernel_threads, || worker_main(&shared))
                    })
                    .expect("spawn serve worker")
            })
            .collect();
        ServePool { tx: Some(tx), workers, shared }
    }

    /// Open the gate of a `start_paused` pool.
    pub fn resume(&self) {
        self.shared.gate.open();
    }

    /// Non-blocking admission: validate, then enqueue or reject.
    pub fn submit(&self, req: Request) -> Result<Ticket> {
        let class = req.class();
        self.class_mut(class, |c| c.submitted += 1);
        let table = self.shared.table.load();
        let n = table.n_nodes();
        let ids = req.ids();
        if let Some(&bad) = ids.iter().find(|&&v| v as usize >= n) {
            self.shared.rejected.fetch_add(1, AtomicOrdering::Relaxed);
            self.class_mut(class, |c| c.rejected += 1);
            anyhow::bail!("rejected: node id {} out of range ({} nodes)", bad, n);
        }
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let job = Job { req, reply: reply_tx, enqueued: Instant::now() };
        match self.tx.as_ref().expect("pool is shut down").try_send(job) {
            Ok(()) => Ok(Ticket { rx: reply_rx }),
            Err(TrySendError::Full(_)) => {
                self.shared.rejected.fetch_add(1, AtomicOrdering::Relaxed);
                self.class_mut(class, |c| c.rejected += 1);
                anyhow::bail!("rejected: serving queue full")
            }
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("serving pool is down"),
        }
    }

    /// Mutate one class's counters under the metrics lock.
    fn class_mut(&self, class: RequestClass, f: impl FnOnce(&mut ServiceClassCounters)) {
        let mut m = self.shared.metrics.lock().unwrap();
        f(&mut m.class_counts[class.index()]);
    }

    /// Blocking call: submit and wait for the response.
    pub fn call(&self, req: Request) -> Result<Response> {
        self.submit(req)?.wait()
    }

    /// Current table epoch (what a request submitted now would see).
    pub fn epoch(&self) -> u64 {
        self.shared.table.load().epoch()
    }

    /// Statistics snapshot (cumulative over the pool's lifetime).
    pub fn stats(&self) -> PoolStats {
        self.stats_from(&StatsMark {
            served: 0,
            failed: 0,
            rejected: 0,
            batches: 0,
            coalesced_similar: 0,
            latency_seen: 0,
            class_counts: Default::default(),
            class_latency_seen: [0; RequestClass::ALL.len()],
        })
    }

    /// Mark the current counters so a later `stats_since` attributes only
    /// the work in between (per-workload stats on a long-lived pool).
    pub fn mark(&self) -> StatsMark {
        let m = self.shared.metrics.lock().unwrap();
        StatsMark {
            served: m.served,
            failed: m.failed,
            rejected: self.shared.rejected.load(AtomicOrdering::Relaxed),
            batches: m.batches,
            coalesced_similar: m.coalesced_similar,
            latency_seen: m.latencies.seen(),
            class_counts: m.class_counts,
            class_latency_seen: [m.class_lat[0].seen(), m.class_lat[1].seen()],
        }
    }

    /// Statistics accumulated since `mark`. Latency summarizes the
    /// reservoir's retained post-mark replies — a uniform (if thinner)
    /// sample of the window, however many replies preceded the mark
    /// (interleaved foreign clients, if any, are attributed too — marks
    /// delimit time, not requests). `max_batch_seen` remains the
    /// pool-lifetime maximum (a windowed max is not reconstructible from
    /// counters).
    pub fn stats_since(&self, mark: &StatsMark) -> PoolStats {
        self.stats_from(mark)
    }

    fn stats_from(&self, mark: &StatsMark) -> PoolStats {
        // Copy the window out under the lock; sort/scan outside it so a
        // stats poll never stalls worker batch accounting.
        let (served, failed, batches, max_batch_seen, coalesced, lats, classes, class_lats) = {
            let m = self.shared.metrics.lock().unwrap();
            (
                m.served - mark.served,
                m.failed - mark.failed,
                m.batches - mark.batches,
                m.max_batch_seen,
                m.coalesced_similar - mark.coalesced_similar,
                m.latencies.values_since(mark.latency_seen),
                [
                    m.class_counts[0].since(&mark.class_counts[0]),
                    m.class_counts[1].since(&mark.class_counts[1]),
                ],
                [
                    m.class_lat[0].values_since(mark.class_latency_seen[0]),
                    m.class_lat[1].values_since(mark.class_latency_seen[1]),
                ],
            )
        };
        let per_class = RequestClass::ALL
            .iter()
            .map(|&class| ClassStats {
                class,
                counters: classes[class.index()],
                latency: Summary::of(&class_lats[class.index()]),
            })
            .collect();
        PoolStats {
            served,
            rejected: self.shared.rejected.load(AtomicOrdering::Relaxed) - mark.rejected,
            failed,
            batches,
            max_batch_seen,
            coalesced_similar: coalesced,
            latency: Summary::of(&lats),
            per_class,
        }
    }

    /// Block until every request submitted so far has been accounted
    /// (served, rejected, or failed) — the queue is drained and no batch
    /// is in flight. Spin-waits with a short sleep; meant for drain
    /// barriers (trace replay, tests), not hot paths. A paused pool with
    /// queued work never quiesces — resume it first.
    pub fn quiesce(&self) {
        loop {
            {
                let m = self.shared.metrics.lock().unwrap();
                if m.class_counts.iter().all(|c| c.accounted() >= c.submitted) {
                    return;
                }
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Drain and stop: close the queue, join workers, return final stats.
    pub fn shutdown(mut self) -> PoolStats {
        self.stop();
        self.stats()
    }

    fn stop(&mut self) {
        // Closing the sender makes worker `recv` fail once the queue is
        // empty; open the gate so paused workers can observe it.
        self.tx.take();
        self.shared.gate.open();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ServePool {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker_main(shared: &Shared) {
    loop {
        shared.gate.wait_open();
        // One worker at a time forms a batch (the queue lock serializes
        // formation, so a deadline wait also holds back sibling formers —
        // by design: the policy decides one batch at a time): pop one job
        // (blocking), then extend it per the batch-formation policy.
        let batch: Vec<Job> = {
            let rx = match shared.queue.lock() {
                Ok(rx) => rx,
                Err(_) => return, // a sibling worker panicked
            };
            let first = match rx.recv() {
                Ok(j) => j,
                Err(_) => return, // queue closed and empty: shutdown
            };
            form_batch(&rx, first, shared.max_batch, shared.policy)
        };
        serve_batch(shared, batch);
    }
}

/// Extend `first` into a batch according to `policy`. Every policy caps
/// at `max_batch` requests; they differ in *when the batch closes*:
/// depth-first closes on an empty queue, deadline holds the batch open
/// for stragglers, size-capped closes on summed id width. Grouping never
/// changes responses (the `SimilarBatch` parity contract), so the policy
/// only moves latency.
fn form_batch(rx: &Receiver<Job>, first: Job, max_batch: usize, policy: BatchPolicy) -> Vec<Job> {
    let mut batch = vec![first];
    match policy {
        BatchPolicy::DepthFirst => {
            while batch.len() < max_batch {
                match rx.try_recv() {
                    Ok(j) => batch.push(j),
                    Err(_) => break,
                }
            }
        }
        BatchPolicy::Deadline { max_wait_us } => {
            let deadline = Instant::now() + Duration::from_micros(max_wait_us);
            while batch.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(j) => batch.push(j),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        }
        BatchPolicy::SizeCapped { max_ids } => {
            let mut ids = batch[0].req.ids().len();
            // the request that crosses the cap is included, so a single
            // over-wide request still forms a (singleton) batch
            while batch.len() < max_batch && ids < max_ids.max(1) {
                match rx.try_recv() {
                    Ok(j) => {
                        ids += j.req.ids().len();
                        batch.push(j);
                    }
                    Err(_) => break,
                }
            }
        }
    }
    batch
}

/// Answer one coalesced batch against a single epoch snapshot.
fn serve_batch(shared: &Shared, batch: Vec<Job>) {
    let table = shared.table.load(); // pinned for the whole batch
    let n = table.n_nodes();

    // Re-check admission against the pinned snapshot: ids validated at
    // submit time may be stale if a refresh changed the node count. Such
    // requests are *rejections* (the client raced a shrink), not serving
    // failures — the zero-failures refresh guarantee stays intact.
    let (batch, stale): (Vec<Job>, Vec<Job>) =
        batch.into_iter().partition(|job| job.req.ids().iter().all(|&v| (v as usize) < n));
    for job in stale {
        shared.rejected.fetch_add(1, AtomicOrdering::Relaxed);
        {
            let mut m = shared.metrics.lock().unwrap();
            m.class_counts[job.req.class().index()].rejected += 1;
        }
        let _ = job.reply.send(Err(anyhow::anyhow!(
            "rejected: node id out of range for epoch {} ({} nodes)",
            table.epoch(),
            n
        )));
    }
    if batch.is_empty() {
        return;
    }
    let n_jobs = batch.len() as u64;

    // Split: Embed jobs answer directly; Similar jobs coalesce.
    let mut similar_jobs: Vec<usize> = Vec::new();
    let mut similar_views: Vec<(&[u32], usize)> = Vec::new();
    for (i, job) in batch.iter().enumerate() {
        if let Request::Similar { ids, k } = &job.req {
            similar_jobs.push(i);
            similar_views.push((ids.as_slice(), *k));
        }
    }
    let sim_results = if similar_views.is_empty() {
        Ok(Vec::new())
    } else {
        SimilarBatch::coalesce(&similar_views).execute(&table, shared.backend.as_ref())
    };
    drop(similar_views); // release the borrows of `batch` before moving it

    let mut replies: Vec<Option<Result<Response>>> = Vec::with_capacity(batch.len());
    for job in &batch {
        match &job.req {
            Request::Embed(ids) => {
                replies.push(Some(table.try_gather(ids).map(Response::Embeddings)));
            }
            Request::Similar { .. } => replies.push(None), // filled below
        }
    }
    match sim_results {
        Ok(mut lists) => {
            // `execute` returns per coalesced request, in `similar_jobs`
            // order; scatter back.
            for i in similar_jobs.iter().rev() {
                let lists_i = lists.pop().expect("similar result arity");
                replies[*i] = Some(Ok(Response::Similar(lists_i)));
            }
        }
        Err(e) => {
            let msg = format!("batched similar failed: {:#}", e);
            for &i in &similar_jobs {
                replies[i] = Some(Err(anyhow::anyhow!(msg.clone())));
            }
        }
    }

    let coalesced = if similar_jobs.len() > 1 { similar_jobs.len() as u64 } else { 0 };
    let mut served = 0u64;
    let mut failed = 0u64;
    let mut class_delta = [ServiceClassCounters::default(); RequestClass::ALL.len()];
    let mut lats = Vec::with_capacity(batch.len());
    let mut to_send = Vec::with_capacity(batch.len());
    for (job, reply) in batch.into_iter().zip(replies) {
        let reply = reply.expect("reply filled");
        let class = job.req.class();
        if reply.is_err() {
            failed += 1;
            class_delta[class.index()].failed += 1;
        } else {
            served += 1;
            class_delta[class.index()].served += 1;
        }
        lats.push((class, job.enqueued.elapsed().as_secs_f64()));
        to_send.push((job.reply, reply));
    }
    // Account *before* replying: a caller that has observed the last
    // response must also observe it in `stats()`.
    {
        let mut m = shared.metrics.lock().unwrap();
        m.served += served;
        m.failed += failed;
        m.batches += 1;
        m.max_batch_seen = m.max_batch_seen.max(n_jobs);
        m.coalesced_similar += coalesced;
        for i in 0..class_delta.len() {
            m.class_counts[i].add(&class_delta[i]);
        }
        for (class, l) in lats {
            m.latencies.push(l);
            m.class_lat[class.index()].push(l);
        }
    }
    for (tx, reply) in to_send {
        // The requester may have given up (dropped its Ticket); ignore.
        let _ = tx.send(reply);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Native;
    use crate::serve::shard::ShardedTable;
    use crate::serve::EmbeddingServer;
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn setup(n: usize, d: usize, shards: usize) -> (Matrix, Arc<TableCell>) {
        let mut rng = Rng::new(77);
        let full = Matrix::random(n, d, 1.0, &mut rng);
        let cell = Arc::new(TableCell::new(ShardedTable::from_full(&full, shards, 0)));
        (full, cell)
    }

    #[test]
    fn pool_answers_embed_and_similar() {
        let (full, cell) = setup(40, 6, 2);
        let pool = ServePool::spawn(cell, Arc::new(Native), PoolOpts::default());
        let server = EmbeddingServer::new(full);

        let resp = pool.call(Request::Embed(vec![3, 9])).unwrap();
        match resp {
            Response::Embeddings(m) => {
                assert_eq!(m.rows, 2);
                assert_eq!(m.row(0), server.embeddings.row(3));
            }
            _ => panic!("wrong response"),
        }
        let req = Request::Similar { ids: vec![1, 20], k: 5 };
        let got = pool.call(req.clone()).unwrap();
        let want = server.handle(&req, &Native).unwrap();
        match (got, want) {
            (Response::Similar(g), Response::Similar(w)) => {
                for (gl, wl) in g.iter().zip(&w) {
                    let gi: Vec<u32> = gl.iter().map(|x| x.0).collect();
                    let wi: Vec<u32> = wl.iter().map(|x| x.0).collect();
                    assert_eq!(gi, wi);
                }
            }
            _ => panic!("wrong response kind"),
        }
        let stats = pool.shutdown();
        assert_eq!(stats.served, 2);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.failed, 0);
    }

    #[test]
    fn out_of_range_ids_are_rejected_at_admission() {
        let (_, cell) = setup(10, 4, 2);
        let pool = ServePool::spawn(cell, Arc::new(Native), PoolOpts::default());
        assert!(pool.submit(Request::Embed(vec![10])).is_err());
        assert!(pool.submit(Request::Similar { ids: vec![99], k: 1 }).is_err());
        assert_eq!(pool.stats().rejected, 2);
    }

    #[test]
    fn latency_reservoir_observes_late_replies() {
        // Regression: the old accounting kept only the first LATENCY_CAP
        // replies, so a mark placed after the cap filled observed an empty
        // latency window forever (and lifetime percentiles described only
        // the pool's first minutes). The reservoir keeps admitting late
        // replies at bounded memory.
        let (_, cell) = setup(16, 4, 2);
        let opts = PoolOpts {
            workers: 1,
            queue_capacity: 256,
            max_batch: 1,
            latency_reservoir: 16,
            ..PoolOpts::default()
        };
        let pool = ServePool::spawn(cell, Arc::new(Native), opts);
        // fill the reservoir three times over...
        for _ in 0..48 {
            pool.call(Request::Embed(vec![1])).unwrap();
        }
        let mark = pool.mark();
        // ...then serve a post-mark workload 3x the pre-mark one
        for _ in 0..144 {
            pool.call(Request::Embed(vec![2])).unwrap();
        }
        let since = pool.stats_since(&mark);
        assert_eq!(since.served, 144);
        let window = since.latency.expect("post-mark replies must stay observable");
        assert!(window.n >= 1 && window.n <= 16, "window n={}", window.n);
        let lifetime = pool.shutdown().latency.expect("lifetime latency");
        assert!(lifetime.n <= 16, "reservoir must stay bounded, n={}", lifetime.n);
    }

    #[test]
    fn per_class_stats_conserve_and_split_latency() {
        let (_, cell) = setup(32, 4, 2);
        let opts = PoolOpts { workers: 1, queue_capacity: 64, ..PoolOpts::default() };
        let pool = ServePool::spawn(cell, Arc::new(Native), opts);
        for i in 0..6 {
            pool.call(Request::Embed(vec![i])).unwrap();
        }
        for i in 0..3 {
            pool.call(Request::Similar { ids: vec![i], k: 2 }).unwrap();
        }
        // one admission reject lands on the embed class
        assert!(pool.submit(Request::Embed(vec![99])).is_err());
        let stats = pool.stats();
        let embed = stats.class(RequestClass::Embed);
        let sim = stats.class(RequestClass::Similar);
        assert_eq!(embed.counters.submitted, 7);
        assert_eq!(embed.counters.served, 6);
        assert_eq!(embed.counters.rejected, 1);
        assert_eq!(embed.counters.accounted(), embed.counters.submitted);
        assert_eq!(sim.counters.submitted, 3);
        assert_eq!(sim.counters.served, 3);
        assert_eq!(sim.counters.accounted(), 3);
        assert_eq!(embed.latency.as_ref().unwrap().n, 6);
        assert_eq!(sim.latency.as_ref().unwrap().n, 3);
        // a windowed mark attributes only post-mark per-class work
        let mark = pool.mark();
        pool.call(Request::Similar { ids: vec![1], k: 1 }).unwrap();
        let since = pool.stats_since(&mark);
        assert_eq!(since.class(RequestClass::Embed).counters.submitted, 0);
        assert_eq!(since.class(RequestClass::Similar).counters.served, 1);
    }

    #[test]
    fn size_capped_policy_bounds_batch_id_width() {
        let (_, cell) = setup(64, 4, 2);
        let opts = PoolOpts {
            workers: 1,
            queue_capacity: 64,
            max_batch: 64,
            start_paused: true,
            policy: BatchPolicy::SizeCapped { max_ids: 16 },
            ..PoolOpts::default()
        };
        let pool = ServePool::spawn(cell, Arc::new(Native), opts);
        // 4 × 8-id embeds: the cap closes each batch at two requests
        let tickets: Vec<Ticket> = (0..4)
            .map(|_| pool.submit(Request::Embed((0..8).collect())).unwrap())
            .collect();
        pool.resume();
        for t in tickets {
            t.wait().unwrap();
        }
        let stats = pool.shutdown();
        assert_eq!(stats.served, 4);
        assert_eq!(stats.batches, 2, "16-id cap splits the backlog in two: {:?}", stats);
        assert_eq!(stats.max_batch_seen, 2);
    }

    #[test]
    fn deadline_policy_coalesces_a_queued_backlog() {
        let (_, cell) = setup(64, 8, 2);
        let opts = PoolOpts {
            workers: 1,
            queue_capacity: 64,
            max_batch: 64,
            start_paused: true,
            policy: BatchPolicy::Deadline { max_wait_us: 100 },
            ..PoolOpts::default()
        };
        let pool = ServePool::spawn(cell, Arc::new(Native), opts);
        let tickets: Vec<Ticket> = (0..10)
            .map(|i| pool.submit(Request::Similar { ids: vec![i as u32], k: 3 }).unwrap())
            .collect();
        pool.resume();
        for t in tickets {
            t.wait().unwrap();
        }
        let stats = pool.shutdown();
        assert_eq!(stats.served, 10);
        // an already-queued backlog coalesces without waiting out the
        // deadline (recv_timeout returns immediately on a non-empty queue)
        assert_eq!(stats.batches, 1, "stats: {:?}", stats);
        assert_eq!(stats.coalesced_similar, 10);
    }

    #[test]
    fn paused_pool_coalesces_the_backlog() {
        let (_, cell) = setup(64, 8, 2);
        let opts = PoolOpts {
            workers: 1,
            queue_capacity: 64,
            max_batch: 64,
            start_paused: true,
            ..PoolOpts::default()
        };
        let pool = ServePool::spawn(cell, Arc::new(Native), opts);
        let tickets: Vec<Ticket> = (0..10)
            .map(|i| pool.submit(Request::Similar { ids: vec![i as u32], k: 3 }).unwrap())
            .collect();
        pool.resume();
        for t in tickets {
            t.wait().unwrap();
        }
        let stats = pool.stats();
        assert_eq!(stats.served, 10);
        // the whole backlog should land in one batch
        assert_eq!(stats.batches, 1, "stats: {:?}", stats);
        assert_eq!(stats.max_batch_seen, 10);
        assert_eq!(stats.coalesced_similar, 10);
    }
}
