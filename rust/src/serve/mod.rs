//! Embedding-serving subsystem — the downstream consumer of end-to-end
//! all-node inference (paper §1: recommendation / fraud detection serve
//! the daily-refreshed embedding table). See DESIGN.md §Serving.
//!
//! Two request kinds against the table:
//! - `Embed`: fetch embeddings for a batch of node ids;
//! - `Similar`: top-k nearest nodes by inner product, computed as a GEMM
//!   against the table — routed through `runtime::Backend`, so with the
//!   XLA backend the scoring matmul runs inside an AOT-compiled artifact.
//!
//! Two serving paths:
//! - [`EmbeddingServer`] — the single-copy, synchronous reference path
//!   (one request, one GEMM). Kept as the correctness oracle and the
//!   baseline the `serving_throughput` bench measures against.
//! - [`ServePool`] over a [`ShardedTable`] in a [`TableCell`] — the
//!   production-shaped path: the table is 1-D row-sharded with the
//!   inference partition layout ([`shard`]), concurrent `Similar`
//!   queries coalesce into one GEMM per shard ([`batch`]), a bounded
//!   queue + worker pool sheds overload and reports p50/p99/throughput
//!   ([`pool`]), and `coordinator::Pipeline` refreshes publish new
//!   epochs without dropping in-flight requests ([`refresh`]).
//!
//! `examples/serve_embeddings.rs` drives both after a full pipeline run
//! (EXPERIMENTS.md §E2E); `benches/serving_throughput.rs` measures the
//! batched/sharded speedup.

pub mod batch;
pub mod pool;
pub mod refresh;
pub mod shard;

pub use batch::{top_k, BatchPolicy, SimilarBatch};
pub use pool::{ClassStats, PoolOpts, PoolStats, ServePool, StatsMark, Ticket};
pub use refresh::{
    refresh_delta, refresh_delta_durable, DeltaRefreshReport, RefreshReport, Refresher, TableCell,
};
pub use shard::ShardedTable;

use std::time::Instant;

use crate::runtime::Backend;
use crate::tensor::Matrix;
use crate::util::stats::Summary;
use crate::Result;

/// A request against the embedding table.
#[derive(Clone, Debug)]
pub enum Request {
    /// Fetch embeddings of these nodes.
    Embed(Vec<u32>),
    /// Top-k similar nodes to each of these query nodes.
    Similar { ids: Vec<u32>, k: usize },
}

impl Request {
    /// The node ids this request touches (admission validation, batch
    /// sizing under [`BatchPolicy::SizeCapped`]).
    pub fn ids(&self) -> &[u32] {
        match self {
            Request::Embed(ids) => ids,
            Request::Similar { ids, .. } => ids,
        }
    }

    /// The request's service class (per-class latency accounting).
    pub fn class(&self) -> RequestClass {
        match self {
            Request::Embed(_) => RequestClass::Embed,
            Request::Similar { .. } => RequestClass::Similar,
        }
    }
}

/// Service class of a request — the axis the traffic harness reports
/// latency percentiles and SLO gates on. `Embed` is the memory-bound
/// gather path, `Similar` the GEMM-bound scoring path; a single p99 over
/// the mix would let the cheap class mask tail collapse in the expensive
/// one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestClass {
    Embed,
    Similar,
}

impl RequestClass {
    /// Every class, in `index` order.
    pub const ALL: [RequestClass; 2] = [RequestClass::Embed, RequestClass::Similar];

    /// Dense index for per-class counter arrays.
    pub fn index(self) -> usize {
        match self {
            RequestClass::Embed => 0,
            RequestClass::Similar => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RequestClass::Embed => "embed",
            RequestClass::Similar => "similar",
        }
    }
}

/// A response.
#[derive(Clone, Debug)]
pub enum Response {
    Embeddings(Matrix),
    /// Per query: (node id, score), best first.
    Similar(Vec<Vec<(u32, f32)>>),
}

/// Order-independent 64-bit digest of a response's exact bit content
/// (FNV-1a over the structure; `f32` scores hashed by bit pattern).
/// Replaying one trace under two batch-formation policies must produce
/// equal digests per request — the parity contract `tests/properties.rs`
/// and `benches/traffic_slo.rs` assert.
pub fn response_digest(resp: &Response) -> u64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    match resp {
        Response::Embeddings(m) => {
            eat(b"E");
            eat(&(m.rows as u64).to_le_bytes());
            eat(&(m.cols as u64).to_le_bytes());
            for v in &m.data {
                eat(&v.to_bits().to_le_bytes());
            }
        }
        Response::Similar(lists) => {
            eat(b"S");
            eat(&(lists.len() as u64).to_le_bytes());
            for list in lists {
                eat(&(list.len() as u64).to_le_bytes());
                for &(id, score) in list {
                    eat(&id.to_le_bytes());
                    eat(&score.to_bits().to_le_bytes());
                }
            }
        }
    }
    h
}

/// The single-copy reference serving table.
pub struct EmbeddingServer {
    pub embeddings: Matrix,
}

impl EmbeddingServer {
    pub fn new(embeddings: Matrix) -> Self {
        EmbeddingServer { embeddings }
    }

    pub fn dim(&self) -> usize {
        self.embeddings.cols
    }

    /// Answer one request.
    pub fn handle(&self, req: &Request, backend: &dyn Backend) -> Result<Response> {
        match req {
            Request::Embed(ids) => {
                let idx: Vec<usize> = ids.iter().map(|&v| v as usize).collect();
                Ok(Response::Embeddings(self.embeddings.gather_rows(&idx)))
            }
            Request::Similar { ids, k } => {
                // scores = table @ queriesᵀ  (N × B) through the backend
                let idx: Vec<usize> = ids.iter().map(|&v| v as usize).collect();
                let queries = self.embeddings.gather_rows(&idx); // B × d
                let qt = queries.transpose(); // d × B
                let scores = backend.gemm(&self.embeddings, &qt)?;
                let mut out = Vec::with_capacity(ids.len());
                for (b, &qid) in ids.iter().enumerate() {
                    let mut ranked: Vec<(u32, f32)> = (0..scores.rows)
                        .filter(|&r| r as u32 != qid)
                        .map(|r| (r as u32, scores.get(r, b)))
                        .collect();
                    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                    ranked.truncate(*k);
                    out.push(ranked);
                }
                Ok(Response::Similar(out))
            }
        }
    }
}

/// The canonical synthetic serving workload shared by `deal serve`, the
/// `serving_throughput` bench, and the serving example: a 3:1 mix of
/// `Embed` (32 ids) and `Similar` (4 ids, k = 10) over `n` nodes;
/// `similar_only` keeps just the GEMM-bound requests.
pub fn synthetic_workload(
    rng: &mut crate::util::rng::Rng,
    n: usize,
    count: usize,
    similar_only: bool,
) -> Vec<Request> {
    (0..count)
        .map(|i| {
            if similar_only || i % 4 == 0 {
                Request::Similar {
                    ids: (0..4).map(|_| rng.next_below(n) as u32).collect(),
                    k: 10,
                }
            } else {
                Request::Embed((0..32).map(|_| rng.next_below(n) as u32).collect())
            }
        })
        .collect()
}

/// Serving statistics.
#[derive(Debug)]
pub struct ServeStats {
    pub requests: usize,
    pub latency: Summary,
    /// Requests per second over the whole workload.
    pub throughput: f64,
}

/// Run a request workload sequentially (one serving thread), collecting
/// per-request latency and overall throughput — the baseline path.
pub fn serve_workload(
    server: &EmbeddingServer,
    requests: &[Request],
    backend: &dyn Backend,
) -> Result<ServeStats> {
    let mut latencies = Vec::with_capacity(requests.len());
    let t0 = Instant::now();
    for req in requests {
        let r0 = Instant::now();
        let _resp = server.handle(req, backend)?;
        latencies.push(r0.elapsed().as_secs_f64());
    }
    let total = t0.elapsed().as_secs_f64();
    Ok(ServeStats {
        requests: requests.len(),
        latency: Summary::of(&latencies).expect("no requests"),
        throughput: requests.len() as f64 / total.max(1e-12),
    })
}

/// Submit a whole workload to a pool (admission-controlled), wait for
/// every accepted response, and fold the outcome into [`ServeStats`] plus
/// the responses (accepted requests only, in submission order).
pub fn serve_workload_pooled(
    pool: &ServePool,
    requests: &[Request],
) -> Result<(Vec<Response>, ServeStats)> {
    let mark = pool.mark();
    let t0 = Instant::now();
    let tickets: Vec<Option<Ticket>> =
        requests.iter().map(|r| pool.submit(r.clone()).ok()).collect();
    let mut responses = Vec::with_capacity(requests.len());
    for t in tickets.into_iter().flatten() {
        responses.push(t.wait()?);
    }
    let total = t0.elapsed().as_secs_f64();
    // only this workload's counters, even on a reused pool
    let stats = pool.stats_since(&mark);
    anyhow::ensure!(stats.served > 0, "no requests completed");
    // The latency reservoir is a uniform sample of the pool's lifetime:
    // on a long-lived pool a small post-mark window can retain zero
    // samples. That is sampling thinness, not failure — fall back to the
    // lifetime summary rather than erroring on a served workload.
    let latency = match stats.latency {
        Some(l) => l,
        None => pool
            .stats()
            .latency
            .ok_or_else(|| anyhow::anyhow!("no requests completed"))?,
    };
    Ok((
        responses,
        ServeStats {
            requests: stats.served as usize,
            latency,
            throughput: stats.served as f64 / total.max(1e-12),
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Native;
    use crate::util::rng::Rng;

    fn server() -> EmbeddingServer {
        let mut rng = Rng::new(5);
        EmbeddingServer::new(Matrix::random(20, 8, 1.0, &mut rng))
    }

    #[test]
    fn embed_fetches_rows() {
        let s = server();
        let resp = s.handle(&Request::Embed(vec![3, 7]), &Native).unwrap();
        match resp {
            Response::Embeddings(m) => {
                assert_eq!(m.rows, 2);
                assert_eq!(m.row(0), s.embeddings.row(3));
            }
            _ => panic!("wrong response"),
        }
    }

    #[test]
    fn similar_excludes_self_and_ranks() {
        let s = server();
        let resp = s
            .handle(&Request::Similar { ids: vec![0, 5], k: 3 }, &Native)
            .unwrap();
        match resp {
            Response::Similar(lists) => {
                assert_eq!(lists.len(), 2);
                for (q, list) in lists.iter().enumerate() {
                    let qid = [0u32, 5][q];
                    assert_eq!(list.len(), 3);
                    assert!(list.iter().all(|&(id, _)| id != qid));
                    for w in list.windows(2) {
                        assert!(w[0].1 >= w[1].1, "not sorted");
                    }
                }
            }
            _ => panic!("wrong response"),
        }
    }

    #[test]
    fn workload_stats() {
        let s = server();
        let reqs = vec![
            Request::Embed(vec![1]),
            Request::Similar { ids: vec![2], k: 2 },
            Request::Embed(vec![0, 1, 2]),
        ];
        let stats = serve_workload(&s, &reqs, &Native).unwrap();
        assert_eq!(stats.requests, 3);
        assert!(stats.throughput > 0.0);
        assert!(stats.latency.p99 >= stats.latency.p50);
    }

    #[test]
    fn pooled_workload_matches_request_count() {
        use std::sync::Arc;
        let s = server();
        let cell = Arc::new(TableCell::new(ShardedTable::from_full(&s.embeddings, 2, 0)));
        let pool = ServePool::spawn(cell, Arc::new(Native), PoolOpts::default());
        let reqs = vec![
            Request::Embed(vec![1]),
            Request::Similar { ids: vec![2], k: 2 },
            Request::Embed(vec![0, 1, 2]),
        ];
        let (responses, stats) = serve_workload_pooled(&pool, &reqs).unwrap();
        assert_eq!(responses.len(), 3);
        assert_eq!(stats.requests, 3);
        assert!(stats.throughput > 0.0);
        // a reused pool attributes only the new workload, not the lifetime
        let (r2, s2) = serve_workload_pooled(&pool, &reqs).unwrap();
        assert_eq!(r2.len(), 3);
        assert_eq!(s2.requests, 3);
    }
}
