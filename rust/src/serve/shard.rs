//! 1-D sharded embedding table (DESIGN.md §Serving).
//!
//! The refreshed `N × d` all-node embedding matrix is split into `S`
//! contiguous row shards with the *same* row bounds as the inference
//! partition plan (`PartitionPlan::serving`), so the machine that computed
//! a node's embedding is the machine that serves it — no re-layout between
//! the inference tier and the serving tier. A [`ShardedTable`] is an
//! immutable epoch snapshot: refresh publishes a whole new table and the
//! worker pool pins the old one per batch (`refresh::TableCell`).
//!
//! **Spill mode** (DESIGN.md §Out-of-core-storage): a table built with
//! [`ShardedTable::from_full_spilled`] /
//! [`ShardedTable::from_inference_plan_spilled`] stages its shards on the
//! paged storage tier behind one budgeted [`SharedPageCache`] instead of
//! holding them resident. Epoch refresh then double-buffers **on disk**:
//! while the old epoch keeps serving from RAM (or its own cache), the
//! incoming epoch costs at most `budget` resident bytes instead of a full
//! second table. Reads fault pages in on demand — gathered values are
//! bit-identical to the resident table's; only fault counts and spill
//! traffic change. Delta patches promote a touched spilled shard to a
//! resident copy (copy-on-write, untouched shards stay shared).

use std::sync::Arc;

use crate::cluster::metrics::StorageCounters;
use crate::coordinator::SimFs;
use crate::partition::PartitionPlan;
use crate::storage::{self, PagedMatrix, SharedPageCache};
use crate::tensor::Matrix;
use crate::Result;

/// One shard's backing: resident RAM or the paged spill tier.
#[derive(Clone, Debug)]
enum ShardData {
    Ram(Arc<Matrix>),
    Spilled(Arc<SpilledShard>),
}

/// A shard staged on the paged tier; all of a table's spilled shards
/// share one budgeted cache (and one simulated spill device).
pub struct SpilledShard {
    store: PagedMatrix,
    cache: SharedPageCache,
}

impl SpilledShard {
    fn copy_row(&self, r: usize, out: &mut [f32]) -> Result<()> {
        self.cache.with(|c| self.store.row_copy(c, r, out))
    }

    fn to_matrix(&self) -> Result<Matrix> {
        self.cache.with(|c| self.store.to_matrix(c))
    }
}

impl std::fmt::Debug for SpilledShard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SpilledShard {{ rows: {}, cols: {} }}", self.store.rows, self.store.cols)
    }
}

/// One immutable epoch of the serving table, row-sharded `S` ways.
#[derive(Clone, Debug)]
pub struct ShardedTable {
    /// Serving layout: `p` row shards, one feature part (see
    /// `PartitionPlan::serving`).
    pub plan: PartitionPlan,
    /// `plan.p` row blocks; shard `s` holds rows `plan.node_range(s)`.
    /// `Arc`-held so a delta epoch (`patched`) shares untouched shards
    /// with its predecessor and copies only the shards it writes.
    shards: Vec<ShardData>,
    /// Refresh epoch this table was published at (0 = initial load).
    epoch: u64,
}

impl ShardedTable {
    /// Shard a full `N × d` matrix `s` ways (contiguous, balanced rows).
    pub fn from_full(full: &Matrix, shards: usize, epoch: u64) -> ShardedTable {
        assert!(shards >= 1 && shards <= full.rows.max(1), "bad shard count {}", shards);
        let plan = PartitionPlan::new(full.rows, full.cols.max(1), shards, 1);
        let blocks = (0..shards)
            .map(|s| {
                let (lo, hi) = plan.node_range(s);
                ShardData::Ram(Arc::new(full.slice_rows(lo, hi)))
            })
            .collect();
        ShardedTable { plan, shards: blocks, epoch }
    }

    /// Assemble a table directly from per-part row bands under `plan` —
    /// the elastic-membership handoff path (`cluster::membership`), where
    /// the bands already live on their owning ranks and concatenating
    /// them into a full matrix first would defeat incremental migration.
    /// `plan` must be serving-shaped (`m == 1`) and `bands[s]` must be
    /// exactly `plan.node_range(s)` rows.
    pub fn from_bands(plan: PartitionPlan, bands: Vec<Matrix>, epoch: u64) -> Result<ShardedTable> {
        anyhow::ensure!(plan.m == 1, "serving tables have one feature part, got {}", plan.m);
        anyhow::ensure!(
            bands.len() == plan.p,
            "{} bands for a {}-part plan",
            bands.len(),
            plan.p
        );
        let dim = bands.first().map(|b| b.cols).unwrap_or(0);
        let blocks = bands
            .into_iter()
            .enumerate()
            .map(|(s, band)| {
                let (lo, hi) = plan.node_range(s);
                anyhow::ensure!(
                    band.rows == hi - lo,
                    "band {} has {} rows, plan wants {}",
                    s,
                    band.rows,
                    hi - lo
                );
                anyhow::ensure!(
                    band.cols == dim,
                    "band {} is {} wide, others are {}",
                    s,
                    band.cols,
                    dim
                );
                Ok(ShardData::Ram(Arc::new(band)))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardedTable { plan, shards: blocks, epoch })
    }

    /// Shard a full matrix with the row ownership of an *inference* plan,
    /// so serving layout matches inference layout (the paper's daily
    /// refresh hands each inference partition's rows to the same serving
    /// shard).
    pub fn from_inference_plan(plan: &PartitionPlan, full: &Matrix, epoch: u64) -> ShardedTable {
        assert_eq!(full.rows, plan.n_nodes, "embedding rows != plan nodes");
        let serving = plan.serving(full.cols);
        let blocks = (0..serving.p)
            .map(|s| {
                let (lo, hi) = serving.node_range(s);
                ShardData::Ram(Arc::new(full.slice_rows(lo, hi)))
            })
            .collect();
        ShardedTable { plan: serving, shards: blocks, epoch }
    }

    /// Stage the shards of `serving_plan`'s layout on the paged tier
    /// under one `budget_bytes` cache (page granularity from the ambient
    /// `storage::page_rows` chain).
    fn spill_blocks(
        serving: PartitionPlan,
        full: &Matrix,
        epoch: u64,
        budget_bytes: u64,
    ) -> Result<ShardedTable> {
        let cache = SharedPageCache::new(budget_bytes);
        let fs = SimFs::new(storage::DEFAULT_SPILL_GBPS);
        let page_rows = storage::page_rows();
        let mut blocks = Vec::with_capacity(serving.p);
        for s in 0..serving.p {
            let (lo, hi) = serving.node_range(s);
            let block = full.slice_rows(lo, hi);
            let store = cache.with(|c| {
                PagedMatrix::from_matrix(
                    c,
                    &format!("serve-e{}-s{}", epoch, s),
                    &block,
                    page_rows,
                    Arc::clone(&fs),
                )
            })?;
            blocks.push(ShardData::Spilled(Arc::new(SpilledShard {
                store,
                cache: cache.clone(),
            })));
        }
        Ok(ShardedTable { plan: serving, shards: blocks, epoch })
    }

    /// [`ShardedTable::from_full`], spilled to the paged tier under a
    /// `budget_bytes` cache.
    pub fn from_full_spilled(
        full: &Matrix,
        shards: usize,
        epoch: u64,
        budget_bytes: u64,
    ) -> Result<ShardedTable> {
        assert!(shards >= 1 && shards <= full.rows.max(1), "bad shard count {}", shards);
        let plan = PartitionPlan::new(full.rows, full.cols.max(1), shards, 1);
        Self::spill_blocks(plan, full, epoch, budget_bytes)
    }

    /// [`ShardedTable::from_inference_plan`], spilled to the paged tier —
    /// the disk-side half of the double-buffered refresh.
    pub fn from_inference_plan_spilled(
        plan: &PartitionPlan,
        full: &Matrix,
        epoch: u64,
        budget_bytes: u64,
    ) -> Result<ShardedTable> {
        assert_eq!(full.rows, plan.n_nodes, "embedding rows != plan nodes");
        Self::spill_blocks(plan.serving(full.cols), full, epoch, budget_bytes)
    }

    pub fn n_nodes(&self) -> usize {
        self.plan.n_nodes
    }

    pub fn dim(&self) -> usize {
        match self.shards.first() {
            Some(ShardData::Ram(m)) => m.cols,
            Some(ShardData::Spilled(sp)) => sp.store.cols,
            None => 0,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Stamp the epoch (used by `TableCell::publish`).
    pub(crate) fn set_epoch(&mut self, epoch: u64) {
        self.epoch = epoch;
    }

    /// True if any shard lives on the paged spill tier.
    pub fn is_spilled(&self) -> bool {
        self.shards.iter().any(|s| matches!(s, ShardData::Spilled(_)))
    }

    /// Shard `s`'s resident row block. Panics for a spilled shard — use
    /// [`ShardedTable::shard_dense`] when the table may be in spill mode.
    pub fn shard(&self, s: usize) -> &Matrix {
        match &self.shards[s] {
            ShardData::Ram(m) => m.as_ref(),
            ShardData::Spilled(_) => {
                panic!("shard {} is spilled; use shard_dense for paged tables", s)
            }
        }
    }

    /// Shard `s` as a resident matrix: RAM shards hand out their `Arc`,
    /// spilled shards materialize through the cache (faulting pages,
    /// counted in [`ShardedTable::storage_counters`]). Materialization is
    /// deliberately **per call, not cached**: pinning a dense copy would
    /// silently hold the whole shard resident and defeat the budget.
    /// Spill mode trades Similar-batch GEMM cost (full-shard fault sweep
    /// + a transient dense copy per batch) for bounded refresh RAM —
    /// Similar-heavy deployments should serve from resident tables.
    pub fn shard_dense(&self, s: usize) -> Arc<Matrix> {
        match &self.shards[s] {
            ShardData::Ram(m) => Arc::clone(m),
            ShardData::Spilled(sp) => {
                Arc::new(sp.to_matrix().expect("spilled shard materialization failed"))
            }
        }
    }

    /// Global row range `[lo, hi)` held by shard `s`.
    pub fn shard_range(&self, s: usize) -> (usize, usize) {
        self.plan.node_range(s)
    }

    /// Embedding of global node `v` (panics if out of range). Only valid
    /// for resident shards — spill-mode callers go through
    /// [`ShardedTable::try_gather`] / [`ShardedTable::copy_row_into`].
    pub fn row(&self, v: u32) -> &[f32] {
        let s = self.plan.node_owner(v);
        let (lo, _) = self.plan.node_range(s);
        match &self.shards[s] {
            ShardData::Ram(m) => m.row(v as usize - lo),
            ShardData::Spilled(_) => {
                panic!("node {}'s shard is spilled; use copy_row_into/try_gather", v)
            }
        }
    }

    /// Copy node `v`'s embedding into `out`, faulting its page in when
    /// the owning shard is spilled.
    pub fn copy_row_into(&self, v: u32, out: &mut [f32]) -> Result<()> {
        anyhow::ensure!(
            (v as usize) < self.n_nodes(),
            "node id {} out of range (table has {} nodes)",
            v,
            self.n_nodes()
        );
        let s = self.plan.node_owner(v);
        let (lo, _) = self.plan.node_range(s);
        match &self.shards[s] {
            ShardData::Ram(m) => {
                out.copy_from_slice(m.row(v as usize - lo));
                Ok(())
            }
            ShardData::Spilled(sp) => sp.copy_row(v as usize - lo, out),
        }
    }

    /// Gather rows by global node id, routing each id to its owning shard.
    /// Errors (rather than panicking a worker) on out-of-range ids.
    pub fn try_gather(&self, ids: &[u32]) -> Result<Matrix> {
        let mut out = Matrix::zeros(ids.len(), self.dim());
        for (i, &v) in ids.iter().enumerate() {
            self.copy_row_into(v, out.row_mut(i))?;
        }
        Ok(out)
    }

    /// A copy of this table with the named rows replaced — the delta-epoch
    /// publish path (`refresh::refresh_delta`): instead of rebuilding the
    /// whole table from a full recompute, only the rows an update batch
    /// affected are patched into the next double-buffered epoch. Shards
    /// are copy-on-write: untouched shards are shared with this table, so
    /// the patch costs O(touched shards), not O(N); a touched *spilled*
    /// shard is promoted to a resident copy first. `values` holds one
    /// row per id, in order. The receiver keeps this table's epoch stamp;
    /// `TableCell::publish` re-stamps on swap.
    pub fn patched(&self, ids: &[u32], values: &Matrix) -> Result<ShardedTable> {
        anyhow::ensure!(
            ids.len() == values.rows,
            "{} ids for {} value rows",
            ids.len(),
            values.rows
        );
        anyhow::ensure!(
            values.cols == self.dim() || ids.is_empty(),
            "patch width {} != table dim {}",
            values.cols,
            self.dim()
        );
        let mut next = self.clone();
        for (i, &v) in ids.iter().enumerate() {
            anyhow::ensure!(
                (v as usize) < self.n_nodes(),
                "patch row {} out of range ({} nodes)",
                v,
                self.n_nodes()
            );
            let s = next.plan.node_owner(v);
            let (lo, _) = next.plan.node_range(s);
            if let ShardData::Spilled(sp) = &next.shards[s] {
                // promote: the patched epoch's touched shard is resident
                next.shards[s] = ShardData::Ram(Arc::new(sp.to_matrix()?));
            }
            match &mut next.shards[s] {
                ShardData::Ram(m) => Arc::make_mut(m)
                    .row_mut(v as usize - lo)
                    .copy_from_slice(values.row(i)),
                ShardData::Spilled(_) => unreachable!("promoted above"),
            }
        }
        Ok(next)
    }

    /// Reassemble the full matrix (tests / debugging).
    pub fn to_full(&self) -> Matrix {
        let dense: Vec<Arc<Matrix>> = (0..self.num_shards()).map(|s| self.shard_dense(s)).collect();
        let refs: Vec<&Matrix> = dense.iter().map(|m| m.as_ref()).collect();
        Matrix::vcat(&refs)
    }

    /// Total bytes across shards (capacity accounting: data bytes,
    /// wherever they live).
    pub fn nbytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| match s {
                ShardData::Ram(m) => m.nbytes(),
                ShardData::Spilled(sp) => sp.store.nbytes(),
            })
            .sum()
    }

    /// Bytes actually resident in RAM: full blocks for RAM shards plus
    /// the (shared) cache residency of the spilled ones.
    pub fn resident_bytes(&self) -> u64 {
        let mut ram = 0u64;
        let mut cache_seen = false;
        let mut cached = 0u64;
        for s in &self.shards {
            match s {
                ShardData::Ram(m) => ram += m.nbytes(),
                ShardData::Spilled(sp) => {
                    // all spilled shards of a table share one cache —
                    // count it once
                    if !cache_seen {
                        cached = sp.cache.with(|c| c.used_bytes());
                        cache_seen = true;
                    }
                }
            }
        }
        ram + cached
    }

    /// Storage counters of the spill tier (zeros for a fully resident
    /// table).
    pub fn storage_counters(&self) -> StorageCounters {
        for s in &self.shards {
            if let ShardData::Spilled(sp) = s {
                return sp.cache.with(|c| c.stats().clone());
            }
        }
        StorageCounters::default()
    }

    /// True when shard `s` of both tables is the same shared block (the
    /// copy-on-write check used by the delta tests).
    pub fn shares_shard_with(&self, other: &ShardedTable, s: usize) -> bool {
        match (&self.shards[s], &other.shards[s]) {
            (ShardData::Ram(a), ShardData::Ram(b)) => Arc::ptr_eq(a, b),
            (ShardData::Spilled(a), ShardData::Spilled(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn table(n: usize, d: usize, s: usize) -> (Matrix, ShardedTable) {
        let mut rng = Rng::new(42);
        let full = Matrix::random(n, d, 1.0, &mut rng);
        let t = ShardedTable::from_full(&full, s, 3);
        (full, t)
    }

    #[test]
    fn shards_cover_and_roundtrip() {
        let (full, t) = table(103, 7, 4);
        assert_eq!(t.num_shards(), 4);
        assert_eq!(t.n_nodes(), 103);
        assert_eq!(t.dim(), 7);
        assert_eq!(t.epoch(), 3);
        assert_eq!(t.to_full(), full);
        assert!(!t.is_spilled());
        let mut covered = 0;
        for s in 0..4 {
            let (lo, hi) = t.shard_range(s);
            assert_eq!(t.shard(s).rows, hi - lo);
            covered += hi - lo;
        }
        assert_eq!(covered, 103);
    }

    #[test]
    fn row_and_gather_match_full() {
        let (full, t) = table(50, 5, 3);
        for v in [0u32, 16, 17, 33, 49] {
            assert_eq!(t.row(v), full.row(v as usize));
        }
        let got = t.try_gather(&[49, 0, 25]).unwrap();
        assert_eq!(got.row(0), full.row(49));
        assert_eq!(got.row(1), full.row(0));
        assert_eq!(got.row(2), full.row(25));
    }

    #[test]
    fn gather_rejects_out_of_range() {
        let (_, t) = table(10, 3, 2);
        assert!(t.try_gather(&[9]).is_ok());
        assert!(t.try_gather(&[10]).is_err());
    }

    #[test]
    fn patched_replaces_only_named_rows() {
        let (full, t) = table(30, 4, 3);
        let patch = Matrix::from_vec(2, 4, vec![9.0; 8]);
        let p = t.patched(&[3, 27], &patch).unwrap();
        assert_eq!(p.row(3), patch.row(0));
        assert_eq!(p.row(27), patch.row(1));
        for v in 0..30u32 {
            if v != 3 && v != 27 {
                assert_eq!(p.row(v), full.row(v as usize), "row {} changed", v);
            }
        }
        // the source table is untouched (double buffering)
        assert_eq!(t.to_full(), full);
        // copy-on-write: only the shards that were written got copied
        for s in 0..t.num_shards() {
            let (lo, hi) = t.shard_range(s);
            let touched = (lo..hi).contains(&3) || (lo..hi).contains(&27);
            assert_eq!(
                t.shares_shard_with(&p, s),
                !touched,
                "shard {} sharing is wrong",
                s
            );
        }
        // arity and range errors
        assert!(t.patched(&[0], &Matrix::zeros(2, 4)).is_err());
        assert!(t.patched(&[30], &Matrix::zeros(1, 4)).is_err());
        assert!(t.patched(&[0], &Matrix::zeros(1, 3)).is_err());
        let empty = t.patched(&[], &Matrix::zeros(0, 0)).unwrap();
        assert_eq!(empty.to_full(), full);
    }

    #[test]
    fn inference_plan_layout_is_reused() {
        let plan = PartitionPlan::new(64, 16, 4, 2);
        let mut rng = Rng::new(1);
        // embedding width differs from input feature width after the GNN
        let emb = Matrix::random(64, 6, 1.0, &mut rng);
        let t = ShardedTable::from_inference_plan(&plan, &emb, 1);
        assert_eq!(t.num_shards(), plan.p);
        for s in 0..plan.p {
            assert_eq!(t.shard_range(s), plan.node_range(s));
        }
    }

    #[test]
    fn spilled_table_serves_identically() {
        let mut rng = Rng::new(21);
        let full = Matrix::random(96, 6, 1.0, &mut rng);
        // budget of ~two pages at 8-row granularity → constant eviction
        let t = crate::storage::with_page_rows(8, || {
            ShardedTable::from_full_spilled(&full, 3, 1, 2 * 8 * 6 * 4).unwrap()
        });
        assert!(t.is_spilled());
        assert_eq!(t.dim(), 6);
        assert_eq!(t.nbytes(), full.nbytes());
        assert!(t.resident_bytes() < full.nbytes(), "budget bounds residency");
        // gathers are bit-identical to the resident table
        let ids: Vec<u32> = vec![95, 0, 12, 12, 63, 31];
        let got = t.try_gather(&ids).unwrap();
        let idx: Vec<usize> = ids.iter().map(|&v| v as usize).collect();
        assert_eq!(got, full.gather_rows(&idx));
        assert_eq!(t.to_full(), full);
        let counters = t.storage_counters();
        assert!(counters.page_faults > 0, "cold reads must fault");
        assert!(counters.evictions > 0, "tiny budget must evict");
        assert!(counters.spill_bytes_written >= full.nbytes(), "staging spilled the table");
        // row() is the resident-only fast path
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = t.row(0);
        }));
        assert!(r.is_err(), "row() must refuse spilled shards");
    }

    #[test]
    fn spilled_patch_promotes_touched_shard_only() {
        let mut rng = Rng::new(22);
        let full = Matrix::random(40, 4, 1.0, &mut rng);
        let t = ShardedTable::from_full_spilled(&full, 4, 0, 0).unwrap();
        let patch = Matrix::from_vec(1, 4, vec![5.0; 4]);
        let p = t.patched(&[2], &patch).unwrap();
        assert_eq!(p.try_gather(&[2]).unwrap().row(0), patch.row(0));
        // untouched spilled shards stay shared; the touched one promoted
        for s in 0..4 {
            let (lo, hi) = t.shard_range(s);
            let touched = (lo..hi).contains(&2);
            assert_eq!(t.shares_shard_with(&p, s), !touched, "shard {}", s);
        }
        // source table unchanged
        assert_eq!(t.to_full(), full);
        let mut expect = full.clone();
        expect.row_mut(2).copy_from_slice(patch.row(0));
        assert_eq!(p.to_full(), expect);
    }
}
