//! 1-D sharded embedding table (DESIGN.md §Serving).
//!
//! The refreshed `N × d` all-node embedding matrix is split into `S`
//! contiguous row shards with the *same* row bounds as the inference
//! partition plan (`PartitionPlan::serving`), so the machine that computed
//! a node's embedding is the machine that serves it — no re-layout between
//! the inference tier and the serving tier. A [`ShardedTable`] is an
//! immutable epoch snapshot: refresh publishes a whole new table and the
//! worker pool pins the old one per batch (`refresh::TableCell`).

use std::sync::Arc;

use crate::partition::PartitionPlan;
use crate::tensor::Matrix;
use crate::Result;

/// One immutable epoch of the serving table, row-sharded `S` ways.
#[derive(Clone, Debug)]
pub struct ShardedTable {
    /// Serving layout: `p` row shards, one feature part (see
    /// `PartitionPlan::serving`).
    pub plan: PartitionPlan,
    /// `plan.p` row blocks; shard `s` holds rows `plan.node_range(s)`.
    /// `Arc`-held so a delta epoch (`patched`) shares untouched shards
    /// with its predecessor and copies only the shards it writes.
    shards: Vec<Arc<Matrix>>,
    /// Refresh epoch this table was published at (0 = initial load).
    epoch: u64,
}

impl ShardedTable {
    /// Shard a full `N × d` matrix `s` ways (contiguous, balanced rows).
    pub fn from_full(full: &Matrix, shards: usize, epoch: u64) -> ShardedTable {
        assert!(shards >= 1 && shards <= full.rows.max(1), "bad shard count {}", shards);
        let plan = PartitionPlan::new(full.rows, full.cols.max(1), shards, 1);
        let blocks = (0..shards)
            .map(|s| {
                let (lo, hi) = plan.node_range(s);
                Arc::new(full.slice_rows(lo, hi))
            })
            .collect();
        ShardedTable { plan, shards: blocks, epoch }
    }

    /// Shard a full matrix with the row ownership of an *inference* plan,
    /// so serving layout matches inference layout (the paper's daily
    /// refresh hands each inference partition's rows to the same serving
    /// shard).
    pub fn from_inference_plan(plan: &PartitionPlan, full: &Matrix, epoch: u64) -> ShardedTable {
        assert_eq!(full.rows, plan.n_nodes, "embedding rows != plan nodes");
        let serving = plan.serving(full.cols);
        let blocks = (0..serving.p)
            .map(|s| {
                let (lo, hi) = serving.node_range(s);
                Arc::new(full.slice_rows(lo, hi))
            })
            .collect();
        ShardedTable { plan: serving, shards: blocks, epoch }
    }

    pub fn n_nodes(&self) -> usize {
        self.plan.n_nodes
    }

    pub fn dim(&self) -> usize {
        if let Some(s) = self.shards.first() {
            s.cols
        } else {
            0
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Stamp the epoch (used by `TableCell::publish`).
    pub(crate) fn set_epoch(&mut self, epoch: u64) {
        self.epoch = epoch;
    }

    /// Shard `s`'s row block.
    pub fn shard(&self, s: usize) -> &Matrix {
        self.shards[s].as_ref()
    }

    /// Global row range `[lo, hi)` held by shard `s`.
    pub fn shard_range(&self, s: usize) -> (usize, usize) {
        self.plan.node_range(s)
    }

    /// Embedding of global node `v` (panics if out of range).
    pub fn row(&self, v: u32) -> &[f32] {
        let s = self.plan.node_owner(v);
        let (lo, _) = self.plan.node_range(s);
        self.shards[s].row(v as usize - lo)
    }

    /// Gather rows by global node id, routing each id to its owning shard.
    /// Errors (rather than panicking a worker) on out-of-range ids.
    pub fn try_gather(&self, ids: &[u32]) -> Result<Matrix> {
        let mut out = Matrix::zeros(ids.len(), self.dim());
        for (i, &v) in ids.iter().enumerate() {
            anyhow::ensure!(
                (v as usize) < self.n_nodes(),
                "node id {} out of range (table has {} nodes)",
                v,
                self.n_nodes()
            );
            out.row_mut(i).copy_from_slice(self.row(v));
        }
        Ok(out)
    }

    /// A copy of this table with the named rows replaced — the delta-epoch
    /// publish path (`refresh::refresh_delta`): instead of rebuilding the
    /// whole table from a full recompute, only the rows an update batch
    /// affected are patched into the next double-buffered epoch. Shards
    /// are copy-on-write: untouched shards are shared with this table, so
    /// the patch costs O(touched shards), not O(N). `values` holds one
    /// row per id, in order. The receiver keeps this table's epoch stamp;
    /// `TableCell::publish` re-stamps on swap.
    pub fn patched(&self, ids: &[u32], values: &Matrix) -> Result<ShardedTable> {
        anyhow::ensure!(
            ids.len() == values.rows,
            "{} ids for {} value rows",
            ids.len(),
            values.rows
        );
        anyhow::ensure!(
            values.cols == self.dim() || ids.is_empty(),
            "patch width {} != table dim {}",
            values.cols,
            self.dim()
        );
        let mut next = self.clone();
        for (i, &v) in ids.iter().enumerate() {
            anyhow::ensure!(
                (v as usize) < self.n_nodes(),
                "patch row {} out of range ({} nodes)",
                v,
                self.n_nodes()
            );
            let s = next.plan.node_owner(v);
            let (lo, _) = next.plan.node_range(s);
            Arc::make_mut(&mut next.shards[s])
                .row_mut(v as usize - lo)
                .copy_from_slice(values.row(i));
        }
        Ok(next)
    }

    /// Reassemble the full matrix (tests / debugging).
    pub fn to_full(&self) -> Matrix {
        let refs: Vec<&Matrix> = self.shards.iter().map(|s| s.as_ref()).collect();
        Matrix::vcat(&refs)
    }

    /// Total bytes across shards (capacity accounting).
    pub fn nbytes(&self) -> u64 {
        self.shards.iter().map(|s| s.nbytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn table(n: usize, d: usize, s: usize) -> (Matrix, ShardedTable) {
        let mut rng = Rng::new(42);
        let full = Matrix::random(n, d, 1.0, &mut rng);
        let t = ShardedTable::from_full(&full, s, 3);
        (full, t)
    }

    #[test]
    fn shards_cover_and_roundtrip() {
        let (full, t) = table(103, 7, 4);
        assert_eq!(t.num_shards(), 4);
        assert_eq!(t.n_nodes(), 103);
        assert_eq!(t.dim(), 7);
        assert_eq!(t.epoch(), 3);
        assert_eq!(t.to_full(), full);
        let mut covered = 0;
        for s in 0..4 {
            let (lo, hi) = t.shard_range(s);
            assert_eq!(t.shard(s).rows, hi - lo);
            covered += hi - lo;
        }
        assert_eq!(covered, 103);
    }

    #[test]
    fn row_and_gather_match_full() {
        let (full, t) = table(50, 5, 3);
        for v in [0u32, 16, 17, 33, 49] {
            assert_eq!(t.row(v), full.row(v as usize));
        }
        let got = t.try_gather(&[49, 0, 25]).unwrap();
        assert_eq!(got.row(0), full.row(49));
        assert_eq!(got.row(1), full.row(0));
        assert_eq!(got.row(2), full.row(25));
    }

    #[test]
    fn gather_rejects_out_of_range() {
        let (_, t) = table(10, 3, 2);
        assert!(t.try_gather(&[9]).is_ok());
        assert!(t.try_gather(&[10]).is_err());
    }

    #[test]
    fn patched_replaces_only_named_rows() {
        let (full, t) = table(30, 4, 3);
        let patch = Matrix::from_vec(2, 4, vec![9.0; 8]);
        let p = t.patched(&[3, 27], &patch).unwrap();
        assert_eq!(p.row(3), patch.row(0));
        assert_eq!(p.row(27), patch.row(1));
        for v in 0..30u32 {
            if v != 3 && v != 27 {
                assert_eq!(p.row(v), full.row(v as usize), "row {} changed", v);
            }
        }
        // the source table is untouched (double buffering)
        assert_eq!(t.to_full(), full);
        // copy-on-write: only the shards that were written got copied
        for s in 0..t.num_shards() {
            let (lo, hi) = t.shard_range(s);
            let touched = (lo..hi).contains(&3) || (lo..hi).contains(&27);
            assert_eq!(
                Arc::ptr_eq(&t.shards[s], &p.shards[s]),
                !touched,
                "shard {} sharing is wrong",
                s
            );
        }
        // arity and range errors
        assert!(t.patched(&[0], &Matrix::zeros(2, 4)).is_err());
        assert!(t.patched(&[30], &Matrix::zeros(1, 4)).is_err());
        assert!(t.patched(&[0], &Matrix::zeros(1, 3)).is_err());
        let empty = t.patched(&[], &Matrix::zeros(0, 0)).unwrap();
        assert_eq!(empty.to_full(), full);
    }

    #[test]
    fn inference_plan_layout_is_reused() {
        let plan = PartitionPlan::new(64, 16, 4, 2);
        let mut rng = Rng::new(1);
        // embedding width differs from input feature width after the GNN
        let emb = Matrix::random(64, 6, 1.0, &mut rng);
        let t = ShardedTable::from_inference_plan(&plan, &emb, 1);
        assert_eq!(t.num_shards(), plan.p);
        for s in 0..plan.p {
            assert_eq!(t.shard_range(s), plan.node_range(s));
        }
    }
}
