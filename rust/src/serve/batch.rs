//! Request batching / coalescing (DESIGN.md §Serving).
//!
//! Concurrent `Similar` queries are merged into **one GEMM per shard**:
//! the union of query ids (deduplicated, first-seen order) becomes a
//! single `d × Q` right-hand side, and each shard scores all `Q` queries
//! in one `rows_s × d @ d × Q` matmul through `runtime::Backend` — so an
//! AOT-compiled artifact sees full tiles instead of per-request slivers,
//! and the table is streamed from memory once per batch instead of once
//! per request. Top-k selection then scatter-gathers per-query results
//! back to the originating requests.
//!
//! Result contract: for every request in the batch the response is
//! identical to the sequential `EmbeddingServer::handle` path — same
//! candidate scores (the dot products are computed row-by-row either
//! way), same ordering (descending score, ties broken by ascending node
//! id, exactly what a stable descending sort over id-ordered candidates
//! produces), same self-exclusion.

use std::cmp::Ordering;

use crate::runtime::Backend;
use crate::tensor::Matrix;
use crate::Result;

use super::shard::ShardedTable;

/// Batch-formation policy: *which queued requests coalesce into one
/// batch*. Mirrors the Deal artifact's scheduler split
/// (`BaseScheduler` / `RingScheduler` / `SrcSortScheduler`): request
/// ordering/grouping is a first-class serving knob that trades latency
/// against tile fullness — while the **results stay bit-identical**
/// under every policy (the coalescing contract above), so policies can
/// be swept under one replayed trace with response parity asserted
/// (`traffic::replay`, `benches/traffic_slo.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Greedy depth-first drain (the `BaseScheduler` analogue and the
    /// historical behavior): pop one request, then take everything
    /// already queued, up to `max_batch`. Minimum latency under light
    /// load; batch depth tracks instantaneous queue depth.
    #[default]
    DepthFirst,
    /// Deadline-driven: after the first request, hold the batch open up
    /// to `max_wait_us` microseconds for stragglers (still capped by
    /// `max_batch`). Trades a bounded latency add for fuller GEMM tiles
    /// — the `RingScheduler` analogue (synchronize arrivals to fill the
    /// pipeline).
    Deadline { max_wait_us: u64 },
    /// Size-capped: close the batch once the summed *id* count reaches
    /// `max_ids` (the request that crosses the cap is included). Bounds
    /// the per-batch gather width the way `SrcSortScheduler` bounds the
    /// per-step source range, keeping worst-case batch service time flat
    /// under bursts of wide requests.
    SizeCapped { max_ids: usize },
}

impl BatchPolicy {
    /// Parse a CLI/config spelling: `depth`, `deadline` / `deadline:US`,
    /// `size` / `size:IDS`.
    pub fn parse(s: &str) -> Result<BatchPolicy> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        match name {
            "depth" | "base" => Ok(BatchPolicy::DepthFirst),
            "deadline" => Ok(BatchPolicy::Deadline {
                max_wait_us: arg.map_or(Ok(200), str::parse)?,
            }),
            "size" => Ok(BatchPolicy::SizeCapped {
                max_ids: arg.map_or(Ok(256), str::parse)?,
            }),
            other => anyhow::bail!(
                "unknown batch policy '{}' (expected depth | deadline[:us] | size[:ids])",
                other
            ),
        }
    }

    /// Short name for reports and sweep labels.
    pub fn name(&self) -> &'static str {
        match self {
            BatchPolicy::DepthFirst => "depth",
            BatchPolicy::Deadline { .. } => "deadline",
            BatchPolicy::SizeCapped { .. } => "size",
        }
    }
}

/// Ranking order shared by the sequential and batched paths: descending
/// score, ascending node id on ties.
#[inline]
fn rank_cmp(a: &(u32, f32), b: &(u32, f32)) -> Ordering {
    b.1.partial_cmp(&a.1)
        .unwrap_or(Ordering::Equal)
        .then_with(|| a.0.cmp(&b.0))
}

/// Keep the `k` best candidates under [`rank_cmp`], sorted. `O(n + k log
/// k)` via quickselect — the sequential baseline's full sort is `O(n log
/// n)`, so batched serving is cheaper even at batch size 1.
pub fn top_k(mut cands: Vec<(u32, f32)>, k: usize) -> Vec<(u32, f32)> {
    if k == 0 {
        return Vec::new();
    }
    if cands.len() > k {
        cands.select_nth_unstable_by(k - 1, rank_cmp);
        cands.truncate(k);
    }
    cands.sort_by(rank_cmp);
    cands
}

/// One coalesced `Similar` group: the queries of many requests, merged.
pub struct SimilarBatch {
    /// Deduplicated query node ids, first-seen order.
    pub qids: Vec<u32>,
    /// For each original (request, query) pair: the column in `qids`.
    cols: Vec<Vec<usize>>,
    /// Per-request `k`.
    ks: Vec<usize>,
}

impl SimilarBatch {
    /// Coalesce `(ids, k)` query lists into one deduplicated batch.
    pub fn coalesce(requests: &[(&[u32], usize)]) -> SimilarBatch {
        let mut qids: Vec<u32> = Vec::new();
        let mut col_of = std::collections::HashMap::new();
        let mut cols = Vec::with_capacity(requests.len());
        let mut ks = Vec::with_capacity(requests.len());
        for (ids, k) in requests {
            let mut req_cols = Vec::with_capacity(ids.len());
            for &v in ids.iter() {
                let c = *col_of.entry(v).or_insert_with(|| {
                    qids.push(v);
                    qids.len() - 1
                });
                req_cols.push(c);
            }
            cols.push(req_cols);
            ks.push(*k);
        }
        SimilarBatch { qids, cols, ks }
    }

    /// Number of coalesced requests.
    pub fn len(&self) -> usize {
        self.cols.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }

    /// Execute the batch: one GEMM per shard over all queries, then
    /// per-request top-k. Returns, per request, the per-query ranked
    /// `(node, score)` lists.
    pub fn execute(
        &self,
        table: &ShardedTable,
        backend: &dyn Backend,
    ) -> Result<Vec<Vec<Vec<(u32, f32)>>>> {
        if self.is_empty() {
            return Ok(Vec::new());
        }
        // Q × d query block gathered from the owning shards, then d × Q.
        let queries = table.try_gather(&self.qids)?;
        let qt = queries.transpose();
        // One full-tile GEMM per shard, shards mapped over the intra-rank
        // pool (each GEMM runs serial inside a worker — no nested fan-out).
        // `shard_dense` hands out the resident Arc for RAM shards and
        // materializes spilled shards through their budgeted cache.
        let panels: Vec<Matrix> =
            crate::runtime::par::map_indexed(table.num_shards(), |s| {
                backend.gemm(&table.shard_dense(s), &qt)
            })
            .into_iter()
            .collect::<Result<_>>()?;
        // Per-request scatter-gather: select top-k per query column.
        let k_max = self.ks.iter().copied().max().unwrap_or(0);
        let mut column_top: Vec<Option<Vec<(u32, f32)>>> = vec![None; self.qids.len()];
        let mut out = Vec::with_capacity(self.len());
        for (req_cols, &k) in self.cols.iter().zip(&self.ks) {
            let mut req_out = Vec::with_capacity(req_cols.len());
            for &c in req_cols {
                // Cache the k_max ranking per distinct query column so a
                // query repeated across coalesced requests is selected once.
                if column_top[c].is_none() {
                    let qid = self.qids[c];
                    let mut cands = Vec::with_capacity(table.n_nodes().saturating_sub(1));
                    for s in 0..table.num_shards() {
                        let (lo, _) = table.shard_range(s);
                        let panel = &panels[s];
                        for r in 0..panel.rows {
                            let v = (lo + r) as u32;
                            if v != qid {
                                cands.push((v, panel.get(r, c)));
                            }
                        }
                    }
                    column_top[c] = Some(top_k(cands, k_max));
                }
                let ranked = column_top[c].as_ref().unwrap();
                req_out.push(ranked[..k.min(ranked.len())].to_vec());
            }
            out.push(req_out);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Native;
    use crate::serve::{EmbeddingServer, Request, Response};
    use crate::util::rng::Rng;

    #[test]
    fn batch_policy_parses_spellings() {
        assert_eq!(BatchPolicy::parse("depth").unwrap(), BatchPolicy::DepthFirst);
        assert_eq!(BatchPolicy::parse("base").unwrap(), BatchPolicy::DepthFirst);
        assert_eq!(
            BatchPolicy::parse("deadline").unwrap(),
            BatchPolicy::Deadline { max_wait_us: 200 }
        );
        assert_eq!(
            BatchPolicy::parse("deadline:750").unwrap(),
            BatchPolicy::Deadline { max_wait_us: 750 }
        );
        assert_eq!(BatchPolicy::parse("size").unwrap(), BatchPolicy::SizeCapped { max_ids: 256 });
        assert_eq!(
            BatchPolicy::parse("size:64").unwrap(),
            BatchPolicy::SizeCapped { max_ids: 64 }
        );
        assert!(BatchPolicy::parse("bogus").is_err());
        assert!(BatchPolicy::parse("size:x").is_err());
        assert_eq!(BatchPolicy::default().name(), "depth");
    }

    #[test]
    fn top_k_orders_and_breaks_ties_by_id() {
        let cands = vec![(3u32, 1.0f32), (1, 2.0), (7, 2.0), (0, 0.5), (5, 2.0)];
        let got = top_k(cands, 3);
        assert_eq!(got, vec![(1, 2.0), (5, 2.0), (7, 2.0)]);
        assert_eq!(top_k(vec![(1, 1.0)], 0), vec![]);
        assert_eq!(top_k(vec![(1, 1.0)], 5), vec![(1, 1.0)]);
    }

    #[test]
    fn coalesce_dedups_queries() {
        let a: Vec<u32> = vec![4, 2, 4];
        let b: Vec<u32> = vec![2, 9];
        let batch = SimilarBatch::coalesce(&[(&a, 3), (&b, 5)]);
        assert_eq!(batch.qids, vec![4, 2, 9]);
        assert_eq!(batch.len(), 2);
        assert_eq!(batch.ks, vec![3, 5]);
        assert_eq!(batch.cols[0], vec![0, 1, 0]);
        assert_eq!(batch.cols[1], vec![1, 2]);
    }

    #[test]
    fn batched_matches_sequential_handle() {
        let mut rng = Rng::new(9);
        let full = Matrix::random(60, 8, 1.0, &mut rng);
        let server = EmbeddingServer::new(full.clone());
        let table = ShardedTable::from_full(&full, 3, 0);

        let reqs: Vec<(Vec<u32>, usize)> = vec![
            (vec![0, 5, 17], 4),
            (vec![5, 59], 7),
            (vec![30], 1),
        ];
        let views: Vec<(&[u32], usize)> =
            reqs.iter().map(|(ids, k)| (ids.as_slice(), *k)).collect();
        let batch = SimilarBatch::coalesce(&views);
        let got = batch.execute(&table, &Native).unwrap();

        for ((ids, k), got_req) in reqs.iter().zip(&got) {
            let resp = server
                .handle(&Request::Similar { ids: ids.clone(), k: *k }, &Native)
                .unwrap();
            let want = match resp {
                Response::Similar(lists) => lists,
                _ => panic!("wrong response"),
            };
            assert_eq!(got_req.len(), want.len());
            for (g, w) in got_req.iter().zip(&want) {
                let g_ids: Vec<u32> = g.iter().map(|&(v, _)| v).collect();
                let w_ids: Vec<u32> = w.iter().map(|&(v, _)| v).collect();
                assert_eq!(g_ids, w_ids);
                for (&(_, gs), &(_, ws)) in g.iter().zip(w) {
                    assert!((gs - ws).abs() <= 1e-6, "score mismatch {} vs {}", gs, ws);
                }
            }
        }
    }
}
