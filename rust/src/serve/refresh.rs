//! Double-buffered table refresh (DESIGN.md §Serving).
//!
//! The paper's deployment refreshes the all-node embedding table daily:
//! the inference tier recomputes every embedding, then the serving tier
//! must start answering from the new table **without dropping in-flight
//! traffic**. [`TableCell`] is the swap point: readers (`ServePool`
//! workers) pin an `Arc` snapshot per batch, the publisher swaps the
//! `Arc` atomically under a short write lock, and the old epoch's memory
//! is freed when its last in-flight batch finishes — classic
//! double-buffering with reference counts instead of a fixed pair of
//! buffers, so overlapping refreshes are also safe.
//!
//! [`Refresher`] drives the whole loop end to end: run the
//! `coordinator::Pipeline` (construct → partition → sample → infer),
//! shard the gathered embeddings with the inference plan's row
//! ownership, and publish.
//!
//! [`refresh_delta`] is the streaming-update counterpart: apply one
//! `UpdateBatch` to a live `coordinator::delta::DeltaState` and publish a
//! **delta epoch** — the next double-buffered table is the current one
//! with only the affected rows patched (`ShardedTable::patched`;
//! copy-on-write per shard, so untouched shards are shared, not copied) —
//! instead of recomputing and rebuilding the whole table. The same
//! `TableCell` swap point serves both: readers never observe a partial
//! patch.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::coordinator::delta::{DeltaState, UpdateBatch};
use crate::coordinator::Pipeline;
use crate::storage::DurableStore;
use crate::Result;

use super::shard::ShardedTable;

/// The atomically swappable serving table, optionally keeping a bounded
/// index of past epochs for time-travel reads (`crate::temporal`).
pub struct TableCell {
    current: RwLock<Arc<ShardedTable>>,
    epoch: AtomicU64,
    /// `Some` when built with [`TableCell::with_retention`]: the last
    /// `retain` published epochs stay pinned (oldest evicted first).
    index: Option<Mutex<EpochIndex>>,
}

/// The bounded epoch deque behind a retaining [`TableCell`].
struct EpochIndex {
    retain: usize,
    retained: VecDeque<(u64, Arc<ShardedTable>)>,
}

impl TableCell {
    /// Install an initial table; its epoch stamp becomes the cell's.
    pub fn new(table: ShardedTable) -> TableCell {
        let epoch = table.epoch();
        TableCell {
            current: RwLock::new(Arc::new(table)),
            epoch: AtomicU64::new(epoch),
            index: None,
        }
    }

    /// Like [`TableCell::new`] but every published epoch — the initial
    /// table included — is pinned in a bounded index: the cell answers
    /// [`TableCell::load_at`] for the last `retain` epochs, evicting
    /// oldest-first. `retain` must be >= 1.
    pub fn with_retention(table: ShardedTable, retain: usize) -> Result<TableCell> {
        anyhow::ensure!(retain >= 1, "retention must keep at least 1 epoch (got {})", retain);
        let epoch = table.epoch();
        let arc = Arc::new(table);
        let mut retained = VecDeque::with_capacity(retain);
        retained.push_back((epoch, Arc::clone(&arc)));
        Ok(TableCell {
            current: RwLock::new(arc),
            epoch: AtomicU64::new(epoch),
            index: Some(Mutex::new(EpochIndex { retain, retained })),
        })
    }

    /// Wrap an already-pinned snapshot without copying it (time-travel
    /// serving: `crate::temporal` pins a retained epoch here and spawns a
    /// `ServePool` over it). The cell starts at the snapshot's own epoch
    /// and shares its memory with every other holder of the `Arc`.
    pub fn pin(table: Arc<ShardedTable>) -> TableCell {
        let epoch = table.epoch();
        TableCell {
            current: RwLock::new(table),
            epoch: AtomicU64::new(epoch),
            index: None,
        }
    }

    /// Snapshot the current epoch's table. The returned `Arc` stays valid
    /// (and unchanged) across any number of concurrent `publish` calls.
    pub fn load(&self) -> Arc<ShardedTable> {
        Arc::clone(&self.current.read().unwrap())
    }

    /// Current epoch number.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Time-travel read: the exact table published at `epoch`, if this
    /// cell retains it. Fails with a cause-naming error when the epoch
    /// was evicted (or never published); callers with a durable history
    /// fall back to `storage::EpochHistory::replay_to`.
    pub fn load_at(&self, epoch: u64) -> Result<Arc<ShardedTable>> {
        if epoch == self.epoch() {
            return Ok(self.load());
        }
        let index = self
            .index
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!(
                "epoch {} requested but this cell keeps no epoch index (current epoch {})",
                epoch,
                self.epoch()
            ))?;
        let idx = index.lock().unwrap();
        idx.retained
            .iter()
            .find(|(e, _)| *e == epoch)
            .map(|(_, t)| Arc::clone(t))
            .ok_or_else(|| {
                let held: Vec<u64> = idx.retained.iter().map(|(e, _)| *e).collect();
                anyhow::anyhow!(
                    "epoch {} is not retained (retain = {}, held epochs {:?})",
                    epoch,
                    idx.retain,
                    held
                )
            })
    }

    /// Epochs currently answerable by [`TableCell::load_at`], oldest
    /// first. Empty for a cell without an index.
    pub fn retained_epochs(&self) -> Vec<u64> {
        match &self.index {
            Some(index) => index.lock().unwrap().retained.iter().map(|(e, _)| *e).collect(),
            None => Vec::new(),
        }
    }

    /// Publish `table` as the next epoch and return its epoch number.
    /// In-flight readers keep their snapshot; new loads see the new table.
    /// On a retaining cell the new epoch is pinned into the index (and
    /// the oldest evicted once past the retention bound).
    pub fn publish(&self, mut table: ShardedTable) -> u64 {
        let mut slot = self.current.write().unwrap();
        let next = self.epoch.load(Ordering::Acquire) + 1;
        table.set_epoch(next);
        let arc = Arc::new(table);
        if let Some(index) = &self.index {
            let mut idx = index.lock().unwrap();
            idx.retained.push_back((next, Arc::clone(&arc)));
            while idx.retained.len() > idx.retain {
                idx.retained.pop_front();
            }
        }
        *slot = arc;
        self.epoch.store(next, Ordering::Release);
        next
    }

    /// A membership-transition publish (`cluster::membership`): identical
    /// swap discipline to [`TableCell::publish`], but validated — the
    /// incoming table must cover the same node set at the same width,
    /// because re-sharding may only *move* rows, never change them. The
    /// shard count is free to differ (that is the point of the handoff).
    pub fn handoff(&self, table: ShardedTable) -> Result<u64> {
        let current = self.load();
        anyhow::ensure!(
            table.n_nodes() == current.n_nodes() && table.dim() == current.dim(),
            "handoff table is {}x{}, serving {}x{}",
            table.n_nodes(),
            table.dim(),
            current.n_nodes(),
            current.dim()
        );
        Ok(self.publish(table))
    }
}

/// Outcome of one refresh cycle.
#[derive(Clone, Debug)]
pub struct RefreshReport {
    /// Epoch the new table was published at.
    pub epoch: u64,
    pub nodes: usize,
    pub dim: usize,
    /// Simulated cluster time of the inference pipeline.
    pub sim_secs: f64,
    /// Wall-clock time of the refresh on this host.
    pub wall_secs: f64,
    /// Bytes moved over the simulated network during the refresh.
    pub net_bytes: u64,
    /// Messages over the simulated network during the refresh.
    pub net_msgs: u64,
}

/// Periodic refresh driver: one inference pipeline feeding one cell.
pub struct Refresher {
    pipeline: Pipeline,
    /// Spill-mode budget for the incoming epoch's table (0 = resident).
    /// With a budget set, refresh double-buffers **on disk**: the old
    /// epoch keeps serving while the new one stages on the paged tier at
    /// `budget` resident bytes instead of doubling table RAM
    /// (DESIGN.md §Out-of-core-storage).
    spill_budget: u64,
    /// Journal target: every published epoch is made durable *before*
    /// the swap (DESIGN.md §Durability). `None` = ephemeral serving.
    durable: Option<Arc<Mutex<DurableStore>>>,
}

impl Refresher {
    pub fn new(mut pipeline: Pipeline) -> Refresher {
        // the refresher exists to harvest the embeddings
        pipeline.keep_embeddings = true;
        Refresher { pipeline, spill_budget: 0, durable: None }
    }

    /// Publish future epochs as spilled tables under `budget_bytes`.
    pub fn with_spill(mut self, budget_bytes: u64) -> Refresher {
        self.spill_budget = budget_bytes;
        self
    }

    /// Journal every future epoch into `store` before publishing it, so
    /// a crash between two refreshes recovers the last published table.
    pub fn with_durable(mut self, store: Arc<Mutex<DurableStore>>) -> Refresher {
        self.durable = Some(store);
        self
    }

    pub fn pipeline(&self) -> &Pipeline {
        &self.pipeline
    }

    /// Run the full pipeline and atomically publish the new epoch into
    /// `cell`. In-flight requests keep being served from the old epoch
    /// throughout. In durable mode the new table is checkpointed and its
    /// publish journaled *before* the swap — the epoch becomes visible
    /// only once it is recoverable.
    pub fn refresh(&self, cell: &TableCell) -> Result<RefreshReport> {
        let t0 = std::time::Instant::now();
        let report = self.pipeline.run()?;
        let embeddings = report
            .embeddings
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("pipeline kept no embeddings"))?;
        let table = if self.spill_budget > 0 {
            ShardedTable::from_inference_plan_spilled(
                &report.plan,
                embeddings,
                0,
                self.spill_budget,
            )?
        } else {
            ShardedTable::from_inference_plan(&report.plan, embeddings, 0)
        };
        let (nodes, dim) = (table.n_nodes(), table.dim());
        if let Some(store) = &self.durable {
            let mut s = store.lock().expect("durable store lock poisoned");
            s.journal_publish(cell.epoch() + 1, embeddings)?;
        }
        let epoch = cell.publish(table);
        let (mut net_bytes, mut net_msgs) = (0u64, 0u64);
        for stage in &report.stages.0 {
            if let Some(c) = &stage.cluster {
                net_bytes += c.total_bytes();
                net_msgs += c.total_msgs();
            }
        }
        Ok(RefreshReport {
            epoch,
            nodes,
            dim,
            sim_secs: report.stages.total(),
            wall_secs: t0.elapsed().as_secs_f64(),
            net_bytes,
            net_msgs,
        })
    }
}

/// Outcome of one delta epoch.
#[derive(Clone, Debug)]
pub struct DeltaRefreshReport {
    /// Epoch the patched table was published at.
    pub epoch: u64,
    /// Rows patched into the new epoch.
    pub updated_rows: usize,
    /// Rows whose neighbor lists changed (re-sampled).
    pub dirty_rows: usize,
    /// Affected-set size per GNN level.
    pub frontier: Vec<usize>,
    /// Simulated cluster seconds of the restricted re-inference.
    pub sim_secs: f64,
    /// Wall-clock seconds of the whole delta refresh on this host.
    pub wall_secs: f64,
    /// Bytes / messages over the simulated network.
    pub net_bytes: u64,
    pub net_msgs: u64,
}

/// Apply one update batch to `state` and publish a **delta epoch** into
/// `cell`: the next table is the current epoch's with only the affected
/// rows patched. In-flight readers keep their snapshot, exactly as with a
/// full refresh — the swap point is the same `TableCell::publish`.
pub fn refresh_delta(
    state: &mut DeltaState,
    batch: &UpdateBatch,
    cell: &TableCell,
) -> Result<DeltaRefreshReport> {
    refresh_delta_inner(state, batch, cell, None)
}

/// [`refresh_delta`] with journal-before-publish: the batch and the row
/// patch it produced are fsync'd into `store` before the epoch becomes
/// visible, and the store compacts (checkpoint + WAL rotation) once its
/// log passes the configured record budget. A crash at any point loses
/// only the epoch that was never published (DESIGN.md §Durability).
pub fn refresh_delta_durable(
    state: &mut DeltaState,
    batch: &UpdateBatch,
    cell: &TableCell,
    store: &Mutex<DurableStore>,
) -> Result<DeltaRefreshReport> {
    refresh_delta_inner(state, batch, cell, Some(store))
}

fn refresh_delta_inner(
    state: &mut DeltaState,
    batch: &UpdateBatch,
    cell: &TableCell,
    store: Option<&Mutex<DurableStore>>,
) -> Result<DeltaRefreshReport> {
    let t0 = std::time::Instant::now();
    let rep = state.apply(batch)?;
    let idx: Vec<usize> = rep.updated_rows.iter().map(|&v| v as usize).collect();
    let values = state.embeddings().gather_rows(&idx);
    let next = cell.load().patched(&rep.updated_rows, &values)?;
    if let Some(store) = store {
        let mut s = store.lock().expect("durable store lock poisoned");
        s.journal_delta(cell.epoch() + 1, batch, &rep.updated_rows, &values)?;
    }
    let epoch = cell.publish(next);
    if let Some(store) = store {
        let mut s = store.lock().expect("durable store lock poisoned");
        if s.should_compact() {
            // compaction snapshots the *published* table, shifting the
            // watermark up to the epoch the WAL was journaling
            let full = cell.load().to_full();
            s.compact(epoch, &full)?;
        }
    }
    Ok(DeltaRefreshReport {
        epoch,
        updated_rows: rep.updated_rows.len(),
        dirty_rows: rep.dirty_rows,
        frontier: rep.frontier,
        sim_secs: rep.sim_secs,
        wall_secs: t0.elapsed().as_secs_f64(),
        net_bytes: rep.net_bytes,
        net_msgs: rep.net_msgs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DealConfig;
    use crate::tensor::Matrix;

    fn constant_table(n: usize, d: usize, value: f32) -> ShardedTable {
        let full = Matrix::from_vec(n, d, vec![value; n * d]);
        ShardedTable::from_full(&full, 2, 0)
    }

    #[test]
    fn publish_bumps_epoch_and_keeps_snapshots() {
        let cell = TableCell::new(constant_table(8, 2, 1.0));
        assert_eq!(cell.epoch(), 0);
        let old = cell.load();
        let e1 = cell.publish(constant_table(8, 2, 2.0));
        assert_eq!(e1, 1);
        assert_eq!(cell.epoch(), 1);
        // the pinned snapshot still reads epoch-0 data
        assert_eq!(old.row(0)[0], 1.0);
        assert_eq!(old.epoch(), 0);
        let new = cell.load();
        assert_eq!(new.row(0)[0], 2.0);
        assert_eq!(new.epoch(), 1);
        let e2 = cell.publish(constant_table(8, 2, 3.0));
        assert_eq!(e2, 2);
    }

    #[test]
    fn retention_index_serves_and_evicts_past_epochs() {
        let cell = TableCell::with_retention(constant_table(8, 2, 0.0), 3).unwrap();
        for v in 1..=5 {
            cell.publish(constant_table(8, 2, v as f32));
        }
        assert_eq!(cell.epoch(), 5);
        assert_eq!(cell.retained_epochs(), vec![3, 4, 5]);
        // retained epochs read back their exact published tables
        for e in 3..=5u64 {
            let t = cell.load_at(e).unwrap();
            assert_eq!(t.epoch(), e);
            assert_eq!(t.row(0)[0], e as f32);
        }
        // evicted epochs fail with a cause-naming error
        let err = cell.load_at(1).unwrap_err().to_string();
        assert!(err.contains("not retained") && err.contains("retain = 3"), "{}", err);
        // an index-free cell still answers the current epoch
        let plain = TableCell::new(constant_table(4, 2, 7.0));
        assert_eq!(plain.load_at(0).unwrap().row(0)[0], 7.0);
        assert!(plain.load_at(1).is_err());
        assert!(plain.retained_epochs().is_empty());
        // retention must keep at least one epoch
        assert!(TableCell::with_retention(constant_table(4, 2, 0.0), 0).is_err());
    }

    #[test]
    fn delta_refresh_publishes_patched_epoch() {
        use crate::util::rng::Rng;

        let mut cfg = DealConfig::default();
        cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
        cfg.cluster.machines = 4;
        cfg.model.layers = 2;
        cfg.model.fanout = 5;
        let mut state = DeltaState::init(cfg).unwrap();
        let table =
            ShardedTable::from_inference_plan(state.plan(), state.embeddings(), 0);
        let cell = TableCell::new(table);
        let epoch0 = cell.load();

        let mut rng = Rng::new(0x57AB);
        let batch = state.synth_batch(&mut rng, 30, 30, 2);
        let rep = refresh_delta(&mut state, &batch, &cell).unwrap();
        assert_eq!(rep.epoch, 1);
        assert!(rep.updated_rows > 0);
        assert!(rep.frontier.len() == 3);
        let now = cell.load();
        assert_eq!(now.epoch(), 1);
        // the published epoch serves exactly the state's new embeddings
        assert_eq!(now.to_full(), *state.embeddings());
        // the pinned old snapshot is untouched (tear-free double buffering)
        assert_eq!(epoch0.epoch(), 0);
        assert_ne!(epoch0.to_full(), *state.embeddings());

        // an empty batch still publishes a (content-identical) epoch
        let rep2 = refresh_delta(&mut state, &UpdateBatch::default(), &cell).unwrap();
        assert_eq!(rep2.epoch, 2);
        assert_eq!(rep2.updated_rows, 0);
        assert_eq!(cell.load().to_full(), *state.embeddings());
    }

    #[test]
    fn spilled_refresh_serves_the_same_epoch() {
        let mut cfg = DealConfig::default();
        cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
        cfg.cluster.machines = 4;
        cfg.model.layers = 2;
        cfg.model.fanout = 5;
        let resident = Refresher::new(Pipeline::new(cfg.clone()));
        let cell_a = TableCell::new(constant_table(4, 2, 0.0));
        resident.refresh(&cell_a).unwrap();
        // 8 KiB budget < the 256 × d table → the spilled epoch pages
        let spilled = Refresher::new(Pipeline::new(cfg)).with_spill(8 << 10);
        let cell_b = TableCell::new(constant_table(4, 2, 0.0));
        let rep = spilled.refresh(&cell_b).unwrap();
        assert_eq!(rep.nodes, 256);
        let a = cell_a.load();
        let b = cell_b.load();
        assert!(b.is_spilled());
        assert!(!a.is_spilled());
        assert_eq!(b.to_full(), a.to_full(), "spilled epoch serves identical embeddings");
        assert!(b.resident_bytes() < a.resident_bytes(), "spill bounds the new epoch's RAM");
        assert!(b.storage_counters().spill_bytes_written > 0);
    }

    #[test]
    fn durable_delta_refresh_journals_and_survives_reopen() {
        use crate::storage::{DurableOptions, DurableStore};
        use crate::util::rng::Rng;

        let dir = std::env::temp_dir()
            .join(format!("deal-refresh-durable-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let mut cfg = DealConfig::default();
        cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
        cfg.cluster.machines = 4;
        cfg.model.layers = 2;
        cfg.model.fanout = 5;
        let mut state = DeltaState::init(cfg).unwrap();
        let store = DurableStore::create(
            &dir,
            0,
            state.embeddings(),
            DurableOptions { compact_every: 2 },
        )
        .unwrap();
        let store = Mutex::new(store);
        let table =
            ShardedTable::from_inference_plan(state.plan(), state.embeddings(), 0);
        let cell = TableCell::new(table);

        let mut rng = Rng::new(0xD00D);
        for _ in 0..3 {
            let batch = state.synth_batch(&mut rng, 10, 10, 1);
            refresh_delta_durable(&mut state, &batch, &cell, &store).unwrap();
        }
        assert_eq!(cell.epoch(), 3);
        {
            let s = store.lock().unwrap();
            // 3 deltas with compact_every=2 → one compaction happened
            assert!(s.generation() >= 1, "gen {}", s.generation());
            assert!(s.watermark() >= 2);
            assert_eq!(s.last_epoch(), 3);
            assert!(s.counters().wal_bytes > 0);
            assert!(s.counters().checkpoints >= 2); // create + compaction
        }
        drop(store);

        let rec = DurableStore::open(&dir, DurableOptions::default()).unwrap().1;
        assert_eq!(rec.epoch, 3);
        let a: Vec<u32> = rec.table.data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> =
            state.embeddings().data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "recovered table must be bit-identical to live state");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refresher_publishes_pipeline_embeddings() {
        let mut cfg = DealConfig::default();
        cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
        cfg.cluster.machines = 4;
        cfg.model.layers = 2;
        cfg.model.fanout = 5;
        let refresher = Refresher::new(Pipeline::new(cfg));
        let cell = TableCell::new(constant_table(4, 2, 0.0));
        let rep = refresher.refresh(&cell).unwrap();
        assert_eq!(rep.epoch, 1);
        assert_eq!(rep.nodes, 256);
        assert!(rep.sim_secs > 0.0);
        assert!(rep.net_msgs > 0);
        let t = cell.load();
        assert_eq!(t.n_nodes(), 256);
        assert_eq!(t.epoch(), 1);
        // serving shards mirror the inference plan (P=2 for 4 machines, M=2)
        assert_eq!(t.num_shards(), 2);
    }
}
