//! Double-buffered table refresh (DESIGN.md §Serving).
//!
//! The paper's deployment refreshes the all-node embedding table daily:
//! the inference tier recomputes every embedding, then the serving tier
//! must start answering from the new table **without dropping in-flight
//! traffic**. [`TableCell`] is the swap point: readers (`ServePool`
//! workers) pin an `Arc` snapshot per batch, the publisher swaps the
//! `Arc` atomically under a short write lock, and the old epoch's memory
//! is freed when its last in-flight batch finishes — classic
//! double-buffering with reference counts instead of a fixed pair of
//! buffers, so overlapping refreshes are also safe.
//!
//! [`Refresher`] drives the whole loop end to end: run the
//! `coordinator::Pipeline` (construct → partition → sample → infer),
//! shard the gathered embeddings with the inference plan's row
//! ownership, and publish.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::coordinator::Pipeline;
use crate::Result;

use super::shard::ShardedTable;

/// The atomically swappable serving table.
pub struct TableCell {
    current: RwLock<Arc<ShardedTable>>,
    epoch: AtomicU64,
}

impl TableCell {
    /// Install an initial table; its epoch stamp becomes the cell's.
    pub fn new(table: ShardedTable) -> TableCell {
        let epoch = table.epoch();
        TableCell { current: RwLock::new(Arc::new(table)), epoch: AtomicU64::new(epoch) }
    }

    /// Snapshot the current epoch's table. The returned `Arc` stays valid
    /// (and unchanged) across any number of concurrent `publish` calls.
    pub fn load(&self) -> Arc<ShardedTable> {
        Arc::clone(&self.current.read().unwrap())
    }

    /// Current epoch number.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Publish `table` as the next epoch and return its epoch number.
    /// In-flight readers keep their snapshot; new loads see the new table.
    pub fn publish(&self, mut table: ShardedTable) -> u64 {
        let mut slot = self.current.write().unwrap();
        let next = self.epoch.load(Ordering::Acquire) + 1;
        table.set_epoch(next);
        *slot = Arc::new(table);
        self.epoch.store(next, Ordering::Release);
        next
    }
}

/// Outcome of one refresh cycle.
#[derive(Clone, Debug)]
pub struct RefreshReport {
    /// Epoch the new table was published at.
    pub epoch: u64,
    pub nodes: usize,
    pub dim: usize,
    /// Simulated cluster time of the inference pipeline.
    pub sim_secs: f64,
    /// Wall-clock time of the refresh on this host.
    pub wall_secs: f64,
    /// Bytes moved over the simulated network during the refresh.
    pub net_bytes: u64,
    /// Messages over the simulated network during the refresh.
    pub net_msgs: u64,
}

/// Periodic refresh driver: one inference pipeline feeding one cell.
pub struct Refresher {
    pipeline: Pipeline,
}

impl Refresher {
    pub fn new(mut pipeline: Pipeline) -> Refresher {
        // the refresher exists to harvest the embeddings
        pipeline.keep_embeddings = true;
        Refresher { pipeline }
    }

    pub fn pipeline(&self) -> &Pipeline {
        &self.pipeline
    }

    /// Run the full pipeline and atomically publish the new epoch into
    /// `cell`. In-flight requests keep being served from the old epoch
    /// throughout.
    pub fn refresh(&self, cell: &TableCell) -> Result<RefreshReport> {
        let t0 = std::time::Instant::now();
        let report = self.pipeline.run()?;
        let embeddings = report
            .embeddings
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("pipeline kept no embeddings"))?;
        let table = ShardedTable::from_inference_plan(&report.plan, embeddings, 0);
        let (nodes, dim) = (table.n_nodes(), table.dim());
        let epoch = cell.publish(table);
        let (mut net_bytes, mut net_msgs) = (0u64, 0u64);
        for stage in &report.stages.0 {
            if let Some(c) = &stage.cluster {
                net_bytes += c.total_bytes();
                net_msgs += c.total_msgs();
            }
        }
        Ok(RefreshReport {
            epoch,
            nodes,
            dim,
            sim_secs: report.stages.total(),
            wall_secs: t0.elapsed().as_secs_f64(),
            net_bytes,
            net_msgs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DealConfig;
    use crate::tensor::Matrix;

    fn constant_table(n: usize, d: usize, value: f32) -> ShardedTable {
        let full = Matrix::from_vec(n, d, vec![value; n * d]);
        ShardedTable::from_full(&full, 2, 0)
    }

    #[test]
    fn publish_bumps_epoch_and_keeps_snapshots() {
        let cell = TableCell::new(constant_table(8, 2, 1.0));
        assert_eq!(cell.epoch(), 0);
        let old = cell.load();
        let e1 = cell.publish(constant_table(8, 2, 2.0));
        assert_eq!(e1, 1);
        assert_eq!(cell.epoch(), 1);
        // the pinned snapshot still reads epoch-0 data
        assert_eq!(old.row(0)[0], 1.0);
        assert_eq!(old.epoch(), 0);
        let new = cell.load();
        assert_eq!(new.row(0)[0], 2.0);
        assert_eq!(new.epoch(), 1);
        let e2 = cell.publish(constant_table(8, 2, 3.0));
        assert_eq!(e2, 2);
    }

    #[test]
    fn refresher_publishes_pipeline_embeddings() {
        let mut cfg = DealConfig::default();
        cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
        cfg.cluster.machines = 4;
        cfg.model.layers = 2;
        cfg.model.fanout = 5;
        let refresher = Refresher::new(Pipeline::new(cfg));
        let cell = TableCell::new(constant_table(4, 2, 0.0));
        let rep = refresher.refresh(&cell).unwrap();
        assert_eq!(rep.epoch, 1);
        assert_eq!(rep.nodes, 256);
        assert!(rep.sim_secs > 0.0);
        assert!(rep.net_msgs > 0);
        let t = cell.load();
        assert_eq!(t.n_nodes(), 256);
        assert_eq!(t.epoch(), 1);
        // serving shards mirror the inference plan (P=2 for 4 machines, M=2)
        assert_eq!(t.num_shards(), 2);
    }
}
