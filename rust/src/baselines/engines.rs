//! The DGI-style and SALIENT++-style distributed inference engines
//! (Fig. 14's comparison points).
//!
//! Both are *ego-centric*: machines own a 1-D range of target nodes plus
//! those nodes' features (full width — no feature partitioning), process
//! their targets in batches of merged ego networks, and fetch remote
//! innermost-layer features from peers' feature servers. They differ in
//! how they exploit sharing:
//!
//! - **DGI**: merges the batch's ego networks per layer (within-batch
//!   dedup) and runs layerwise compute over the merged MFG.
//! - **SALIENT++**: keeps an LRU feature cache; remote fetches consult it
//!   first, and cache bookkeeping costs real time (the overhead Fig. 14's
//!   analysis attributes to it).

use std::collections::HashMap;

use crate::cluster::{Cluster, ClusterReport, Ctx, NetConfig, Payload, Tag};
use crate::graph::{Csr, NodeId};
use crate::model::{Aggregator, ModelKind, ModelWeights};
use crate::partition::PartitionPlan;
use crate::primitives::spmm::feature_server;
use crate::runtime::{Act, Backend};
use crate::tensor::{leaky_relu, Matrix};
use crate::util::rng::Rng;
use crate::Result;

use super::mfg::{build_mfg, Mfg};
use super::BaselineOpts;

const PHASE: u32 = 0xBA5E;
const RESP_BIT: u32 = 0x8000_0000;

/// Which baseline engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Dgi,
    SalientPlusPlus,
}

/// Drive a full all-node inference with a baseline engine on a simulated
/// cluster of `world` machines. Returns the embeddings and the report.
pub fn run_baseline(
    engine: Engine,
    g: &std::sync::Arc<Csr>,
    features: &Matrix,
    weights: &ModelWeights,
    world: usize,
    net: NetConfig,
    backend: std::sync::Arc<dyn Backend>,
    opts: BaselineOpts,
) -> Result<(Matrix, ClusterReport)> {
    let n = g.n_rows;
    let d = features.cols;
    let plan = PartitionPlan::new(n, d, world, 1);
    let tiles: Vec<Matrix> = (0..world)
        .map(|p| {
            let (lo, hi) = plan.node_range(p);
            features.slice_rows(lo, hi)
        })
        .collect();
    let tiles = std::sync::Arc::new(tiles);
    let g2 = std::sync::Arc::clone(g);
    let plan2 = plan.clone();
    let weights2 = std::sync::Arc::new(weights.clone());
    let cluster = Cluster::new(world, net);
    let (outs, report) = cluster.run(move |ctx| {
        machine_main(
            ctx,
            engine,
            &plan2,
            &g2,
            &tiles[ctx.rank],
            &weights2,
            backend.as_ref(),
            &opts,
        )
    })?;
    let outs: Vec<Matrix> = outs.into_iter().collect::<Result<_>>()?;
    let refs: Vec<&Matrix> = outs.iter().collect();
    Ok((Matrix::vcat(&refs), report))
}

#[allow(clippy::too_many_arguments)]
fn machine_main(
    ctx: &mut Ctx,
    engine: Engine,
    plan: &PartitionPlan,
    g: &Csr,
    h_local: &Matrix,
    weights: &ModelWeights,
    backend: &dyn Backend,
    opts: &BaselineOpts,
) -> Result<Matrix> {
    let (p_idx, _) = plan.coords_of(ctx.rank);
    let (rlo, rhi) = plan.node_range(p_idx);
    let k = weights.config.layers;
    let d = weights.config.dim;

    // ---- Pass 1: sample every batch's merged ego network (the
    // construction cost Deal's layerwise sampling avoids re-paying).
    let mut rng = Rng::new(opts.seed ^ ctx.rank as u64);
    let roots: Vec<NodeId> = (rlo as NodeId..rhi as NodeId).collect();
    let batches: Vec<Mfg> = ctx.compute(|| {
        roots
            .chunks(opts.batch_size.max(1))
            .map(|chunk| build_mfg(g, chunk, k, opts.fanout, &mut rng))
            .collect()
    });

    // One fetch request per (batch, peer) — counts are symmetric.
    for q in 0..plan.world() {
        if q != ctx.rank {
            ctx.send_service(
                q,
                Tag::of(PHASE, u32::MAX),
                Payload::U32(vec![batches.len() as u32]),
            );
        }
    }

    let expected_peers = plan.world() - 1;
    let out = ctx.with_server(
        |sctx| feature_server(sctx, h_local, rlo, expected_peers, PHASE),
        |ctx| -> Result<Matrix> {
            let mut cache = LruCache::new(opts.cache_rows, d);
            let mut out = Matrix::zeros(rhi - rlo, d);
            ctx.mem.alloc(out.nbytes());
            for (bi, mfg) in batches.iter().enumerate() {
                // --- gather innermost-layer features
                let inner = &mfg.layer_nodes[0];
                let mut feats = Matrix::zeros(inner.len(), d);
                let fb = feats.nbytes();
                ctx.mem.alloc(fb);
                // split into local / cached / missing-per-peer
                let mut missing_by_peer: Vec<Vec<u32>> = vec![Vec::new(); plan.world()];
                let mut missing_pos: Vec<Vec<usize>> = vec![Vec::new(); plan.world()];
                for (i, &v) in inner.iter().enumerate() {
                    let vu = v as usize;
                    if vu >= rlo && vu < rhi {
                        feats.row_mut(i).copy_from_slice(h_local.row(vu - rlo));
                    } else if engine == Engine::SalientPlusPlus {
                        // consult the cache (its bookkeeping is real work)
                        let hit = ctx.compute(|| cache.get(v));
                        if let Some(row) = hit {
                            feats.row_mut(i).copy_from_slice(&row);
                        } else {
                            let owner = plan.node_owner(v);
                            missing_by_peer[owner].push(v);
                            missing_pos[owner].push(i);
                        }
                    } else {
                        let owner = plan.node_owner(v);
                        missing_by_peer[owner].push(v);
                        missing_pos[owner].push(i);
                    }
                }
                // one request per peer per batch (possibly empty)
                for q in 0..plan.world() {
                    if q == ctx.rank {
                        continue;
                    }
                    ctx.send_service(
                        q,
                        Tag::of(PHASE, bi as u32),
                        Payload::U32(missing_by_peer[q].clone()),
                    );
                }
                for q in 0..plan.world() {
                    if q == ctx.rank {
                        continue;
                    }
                    let block = ctx.recv_matrix(q, Tag::of(PHASE, bi as u32 | RESP_BIT));
                    for (j, &i) in missing_pos[q].iter().enumerate() {
                        feats.row_mut(i).copy_from_slice(block.row(j));
                    }
                    if engine == Engine::SalientPlusPlus {
                        ctx.compute(|| {
                            for (j, &v) in missing_by_peer[q].iter().enumerate() {
                                cache.insert(v, block.row(j));
                            }
                        });
                    }
                }
                // --- layerwise compute over the merged MFG
                let emb = ctx.compute(|| compute_mfg(mfg, feats, weights, backend))?;
                // roots of this batch are contiguous in out
                let first_root = mfg.layer_nodes[k][0] as usize - rlo;
                out.set_rows(first_root, &emb);
                ctx.mem.free(fb);
            }
            Ok(out)
        },
    )?;
    Ok(out)
}

/// Layerwise GCN/GAT/SAGE compute over one merged ego network (dense
/// local math through the backend, mirroring the distributed model
/// semantics: mean aggregation with self loop / additive attention with
/// self edge / SAGE's separate self and neighbor projections).
fn compute_mfg(
    mfg: &Mfg,
    mut feats: Matrix,
    weights: &ModelWeights,
    backend: &dyn Backend,
) -> Result<Matrix> {
    let k = weights.config.layers;
    let d = weights.config.dim;
    for l in 0..k {
        let act = if l + 1 == k { Act::None } else { Act::Relu };
        let next_nodes = &mfg.layer_nodes[l + 1];
        let edges = &mfg.layer_edges[l];
        let z = backend.gemm(&feats, weights.layer_w(l))?;
        let b = weights.layer_b(l);
        let mut next = Matrix::zeros(next_nodes.len(), d);
        match weights.config.kind {
            ModelKind::Gcn => {
                let mut deg = vec![0u32; next_nodes.len()];
                for &(_, dst) in edges {
                    deg[dst as usize] += 1;
                }
                for &(s, dst) in edges {
                    let w = 1.0 / (deg[dst as usize] as f32 + 1.0);
                    let src = z.row(s as usize);
                    let row = next.row_mut(dst as usize);
                    for (o, &x) in row.iter_mut().zip(src) {
                        *o += w * x;
                    }
                }
                for i in 0..next_nodes.len() {
                    let w = 1.0 / (deg[i] as f32 + 1.0);
                    let sp = mfg.self_pos[l][i] as usize;
                    let src = z.row(sp);
                    let row = next.row_mut(i);
                    for j in 0..d {
                        let v = row[j] + w * src[j] + b[j];
                        row[j] = match act {
                            Act::None => v,
                            Act::Relu => v.max(0.0),
                        };
                    }
                }
            }
            ModelKind::Gat => {
                let heads = weights.config.heads;
                let head_dim = d / heads;
                let u_all = backend.gemm(&z, weights.layer_a_dst(l))?;
                let v_all = backend.gemm(&z, weights.layer_a_src(l))?;
                // per-dst softmax over incoming edges + self
                let mut scores: Vec<Vec<(u32, Vec<f32>)>> =
                    vec![Vec::new(); next_nodes.len()];
                for &(s, dst) in edges {
                    let sp = mfg.self_pos[l][dst as usize] as usize;
                    let sc: Vec<f32> = (0..heads)
                        .map(|h| leaky_relu(u_all.get(sp, h) + v_all.get(s as usize, h)))
                        .collect();
                    scores[dst as usize].push((s, sc));
                }
                for i in 0..next_nodes.len() {
                    let sp = mfg.self_pos[l][i] as usize;
                    let self_sc: Vec<f32> = (0..heads)
                        .map(|h| leaky_relu(u_all.get(sp, h) + v_all.get(sp, h)))
                        .collect();
                    let row_scores = &scores[i];
                    // softmax per head
                    let mut alpha = vec![vec![0.0f32; heads]; row_scores.len()];
                    let mut alpha_self = vec![0.0f32; heads];
                    for h in 0..heads {
                        let mut mx = self_sc[h];
                        for (_, sc) in row_scores {
                            mx = mx.max(sc[h]);
                        }
                        let mut sum = (self_sc[h] - mx).exp();
                        alpha_self[h] = sum;
                        for (e, (_, sc)) in row_scores.iter().enumerate() {
                            let x = (sc[h] - mx).exp();
                            alpha[e][h] = x;
                            sum += x;
                        }
                        alpha_self[h] /= sum;
                        for a in alpha.iter_mut() {
                            a[h] /= sum;
                        }
                    }
                    let row = next.row_mut(i);
                    for (e, (s, _)) in row_scores.iter().enumerate() {
                        let src = z.row(*s as usize);
                        for j in 0..d {
                            row[j] += alpha[e][j / head_dim] * src[j];
                        }
                    }
                    let src = z.row(sp);
                    for j in 0..d {
                        let v = row[j] + alpha_self[j / head_dim] * src[j] + b[j];
                        row[j] = match act {
                            Act::None => v,
                            Act::Relu => v.max(0.0),
                        };
                    }
                }
            }
            ModelKind::Sage => {
                // neighbor term: mean of W_neigh-projected sources, or
                // max-pool of relu(W_pool·h + b_pool) pushed through
                // W_neigh; self term reuses z = feats · W_self.
                let neigh = match weights.config.aggregator {
                    Aggregator::Mean => {
                        let hn = backend.gemm(&feats, weights.layer_w_neigh(l))?;
                        let mut deg = vec![0u32; next_nodes.len()];
                        for &(_, dst) in edges {
                            deg[dst as usize] += 1;
                        }
                        let mut acc = Matrix::zeros(next_nodes.len(), d);
                        for &(s, dst) in edges {
                            let w = 1.0 / deg[dst as usize] as f32;
                            let src = hn.row(s as usize);
                            let row = acc.row_mut(dst as usize);
                            for (o, &x) in row.iter_mut().zip(src) {
                                *o += w * x;
                            }
                        }
                        acc
                    }
                    Aggregator::Pool => {
                        let mut hp = backend.gemm(&feats, weights.layer_w_pool(l))?;
                        let bp = weights.layer_b_pool(l);
                        for r in 0..hp.rows {
                            let row = hp.row_mut(r);
                            for j in 0..d {
                                row[j] = (row[j] + bp[j]).max(0.0);
                            }
                        }
                        let mut mx = Matrix::zeros(next_nodes.len(), d);
                        let mut seen = vec![false; next_nodes.len()];
                        for &(s, dst) in edges {
                            let src = hp.row(s as usize);
                            let row = mx.row_mut(dst as usize);
                            if seen[dst as usize] {
                                for (o, &x) in row.iter_mut().zip(src) {
                                    *o = o.max(x);
                                }
                            } else {
                                row.copy_from_slice(src);
                                seen[dst as usize] = true;
                            }
                        }
                        backend.gemm(&mx, weights.layer_w_neigh(l))?
                    }
                };
                for i in 0..next_nodes.len() {
                    let sp = mfg.self_pos[l][i] as usize;
                    let srow = z.row(sp);
                    let nrow = neigh.row(i);
                    let row = next.row_mut(i);
                    for j in 0..d {
                        let v = nrow[j] + srow[j] + b[j];
                        row[j] = match act {
                            Act::None => v,
                            Act::Relu => v.max(0.0),
                        };
                    }
                }
            }
        }
        feats = next;
    }
    Ok(feats)
}

/// A counting LRU cache of feature rows (SALIENT++'s hub-feature cache).
pub struct LruCache {
    capacity: usize,
    d: usize,
    map: HashMap<NodeId, (Vec<f32>, u64)>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl LruCache {
    pub fn new(capacity: usize, d: usize) -> LruCache {
        LruCache { capacity, d, map: HashMap::new(), tick: 0, hits: 0, misses: 0 }
    }

    pub fn get(&mut self, key: NodeId) -> Option<Vec<f32>> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(&key) {
            Some((row, at)) => {
                *at = tick;
                self.hits += 1;
                Some(row.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn insert(&mut self, key: NodeId, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d);
        if self.capacity == 0 {
            return;
        }
        self.tick += 1;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            // evict LRU (linear scan — SALIENT++'s maintenance overhead is
            // the point; a real system pays for this bookkeeping too)
            if let Some((&victim, _)) = self.map.iter().min_by_key(|(_, (_, at))| *at) {
                self.map.remove(&victim);
            }
        }
        self.map.insert(key, (row.to_vec(), self.tick));
    }

    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::model::reference::gcn_reference;
    use crate::model::ModelConfig;
    use crate::sampling::sample_all_layers;
    use crate::util::prop::assert_close;

    #[test]
    fn lru_cache_hits_and_evicts() {
        let mut c = LruCache::new(2, 1);
        assert!(c.get(1).is_none());
        c.insert(1, &[1.0]);
        c.insert(2, &[2.0]);
        assert_eq!(c.get(1), Some(vec![1.0]));
        c.insert(3, &[3.0]); // evicts 2 (LRU)
        assert!(c.get(2).is_none());
        assert_eq!(c.get(1), Some(vec![1.0]));
        assert_eq!(c.get(3), Some(vec![3.0]));
        assert!(c.hit_ratio() > 0.0);
    }

    /// Full-neighbor mode: both baselines must match the dense reference
    /// exactly (sampling differences vanish at fanout 0).
    #[test]
    fn baselines_match_reference_at_full_fanout() {
        let el = rmat(6, 400, RmatParams::paper(), 51);
        let g = std::sync::Arc::new(Csr::from(&el));
        let d = 8;
        let mut rng = Rng::new(77);
        let features = Matrix::random(g.n_rows, d, 1.0, &mut rng);
        let layers = sample_all_layers(&g, 2, 0, 1); // full graph
        for kind in ["gcn", "gat", "sage-mean", "sage-pool"] {
            let cfg = match kind {
                "gcn" => ModelConfig::gcn(2, d),
                "gat" => ModelConfig::gat(2, d, 4),
                "sage-mean" => ModelConfig::sage(2, d, Aggregator::Mean),
                _ => ModelConfig::sage(2, d, Aggregator::Pool),
            };
            let weights = ModelWeights::random(&cfg, 9);
            let expect = match kind {
                "gcn" => gcn_reference(&layers, &features, &weights),
                "gat" => crate::model::reference::gat_reference(&layers, &features, &weights),
                _ => crate::model::reference::sage_reference(&layers, &features, &weights),
            };
            for engine in [Engine::Dgi, Engine::SalientPlusPlus] {
                let opts = BaselineOpts { fanout: 0, batch_size: 16, ..Default::default() };
                let (got, report) = run_baseline(
                    engine,
                    &g,
                    &features,
                    &weights,
                    2,
                    NetConfig::default(),
                    std::sync::Arc::new(crate::runtime::Native),
                    opts,
                )
                .unwrap();
                assert_close(&got.data, &expect.data, 2e-3, 2e-3)
                    .unwrap_or_else(|e| panic!("{:?}/{}: {}", engine, kind, e));
                assert!(report.total_bytes() > 0);
            }
        }
    }

    #[test]
    fn salient_cache_reduces_traffic() {
        let el = rmat(7, 2000, RmatParams::paper(), 52);
        let g = std::sync::Arc::new(Csr::from(&el));
        let d = 16;
        let mut rng = Rng::new(3);
        let features = Matrix::random(g.n_rows, d, 1.0, &mut rng);
        let weights = ModelWeights::random(&ModelConfig::gcn(2, d), 4);
        let opts_small_batch = BaselineOpts { fanout: 5, batch_size: 8, cache_rows: 4096, ..Default::default() };
        let (_, dgi) = run_baseline(
            Engine::Dgi,
            &g,
            &features,
            &weights,
            2,
            NetConfig::default(),
            std::sync::Arc::new(crate::runtime::Native),
            opts_small_batch,
        )
        .unwrap();
        let (_, sal) = run_baseline(
            Engine::SalientPlusPlus,
            &g,
            &features,
            &weights,
            2,
            NetConfig::default(),
            std::sync::Arc::new(crate::runtime::Native),
            opts_small_batch,
        )
        .unwrap();
        assert!(
            sal.total_bytes() < dgi.total_bytes(),
            "salient {} !< dgi {}",
            sal.total_bytes(),
            dgi.total_bytes()
        );
    }
}
