//! Merged multi-hop ego networks ("message-flow graphs", DGL's MFG): the
//! unit of work for the ego-centric baselines, built by expanding a batch
//! of roots hop by hop with per-layer dedup — exactly the structure whose
//! construction cost and cross-batch redundancy Deal eliminates.

use std::collections::HashMap;

use crate::graph::{Csr, NodeId};
use crate::util::rng::Rng;

/// A merged ego network for a batch of roots.
///
/// `layer_nodes[0]` is the innermost (hop-k) node set; `layer_nodes[k]`
/// are the roots. `layer_edges[l][(src_pos, dst_pos)]` connects positions
/// in `layer_nodes[l]` to positions in `layer_nodes[l+1]`. Because the
/// models use self-loop aggregation, every `layer_nodes[l+1]` node is also
/// present in `layer_nodes[l]` (its own position recorded in
/// `self_pos[l]`).
#[derive(Clone, Debug)]
pub struct Mfg {
    pub layer_nodes: Vec<Vec<NodeId>>,
    pub layer_edges: Vec<Vec<(u32, u32)>>,
    /// `self_pos[l][i]` = position of `layer_nodes[l+1][i]` inside
    /// `layer_nodes[l]`.
    pub self_pos: Vec<Vec<u32>>,
}

impl Mfg {
    /// Total node occurrences (the sharing-accounting denominator).
    pub fn node_occurrences(&self) -> usize {
        self.layer_nodes.iter().map(|l| l.len()).sum()
    }
}

/// Build the merged ego network of `roots` over `g` (global CSR), `k`
/// hops, `fanout` samples per hop (0 = all neighbors), deduplicating
/// frontier nodes per layer *within this batch*.
pub fn build_mfg(g: &Csr, roots: &[NodeId], k: usize, fanout: usize, rng: &mut Rng) -> Mfg {
    let mut layer_nodes: Vec<Vec<NodeId>> = Vec::with_capacity(k + 1);
    let mut layer_edges: Vec<Vec<(u32, u32)>> = Vec::with_capacity(k);
    layer_nodes.push(roots.to_vec());
    // expand from roots inwards
    for _ in 0..k {
        let frontier = layer_nodes.last().unwrap();
        let mut next: Vec<NodeId> = Vec::new();
        let mut pos: HashMap<NodeId, u32> = HashMap::new();
        // self-loops: every frontier node appears in the next layer
        for &v in frontier {
            pos.entry(v).or_insert_with(|| {
                next.push(v);
                (next.len() - 1) as u32
            });
        }
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for (dst_pos, &v) in frontier.iter().enumerate() {
            let row = g.row(v as usize);
            if row.is_empty() {
                continue;
            }
            let take = if fanout == 0 { row.len() } else { fanout.min(row.len()) };
            let mut pool: Vec<NodeId> = row.to_vec();
            // partial Fisher–Yates
            let n = pool.len();
            for i in 0..take.min(n.saturating_sub(1)) {
                let j = rng.range(i, n);
                pool.swap(i, j);
            }
            for &s in &pool[..take] {
                let sp = *pos.entry(s).or_insert_with(|| {
                    next.push(s);
                    (next.len() - 1) as u32
                });
                edges.push((sp, dst_pos as u32));
            }
        }
        layer_nodes.push(next);
        layer_edges.push(edges);
    }
    // flip to innermost-first
    layer_nodes.reverse();
    layer_edges.reverse();
    // self positions: node layer l+1 position i → its position in layer l
    let mut self_pos: Vec<Vec<u32>> = Vec::with_capacity(k);
    for l in 0..k {
        let inner: HashMap<NodeId, u32> = layer_nodes[l]
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, i as u32))
            .collect();
        self_pos.push(
            layer_nodes[l + 1]
                .iter()
                .map(|v| *inner.get(v).expect("self node missing from inner layer"))
                .collect(),
        );
    }
    Mfg { layer_nodes, layer_edges, self_pos }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{rmat, RmatParams};

    fn g() -> Csr {
        Csr::from(&rmat(8, 3000, RmatParams::paper(), 3))
    }

    #[test]
    fn mfg_structure() {
        let g = g();
        let mut rng = Rng::new(1);
        let roots: Vec<NodeId> = (0..16).collect();
        let mfg = build_mfg(&g, &roots, 2, 4, &mut rng);
        assert_eq!(mfg.layer_nodes.len(), 3);
        assert_eq!(*mfg.layer_nodes.last().unwrap(), roots);
        // layers are dedup'd
        for layer in &mfg.layer_nodes {
            let mut d = layer.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), layer.len());
        }
        // every outer node present in inner layer (self loop)
        for l in 0..2 {
            for (i, &v) in mfg.layer_nodes[l + 1].iter().enumerate() {
                let p = mfg.self_pos[l][i] as usize;
                assert_eq!(mfg.layer_nodes[l][p], v);
            }
        }
        // edges reference valid positions
        for l in 0..2 {
            for &(s, d) in &mfg.layer_edges[l] {
                assert!((s as usize) < mfg.layer_nodes[l].len());
                assert!((d as usize) < mfg.layer_nodes[l + 1].len());
            }
        }
    }

    #[test]
    fn batching_shares_within_batch() {
        // one batch of 32 roots must have fewer occurrences than 32
        // separate singleton batches.
        let g = g();
        let mut rng = Rng::new(2);
        let roots: Vec<NodeId> = (0..32).collect();
        let merged = build_mfg(&g, &roots, 2, 8, &mut rng).node_occurrences();
        let mut separate = 0;
        for &r in &roots {
            separate += build_mfg(&g, &[r], 2, 8, &mut rng).node_occurrences();
        }
        assert!(merged < separate, "merged {} !< separate {}", merged, separate);
    }
}
