//! Baseline inference systems reimplemented from their papers' algorithmic
//! descriptions (P³ is closed source; the paper also reimplements its
//! baselines — §4.1):
//!
//! - [`engines::dgi_inference`] — DGI-style layerwise inference over
//!   *batches* of merged ego networks: full sharing within a batch, none
//!   across batches.
//! - [`engines::salient_inference`] — SALIENT++-style per-batch ego
//!   network execution with an LRU feature cache; sharing is bounded by
//!   the hit ratio and cache maintenance costs real time.
//! - [`sharing`] — the pure-counting studies: leveraged sharing vs batch
//!   size (Fig. 5) and the DGI / P³ / SALIENT++ sharing ratios (Table 5).
//!
//! Simulation note (DESIGN.md §Substitutions): baseline machines sample
//! ego networks against a shared read-only CSR (DistDGL samples via RPC;
//! not charging that communication *favors the baselines*, making Deal's
//! measured speedups conservative). Feature traffic is fully charged.

pub mod engines;
pub mod mfg;
pub mod sharing;

/// Options shared by the baseline engines.
#[derive(Clone, Copy, Debug)]
pub struct BaselineOpts {
    /// Ego-network batch size per machine.
    pub batch_size: usize,
    /// Neighbors sampled per hop (0 = full neighborhood).
    pub fanout: usize,
    /// LRU feature-cache capacity in rows (SALIENT++ only).
    pub cache_rows: usize,
    pub seed: u64,
}

impl Default for BaselineOpts {
    fn default() -> Self {
        BaselineOpts { batch_size: 1024, fanout: 50, cache_rows: 4096, seed: 0xBA5E }
    }
}
