//! Sharing-opportunity accounting (Fig. 5, Table 5).
//!
//! All quantities are node *occurrence* counts over sampled ego networks:
//! without sharing, every occurrence is one projection + one aggregation
//! input. Definitions:
//!
//! - `no_sharing` — Σ over roots of per-ego occurrences (per-ego dedup
//!   only, which any MFG builder performs).
//! - `full` — occurrences of one merged batch containing *all* roots (what
//!   Deal's layerwise execution achieves by construction).
//! - an approach's **leveraged sharing ratio** is
//!   `(no_sharing − occ_approach) / (no_sharing − full)` — the fraction of
//!   the total sharing opportunity it captures (1.0 = Deal).
//!
//! Approaches (per the paper's §5 descriptions):
//! - **DGI**: merged batches → within-batch dedup at every layer.
//! - **P³**: the layer consuming `H^(0)` is computed collectively for all
//!   nodes (full dedup there); the remaining layers run per ego network.
//! - **SALIENT++**: DGI-style batches plus an LRU feature cache that
//!   additionally dedups innermost-layer occurrences across batches.

use crate::graph::{Csr, NodeId};
use crate::util::rng::Rng;

use super::mfg::build_mfg;

/// Occurrences for per-ego execution (the no-sharing denominator).
pub fn occ_no_sharing(g: &Csr, k: usize, fanout: usize, seed: u64) -> usize {
    let mut rng = Rng::new(seed);
    let mut total = 0usize;
    for v in 0..g.n_rows {
        let mfg = build_mfg(g, &[v as NodeId], k, fanout, &mut rng);
        total += mfg.node_occurrences();
    }
    total
}

/// Occurrences under batched merged execution (batch size in roots).
pub fn occ_batched(g: &Csr, batch: usize, k: usize, fanout: usize, seed: u64) -> usize {
    let mut rng = Rng::new(seed);
    let roots: Vec<NodeId> = (0..g.n_rows as NodeId).collect();
    roots
        .chunks(batch.max(1))
        .map(|c| build_mfg(g, c, k, fanout, &mut rng).node_occurrences())
        .sum()
}

/// Occurrences of the single all-node batch (full sharing — Deal).
pub fn occ_full(g: &Csr, k: usize, fanout: usize, seed: u64) -> usize {
    occ_batched(g, g.n_rows.max(1), k, fanout, seed)
}

/// P³: within each batch, its hybrid parallelism computes the *first GNN
/// layer* (the outermost hop's aggregation into hop-(k−1) nodes) with
/// model parallelism — full sharing of the innermost layer inside the
/// batch — then every ego network finishes its remaining layers
/// individually ("the outermost hop alone only contributes limited
/// sharings", §4.2: upper layers, which DGI also dedups, get none).
pub fn occ_p3(g: &Csr, batch: usize, k: usize, fanout: usize, seed: u64) -> usize {
    let mut rng = Rng::new(seed);
    let roots: Vec<NodeId> = (0..g.n_rows as NodeId).collect();
    let mut total = 0usize;
    for chunk in roots.chunks(batch.max(1)) {
        // innermost layer: batch-merged (model-parallel first layer)
        let merged = build_mfg(g, chunk, k, fanout, &mut rng);
        total += merged.layer_nodes[0].len();
        // upper layers: per ego, no sharing
        for &v in chunk {
            let ego = build_mfg(g, &[v], k, fanout, &mut rng);
            for l in 1..=k {
                total += ego.layer_nodes[l].len();
            }
        }
    }
    total
}

/// SALIENT++: DGI batches + an LRU cache (capacity in rows) that saves
/// repeated innermost-layer occurrences across batches.
pub fn occ_salient(
    g: &Csr,
    batch: usize,
    cache_rows: usize,
    k: usize,
    fanout: usize,
    seed: u64,
) -> usize {
    let mut rng = Rng::new(seed);
    let roots: Vec<NodeId> = (0..g.n_rows as NodeId).collect();
    let mut total = 0usize;
    let mut cache = super::engines::LruCache::new(cache_rows, 0);
    for c in roots.chunks(batch.max(1)) {
        let mfg = build_mfg(g, c, k, fanout, &mut rng);
        let mut occ = mfg.node_occurrences();
        for &v in &mfg.layer_nodes[0] {
            if cache.get(v).is_some() {
                occ -= 1; // cached: innermost occurrence saved
            } else {
                cache.insert(v, &[]);
            }
        }
        total += occ;
    }
    total
}

/// Leveraged sharing ratio given an approach's occurrence count.
pub fn sharing_ratio(no_sharing: usize, full: usize, approach: usize) -> f64 {
    let potential = no_sharing.saturating_sub(full);
    if potential == 0 {
        return 1.0;
    }
    no_sharing.saturating_sub(approach) as f64 / potential as f64
}

/// Fig. 5 curve: leveraged sharing vs batch size (fraction of all nodes).
pub fn fig5_curve(g: &Csr, fractions: &[f64], k: usize, fanout: usize, seed: u64) -> Vec<(f64, f64)> {
    let no_share = occ_no_sharing(g, k, fanout, seed);
    let full = occ_full(g, k, fanout, seed);
    fractions
        .iter()
        .map(|&f| {
            let batch = ((g.n_rows as f64 * f).round() as usize).max(1);
            let occ = occ_batched(g, batch, k, fanout, seed);
            (f, sharing_ratio(no_share, full, occ))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{rmat, RmatParams};

    fn g() -> Csr {
        Csr::from(&rmat(9, 6000, RmatParams::paper(), 61))
    }

    #[test]
    fn ordering_no_sharing_ge_batched_ge_full() {
        let g = g();
        let ns = occ_no_sharing(&g, 2, 5, 1);
        let b = occ_batched(&g, 64, 2, 5, 1);
        let f = occ_full(&g, 2, 5, 1);
        assert!(ns >= b, "{} >= {}", ns, b);
        assert!(b >= f, "{} >= {}", b, f);
        assert!(f > 0);
    }

    #[test]
    fn ratios_in_unit_interval_and_monotone_in_batch() {
        let g = g();
        let curve = fig5_curve(&g, &[0.01, 0.1, 0.5, 1.0], 2, 5, 2);
        for &(_, r) in &curve {
            assert!((0.0..=1.0001).contains(&r), "ratio {}", r);
        }
        // full batch == full sharing
        assert!((curve.last().unwrap().1 - 1.0).abs() < 1e-9);
        // larger batches never reduce sharing (monotone up to noise)
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1 - 0.05, "curve not monotone: {:?}", curve);
        }
    }

    #[test]
    fn table5_shape_dgi_beats_p3_salient_beats_dgi() {
        let g = g();
        let (k, fanout, seed) = (3, 10, 3);
        let ns = occ_no_sharing(&g, k, fanout, seed);
        let full = occ_full(&g, k, fanout, seed);
        let dgi = sharing_ratio(ns, full, occ_batched(&g, 64, k, fanout, seed));
        let p3 = sharing_ratio(ns, full, occ_p3(&g, 64, k, fanout, seed));
        let sal = sharing_ratio(ns, full, occ_salient(&g, 64, 1 << 20, k, fanout, seed));
        // Paper Table 5 ordering: SALIENT++ ≥ DGI > P³, all < 100%.
        assert!(sal >= dgi, "salient {} >= dgi {}", sal, dgi);
        assert!(dgi > p3, "dgi {} > p3 {}", dgi, p3);
        assert!(sal < 1.0);
        assert!(p3 > 0.0);
    }
}
