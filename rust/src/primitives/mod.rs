//! Deal's distributed GNN primitives (paper §3.4) and their baselines.
//!
//! All primitives operate on the collaborative partition of `partition::
//! PartitionPlan`: machine `(p, m)` holds graph partition `p` (rows, global
//! columns) and feature columns `m` of those rows. Every primitive is
//! written as a *per-machine* function called inside a `cluster::Ctx`
//! closure, moving real bytes through the simulated network:
//!
//! - [`gemm`] — Deal's ring GEMM vs. CAGNET's all-reduce GEMM (Fig. 7,
//!   Table 1; bench `fig16_gemm`).
//! - [`spmm`] — Deal's feature-exchange SPMM vs. exchange-G0 vs.
//!   2-D-style SPMM (Figs. 8–9, Table 2; bench `fig17_spmm`), with the
//!   §3.5 execution modes (monolithic / partitioned groups / pipelined,
//!   Figs. 11–12; bench `fig19_pipeline`).
//! - [`sddmm`] — output-oriented SDDMM, approach (ii) vs. (i) (Fig. 10,
//!   Table 3; bench `fig18_sddmm`).
//! - [`groups`] — the §3.5 non-zero group partitioning shared by SPMM and
//!   SDDMM.
//! - [`costs`] — the closed-form memory/communication models of
//!   Tables 1–3, validated against measured byte counters.

/// Closed-form memory/communication/overlap cost models (Tables 1–3, §4).
pub mod costs;
/// Deal's ring GEMM and the CAGNET-style all-reduce baseline.
pub mod gemm;
/// §3.5 non-zero group partitioning shared by SPMM and SDDMM.
pub mod groups;
/// Output-oriented distributed SDDMM, approaches (i) and (ii).
pub mod sddmm;
/// Feature-exchange distributed SPMM and its baselines.
pub mod spmm;

use crate::partition::PartitionPlan;
use crate::tensor::Matrix;

/// Execution mode for the sparse primitives (§3.5 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Per-edge fetch: one feature row per non-zero, duplicates and all —
    /// the unoptimized baseline Fig. 19's "partitioned communication"
    /// speedup is measured against.
    Naive,
    /// Fetch every remote feature (distinct columns) in one exchange,
    /// then compute.
    Monolithic,
    /// Partitioned communication: group-by-group fetch + compute.
    Grouped,
    /// Grouped with pipelined prefetch (Fig. 12(b,c) reorderings).
    Pipelined,
}

impl ExecMode {
    /// All modes, in ablation order (benches and property tests sweep it).
    pub const ALL: [ExecMode; 4] = [
        ExecMode::Naive,
        ExecMode::Monolithic,
        ExecMode::Grouped,
        ExecMode::Pipelined,
    ];

    /// The config-file / CLI spelling of this mode.
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Naive => "naive",
            ExecMode::Monolithic => "monolithic",
            ExecMode::Grouped => "grouped",
            ExecMode::Pipelined => "pipelined",
        }
    }
}

/// Scatter a full `N × D` matrix into per-rank tiles according to the plan
/// (rank `(p, m)` gets rows of partition `p`, columns of feature part `m`).
/// Test/driver helper — production feature loading uses
/// `coordinator::feature_prep`.
pub fn scatter(plan: &PartitionPlan, full: &Matrix) -> Vec<Matrix> {
    assert_eq!(full.rows, plan.n_nodes);
    assert_eq!(full.cols, plan.feature_dim);
    (0..plan.world())
        .map(|rank| {
            let (p, m) = plan.coords_of(rank);
            let (rlo, rhi) = plan.node_range(p);
            let (clo, chi) = plan.feat_range(m);
            full.slice_rows(rlo, rhi).slice_cols(clo, chi)
        })
        .collect()
}

/// Reassemble per-rank tiles into the full matrix (inverse of `scatter`).
/// `out_dim` is the feature dimension of the tiles' plan (which may differ
/// from `plan.feature_dim` after a GEMM changed the width).
pub fn gather_tiles(plan: &PartitionPlan, out_dim: usize, tiles: &[Matrix]) -> Matrix {
    assert_eq!(tiles.len(), plan.world());
    let out_bounds = crate::util::even_ranges(out_dim, plan.m);
    let mut full = Matrix::zeros(plan.n_nodes, out_dim);
    for rank in 0..plan.world() {
        let (p, m) = plan.coords_of(rank);
        let (rlo, _rhi) = plan.node_range(p);
        let (clo, chi) = (out_bounds[m], out_bounds[m + 1]);
        let t = &tiles[rank];
        assert_eq!(t.rows, plan.rows_of(p), "rank {} row mismatch", rank);
        assert_eq!(t.cols, chi - clo, "rank {} col mismatch", rank);
        for r in 0..t.rows {
            full.row_mut(rlo + r)[clo..chi].copy_from_slice(t.row(r));
        }
    }
    full
}

/// Mean-aggregation edge weights for a (sub-)CSR: `w(e into d) = 1/deg(d)`.
/// The GCN aggregation the paper's workflow example uses.
pub fn mean_weights(csr: &crate::graph::Csr) -> Vec<f32> {
    let mut w = vec![0.0f32; csr.n_edges()];
    for d in 0..csr.n_rows {
        let (lo, hi) = (csr.indptr[d] as usize, csr.indptr[d + 1] as usize);
        let deg = (hi - lo) as f32;
        for e in lo..hi {
            w[e] = 1.0 / deg;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;
    use crate::util::rng::Rng;

    #[test]
    fn scatter_gather_roundtrip() {
        let mut rng = Rng::new(4);
        let plan = PartitionPlan::new(10, 6, 2, 3);
        let full = Matrix::random(10, 6, 1.0, &mut rng);
        let tiles = scatter(&plan, &full);
        assert_eq!(tiles.len(), 6);
        let back = gather_tiles(&plan, 6, &tiles);
        assert_eq!(back, full);
    }

    #[test]
    fn mean_weights_sum_to_one_per_row() {
        let g = Csr::from_edges(4, &[(0, 1), (2, 1), (3, 1), (1, 0)]);
        let w = mean_weights(&g);
        for d in 0..g.n_rows {
            let (lo, hi) = (g.indptr[d] as usize, g.indptr[d + 1] as usize);
            if hi > lo {
                let s: f32 = w[lo..hi].iter().sum();
                assert!((s - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn exec_mode_names() {
        for m in ExecMode::ALL {
            assert!(!m.name().is_empty());
        }
    }
}
