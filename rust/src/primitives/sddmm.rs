//! Distributed SDDMM: `attn = G ⊙ (H_dst · H_src^T)` (paper §3.4 Fig. 10,
//! Table 3; benches `fig18_sddmm`, `fig19_pipeline`).
//!
//! Every non-zero `(s, d)` needs the *full-width* dot product of rows `d`
//! and `s` of `H` — under the collaborative partition both rows are
//! scattered across feature parts, so the computation is assigned
//! **output-oriented**: results land where the sparse matrix lives.
//!
//! - **Approach (ii) — Deal**: the row group splits partition `p`'s rows
//!   into `M` sub-ranges; machine `(p, m)` computes the non-zeros of
//!   sub-range `m` only (fetching `1/M` of the dst rows and only its
//!   sub-range's src rows), then the group all-exchanges the scores
//!   (`NZ(M-1)/PM` result traffic, Table 3).
//! - **Approach (i) — baseline**: every machine computes *all* of
//!   partition `p`'s non-zeros, duplicating compute and fetching the full
//!   dst range + full src set (`(M + MP − 2)·ND/MP` traffic).
//!
//! Fetches use the same concurrent feature server as SPMM; the §3.5
//! execution modes (monolithic / grouped / pipelined) schedule the
//! per-source-partition column groups. Responses stream as row-band
//! chunks (`pipeline.chunk_rows`, §4): a group's `M` column-slice streams
//! are consumed in lock step, and each completed band's dot products run
//! while later chunks are still in flight — bit-identical at every chunk
//! size because scores are per-edge single writes.

use crate::cluster::{Ctx, MatrixStream, Payload, Tag};
use crate::graph::Csr;
use crate::partition::PartitionPlan;
use crate::runtime::par;
use crate::tensor::Matrix;
use crate::util::even_ranges;

/// Element-op floor below which the parallel dot loops stay serial.
const MIN_SDDMM_WORK: u64 = 64 * 1024;

use super::groups::build_groups;
use super::spmm::feature_server;
use super::ExecMode;

const COUNT_SEQ: u32 = u32::MAX;
const RESP_BIT: u32 = 0x8000_0000;

/// Dot products for `g.edges[erange]`: band-parallel on the `runtime::par`
/// pool into a group-ordered scratch, then a serial scatter to global edge
/// ids. One full-width dot and one write per edge, so neither chunk
/// boundaries nor band boundaries can change a score — bit-identical at
/// every chunk size and thread count.
#[allow(clippy::too_many_arguments)]
fn dot_band(
    g: &super::groups::EdgeGroup,
    erange: std::ops::Range<usize>,
    dst_full: &Matrix,
    src_full: &Matrix,
    feature_dim: usize,
    eid_base: usize,
    scores: &mut [f32],
) {
    let n_e = erange.len();
    if n_e == 0 {
        return;
    }
    let work = n_e as u64 * feature_dim as u64;
    let bounds = par::plan_bands(n_e, work, MIN_SDDMM_WORK);
    let mut tmp = vec![0.0f32; n_e];
    let parts = par::split_rows(&mut tmp, &bounds, 1);
    par::run_parts(parts, |_, (rows, band)| {
        for i in rows.clone() {
            let (r, ci) = g.edges[erange.start + i];
            let d = dst_full.row(r as usize);
            let s = src_full.row(ci as usize);
            let mut acc = 0.0f32;
            for (a, b) in d.iter().zip(s) {
                acc += a * b;
            }
            band[i - rows.start] = acc;
        }
    });
    for (i, &score) in tmp.iter().enumerate() {
        scores[eid_base + g.eids[erange.start + i] as usize] = score;
    }
}

/// Inputs for one machine's SDDMM call.
pub struct SddmmInput<'a> {
    /// Plan whose `feature_dim` equals `H`'s width.
    pub plan: &'a PartitionPlan,
    /// Local partition of the graph (`rows_of(p)` rows, global columns).
    pub g: &'a Csr,
    /// Local feature tile `rows_of(p) × feat_width(m)` (src and dst roles
    /// both read from `H^{(l-1)}`, as in GAT attention).
    pub h: &'a Matrix,
}

/// Which SDDMM algorithm (Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SddmmAlgo {
    /// Approach (i): duplicate the computation across the row group.
    Duplicate,
    /// Approach (ii): split non-zeros among the row group, exchange results.
    Split,
}

/// Distributed SDDMM (per machine). Returns the full attention vector for
/// this machine's partition, aligned with `input.g`'s edge order — every
/// row-group member ends with the complete vector (both approaches
/// guarantee it; that is the co-location property §3.4 wants for the
/// following SPMM).
pub fn sddmm(
    ctx: &mut Ctx,
    input: &SddmmInput,
    algo: SddmmAlgo,
    mode: ExecMode,
    max_cols_per_group: usize,
    phase: u32,
) -> Vec<f32> {
    let plan = input.plan;
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let rows = plan.rows_of(p_idx);
    let row_lo = plan.node_range(p_idx).0;
    assert_eq!(input.g.n_rows, rows);

    // ---- Responsibility split.
    let sub = even_ranges(rows, plan.m);
    let (my_rlo, my_rhi) = match algo {
        SddmmAlgo::Split => (sub[m_idx], sub[m_idx + 1]),
        SddmmAlgo::Duplicate => (0, rows),
    };
    // Sub-CSR of my responsible rows (rows rebased; edge ids offset by
    // indptr[my_rlo]).
    let my_g = input.g.slice_rows(my_rlo, my_rhi);
    let eid_base = input.g.indptr[my_rlo] as usize;

    // ---- Build fetch groups over my responsible edges.
    let ones = vec![1.0f32; my_g.n_edges()];
    let groups = ctx.compute(|| match mode {
        ExecMode::Naive => {
            super::groups::build_naive_groups(&my_g, &ones, plan, p_idx)
        }
        ExecMode::Monolithic => build_groups(&my_g, &ones, plan, p_idx, 0),
        _ => build_groups(&my_g, &ones, plan, p_idx, max_cols_per_group),
    });

    // ---- Count messages to every machine's server.
    // Requests to server (q, j):
    //  - src fetches: one per group per feature part j (incl. j == m for
    //    remote partitions; for the own partition, j == m is local).
    //  - dst fetches: one to each row-group peer (p, j), j != m.
    let mut counts = vec![0u32; plan.world()];
    for g in &groups {
        for j in 0..plan.m {
            if g.local && j == m_idx {
                continue; // fully local slice
            }
            counts[plan.rank_of(g.src_part, j)] += 1;
        }
    }
    if my_rhi > my_rlo {
        for j in 0..plan.m {
            if j != m_idx {
                counts[plan.rank_of(p_idx, j)] += 1; // dst fetch
            }
        }
    }
    for rank in 0..plan.world() {
        if rank != ctx.rank {
            ctx.send_service(rank, Tag::of(phase, COUNT_SEQ), Payload::U32(vec![counts[rank]]));
        }
    }

    let h = input.h;
    let expected_peers = plan.world() - 1;
    let scores_mine = ctx.with_server(
        |sctx| feature_server(sctx, h, row_lo, expected_peers, phase),
        |ctx| {
            // ---- Fetch the dst rows (my responsible sub-range, all parts).
            let mut seq: u32 = 0;
            let mut dst_reqs: Vec<(usize, u32)> = Vec::new(); // (rank, seq) per part j
            if my_rhi > my_rlo {
                let dst_ids: Vec<u32> = (my_rlo..my_rhi).map(|r| (r + row_lo) as u32).collect();
                for j in 0..plan.m {
                    if j != m_idx {
                        let rank = plan.rank_of(p_idx, j);
                        ctx.send_service(rank, Tag::of(phase, seq), Payload::U32(dst_ids.clone()));
                        dst_reqs.push((rank, seq));
                        seq += 1;
                    }
                }
            }
            // Assemble full-width dst features for my sub-range.
            let mut dst_full = Matrix::zeros(my_rhi - my_rlo, plan.feature_dim);
            ctx.mem.alloc(dst_full.nbytes());
            {
                let (flo, fhi) = plan.feat_range(m_idx);
                for r in my_rlo..my_rhi {
                    dst_full.row_mut(r - my_rlo)[flo..fhi].copy_from_slice(h.row(r));
                }
            }
            for (i, &(rank, s)) in dst_reqs.iter().enumerate() {
                let j = if i < m_idx { i } else { i + 1 }; // part index of this response
                let block = ctx.recv_matrix(rank, Tag::of(phase, s | RESP_BIT));
                let (flo, fhi) = plan.feat_range(j);
                for r in 0..block.rows {
                    dst_full.row_mut(r)[flo..fhi].copy_from_slice(block.row(r));
                }
            }

            // ---- Schedule src fetch groups per execution mode.
            let mut scores = vec![0.0f32; input.g.n_edges()];
            ctx.mem.alloc((scores.len() * 4) as u64);
            // order: pipelined puts own-partition (cheapest) groups first
            let order: Vec<usize> = match mode {
                ExecMode::Pipelined => {
                    let mut o: Vec<usize> = (0..groups.len()).filter(|&i| groups[i].local).collect();
                    o.extend((0..groups.len()).filter(|&i| !groups[i].local));
                    o
                }
                _ => (0..groups.len()).collect(),
            };
            let lookahead = match mode {
                ExecMode::Naive | ExecMode::Monolithic => groups.len(),
                ExecMode::Grouped => 1,
                ExecMode::Pipelined => 2,
            };
            // send requests with lookahead; each group needs M slices
            // (minus the local slice for own-partition groups)
            let mut req_seq: Vec<Vec<(usize, u32, usize)>> = vec![Vec::new(); groups.len()];
            fn send_group(
                ctx: &mut Ctx,
                plan: &PartitionPlan,
                groups: &[super::groups::EdgeGroup],
                m_idx: usize,
                phase: u32,
                gi: usize,
                seq: &mut u32,
                req_seq: &mut [Vec<(usize, u32, usize)>],
            ) {
                let g = &groups[gi];
                for j in 0..plan.m {
                    if g.local && j == m_idx {
                        continue;
                    }
                    let rank = plan.rank_of(g.src_part, j);
                    ctx.send_service(rank, Tag::of(phase, *seq), Payload::U32(g.cols.clone()));
                    req_seq[gi].push((rank, *seq, j));
                    *seq += 1;
                }
            }
            for &gi in order.iter().take(lookahead) {
                send_group(ctx, plan, &groups, m_idx, phase, gi, &mut seq, &mut req_seq);
            }
            for (pos, &gi) in order.iter().enumerate() {
                if pos + lookahead < order.len() {
                    send_group(ctx, plan, &groups, m_idx, phase, order[pos + lookahead], &mut seq, &mut req_seq);
                }
                let g = &groups[gi];
                // assemble full-width src features for this group's cols
                let mut src_full = Matrix::zeros(g.cols.len(), plan.feature_dim);
                let sb = src_full.nbytes();
                ctx.mem.alloc(sb);
                if g.local {
                    let (flo, fhi) = plan.feat_range(m_idx);
                    for (i, &c) in g.cols.iter().enumerate() {
                        src_full.row_mut(i)[flo..fhi].copy_from_slice(h.row(c as usize - row_lo));
                    }
                }
                // One stream per remote column slice. Every slice covers
                // the same `g.cols` rows with the same chunk plan, so row
                // band `c` of `src_full` is complete as soon as every
                // stream has delivered its chunk `c` — that band's dots
                // run while the later chunks are still in flight (§4).
                let mut streams: Vec<(MatrixStream, usize, usize)> = req_seq[gi]
                    .iter()
                    .map(|&(rank, s, j)| {
                        let st = ctx.open_stream(rank, Tag::of(phase, s | RESP_BIT));
                        let (flo, fhi) = plan.feat_range(j);
                        (st, flo, fhi)
                    })
                    .collect();
                let mut e_at = 0usize;
                if streams.is_empty() {
                    // fully local group (M = 1): no transfers to overlap
                    ctx.compute(|| {
                        dot_band(
                            g,
                            0..g.edges.len(),
                            &dst_full,
                            &src_full,
                            plan.feature_dim,
                            eid_base,
                            &mut scores,
                        )
                    });
                } else {
                    loop {
                        let mut band_end: Option<usize> = None;
                        for (st, flo, fhi) in streams.iter_mut() {
                            let Some((band, chunk)) = st.next(ctx) else { continue };
                            for r in 0..chunk.rows {
                                src_full.row_mut(band.start + r)[*flo..*fhi]
                                    .copy_from_slice(chunk.row(r));
                            }
                            // completed prefix = min over this round's
                            // deliveries (streams already drained are
                            // fully present and stop constraining)
                            band_end = Some(band_end.map_or(band.end, |e| e.min(band.end)));
                        }
                        let Some(end) = band_end else { break };
                        let e_lo = e_at;
                        while e_at < g.edges.len() && (g.edges[e_at].1 as usize) < end {
                            e_at += 1;
                        }
                        let e_hi = e_at;
                        if e_lo < e_hi {
                            ctx.compute(|| {
                                dot_band(
                                    g,
                                    e_lo..e_hi,
                                    &dst_full,
                                    &src_full,
                                    plan.feature_dim,
                                    eid_base,
                                    &mut scores,
                                )
                            });
                        }
                    }
                    assert_eq!(e_at, g.edges.len(), "streamed SDDMM under-consumed its edges");
                }
                ctx.mem.free(sb);
            }
            ctx.mem.free(dst_full.nbytes());
            scores
        },
    );

    // ---- Result exchange (approach ii only): all-gather scores within the
    // row group so everyone holds the full attention vector.
    match algo {
        SddmmAlgo::Duplicate => scores_mine,
        SddmmAlgo::Split => {
            let group = plan.row_group(p_idx);
            let phase2 = phase ^ 0x2000_0000;
            let my_scores = scores_mine[input.g.indptr[my_rlo] as usize
                ..input.g.indptr[my_rhi] as usize]
                .to_vec();
            for (j, &rank) in group.iter().enumerate() {
                if j != m_idx {
                    ctx.send(rank, Tag::of(phase2, m_idx as u32), Payload::F32(my_scores.clone()));
                }
            }
            let mut full = scores_mine;
            for (j, &rank) in group.iter().enumerate() {
                if j != m_idx {
                    let part = ctx.recv(rank, Tag::of(phase2, j as u32)).into_f32();
                    let (lo, hi) = (sub[j], sub[j + 1]);
                    let (elo, ehi) = (input.g.indptr[lo] as usize, input.g.indptr[hi] as usize);
                    assert_eq!(part.len(), ehi - elo);
                    full[elo..ehi].copy_from_slice(&part);
                }
            }
            full
        }
    }
}

/// Dense single-machine oracle: `scores[e=(s,d)] = dot(H[d], H[s])`.
/// Row-parallel over degree-balanced bands; each destination row's edge
/// range is contiguous in CSR order, so bands write disjoint slices and
/// every dot product is computed exactly as the scalar loop would.
pub fn sddmm_reference(g: &Csr, h: &Matrix) -> Vec<f32> {
    assert_eq!(h.rows, g.n_cols);
    let width = h.cols;
    let mut out = vec![0.0f32; g.n_edges()];
    let bounds = par::weighted_bands(
        g.n_rows,
        |r| (g.indptr[r + 1] - g.indptr[r]) * width as u64 + 1,
        MIN_SDDMM_WORK,
    );
    let cuts: Vec<usize> = bounds.iter().map(|&r| g.indptr[r] as usize).collect();
    let slices = par::split_at_cuts(&mut out, &cuts);
    let parts: Vec<(usize, &mut [f32])> = bounds[..bounds.len() - 1]
        .iter()
        .copied()
        .zip(slices)
        .collect();
    par::run_parts(parts, |bi, (rlo, band)| {
        let rhi = bounds[bi + 1];
        let elo = g.indptr[rlo] as usize;
        for d in rlo..rhi {
            let (lo, hi) = (g.indptr[d] as usize, g.indptr[d + 1] as usize);
            let drow = h.row(d);
            for e in lo..hi {
                let srow = h.row(g.indices[e] as usize);
                let mut acc = 0.0f32;
                for (a, b) in drow.iter().zip(srow) {
                    acc += a * b;
                }
                band[e - elo] = acc;
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterReport, NetConfig};
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::graph::NodeId;
    use crate::primitives::scatter;
    use crate::util::prop::{assert_close, run, Config};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn run_sddmm(
        plan: &PartitionPlan,
        g: &Csr,
        h: &Matrix,
        algo: SddmmAlgo,
        mode: ExecMode,
        max_cols: usize,
    ) -> (Vec<Vec<f32>>, ClusterReport) {
        let tiles = Arc::new(scatter(plan, h));
        let mut subs: Vec<Csr> = Vec::new();
        for p in 0..plan.p {
            let (lo, hi) = plan.node_range(p);
            subs.push(g.slice_rows(lo, hi));
        }
        let subs = Arc::new(subs);
        let plan2 = plan.clone();
        let cluster = Cluster::new(plan.world(), NetConfig::default());
        let (outs, report) = cluster
            .run(move |ctx| {
                let (p_idx, _m) = plan2.coords_of(ctx.rank);
                let input = SddmmInput { plan: &plan2, g: &subs[p_idx], h: &tiles[ctx.rank] };
                sddmm(ctx, &input, algo, mode, max_cols, 11)
            })
            .unwrap();
        (outs, report)
    }

    fn check_all(plan: &PartitionPlan, g: &Csr, h: &Matrix, outs: &[Vec<f32>]) -> Result<(), String> {
        let expect = sddmm_reference(g, h);
        for rank in 0..plan.world() {
            let (p_idx, _) = plan.coords_of(rank);
            let (lo, hi) = plan.node_range(p_idx);
            let (elo, ehi) = (g.indptr[lo] as usize, g.indptr[hi] as usize);
            // NOTE: partition sub-CSR re-sorts rows identically (columns
            // already sorted), so edge order matches the global CSR slice.
            assert_close(&outs[rank], &expect[elo..ehi], 1e-4, 1e-4)
                .map_err(|e| format!("rank {}: {}", rank, e))?;
        }
        Ok(())
    }

    #[test]
    fn both_approaches_match_reference() {
        let el = rmat(6, 400, RmatParams::paper(), 17);
        let g = Csr::from(&el);
        let mut rng = Rng::new(5);
        let h = Matrix::random(g.n_cols, 8, 1.0, &mut rng);
        let plan = PartitionPlan::new(g.n_rows, 8, 2, 2);
        for algo in [SddmmAlgo::Split, SddmmAlgo::Duplicate] {
            for mode in ExecMode::ALL {
                let (outs, _) = run_sddmm(&plan, &g, &h, algo, mode, 8);
                check_all(&plan, &g, &h, &outs)
                    .unwrap_or_else(|e| panic!("{:?}/{:?}: {}", algo, mode, e));
            }
        }
    }

    #[test]
    fn sddmm_property_random_plans() {
        run(Config::default().cases(5), |rng| {
            let p = rng.range(1, 4);
            let m = rng.range(1, 4);
            let n = rng.range(p * m * 4, 60);
            let d = rng.range(m.max(2) * 2, 16);
            let ne = rng.range(1, n * 4);
            let edges: Vec<(NodeId, NodeId)> = (0..ne)
                .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
                .collect();
            let g = Csr::from_edges(n, &edges);
            let h = Matrix::random(n, d, 1.0, rng);
            let plan = PartitionPlan::new(n, d, p, m);
            let maxc = [0usize, 4, 16][rng.next_below(3)];
            for algo in [SddmmAlgo::Split, SddmmAlgo::Duplicate] {
                let mode = ExecMode::ALL[rng.next_below(3)];
                let (outs, _) = run_sddmm(&plan, &g, &h, algo, mode, maxc);
                check_all(&plan, &g, &h, &outs)
                    .map_err(|e| format!("{:?}/{:?}: {}", algo, mode, e))?;
            }
            Ok(())
        });
    }

    #[test]
    fn chunked_sddmm_bit_identical_across_chunk_sizes() {
        let el = rmat(7, 500, RmatParams::paper(), 29);
        let g = Csr::from(&el);
        let mut rng = Rng::new(8);
        let h = Matrix::random(g.n_cols, 12, 1.0, &mut rng);
        let plan = PartitionPlan::new(g.n_rows, 12, 2, 2);
        let base = crate::cluster::net::with_chunk_rows(0, || {
            run_sddmm(&plan, &g, &h, SddmmAlgo::Split, ExecMode::Pipelined, 16).0
        });
        for chunk in [1usize, 3, 16, 4096] {
            let got = crate::cluster::net::with_chunk_rows(chunk, || {
                run_sddmm(&plan, &g, &h, SddmmAlgo::Split, ExecMode::Pipelined, 16).0
            });
            assert_eq!(got, base, "chunk_rows={}", chunk);
        }
    }

    #[test]
    fn split_moves_fewer_input_bytes_than_duplicate() {
        let el = rmat(8, 3000, RmatParams::paper(), 23);
        let g = Csr::from(&el);
        let mut rng = Rng::new(6);
        let h = Matrix::random(g.n_cols, 32, 1.0, &mut rng);
        // M large relative to Z makes approach (ii) win (Table 3).
        let plan = PartitionPlan::new(g.n_rows, 32, 2, 4);
        let (_, split) = run_sddmm(&plan, &g, &h, SddmmAlgo::Split, ExecMode::Monolithic, 0);
        let (_, dup) = run_sddmm(&plan, &g, &h, SddmmAlgo::Duplicate, ExecMode::Monolithic, 0);
        assert!(
            split.total_bytes() < dup.total_bytes(),
            "split {} !< dup {}",
            split.total_bytes(),
            dup.total_bytes()
        );
        // and duplicates compute: dup's total compute must exceed split's
        assert!(dup.total_compute() > split.total_compute());
    }
}
