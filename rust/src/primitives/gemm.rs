//! Distributed GEMM: `H' = H @ W` with `H` collaboratively partitioned and
//! `W` replicated (paper §3.4, Fig. 7, Table 1; bench `fig16_gemm`).
//!
//! **Deal's ring GEMM** (Fig. 7b) avoids CAGNET's full-size intermediate:
//! within a row group (the `M` machines sharing one graph partition), each
//! machine re-shards its `rows × D/M` tile *row-wise* into `M` blocks and
//! ring-exchanges them (step 1), so machine `m` temporarily owns sub-rows
//! `m` across the full feature width. It multiplies each arriving block
//! with the matching rows of `W` and accumulates — the intermediate is one
//! `rows/M × D/M` block plus the `rows/M × D_out` accumulator, never
//! `rows × D_out`. A reverse ring exchange (step 3) restores the
//! column-partitioned layout. Communication: `2·(M-1)·rows·D/M²` per
//! machine (Table 1 "Ours").
//!
//! **CAGNET baseline** (Fig. 7a): every machine computes the full partial
//! `rows × D_out` from its column slice (memory `N·D_out/P`), then the row
//! group reduce-scatters — each machine ships `(M-1)` blocks of
//! `rows × D_out/M` (Table 1 "SOTA").

use crate::cluster::{Ctx, Payload, Tag};
use crate::partition::PartitionPlan;
use crate::runtime::Backend;
use crate::tensor::Matrix;
use crate::util::even_ranges;

/// Deal ring GEMM, per-machine. `local` is this rank's `rows_of(p) ×
/// feat_width(m)` tile; `w` is the replicated `feature_dim × d_out`
/// weight. Returns this rank's `rows_of(p) × out_width(m)` tile of `H@W`
/// (output columns split by `even_ranges(d_out, plan.m)`).
///
/// Ring transfers are chunked (`pipeline.chunk_rows`, paper §4): each
/// arriving row band is multiplied with its `W` rows while later bands
/// are still in flight, so a stage costs `max(comm, compute) + fill`
/// instead of `comm + compute`. Results are bit-identical at every chunk
/// size (row-band GEMM preserves per-row dot order; each accumulator row
/// is added to once per stage either way).
pub fn deal_gemm(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    local: &Matrix,
    w: &Matrix,
    backend: &dyn Backend,
    phase: u32,
) -> crate::Result<Matrix> {
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let rows = plan.rows_of(p_idx);
    let mm = plan.m;
    let d_out = w.cols;
    assert_eq!(local.rows, rows);
    assert_eq!(local.cols, plan.feat_width(m_idx));
    assert_eq!(w.rows, plan.feature_dim);
    let group = plan.row_group(p_idx);
    let sub = even_ranges(rows, mm);
    let out_bounds = even_ranges(d_out, mm);

    if mm == 1 {
        // Degenerate: the whole feature width is local.
        let out = ctx.compute(|| backend.gemm(local, w))?;
        ctx.mem.alloc(out.nbytes());
        return Ok(out);
    }

    // ---- Step 1: row-wise re-shard via ring all-to-all (chunked sends up
    // front, non-blocking; receives interleaved with compute below).
    for s in 1..mm {
        let j = (m_idx + s) % mm;
        let block = local.slice_rows(sub[j], sub[j + 1]);
        ctx.send_chunked(group[j], Tag::of(phase, s as u32), block);
    }

    // Accumulator for my sub-rows across the full output width: this is
    // the *only* sizeable intermediate (rows/M × D_out).
    let my_rows = sub[m_idx + 1] - sub[m_idx];
    let mut acc = Matrix::zeros(my_rows, d_out);
    ctx.mem.alloc(acc.nbytes());

    // Local contribution first — overlaps the in-flight transfers.
    let (flo, fhi) = plan.feat_range(m_idx);
    {
        let my_block = local.slice_rows(sub[m_idx], sub[m_idx + 1]);
        let w_rows = w.slice_rows(flo, fhi);
        let part = ctx.compute(|| backend.gemm(&my_block, &w_rows))?;
        add_assign(&mut acc, &part);
    }

    // Ring stages: stream each block from (m - s) mod M as row-band
    // chunks, multiplying every band with the matching W rows as it lands
    // (§4 chunk-level overlap: the tail of the transfer hides behind the
    // band GEMMs). Row-band GEMM keeps each output row's dot products —
    // and the once-per-stage row adds — in the monolithic order, so the
    // result is bit-identical at every chunk size.
    for s in 1..mm {
        let src_pos = (m_idx + mm - s) % mm;
        let (slo, shi) = plan.feat_range(src_pos);
        let w_rows = w.slice_rows(slo, shi);
        let mut err: Option<anyhow::Error> = None;
        ctx.recv_stream(group[src_pos], Tag::of(phase, s as u32), |ctx, band, block| {
            if err.is_some() {
                return;
            }
            ctx.mem.with_transient(block.nbytes(), || ());
            match ctx.compute(|| backend.gemm(&block, &w_rows)) {
                Ok(part) => add_assign_rows(&mut acc, band.start, &part),
                Err(e) => err = Some(e),
            }
        });
        if let Some(e) = err {
            return Err(e);
        }
    }

    // ---- Step 3: reverse exchange to restore column partitioning
    // (chunked the same way; consumption is a copy, so bands just stream
    // into place).
    let phase2 = phase ^ 0x8000_0000;
    for s in 1..mm {
        let j = (m_idx + s) % mm;
        let block = acc.slice_cols(out_bounds[j], out_bounds[j + 1]);
        ctx.send_chunked(group[j], Tag::of(phase2, s as u32), block);
    }
    let my_width = out_bounds[m_idx + 1] - out_bounds[m_idx];
    let mut out = Matrix::zeros(rows, my_width);
    ctx.mem.alloc(out.nbytes());
    {
        let mine = acc.slice_cols(out_bounds[m_idx], out_bounds[m_idx + 1]);
        out.set_rows(sub[m_idx], &mine);
    }
    for s in 1..mm {
        let src_pos = (m_idx + mm - s) % mm;
        ctx.recv_stream(group[src_pos], Tag::of(phase2, s as u32), |_, band, block| {
            out.set_rows(sub[src_pos] + band.start, &block);
        });
    }
    ctx.mem.free(acc.nbytes());
    Ok(out)
}

/// CAGNET-style all-reduce GEMM, per-machine (the Table 1 "SOTA"
/// baseline): full-size partial + reduce-scatter within the row group.
pub fn cagnet_gemm(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    local: &Matrix,
    w: &Matrix,
    backend: &dyn Backend,
    phase: u32,
) -> crate::Result<Matrix> {
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let _rows = plan.rows_of(p_idx);
    let mm = plan.m;
    let d_out = w.cols;
    let group = plan.row_group(p_idx);
    let out_bounds = even_ranges(d_out, mm);
    let (flo, fhi) = plan.feat_range(m_idx);

    // Full-size partial result: rows × d_out — the memory cost Table 1
    // charges CAGNET for.
    let w_rows = w.slice_rows(flo, fhi);
    let partial = ctx.compute(|| backend.gemm(local, &w_rows))?;
    ctx.mem.alloc(partial.nbytes());

    // Reduce-scatter: send every other member its output-column slice.
    for (j, &rank) in group.iter().enumerate() {
        if j != m_idx {
            let block = partial.slice_cols(out_bounds[j], out_bounds[j + 1]);
            ctx.send(rank, Tag::of(phase, m_idx as u32), Payload::Matrix(block));
        }
    }
    let mut out = partial.slice_cols(out_bounds[m_idx], out_bounds[m_idx + 1]);
    ctx.mem.alloc(out.nbytes());
    for (j, &rank) in group.iter().enumerate() {
        if j != m_idx {
            let block = ctx.recv(rank, Tag::of(phase, j as u32)).into_matrix();
            add_assign(&mut out, &block);
        }
    }
    ctx.mem.free(partial.nbytes());
    Ok(out)
}

fn add_assign(acc: &mut Matrix, other: &Matrix) {
    assert_eq!((acc.rows, acc.cols), (other.rows, other.cols));
    for (a, &b) in acc.data.iter_mut().zip(&other.data) {
        *a += b;
    }
}

/// `acc[row_off + r] += other[r]`: the streamed ring stage lands each row
/// band exactly once, preserving the monolithic add's per-element order.
fn add_assign_rows(acc: &mut Matrix, row_off: usize, other: &Matrix) {
    assert_eq!(acc.cols, other.cols);
    for r in 0..other.rows {
        let dst = acc.row_mut(row_off + r);
        for (a, &b) in dst.iter_mut().zip(other.row(r)) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, NetConfig};
    use crate::primitives::{gather_tiles, scatter};
    use crate::util::prop::{assert_close, run, Config};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn run_gemm(
        plan: &PartitionPlan,
        h: &Matrix,
        w: &Matrix,
        deal: bool,
    ) -> (Matrix, crate::cluster::ClusterReport) {
        let tiles = Arc::new(scatter(plan, h));
        let plan2 = plan.clone();
        let w2 = Arc::new(w.clone());
        let cluster = Cluster::new(plan.world(), NetConfig::default());
        let (outs, report) = cluster
            .run(move |ctx| {
                let local = &tiles[ctx.rank];
                let backend = crate::runtime::Native;
                if deal {
                    deal_gemm(ctx, &plan2, local, &w2, &backend, 1).unwrap()
                } else {
                    cagnet_gemm(ctx, &plan2, local, &w2, &backend, 1).unwrap()
                }
            })
            .unwrap();
        (gather_tiles(plan, w.cols, &outs), report)
    }

    #[test]
    fn deal_gemm_matches_dense_oracle() {
        let mut rng = Rng::new(8);
        let plan = PartitionPlan::new(24, 8, 2, 2);
        let h = Matrix::random(24, 8, 1.0, &mut rng);
        let w = Matrix::random(8, 6, 1.0, &mut rng);
        let (got, _) = run_gemm(&plan, &h, &w, true);
        let expect = h.matmul(&w);
        assert_close(&got.data, &expect.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn cagnet_gemm_matches_dense_oracle() {
        let mut rng = Rng::new(9);
        let plan = PartitionPlan::new(20, 9, 2, 3);
        let h = Matrix::random(20, 9, 1.0, &mut rng);
        let w = Matrix::random(9, 5, 1.0, &mut rng);
        let (got, _) = run_gemm(&plan, &h, &w, false);
        let expect = h.matmul(&w);
        assert_close(&got.data, &expect.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn chunked_gemm_bit_identical_across_chunk_sizes() {
        let mut rng = Rng::new(12);
        let plan = PartitionPlan::new(96, 32, 2, 4);
        let h = Matrix::random(96, 32, 1.0, &mut rng);
        let w = Matrix::random(32, 24, 1.0, &mut rng);
        let base = crate::cluster::net::with_chunk_rows(0, || run_gemm(&plan, &h, &w, true).0);
        for chunk in [1usize, 3, 16, 4096] {
            let got =
                crate::cluster::net::with_chunk_rows(chunk, || run_gemm(&plan, &h, &w, true).0);
            assert_eq!(got, base, "chunk_rows={}", chunk);
        }
    }

    #[test]
    fn gemm_property_random_plans() {
        run(Config::default().cases(10), |rng| {
            let p = rng.range(1, 4);
            let m = rng.range(1, 4);
            let n = rng.range(p * m * 2, 60);
            let d = rng.range(m * 2, 24);
            let d_out = rng.range(2, 20);
            let plan = PartitionPlan::new(n, d, p, m);
            let h = Matrix::random(n, d, 1.0, rng);
            let w = Matrix::random(d, d_out, 1.0, rng);
            let expect = h.matmul(&w);
            for deal in [true, false] {
                let (got, _) = run_gemm(&plan, &h, &w, deal);
                assert_close(&got.data, &expect.data, 1e-3, 1e-3)
                    .map_err(|e| format!("deal={}: {}", deal, e))?;
            }
            Ok(())
        });
    }

    #[test]
    fn deal_moves_fewer_bytes_and_less_memory_than_cagnet() {
        let mut rng = Rng::new(10);
        // Need M > 2 for the M/2 communication advantage to show.
        let plan = PartitionPlan::new(128, 64, 2, 4);
        let h = Matrix::random(128, 64, 1.0, &mut rng);
        let w = Matrix::random(64, 64, 1.0, &mut rng);
        let (_, deal_rep) = run_gemm(&plan, &h, &w, true);
        let (_, cag_rep) = run_gemm(&plan, &h, &w, false);
        assert!(
            deal_rep.total_bytes() < cag_rep.total_bytes(),
            "deal bytes {} !< cagnet bytes {}",
            deal_rep.total_bytes(),
            cag_rep.total_bytes()
        );
        assert!(
            deal_rep.max_peak_mem() < cag_rep.max_peak_mem(),
            "deal mem {} !< cagnet mem {}",
            deal_rep.max_peak_mem(),
            cag_rep.max_peak_mem()
        );
    }
}
