//! Closed-form per-machine memory and communication models — the formulas
//! of Tables 1–3 — in *elements* (multiply by 4 for f32 bytes). The
//! `tables_cost_model` bench validates them against the byte counters
//! measured by the simulated cluster.
//!
//! Symbols (paper §3.4): `H` is `N × D`, partitioned into `P` row parts ×
//! `M` column parts (`P·M` machines); the sparse `G_0` is `N × N` with `Z`
//! non-zeros per column on average.

/// Inputs to the cost model.
#[derive(Clone, Copy, Debug)]
pub struct CostParams {
    /// Node count `N`.
    pub n: f64,
    /// Feature dimension `D`.
    pub d: f64,
    /// Graph (row) partitions `P`.
    pub p: f64,
    /// Feature (column) partitions `M`.
    pub m: f64,
    /// Average non-zeros per column of `G_0`.
    pub z: f64,
}

impl CostParams {
    /// Parameters for an `N × D` feature matrix over a `P × M` machine
    /// grid with `z` average non-zeros per `G_0` column.
    pub fn new(n: usize, d: usize, p: usize, m: usize, z: f64) -> Self {
        CostParams { n: n as f64, d: d as f64, p: p as f64, m: m as f64, z }
    }
}

// ---------------------------------------------------------------- Table 1

/// Deal GEMM peak intermediate (elements): one `N/(PM) × D/M` block.
pub fn gemm_ours_memory(c: &CostParams) -> f64 {
    c.n * c.d / (c.p * c.m * c.m)
}

/// CAGNET GEMM peak intermediate (elements): the full `N/P × D` partial.
pub fn gemm_sota_memory(c: &CostParams) -> f64 {
    c.n * c.d / c.p
}

/// Deal GEMM per-machine communication (elements sent): `2·(M−1)·ND/PM²`.
pub fn gemm_ours_comm(c: &CostParams) -> f64 {
    2.0 * c.n * c.d / (c.p * c.m * c.m) * (c.m - 1.0)
}

/// CAGNET GEMM per-machine communication (elements sent):
/// `(M−1)·ND/(PM)`.
pub fn gemm_sota_comm(c: &CostParams) -> f64 {
    c.n * c.d / (c.p * c.m) * (c.m - 1.0)
}

// ---------------------------------------------------------------- Table 2

/// Deal (feature-exchange) SPMM per-machine communication (elements
/// received): non-zero ids + remote unique-column features.
/// `ZN(P−1)/P² + N(P−1)/P² · D/M`.
pub fn spmm_ours_comm(c: &CostParams) -> f64 {
    let frac = (c.p - 1.0) / (c.p * c.p);
    c.z * c.n * frac + c.n * frac * c.d / c.m
}

/// Exchange-G0 SPMM per-machine communication (elements):
/// graph tile traffic + dense partial results:
/// `ZN(P−1)/P² · 2 + ND/(PM) · (P−1)/P` — we charge the graph term its id
/// + value pair (the paper's `D/M` factor there is a typo; dimensional
/// analysis and its own Fig. 17 discussion say the tile is ids+values and
/// the second phase moves dense partials, which dominate).
pub fn spmm_exchange_g0_comm(c: &CostParams) -> f64 {
    let frac = (c.p - 1.0) / (c.p * c.p);
    2.0 * c.z * c.n * frac + c.n * c.d / (c.p * c.m) * (c.p - 1.0) / c.p
}

/// 2-D-style SPMM per-machine communication (elements):
/// same feature fetch as ours + full partial aggregation:
/// `N(P−1)/P² · D/M + ND(M−1)/(PM)`.
pub fn spmm_2d_comm(c: &CostParams) -> f64 {
    let frac = (c.p - 1.0) / (c.p * c.p);
    c.n * frac * c.d / c.m + c.n * c.d * (c.m - 1.0) / (c.p * c.m)
}

// ---------------------------------------------------------------- Table 3

/// SDDMM approach (i) per-machine communication (elements received):
/// `(M + MP − 2) · ND/(MP)`.
pub fn sddmm_dup_comm(c: &CostParams) -> f64 {
    (c.m + c.m * c.p - 2.0) * c.n * c.d / (c.m * c.p)
}

/// SDDMM approach (ii) per-machine communication (elements received):
/// `(M + MP − 2) · ND/(M²P) + NZ(M−1)/(PM)`.
pub fn sddmm_split_comm(c: &CostParams) -> f64 {
    (c.m + c.m * c.p - 2.0) * c.n * c.d / (c.m * c.m * c.p) + c.n * c.z * (c.m - 1.0) / (c.p * c.m)
}

// ------------------------------------------------- intra-rank parallelism

/// Fork/join cost charged per spawned pool worker (thread spawn + scoped
/// join on the host, measured at the tens-of-microseconds scale).
pub const FORK_JOIN_OVERHEAD_SECS: f64 = 25e-6;

/// Simulated seconds for a kernel that consumed `cpu_secs` of **total**
/// CPU (calling thread + every `runtime::par` worker it fanned out to,
/// summed) on a machine with `cores` cores, having spawned `forks`
/// workers. The work term divides total CPU by the machine's core count —
/// the same capacity model `Ctx::compute` always used, except the work is
/// now measured across all real threads instead of one — and the fork
/// term keeps the makespan honest about fan-out overhead: a kernel that
/// sprays threads at tiny tiles pays for it in simulated time too.
pub fn intra_rank_compute_secs(cpu_secs: f64, forks: u64, cores: f64) -> f64 {
    cpu_secs / cores.max(1.0) + FORK_JOIN_OVERHEAD_SECS * forks as f64
}

// ---------------------------------------- pipelined chunked communication

/// Simulated time for one communication/computation step when a transfer
/// of `comm` seconds is split into `k` equal chunks overlapped with
/// `compute` seconds of chunk-local work (paper §4; DESIGN.md
/// §Pipelined-communication): the slower side sets the pace and one chunk
/// of the faster side sticks out as fill (or drain), giving
/// `max(comm, compute) + min(comm, compute) / k`. At `k ≤ 1` the step
/// serializes to `comm + compute` — the monolithic `Ctx::recv` behavior.
/// Per-chunk latency overhead is modeled separately by
/// [`chunking_overhead_secs`]; fold it into `comm` before calling.
pub fn pipelined_step_secs(comm: f64, compute: f64, k: u64) -> f64 {
    if k <= 1 {
        return comm + compute;
    }
    comm.max(compute) + comm.min(compute) / k as f64
}

/// Extra wire time a `k`-chunk transfer pays over a monolithic one: every
/// chunk is its own link transfer, so `(k − 1)` additional latency terms.
/// (Per-chunk envelope bytes are charged by `Payload::nbytes` and already
/// sit in the byte counters.)
pub fn chunking_overhead_secs(latency_secs: f64, k: u64) -> f64 {
    latency_secs * k.saturating_sub(1) as f64
}

/// Chunk count minimizing fill + per-chunk latency,
/// `argmin_k [min(comm, compute)/k + (k − 1)·latency]`:
/// `k* = sqrt(min(comm, compute) / latency)`, at least 1. The
/// `pipeline.chunk_rows` knob is this in row units; the
/// `pipeline_overlap` bench sweeps around it.
pub fn optimal_chunks(comm: f64, compute: f64, latency_secs: f64) -> u64 {
    let overlap = comm.min(compute);
    // NaN must land in the degenerate branch too: `NaN <= 0.0` is false,
    // and `NaN as u64` is 0 — an invalid chunk count.
    if overlap.is_nan() || overlap <= 0.0 {
        return 1;
    }
    (overlap / latency_secs.max(1e-9)).sqrt().round().max(1.0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> CostParams {
        CostParams::new(1 << 20, 128, 4, 4, 20.0)
    }

    #[test]
    fn table1_ratios() {
        let c = params();
        // memory advantage M²×
        let ratio = gemm_sota_memory(&c) / gemm_ours_memory(&c);
        assert!((ratio - c.m * c.m).abs() < 1e-9);
        // communication advantage M/2×
        let ratio = gemm_sota_comm(&c) / gemm_ours_comm(&c);
        assert!((ratio - c.m / 2.0).abs() < 1e-9);
    }

    #[test]
    fn table2_ordering() {
        let c = params();
        let ours = spmm_ours_comm(&c);
        assert!(ours < spmm_exchange_g0_comm(&c));
        assert!(ours < spmm_2d_comm(&c));
    }

    #[test]
    fn table3_split_wins_when_m_grows() {
        // M = 1: both equal (no column split).
        let c1 = CostParams::new(1 << 18, 128, 8, 1, 20.0);
        assert!((sddmm_dup_comm(&c1) - sddmm_split_comm(&c1)).abs() < 1e-6);
        // Larger M: split's input term shrinks M× faster.
        let c4 = CostParams::new(1 << 18, 128, 2, 4, 20.0);
        assert!(sddmm_split_comm(&c4) < sddmm_dup_comm(&c4));
    }

    #[test]
    fn intra_rank_term_charges_work_and_forks() {
        // no forks: pure capacity division, the historical model
        assert!((intra_rank_compute_secs(6.4, 0, 64.0) - 0.1).abs() < 1e-12);
        // forks add overhead on top of the divided work
        let with_forks = intra_rank_compute_secs(6.4, 3, 64.0);
        assert!((with_forks - (0.1 + 3.0 * FORK_JOIN_OVERHEAD_SECS)).abs() < 1e-12);
        // degenerate core count clamps to 1
        assert_eq!(intra_rank_compute_secs(2.0, 0, 0.0), 2.0);
    }

    #[test]
    fn pipelined_step_overlaps() {
        // k = 1 serializes; k → ∞ approaches max(comm, compute).
        assert_eq!(pipelined_step_secs(2.0, 1.0, 1), 3.0);
        assert!((pipelined_step_secs(2.0, 1.0, 4) - 2.25).abs() < 1e-12);
        assert!((pipelined_step_secs(1.0, 2.0, 4) - 2.25).abs() < 1e-12);
        assert!(pipelined_step_secs(2.0, 1.0, 1000) < 2.01);
        // monotone non-increasing in k
        let mut prev = f64::INFINITY;
        for k in 1..=64 {
            let t = pipelined_step_secs(3.0, 2.0, k);
            assert!(t <= prev + 1e-12);
            prev = t;
        }
    }

    #[test]
    fn chunking_overhead_and_optimum() {
        assert_eq!(chunking_overhead_secs(100e-6, 1), 0.0);
        assert!((chunking_overhead_secs(100e-6, 8) - 700e-6).abs() < 1e-12);
        // 10 ms of overlap at 100 µs latency → k* = sqrt(100) = 10
        assert_eq!(optimal_chunks(10e-3, 20e-3, 100e-6), 10);
        assert_eq!(optimal_chunks(0.0, 1.0, 100e-6), 1);
        // the optimum beats both endpoints once overhead is folded in
        let (c, x, lat) = (10e-3, 10e-3, 100e-6);
        let total = |k: u64| {
            pipelined_step_secs(c + chunking_overhead_secs(lat, k), x, k)
        };
        let kstar = optimal_chunks(c, x, lat);
        assert!(total(kstar) < total(1));
        assert!(total(kstar) < total(10_000));
    }

    #[test]
    fn optimal_chunks_edge_cases_never_return_zero() {
        // comm ≈ 0: nothing to overlap → monolithic
        assert_eq!(optimal_chunks(0.0, 1.0, 100e-6), 1);
        assert_eq!(optimal_chunks(f64::MIN_POSITIVE, 1.0, 100e-6), 1);
        // compute ≈ 0: likewise
        assert_eq!(optimal_chunks(1.0, 0.0, 100e-6), 1);
        assert_eq!(optimal_chunks(1.0, -1.0, 100e-6), 1);
        // latency ≈ 0: clamped to 1 ns, finite and ≥ 1 — no div-by-zero
        let k = optimal_chunks(1.0, 1.0, 0.0);
        assert!(k >= 1);
        assert_eq!(k, (1.0f64 / 1e-9).sqrt().round() as u64);
        // NaN inputs (a cost model fed garbage) degrade to monolithic,
        // not to the invalid chunk count 0 that `NaN as u64` produces
        assert_eq!(optimal_chunks(f64::NAN, 1.0, 100e-6), 1);
        assert_eq!(optimal_chunks(1.0, f64::NAN, 100e-6), 1);
        assert_eq!(optimal_chunks(f64::NAN, f64::NAN, 0.0), 1);
        // and the result is always at least 1 across a broad sweep
        for &c in &[0.0, 1e-12, 1e-6, 1.0, 1e3] {
            for &x in &[0.0, 1e-12, 1e-6, 1.0, 1e3] {
                for &l in &[0.0, 1e-9, 1e-6, 1e-3] {
                    assert!(optimal_chunks(c, x, l) >= 1);
                }
            }
        }
    }

    #[test]
    fn chunking_overhead_zero_chunks_saturates() {
        // k = 0 is a degenerate caller value: saturating_sub keeps the
        // overhead at zero instead of underflowing to u64::MAX latencies.
        assert_eq!(chunking_overhead_secs(100e-6, 0), 0.0);
        assert_eq!(chunking_overhead_secs(0.0, 0), 0.0);
    }

    #[test]
    fn intra_rank_compute_fractional_cores_clamp() {
        // cores < 1 (bad config) clamps to one core, never divides by a
        // fraction (which would *inflate* simulated time) or by zero
        assert_eq!(intra_rank_compute_secs(2.0, 0, 0.5), 2.0);
        assert_eq!(intra_rank_compute_secs(2.0, 0, -4.0), 2.0);
        assert!(intra_rank_compute_secs(2.0, 0, 0.0).is_finite());
    }

    #[test]
    fn degenerate_single_machine_is_free() {
        let c = CostParams::new(1024, 64, 1, 1, 10.0);
        assert_eq!(gemm_ours_comm(&c), 0.0);
        assert_eq!(gemm_sota_comm(&c), 0.0);
        assert_eq!(spmm_ours_comm(&c), 0.0);
        assert!(sddmm_split_comm(&c).abs() < 1e-9);
    }
}
