//! Partitioned communication: non-zero group assignment (paper §3.5,
//! Fig. 11).
//!
//! For one machine's graph partition (rows local, columns global), the
//! non-zeros are split into groups:
//!
//! - **group 0 per source partition = the local group**: non-zeros whose
//!   column (source node) lives in this machine's own partition — no
//!   communication needed;
//! - remote non-zeros are bucketed *by source partition* (they must be
//!   fetched from that partition's row group) and, within a source
//!   partition, split by **sorted column id** into chunks of roughly equal
//!   distinct-column count ("we sort the column ID array in CSR and assign
//!   non-zeros in adjacent columns into groups").
//!
//! Each group carries its distinct column list (the id request message) and
//! its edges re-indexed against that list (so the compute loop indexes the
//! received feature buffer directly). Both SPMM and SDDMM consume these.

use crate::graph::{Csr, NodeId};
use crate::partition::PartitionPlan;

/// One communication/computation group.
#[derive(Clone, Debug)]
pub struct EdgeGroup {
    /// Source graph partition the features come from.
    pub src_part: usize,
    /// True iff `src_part` is the owning machine's own partition.
    pub local: bool,
    /// Distinct global column ids referenced by this group, sorted.
    pub cols: Vec<NodeId>,
    /// Edges as `(local_row, col_index_into_cols)`.
    pub edges: Vec<(u32, u32)>,
    /// Per-edge values aligned with `edges` (aggregation weights or ones).
    pub vals: Vec<f32>,
    /// Original edge indices in the source CSR (SDDMM writes its scores
    /// back through these).
    pub eids: Vec<u32>,
}

impl EdgeGroup {
    /// Number of non-zeros assigned to this group.
    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }
}

/// Build the §3.5 groups for partition `p_idx` of `plan` from its local
/// CSR (`rows = plan.rows_of(p_idx)`, global columns) and per-edge values.
///
/// `max_cols_per_group` bounds each remote group's distinct-column count
/// (the paper tunes group size to bound peak memory); `0` means one group
/// per source partition (no sub-splitting).
pub fn build_groups(
    csr: &Csr,
    vals: &[f32],
    plan: &PartitionPlan,
    p_idx: usize,
    max_cols_per_group: usize,
) -> Vec<EdgeGroup> {
    // NOTE: `csr` may be the full partition or a row sub-range of it
    // (SDDMM approach (ii) builds groups over its responsibility rows), so
    // only the value alignment is asserted.
    assert_eq!(vals.len(), csr.n_edges());

    // Bucket edges by source partition, keeping (row, col, val, edge id).
    let mut by_part: Vec<Vec<(u32, NodeId, f32, u32)>> = vec![Vec::new(); plan.p];
    for r in 0..csr.n_rows {
        let (lo, hi) = (csr.indptr[r] as usize, csr.indptr[r + 1] as usize);
        for e in lo..hi {
            let c = csr.indices[e];
            by_part[plan.node_owner(c)].push((r as u32, c, vals[e], e as u32));
        }
    }

    let mut groups = Vec::new();
    // Local group first (Fig. 12(c): schedule the local group to cover the
    // pipeline fill time). Order the remaining source partitions starting
    // after our own so load spreads across serving machines.
    let order: Vec<usize> = std::iter::once(p_idx)
        .chain((1..plan.p).map(|d| (p_idx + d) % plan.p))
        .collect();
    for q in order {
        let mut edges = std::mem::take(&mut by_part[q]);
        if edges.is_empty() {
            continue;
        }
        // Sort by column id so adjacent columns land in the same group.
        // Columns lie within one partition range, so an O(E + range)
        // counting sort beats the comparison sort (§Perf: 1.6x SPMM
        // end-to-end at fanout 50).
        counting_sort_by_col(&mut edges, plan.node_range(q));
        let local = q == p_idx;
        // Split into chunks of at most `max_cols_per_group` distinct cols.
        // The local group is never split (no communication to bound).
        let chunk_limit = if local || max_cols_per_group == 0 {
            usize::MAX
        } else {
            max_cols_per_group
        };
        let mut start = 0usize;
        while start < edges.len() {
            let mut cols: Vec<NodeId> = Vec::new();
            let mut end = start;
            let mut last_col = None;
            while end < edges.len() {
                let c = edges[end].1;
                if Some(c) != last_col {
                    if cols.len() == chunk_limit {
                        break;
                    }
                    cols.push(c);
                    last_col = Some(c);
                }
                end += 1;
            }
            let mut g_edges = Vec::with_capacity(end - start);
            let mut g_vals = Vec::with_capacity(end - start);
            let mut g_eids = Vec::with_capacity(end - start);
            for &(r, c, v, e) in &edges[start..end] {
                let ci = cols.binary_search(&c).unwrap() as u32;
                g_edges.push((r, ci));
                g_vals.push(v);
                g_eids.push(e);
            }
            groups.push(EdgeGroup { src_part: q, local, cols, edges: g_edges, vals: g_vals, eids: g_eids });
            start = end;
        }
    }
    groups
}

/// Counting sort of `(row, col, val, eid)` tuples by `col`, where all
/// columns lie in `[range.0, range.1)`.
fn counting_sort_by_col(edges: &mut Vec<(u32, NodeId, f32, u32)>, range: (usize, usize)) {
    let (lo, hi) = range;
    let width = hi - lo;
    if edges.len() < 64 || width == 0 {
        edges.sort_unstable_by_key(|&(_, c, _, _)| c);
        return;
    }
    let mut counts = vec![0u32; width + 1];
    for &(_, c, _, _) in edges.iter() {
        counts[c as usize - lo + 1] += 1;
    }
    for i in 0..width {
        counts[i + 1] += counts[i];
    }
    let mut out = vec![(0u32, 0 as NodeId, 0.0f32, 0u32); edges.len()];
    for &e in edges.iter() {
        let slot = &mut counts[e.1 as usize - lo];
        out[*slot as usize] = e;
        *slot += 1;
    }
    *edges = out;
}

/// Naive (per-edge) groups: one group per source partition whose `cols`
/// list has one entry *per edge* (duplicates kept) — the unoptimized
/// fetch pattern that partitioned communication improves on (Fig. 19).
pub fn build_naive_groups(
    csr: &Csr,
    vals: &[f32],
    plan: &PartitionPlan,
    p_idx: usize,
) -> Vec<EdgeGroup> {
    assert_eq!(vals.len(), csr.n_edges());
    let mut by_part: Vec<EdgeGroup> = (0..plan.p)
        .map(|q| EdgeGroup {
            src_part: q,
            local: q == p_idx,
            cols: Vec::new(),
            edges: Vec::new(),
            vals: Vec::new(),
            eids: Vec::new(),
        })
        .collect();
    for r in 0..csr.n_rows {
        let (lo, hi) = (csr.indptr[r] as usize, csr.indptr[r + 1] as usize);
        for e in lo..hi {
            let c = csr.indices[e];
            let g = &mut by_part[plan.node_owner(c)];
            let ci = g.cols.len() as u32;
            g.cols.push(c);
            g.edges.push((r as u32, ci));
            g.vals.push(vals[e]);
            g.eids.push(e as u32);
        }
    }
    // local group first, then the others in rotation order
    let mut groups = Vec::with_capacity(plan.p);
    for d in 0..plan.p {
        let q = (p_idx + d) % plan.p;
        let g = std::mem::replace(
            &mut by_part[q],
            EdgeGroup {
                src_part: q,
                local: false,
                cols: Vec::new(),
                edges: Vec::new(),
                vals: Vec::new(),
                eids: Vec::new(),
            },
        );
        if !g.edges.is_empty() {
            groups.push(g);
        }
    }
    groups
}

/// Validate that groups exactly cover the CSR's edges (property tests).
pub fn validate_cover(groups: &[EdgeGroup], csr: &Csr, plan: &PartitionPlan, p_idx: usize) -> Result<(), String> {
    let total: usize = groups.iter().map(|g| g.n_edges()).sum();
    if total != csr.n_edges() {
        return Err(format!("groups cover {} edges, csr has {}", total, csr.n_edges()));
    }
    let mut seen: Vec<(u32, NodeId)> = Vec::with_capacity(total);
    for g in groups {
        let (plo, phi) = plan.node_range(g.src_part);
        for (i, &(r, ci)) in g.edges.iter().enumerate() {
            let c = g.cols[ci as usize];
            if !((plo as NodeId) <= c && c < phi as NodeId) {
                return Err(format!("group col {} outside src part {}", c, g.src_part));
            }
            if g.local != (g.src_part == p_idx) {
                return Err("local flag wrong".into());
            }
            let _ = i;
            seen.push((r, c));
        }
        // distinct, sorted cols
        for w in g.cols.windows(2) {
            if w[0] >= w[1] {
                return Err("group cols not sorted/distinct".into());
            }
        }
    }
    seen.sort_unstable();
    let mut expect: Vec<(u32, NodeId)> = Vec::with_capacity(total);
    for r in 0..csr.n_rows {
        for &c in csr.row(r) {
            expect.push((r as u32, c));
        }
    }
    expect.sort_unstable();
    if seen != expect {
        return Err("group edges != csr edges".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run, Config};

    fn plan_4x() -> PartitionPlan {
        PartitionPlan::new(8, 4, 2, 2)
    }

    #[test]
    fn figure11_grouping() {
        // partition 0 (rows 0-3) of an 8-node graph; sources span both
        // partitions.
        let plan = plan_4x();
        let edges = vec![
            (0u32, 0u32),
            (2, 0),
            (5, 0), // remote
            (1, 1),
            (4, 1), // remote
            (6, 2), // remote
            (3, 3),
            (7, 3), // remote
        ];
        let csr = Csr::from_edges_rect(4, 8, &edges);
        let vals = vec![1.0; csr.n_edges()];
        let groups = build_groups(&csr, &vals, &plan, 0, 2);
        validate_cover(&groups, &csr, &plan, 0).unwrap();
        // first group must be the local one
        assert!(groups[0].local);
        assert_eq!(groups[0].src_part, 0);
        // remote groups have ≤ 2 distinct cols each
        for g in &groups[1..] {
            assert!(!g.local);
            assert!(g.cols.len() <= 2);
            assert_eq!(g.src_part, 1);
        }
        // remote cols are 4..8 split as [4,5], [6,7]
        let remote_cols: Vec<Vec<NodeId>> = groups[1..].iter().map(|g| g.cols.clone()).collect();
        assert_eq!(remote_cols, vec![vec![4, 5], vec![6, 7]]);
    }

    #[test]
    fn local_group_first_even_when_other_parts_present() {
        let plan = plan_4x();
        let edges = vec![(4u32, 0u32), (0, 1)];
        let csr = Csr::from_edges_rect(4, 8, &edges);
        let groups = build_groups(&csr, &[1.0, 1.0], &plan, 0, 0);
        assert_eq!(groups.len(), 2);
        assert!(groups[0].local);
    }

    #[test]
    fn grouping_cover_property() {
        run(Config::default().cases(24), |rng| {
            let p = rng.range(1, 5);
            let m = rng.range(1, 4);
            let n = rng.range(p * 2, 120);
            let plan = PartitionPlan::new(n, 16, p, m);
            let p_idx = rng.next_below(p);
            let rows = plan.rows_of(p_idx);
            let ne = rng.range(0, 300);
            let edges: Vec<(NodeId, NodeId)> = (0..ne)
                .map(|_| (rng.next_below(n) as NodeId, rng.next_below(rows) as NodeId))
                .collect();
            let csr = Csr::from_edges_rect(rows, n, &edges);
            let vals: Vec<f32> = (0..csr.n_edges()).map(|_| rng.next_f32()).collect();
            let max_cols = [0usize, 1, 4, 16][rng.next_below(4)];
            let groups = build_groups(&csr, &vals, &plan, p_idx, max_cols);
            validate_cover(&groups, &csr, &plan, p_idx)?;
            if max_cols > 0 {
                for g in &groups {
                    if !g.local && g.cols.len() > max_cols {
                        return Err(format!("group has {} cols > {}", g.cols.len(), max_cols));
                    }
                }
            }
            Ok(())
        });
    }
}
