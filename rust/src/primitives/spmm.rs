//! Distributed SPMM: `H1 = G · H'` (element-weighted aggregation) under the
//! collaborative partition (paper §3.4 Fig. 8, §3.5 Figs. 11–12; Table 2;
//! benches `fig17_spmm`, `fig19_pipeline`).
//!
//! **Deal (feature exchange)**: machine `(p, m)` computes `H1[R_p, F_m]`
//! from its local `G_p` and `H'[·, F_m]` fetched by column id from the
//! machines `(q, m)` owning remote source rows. Fetches go through each
//! machine's *feature server* (a concurrent thread, as in any RPC-based
//! GNN system); the requester's schedule implements the §3.5 execution
//! modes:
//!
//! - `Monolithic`: all ids out, all features in, then compute — the
//!   peak-memory blowup of Fig. 3b.
//! - `Grouped` (Fig. 12a): non-zeros split into column groups; ids for
//!   group g+1 go out right before features for g are consumed — partial
//!   overlap, bounded memory, but an ids→features serialization bubble.
//! - `Pipelined` (Fig. 12b+c): ids run two groups ahead so the pipe
//!   stays full behind the local compute.
//!
//! All three production modes accumulate in one **canonical order** —
//! local groups first, then remote groups in group-sequence order (the
//! order [`build_groups`] emits, local partition leading). Since float
//! accumulation is order-sensitive, sharing the order is what makes the
//! mode choice value-invariant: the runtime autotuner may switch modes
//! per layer and the outputs stay bit-identical. The modes differ only
//! in *scheduling* — when ids go out and responses are consumed.
//! `Naive` (per-edge groups in raw partition order) sits outside this
//! family and is never selected by the autotuner.
//!
//! Orthogonally to the mode, feature responses stream as row-band
//! **chunks** (`pipeline.chunk_rows`; paper §4): grouped/pipelined
//! requesters feed each arriving band's edge run straight into the
//! accumulation while later bands are in flight, which is bit-identical
//! to the monolithic receive because group edges are sorted by column.
//!
//! **Exchange-G0 baseline**: ship the sparse tile + edge values to the
//! feature owners and get partial results back (its second phase moves
//! dense partials, which is why Table 2 ranks it worse).
//!
//! **2-D-style baseline**: each row-group member aggregates only its
//! column chunk of sources, then the row group all-exchanges full-size
//! partials (the `ND(M-1)/PM` aggregation term of Table 2).

use crate::cluster::{Ctx, Payload, ServerCtx, Tag};
use crate::graph::{Csr, NodeId};
use crate::partition::PartitionPlan;
use crate::runtime::{par, Backend};
use crate::storage::{PagedMatrix, SharedPageCache};
use crate::tensor::Matrix;
use crate::util::even_ranges;
use crate::Result;

/// Element-op floor below which the row-parallel CSR kernels stay serial.
const MIN_SPMM_WORK: u64 = 64 * 1024;

/// Degree-balanced row bands for a CSR aggregation over `width` feature
/// columns: band weight = row nnz × width plus a constant per-row term.
fn csr_row_bands(g: &Csr, width: usize) -> Vec<usize> {
    par::weighted_bands(
        g.n_rows,
        |r| (g.indptr[r + 1] - g.indptr[r]) * width as u64 + 1,
        MIN_SPMM_WORK,
    )
}

use super::groups::{build_groups, EdgeGroup};
use super::ExecMode;

/// Request seq used for the count message.
const COUNT_SEQ: u32 = u32::MAX;
/// Response tags set the top bit of the seq.
const RESP_BIT: u32 = 0x8000_0000;

/// Per-edge values for the three-tensor SPMM (paper §3.4: `H1[][i] =
/// multiply_G(E[i][], H'[][i])` — edge features multiply feature columns).
pub enum EdgeValues<'a> {
    /// One weight per edge (GCN mean aggregation).
    Scalar(&'a [f32]),
    /// Per-edge per-head weights (GAT attention): `vals[eid * heads + h]`,
    /// with `col_head[j]` mapping this machine's local feature column `j`
    /// to its head.
    PerHead {
        vals: &'a [f32],
        heads: usize,
        col_head: &'a [u8],
    },
}

impl<'a> EdgeValues<'a> {
    /// Scalar weights used for group construction (ones for per-head).
    fn group_vals(&self, n_edges: usize) -> std::borrow::Cow<'a, [f32]> {
        match self {
            EdgeValues::Scalar(v) => std::borrow::Cow::Borrowed(v),
            EdgeValues::PerHead { .. } => std::borrow::Cow::Owned(vec![1.0; n_edges]),
        }
    }
}

/// Inputs for one machine's SPMM call.
pub struct SpmmInput<'a> {
    /// Plan whose `feature_dim` equals `H'`'s width.
    pub plan: &'a PartitionPlan,
    /// Local partition of the (sampled) graph: `rows_of(p)` rows, global
    /// columns.
    pub g: &'a Csr,
    /// Per-edge aggregation values aligned with `g`.
    pub vals: EdgeValues<'a>,
    /// Local feature tile `rows_of(p) × feat_width(m)`.
    pub h: &'a Matrix,
}

impl<'a> SpmmInput<'a> {
    fn scalar_vals(&self) -> &'a [f32] {
        match self.vals {
            EdgeValues::Scalar(v) => v,
            _ => panic!("this SPMM path supports scalar edge values only"),
        }
    }
}

/// Run the feature-server side: answer `expected_peers` peers' gather
/// requests against `h` (rows are this machine's partition, `row_lo`
/// global offset). Each peer first sends a COUNT message (its number of
/// requests), then that many id lists; the server replies with the
/// gathered rows, streamed as row-band chunks (`ServerCtx::send_chunked`)
/// so the requester can fold compute into the tail of each response.
pub fn feature_server(
    sctx: &mut ServerCtx,
    h: &Matrix,
    row_lo: usize,
    expected_peers: usize,
    phase: u32,
) {
    let mut counts_pending = expected_peers;
    let mut to_serve: u64 = 0;
    let mut served: u64 = 0;
    while counts_pending > 0 || served < to_serve {
        let msg = sctx.recv_any(phase);
        let seq = (msg.tag & 0xFFFF_FFFF) as u32;
        if seq == COUNT_SEQ {
            let c = msg.payload.into_u32();
            to_serve += c[0] as u64;
            counts_pending -= 1;
            continue;
        }
        let ids = msg.payload.into_u32();
        let gathered = sctx.compute(|| {
            let idx: Vec<usize> = ids.iter().map(|&c| c as usize - row_lo).collect();
            h.gather_rows(&idx)
        });
        sctx.send_chunked(msg.src, Tag::of(phase, seq | RESP_BIT), gathered);
        served += 1;
    }
}

/// The out-of-core twin of [`feature_server`]: the serving tile lives in
/// a [`PagedMatrix`] behind the rank's budgeted [`SharedPageCache`], so
/// each gather faults in only the pages it touches and the response
/// streams from the cache straight into the existing chunked-send path
/// (`ServerCtx::send_chunked`). Gathered values are bit-identical to the
/// resident tile's; only page-fault counts and simulated I/O time depend
/// on the budget.
pub fn paged_feature_server(
    sctx: &mut ServerCtx,
    h: &PagedMatrix,
    cache: &SharedPageCache,
    row_lo: usize,
    expected_peers: usize,
    phase: u32,
) {
    let mut counts_pending = expected_peers;
    let mut to_serve: u64 = 0;
    let mut served: u64 = 0;
    while counts_pending > 0 || served < to_serve {
        let msg = sctx.recv_any(phase);
        let seq = (msg.tag & 0xFFFF_FFFF) as u32;
        if seq == COUNT_SEQ {
            let c = msg.payload.into_u32();
            to_serve += c[0] as u64;
            counts_pending -= 1;
            continue;
        }
        let ids = msg.payload.into_u32();
        let (gathered, io) = sctx.compute(|| {
            let idx: Vec<usize> = ids.iter().map(|&c| c as usize - row_lo).collect();
            h.gather_shared(cache, &idx).expect("paged feature gather failed")
        });
        sctx.advance(io);
        sctx.send_chunked(msg.src, Tag::of(phase, seq | RESP_BIT), gathered);
        served += 1;
    }
}

/// Deal's distributed SPMM (per machine). Returns `H1[R_p, F_m]`.
pub fn deal_spmm(
    ctx: &mut Ctx,
    input: &SpmmInput,
    backend: &dyn Backend,
    mode: ExecMode,
    max_cols_per_group: usize,
    phase: u32,
) -> Matrix {
    let plan = input.plan;
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let width = plan.feat_width(m_idx);
    let rows = plan.rows_of(p_idx);
    assert_eq!(input.h.rows, rows);
    assert_eq!(input.h.cols, width);

    // Single graph partition: everything is local — aggregate straight
    // off the CSR with degree-balanced row bands, no grouping, no
    // communication (§Perf fast path).
    if plan.p == 1 {
        let row_lo = plan.node_range(p_idx).0;
        let mut out = Matrix::zeros(rows, width);
        ctx.mem.alloc(out.nbytes());
        ctx.compute(|| {
            let g = input.g;
            let h = input.h;
            let bounds = csr_row_bands(g, width);
            let parts = par::split_rows(&mut out.data, &bounds, width);
            par::run_parts(parts, |_, (rows, band)| match &input.vals {
                EdgeValues::Scalar(vals) => {
                    for r in rows.clone() {
                        let (lo, hi) = (g.indptr[r] as usize, g.indptr[r + 1] as usize);
                        let at = (r - rows.start) * width;
                        let orow = &mut band[at..at + width];
                        for e in lo..hi {
                            let src = h.row(g.indices[e] as usize - row_lo);
                            let v = vals[e];
                            for (o, &x) in orow.iter_mut().zip(src) {
                                *o += v * x;
                            }
                        }
                    }
                }
                EdgeValues::PerHead { vals, heads, col_head } => {
                    for r in rows.clone() {
                        let (lo, hi) = (g.indptr[r] as usize, g.indptr[r + 1] as usize);
                        let at = (r - rows.start) * width;
                        let orow = &mut band[at..at + width];
                        for e in lo..hi {
                            let src = h.row(g.indices[e] as usize - row_lo);
                            let ev = &vals[e * heads..(e + 1) * heads];
                            for j in 0..orow.len() {
                                orow[j] += ev[col_head[j] as usize] * src[j];
                            }
                        }
                    }
                }
            });
        });
        return out;
    }

    // Group construction (Monolithic uses one group per source partition;
    // Naive skips the sort/dedup entirely — per-edge fetch).
    let gvals = input.vals.group_vals(input.g.n_edges());
    let groups = ctx.compute(|| match mode {
        ExecMode::Naive => super::groups::build_naive_groups(input.g, &gvals, plan, p_idx),
        ExecMode::Monolithic => build_groups(input.g, &gvals, plan, p_idx, 0),
        _ => build_groups(input.g, &gvals, plan, p_idx, max_cols_per_group),
    });

    // Count messages so every peer's server knows how many requests to
    // expect from us (0 is a valid count).
    let mut per_peer: Vec<u32> = vec![0; plan.p];
    for g in &groups {
        if !g.local {
            per_peer[g.src_part] += 1;
        }
    }
    for q in 0..plan.p {
        if q != p_idx {
            ctx.send_service(
                plan.rank_of(q, m_idx),
                Tag::of(phase, COUNT_SEQ),
                Payload::U32(vec![per_peer[q]]),
            );
        }
    }

    let h = input.h;
    let row_lo = plan.node_range(p_idx).0;
    let expected_peers = plan.p - 1;
    ctx.with_server(
        |sctx| feature_server(sctx, h, row_lo, expected_peers, phase),
        |ctx| {
            let mut out = Matrix::zeros(rows, width);
            ctx.mem.alloc(out.nbytes());
            let acc = Accum { values: &input.vals, backend };
            match mode {
                ExecMode::Naive | ExecMode::Monolithic => run_monolithic(
                    ctx, plan, m_idx, &groups, h, row_lo, &mut out, &acc, phase, None,
                ),
                ExecMode::Grouped => run_grouped(
                    ctx, plan, m_idx, &groups, h, row_lo, &mut out, &acc, phase, 1, None,
                ),
                ExecMode::Pipelined => run_grouped(
                    ctx, plan, m_idx, &groups, h, row_lo, &mut out, &acc, phase, 2, None,
                ),
            }
            out
        },
    )
}

/// Inputs for one machine's out-of-core SPMM call: the local feature tile
/// lives in a [`PagedMatrix`] behind the rank's budgeted cache instead of
/// resident RAM.
pub struct PagedSpmmInput<'a> {
    /// Plan whose `feature_dim` equals `H'`'s width.
    pub plan: &'a PartitionPlan,
    /// Local partition of the (sampled) graph: `rows_of(p)` rows, global
    /// columns.
    pub g: &'a Csr,
    /// Per-edge aggregation values aligned with `g`.
    pub vals: EdgeValues<'a>,
    /// Paged local feature tile, `rows_of(p) × feat_width(m)`.
    pub h: &'a PagedMatrix,
    /// The rank's shared page cache holding `h`'s pages.
    pub cache: &'a SharedPageCache,
}

/// Deal's distributed SPMM over a **paged** local tile (DESIGN.md
/// §Out-of-core-storage): the feature server streams gathered rows from
/// the budgeted cache into the chunked-send path, each local group
/// gathers its source rows through the cache **right before it
/// accumulates** (one group's block resident at a time — never the whole
/// tile), and remote groups stream off the wire exactly as in
/// [`deal_spmm`]. Every destination row accumulates its edges in the
/// same order as the in-memory path, so the result is bit-identical at
/// every budget and page size — only fault counts and simulated I/O
/// time change.
pub fn deal_spmm_paged(
    ctx: &mut Ctx,
    input: &PagedSpmmInput,
    backend: &dyn Backend,
    mode: ExecMode,
    max_cols_per_group: usize,
    phase: u32,
) -> Result<Matrix> {
    let plan = input.plan;
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let width = plan.feat_width(m_idx);
    let rows = plan.rows_of(p_idx);
    assert_eq!(input.h.rows, rows);
    assert_eq!(input.h.cols, width);
    let row_lo = plan.node_range(p_idx).0;

    // Single graph partition: everything is local — aggregate straight
    // off the CSR, copying each edge's source row out of the cache into a
    // reused scratch buffer. No server runs at p = 1, so one lock covers
    // the whole serial pass (per-edge work happens on page-resident
    // frames). Every destination row consumes its edges in CSR order,
    // the same per-destination order as the banded in-memory kernel, so
    // the result is bit-identical; the serial schedule is the honest
    // price of reading through the cache.
    if plan.p == 1 {
        let g = input.g;
        let h = input.h;
        let mut out = Matrix::zeros(rows, width);
        ctx.mem.alloc(out.nbytes());
        let mut io_total = 0.0f64;
        let vals_ref = &input.vals;
        ctx.compute(|| {
            input.cache.with(|c| {
                for r in 0..g.n_rows {
                    let (lo, hi) = (g.indptr[r] as usize, g.indptr[r + 1] as usize);
                    if lo == hi {
                        continue;
                    }
                    let orow = out.row_mut(r);
                    for e in lo..hi {
                        let sr = g.indices[e] as usize - row_lo;
                        // borrow the source row in the resident frame —
                        // no per-edge copy, faults only on page misses
                        let src = c
                            .read_row(h.file, sr)
                            .expect("paged SPMM gather failed");
                        match vals_ref {
                            EdgeValues::Scalar(vals) => {
                                let v = vals[e];
                                for (o, &x) in orow.iter_mut().zip(src) {
                                    *o += v * x;
                                }
                            }
                            EdgeValues::PerHead { vals, heads, col_head } => {
                                let ev = &vals[e * heads..(e + 1) * heads];
                                for j in 0..orow.len() {
                                    orow[j] += ev[col_head[j] as usize] * src[j];
                                }
                            }
                        }
                    }
                }
                io_total = c.take_io_secs();
            });
        });
        ctx.advance(io_total);
        crate::storage::charge_main(ctx, input.cache);
        return Ok(out);
    }

    // Group construction: identical to the in-memory path.
    let gvals = input.vals.group_vals(input.g.n_edges());
    let groups = ctx.compute(|| match mode {
        ExecMode::Naive => super::groups::build_naive_groups(input.g, &gvals, plan, p_idx),
        ExecMode::Monolithic => build_groups(input.g, &gvals, plan, p_idx, 0),
        _ => build_groups(input.g, &gvals, plan, p_idx, max_cols_per_group),
    });

    let mut per_peer: Vec<u32> = vec![0; plan.p];
    for g in &groups {
        if !g.local {
            per_peer[g.src_part] += 1;
        }
    }
    for q in 0..plan.p {
        if q != p_idx {
            ctx.send_service(
                plan.rank_of(q, m_idx),
                Tag::of(phase, COUNT_SEQ),
                Payload::U32(vec![per_peer[q]]),
            );
        }
    }

    let store = *input.h;
    let cache = input.cache.clone();
    let paged_local = PagedLocal { store: input.h, cache: input.cache, row_lo };
    let expected_peers = plan.p - 1;
    // remote groups always accumulate from fetched/streamed blocks and
    // local groups gather on demand through `paged_local`, so the
    // resident-tile argument is never read — a width-matched empty
    // matrix stands in for it.
    let empty = Matrix::zeros(0, width);
    let out = ctx.with_server(
        |sctx| paged_feature_server(sctx, &store, &cache, row_lo, expected_peers, phase),
        |ctx| {
            let mut out = Matrix::zeros(rows, width);
            ctx.mem.alloc(out.nbytes());
            let acc = Accum { values: &input.vals, backend };
            match mode {
                ExecMode::Naive | ExecMode::Monolithic => run_monolithic(
                    ctx, plan, m_idx, &groups, &empty, row_lo, &mut out, &acc, phase,
                    Some(&paged_local),
                ),
                ExecMode::Grouped => run_grouped(
                    ctx, plan, m_idx, &groups, &empty, row_lo, &mut out, &acc, phase, 1,
                    Some(&paged_local),
                ),
                ExecMode::Pipelined => run_grouped(
                    ctx, plan, m_idx, &groups, &empty, row_lo, &mut out, &acc, phase, 2,
                    Some(&paged_local),
                ),
            }
            out
        },
    );
    crate::storage::charge_main(ctx, input.cache);
    Ok(out)
}

/// On-demand local-group source for the paged SPMM: gathers one group's
/// rows through the budgeted cache right before that group accumulates,
/// so at most one local block is resident at a time (the out-of-core
/// twin of reading the resident tile in place; same values, same order).
struct PagedLocal<'a> {
    store: &'a PagedMatrix,
    cache: &'a SharedPageCache,
    row_lo: usize,
}

impl PagedLocal<'_> {
    /// Gather `g.cols`' rows (block layout = the fetched-group layout
    /// `accumulate_group` expects), charging the I/O to `ctx`.
    fn gather_group(&self, ctx: &mut Ctx, g: &EdgeGroup) -> Matrix {
        let idx: Vec<usize> = g.cols.iter().map(|&c| c as usize - self.row_lo).collect();
        let (block, io) = self
            .store
            .gather_shared(self.cache, &idx)
            .expect("paged SPMM local gather failed");
        ctx.advance(io);
        block
    }
}

/// Monolithic: all requests, all responses, then all compute.
/// `paged_local` (the out-of-core path) gathers each local group's rows
/// through the budgeted cache right before accumulating it; `None` reads
/// the resident tile `h` directly.
#[allow(clippy::too_many_arguments)]
fn run_monolithic(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    m_idx: usize,
    groups: &[EdgeGroup],
    h: &Matrix,
    row_lo: usize,
    out: &mut Matrix,
    acc: &Accum,
    phase: u32,
    paged_local: Option<&PagedLocal>,
) {
    for (seq, g) in groups.iter().enumerate() {
        if !g.local {
            let server = plan.rank_of(g.src_part, m_idx);
            ctx.send_service(server, Tag::of(phase, seq as u32), Payload::U32(g.cols.clone()));
        }
    }
    let mut feats: Vec<Option<Matrix>> = vec![None; groups.len()];
    let mut held_bytes = 0u64;
    for (seq, g) in groups.iter().enumerate() {
        if !g.local {
            let server = plan.rank_of(g.src_part, m_idx);
            // assembled receive: the monolithic mode deliberately keeps
            // its all-comm-then-all-compute shape (the Fig. 3b baseline),
            // even when the wire protocol streams chunks under it
            let m = ctx.recv_matrix(server, Tag::of(phase, seq as u32 | RESP_BIT));
            held_bytes += m.nbytes();
            ctx.mem.alloc(m.nbytes());
            feats[seq] = Some(m);
        }
    }
    for (seq, g) in groups.iter().enumerate() {
        let local_block = match paged_local {
            Some(p) if g.local => Some(p.gather_group(ctx, g)),
            _ => None,
        };
        if let Some(b) = &local_block {
            ctx.mem.alloc(b.nbytes());
        }
        let feats_ref = feats[seq].as_ref().or(local_block.as_ref());
        ctx.compute(|| acc.accumulate_group(g, feats_ref, h, row_lo, out));
        if let Some(b) = &local_block {
            ctx.mem.free(b.nbytes());
        }
    }
    ctx.mem.free(held_bytes);
}

/// Grouped / pipelined: `lookahead` groups of ids in flight; the local
/// (no-communication) groups are always computed first so they cover
/// the pipe-fill time (Fig. 12c) *and* so every mode shares the
/// canonical accumulation order (see the module doc — this is what
/// keeps the autotuner's per-layer mode choice value-invariant).
/// `paged_local` as in [`run_monolithic`].
#[allow(clippy::too_many_arguments)]
fn run_grouped(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    m_idx: usize,
    groups: &[EdgeGroup],
    h: &Matrix,
    row_lo: usize,
    out: &mut Matrix,
    acc: &Accum,
    phase: u32,
    lookahead: usize,
    paged_local: Option<&PagedLocal>,
) {
    // Split group indices into local and remote, preserving order.
    let local_idx: Vec<usize> = (0..groups.len()).filter(|&i| groups[i].local).collect();
    let remote_idx: Vec<usize> = (0..groups.len()).filter(|&i| !groups[i].local).collect();

    let send_ids = |ctx: &mut Ctx, gi: usize| {
        let g = &groups[gi];
        let server = plan.rank_of(g.src_part, m_idx);
        ctx.send_service(server, Tag::of(phase, gi as u32), Payload::U32(g.cols.clone()));
    };

    // Prime the pipeline.
    for &gi in remote_idx.iter().take(lookahead) {
        send_ids(ctx, gi);
    }
    let run_local = |ctx: &mut Ctx, out: &mut Matrix, gi: usize| {
        let block = paged_local.map(|p| p.gather_group(ctx, &groups[gi]));
        if let Some(b) = &block {
            ctx.mem.alloc(b.nbytes());
        }
        let feats = block.as_ref();
        ctx.compute(|| acc.accumulate_group(&groups[gi], feats, h, row_lo, out));
        if let Some(b) = &block {
            ctx.mem.free(b.nbytes());
        }
    };

    // Fig. 12(c): the no-communication groups cover the fill time, and
    // running them first matches the canonical accumulation order.
    for &gi in &local_idx {
        run_local(ctx, out, gi);
    }
    for (pos, &gi) in remote_idx.iter().enumerate() {
        if pos + lookahead < remote_idx.len() {
            send_ids(ctx, remote_idx[pos + lookahead]);
        }
        let g = &groups[gi];
        let server = plan.rank_of(g.src_part, m_idx);
        // Streamed consume: each arriving column band feeds its edge run
        // straight into the accumulation while later bands are in flight
        // (§4 chunk-level overlap; order-preserving, so bit-identical to
        // the monolithic receive — see `Accum::consume_stream`).
        acc.consume_stream(ctx, server, Tag::of(phase, gi as u32 | RESP_BIT), g, h, row_lo, out);
    }
}

/// Group accumulation: `out[row] += E[edge] * feat_row`. Local groups read
/// from the local tile (`h`), remote groups from the fetched buffer (rows
/// aligned with `group.cols`) — either whole (`accumulate_group`) or as
/// streamed column bands fed into the kernel chunk by chunk
/// (`consume_stream`, the §4 pipelined path). Scalar edge values on an
/// accelerated backend are routed through its `spmm_tile` (gather +
/// weighted segment-sum — the AOT-compiled Pallas kernel); the per-head
/// (GAT three-tensor) form and the native backend use the in-place loop.
struct Accum<'a> {
    values: &'a EdgeValues<'a>,
    backend: &'a dyn Backend,
}

impl<'a> Accum<'a> {
    /// True when scalar edge values route through the backend's fused
    /// `spmm_tile`. The AOT tile is a monolithic kernel, so streamed
    /// chunks are gathered first and the tile fires once per group —
    /// keeping its output bit-identical at every chunk size — while the
    /// gather (the expensive memory traffic) still overlaps the wire.
    fn uses_tile(&self) -> bool {
        matches!(self.values, EdgeValues::Scalar(_)) && self.backend.name() != "native"
    }

    /// Accumulate `group.edges[erange]` into `out`. `fetched` carries the
    /// feature rows for group columns `col_lo..` (`None` = read the local
    /// tile). Group edges are sorted by column index, so consuming
    /// ascending column bands as contiguous edge runs reproduces the
    /// monolithic loop's per-destination accumulation order *exactly* —
    /// this is what makes chunked consumption bit-identical.
    fn accumulate_edges(
        &self,
        group: &EdgeGroup,
        erange: std::ops::Range<usize>,
        fetched: Option<(&Matrix, usize)>,
        h: &Matrix,
        row_lo: usize,
        out: &mut Matrix,
    ) {
        let row_of = |ci: u32| -> &[f32] {
            match fetched {
                None => h.row(group.cols[ci as usize] as usize - row_lo),
                Some((f, col_lo)) => f.row(ci as usize - col_lo),
            }
        };
        match self.values {
            EdgeValues::Scalar(_) => {
                for e in erange {
                    let (r, ci) = group.edges[e];
                    let v = group.vals[e];
                    let src_row = row_of(ci);
                    let out_row = out.row_mut(r as usize);
                    for (o, &x) in out_row.iter_mut().zip(src_row) {
                        *o += v * x;
                    }
                }
            }
            EdgeValues::PerHead { vals, heads, col_head } => {
                for e in erange {
                    let (r, ci) = group.edges[e];
                    let eid = group.eids[e] as usize;
                    let ev = &vals[eid * heads..(eid + 1) * heads];
                    let src_row = row_of(ci);
                    let out_row = out.row_mut(r as usize);
                    for j in 0..out_row.len() {
                        out_row[j] += ev[col_head[j] as usize] * src_row[j];
                    }
                }
            }
        }
    }

    /// Accumulate a whole group at once (local groups, monolithic mode).
    fn accumulate_group(
        &self,
        group: &EdgeGroup,
        fetched: Option<&Matrix>,
        h: &Matrix,
        row_lo: usize,
        out: &mut Matrix,
    ) {
        if self.uses_tile() {
            // Gather per-edge source rows, then one tile call.
            let mut feats = Matrix::zeros(group.n_edges(), out.cols);
            for (e, &(_, ci)) in group.edges.iter().enumerate() {
                let src_row = match fetched {
                    None => h.row(group.cols[ci as usize] as usize - row_lo),
                    Some(f) => f.row(ci as usize),
                };
                feats.row_mut(e).copy_from_slice(src_row);
            }
            self.tile_accumulate(&feats, group, out);
            return;
        }
        self.accumulate_edges(group, 0..group.n_edges(), fetched.map(|f| (f, 0)), h, row_lo, out);
    }

    /// One fused `spmm_tile` call over the group's gathered per-edge rows.
    fn tile_accumulate(&self, feats: &Matrix, group: &EdgeGroup, out: &mut Matrix) {
        let seg: Vec<u32> = group.edges.iter().map(|&(r, _)| r).collect();
        let partial = self
            .backend
            .spmm_tile(feats, &group.vals, &seg, out.rows)
            .expect("backend spmm_tile failed");
        for (o, &v) in out.data.iter_mut().zip(&partial.data) {
            *o += v;
        }
    }

    /// Consume one streamed feature response for `group`: each arriving
    /// column band is fed straight into the accumulation (native backend)
    /// or into the tile gather (accelerated backends), with `ctx.compute`
    /// charging per-band work so simulated time interleaves chunk comm
    /// and chunk compute. Peak memory holds at most one chunk instead of
    /// the whole response.
    fn consume_stream(
        &self,
        ctx: &mut Ctx,
        server: usize,
        tag: Tag,
        group: &EdgeGroup,
        h: &Matrix,
        row_lo: usize,
        out: &mut Matrix,
    ) {
        let mut e_at = 0usize;
        if self.uses_tile() {
            let mut feats = Matrix::zeros(group.n_edges(), out.cols);
            ctx.recv_stream(server, tag, |ctx, band, chunk| {
                ctx.mem.with_transient(chunk.nbytes(), || ());
                let e_lo = e_at;
                while e_at < group.edges.len() && (group.edges[e_at].1 as usize) < band.end {
                    e_at += 1;
                }
                let e_hi = e_at;
                ctx.compute(|| {
                    for e in e_lo..e_hi {
                        let ci = group.edges[e].1 as usize;
                        feats.row_mut(e).copy_from_slice(chunk.row(ci - band.start));
                    }
                });
            });
            debug_assert_eq!(e_at, group.edges.len());
            ctx.compute(|| self.tile_accumulate(&feats, group, out));
            return;
        }
        ctx.recv_stream(server, tag, |ctx, band, chunk| {
            ctx.mem.with_transient(chunk.nbytes(), || ());
            let e_lo = e_at;
            while e_at < group.edges.len() && (group.edges[e_at].1 as usize) < band.end {
                e_at += 1;
            }
            let e_hi = e_at;
            if e_lo < e_hi {
                let fetched = Some((&chunk, band.start));
                ctx.compute(|| self.accumulate_edges(group, e_lo..e_hi, fetched, h, row_lo, out));
            }
        });
        debug_assert_eq!(e_at, group.edges.len());
    }
}

/// Exchange-G0 baseline (per machine): send the sparse sub-tile + values
/// to each feature owner, which computes a dense partial *on its main
/// compute path* (the duplicated aggregation work is exactly what Table 2
/// charges this approach for) and returns it.
///
/// Protocol (deadlock-free, no server thread): every machine first sends
/// its tiles to all peers (non-blocking), then receives peers' tiles and
/// computes their partials, then receives its own partials back.
pub fn exchange_g0_spmm(ctx: &mut Ctx, input: &SpmmInput, phase: u32) -> Matrix {
    let plan = input.plan;
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let width = plan.feat_width(m_idx);
    let rows = plan.rows_of(p_idx);
    let row_lo = plan.node_range(p_idx).0;
    let rows_by_rank: Vec<usize> =
        (0..plan.world()).map(|r| plan.rows_of(plan.coords_of(r).0)).collect();

    // Partition the edges by source partition (triplets, global cols).
    let vals = input.scalar_vals();
    let by_part = ctx.compute(|| {
        let mut by_part: Vec<(Vec<u32>, Vec<f32>)> = vec![(Vec::new(), Vec::new()); plan.p];
        for r in 0..input.g.n_rows {
            let (lo, hi) = (input.g.indptr[r] as usize, input.g.indptr[r + 1] as usize);
            for e in lo..hi {
                let c = input.g.indices[e];
                let q = plan.node_owner(c);
                by_part[q].0.extend_from_slice(&[r as u32, c]);
                by_part[q].1.push(vals[e]);
            }
        }
        by_part
    });

    // Phase A: ship tiles to their feature owners (empty tiles included so
    // receive counts stay symmetric).
    for q in 0..plan.p {
        if q == p_idx {
            continue;
        }
        let server = plan.rank_of(q, m_idx);
        ctx.send(server, Tag::of(phase, 0), Payload::U32(by_part[q].0.clone()));
        ctx.send(server, Tag::of(phase, 1), Payload::F32(by_part[q].1.clone()));
    }

    // Phase B: local partial while the tiles fly.
    let h = input.h;
    let mut out = Matrix::zeros(rows, width);
    ctx.mem.alloc(out.nbytes());
    ctx.compute(|| {
        let (ids, vals) = &by_part[p_idx];
        for (e, pair) in ids.chunks_exact(2).enumerate() {
            let (r, c) = (pair[0] as usize, pair[1] as usize - row_lo);
            let v = vals[e];
            let src = h.row(c);
            let o = out.row_mut(r);
            for (a, &x) in o.iter_mut().zip(src) {
                *a += v * x;
            }
        }
    });

    // Phase C: compute peers' partials on the MAIN compute path.
    for q in 0..plan.p {
        if q == p_idx {
            continue;
        }
        let peer = plan.rank_of(q, m_idx);
        let ids = ctx.recv(peer, Tag::of(phase, 0)).into_u32();
        let pvals = ctx.recv(peer, Tag::of(phase, 1)).into_f32();
        let partial = ctx.compute(|| {
            let mut partial = Matrix::zeros(rows_by_rank[peer], width);
            for (e, pair) in ids.chunks_exact(2).enumerate() {
                let (r, c) = (pair[0] as usize, pair[1] as usize - row_lo);
                let v = pvals[e];
                let src = h.row(c);
                let o = partial.row_mut(r);
                for (a, &x) in o.iter_mut().zip(src) {
                    *a += v * x;
                }
            }
            partial
        });
        ctx.send(peer, Tag::of(phase, 2), Payload::Matrix(partial));
    }

    // Phase D: accumulate returned partials.
    for q in 0..plan.p {
        if q == p_idx || by_part[q].1.is_empty() {
            continue;
        }
        let peer = plan.rank_of(q, m_idx);
        let partial = ctx.recv(peer, Tag::of(phase, 2)).into_matrix();
        let pb = partial.nbytes();
        ctx.mem.alloc(pb);
        for (o, &v) in out.data.iter_mut().zip(&partial.data) {
            *o += v;
        }
        ctx.mem.free(pb);
    }
    out
}

/// 2-D-style baseline (per machine): row-group member `m` aggregates its
/// column *chunk* of sources across the **full feature width** (fetching
/// every feature part of each chunk source), producing a full-width
/// partial `R_p x D`; the row group then reduce-scatters - each member
/// ships `(M-1)` slices of `R_p x D/M`, the `ND(M-1)/PM` aggregation term
/// Table 2 charges 2-D SPMM.
pub fn spmm_2d(ctx: &mut Ctx, input: &SpmmInput, phase: u32) -> Matrix {
    let plan = input.plan;
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let width = plan.feat_width(m_idx);
    let rows = plan.rows_of(p_idx);
    let row_lo = plan.node_range(p_idx).0;
    let d = plan.feature_dim;
    let chunk_bounds = even_ranges(plan.n_nodes, plan.m);
    let (clo, chi) = (chunk_bounds[m_idx] as NodeId, chunk_bounds[m_idx + 1] as NodeId);

    // Edges whose source is in my column chunk, bucketed by owner part.
    let vals = input.scalar_vals();
    let mine = ctx.compute(|| {
        let mut mine: Vec<Vec<(u32, NodeId, f32)>> = vec![Vec::new(); plan.p];
        for r in 0..input.g.n_rows {
            let (lo, hi) = (input.g.indptr[r] as usize, input.g.indptr[r + 1] as usize);
            for e in lo..hi {
                let c = input.g.indices[e];
                if c >= clo && c < chi {
                    mine[plan.node_owner(c)].push((r as u32, c, vals[e]));
                }
            }
        }
        mine
    });
    // Distinct chunk sources per owner partition.
    let cols_by_part: Vec<Vec<NodeId>> = (0..plan.p)
        .map(|q| {
            let mut cols: Vec<NodeId> = mine[q].iter().map(|&(_, c, _)| c).collect();
            cols.sort_unstable();
            cols.dedup();
            cols
        })
        .collect();

    // Counts: I request slice j of partition q's sources from (q, j) -
    // every feature part, including my own partition's other parts.
    for rank in 0..plan.world() {
        if rank == ctx.rank {
            continue;
        }
        let (q, _j) = plan.coords_of(rank);
        let n = u32::from(!cols_by_part[q].is_empty());
        ctx.send_service(rank, Tag::of(phase, COUNT_SEQ), Payload::U32(vec![n]));
    }

    let h = input.h;
    let expected_peers = plan.world() - 1;
    ctx.with_server(
        |sctx| feature_server(sctx, h, row_lo, expected_peers, phase),
        |ctx| {
            // Full-width partial - the 2-D baseline's memory cost.
            let mut partial = Matrix::zeros(rows, d);
            ctx.mem.alloc(partial.nbytes());
            let mut seq = 0u32;
            for q in 0..plan.p {
                if cols_by_part[q].is_empty() {
                    continue;
                }
                let cols = &cols_by_part[q];
                // Assemble full-width features for this partition's sources.
                let mut src_full = Matrix::zeros(cols.len(), d);
                let sb = src_full.nbytes();
                ctx.mem.alloc(sb);
                let mut reqs: Vec<(usize, u32, usize)> = Vec::new();
                for j in 0..plan.m {
                    let rank = plan.rank_of(q, j);
                    if rank == ctx.rank {
                        let (flo, fhi) = plan.feat_range(j);
                        for (i, &c) in cols.iter().enumerate() {
                            src_full.row_mut(i)[flo..fhi]
                                .copy_from_slice(h.row(c as usize - row_lo));
                        }
                    } else {
                        ctx.send_service(rank, Tag::of(phase, seq), Payload::U32(cols.clone()));
                        reqs.push((rank, seq, j));
                        seq += 1;
                    }
                }
                for &(rank, s, j) in &reqs {
                    let block = ctx.recv_matrix(rank, Tag::of(phase, s | RESP_BIT));
                    let (flo, fhi) = plan.feat_range(j);
                    for r in 0..block.rows {
                        src_full.row_mut(r)[flo..fhi].copy_from_slice(block.row(r));
                    }
                }
                ctx.compute(|| {
                    for &(r, c, v) in &mine[q] {
                        let fi = cols.binary_search(&c).unwrap();
                        let src = src_full.row(fi);
                        let o = partial.row_mut(r as usize);
                        for (a, &x) in o.iter_mut().zip(src) {
                            *a += v * x;
                        }
                    }
                });
                ctx.mem.free(sb);
            }
            // Reduce-scatter within the row group: ship slice F_j of my
            // partial to member j; sum received slices into F_m.
            let group = plan.row_group(p_idx);
            let phase2 = phase ^ 0x4000_0000;
            for (j, &rank) in group.iter().enumerate() {
                if j != m_idx {
                    let (flo, fhi) = plan.feat_range(j);
                    let slice = partial.slice_cols(flo, fhi);
                    ctx.send(rank, Tag::of(phase2, m_idx as u32), Payload::Matrix(slice));
                }
            }
            let (flo, fhi) = plan.feat_range(m_idx);
            let mut out = partial.slice_cols(flo, fhi);
            ctx.mem.alloc(out.nbytes());
            for (j, &rank) in group.iter().enumerate() {
                if j != m_idx {
                    let p = ctx.recv(rank, Tag::of(phase2, j as u32)).into_matrix();
                    let pb = p.nbytes();
                    ctx.mem.alloc(pb);
                    for (o, &v) in out.data.iter_mut().zip(&p.data) {
                        *o += v;
                    }
                    ctx.mem.free(pb);
                }
            }
            ctx.mem.free(partial.nbytes());
            debug_assert_eq!(out.cols, width);
            out
        },
    )
}

/// Dense single-machine oracle: `out = G · H` with per-edge weights.
/// Row-parallel over degree-balanced bands; each destination row still
/// accumulates its edges in CSR order, so the result is bit-identical to
/// the scalar loop at every thread count.
pub fn spmm_reference(g: &Csr, vals: &[f32], h: &Matrix) -> Matrix {
    assert_eq!(vals.len(), g.n_edges());
    assert_eq!(h.rows, g.n_cols);
    let width = h.cols;
    let mut out = Matrix::zeros(g.n_rows, width);
    let bounds = csr_row_bands(g, width);
    let parts = par::split_rows(&mut out.data, &bounds, width);
    par::run_parts(parts, |_, (rows, band)| {
        for r in rows.clone() {
            let (lo, hi) = (g.indptr[r] as usize, g.indptr[r + 1] as usize);
            let at = (r - rows.start) * width;
            let orow = &mut band[at..at + width];
            for e in lo..hi {
                let src = h.row(g.indices[e] as usize);
                let v = vals[e];
                for (a, &x) in orow.iter_mut().zip(src) {
                    *a += v * x;
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterReport, NetConfig};
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::primitives::{gather_tiles, mean_weights, scatter};
    use crate::util::prop::{assert_close, run, Config};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[derive(Clone, Copy)]
    enum Algo {
        Deal(ExecMode, usize),
        ExchangeG0,
        TwoD,
    }

    fn run_spmm(
        plan: &PartitionPlan,
        g: &Csr,
        vals: &[f32],
        h: &Matrix,
        algo: Algo,
    ) -> (Matrix, ClusterReport) {
        let tiles = Arc::new(scatter(plan, h));
        // per-partition sub-CSRs + aligned vals
        let mut subs: Vec<(Csr, Vec<f32>)> = Vec::new();
        for p in 0..plan.p {
            let (lo, hi) = plan.node_range(p);
            let sub = g.slice_rows(lo, hi);
            let vlo = g.indptr[lo] as usize;
            let vhi = g.indptr[hi] as usize;
            subs.push((sub, vals[vlo..vhi].to_vec()));
        }
        let subs = Arc::new(subs);
        let plan2 = plan.clone();
        let cluster = Cluster::new(plan.world(), NetConfig::default());
        let (outs, report) = cluster
            .run(move |ctx| {
                let (p_idx, _m) = plan2.coords_of(ctx.rank);
                let (sub, svals) = &subs[p_idx];
                let input = SpmmInput {
                    plan: &plan2,
                    g: sub,
                    vals: EdgeValues::Scalar(svals),
                    h: &tiles[ctx.rank],
                };
                let backend = crate::runtime::Native;
                match algo {
                    Algo::Deal(mode, maxc) => deal_spmm(ctx, &input, &backend, mode, maxc, 7),
                    Algo::ExchangeG0 => exchange_g0_spmm(ctx, &input, 7),
                    Algo::TwoD => spmm_2d(ctx, &input, 7),
                }
            })
            .unwrap();
        (gather_tiles(plan, h.cols, &outs), report)
    }

    fn setup(n: usize, d: usize, deg: usize, seed: u64) -> (Csr, Vec<f32>, Matrix) {
        let scale = (n as f64).log2().ceil() as u32;
        let el = rmat(scale, n * deg, RmatParams::paper(), seed);
        let g = Csr::from(&el);
        let vals = mean_weights(&g);
        let mut rng = Rng::new(seed ^ 1);
        let h = Matrix::random(g.n_cols, d, 1.0, &mut rng);
        (g, vals, h)
    }

    #[test]
    fn all_algorithms_match_reference() {
        let (g, vals, h) = setup(64, 8, 6, 3);
        let expect = spmm_reference(&g, &vals, &h);
        let plan = PartitionPlan::new(g.n_rows, h.cols, 2, 2);
        let algos = [
            ("mono", Algo::Deal(ExecMode::Monolithic, 0)),
            ("grouped", Algo::Deal(ExecMode::Grouped, 8)),
            ("pipelined", Algo::Deal(ExecMode::Pipelined, 8)),
            ("xg0", Algo::ExchangeG0),
            ("2d", Algo::TwoD),
        ];
        for (name, algo) in algos {
            let (got, _) = run_spmm(&plan, &g, &vals, &h, algo);
            assert_close(&got.data, &expect.data, 1e-4, 1e-4)
                .unwrap_or_else(|e| panic!("{}: {}", name, e));
        }
    }

    #[test]
    fn spmm_property_random_plans() {
        run(Config::default().cases(6), |rng| {
            let p = rng.range(1, 4);
            let m = rng.range(1, 4);
            let n = rng.range(p * m * 4, 80);
            let d = rng.range(m * 2, 20);
            let ne = rng.range(1, n * 6);
            let edges: Vec<(NodeId, NodeId)> = (0..ne)
                .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
                .collect();
            let g = Csr::from_edges(n, &edges);
            let vals: Vec<f32> = (0..g.n_edges()).map(|_| rng.next_f32() + 0.1).collect();
            let h = Matrix::random(n, d, 1.0, rng);
            let expect = spmm_reference(&g, &vals, &h);
            let plan = PartitionPlan::new(n, d, p, m);
            let maxc = [0usize, 4, 32][rng.next_below(3)];
            for algo in [
                Algo::Deal(ExecMode::Monolithic, 0),
                Algo::Deal(ExecMode::Grouped, maxc),
                Algo::Deal(ExecMode::Pipelined, maxc),
                Algo::ExchangeG0,
                Algo::TwoD,
            ] {
                let (got, _) = run_spmm(&plan, &g, &vals, &h, algo);
                assert_close(&got.data, &expect.data, 1e-3, 1e-3)?;
            }
            Ok(())
        });
    }

    #[test]
    fn chunked_spmm_bit_identical_across_chunk_sizes() {
        let (g, vals, h) = setup(128, 16, 8, 21);
        let plan = PartitionPlan::new(g.n_rows, h.cols, 2, 2);
        let algo = Algo::Deal(ExecMode::Pipelined, 16);
        let base = crate::cluster::net::with_chunk_rows(0, || {
            run_spmm(&plan, &g, &vals, &h, algo).0
        });
        for chunk in [1usize, 3, 16, 4096] {
            let got = crate::cluster::net::with_chunk_rows(chunk, || {
                run_spmm(&plan, &g, &vals, &h, algo).0
            });
            assert_eq!(got, base, "chunk_rows={}", chunk);
        }
    }

    /// The canonical accumulation order (module doc): Monolithic,
    /// Grouped, and Pipelined must produce bit-identical outputs at any
    /// group size, so the autotuner's per-layer mode choice never
    /// changes values. (`Naive` is outside the family by design.)
    #[test]
    fn production_modes_bit_identical() {
        let (g, vals, h) = setup(128, 16, 8, 33);
        let plan = PartitionPlan::new(g.n_rows, h.cols, 2, 2);
        let base = run_spmm(&plan, &g, &vals, &h, Algo::Deal(ExecMode::Monolithic, 0)).0;
        for mode in [ExecMode::Grouped, ExecMode::Pipelined] {
            for maxc in [0usize, 8, 64] {
                let got = run_spmm(&plan, &g, &vals, &h, Algo::Deal(mode, maxc)).0;
                assert_eq!(got, base, "mode={:?} group_cols={}", mode, maxc);
            }
        }
    }

    fn run_spmm_paged(
        plan: &PartitionPlan,
        g: &Csr,
        vals: &[f32],
        h: &Matrix,
        mode: ExecMode,
        maxc: usize,
        budget: u64,
        page_rows: usize,
    ) -> (Matrix, ClusterReport) {
        use crate::coordinator::SimFs;
        use crate::storage::{PagedMatrix, SharedPageCache};
        let tiles = Arc::new(scatter(plan, h));
        let mut subs: Vec<(Csr, Vec<f32>)> = Vec::new();
        for p in 0..plan.p {
            let (lo, hi) = plan.node_range(p);
            let sub = g.slice_rows(lo, hi);
            let vlo = g.indptr[lo] as usize;
            let vhi = g.indptr[hi] as usize;
            subs.push((sub, vals[vlo..vhi].to_vec()));
        }
        let subs = Arc::new(subs);
        let plan2 = plan.clone();
        let cluster = Cluster::new(plan.world(), NetConfig::default());
        let (outs, report) = cluster
            .run(move |ctx| {
                let (p_idx, _m) = plan2.coords_of(ctx.rank);
                let (sub, svals) = &subs[p_idx];
                let cache = SharedPageCache::new(budget);
                let fs = SimFs::new(crate::storage::DEFAULT_SPILL_GBPS);
                let pm = cache
                    .with(|c| {
                        PagedMatrix::from_matrix(
                            c,
                            &format!("spmm-test-r{}", ctx.rank),
                            &tiles[ctx.rank],
                            page_rows,
                            fs,
                        )
                    })
                    .unwrap();
                let input = PagedSpmmInput {
                    plan: &plan2,
                    g: sub,
                    vals: EdgeValues::Scalar(svals),
                    h: &pm,
                    cache: &cache,
                };
                let out =
                    deal_spmm_paged(ctx, &input, &crate::runtime::Native, mode, maxc, 7).unwrap();
                crate::storage::absorb_scope(ctx, &cache);
                out
            })
            .unwrap();
        (gather_tiles(plan, h.cols, &outs), report)
    }

    #[test]
    fn paged_spmm_bit_identical_to_ram_at_every_budget() {
        let (g, vals, h) = setup(96, 8, 6, 5);
        for (p, m) in [(2usize, 2usize), (1, 2), (4, 1)] {
            let plan = PartitionPlan::new(g.n_rows, h.cols, p, m);
            for mode in [ExecMode::Monolithic, ExecMode::Pipelined] {
                let (ram, _) = run_spmm(&plan, &g, &vals, &h, Algo::Deal(mode, 8));
                for (budget, page_rows) in [(0u64, 16usize), (2048, 4), (512, 1), (4096, 4096)]
                {
                    let (paged, rep) =
                        run_spmm_paged(&plan, &g, &vals, &h, mode, 8, budget, page_rows);
                    assert_eq!(
                        paged, ram,
                        "paged != ram at ({},{}) mode {:?} budget {} page_rows {}",
                        p, m, mode, budget, page_rows
                    );
                    if budget > 0 {
                        assert!(
                            rep.max_storage_resident() <= budget.max((page_rows * 8 * 4) as u64)
                                + (page_rows * 8 * 4) as u64,
                            "residency {} blew the budget {} (page_rows {})",
                            rep.max_storage_resident(),
                            budget,
                            page_rows
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn grouped_bounds_peak_memory_vs_monolithic() {
        let (g, vals, h) = setup(256, 32, 16, 9);
        let plan = PartitionPlan::new(g.n_rows, h.cols, 2, 2);
        let (_, mono) = run_spmm(&plan, &g, &vals, &h, Algo::Deal(ExecMode::Monolithic, 0));
        let (_, grouped) = run_spmm(&plan, &g, &vals, &h, Algo::Deal(ExecMode::Grouped, 16));
        assert!(
            grouped.max_peak_mem() < mono.max_peak_mem(),
            "grouped {} !< mono {}",
            grouped.max_peak_mem(),
            mono.max_peak_mem()
        );
    }

    #[test]
    fn deal_moves_fewer_bytes_than_exchange_g0() {
        let (g, vals, h) = setup(256, 32, 16, 10);
        let plan = PartitionPlan::new(g.n_rows, h.cols, 2, 2);
        let (_, deal) = run_spmm(&plan, &g, &vals, &h, Algo::Deal(ExecMode::Pipelined, 64));
        let (_, xg0) = run_spmm(&plan, &g, &vals, &h, Algo::ExchangeG0);
        let (_, twod) = run_spmm(&plan, &g, &vals, &h, Algo::TwoD);
        assert!(
            deal.total_bytes() < xg0.total_bytes(),
            "deal {} !< xg0 {}",
            deal.total_bytes(),
            xg0.total_bytes()
        );
        assert!(
            deal.total_bytes() < twod.total_bytes(),
            "deal {} !< 2d {}",
            deal.total_bytes(),
            twod.total_bytes()
        );
    }
}
