//! Incremental (delta) inference for streaming graph updates
//! (DESIGN.md §Delta).
//!
//! [`DeltaState`] owns everything a full pipeline run produces — the
//! partitioned CSRs, the sampled layer graphs, and every intermediate
//! activation `H^(0) .. H^(k)` — and [`DeltaState::apply`] advances it by
//! one [`UpdateBatch`]:
//!
//! 1. **Compaction** — each partition merges the batch into its CSR
//!    (`graph::delta::PartitionDelta`), reporting the *dirty* rows whose
//!    in-neighbor list changed.
//! 2. **Re-sampling** — only dirty rows re-draw their per-layer samples
//!    (`sampling::resample_rows`); because the sampler forks its RNG per
//!    row, the patched layer graphs are bit-identical to what a
//!    from-scratch sampling pass over the updated CSR would build.
//! 3. **Frontier** — `graph::delta::affected_frontier` derives, per GNN
//!    level, the set of rows whose activations can change.
//! 4. **Restricted re-inference** — GCN runs a `p × m` cluster job that
//!    recomputes only the affected rows: the projection goes through a
//!    frontier-restricted row-group GEMM ([`delta_gemm_rows`]); the
//!    aggregation *reuses `primitives::spmm::deal_spmm` unchanged*, fed a
//!    layer CSR whose unaffected rows are empty — the §3.5 group machinery
//!    then requests exactly the frontier's columns and nothing else. Every
//!    other model (and GCN in *exact mode*, see [`DeltaState::set_exact`])
//!    goes through [`GnnModel::layer_rows`]: per partition, a sparse
//!    frontier-restricted recompute against the partition-local layer CSR
//!    whose output rows are **bit-identical** to the dense layer on the
//!    stitched graph. (This replaced the PR 2 stopgap that kept a global
//!    stitched CSR cache just for a dense GAT fallback.)
//!
//! Parity contract (tested in `tests/delta_stream.rs`): after any replayed
//! update trace, `DeltaState::embeddings()` matches a from-scratch
//! `Pipeline::run` on the updated graph within the end-to-end parity
//! tolerance — unchanged rows keep their cached values (identical samples
//! ⇒ identical inputs), affected rows are recomputed from those caches. On
//! the `layer_rows` path the contract is stronger: the state stays
//! bit-identical to a fresh dense init over the current graph after every
//! batch — the invariant the temporal engine's snapshot guarantee
//! (DESIGN.md §Temporal) is built on.

use std::sync::Arc;
use std::time::Instant;

use crate::cluster::{thread_cpu_time, Cluster, Ctx, Payload, Tag};
use crate::config::DealConfig;
use crate::graph::builder::build_in_memory;
use crate::graph::delta::{
    affected_frontier, replace_rows, restrict_rows, stack_partitions, PartitionDelta,
};
pub use crate::graph::delta::UpdateBatch;
use crate::graph::{datasets, Csr, EdgeList, NodeId};
use crate::model::{LayerPart, ModelKind, ModelWeights};
use crate::partition::PartitionPlan;
use crate::primitives::scatter;
use crate::primitives::spmm::{deal_spmm, EdgeValues, SpmmInput};
use crate::runtime::{backend_from_config, Backend};
use crate::sampling::{resample_rows, sample_all_layers};
use crate::tensor::Matrix;
use crate::util::even_ranges;
use crate::util::rng::Rng;
use crate::Result;

/// Message phase base for the delta cluster job (stride 0x10 per layer).
const DELTA_PHASE: u32 = 0x5000;

/// Outcome of one applied update batch.
#[derive(Clone, Debug)]
pub struct DeltaReport {
    /// Edge insertions / removals actually applied.
    pub edges_added: usize,
    pub edges_removed: usize,
    /// Rows whose in-neighbor list changed (re-sampled).
    pub dirty_rows: usize,
    /// `|changed^(l)|` per activation level `0..=k`.
    pub frontier: Vec<usize>,
    /// Final-level affected rows (sorted global ids) — the rows a delta
    /// epoch patches into the serving table.
    pub updated_rows: Vec<NodeId>,
    /// Simulated cluster seconds for the whole delta refresh: staging
    /// (compaction + re-sampling + frontier), restricted-job assembly and
    /// result patch-back — all charged at single-machine rate scaled by
    /// the configured cores — plus the restricted job's makespan.
    pub sim_secs: f64,
    /// Wall-clock seconds on this host.
    pub wall_secs: f64,
    /// Bytes / messages over the simulated network.
    pub net_bytes: u64,
    pub net_msgs: u64,
}

/// Live incremental-inference state: current partitioned graph, sampled
/// layer graphs, and all cached per-level activations.
pub struct DeltaState {
    cfg: DealConfig,
    plan: PartitionPlan,
    kind: ModelKind,
    weights: Arc<ModelWeights>,
    backend: Arc<dyn Backend>,
    /// Per-partition CSR (local rows, global columns).
    partitions: Vec<Csr>,
    /// `[p][l]` sampled layer graphs over partition-local rows.
    layer_csrs: Vec<Vec<Csr>>,
    /// Exact mode: route every model — GCN included — through the
    /// bit-exact `GnnModel::layer_rows` recompute instead of the
    /// distributed GCN delta job (see [`DeltaState::set_exact`]).
    exact: bool,
    /// Cached activations `H^(0) .. H^(k)`, each global `N × d`
    /// (`activations[0]` is the feature matrix).
    activations: Vec<Matrix>,
}

/// Stitch per-partition layer CSRs into `k` global layer graphs.
fn stitch_layers(layer_csrs: &[Vec<Csr>], k: usize) -> Vec<Csr> {
    (0..k)
        .map(|l| {
            let refs: Vec<&Csr> = layer_csrs.iter().map(|ls| &ls[l]).collect();
            stack_partitions(&refs)
        })
        .collect()
}

impl DeltaState {
    /// Build the baseline state from the configured dataset: partition,
    /// sample with the pipeline's per-partition seeds, and run a dense
    /// forward pass keeping every intermediate level.
    pub fn init(cfg: DealConfig) -> Result<DeltaState> {
        let ds = datasets::load(&cfg.dataset.name, cfg.dataset.scale)?;
        Self::init_with(cfg, ds.edges, ds.features)
    }

    /// Like [`DeltaState::init`] but over an explicit in-memory graph.
    pub fn init_with(cfg: DealConfig, edges: EdgeList, features: Matrix) -> Result<DeltaState> {
        let (p, m) = cfg.parts()?;
        anyhow::ensure!(
            edges.n_nodes == features.rows,
            "features have {} rows for {} nodes",
            features.rows,
            edges.n_nodes
        );
        let dim = features.cols;
        let plan = PartitionPlan::new(edges.n_nodes, dim, p, m);
        let kind = ModelKind::parse(&cfg.model.kind)?;
        let model_cfg = cfg.model_config(dim)?;
        let weights = if cfg.model.weights.is_empty() {
            ModelWeights::random(&model_cfg, cfg.exec.seed ^ 0xBEEF)
        } else {
            ModelWeights::load(&model_cfg, std::path::Path::new(&cfg.model.weights))?
        };
        let partitions: Vec<Csr> =
            build_in_memory(&edges, p).into_iter().map(|gp| gp.csr).collect();
        let layer_csrs: Vec<Vec<Csr>> = partitions
            .iter()
            .enumerate()
            .map(|(pi, g)| {
                sample_all_layers(g, cfg.model.layers, cfg.model.fanout, cfg.exec.seed ^ pi as u64)
                    .layers
            })
            .collect();
        let backend = backend_from_config(&cfg.exec.backend, &cfg.artifacts_dir())?;
        let k = cfg.model.layers;
        let stitched = stitch_layers(&layer_csrs, k);
        let mut state = DeltaState {
            cfg,
            plan,
            kind,
            weights: Arc::new(weights),
            backend,
            partitions,
            layer_csrs,
            exact: false,
            activations: Vec::new(),
        };
        state.activations = state.forward_all(features, &stitched);
        Ok(state)
    }

    /// Dense forward over the given stitched layer graphs through the
    /// model-zoo trait, keeping every level.
    fn forward_all(&self, features: Matrix, layers: &[Csr]) -> Vec<Matrix> {
        let k = self.cfg.model.layers;
        let model = self.kind.model();
        let mut acts = Vec::with_capacity(k + 1);
        acts.push(features);
        for (l, g) in layers.iter().enumerate() {
            let relu = l + 1 != k;
            let next = model.layer(g, &acts[l], &self.weights, l, relu);
            acts.push(next);
        }
        acts
    }

    /// Route every batch — GCN included — through the bit-exact
    /// `GnnModel::layer_rows` recompute. In exact mode the cached state is
    /// bit-identical to a fresh dense init over the current graph after
    /// *every* apply (unaffected rows by the frontier property, affected
    /// rows by the `layer_rows` restriction contract) — which is why a
    /// published temporal snapshot can never depend on how the replayed
    /// event stream was batched. The distributed GCN delta job trades that
    /// last bit of exactness (its accumulation order differs from the
    /// dense oracle's) for simulated-cluster fidelity; models other than
    /// GCN always take the exact path.
    pub fn set_exact(&mut self, on: bool) {
        self.exact = on;
    }

    // ---- accessors -----------------------------------------------------

    pub fn plan(&self) -> &PartitionPlan {
        &self.plan
    }

    pub fn kind(&self) -> ModelKind {
        self.kind
    }

    pub fn n_nodes(&self) -> usize {
        self.plan.n_nodes
    }

    pub fn n_edges(&self) -> usize {
        self.partitions.iter().map(|c| c.n_edges()).sum()
    }

    /// Current node features (`H^(0)`).
    pub fn features(&self) -> &Matrix {
        &self.activations[0]
    }

    /// Current all-node embeddings (`H^(k)`).
    pub fn embeddings(&self) -> &Matrix {
        self.activations.last().expect("state is initialized")
    }

    /// Reassemble the current global edge list (full-recompute parity
    /// checks; CSR construction is order-insensitive).
    pub fn edge_list(&self) -> EdgeList {
        let mut edges = Vec::with_capacity(self.n_edges());
        for (pi, csr) in self.partitions.iter().enumerate() {
            let rlo = self.plan.node_range(pi).0;
            for r in 0..csr.n_rows {
                for &s in csr.row(r) {
                    edges.push((s, (rlo + r) as NodeId));
                }
            }
        }
        EdgeList::new(self.plan.n_nodes, edges)
    }

    /// Synthesize an update batch against the *current* graph: `adds`
    /// uniform random insertions, `removes` uniform random existing edges
    /// (degree-weighted by construction), `feat_updates` random feature
    /// row replacements.
    pub fn synth_batch(
        &self,
        rng: &mut Rng,
        adds: usize,
        removes: usize,
        feat_updates: usize,
    ) -> UpdateBatch {
        let n = self.plan.n_nodes;
        let mut batch = UpdateBatch::default();
        for _ in 0..adds {
            batch
                .add_edges
                .push((rng.next_below(n) as NodeId, rng.next_below(n) as NodeId));
        }
        let total_edges = self.n_edges();
        if total_edges > 0 {
            for _ in 0..removes {
                let mut e = rng.next_below(total_edges);
                for (pi, csr) in self.partitions.iter().enumerate() {
                    if e < csr.n_edges() {
                        let r = csr.indptr.partition_point(|&x| (x as usize) <= e) - 1;
                        let dst = (self.plan.node_range(pi).0 + r) as NodeId;
                        batch.remove_edges.push((csr.indices[e], dst));
                        break;
                    }
                    e -= csr.n_edges();
                }
            }
        }
        let dim = self.plan.feature_dim;
        for _ in 0..feat_updates {
            let v = rng.next_below(n) as NodeId;
            let row: Vec<f32> = (0..dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            batch.feature_updates.push((v, row));
        }
        batch
    }

    // ---- the delta step ------------------------------------------------

    /// Apply one update batch: compact, re-sample dirty rows, derive the
    /// affected frontier, and re-infer only affected rows.
    pub fn apply(&mut self, batch: &UpdateBatch) -> Result<DeltaReport> {
        let t0 = Instant::now();
        let k = self.cfg.model.layers;
        let n = self.plan.n_nodes;
        batch.validate(n, self.plan.feature_dim)?;
        let staging_cpu0 = thread_cpu_time();

        // 1 + 2: per-partition compaction and dirty-row re-sampling.
        let mut dirty_global: Vec<NodeId> = Vec::new();
        let mut edges_added = 0usize;
        let mut edges_removed = 0usize;
        for p_idx in 0..self.plan.p {
            let (rlo, rhi) = self.plan.node_range(p_idx);
            let mut delta = PartitionDelta::new(rlo, rhi);
            let (staged_adds, _) = delta.stage(batch);
            if delta.is_empty() {
                continue;
            }
            let before = self.partitions[p_idx].n_edges();
            let (updated, dirty_local) = delta.compact(&self.partitions[p_idx]);
            edges_added += staged_adds;
            edges_removed += before + staged_adds - updated.n_edges();
            if !dirty_local.is_empty() {
                let seed = self.cfg.exec.seed ^ p_idx as u64;
                let samples =
                    resample_rows(&updated, &dirty_local, k, self.cfg.model.fanout, seed);
                for l in 0..k {
                    let updates: Vec<(usize, Vec<NodeId>)> = dirty_local
                        .iter()
                        .zip(&samples)
                        .map(|(&r, per_layer)| (r, per_layer[l].clone()))
                        .collect();
                    self.layer_csrs[p_idx][l] = replace_rows(&self.layer_csrs[p_idx][l], &updates);
                }
            }
            dirty_global.extend(dirty_local.iter().map(|&r| (rlo + r) as NodeId));
            self.partitions[p_idx] = updated;
        }

        // Feature-row replacements seed level 0 of the frontier.
        let mut feat_changed: Vec<NodeId> =
            batch.feature_updates.iter().map(|(v, _)| *v).collect();
        feat_changed.sort_unstable();
        feat_changed.dedup();
        for (v, row) in &batch.feature_updates {
            self.activations[0].row_mut(*v as usize).copy_from_slice(row);
        }

        // 3: affected frontier over the updated layer graphs.
        let row_offsets: Vec<usize> =
            (0..self.plan.p).map(|pi| self.plan.node_range(pi).0).collect();
        let levels =
            affected_frontier(&self.layer_csrs, &row_offsets, n, k, &dirty_global, &feat_changed);
        let staging_sim =
            (thread_cpu_time() - staging_cpu0).max(0.0) / self.cfg.cluster.cores;

        let frontier: Vec<usize> = levels.iter().map(|lv| lv.len()).collect();
        if levels[1..].iter().all(|lv| lv.is_empty()) {
            return Ok(DeltaReport {
                edges_added,
                edges_removed,
                dirty_rows: dirty_global.len(),
                frontier,
                updated_rows: Vec::new(),
                sim_secs: staging_sim,
                wall_secs: t0.elapsed().as_secs_f64(),
                net_bytes: 0,
                net_msgs: 0,
            });
        }

        // 4: restricted re-inference.
        let (job_sim, net_bytes, net_msgs) = if self.kind == ModelKind::Gcn && !self.exact {
            self.gcn_delta(&levels)?
        } else {
            self.trait_delta(&levels)?
        };

        Ok(DeltaReport {
            edges_added,
            edges_removed,
            dirty_rows: dirty_global.len(),
            frontier,
            updated_rows: levels[k].clone(),
            sim_secs: staging_sim + job_sim,
            wall_secs: t0.elapsed().as_secs_f64(),
            net_bytes,
            net_msgs,
        })
    }

    /// Distributed GCN delta across the `p × m` cluster: restricted
    /// row-group GEMM, then the stock `deal_spmm` over frontier-restricted
    /// layer parts. Returns (sim seconds, net bytes, net msgs); the
    /// returned sim time covers the coordinator-side job assembly and
    /// result patch-back (cores-scaled CPU time, like staging) plus the
    /// cluster job's makespan, so the bench's speedup metric sees every
    /// piece of delta work.
    fn gcn_delta(&mut self, levels: &[Vec<NodeId>]) -> Result<(f64, u64, u64)> {
        let k = self.cfg.model.layers;
        let plan = Arc::new(self.plan.clone());
        let p = plan.p;
        let prep_cpu0 = thread_cpu_time();

        // Per (partition, layer): restricted layer part, rows needing
        // projection, and affected local rows.
        let mut restricted: Vec<Vec<LayerPart>> = (0..p).map(|_| Vec::with_capacity(k)).collect();
        let mut proj_rows: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); k]; p];
        let mut affected_local: Vec<Vec<Vec<usize>>> = vec![vec![Vec::new(); k]; p];
        for l in 0..k {
            let aff = &levels[l + 1];
            // Projection is needed for every source any affected row pulls
            // (across all partitions — the feature servers gather from the
            // projected tile) plus the affected rows themselves (self loop).
            let mut need = vec![false; plan.n_nodes];
            for pi in 0..p {
                let (rlo, rhi) = plan.node_range(pi);
                let keep: Vec<usize> = aff
                    .iter()
                    .filter(|&&v| (v as usize) >= rlo && (v as usize) < rhi)
                    .map(|&v| v as usize - rlo)
                    .collect();
                let rcsr = restrict_rows(&self.layer_csrs[pi][l], &keep);
                for &s in &rcsr.indices {
                    need[s as usize] = true;
                }
                affected_local[pi][l] = keep;
                restricted[pi].push(LayerPart::new(rcsr));
            }
            for &v in aff {
                need[v as usize] = true;
            }
            for pi in 0..p {
                let (rlo, rhi) = plan.node_range(pi);
                proj_rows[pi][l] =
                    (rlo..rhi).filter(|&v| need[v]).map(|v| (v - rlo) as u32).collect();
            }
        }

        // Scattered input tiles (updated H^0) and cached output bases
        // (baseline H^(l+1), patched per layer inside the job). In a real
        // deployment each machine retains its tiles between batches; the
        // re-scatter is a simulation artifact, so its (small, memcpy-rate)
        // cost is charged below with the rest of the assembly.
        let tiles_in = Arc::new(scatter(&plan, &self.activations[0]));
        let cached: Arc<Vec<Vec<Matrix>>> =
            Arc::new((1..=k).map(|l| scatter(&plan, &self.activations[l])).collect());
        let restricted = Arc::new(restricted);
        let proj_rows = Arc::new(proj_rows);
        let affected_local = Arc::new(affected_local);
        let affected_back = Arc::clone(&affected_local);
        let weights = Arc::clone(&self.weights);
        let backend = Arc::clone(&self.backend);
        let mode = self.cfg.exec_mode()?;
        let group_cols = self.cfg.exec.group_cols;
        let plan_job = Arc::clone(&plan);
        let prep_sim = (thread_cpu_time() - prep_cpu0).max(0.0) / self.cfg.cluster.cores;

        let cluster =
            Cluster::new(plan.world(), self.cfg.net()).with_cores(self.cfg.cluster.cores);
        let (tiles, report) = cluster.run(move |ctx| -> Result<Vec<Matrix>> {
            let (p_idx, m_idx) = plan_job.coords_of(ctx.rank);
            let (flo, fhi) = plan_job.feat_range(m_idx);
            let mut h = tiles_in[ctx.rank].clone();
            ctx.mem.alloc(h.nbytes());
            let mut outs: Vec<Matrix> = Vec::with_capacity(k);
            for l in 0..k {
                let phase = DELTA_PHASE + (l as u32) * 0x10;
                let hw = delta_gemm_rows(
                    ctx,
                    &plan_job,
                    &h,
                    weights.layer_w(l),
                    &proj_rows[p_idx][l],
                    backend.as_ref(),
                    phase,
                )?;
                ctx.mem.free(h.nbytes());
                let part = &restricted[p_idx][l];
                let input = SpmmInput {
                    plan: &plan_job,
                    g: &part.csr,
                    vals: EdgeValues::Scalar(&part.mean_w),
                    h: &hw,
                };
                let agg = deal_spmm(ctx, &input, backend.as_ref(), mode, group_cols, phase + 4);
                let mut next = cached[l][ctx.rank].clone();
                ctx.mem.alloc(next.nbytes());
                let bias = &weights.layer_b(l)[flo..fhi];
                let relu = l + 1 != k;
                ctx.compute(|| {
                    for &r in &affected_local[p_idx][l] {
                        let sw = part.self_w[r];
                        let hw_row = hw.row(r);
                        let arow = agg.row(r);
                        let nrow = next.row_mut(r);
                        for j in 0..nrow.len() {
                            let v = arow[j] + sw * hw_row[j] + bias[j];
                            nrow[j] = if relu { v.max(0.0) } else { v };
                        }
                    }
                });
                ctx.mem.free(hw.nbytes() + agg.nbytes());
                // ship back only the affected rows — the patch a delta
                // epoch is made of (churn-proportional, not O(N))
                outs.push(next.gather_rows(&affected_local[p_idx][l]));
                h = next;
            }
            Ok(outs)
        })?;
        let blocks: Vec<Vec<Matrix>> = tiles.into_iter().collect::<Result<_>>()?;
        let patch_cpu0 = thread_cpu_time();
        for (rank, per_layer) in blocks.iter().enumerate() {
            let (pi, mi) = plan.coords_of(rank);
            let rlo = plan.node_range(pi).0;
            let (flo, fhi) = plan.feat_range(mi);
            for (l, block) in per_layer.iter().enumerate() {
                let act = &mut self.activations[l + 1];
                for (i, &r) in affected_back[pi][l].iter().enumerate() {
                    act.row_mut(rlo + r)[flo..fhi].copy_from_slice(block.row(i));
                }
            }
        }
        let patch_sim = (thread_cpu_time() - patch_cpu0).max(0.0) / self.cfg.cluster.cores;
        Ok((
            prep_sim + report.makespan() + patch_sim,
            report.total_bytes(),
            report.total_msgs(),
        ))
    }

    /// Frontier-restricted sparse recompute through the model-zoo trait:
    /// per partition, [`GnnModel::layer_rows`] against the partition-local
    /// layer CSR over that partition's slice of the affected frontier —
    /// bit-identical to the dense layer on the stitched graph, charged at
    /// single-machine rate scaled by the configured core count (no
    /// simulated network traffic — see the module docs).
    fn trait_delta(&mut self, levels: &[Vec<NodeId>]) -> Result<(f64, u64, u64)> {
        let k = self.cfg.model.layers;
        let model = self.kind.model();
        let cpu0 = thread_cpu_time();
        for l in 0..k {
            let aff = &levels[l + 1];
            if aff.is_empty() {
                continue;
            }
            let relu = l + 1 != k;
            let (head, tail) = self.activations.split_at_mut(l + 1);
            let h = &head[l];
            for pi in 0..self.plan.p {
                let (rlo, rhi) = self.plan.node_range(pi);
                let lo = aff.partition_point(|&v| (v as usize) < rlo);
                let hi = aff.partition_point(|&v| (v as usize) < rhi);
                if lo == hi {
                    continue;
                }
                let rows = &aff[lo..hi];
                let block = model.layer_rows(
                    &self.layer_csrs[pi][l],
                    rlo,
                    h,
                    &self.weights,
                    l,
                    relu,
                    rows,
                );
                for (i, &r) in rows.iter().enumerate() {
                    tail[0].row_mut(r as usize).copy_from_slice(block.row(i));
                }
            }
        }
        let sim = (thread_cpu_time() - cpu0).max(0.0) / self.cfg.cluster.cores;
        Ok((sim, 0, 0))
    }
}

/// Frontier-restricted row-group GEMM: computes `(H W)[rows, F_m]` for
/// this rank and returns a full-size `rows_of(p) × feat_width(m)` tile
/// with zeros in every other row (the shape `deal_spmm`'s feature servers
/// gather from). Every member of the row group contributes its feature
/// columns' partial product for the *same* agreed row set, so the
/// exchange is |rows|-proportional — the Table 1 ring GEMM collapsed onto
/// the frontier.
pub fn delta_gemm_rows(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    h_tile: &Matrix,
    w: &Matrix,
    rows: &[u32],
    backend: &dyn Backend,
    phase: u32,
) -> Result<Matrix> {
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let local_rows = plan.rows_of(p_idx);
    let (flo, fhi) = plan.feat_range(m_idx);
    let out_bounds = even_ranges(w.cols, plan.m);
    let (olo, ohi) = (out_bounds[m_idx], out_bounds[m_idx + 1]);
    assert_eq!(h_tile.rows, local_rows);
    assert_eq!(h_tile.cols, fhi - flo);
    assert_eq!(w.rows, plan.feature_dim);
    let mut full = Matrix::zeros(local_rows, ohi - olo);
    ctx.mem.alloc(full.nbytes());
    if rows.is_empty() {
        // the whole row group agrees on `rows`, so nobody sends
        return Ok(full);
    }
    let idx: Vec<usize> = rows.iter().map(|&r| r as usize).collect();
    let sub = ctx.compute(|| h_tile.gather_rows(&idx));
    let w_mine = w.slice_rows(flo, fhi);
    let group = plan.row_group(p_idx);
    // Partial products for every other member's output columns, sent up
    // front (non-blocking), then my own columns while they fly.
    for (j, &rank) in group.iter().enumerate() {
        if j == m_idx {
            continue;
        }
        let wj = w_mine.slice_cols(out_bounds[j], out_bounds[j + 1]);
        let part = ctx.compute(|| backend.gemm(&sub, &wj))?;
        ctx.send(rank, Tag::of(phase, m_idx as u32), Payload::Matrix(part));
    }
    let w_own = w_mine.slice_cols(olo, ohi);
    let mut acc = ctx.compute(|| backend.gemm(&sub, &w_own))?;
    ctx.mem.alloc(acc.nbytes());
    for (j, &rank) in group.iter().enumerate() {
        if j == m_idx {
            continue;
        }
        let part = ctx.recv(rank, Tag::of(phase, j as u32)).into_matrix();
        for (a, &b) in acc.data.iter_mut().zip(&part.data) {
            *a += b;
        }
    }
    for (i, &r) in idx.iter().enumerate() {
        full.row_mut(r).copy_from_slice(acc.row(i));
    }
    ctx.mem.free(acc.nbytes());
    Ok(full)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(kind: &str, fanout: usize) -> DealConfig {
        let mut cfg = DealConfig::default();
        cfg.dataset.name = "products-sim".into();
        cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
        cfg.cluster.machines = 4;
        cfg.cluster.feature_parts = 2;
        cfg.model.kind = kind.into();
        cfg.model.layers = 2;
        cfg.model.fanout = fanout;
        cfg
    }

    /// Delta state after a batch must match a *fresh* state built over the
    /// updated graph: unchanged rows bit-identically (same samples, same
    /// dense arithmetic), affected rows within the distributed-vs-dense
    /// accumulation tolerance.
    fn assert_matches_fresh(state: &DeltaState, tol: f32) {
        let fresh = DeltaState::init_with(
            state.cfg.clone(),
            state.edge_list(),
            state.features().clone(),
        )
        .unwrap();
        let diff = state.embeddings().max_abs_diff(fresh.embeddings());
        assert!(diff < tol, "delta vs fresh recompute diverged: {}", diff);
    }

    /// The `layer_rows` path promises more: *every* cached level is
    /// bit-identical to a fresh dense init over the updated graph.
    fn assert_matches_fresh_bitwise(state: &DeltaState) {
        let fresh = DeltaState::init_with(
            state.cfg.clone(),
            state.edge_list(),
            state.features().clone(),
        )
        .unwrap();
        for l in 0..state.activations.len() {
            assert_eq!(
                state.activations[l], fresh.activations[l],
                "level {} diverged from a fresh dense init",
                l
            );
        }
    }

    #[test]
    fn gcn_delta_matches_fresh_recompute() {
        let mut state = DeltaState::init(small_cfg("gcn", 5)).unwrap();
        let mut rng = Rng::new(0xDE17A);
        for _ in 0..3 {
            let batch = state.synth_batch(&mut rng, 40, 40, 4);
            let rep = state.apply(&batch).unwrap();
            assert!(rep.dirty_rows > 0);
            assert_eq!(rep.frontier.len(), 3);
            assert!(!rep.updated_rows.is_empty());
            assert!(rep.net_bytes > 0, "restricted SPMM should still exchange frontier columns");
        }
        assert_matches_fresh(&state, 2e-3);
    }

    #[test]
    fn gcn_exact_mode_is_bitwise() {
        let mut state = DeltaState::init(small_cfg("gcn", 5)).unwrap();
        state.set_exact(true);
        let mut rng = Rng::new(0xE6AC);
        for _ in 0..2 {
            let batch = state.synth_batch(&mut rng, 35, 35, 3);
            let rep = state.apply(&batch).unwrap();
            assert_eq!(rep.net_bytes, 0, "exact mode stays off the cluster");
        }
        assert_matches_fresh_bitwise(&state);
    }

    #[test]
    fn gat_delta_matches_fresh_bitwise() {
        let mut state = DeltaState::init(small_cfg("gat", 5)).unwrap();
        let mut rng = Rng::new(0x6A7);
        for _ in 0..2 {
            let batch = state.synth_batch(&mut rng, 30, 30, 2);
            state.apply(&batch).unwrap();
        }
        assert_matches_fresh_bitwise(&state);
    }

    #[test]
    fn sage_delta_matches_fresh_bitwise_both_aggregators() {
        for agg in ["mean", "pool"] {
            let mut cfg = small_cfg("sage", 5);
            cfg.model.aggregator = agg.into();
            let mut state = DeltaState::init(cfg).unwrap();
            let mut rng = Rng::new(0x5A6E);
            for _ in 0..2 {
                let batch = state.synth_batch(&mut rng, 30, 30, 2);
                state.apply(&batch).unwrap();
            }
            assert_matches_fresh_bitwise(&state);
        }
    }

    /// Parity of the per-partition sparse recompute against the dense
    /// stitched-graph fallback it replaced: restricting row-by-row inside
    /// each partition CSR must reproduce, bit for bit, `gat_layer_rows`
    /// over the stitched global layer graph.
    #[test]
    fn partitioned_layer_rows_matches_stitched_dense_rows() {
        use crate::model::reference::gat_layer_rows;
        let state = DeltaState::init(small_cfg("gat", 5)).unwrap();
        let k = state.cfg.model.layers;
        let stitched = stitch_layers(&state.layer_csrs, k);
        let model = state.kind.model();
        let n = state.n_nodes();
        let rows: Vec<NodeId> = (0..n as NodeId).step_by(3).collect();
        for l in 0..k {
            let relu = l + 1 != k;
            let h = &state.activations[l];
            let dense = gat_layer_rows(&stitched[l], 0, h, &state.weights, l, relu, &rows);
            for pi in 0..state.plan.p {
                let (rlo, rhi) = state.plan.node_range(pi);
                let lo = rows.partition_point(|&v| (v as usize) < rlo);
                let hi = rows.partition_point(|&v| (v as usize) < rhi);
                if lo == hi {
                    continue;
                }
                let block = model.layer_rows(
                    &state.layer_csrs[pi][l],
                    rlo,
                    h,
                    &state.weights,
                    l,
                    relu,
                    &rows[lo..hi],
                );
                for (i, ri) in (lo..hi).enumerate() {
                    assert_eq!(
                        block.row(i),
                        dense.row(ri),
                        "layer {} row {} diverged between partitioned and stitched recompute",
                        l,
                        rows[ri]
                    );
                }
            }
        }
    }

    #[test]
    fn full_fanout_delta_matches_fresh_recompute() {
        let mut state = DeltaState::init(small_cfg("gcn", 0)).unwrap();
        let mut rng = Rng::new(0xF0);
        let batch = state.synth_batch(&mut rng, 25, 25, 0);
        state.apply(&batch).unwrap();
        assert_matches_fresh(&state, 2e-3);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut state = DeltaState::init(small_cfg("gcn", 5)).unwrap();
        let before = state.embeddings().clone();
        let edges_before = state.n_edges();
        let rep = state.apply(&UpdateBatch::default()).unwrap();
        assert_eq!(rep.dirty_rows, 0);
        assert!(rep.updated_rows.is_empty());
        assert_eq!(rep.net_bytes, 0);
        assert_eq!(state.embeddings(), &before);
        assert_eq!(state.n_edges(), edges_before);
    }

    #[test]
    fn feature_update_touches_only_the_frontier() {
        let mut state = DeltaState::init(small_cfg("gcn", 5)).unwrap();
        let before = state.embeddings().clone();
        let dim = state.plan().feature_dim;
        let batch = UpdateBatch {
            feature_updates: vec![(7, vec![0.25; dim])],
            ..Default::default()
        };
        let rep = state.apply(&batch).unwrap();
        assert_eq!(rep.dirty_rows, 0);
        assert_eq!(rep.frontier[0], 1);
        assert!(rep.frontier[2] >= rep.frontier[1]);
        // rows outside the final frontier keep their exact cached values
        let updated: std::collections::HashSet<NodeId> =
            rep.updated_rows.iter().copied().collect();
        let after = state.embeddings();
        for r in 0..state.n_nodes() {
            if !updated.contains(&(r as NodeId)) {
                assert_eq!(after.row(r), before.row(r), "untouched row {} changed", r);
            }
        }
        assert_matches_fresh(&state, 2e-3);
    }

    #[test]
    fn edge_removals_shrink_the_graph() {
        let mut state = DeltaState::init(small_cfg("gcn", 5)).unwrap();
        let before = state.n_edges();
        let mut rng = Rng::new(3);
        let batch = state.synth_batch(&mut rng, 0, 50, 0);
        let rep = state.apply(&batch).unwrap();
        assert!(rep.edges_removed > 0);
        assert_eq!(state.n_edges(), before - rep.edges_removed);
        assert_matches_fresh(&state, 2e-3);
    }

    #[test]
    fn synth_batch_respects_bounds() {
        let state = DeltaState::init(small_cfg("gcn", 5)).unwrap();
        let mut rng = Rng::new(9);
        let batch = state.synth_batch(&mut rng, 10, 10, 3);
        batch.validate(state.n_nodes(), state.plan().feature_dim).unwrap();
        assert_eq!(batch.add_edges.len(), 10);
        assert_eq!(batch.remove_edges.len(), 10);
        assert_eq!(batch.feature_updates.len(), 3);
        // removals name edges that actually exist
        let el = state.edge_list();
        for rm in &batch.remove_edges {
            assert!(el.edges.contains(rm), "removal {:?} not in graph", rm);
        }
    }
}
