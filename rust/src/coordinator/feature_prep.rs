//! Feature preparation (paper §3.5 "Fusing feature preparation with the
//! first GNN primitive", Fig. 13, Fig. 21).
//!
//! Node features arrive as unsorted shard files on a shared filesystem.
//! Three strategies bring them into the collaborative layout:
//!
//! - **scan** (baseline): every machine reads *all* feature files and
//!   keeps its own tile — `O(M·N)` filesystem traffic; the shared-FS
//!   aggregate bandwidth caps it, so adding machines does not help.
//! - **redistribute**: each machine reads `1/world` of the rows, then an
//!   all-to-all moves every row to its `(p, m)` owners — FS traffic drops
//!   `world×`, network pays `O(N·(world-1)/world)` rows.
//! - **fused** (Deal): each machine reads `1/world` of the rows and *no
//!   redistribution happens*. The loader shard computes the first-layer
//!   projection locally (row-wise independent), serves `(HW)` rows to the
//!   first SPMM by a location table, and the SPMM's output-oriented
//!   assignment lands `H^(1)` already in the collaborative layout.
//!
//! The shared filesystem is modeled like a network link with a fixed
//! *aggregate* bandwidth (EFS-style, per the paper's [60] citation):
//! concurrent readers serialize on it.

use std::sync::{Arc, Mutex};

use crate::cluster::{Ctx, Payload, Tag};
use crate::partition::PartitionPlan;
use crate::runtime::Backend;
use crate::storage::{PagedMatrix, SharedPageCache};
use crate::tensor::Matrix;
use crate::util::even_ranges;

/// Feature preparation strategy (Fig. 21 series).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeaturePrep {
    Scan,
    Redistribute,
    Fused,
}

impl FeaturePrep {
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "scan" => Ok(FeaturePrep::Scan),
            "redistribute" => Ok(FeaturePrep::Redistribute),
            "fused" => Ok(FeaturePrep::Fused),
            other => anyhow::bail!("unknown feature_prep '{}' (scan|redistribute|fused)", other),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            FeaturePrep::Scan => "scan",
            FeaturePrep::Redistribute => "redistribute",
            FeaturePrep::Fused => "fused",
        }
    }
}

/// Shared-filesystem model: serializes reads on an aggregate-bandwidth
/// "link" and returns each read's completion time.
pub struct SimFs {
    aggregate_gbps: f64,
    busy_until: Mutex<f64>,
}

impl SimFs {
    /// EFS-like default: 4 Gbps aggregate throughput.
    pub fn new(aggregate_gbps: f64) -> Arc<SimFs> {
        Arc::new(SimFs { aggregate_gbps, busy_until: Mutex::new(0.0) })
    }

    /// Schedule a read of `bytes` starting at `now`; returns completion.
    pub fn read(&self, now: f64, bytes: u64) -> f64 {
        let mut busy = self.busy_until.lock().unwrap();
        let start = busy.max(now);
        let done = start + bytes as f64 * 8.0 / (self.aggregate_gbps * 1e9);
        *busy = done;
        done
    }

    /// Schedule a transfer of `bytes` at the device's current backlog
    /// front and return its **duration** (not a completion stamp). The
    /// spill-device accounting (`storage::PageFile`) uses this: callers
    /// without a simulated clock charge exactly the transfer time, and
    /// sharing one device still serializes (the backlog advances) without
    /// ever re-charging another file's backlog.
    pub fn charge(&self, bytes: u64) -> f64 {
        let mut busy = self.busy_until.lock().unwrap();
        let dt = bytes as f64 * 8.0 / (self.aggregate_gbps * 1e9);
        *busy += dt;
        dt
    }

    /// Reset between stages/benches.
    pub fn reset(&self) {
        *self.busy_until.lock().unwrap() = 0.0;
    }
}

/// The "unsorted feature files": a row permutation standing in for the
/// arbitrary on-disk order, plus the location table (which loader shard
/// holds each node's features — Fig. 13's table).
pub struct FeatureStore {
    /// `file_order[i]` = node whose features sit at file position `i`.
    pub file_order: Vec<u32>,
    /// `loader_of[v]` = rank whose shard contains node `v` (fused mode).
    pub loader_of: Vec<u32>,
    /// shard boundaries over file positions (world + 1 entries).
    pub shard_bounds: Vec<usize>,
}

impl FeatureStore {
    pub fn new(n_nodes: usize, world: usize, seed: u64) -> FeatureStore {
        let mut order: Vec<u32> = (0..n_nodes as u32).collect();
        let mut rng = crate::util::rng::Rng::new(seed ^ 0xF11E);
        rng.shuffle(&mut order);
        let shard_bounds = even_ranges(n_nodes, world);
        let mut loader_of = vec![0u32; n_nodes];
        for w in 0..world {
            for i in shard_bounds[w]..shard_bounds[w + 1] {
                loader_of[order[i] as usize] = w as u32;
            }
        }
        FeatureStore { file_order: order, loader_of, shard_bounds }
    }

    /// Nodes in rank `w`'s loader shard, in file order.
    pub fn shard_nodes(&self, w: usize) -> &[u32] {
        &self.file_order[self.shard_bounds[w]..self.shard_bounds[w + 1]]
    }
}

const PREP_PHASE: u32 = 0xFEA7;

/// Out-of-core fused staging (DESIGN.md §Out-of-core-storage): stream
/// this rank's loader shard through the first-layer projection into a
/// paged tier, one page-sized band at a time — read the band's rows from
/// the shared FS (the band reads serialize on `SimFs` and sum to the
/// monolithic read time), project `band × W0`, write one page. The raw
/// shard is never fully resident; the projected table lands behind the
/// budgeted cache that then serves loader fetches.
///
/// Bit-identity: the `Native` projection is row-wise independent and each
/// output row accumulates its `k` products in the same ascending order
/// whether the GEMM runs whole-shard or band-wise, so the paged `HW`
/// equals the in-memory one bit for bit. Accelerated (AOT tile) backends
/// compile fixed shapes and may accumulate shape-dependently, so for a
/// non-native backend the projection keeps its single whole-shard GEMM
/// call (the shard is transient — gathered, projected, paged out, freed)
/// and only the *output* is paged.
#[allow(clippy::too_many_arguments)]
pub fn project_shard_paged(
    ctx: &mut Ctx,
    store: &FeatureStore,
    features: &Matrix,
    fs: &SimFs,
    w0: &Matrix,
    backend: &dyn Backend,
    cache: &SharedPageCache,
    page_rows: usize,
    spill_fs: Arc<SimFs>,
    tag: &str,
) -> crate::Result<PagedMatrix> {
    let mine = store.shard_nodes(ctx.rank);
    let row_bytes = (features.cols * 4) as u64;
    let pm = cache.with(|c| {
        PagedMatrix::create(c, tag, mine.len(), w0.cols, page_rows, spill_fs)
    })?;
    if backend.name() != "native" {
        // shape-preserving path: exactly the in-memory read + one GEMM,
        // then page the projected table out
        let done = fs.read(ctx.now(), row_bytes * mine.len() as u64);
        ctx.advance((done - ctx.now()).max(0.0));
        let shard = ctx.compute(|| {
            let idx: Vec<usize> = mine.iter().map(|&v| v as usize).collect();
            features.gather_rows(&idx)
        });
        let hw = ctx.compute(|| backend.gemm(&shard, w0))?;
        ctx.mem.with_transient(shard.nbytes() + hw.nbytes(), || ());
        let io = cache.with(|c| -> crate::Result<f64> {
            pm.write_rows(c, 0, &hw)?;
            Ok(c.take_io_secs())
        })?;
        ctx.advance(io);
        crate::storage::charge_main(ctx, cache);
        return Ok(pm);
    }
    let mut lo = 0;
    while lo < mine.len() {
        let hi = (lo + page_rows).min(mine.len());
        let done = fs.read(ctx.now(), row_bytes * (hi - lo) as u64);
        ctx.advance((done - ctx.now()).max(0.0));
        let band = ctx.compute(|| {
            let idx: Vec<usize> = mine[lo..hi].iter().map(|&v| v as usize).collect();
            features.gather_rows(&idx)
        });
        let hw_band = ctx.compute(|| backend.gemm(&band, w0))?;
        ctx.mem.with_transient(band.nbytes() + hw_band.nbytes(), || ());
        let io = cache.with(|c| -> crate::Result<f64> {
            pm.write_rows(c, lo, &hw_band)?;
            Ok(c.take_io_secs())
        })?;
        ctx.advance(io);
        lo = hi;
    }
    crate::storage::charge_main(ctx, cache);
    Ok(pm)
}

/// Per-machine: run `scan` or `redistribute` preparation, returning this
/// rank's collaborative tile of `H^(0)`. (`Fused` skips this stage
/// entirely — see `fused_first_layer` in `coordinator`.)
pub fn prepare_features(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    store: &FeatureStore,
    features: &Matrix, // the "on-disk" content, globally indexed
    fs: &SimFs,
    strategy: FeaturePrep,
) -> Matrix {
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let (rlo, rhi) = plan.node_range(p_idx);
    let (flo, fhi) = plan.feat_range(m_idx);
    let row_bytes = (features.cols * 4) as u64;

    match strategy {
        FeaturePrep::Scan => {
            // Read every shard file, keep own rows/cols.
            let done = fs.read(ctx.now(), row_bytes * features.rows as u64);
            ctx.advance((done - ctx.now()).max(0.0));
            let mut tile = Matrix::zeros(rhi - rlo, fhi - flo);
            ctx.mem.alloc(tile.nbytes());
            ctx.compute(|| {
                for r in rlo..rhi {
                    tile.row_mut(r - rlo)
                        .copy_from_slice(&features.row(r)[flo..fhi]);
                }
            });
            tile
        }
        FeaturePrep::Redistribute => {
            // Read my loader shard...
            let mine = store.shard_nodes(ctx.rank);
            let done = fs.read(ctx.now(), row_bytes * mine.len() as u64);
            ctx.advance((done - ctx.now()).max(0.0));
            // ...then all-to-all: send each row's column slice to each of
            // its owners (one message per (dst_rank) carrying ids + data).
            for dst in 0..plan.world() {
                let (dp, dm) = plan.coords_of(dst);
                let (dlo, dhi) = plan.node_range(dp);
                let (dflo, dfhi) = plan.feat_range(dm);
                let ids: Vec<u32> = mine
                    .iter()
                    .copied()
                    .filter(|&v| (v as usize) >= dlo && (v as usize) < dhi)
                    .collect();
                let mut block = Matrix::zeros(ids.len(), dfhi - dflo);
                for (i, &v) in ids.iter().enumerate() {
                    block
                        .row_mut(i)
                        .copy_from_slice(&features.row(v as usize)[dflo..dfhi]);
                }
                if dst == ctx.rank {
                    // keep local rows aside via self-send (free link)
                }
                ctx.send(dst, Tag::of(PREP_PHASE, ctx.rank as u32), Payload::U32(ids));
                ctx.send(dst, Tag::of(PREP_PHASE + 1, ctx.rank as u32), Payload::Matrix(block));
            }
            let mut tile = Matrix::zeros(rhi - rlo, fhi - flo);
            ctx.mem.alloc(tile.nbytes());
            for src in 0..plan.world() {
                let ids = ctx.recv(src, Tag::of(PREP_PHASE, src as u32)).into_u32();
                let block = ctx.recv(src, Tag::of(PREP_PHASE + 1, src as u32)).into_matrix();
                for (i, &v) in ids.iter().enumerate() {
                    tile.row_mut(v as usize - rlo).copy_from_slice(block.row(i));
                }
            }
            tile
        }
        FeaturePrep::Fused => {
            panic!("fused preparation is part of the first layer — use coordinator::fused_first_layer")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, NetConfig};
    use crate::primitives::scatter;
    use crate::util::rng::Rng;
    use std::sync::Arc as StdArc;

    #[test]
    fn fs_serializes_aggregate_bandwidth() {
        let fs = SimFs::new(1.0); // 1 Gbps
        let t1 = fs.read(0.0, 125_000_000); // 1 second of bytes
        let t2 = fs.read(0.0, 125_000_000);
        assert!((t1 - 1.0).abs() < 1e-9);
        assert!((t2 - 2.0).abs() < 1e-9, "reads must serialize");
        fs.reset();
        assert!((fs.read(0.0, 125_000_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn charge_returns_durations_not_completion_stamps() {
        let fs = SimFs::new(1.0); // 1 Gbps
        let d1 = fs.charge(125_000_000); // 1 second of bytes
        let d2 = fs.charge(125_000_000);
        assert!((d1 - 1.0).abs() < 1e-9);
        assert!((d2 - 1.0).abs() < 1e-9, "a second charge must not re-pay the backlog");
        // stamped reads still queue behind the charged backlog
        assert!((fs.read(0.0, 125_000_000) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn store_covers_all_nodes() {
        let store = FeatureStore::new(100, 4, 7);
        let mut seen = vec![false; 100];
        for w in 0..4 {
            for &v in store.shard_nodes(w) {
                assert!(!seen[v as usize]);
                seen[v as usize] = true;
                assert_eq!(store.loader_of[v as usize], w as u32);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn scan_and_redistribute_produce_collaborative_layout() {
        let mut rng = Rng::new(12);
        let n = 24;
        let d = 8;
        let features = Matrix::random(n, d, 1.0, &mut rng);
        let plan = PartitionPlan::new(n, d, 2, 2);
        let expect = scatter(&plan, &features);
        for strategy in [FeaturePrep::Scan, FeaturePrep::Redistribute] {
            let store = StdArc::new(FeatureStore::new(n, plan.world(), 3));
            let fs = SimFs::new(4.0);
            let plan2 = plan.clone();
            let feats = StdArc::new(features.clone());
            let cluster = Cluster::new(plan.world(), NetConfig::default());
            let (tiles, report) = cluster
                .run(move |ctx| prepare_features(ctx, &plan2, &store, &feats, &fs, strategy))
                .unwrap();
            for (rank, tile) in tiles.iter().enumerate() {
                assert_eq!(tile, &expect[rank], "{:?} rank {}", strategy, rank);
            }
            assert!(report.makespan() > 0.0);
        }
    }

    #[test]
    fn scan_costs_more_fs_time_than_redistribute() {
        let mut rng = Rng::new(13);
        let n = 64;
        let d = 16;
        let features = Matrix::random(n, d, 1.0, &mut rng);
        let plan = PartitionPlan::new(n, d, 2, 2);
        let mut makespans = Vec::new();
        for strategy in [FeaturePrep::Scan, FeaturePrep::Redistribute] {
            let store = StdArc::new(FeatureStore::new(n, plan.world(), 3));
            let fs = SimFs::new(0.001); // slow FS so it dominates
            let plan2 = plan.clone();
            let feats = StdArc::new(features.clone());
            let cluster = Cluster::new(plan.world(), NetConfig::default());
            let (_, report) = cluster
                .run(move |ctx| prepare_features(ctx, &plan2, &store, &feats, &fs, strategy))
                .unwrap();
            makespans.push(report.makespan());
        }
        assert!(
            makespans[0] > makespans[1] * 2.0,
            "scan {} should dwarf redistribute {}",
            makespans[0],
            makespans[1]
        );
    }

    #[test]
    fn parse_names() {
        assert_eq!(FeaturePrep::parse("fused").unwrap(), FeaturePrep::Fused);
        assert!(FeaturePrep::parse("x").is_err());
        assert_eq!(FeaturePrep::Scan.name(), "scan");
    }
}
