//! The end-to-end inference coordinator: construct → partition → feature
//! preparation → layerwise sampling → distributed layer-by-layer GNN
//! inference (paper Fig. 2 / Fig. 4), with per-stage time/memory/byte
//! accounting (Fig. 3) and the fused first layer (§3.5, Fig. 13).

pub mod delta;
pub mod feature_prep;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::cluster::{Cluster, ClusterReport, Ctx, Payload, Tag};
use crate::config::DealConfig;
use crate::graph::builder::{build_distributed, GraphPartition};
use crate::graph::{datasets, EdgeList};
use crate::model::{gcn::gcn_forward, ExecOpts, LayerPart, ModelKind, ModelWeights};
use crate::partition::PartitionPlan;
use crate::runtime::{backend_from_config, Act, Backend};
use crate::tensor::Matrix;
use crate::util::bench::time_once;
use crate::Result;

pub use feature_prep::{FeaturePrep, FeatureStore, SimFs};

/// Timing/accounting for one pipeline stage.
#[derive(Clone, Debug)]
pub struct StageReport {
    pub name: &'static str,
    /// Wall-clock seconds on this host (informational).
    pub wall_secs: f64,
    /// Simulated cluster makespan for the stage.
    pub sim_secs: f64,
    pub cluster: Option<ClusterReport>,
}

/// Aggregated stage timings.
#[derive(Clone, Debug, Default)]
pub struct Stages(pub Vec<StageReport>);

impl Stages {
    pub fn push(&mut self, s: StageReport) {
        self.0.push(s);
    }
    /// Total simulated end-to-end time.
    pub fn total(&self) -> f64 {
        self.0.iter().map(|s| s.sim_secs).sum()
    }
    pub fn sim_of(&self, name: &str) -> f64 {
        self.0.iter().filter(|s| s.name == name).map(|s| s.sim_secs).sum()
    }
    /// Pre-processing fraction (everything before "inference") — the
    /// Fig. 3a ratio.
    pub fn preprocessing_fraction(&self) -> f64 {
        let total = self.total();
        if total == 0.0 {
            return 0.0;
        }
        (total - self.sim_of("inference")) / total
    }
}

/// Result of one end-to-end run.
pub struct RunReport {
    pub stages: Stages,
    pub plan: PartitionPlan,
    /// Full embedding matrix (gathered from tiles).
    pub embeddings: Option<Matrix>,
    /// Peak tracked memory across machines (bytes).
    pub max_peak_mem: u64,
    /// The autotune plan the inference stage ran under (`None` when
    /// autotuning was off). Choices are schedule-only: a tuned run's
    /// `embeddings` are bit-identical to any fixed configuration's.
    pub autotune: Option<std::sync::Arc<crate::runtime::autotune::Plan>>,
}

impl RunReport {
    /// Shard the refreshed embeddings for the serving tier, reusing this
    /// run's partition row ownership (`PartitionPlan::serving`). `None`
    /// when the run was configured with `keep_embeddings = false`.
    pub fn serving_table(&self) -> Option<crate::serve::ShardedTable> {
        self.embeddings
            .as_ref()
            .map(|e| crate::serve::ShardedTable::from_inference_plan(&self.plan, e, 0))
    }

    /// Re-plan this run's serving layout for an elastic world of `ranks`
    /// band owners (`cluster::membership`): same node set, `ranks` row
    /// shards, one feature part. The membership layer diffs this against
    /// the current layout (`PartitionPlan::band_diff`) to move only the
    /// rows whose owner changes.
    pub fn replan_serving(
        &self,
        ranks: usize,
        out_dim: usize,
    ) -> std::result::Result<PartitionPlan, String> {
        self.plan.serving(out_dim).refactor_world(ranks, 1)
    }
}

/// The end-to-end pipeline.
pub struct Pipeline {
    pub cfg: DealConfig,
    /// Keep the gathered embeddings in the report (disable for large runs).
    pub keep_embeddings: bool,
    /// In-memory dataset override (delta-parity tests, `deal stream
    /// --verify`): the edge list is still staged to
    /// `data/<tag>.edges.bin` so construction reads a real file.
    dataset_override: Option<(String, EdgeList, Matrix)>,
}

impl Pipeline {
    pub fn new(cfg: DealConfig) -> Self {
        Pipeline { cfg, keep_embeddings: true, dataset_override: None }
    }

    /// A pipeline over an explicit in-memory graph + features instead of
    /// the registry dataset named in `cfg`. `tag` names the staged edge
    /// file; callers running concurrently must pick distinct tags.
    pub fn with_dataset(cfg: DealConfig, tag: &str, edges: EdgeList, features: Matrix) -> Self {
        Pipeline {
            cfg,
            keep_embeddings: true,
            dataset_override: Some((tag.to_string(), edges, features)),
        }
    }

    /// Stage the dataset's edge file on "disk" (not counted — the input is
    /// assumed to exist, as in the paper).
    fn stage_dataset(&self) -> Result<(PathBuf, datasets::Dataset)> {
        let dir = PathBuf::from("data");
        std::fs::create_dir_all(&dir)?;
        if let Some((tag, edges, features)) = &self.dataset_override {
            anyhow::ensure!(
                edges.n_nodes == features.rows,
                "override features have {} rows for {} nodes",
                features.rows,
                edges.n_nodes
            );
            let path = dir.join(format!("{}.edges.bin", tag));
            // always rewrite: the override's content changes between runs
            edges.write_binary(&path)?;
            // cloning keeps `run(&self)` repeatable (Refresher re-runs the
            // same pipeline); override graphs are test/bench scale
            let ds = datasets::Dataset {
                name: tag.clone(),
                edges: edges.clone(),
                features: features.clone(),
                feature_dim: features.cols,
            };
            return Ok((path, ds));
        }
        let ds = datasets::load(&self.cfg.dataset.name, self.cfg.dataset.scale)?;
        let path = dir.join(format!(
            "{}-x{}.edges.bin",
            ds.name,
            self.cfg.dataset.scale
        ));
        if !path.exists() {
            ds.edges.write_binary(&path)?;
        }
        Ok((path, ds))
    }

    /// Run the full pipeline.
    pub fn run(&self) -> Result<RunReport> {
        let (p, m) = self.cfg.parts()?;
        let world = p * m;
        let net = self.cfg.net();
        let (path, ds) = self.stage_dataset()?;
        let dim = ds.feature_dim;
        let mut stages = Stages::default();
        let mut max_peak = 0u64;

        // ---- Stage 1: graph construction (Fig. 2 ①–③): fully
        // distributed (Deal) or single-worker (DistDGL-like baseline).
        let single = self.cfg.exec.construction == "single";
        let (res, wall) = time_once(|| {
            if single {
                crate::graph::builder::build_single_worker(&path, world, p, net)
            } else {
                build_distributed(&path, world, p, net)
            }
        });
        let (partitions, construct_rep): (Vec<GraphPartition>, ClusterReport) = res?;
        max_peak = max_peak.max(construct_rep.max_peak_mem());
        stages.push(StageReport {
            name: "construct",
            wall_secs: wall,
            sim_secs: construct_rep.makespan(),
            cluster: Some(construct_rep),
        });
        if self.dataset_override.is_some() {
            // override stagings are per-run scratch (tagged per caller);
            // registry stagings stay cached for reuse
            let _ = std::fs::remove_file(&path);
        }

        // ---- Stage 2: partition planning (lightweight by design —
        // Observation #1).
        let (plan, wall) = time_once(|| PartitionPlan::new(ds.edges.n_nodes, dim, p, m));
        stages.push(StageReport { name: "partition", wall_secs: wall, sim_secs: wall, cluster: None });

        // ---- Stage 3: all-node layerwise sampling (§3.2).
        let partitions = Arc::new(partitions);
        let layers = self.cfg.model.layers;
        let fanout = self.cfg.model.fanout;
        let seed = self.cfg.exec.seed;
        let plan_arc = Arc::new(plan.clone());
        let parts_in = Arc::clone(&partitions);
        let cluster = Cluster::new(world, net).with_cores(self.cfg.cluster.cores);
        let (res, wall) = time_once(|| {
            cluster.run(move |ctx| {
                let (p_idx, m_idx) = plan_arc.coords_of(ctx.rank);
                let g = &parts_in[p_idx].csr;
                // Same seed per partition → row-group machines derive
                // identical samples without communicating.
                let lg = ctx.compute(|| {
                    crate::sampling::sample_all_layers(g, layers, fanout, seed ^ p_idx as u64)
                });
                if m_idx == 0 {
                    Some(lg.layers.into_iter().map(LayerPart::new).collect::<Vec<_>>())
                } else {
                    None
                }
            })
        });
        let (sampled, sample_rep) = res?;
        max_peak = max_peak.max(sample_rep.max_peak_mem());
        stages.push(StageReport {
            name: "sampling",
            wall_secs: wall,
            sim_secs: sample_rep.makespan(),
            cluster: Some(sample_rep),
        });
        // parts per partition (from each row group's m=0 machine)
        let mut parts_by_p: Vec<Vec<LayerPart>> = Vec::with_capacity(p);
        for (rank, v) in sampled.into_iter().enumerate() {
            if let Some(parts) = v {
                debug_assert_eq!(plan.coords_of(rank).1, 0);
                parts_by_p.push(parts);
            }
        }
        anyhow::ensure!(parts_by_p.len() == p, "sampling returned wrong partition count");
        let parts_by_p = Arc::new(parts_by_p);

        // ---- Stage 4+5: feature preparation + inference.
        let strategy = FeaturePrep::parse(&self.cfg.exec.feature_prep)?;
        let backend = backend_from_config(&self.cfg.exec.backend, &self.cfg.artifacts_dir())?;
        let kind = ModelKind::parse(&self.cfg.model.kind)?;
        let model_cfg = self.cfg.model_config(dim)?;
        let weights = if self.cfg.model.weights.is_empty() {
            ModelWeights::random(&model_cfg, seed ^ 0xBEEF)
        } else {
            ModelWeights::load(&model_cfg, std::path::Path::new(&self.cfg.model.weights))?
        };
        let weights = Arc::new(weights);
        let features = Arc::new(ds.features);
        let store = Arc::new(FeatureStore::new(plan.n_nodes, world, seed));
        let fs = SimFs::new(4.0);
        let mode = self.cfg.exec_mode()?;
        let opts = ExecOpts { mode, group_cols: self.cfg.exec.group_cols, phase: 0x1000 };

        // Cost-model-driven autotuning (DESIGN.md §Autotuning): calibrate
        // (or load the cached sidecar), price this run's shape, and install
        // the chosen variants around the inference launch. Choices are
        // schedule-only — embeddings stay bit-identical to every fixed
        // configuration, which tests/autotune.rs proves exhaustively.
        let tuned = if self.cfg.exec.autotune || crate::runtime::autotune::enabled() {
            use crate::runtime::autotune;
            let (calib, _source) =
                autotune::Calibration::load_or_measure(&autotune::sidecar_path(), seed);
            let shape =
                autotune::ShapeInfo::for_run(&self.cfg, ds.edges.n_nodes, ds.edges.n_edges(), dim)?;
            Some(Arc::new(autotune::Planner::new(calib).plan(&shape)))
        } else {
            None
        };

        // fused is a GCN-shaped optimization; every other model falls back
        // to redistribute (documented in DESIGN.md).
        let effective = if strategy == FeaturePrep::Fused && kind != ModelKind::Gcn {
            FeaturePrep::Redistribute
        } else {
            strategy
        };

        let plan_arc = Arc::new(plan.clone());
        let parts_arc = Arc::clone(&parts_by_p);
        let weights2 = Arc::clone(&weights);
        let features2 = Arc::clone(&features);
        let store2 = Arc::clone(&store);
        let fs2 = Arc::clone(&fs);
        let backend2 = Arc::clone(&backend);
        let cluster = Cluster::new(world, net).with_cores(self.cfg.cluster.cores);
        let tuned_for_launch = tuned.clone();
        let (res, wall) = time_once(move || {
            let launch = move || cluster.run(move |ctx| -> Result<Matrix> {
                let (p_idx, _) = plan_arc.coords_of(ctx.rank);
                let parts = &parts_arc[p_idx];
                match effective {
                    FeaturePrep::Fused => {
                        // fused first layer consumes loader-sharded
                        // features directly; remaining layers are standard.
                        let h1 = fused_first_layer(
                            ctx,
                            &plan_arc,
                            &store2,
                            &features2,
                            &fs2,
                            &parts[0],
                            &weights2,
                            backend2.as_ref(),
                            opts.phase,
                        )?;
                        let rest = ExecOpts { phase: opts.phase + 0x100, ..opts };
                        gcn_rest(ctx, &plan_arc, &parts[1..], h1, &weights2, backend2.as_ref(), &rest)
                    }
                    _ => {
                        let h0 = feature_prep::prepare_features(
                            ctx,
                            &plan_arc,
                            &store2,
                            &features2,
                            &fs2,
                            effective,
                        );
                        ctx.barrier();
                        // model-zoo dispatch: every GnnModel impl shares
                        // this launch path
                        kind.model().forward(
                            ctx,
                            &plan_arc,
                            parts,
                            h0,
                            &weights2,
                            backend2.as_ref(),
                            &opts,
                        )
                    }
                }
            });
            match &tuned_for_launch {
                Some(plan) => plan.apply(launch),
                None => launch(),
            }
        });
        let (tiles, infer_rep) = res?;
        let tiles: Vec<Matrix> = tiles.into_iter().collect::<Result<_>>()?;
        max_peak = max_peak.max(infer_rep.max_peak_mem());
        stages.push(StageReport {
            name: "inference",
            wall_secs: wall,
            sim_secs: infer_rep.makespan(),
            cluster: Some(infer_rep),
        });

        let embeddings = if self.keep_embeddings {
            Some(crate::primitives::gather_tiles(&plan, dim, &tiles))
        } else {
            None
        };
        Ok(RunReport { stages, plan, embeddings, max_peak_mem: max_peak, autotune: tuned })
    }

    /// Rebuild the serving state from a durable store instead of
    /// recomputing it: open `dir`, replay log-over-checkpoint, and wrap
    /// the recovered table in a [`RunReport`] shaped like [`run`]'s (one
    /// `"recovery"` stage whose cluster report carries the store's
    /// durability counters), so `deal serve --resume` and the restart
    /// bench reuse every downstream path unchanged.
    ///
    /// Returns the report, the reopened store (ready for further
    /// journaling), and the recovery details — with [`Recovered::table`]
    /// moved into `report.embeddings` (the `Recovered` copy is emptied).
    ///
    /// [`run`]: Pipeline::run
    pub fn warm_restart(
        &self,
        dir: &Path,
    ) -> Result<(RunReport, crate::storage::DurableStore, crate::storage::Recovered)> {
        use crate::storage::{DurableOptions, DurableStore};

        let (p, m) = self.cfg.parts()?;
        let (opened, wall) = time_once(|| DurableStore::open(dir, DurableOptions::default()));
        let (store, mut rec) = opened?;
        anyhow::ensure!(
            store.seed() == self.cfg.exec.seed,
            "durable store in {:?} was written with seed {}, config says {}",
            dir,
            store.seed(),
            self.cfg.exec.seed
        );
        let table = std::mem::replace(&mut rec.table, Matrix::zeros(0, 0));
        anyhow::ensure!(
            table.rows > 0 && table.cols >= m,
            "recovered table {}x{} cannot shard over {} feature parts",
            table.rows,
            table.cols,
            m
        );
        let plan = PartitionPlan::new(table.rows, table.cols, p, m);
        let mut cluster = ClusterReport::new(1);
        cluster.machines[0].storage = store.counters();
        cluster.final_clocks[0] = rec.sim_secs;
        let mut stages = Stages::default();
        stages.push(StageReport {
            name: "recovery",
            wall_secs: wall,
            sim_secs: rec.sim_secs,
            cluster: Some(cluster),
        });
        let report = RunReport {
            stages,
            plan,
            embeddings: Some(table),
            max_peak_mem: 0,
            autotune: None,
        };
        Ok((report, store, rec))
    }
}

/// Continue a GCN forward from layer 1 (used after the fused first layer).
fn gcn_rest(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    parts: &[LayerPart],
    h: Matrix,
    weights: &ModelWeights,
    backend: &dyn Backend,
    opts: &ExecOpts,
) -> Result<Matrix> {
    if parts.is_empty() {
        return Ok(h);
    }
    // Reuse gcn_forward with a weight view shifted by one layer.
    let shifted = ModelWeights {
        config: {
            let mut c = weights.config.clone();
            c.layers -= 1;
            c
        },
        tensors: weights.tensors[weights.config.tensors_per_layer()..].to_vec(),
    };
    gcn_forward(ctx, plan, parts, h, &shifted, backend, opts)
}

/// The fused first GCN layer (§3.5, Fig. 13): loader shards project their
/// own rows (`H W0` is row-independent), the SPMM fetches projected rows
/// *from loader locations* via a location table, and the output-oriented
/// aggregation lands `H^(1)` in the collaborative layout — no
/// redistribution round.
///
/// Loader responses stream as row-band chunks and are assembled on
/// arrival; the aggregation itself stays whole-buffer because each
/// destination row mixes sources from *several* loader blocks, so
/// chunk-wise accumulation would make the float-add order depend on the
/// chunk size — forbidden by the determinism contract (DESIGN.md
/// §Pipelined-communication).
///
/// When a storage budget is active (`storage::mem_budget() > 0`) the
/// whole stage runs out-of-core (the paged twin below): the loader shard
/// streams through the projection into a paged `HW` tier, the feature
/// server answers fetches from the budgeted cache, and the aggregation
/// walks `G_0`'s adjacency bands through a
/// [`PagedCsr`](crate::storage::PagedCsr) — bit-identical output at every
/// budget and page size.
#[allow(clippy::too_many_arguments)]
pub fn fused_first_layer(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    store: &FeatureStore,
    features: &Matrix,
    fs: &SimFs,
    part0: &LayerPart,
    weights: &ModelWeights,
    backend: &dyn Backend,
    phase: u32,
) -> Result<Matrix> {
    if let Some(scope) = crate::model::gcn::StorageScope::open() {
        return fused_first_layer_paged(
            ctx, plan, store, features, fs, part0, weights, backend, phase, &scope,
        );
    }
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let (rlo, rhi) = plan.node_range(p_idx);
    let (flo, fhi) = plan.feat_range(m_idx);
    let width = fhi - flo;
    let w0 = weights.layer_w(0);
    let b0 = &weights.layer_b(0)[flo..fhi];
    let act = if weights.config.layers == 1 { Act::None } else { Act::Relu };

    // 1. Read my loader shard (unsorted rows, full width).
    let mine = store.shard_nodes(ctx.rank);
    let row_bytes = (features.cols * 4) as u64;
    let done = fs.read(ctx.now(), row_bytes * mine.len() as u64);
    ctx.advance((done - ctx.now()).max(0.0));
    let shard = ctx.compute(|| {
        let idx: Vec<usize> = mine.iter().map(|&v| v as usize).collect();
        features.gather_rows(&idx)
    });
    ctx.mem.alloc(shard.nbytes());

    // 2. Local projection of my shard (full width) — fused GEMM.
    let hw = ctx.compute(|| backend.gemm(&shard, w0))?;
    ctx.mem.alloc(hw.nbytes());
    ctx.mem.free(shard.nbytes());
    drop(shard);
    let index: HashMap<u32, usize> = mine.iter().enumerate().map(|(i, &v)| (v, i)).collect();

    // 3. Figure out which projected rows I need: all distinct sources of
    //    my partition's `G_0` plus my own rows (self loops), bucketed by
    //    loader.
    let mut needed: Vec<u32> = part0.csr.distinct_columns();
    needed.extend((rlo..rhi).map(|v| v as u32));
    needed.sort_unstable();
    needed.dedup();
    let mut by_loader: Vec<Vec<u32>> = vec![Vec::new(); plan.world()];
    for &v in &needed {
        by_loader[store.loader_of[v as usize] as usize].push(v);
    }
    // counts to every peer (they expect world-1 counts)
    for rank in 0..plan.world() {
        if rank != ctx.rank {
            let n = u32::from(!by_loader[rank].is_empty());
            ctx.send_service(rank, Tag::of(phase, u32::MAX), Payload::U32(vec![n]));
        }
    }

    let expected_peers = plan.world() - 1;
    let hw_ref = &hw;
    let index_ref = &index;
    let out = ctx.with_server(
        move |sctx| {
            // mapped feature server: ids are global; first two entries of
            // the request carry the column window.
            let mut counts_pending = expected_peers;
            let mut to_serve: u64 = 0;
            let mut served: u64 = 0;
            while counts_pending > 0 || served < to_serve {
                let msg = sctx.recv_any(phase);
                let seq = (msg.tag & 0xFFFF_FFFF) as u32;
                if seq == u32::MAX {
                    to_serve += msg.payload.into_u32()[0] as u64;
                    counts_pending -= 1;
                    continue;
                }
                let req = msg.payload.into_u32();
                let (cl, ch) = (req[0] as usize, req[1] as usize);
                let gathered = sctx.compute(|| {
                    let mut out = Matrix::zeros(req.len() - 2, ch - cl);
                    for (i, &v) in req[2..].iter().enumerate() {
                        let pos = *index_ref.get(&v).expect("row not in shard");
                        out.row_mut(i).copy_from_slice(&hw_ref.row(pos)[cl..ch]);
                    }
                    out
                });
                // streamed response: the requester's staging copy starts
                // on the first band while the rest is still in flight
                sctx.send_chunked(msg.src, Tag::of(phase, seq | 0x8000_0000), gathered);
                served += 1;
            }
        },
        |ctx| -> Result<Matrix> {
            // Fetch projected rows (my column window) from loaders.
            let mut fetched: HashMap<u32, usize> = HashMap::new();
            let mut rows: Vec<Matrix> = Vec::new();
            let mut fetched_bytes = 0u64;
            let mut pending: Vec<(usize, u32, usize)> = Vec::new(); // (rank, seq, bucket)
            for (rank, ids) in by_loader.iter().enumerate() {
                if ids.is_empty() {
                    continue;
                }
                if rank == ctx.rank {
                    let mut block = Matrix::zeros(ids.len(), width);
                    for (i, &v) in ids.iter().enumerate() {
                        block.row_mut(i).copy_from_slice(&hw.row(index[&v])[flo..fhi]);
                    }
                    rows.push(block);
                    let bucket = rows.len() - 1;
                    for (i, &v) in ids.iter().enumerate() {
                        fetched.insert(v, bucket << 32 | i);
                    }
                    continue;
                }
                let mut req = Vec::with_capacity(ids.len() + 2);
                req.push(flo as u32);
                req.push(fhi as u32);
                req.extend_from_slice(ids);
                ctx.send_service(rank, Tag::of(phase, rank as u32), Payload::U32(req));
                pending.push((rank, rank as u32, 0));
            }
            for &(rank, seq, _) in &pending {
                let block = ctx.recv_matrix(rank, Tag::of(phase, seq | 0x8000_0000));
                ctx.mem.alloc(block.nbytes());
                fetched_bytes += block.nbytes();
                rows.push(block);
                let bucket = rows.len() - 1;
                for (i, &v) in by_loader[rank].iter().enumerate() {
                    fetched.insert(v, bucket << 32 | i);
                }
            }
            // 4. Aggregate into H^(1)[R_p, F_m] (output-oriented: lands in
            //    collaborative layout by construction).
            let mut out = Matrix::zeros(rhi - rlo, width);
            ctx.mem.alloc(out.nbytes());
            let row_of = |v: u32| -> &[f32] {
                let key = fetched[&v];
                rows[key >> 32].row(key & 0xFFFF_FFFF)
            };
            ctx.compute(|| {
                for r in 0..part0.csr.n_rows {
                    let (lo, hi) = (part0.csr.indptr[r] as usize, part0.csr.indptr[r + 1] as usize);
                    let orow = out.row_mut(r);
                    for e in lo..hi {
                        let srow = row_of(part0.csr.indices[e]);
                        let wv = part0.mean_w[e];
                        for (o, &x) in orow.iter_mut().zip(srow) {
                            *o += wv * x;
                        }
                    }
                    // self loop + bias + act
                    let srow = row_of((rlo + r) as u32);
                    let sw = part0.self_w[r];
                    for j in 0..orow.len() {
                        let v = orow[j] + sw * srow[j] + b0[j];
                        orow[j] = match act {
                            Act::None => v,
                            Act::Relu => v.max(0.0),
                        };
                    }
                }
            });
            // the fetched blocks die with this closure — balance the ledger
            ctx.mem.free(fetched_bytes);
            Ok(out)
        },
    )?;
    ctx.mem.free(hw.nbytes());
    Ok(out)
}

/// The out-of-core twin of [`fused_first_layer`] (DESIGN.md
/// §Out-of-core-storage). Three paged tiers replace the resident state:
///
/// 1. the loader shard streams band-wise through the projection into a
///    paged `HW` table (`feature_prep::project_shard_paged`) — the raw
///    shard is never fully resident;
/// 2. the mapped feature server gathers requested rows *from the
///    budgeted cache* and streams them into the existing chunked-send
///    path;
/// 3. the output-oriented aggregation walks `G_0`'s adjacency through a
///    [`crate::storage::PagedCsr`], band by band.
///
/// Fetched peer blocks stay resident exactly as in the in-memory path
/// (the whole-buffer aggregation is the PR 4 determinism boundary), so
/// every destination row accumulates the same values in the same order —
/// bit-identical at every budget, page size, chunk size, and thread
/// count.
///
/// KEEP IN SYNC with [`fused_first_layer`]: the request protocol
/// (count tags, seq layout), the `by_loader` bucketing, and the
/// aggregation arithmetic are deliberately line-for-line twins; any
/// change to one must land in both or the bit-identity sweep in
/// `tests/storage.rs` will catch the drift.
#[allow(clippy::too_many_arguments)]
fn fused_first_layer_paged(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    store: &FeatureStore,
    features: &Matrix,
    fs: &SimFs,
    part0: &LayerPart,
    weights: &ModelWeights,
    backend: &dyn Backend,
    phase: u32,
    scope: &crate::model::gcn::StorageScope,
) -> Result<Matrix> {
    use crate::storage::PagedCsr;

    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let (rlo, rhi) = plan.node_range(p_idx);
    let (flo, fhi) = plan.feat_range(m_idx);
    let width = fhi - flo;
    let w0 = weights.layer_w(0);
    let b0 = &weights.layer_b(0)[flo..fhi];
    let act = if weights.config.layers == 1 { Act::None } else { Act::Relu };
    let mine = store.shard_nodes(ctx.rank);

    // 1+2. Stream-read + project the loader shard into the paged tier.
    let hw = feature_prep::project_shard_paged(
        ctx,
        store,
        features,
        fs,
        w0,
        backend,
        &scope.cache,
        scope.page_rows,
        Arc::clone(&scope.fs),
        &format!("fused-hw-r{}", ctx.rank),
    )?;
    let index: HashMap<u32, usize> = mine.iter().enumerate().map(|(i, &v)| (v, i)).collect();

    // Page G_0's adjacency (ids + mean weights) so the aggregation walks
    // disk-backed bands instead of the resident CSR.
    let pcsr = scope.cache.with(|c| {
        PagedCsr::from_csr(
            c,
            &format!("fused-g0-r{}", ctx.rank),
            &part0.csr,
            &part0.mean_w,
            scope.page_rows,
            Arc::clone(&scope.fs),
        )
    })?;
    crate::storage::charge_main(ctx, &scope.cache);

    // 3. Needed projected rows, bucketed by loader — identical to the
    // in-memory path.
    let mut needed: Vec<u32> = part0.csr.distinct_columns();
    needed.extend((rlo..rhi).map(|v| v as u32));
    needed.sort_unstable();
    needed.dedup();
    let mut by_loader: Vec<Vec<u32>> = vec![Vec::new(); plan.world()];
    for &v in &needed {
        by_loader[store.loader_of[v as usize] as usize].push(v);
    }
    for rank in 0..plan.world() {
        if rank != ctx.rank {
            let n = u32::from(!by_loader[rank].is_empty());
            ctx.send_service(rank, Tag::of(phase, u32::MAX), Payload::U32(vec![n]));
        }
    }

    let expected_peers = plan.world() - 1;
    let hw_ref = &hw;
    let cache_ref = &scope.cache;
    let index_ref = &index;
    let pcsr_ref = &pcsr;
    let out = ctx.with_server(
        move |sctx| {
            // mapped feature server over the paged tier: gathers fault
            // pages through the budgeted cache and the response streams
            // into the chunked-send path.
            let mut counts_pending = expected_peers;
            let mut to_serve: u64 = 0;
            let mut served: u64 = 0;
            while counts_pending > 0 || served < to_serve {
                let msg = sctx.recv_any(phase);
                let seq = (msg.tag & 0xFFFF_FFFF) as u32;
                if seq == u32::MAX {
                    to_serve += msg.payload.into_u32()[0] as u64;
                    counts_pending -= 1;
                    continue;
                }
                let req = msg.payload.into_u32();
                let (cl, ch) = (req[0] as usize, req[1] as usize);
                let (gathered, io) = sctx.compute(|| {
                    let mut out = Matrix::zeros(req.len() - 2, ch - cl);
                    cache_ref.with(|c| {
                        let mut buf = vec![0.0f32; hw_ref.cols];
                        for (i, &v) in req[2..].iter().enumerate() {
                            let pos = *index_ref.get(&v).expect("row not in shard");
                            hw_ref.row_copy(c, pos, &mut buf).expect("paged row fetch failed");
                            out.row_mut(i).copy_from_slice(&buf[cl..ch]);
                        }
                        (out, c.take_io_secs())
                    })
                });
                sctx.advance(io);
                sctx.send_chunked(msg.src, Tag::of(phase, seq | 0x8000_0000), gathered);
                served += 1;
            }
        },
        |ctx| -> Result<Matrix> {
            // Fetch projected rows (my column window) from loaders; local
            // rows come through the cache.
            let mut fetched: HashMap<u32, usize> = HashMap::new();
            let mut rows: Vec<Matrix> = Vec::new();
            let mut fetched_bytes = 0u64;
            let mut pending: Vec<(usize, u32, usize)> = Vec::new();
            for (rank, ids) in by_loader.iter().enumerate() {
                if ids.is_empty() {
                    continue;
                }
                if rank == ctx.rank {
                    let mut block = Matrix::zeros(ids.len(), width);
                    let io = cache_ref.with(|c| -> Result<f64> {
                        let mut buf = vec![0.0f32; hw_ref.cols];
                        for (i, &v) in ids.iter().enumerate() {
                            hw_ref.row_copy(c, index_ref[&v], &mut buf)?;
                            block.row_mut(i).copy_from_slice(&buf[flo..fhi]);
                        }
                        Ok(c.take_io_secs())
                    })?;
                    ctx.advance(io);
                    ctx.mem.alloc(block.nbytes());
                    fetched_bytes += block.nbytes();
                    rows.push(block);
                    let bucket = rows.len() - 1;
                    for (i, &v) in ids.iter().enumerate() {
                        fetched.insert(v, bucket << 32 | i);
                    }
                    continue;
                }
                let mut req = Vec::with_capacity(ids.len() + 2);
                req.push(flo as u32);
                req.push(fhi as u32);
                req.extend_from_slice(ids);
                ctx.send_service(rank, Tag::of(phase, rank as u32), Payload::U32(req));
                pending.push((rank, rank as u32, 0));
            }
            for &(rank, seq, _) in &pending {
                let block = ctx.recv_matrix(rank, Tag::of(phase, seq | 0x8000_0000));
                ctx.mem.alloc(block.nbytes());
                fetched_bytes += block.nbytes();
                rows.push(block);
                let bucket = rows.len() - 1;
                for (i, &v) in by_loader[rank].iter().enumerate() {
                    fetched.insert(v, bucket << 32 | i);
                }
            }
            // 4. Output-oriented aggregation over paged adjacency bands:
            // every destination row consumes its edges in CSR order, so
            // the result matches the resident-CSR loop bit for bit.
            let mut out = Matrix::zeros(rhi - rlo, width);
            ctx.mem.alloc(out.nbytes());
            let row_of = |v: u32| -> &[f32] {
                let key = fetched[&v];
                rows[key >> 32].row(key & 0xFFFF_FFFF)
            };
            let mut io_total = 0.0f64;
            ctx.compute(|| {
                let mut srcs: Vec<u32> = Vec::new();
                let mut ws: Vec<f32> = Vec::new();
                for r in 0..pcsr_ref.n_rows {
                    cache_ref.with(|c| {
                        pcsr_ref
                            .row_edges(c, r, &mut srcs, &mut ws)
                            .expect("paged adjacency fetch failed");
                        io_total += c.take_io_secs();
                    });
                    let orow = out.row_mut(r);
                    for (k, &src) in srcs.iter().enumerate() {
                        let srow = row_of(src);
                        let wv = ws[k];
                        for (o, &x) in orow.iter_mut().zip(srow) {
                            *o += wv * x;
                        }
                    }
                    // self loop + bias + act
                    let srow = row_of((rlo + r) as u32);
                    let sw = part0.self_w[r];
                    for j in 0..orow.len() {
                        let v = orow[j] + sw * srow[j] + b0[j];
                        orow[j] = match act {
                            Act::None => v,
                            Act::Relu => v.max(0.0),
                        };
                    }
                }
            });
            ctx.advance(io_total);
            // the fetched blocks die with this closure — balance the ledger
            ctx.mem.free(fetched_bytes);
            Ok(out)
        },
    )?;
    scope.cache.with(|c| {
        c.remove_file(hw.file);
        c.remove_file(pcsr.edges.file);
    });
    crate::storage::charge_main(ctx, &scope.cache);
    scope.finish(ctx);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(prep: &str, kind: &str) -> DealConfig {
        let mut cfg = DealConfig::default();
        cfg.dataset.name = "products-sim".into();
        cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
        cfg.cluster.machines = 4;
        cfg.cluster.feature_parts = 2;
        cfg.model.kind = kind.into();
        cfg.model.layers = 2;
        cfg.model.fanout = 5;
        cfg.exec.feature_prep = prep.into();
        cfg
    }

    #[test]
    fn pipeline_end_to_end_gcn_all_preps_agree() {
        let mut outputs = Vec::new();
        for prep in ["scan", "redistribute", "fused"] {
            let report = Pipeline::new(small_cfg(prep, "gcn")).run().unwrap();
            assert!(report.stages.total() > 0.0);
            assert_eq!(report.stages.0.len(), 4);
            outputs.push(report.embeddings.unwrap());
        }
        // all three preparation strategies compute the same embeddings
        let base = &outputs[0];
        for other in &outputs[1..] {
            let diff = base.max_abs_diff(other);
            assert!(diff < 1e-3, "feature preps disagree: {}", diff);
        }
    }

    #[test]
    fn pipeline_gat_runs() {
        let report = Pipeline::new(small_cfg("redistribute", "gat")).run().unwrap();
        let e = report.embeddings.unwrap();
        assert_eq!(e.rows, 256);
        assert!(e.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn preprocessing_fraction_positive() {
        let report = Pipeline::new(small_cfg("scan", "gcn")).run().unwrap();
        let frac = report.stages.preprocessing_fraction();
        assert!(frac > 0.0 && frac < 1.0, "frac={}", frac);
    }

    #[test]
    fn run_report_yields_serving_table() {
        let report = Pipeline::new(small_cfg("scan", "gcn")).run().unwrap();
        let table = report.serving_table().expect("embeddings kept");
        assert_eq!(table.n_nodes(), 256);
        assert_eq!(table.num_shards(), report.plan.p);
        assert_eq!(table.to_full(), *report.embeddings.as_ref().unwrap());
    }
}
