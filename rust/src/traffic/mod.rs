//! Production-traffic harness for the serving tier (DESIGN.md §Traffic).
//!
//! The serving benchmarks up to PR 5 measured *throughput*: feed the pool
//! as fast as it drains. Production load is nothing like that — arrivals
//! are skewed, diurnal, bursty, and **do not slow down when the server
//! does**. This module makes that workload a first-class, reproducible
//! artifact:
//!
//! - [`trace`] — seeded trace generation (Zipfian key skew, diurnal +
//!   bursty nonhomogeneous Poisson arrivals, interleaved churn batches)
//!   and the versioned on-disk trace format;
//! - [`replay`] — the open-loop replay driver (inject on the trace
//!   schedule, never wait for completions) and the sequenced
//!   deterministic mode that the batch-policy parity sweep uses.
//!
//! Trace format v2 adds membership events (`join:4,kill:2` schedules in
//! [`TraceConfig::membership_schedule`]), so a replay can shrink, grow,
//! or kill-and-recover the serving cluster *mid-load* via
//! [`replay_elastic`] and an `ElasticCluster` hook — the SLO gates then
//! cover reconfiguration windows, not just steady state.
//!
//! `deal traffic` (cli) drives both; `benches/traffic_slo.rs` turns the
//! replay's per-class p50/p99/p999 into SLO gates and emits
//! `BENCH_traffic.json` (EXPERIMENTS.md §Traffic).

pub mod replay;
pub mod trace;

pub use replay::{
    churn_into_cell, churn_into_cell_durable, replay, replay_elastic, ReplayMode, ReplayOpts,
    ReplayReport,
};
pub use trace::{temporal_probe, ChurnEvent, Trace, TraceConfig, TraceEvent, ZipfSampler};
