//! Trace replay against a live [`ServePool`] (DESIGN.md §Traffic).
//!
//! Two modes, two questions:
//!
//! - [`ReplayMode::OpenLoop`] — *"what does production latency look
//!   like?"* Requests are injected on the trace's simulated-arrival
//!   schedule, **never waiting for completions**: if the pool falls
//!   behind, the queue fills and admission control sheds load, exactly
//!   as a real front end would. A closed-loop driver (wait for each
//!   response before sending the next) self-throttles under overload
//!   and hides tail collapse; open loop is what makes the p99/p999 SLO
//!   gates in `benches/traffic_slo.rs` meaningful.
//! - [`ReplayMode::Sequenced`] — *"are the answers right?"* Timing is
//!   ignored; events run in trace order with a full drain barrier
//!   around every churn event, so each request's response is a pure
//!   function of (trace, initial state). Replaying one trace under two
//!   batch-formation policies must then produce identical
//!   [`response_digest`]s per request — the parity sweep's contract.
//!
//! Latency is accounted **pool-side** (worker timestamps, per class);
//! the replay collector thread only drains tickets and folds digests,
//! so a slow collector can never inflate a class's tail.

use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::delta::DeltaState;
use crate::serve::{
    refresh_delta, refresh_delta_durable, response_digest, PoolStats, Response, ServePool,
    TableCell, Ticket,
};
use crate::storage::DurableStore;
use crate::util::rng::Rng;
use crate::Result;

use super::trace::{ChurnEvent, Trace, TraceEvent};
use crate::cluster::membership::MembershipEvent;

/// How the driver maps trace time onto wall-clock time.
#[derive(Clone, Copy, Debug)]
pub enum ReplayMode {
    /// Open-loop: dispatch each event at `start + at_secs / speed`
    /// wall-clock, regardless of completions. `speed` > 1 compresses the
    /// trace (a 10 s trace at speed 10 replays in ~1 s).
    OpenLoop { speed: f64 },
    /// In-order, untimed, with drain barriers around churn — the
    /// deterministic mode parity sweeps use.
    Sequenced,
}

/// Replay options.
#[derive(Clone, Copy, Debug)]
pub struct ReplayOpts {
    pub mode: ReplayMode,
    /// Keep every accepted response in the report (tear-free epoch
    /// checks); costs memory proportional to the trace.
    pub keep_responses: bool,
}

impl Default for ReplayOpts {
    fn default() -> Self {
        ReplayOpts { mode: ReplayMode::OpenLoop { speed: 1.0 }, keep_responses: false }
    }
}

/// Outcome of one replay run.
#[derive(Debug)]
pub struct ReplayReport {
    /// Wall-clock seconds from first dispatch to last response.
    pub wall_secs: f64,
    /// Request events dispatched (accepted + rejected).
    pub dispatched: u64,
    /// Pool statistics for exactly this replay's window (per-class
    /// counters and latency summaries included).
    pub stats: PoolStats,
    /// Per request (trace order): FNV-1a digest of the response, or 0 if
    /// the request was rejected/failed. Two runs of the same trace over
    /// the same initial state in `Sequenced` mode must produce equal
    /// vectors, whatever the batch policy.
    pub digests: Vec<u64>,
    /// Accepted responses in trace order (`None` = rejected/failed);
    /// empty unless `keep_responses`.
    pub responses: Vec<Option<Response>>,
    /// Epoch published by each churn event, in trace order.
    pub churn_epochs: Vec<u64>,
    /// Membership epoch committed by each membership event, in trace
    /// order. Empty unless the trace carries membership events *and* the
    /// replay was driven through [`replay_elastic`] (plain [`replay`]
    /// skips them: a static-table replay).
    pub membership_epochs: Vec<u64>,
    /// Worst dispatcher lateness vs. the trace schedule (open loop only;
    /// large values mean the driver itself — not the pool — was the
    /// bottleneck and the measured tail is suspect).
    pub max_dispatch_lag_secs: f64,
    /// Served responses per wall-clock second.
    pub goodput: f64,
}

/// Replay `trace` against `pool`, calling `on_churn` for every churn
/// event (in the dispatcher thread; return the published epoch). Use
/// [`churn_into_cell`] for the standard `DeltaState` hook, or pass
/// `|_| Ok(0)` for a static-table replay. Membership events in the
/// trace (format v2) are skipped — the world stays fixed; use
/// [`replay_elastic`] to drive reconfiguration mid-load.
pub fn replay(
    pool: &ServePool,
    trace: &Trace,
    opts: &ReplayOpts,
    mut on_churn: impl FnMut(&ChurnEvent) -> Result<u64>,
) -> Result<ReplayReport> {
    replay_inner(pool, trace, opts, &mut on_churn, None)
}

/// [`replay`] plus a membership hook: every [`TraceEvent::Membership`]
/// calls `on_membership` from the dispatcher thread (return the
/// committed membership epoch — typically `ElasticCluster::apply`
/// followed by `epoch()`). In open loop the hook runs on schedule while
/// requests are in flight, so SLO gates cover the reconfiguration
/// window; in [`ReplayMode::Sequenced`] a drain barrier wraps the hook
/// exactly like churn, keeping responses a pure function of the trace.
pub fn replay_elastic(
    pool: &ServePool,
    trace: &Trace,
    opts: &ReplayOpts,
    mut on_churn: impl FnMut(&ChurnEvent) -> Result<u64>,
    mut on_membership: impl FnMut(&MembershipEvent) -> Result<u64>,
) -> Result<ReplayReport> {
    replay_inner(pool, trace, opts, &mut on_churn, Some(&mut on_membership))
}

#[allow(clippy::type_complexity)]
fn replay_inner(
    pool: &ServePool,
    trace: &Trace,
    opts: &ReplayOpts,
    on_churn: &mut dyn FnMut(&ChurnEvent) -> Result<u64>,
    mut on_membership: Option<&mut dyn FnMut(&MembershipEvent) -> Result<u64>>,
) -> Result<ReplayReport> {
    let n_requests = trace.n_requests();
    let mark = pool.mark();
    let keep = opts.keep_responses;

    // Collector: drains tickets in dispatch order, folding digests (and
    // optionally responses). Tickets buffer replies, so FIFO waiting here
    // never blocks the pool — and latency is measured pool-side anyway.
    let (tx, rx) = mpsc::channel::<(usize, Option<Ticket>)>();
    let collector = std::thread::Builder::new()
        .name("traffic-collector".into())
        .spawn(move || {
            let mut digests = vec![0u64; n_requests];
            let mut responses: Vec<Option<Response>> =
                if keep { (0..n_requests).map(|_| None).collect() } else { Vec::new() };
            for (idx, ticket) in rx {
                if let Some(t) = ticket {
                    if let Ok(resp) = t.wait() {
                        digests[idx] = response_digest(&resp);
                        if keep {
                            responses[idx] = Some(resp);
                        }
                    }
                }
            }
            (digests, responses)
        })
        .expect("spawn traffic collector");

    let mut churn_epochs = Vec::new();
    let mut membership_epochs = Vec::new();
    let mut dispatched = 0u64;
    let mut max_lag = 0.0f64;
    let t0 = Instant::now();
    let result = (|| -> Result<()> {
        match opts.mode {
            ReplayMode::OpenLoop { speed } => {
                anyhow::ensure!(speed > 0.0, "replay speed must be positive");
                let mut idx = 0usize;
                for ev in &trace.events {
                    let target = Duration::from_secs_f64(ev.at_secs() / speed);
                    let now = t0.elapsed();
                    if now < target {
                        std::thread::sleep(target - now);
                    } else {
                        max_lag = max_lag.max((now - target).as_secs_f64());
                    }
                    match ev {
                        TraceEvent::Request { req, .. } => {
                            // open loop: an admission reject is data, not
                            // an error — record and move on
                            let ticket = pool.submit(req.clone()).ok();
                            tx.send((idx, ticket)).expect("collector alive");
                            idx += 1;
                            dispatched += 1;
                        }
                        TraceEvent::Churn(c) => {
                            // no drain: churn lands mid-flight, exactly
                            // like a production delta refresh
                            churn_epochs.push(on_churn(c)?);
                        }
                        TraceEvent::Membership { event, .. } => {
                            // no drain either: reconfiguration happens
                            // under load, tails and all
                            if let Some(ref mut hook) = on_membership {
                                membership_epochs.push(hook(event)?);
                            }
                        }
                    }
                }
            }
            ReplayMode::Sequenced => {
                let mut idx = 0usize;
                let mut pending: Vec<(usize, Option<Ticket>)> = Vec::new();
                for ev in &trace.events {
                    match ev {
                        TraceEvent::Request { req, .. } => {
                            pending.push((idx, pool.submit(req.clone()).ok()));
                            idx += 1;
                            dispatched += 1;
                        }
                        TraceEvent::Churn(c) => {
                            // drain barrier: every in-flight request
                            // resolves against the pre-churn epoch, so
                            // responses are reproducible run to run
                            for (i, t) in pending.drain(..) {
                                tx.send((i, t)).expect("collector alive");
                            }
                            pool.quiesce();
                            churn_epochs.push(on_churn(c)?);
                        }
                        TraceEvent::Membership { event, .. } => {
                            // same barrier as churn: each request reads a
                            // table from exactly one membership epoch
                            if let Some(ref mut hook) = on_membership {
                                for (i, t) in pending.drain(..) {
                                    tx.send((i, t)).expect("collector alive");
                                }
                                pool.quiesce();
                                membership_epochs.push(hook(event)?);
                            }
                        }
                    }
                }
                for (i, t) in pending.drain(..) {
                    tx.send((i, t)).expect("collector alive");
                }
            }
        }
        Ok(())
    })();
    drop(tx); // close the channel so the collector finishes
    let (digests, responses) = collector.join().expect("collector panicked");
    result?;
    // wait for the pool to finish everything we injected, so the stats
    // window is drained (submitted == accounted per class)
    pool.quiesce();
    let wall_secs = t0.elapsed().as_secs_f64();
    let stats = pool.stats_since(&mark);
    let goodput = stats.served as f64 / wall_secs.max(1e-12);
    Ok(ReplayReport {
        wall_secs,
        dispatched,
        stats,
        digests,
        responses,
        churn_epochs,
        membership_epochs,
        max_dispatch_lag_secs: max_lag,
        goodput,
    })
}

/// The standard churn hook: synthesize the event's update batch from its
/// seed and sizes via [`DeltaState::synth_batch`], apply it, and publish
/// a delta epoch into `cell` ([`refresh_delta`]). Returns the published
/// epoch.
pub fn churn_into_cell<'a>(
    state: &'a mut DeltaState,
    cell: &'a TableCell,
) -> impl FnMut(&ChurnEvent) -> Result<u64> + 'a {
    move |ev: &ChurnEvent| {
        let mut rng = Rng::new(ev.seed);
        let batch = state.synth_batch(
            &mut rng,
            ev.edge_adds as usize,
            ev.edge_removes as usize,
            ev.feat_updates as usize,
        );
        let rep = refresh_delta(state, &batch, cell)?;
        Ok(rep.epoch)
    }
}

/// [`churn_into_cell`] with journal-before-publish: every churn epoch is
/// fsync'd into `store` before it becomes visible ([`refresh_delta_durable`]),
/// so killing the replay at any point recovers the last published table
/// bit-identically. The parity test in `tests/recovery.rs` runs the same
/// trace through both hooks and asserts identical response digests.
pub fn churn_into_cell_durable<'a>(
    state: &'a mut DeltaState,
    cell: &'a TableCell,
    store: &'a Mutex<DurableStore>,
) -> impl FnMut(&ChurnEvent) -> Result<u64> + 'a {
    move |ev: &ChurnEvent| {
        let mut rng = Rng::new(ev.seed);
        let batch = state.synth_batch(
            &mut rng,
            ev.edge_adds as usize,
            ev.edge_removes as usize,
            ev.feat_updates as usize,
        );
        let rep = refresh_delta_durable(state, &batch, cell, store)?;
        Ok(rep.epoch)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::runtime::Native;
    use crate::serve::shard::ShardedTable;
    use crate::serve::{BatchPolicy, PoolOpts};
    use crate::tensor::Matrix;
    use crate::traffic::trace::TraceConfig;

    fn table_cell(n: usize, d: usize) -> Arc<TableCell> {
        let mut rng = Rng::new(123);
        let full = Matrix::random(n, d, 1.0, &mut rng);
        Arc::new(TableCell::new(ShardedTable::from_full(&full, 2, 0)))
    }

    fn tiny_trace() -> Trace {
        Trace::generate(&TraceConfig {
            seed: 5,
            n_nodes: 48,
            requests: 120,
            base_rate: 50_000.0, // compress simulated time for the test
            churn_batches: 0,
            ..TraceConfig::default()
        })
    }

    #[test]
    fn open_loop_replay_accounts_every_request() {
        let cell = table_cell(48, 8);
        let pool = ServePool::spawn(cell, Arc::new(Native), PoolOpts::default());
        let trace = tiny_trace();
        let opts =
            ReplayOpts { mode: ReplayMode::OpenLoop { speed: 100.0 }, ..ReplayOpts::default() };
        let rep = replay(&pool, &trace, &opts, |_| Ok(0)).unwrap();
        assert_eq!(rep.dispatched, 120);
        assert_eq!(rep.digests.len(), 120);
        let mut total = 0u64;
        for c in &rep.stats.per_class {
            total += c.counters.submitted;
            assert_eq!(
                c.counters.accounted(),
                c.counters.submitted,
                "{} class leaks requests: {:?}",
                c.class.name(),
                c.counters
            );
        }
        assert_eq!(total, 120);
        // everything fit in the (big) queue: no rejects, digests nonzero
        assert_eq!(rep.stats.rejected, 0);
        assert!(rep.digests.iter().all(|&d| d != 0));
        assert!(rep.goodput > 0.0);
    }

    #[test]
    fn sequenced_replay_is_policy_invariant() {
        let trace = tiny_trace();
        let policies = [
            BatchPolicy::DepthFirst,
            BatchPolicy::Deadline { max_wait_us: 100 },
            BatchPolicy::SizeCapped { max_ids: 16 },
        ];
        let mut all: Vec<Vec<u64>> = Vec::new();
        for policy in policies {
            let cell = table_cell(48, 8);
            let pool = ServePool::spawn(
                cell,
                Arc::new(Native),
                PoolOpts { workers: 2, policy, ..PoolOpts::default() },
            );
            let opts = ReplayOpts { mode: ReplayMode::Sequenced, ..ReplayOpts::default() };
            let rep = replay(&pool, &trace, &opts, |_| Ok(0)).unwrap();
            assert!(rep.digests.iter().all(|&d| d != 0));
            all.push(rep.digests);
        }
        assert_eq!(all[0], all[1], "deadline policy changed responses");
        assert_eq!(all[0], all[2], "size-capped policy changed responses");
    }

    #[test]
    fn elastic_replay_reconfigures_without_changing_answers() {
        use crate::cluster::membership::{ElasticCluster, ElasticOpts};

        let trace = Trace::generate(&TraceConfig {
            seed: 5,
            n_nodes: 48,
            requests: 120,
            base_rate: 50_000.0,
            churn_batches: 0,
            membership_schedule: "leave:3,join:3".into(),
            ..TraceConfig::default()
        });
        assert_eq!(trace.n_membership(), 2);
        let opts = ReplayOpts { mode: ReplayMode::Sequenced, ..ReplayOpts::default() };

        // fixed-world reference: same trace, membership events skipped
        let cell = table_cell(48, 8);
        let pool = ServePool::spawn(cell, Arc::new(Native), PoolOpts::default());
        let reference = replay(&pool, &trace, &opts, |_| Ok(0)).unwrap();
        assert!(reference.membership_epochs.is_empty(), "plain replay skips membership");

        // elastic run: the same trace shrinks then regrows the world
        let mut rng = Rng::new(123);
        let full = Matrix::random(48, 8, 1.0, &mut rng);
        let mut cluster = ElasticCluster::new(&full, 4, ElasticOpts::default()).unwrap();
        let pool = ServePool::spawn(cluster.cell(), Arc::new(Native), PoolOpts::default());
        let rep = replay_elastic(&pool, &trace, &opts, |_| Ok(0), |ev| {
            cluster.apply(*ev)?;
            Ok(cluster.epoch())
        })
        .unwrap();
        assert_eq!(rep.membership_epochs, vec![1, 2]);
        assert!(rep.digests.iter().all(|&d| d != 0));
        // the serving values never depended on the membership schedule —
        // but the reference pool was seeded from ShardedTable::from_full
        // over the same matrix, so digests must agree request for request
        assert_eq!(rep.digests, reference.digests);
    }

    #[test]
    fn keep_responses_returns_them_in_trace_order() {
        let cell = table_cell(48, 8);
        let pool = ServePool::spawn(cell, Arc::new(Native), PoolOpts::default());
        let trace = tiny_trace();
        let opts = ReplayOpts { mode: ReplayMode::Sequenced, keep_responses: true };
        let rep = replay(&pool, &trace, &opts, |_| Ok(0)).unwrap();
        assert_eq!(rep.responses.len(), 120);
        for (i, (resp, &digest)) in rep.responses.iter().zip(&rep.digests).enumerate() {
            let resp = resp.as_ref().unwrap_or_else(|| panic!("request {} dropped", i));
            assert_eq!(response_digest(resp), digest);
        }
    }
}
