//! Deterministic production-traffic traces (DESIGN.md §Traffic).
//!
//! A [`Trace`] is a reproducible artifact: the full request schedule a
//! replay run injects against the serving tier, generated from a seeded
//! [`TraceConfig`] and serialized to a **versioned** on-disk format. The
//! same seed + config always produces byte-identical bytes
//! (`tests/traffic_props.rs`), so a latency regression seen in CI can be
//! replayed locally from the identical workload.
//!
//! The generator models the three production phenomena the paper's
//! serving story cares about:
//! - **key skew** — node-id popularity is Zipfian (rank `r` drawn with
//!   probability ∝ `1/(r+1)^s`), with ranks mapped to node ids through a
//!   seeded permutation so hot keys scatter across table shards;
//! - **rate shape** — arrivals follow a nonhomogeneous Poisson process by
//!   thinning: a diurnal sinusoid modulates the base rate and Poisson
//!   burst windows multiply it (`λ(t) = base · (1 + a·sin(2πt/T)) ·
//!   burst?·F`), so a replay exercises both troughs and overload;
//! - **churn** — [`ChurnEvent`]s interleave with requests; each carries a
//!   seed plus update-batch sizes, and the replay driver synthesizes the
//!   graph update from exactly those, keeping the trace self-contained.
//!
//! Arrival timestamps are *simulated seconds*; the open-loop replay
//! driver ([`super::replay`]) maps them onto wall-clock time.

use std::path::Path;

use crate::cluster::membership::{self, MembershipEvent};
use crate::serve::Request;
use crate::util::rng::Rng;
use crate::Result;

/// Magic prefix of the on-disk trace format.
pub const TRACE_MAGIC: &[u8; 8] = b"DEALTRAC";
/// Current trace format version. Bump on any layout change; `from_bytes`
/// rejects versions it does not know. v2 added membership events
/// (`TraceEvent::Membership`, tag 3) and the `membership_schedule` config
/// field; v1 traces still load (empty schedule, no tag-3 events).
pub const TRACE_VERSION: u32 = 2;
/// Oldest version `from_bytes` still reads.
pub const TRACE_MIN_VERSION: u32 = 1;

/// Everything that determines a trace, bit for bit.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    /// Master seed; every derived stream (arrivals, ids, churn) forks it.
    pub seed: u64,
    /// Node-id universe the requests draw from (the serving table size).
    pub n_nodes: usize,
    /// Number of requests to generate.
    pub requests: usize,
    /// Base arrival rate in requests per simulated second.
    pub base_rate: f64,
    /// Zipf exponent `s` of the key-popularity distribution (0 = uniform).
    pub zipf_s: f64,
    /// Diurnal modulation amplitude `a` in `[0, 1)`.
    pub diurnal_amplitude: f64,
    /// Diurnal period `T` in simulated seconds.
    pub diurnal_period_secs: f64,
    /// Rate multiplier inside a burst window (1 = bursts disabled).
    pub burst_factor: f64,
    /// Burst onset rate in bursts per simulated second (Poisson).
    pub burst_rate_hz: f64,
    /// Burst window length in simulated seconds.
    pub burst_secs: f64,
    /// Fraction of requests that are `Similar` (the GEMM-bound class);
    /// the rest are `Embed` (the gather-bound class).
    pub similar_fraction: f64,
    /// Ids per `Embed` request.
    pub embed_ids: usize,
    /// Ids per `Similar` request.
    pub similar_ids: usize,
    /// `k` of each `Similar` request.
    pub similar_k: usize,
    /// Churn batches interleaved across the trace (0 = static graph).
    pub churn_batches: usize,
    /// Edge insertions per churn batch.
    pub churn_edge_adds: usize,
    /// Edge deletions per churn batch.
    pub churn_edge_removes: usize,
    /// Feature updates per churn batch.
    pub churn_feat_updates: usize,
    /// Membership events to interleave across the trace, in
    /// `cluster::membership::parse_schedule` format (`"join:4,kill:2"`);
    /// empty = fixed world. Events are spread evenly over the request
    /// stream like churn batches, so open-loop replay drives
    /// join/leave/kill mid-load and the SLO gates cover reconfiguration
    /// windows. (Trace format v2.)
    pub membership_schedule: String,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 0xDEA1,
            n_nodes: 1024,
            requests: 2048,
            base_rate: 2000.0,
            zipf_s: 1.0,
            diurnal_amplitude: 0.5,
            diurnal_period_secs: 1.0,
            burst_factor: 4.0,
            burst_rate_hz: 1.0,
            burst_secs: 0.05,
            similar_fraction: 0.25,
            embed_ids: 8,
            similar_ids: 2,
            similar_k: 8,
            churn_batches: 0,
            churn_edge_adds: 24,
            churn_edge_removes: 24,
            churn_feat_updates: 2,
            membership_schedule: String::new(),
        }
    }
}

/// One interleaved graph-update point. The event carries *how to
/// synthesize* the update (sizes + a seed), not the update itself, so the
/// trace stays small and self-contained; replay feeds these to
/// `DeltaState::synth_batch` and `refresh_delta`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnEvent {
    /// Simulated arrival time.
    pub at_secs: f64,
    pub edge_adds: u32,
    pub edge_removes: u32,
    pub feat_updates: u32,
    /// Seed for synthesizing this batch's update.
    pub seed: u64,
}

/// One trace event, in nondecreasing `at_secs` order.
#[derive(Clone, Debug)]
pub enum TraceEvent {
    /// Inject `req` at simulated time `at_secs`.
    Request { at_secs: f64, req: Request },
    /// Apply a graph-update batch.
    Churn(ChurnEvent),
    /// Reconfigure the cluster mid-load (trace format v2): the replay
    /// driver hands `event` to its membership hook (an `ElasticCluster`
    /// in production-shaped runs).
    Membership { at_secs: f64, event: MembershipEvent },
}

impl TraceEvent {
    pub fn at_secs(&self) -> f64 {
        match self {
            TraceEvent::Request { at_secs, .. } => *at_secs,
            TraceEvent::Churn(c) => c.at_secs,
            TraceEvent::Membership { at_secs, .. } => *at_secs,
        }
    }
}

/// Wire code of a membership action (trace event tag 3).
fn action_code(ev: &MembershipEvent) -> u8 {
    match ev {
        MembershipEvent::Join { .. } => 0,
        MembershipEvent::Leave { .. } => 1,
        MembershipEvent::Kill { .. } => 2,
    }
}

fn action_from(code: u8, rank: usize) -> Result<MembershipEvent> {
    Ok(match code {
        0 => MembershipEvent::Join { rank },
        1 => MembershipEvent::Leave { rank },
        2 => MembershipEvent::Kill { rank },
        other => anyhow::bail!("unknown membership action code {}", other),
    })
}

/// A generated (or loaded) trace: the config that made it plus the event
/// schedule.
#[derive(Clone, Debug)]
pub struct Trace {
    pub config: TraceConfig,
    pub events: Vec<TraceEvent>,
}

/// Zipfian rank sampler over `[0, n)` by inverse-CDF binary search, with
/// a seeded permutation mapping popularity rank → node id (so the hot
/// keys are not simply ids 0, 1, 2, … — they scatter across shards the
/// way real hot entities do).
pub struct ZipfSampler {
    /// cdf[r] = P(rank <= r); cdf[n-1] == 1.
    cdf: Vec<f64>,
    /// rank → node id.
    perm: Vec<u32>,
}

impl ZipfSampler {
    pub fn new(n: usize, s: f64, rng: &mut Rng) -> ZipfSampler {
        assert!(n >= 1, "zipf needs a nonempty universe");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for r in 0..n {
            acc += 1.0 / ((r + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        let mut perm: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut perm);
        ZipfSampler { cdf, perm }
    }

    /// Draw one node id.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let u = rng.next_f64();
        // first rank whose cdf exceeds u
        let rank = self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1);
        self.perm[rank]
    }

    /// The node id holding popularity rank `r` (tests compare observed
    /// frequencies against the theoretical ranks).
    pub fn id_of_rank(&self, r: usize) -> u32 {
        self.perm[r]
    }

    /// Theoretical probability of rank `r`.
    pub fn rank_probability(&self, r: usize) -> f64 {
        let prev = if r == 0 { 0.0 } else { self.cdf[r - 1] };
        self.cdf[r] - prev
    }
}

/// A deterministic Zipf-skewed probe set for time-travel serving
/// (`deal temporal --at`, `tests/temporal.rs`): `count` alternating
/// `Embed`/`Similar` requests over an `n`-node universe. The same
/// `(seed, n, count)` always yields the same requests, so response
/// digests are comparable across epochs, retention evictions, and
/// resumed engines.
pub fn temporal_probe(seed: u64, n: usize, count: usize) -> Vec<Request> {
    let mut rng = Rng::new(seed ^ 0x7E4F_0B3Du64);
    let zipf = ZipfSampler::new(n, 1.1, &mut rng);
    (0..count)
        .map(|i| {
            let ids: Vec<u32> = (0..4).map(|_| zipf.sample(&mut rng)).collect();
            if i % 2 == 0 {
                Request::Embed(ids)
            } else {
                Request::Similar { ids, k: 8 }
            }
        })
        .collect()
}

/// Exponential(rate) draw; `rate` must be positive.
fn exponential(rng: &mut Rng, rate: f64) -> f64 {
    -(1.0 - rng.next_f64()).max(f64::MIN_POSITIVE).ln() / rate
}

impl Trace {
    /// Generate the trace for `config`. Deterministic: the same config
    /// (seed included) always yields byte-identical `to_bytes` output.
    pub fn generate(config: &TraceConfig) -> Trace {
        assert!(config.n_nodes >= 1, "trace needs nodes");
        assert!(config.base_rate > 0.0, "base_rate must be positive");
        assert!(
            (0.0..1.0).contains(&config.diurnal_amplitude),
            "diurnal amplitude must be in [0, 1)"
        );
        assert!(config.burst_factor >= 1.0, "burst factor must be >= 1");
        let base = Rng::new(config.seed);
        let mut perm_rng = base.fork(1);
        let mut arrival_rng = base.fork(2);
        let mut id_rng = base.fork(3);
        let mut churn_rng = base.fork(4);
        let zipf = ZipfSampler::new(config.n_nodes, config.zipf_s, &mut perm_rng);

        // Nonhomogeneous Poisson arrivals by thinning at λ_max.
        let bursts_on = config.burst_factor > 1.0 && config.burst_rate_hz > 0.0;
        let lambda_max = config.base_rate
            * (1.0 + config.diurnal_amplitude)
            * if bursts_on { config.burst_factor } else { 1.0 };
        let mut t = 0.0f64;
        // Burst windows are a renewal process: each onset is the previous
        // window's end plus an Exponential(burst_rate_hz) gap.
        let mut burst_onset = if bursts_on {
            exponential(&mut arrival_rng, config.burst_rate_hz)
        } else {
            f64::INFINITY
        };
        let mut requests: Vec<(f64, Request)> = Vec::with_capacity(config.requests);
        while requests.len() < config.requests {
            t += exponential(&mut arrival_rng, lambda_max);
            while bursts_on && t >= burst_onset + config.burst_secs {
                burst_onset +=
                    config.burst_secs + exponential(&mut arrival_rng, config.burst_rate_hz);
            }
            let in_burst = bursts_on && t >= burst_onset;
            let diurnal = 1.0
                + config.diurnal_amplitude
                    * (2.0 * std::f64::consts::PI * t / config.diurnal_period_secs.max(1e-9))
                        .sin();
            let lambda = config.base_rate
                * diurnal
                * if in_burst { config.burst_factor } else { 1.0 };
            if arrival_rng.next_f64() >= lambda / lambda_max {
                continue; // thinned: candidate rejected
            }
            let req = if id_rng.next_f64() < config.similar_fraction {
                Request::Similar {
                    ids: (0..config.similar_ids.max(1))
                        .map(|_| zipf.sample(&mut id_rng))
                        .collect(),
                    k: config.similar_k.max(1),
                }
            } else {
                Request::Embed(
                    (0..config.embed_ids.max(1)).map(|_| zipf.sample(&mut id_rng)).collect(),
                )
            };
            requests.push((t, req));
        }

        // Interleave churn: batch b lands just before request b·stride, at
        // that request's timestamp (replay applies churn first at a tie).
        // Membership events get the same even spacing with their own
        // stride, so a trace can drive join/leave/kill mid-load.
        let schedule = membership::parse_schedule(&config.membership_schedule)
            .expect("invalid membership_schedule in trace config");
        let mut events =
            Vec::with_capacity(requests.len() + config.churn_batches + schedule.len());
        let stride = if config.churn_batches > 0 {
            (config.requests / (config.churn_batches + 1)).max(1)
        } else {
            usize::MAX
        };
        let m_stride = if !schedule.is_empty() {
            (config.requests / (schedule.len() + 1)).max(1)
        } else {
            usize::MAX
        };
        let mut emitted_churn = 0usize;
        let mut emitted_membership = 0usize;
        for (i, (at_secs, req)) in requests.into_iter().enumerate() {
            if emitted_churn < config.churn_batches
                && i > 0
                && i % stride == 0
                && i / stride == emitted_churn + 1
            {
                events.push(TraceEvent::Churn(ChurnEvent {
                    at_secs,
                    edge_adds: config.churn_edge_adds as u32,
                    edge_removes: config.churn_edge_removes as u32,
                    feat_updates: config.churn_feat_updates as u32,
                    seed: churn_rng.next_u64(),
                }));
                emitted_churn += 1;
            }
            if emitted_membership < schedule.len()
                && i > 0
                && i % m_stride == 0
                && i / m_stride == emitted_membership + 1
            {
                events.push(TraceEvent::Membership {
                    at_secs,
                    event: schedule[emitted_membership],
                });
                emitted_membership += 1;
            }
            events.push(TraceEvent::Request { at_secs, req });
        }
        Trace { config: config.clone(), events }
    }

    /// Number of request events.
    pub fn n_requests(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Request { .. }))
            .count()
    }

    /// Number of churn events.
    pub fn n_churn(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Churn(_)))
            .count()
    }

    /// Number of membership events (0 for v1 traces).
    pub fn n_membership(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Membership { .. }))
            .count()
    }

    /// Simulated length: last event's arrival time (0 for an empty trace).
    pub fn duration_secs(&self) -> f64 {
        self.events.last().map_or(0.0, |e| e.at_secs())
    }

    /// Serialize to the versioned on-disk format (EXPERIMENTS.md §Traffic
    /// documents the layout): `DEALTRAC` magic, `u32` version, the config
    /// echoed field by field, the event list, and a trailing FNV-1a
    /// checksum over everything before it. All integers little-endian;
    /// floats as IEEE-754 bit patterns.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(64 + self.events.len() * 48);
        buf.extend_from_slice(TRACE_MAGIC);
        put_u32(&mut buf, TRACE_VERSION);
        let c = &self.config;
        put_u64(&mut buf, c.seed);
        put_u64(&mut buf, c.n_nodes as u64);
        put_u64(&mut buf, c.requests as u64);
        put_f64(&mut buf, c.base_rate);
        put_f64(&mut buf, c.zipf_s);
        put_f64(&mut buf, c.diurnal_amplitude);
        put_f64(&mut buf, c.diurnal_period_secs);
        put_f64(&mut buf, c.burst_factor);
        put_f64(&mut buf, c.burst_rate_hz);
        put_f64(&mut buf, c.burst_secs);
        put_f64(&mut buf, c.similar_fraction);
        put_u64(&mut buf, c.embed_ids as u64);
        put_u64(&mut buf, c.similar_ids as u64);
        put_u64(&mut buf, c.similar_k as u64);
        put_u64(&mut buf, c.churn_batches as u64);
        put_u64(&mut buf, c.churn_edge_adds as u64);
        put_u64(&mut buf, c.churn_edge_removes as u64);
        put_u64(&mut buf, c.churn_feat_updates as u64);
        // v2 config tail: length-prefixed membership schedule string.
        put_u32(&mut buf, c.membership_schedule.len() as u32);
        buf.extend_from_slice(c.membership_schedule.as_bytes());
        put_u64(&mut buf, self.events.len() as u64);
        for ev in &self.events {
            match ev {
                TraceEvent::Request { at_secs, req: Request::Embed(ids) } => {
                    buf.push(0);
                    put_f64(&mut buf, *at_secs);
                    put_u32(&mut buf, ids.len() as u32);
                    for &id in ids {
                        put_u32(&mut buf, id);
                    }
                }
                TraceEvent::Request { at_secs, req: Request::Similar { ids, k } } => {
                    buf.push(1);
                    put_f64(&mut buf, *at_secs);
                    put_u32(&mut buf, ids.len() as u32);
                    for &id in ids {
                        put_u32(&mut buf, id);
                    }
                    put_u32(&mut buf, *k as u32);
                }
                TraceEvent::Churn(c) => {
                    buf.push(2);
                    put_f64(&mut buf, c.at_secs);
                    put_u32(&mut buf, c.edge_adds);
                    put_u32(&mut buf, c.edge_removes);
                    put_u32(&mut buf, c.feat_updates);
                    put_u64(&mut buf, c.seed);
                }
                TraceEvent::Membership { at_secs, event } => {
                    buf.push(3);
                    put_f64(&mut buf, *at_secs);
                    buf.push(action_code(event));
                    put_u32(&mut buf, event.rank() as u32);
                }
            }
        }
        let sum = fnv1a(&buf);
        put_u64(&mut buf, sum);
        buf
    }

    /// Parse a serialized trace, validating magic, version, and checksum.
    pub fn from_bytes(bytes: &[u8]) -> Result<Trace> {
        let mut r = Reader { bytes, pos: 0 };
        let magic = r.take(8)?;
        anyhow::ensure!(magic == TRACE_MAGIC, "not a deal trace (bad magic)");
        let version = r.u32()?;
        anyhow::ensure!(
            (TRACE_MIN_VERSION..=TRACE_VERSION).contains(&version),
            "trace format version {} (this build reads {}..={})",
            version,
            TRACE_MIN_VERSION,
            TRACE_VERSION
        );
        anyhow::ensure!(bytes.len() >= 8, "trace truncated");
        let body = &bytes[..bytes.len() - 8];
        let mut tail = Reader { bytes, pos: bytes.len() - 8 };
        let expect = tail.u64()?;
        let got = fnv1a(body);
        anyhow::ensure!(
            expect == got,
            "trace checksum mismatch (stored {:#018x}, computed {:#018x})",
            expect,
            got
        );
        let config = TraceConfig {
            seed: r.u64()?,
            n_nodes: r.u64()? as usize,
            requests: r.u64()? as usize,
            base_rate: r.f64()?,
            zipf_s: r.f64()?,
            diurnal_amplitude: r.f64()?,
            diurnal_period_secs: r.f64()?,
            burst_factor: r.f64()?,
            burst_rate_hz: r.f64()?,
            burst_secs: r.f64()?,
            similar_fraction: r.f64()?,
            embed_ids: r.u64()? as usize,
            similar_ids: r.u64()? as usize,
            similar_k: r.u64()? as usize,
            churn_batches: r.u64()? as usize,
            churn_edge_adds: r.u64()? as usize,
            churn_edge_removes: r.u64()? as usize,
            churn_feat_updates: r.u64()? as usize,
            membership_schedule: if version >= 2 {
                let len = r.u32()? as usize;
                anyhow::ensure!(len <= 1 << 16, "membership schedule oversized ({len} bytes)");
                String::from_utf8(r.take(len)?.to_vec())
                    .map_err(|e| anyhow::anyhow!("membership schedule not utf-8: {}", e))?
            } else {
                String::new() // v1 predates membership events
            },
        };
        let n_events = r.u64()? as usize;
        let mut events = Vec::with_capacity(n_events.min(1 << 22));
        for _ in 0..n_events {
            let tag = r.take(1)?[0];
            let ev = match tag {
                0 | 1 => {
                    let at_secs = r.f64()?;
                    let n_ids = r.u32()? as usize;
                    let mut ids = Vec::with_capacity(n_ids.min(1 << 20));
                    for _ in 0..n_ids {
                        ids.push(r.u32()?);
                    }
                    let req = if tag == 0 {
                        Request::Embed(ids)
                    } else {
                        Request::Similar { ids, k: r.u32()? as usize }
                    };
                    TraceEvent::Request { at_secs, req }
                }
                2 => TraceEvent::Churn(ChurnEvent {
                    at_secs: r.f64()?,
                    edge_adds: r.u32()?,
                    edge_removes: r.u32()?,
                    feat_updates: r.u32()?,
                    seed: r.u64()?,
                }),
                3 if version >= 2 => {
                    let at_secs = r.f64()?;
                    let code = r.take(1)?[0];
                    let rank = r.u32()? as usize;
                    TraceEvent::Membership { at_secs, event: action_from(code, rank)? }
                }
                other => anyhow::bail!(
                    "unknown trace event tag {} for format version {}",
                    other,
                    version
                ),
            };
            events.push(ev);
        }
        anyhow::ensure!(r.pos == bytes.len() - 8, "trailing bytes after trace events");
        Ok(Trace { config, events })
    }

    /// Write the serialized trace to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .map_err(|e| anyhow::anyhow!("write trace {}: {}", path.display(), e))
    }

    /// Load a trace from `path`.
    pub fn load(path: &Path) -> Result<Trace> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("read trace {}: {}", path.display(), e))?;
        Trace::from_bytes(&bytes)
    }
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// FNV-1a over a byte slice (the trace checksum; same constants as
/// `serve::response_digest`). Re-exported from [`crate::util::fnv1a`],
/// which the durable store's WAL/checkpoint formats share.
pub use crate::util::fnv1a;

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(self.pos + n <= self.bytes.len(), "trace truncated");
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> TraceConfig {
        TraceConfig {
            seed: 11,
            n_nodes: 64,
            requests: 200,
            churn_batches: 3,
            ..TraceConfig::default()
        }
    }

    #[test]
    fn roundtrips_and_checks() {
        let trace = Trace::generate(&small_cfg());
        assert_eq!(trace.n_requests(), 200);
        assert_eq!(trace.n_churn(), 3);
        let bytes = trace.to_bytes();
        let back = Trace::from_bytes(&bytes).unwrap();
        assert_eq!(back.config, trace.config);
        assert_eq!(back.to_bytes(), bytes, "reserialization is identity");
        // corruption is caught by the checksum
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        let err = Trace::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("checksum"), "err: {}", err);
        // wrong magic is caught before anything else
        let mut nomagic = bytes.clone();
        nomagic[0] = b'X';
        assert!(Trace::from_bytes(&nomagic).is_err());
        // truncation is caught
        assert!(Trace::from_bytes(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn events_are_time_ordered_and_churn_precedes_its_request() {
        let trace = Trace::generate(&small_cfg());
        let mut last = 0.0;
        for ev in &trace.events {
            assert!(ev.at_secs() >= last, "events out of order");
            last = ev.at_secs();
        }
        // churn seeds are distinct (forked stream draws)
        let seeds: Vec<u64> = trace
            .events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Churn(c) => Some(c.seed),
                _ => None,
            })
            .collect();
        assert_eq!(seeds.len(), 3);
        assert!(seeds[0] != seeds[1] && seeds[1] != seeds[2]);
    }

    #[test]
    fn zipf_sampler_is_skewed_and_in_range() {
        let mut rng = Rng::new(7);
        let z = ZipfSampler::new(100, 1.2, &mut rng);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        let top = z.id_of_rank(0) as usize;
        let bottom = z.id_of_rank(99) as usize;
        assert!(
            counts[top] > 10 * counts[bottom].max(1),
            "rank 0 ({}) vs rank 99 ({})",
            counts[top],
            counts[bottom]
        );
        let p0 = z.rank_probability(0);
        let obs = counts[top] as f64 / 20_000.0;
        assert!((obs - p0).abs() < 0.05, "obs {} vs theory {}", obs, p0);
    }

    #[test]
    fn ids_stay_in_universe() {
        let trace = Trace::generate(&small_cfg());
        for ev in &trace.events {
            if let TraceEvent::Request { req, .. } = ev {
                assert!(req.ids().iter().all(|&id| (id as usize) < 64));
            }
        }
    }

    // Offset of the v2 membership-schedule length field: 8 magic + 4
    // version + 144 bytes of v1 config (3 u64 + 8 f64 + 7 u64).
    const SCHEDULE_OFF: usize = 8 + 4 + 144;

    /// Strip a v2 buffer down to v1 layout: rewrite the version word,
    /// splice out the schedule field, recompute the checksum.
    fn downgrade_to_v1(bytes: &[u8], schedule_len: usize) -> Vec<u8> {
        let mut v1 = bytes[..bytes.len() - 8].to_vec(); // drop checksum
        v1[8..12].copy_from_slice(&1u32.to_le_bytes());
        v1.drain(SCHEDULE_OFF..SCHEDULE_OFF + 4 + schedule_len);
        let sum = fnv1a(&v1);
        put_u64(&mut v1, sum);
        v1
    }

    #[test]
    fn membership_events_roundtrip() {
        let cfg = TraceConfig {
            membership_schedule: "join:4,kill:2,leave:0".into(),
            ..small_cfg()
        };
        let trace = Trace::generate(&cfg);
        assert_eq!(trace.n_requests(), 200);
        assert_eq!(trace.n_membership(), 3);
        let got: Vec<MembershipEvent> = trace
            .events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Membership { event, .. } => Some(*event),
                _ => None,
            })
            .collect();
        assert_eq!(
            got,
            vec![
                MembershipEvent::Join { rank: 4 },
                MembershipEvent::Kill { rank: 2 },
                MembershipEvent::Leave { rank: 0 },
            ],
            "schedule order survives interleaving"
        );
        // time-ordered alongside requests and churn
        let mut last = 0.0;
        for ev in &trace.events {
            assert!(ev.at_secs() >= last);
            last = ev.at_secs();
        }
        let bytes = trace.to_bytes();
        let back = Trace::from_bytes(&bytes).unwrap();
        assert_eq!(back.config, trace.config);
        assert_eq!(back.n_membership(), 3);
        assert_eq!(back.to_bytes(), bytes, "reserialization is identity");
    }

    #[test]
    fn reads_v1_traces() {
        // A membership-free v2 trace differs from its v1 form only by the
        // version word and the empty schedule-length field; hand-patch it
        // into v1 layout and check the reader accepts it.
        let trace = Trace::generate(&small_cfg());
        assert!(trace.config.membership_schedule.is_empty());
        let v1 = downgrade_to_v1(&trace.to_bytes(), 0);
        let back = Trace::from_bytes(&v1).unwrap();
        assert_eq!(back.config, trace.config, "v1 read defaults to empty schedule");
        assert_eq!(back.events.len(), trace.events.len());
        assert_eq!(back.to_bytes(), trace.to_bytes(), "v1 loads re-save as v2");
    }

    #[test]
    fn v1_rejects_membership_events_and_future_versions_fail() {
        let cfg = TraceConfig { membership_schedule: "kill:1".into(), ..small_cfg() };
        let trace = Trace::generate(&cfg);
        // Same downgrade surgery, but the body still carries tag-3 events:
        // a v1 reader must refuse them rather than misparse.
        let v1 = downgrade_to_v1(&trace.to_bytes(), "kill:1".len());
        let err = Trace::from_bytes(&v1).unwrap_err().to_string();
        assert!(err.contains("tag 3"), "err: {}", err);
        // and an unknown future version is refused up front
        let mut future = trace.to_bytes();
        future[8..12].copy_from_slice(&99u32.to_le_bytes());
        let body_len = future.len() - 8;
        let sum = fnv1a(&future[..body_len]);
        future[body_len..].copy_from_slice(&sum.to_le_bytes());
        let err = Trace::from_bytes(&future).unwrap_err().to_string();
        assert!(err.contains("version 99"), "err: {}", err);
    }
}
