//! Temporal embedding engine (DESIGN.md §Temporal): replay a timestamped
//! edge stream through the delta engine, seal a **versioned epoch
//! snapshot** at every tick boundary, and serve time-travel queries
//! (`embed` / `similar` *at epoch t*) against any retained epoch.
//!
//! The engine folds events into one pending [`UpdateBatch`] per epoch
//! window. Two properties make the published snapshots *exact*:
//!
//! 1. **Sequential fold** — an in-window `RemoveEdge` that matches a
//!    still-pending `AddEdge` cancels it (edge instances are
//!    indistinguishable), so the single batch the boundary applies is
//!    semantically identical to applying the events one by one. Any other
//!    order (`remove` before `add`, repeated feature writes) already
//!    matches the batch discipline (removals resolve against the
//!    pre-batch graph, adds append afterwards, feature writes apply in
//!    order).
//! 2. **Exact delta mode** — the state runs with
//!    [`DeltaState::set_exact`], so after *every* apply the cached
//!    activations are bit-identical to a fresh dense init over the
//!    current graph. A published snapshot therefore depends only on the
//!    graph as of its boundary tick — never on how the replayed stream
//!    was chopped into `ingest` calls — and is bit-identical to a cold
//!    full-graph rerun at every thread count, chunk size, and memory
//!    budget (hard-asserted in `tests/temporal.rs`).
//!
//! Snapshots publish into a retention-bounded
//! [`TableCell`](crate::serve::TableCell) (copy-on-write per shard: an
//! epoch that patched 1% of rows shares the other 99% with its
//! predecessor). With a durable directory configured, every sealed epoch
//! is journaled (`DurableStore::journal_delta`) and digest-marked
//! (`DurableStore::journal_mark`) *before* it publishes — evicted epochs
//! stay reachable through `storage::EpochHistory::replay_to`, and
//! [`TemporalEngine::resume`] rebuilds the full epoch index from the
//! journal after a restart.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::config::DealConfig;
use crate::coordinator::delta::{DeltaState, UpdateBatch};
use crate::graph::NodeId;
use crate::runtime::Backend;
use crate::serve::{PoolOpts, Request, Response, ServePool, ShardedTable, TableCell};
use crate::storage::durable::table_digest;
use crate::storage::{DurableOptions, DurableStore, EpochHistory};
use crate::util::rng::Rng;
use crate::Result;

/// Per-epoch seed salt for deterministic event synthesis: resuming from
/// the journal regenerates the exact same future stream.
const SYNTH_SALT: u64 = 0x7E4C_0DE5_EED5_A17u64;

/// One timestamped graph event.
#[derive(Clone, Debug)]
pub struct TemporalEvent {
    /// Logical timestamp; the stream must be non-decreasing in `tick`.
    pub tick: u64,
    pub op: TemporalOp,
}

/// The event kinds a temporal stream carries.
#[derive(Clone, Debug, PartialEq)]
pub enum TemporalOp {
    /// `(src, dst)`: src becomes an in-neighbor of dst.
    AddEdge(NodeId, NodeId),
    /// `(src, dst)`: remove one instance of the edge if present.
    RemoveEdge(NodeId, NodeId),
    /// Replace a node's feature row.
    SetFeature(NodeId, Vec<f32>),
}

/// Engine knobs (CLI: `deal temporal --snapshot-every --retain`).
#[derive(Clone, Debug)]
pub struct TemporalOpts {
    /// Ticks per epoch window: epoch `e` seals once an event at tick
    /// `>= e * snapshot_every` arrives (or `advance_to` passes it).
    pub snapshot_every: u64,
    /// Resident snapshots kept for time-travel reads (oldest evicted
    /// first); evicted epochs need a durable history to stay reachable.
    pub retain: usize,
    /// Journal directory; `None` = ephemeral (no resume, no eviction
    /// fallback).
    pub durable_dir: Option<PathBuf>,
}

impl Default for TemporalOpts {
    fn default() -> Self {
        TemporalOpts { snapshot_every: 8, retain: 4, durable_dir: None }
    }
}

/// What sealing one epoch produced.
#[derive(Clone, Debug)]
pub struct EpochReport {
    pub epoch: u64,
    /// Boundary tick the epoch sealed at (`epoch * snapshot_every`).
    pub seal_tick: u64,
    /// Events folded into the epoch's batch.
    pub events: usize,
    /// Embedding rows the epoch actually changed.
    pub updated_rows: usize,
    /// `storage::durable::table_digest` of the published snapshot.
    pub digest: u64,
    /// Simulated seconds of the incremental refresh.
    pub sim_secs: f64,
    /// Wall seconds of the seal on this host.
    pub wall_secs: f64,
}

/// The temporal engine: a live exact-mode [`DeltaState`], a
/// retention-bounded epoch index, and an optional durable journal.
pub struct TemporalEngine {
    cfg: DealConfig,
    state: DeltaState,
    cell: Arc<TableCell>,
    durable: Option<DurableStore>,
    snapshot_every: u64,
    /// Last ingested tick.
    clock: u64,
    /// Last sealed (published) epoch.
    sealed: u64,
    pending: UpdateBatch,
    pending_events: usize,
    reports: Vec<EpochReport>,
}

impl TemporalEngine {
    /// Build epoch 0 from the configured dataset: full inference state in
    /// exact mode, snapshot published (and journaled when durable).
    pub fn new(cfg: DealConfig, opts: &TemporalOpts) -> Result<TemporalEngine> {
        anyhow::ensure!(opts.snapshot_every >= 1, "snapshot_every must be >= 1");
        let mut state = DeltaState::init(cfg.clone())?;
        state.set_exact(true);
        let table = ShardedTable::from_inference_plan(state.plan(), state.embeddings(), 0);
        let cell = Arc::new(TableCell::with_retention(table, opts.retain)?);
        let durable = match &opts.durable_dir {
            Some(dir) => {
                let mut store = DurableStore::create(
                    dir,
                    cfg.exec.seed,
                    state.embeddings(),
                    DurableOptions { compact_every: u64::MAX },
                )?;
                store.journal_mark(0, state.embeddings())?;
                Some(store)
            }
            None => None,
        };
        Ok(TemporalEngine {
            cfg,
            state,
            cell,
            durable,
            snapshot_every: opts.snapshot_every,
            clock: 0,
            sealed: 0,
            pending: UpdateBatch::default(),
            pending_events: 0,
            reports: Vec::new(),
        })
    }

    /// Rebuild the engine from a durable journal: fresh baseline from the
    /// config, then every journaled batch re-applied in epoch order with
    /// the journal's own patches and digests verified bit-for-bit along
    /// the way. The restored epoch index (current epoch, retained
    /// snapshots, digests) is exactly what the pre-restart engine held.
    pub fn resume(cfg: DealConfig, opts: &TemporalOpts) -> Result<TemporalEngine> {
        anyhow::ensure!(opts.snapshot_every >= 1, "snapshot_every must be >= 1");
        let dir = opts.durable_dir.as_ref().ok_or_else(|| {
            anyhow::anyhow!("resume needs a durable directory (--storage-dir)")
        })?;
        let hist = EpochHistory::read(dir)?;
        anyhow::ensure!(
            hist.seed == cfg.exec.seed,
            "durable store in {:?} was written with seed {}, config says {}",
            dir,
            hist.seed,
            cfg.exec.seed
        );
        let mut state = DeltaState::init(cfg.clone())?;
        state.set_exact(true);
        anyhow::ensure!(
            table_digest(state.embeddings()) == table_digest(&hist.baseline),
            "journaled baseline does not match this config's epoch-0 state \
             ({:#018x} vs {:#018x}) — wrong dataset/model/seed for this store",
            table_digest(&hist.baseline),
            table_digest(state.embeddings())
        );
        let table = ShardedTable::from_inference_plan(state.plan(), state.embeddings(), 0);
        let cell = Arc::new(TableCell::with_retention(table, opts.retain)?);
        let mut reports = Vec::with_capacity(hist.deltas.len());
        for (epoch, batch, rows, values) in &hist.deltas {
            let t0 = Instant::now();
            let events = batch.len();
            let rep = state.apply(batch)?;
            anyhow::ensure!(
                rep.updated_rows == *rows,
                "epoch {}: replay touched different rows than the journal recorded",
                epoch
            );
            let idx: Vec<usize> = rows.iter().map(|&v| v as usize).collect();
            let recomputed = state.embeddings().gather_rows(&idx);
            anyhow::ensure!(
                recomputed == *values,
                "epoch {}: replayed patch values diverged from the journal",
                epoch
            );
            let published = cell.publish(cell.load().patched(rows, values)?);
            anyhow::ensure!(published == *epoch, "epoch numbering drifted during resume");
            let digest = table_digest(state.embeddings());
            if let Some(&(_, marked)) = hist.published.iter().find(|(e, _)| e == epoch) {
                anyhow::ensure!(
                    marked == digest,
                    "epoch {}: journaled snapshot digest {:#018x}, replay produced {:#018x}",
                    epoch,
                    marked,
                    digest
                );
            }
            reports.push(EpochReport {
                epoch: *epoch,
                seal_tick: epoch * opts.snapshot_every,
                events,
                updated_rows: rows.len(),
                digest,
                sim_secs: rep.sim_secs,
                wall_secs: t0.elapsed().as_secs_f64(),
            });
        }
        let (store, rec) = DurableStore::open(dir, DurableOptions { compact_every: u64::MAX })?;
        anyhow::ensure!(
            rec.table == *state.embeddings(),
            "recovered table is not bit-identical to the replayed state"
        );
        let sealed = hist.last_epoch();
        Ok(TemporalEngine {
            cfg,
            state,
            cell,
            durable: Some(store),
            snapshot_every: opts.snapshot_every,
            clock: sealed * opts.snapshot_every,
            sealed,
            pending: UpdateBatch::default(),
            pending_events: 0,
            reports,
        })
    }

    // ---- accessors -----------------------------------------------------

    /// Last sealed epoch.
    pub fn epoch(&self) -> u64 {
        self.sealed
    }

    /// Last ingested tick.
    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// The live inference state (current graph, current embeddings).
    pub fn state(&self) -> &DeltaState {
        &self.state
    }

    /// The serving cell holding the retained epoch index.
    pub fn cell(&self) -> &Arc<TableCell> {
        &self.cell
    }

    /// Epochs answerable from resident snapshots, oldest first.
    pub fn retained_epochs(&self) -> Vec<u64> {
        self.cell.retained_epochs()
    }

    /// Seal reports so far, oldest first (resume rebuilds them from the
    /// journal).
    pub fn reports(&self) -> &[EpochReport] {
        &self.reports
    }

    // ---- the replay loop -----------------------------------------------

    /// Fold a tick-ordered slice of events, sealing every epoch whose
    /// boundary the stream crosses. Returns the epochs sealed by this
    /// call, oldest first.
    pub fn ingest(&mut self, events: &[TemporalEvent]) -> Result<Vec<EpochReport>> {
        let mut sealed = Vec::new();
        for ev in events {
            anyhow::ensure!(
                ev.tick >= self.clock,
                "event stream is not tick-ordered: tick {} after tick {}",
                ev.tick,
                self.clock
            );
            while ev.tick >= (self.sealed + 1) * self.snapshot_every {
                sealed.push(self.seal()?);
            }
            self.clock = ev.tick;
            match &ev.op {
                TemporalOp::AddEdge(s, d) => self.pending.add_edges.push((*s, *d)),
                TemporalOp::RemoveEdge(s, d) => {
                    // cancel an in-window add instead of queueing a
                    // removal — the sequential-fold rule (module docs)
                    if let Some(pos) =
                        self.pending.add_edges.iter().rposition(|&e| e == (*s, *d))
                    {
                        self.pending.add_edges.remove(pos);
                    } else {
                        self.pending.remove_edges.push((*s, *d));
                    }
                }
                TemporalOp::SetFeature(v, row) => {
                    self.pending.feature_updates.push((*v, row.clone()))
                }
            }
            self.pending_events += 1;
        }
        Ok(sealed)
    }

    /// Advance the clock to `tick`, sealing every boundary passed — the
    /// stream's way of saying "nothing happened until `tick`". Quiet
    /// epochs still publish (a content-identical snapshot) so the
    /// epoch↔tick mapping stays dense.
    pub fn advance_to(&mut self, tick: u64) -> Result<Vec<EpochReport>> {
        anyhow::ensure!(
            tick >= self.clock,
            "cannot advance the clock backwards: tick {} after tick {}",
            tick,
            self.clock
        );
        let mut sealed = Vec::new();
        while tick >= (self.sealed + 1) * self.snapshot_every {
            sealed.push(self.seal()?);
        }
        self.clock = tick;
        Ok(sealed)
    }

    /// Seal the pending window: apply the folded batch, journal it (when
    /// durable), publish the snapshot into the epoch index.
    fn seal(&mut self) -> Result<EpochReport> {
        let t0 = Instant::now();
        let epoch = self.sealed + 1;
        let batch = std::mem::take(&mut self.pending);
        let events = std::mem::take(&mut self.pending_events);
        let rep = self.state.apply(&batch)?;
        let idx: Vec<usize> = rep.updated_rows.iter().map(|&v| v as usize).collect();
        let values = self.state.embeddings().gather_rows(&idx);
        let next = self.cell.load().patched(&rep.updated_rows, &values)?;
        if let Some(store) = &mut self.durable {
            // journal-then-publish: the epoch becomes visible only once
            // its batch, patch, and snapshot digest are durable
            store.journal_delta(epoch, &batch, &rep.updated_rows, &values)?;
            store.journal_mark(epoch, self.state.embeddings())?;
        }
        let published = self.cell.publish(next);
        debug_assert_eq!(published, epoch);
        self.sealed = epoch;
        self.clock = self.clock.max(epoch * self.snapshot_every);
        let report = EpochReport {
            epoch,
            seal_tick: epoch * self.snapshot_every,
            events,
            updated_rows: rep.updated_rows.len(),
            digest: table_digest(self.state.embeddings()),
            sim_secs: rep.sim_secs,
            wall_secs: t0.elapsed().as_secs_f64(),
        };
        self.reports.push(report.clone());
        Ok(report)
    }

    // ---- time travel ---------------------------------------------------

    /// The exact snapshot published at `epoch`: resident if retained,
    /// otherwise reconstructed from the durable journal with its digest
    /// mark re-verified. Fails with a cause-naming error when the epoch
    /// is unreachable.
    pub fn snapshot_at(&self, epoch: u64) -> Result<Arc<ShardedTable>> {
        anyhow::ensure!(
            epoch <= self.sealed,
            "epoch {} has not been sealed yet (current epoch {})",
            epoch,
            self.sealed
        );
        let resident = self.cell.load_at(epoch);
        if let Ok(table) = resident {
            return Ok(table);
        }
        let store = self.durable.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "epoch {} was evicted (retained: {:?}) and no durable history is \
                 configured — rerun with --storage-dir to keep evicted epochs reachable",
                epoch,
                self.retained_epochs()
            )
        })?;
        let hist = EpochHistory::read(store.dir())?;
        let table = hist.replay_to(epoch)?;
        let shards = self.state.plan().p;
        Ok(Arc::new(ShardedTable::from_full(&table, shards, epoch)))
    }

    /// Serve a batch of requests *as of* `epoch` through the production
    /// pool path: the snapshot is pinned into a fresh
    /// [`TableCell`](crate::serve::TableCell) and a short-lived
    /// [`ServePool`] answers from it — same batching, same admission,
    /// same response bits as serving that epoch live.
    pub fn serve_at(
        &self,
        epoch: u64,
        backend: Arc<dyn Backend>,
        requests: &[Request],
    ) -> Result<Vec<Response>> {
        let snapshot = self.snapshot_at(epoch)?;
        let cell = Arc::new(TableCell::pin(snapshot));
        let pool = ServePool::spawn(cell, backend, PoolOpts::default());
        let mut out = Vec::with_capacity(requests.len());
        for req in requests {
            out.push(pool.call(req.clone())?);
        }
        let _ = pool.shutdown();
        Ok(out)
    }

    // ---- deterministic stream synthesis --------------------------------

    /// Synthesize a deterministic event stream for the *next* epoch
    /// window against the current graph: `removes` removals of existing
    /// edges, then `adds` insertions, then `feats` feature rewrites,
    /// tick-spread across the window. The per-epoch seed derivation means
    /// a resumed engine regenerates the identical future stream.
    pub fn synth_events(
        &self,
        adds: usize,
        removes: usize,
        feats: usize,
    ) -> Vec<TemporalEvent> {
        let epoch = self.sealed + 1;
        let mut rng =
            Rng::new(self.cfg.exec.seed ^ SYNTH_SALT.wrapping_add(epoch.wrapping_mul(0x9E37)));
        let batch = self.state.synth_batch(&mut rng, adds, removes, feats);
        let mut ops: Vec<TemporalOp> = Vec::with_capacity(batch.len());
        ops.extend(batch.remove_edges.iter().map(|&(s, d)| TemporalOp::RemoveEdge(s, d)));
        ops.extend(batch.add_edges.iter().map(|&(s, d)| TemporalOp::AddEdge(s, d)));
        ops.extend(
            batch.feature_updates.into_iter().map(|(v, row)| TemporalOp::SetFeature(v, row)),
        );
        let lo = self.clock.max((epoch - 1) * self.snapshot_every);
        let hi = epoch * self.snapshot_every;
        let span = hi.saturating_sub(lo).max(1);
        let n = ops.len().max(1) as u64;
        ops.into_iter()
            .enumerate()
            .map(|(i, op)| TemporalEvent {
                tick: (lo + (i as u64 * span) / n).min(hi - 1),
                op,
            })
            .collect()
    }

    /// Fresh full-recompute oracle over the *current* graph: a cold
    /// `DeltaState::init_with` (dense forward from scratch). The temporal
    /// contract says the latest published snapshot equals this bitwise.
    pub fn cold_oracle(&self) -> Result<crate::tensor::Matrix> {
        let fresh = DeltaState::init_with(
            self.cfg.clone(),
            self.state.edge_list(),
            self.state.features().clone(),
        )?;
        Ok(fresh.embeddings().clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::response_digest;

    fn small_cfg(kind: &str) -> DealConfig {
        let mut cfg = DealConfig::default();
        cfg.dataset.name = "products-sim".into();
        cfg.dataset.scale = 1.0 / 256.0; // 256 nodes
        cfg.cluster.machines = 4;
        cfg.cluster.feature_parts = 2;
        cfg.model.kind = kind.into();
        cfg.model.layers = 2;
        cfg.model.fanout = 5;
        cfg
    }

    fn opts(snapshot_every: u64, retain: usize) -> TemporalOpts {
        TemporalOpts { snapshot_every, retain, durable_dir: None }
    }

    #[test]
    fn epochs_seal_at_tick_boundaries_and_match_cold_rerun() {
        let mut eng = TemporalEngine::new(small_cfg("gcn"), &opts(10, 8)).unwrap();
        assert_eq!(eng.epoch(), 0);
        for _ in 0..3 {
            let events = eng.synth_events(12, 12, 2);
            assert!(!events.is_empty());
            eng.ingest(&events).unwrap();
            let sealed = eng.advance_to((eng.epoch() + 1) * 10).unwrap();
            assert_eq!(sealed.len(), 1);
            // published snapshot == cold full-graph recompute, bitwise
            let snap = eng.snapshot_at(eng.epoch()).unwrap();
            assert_eq!(snap.to_full(), eng.cold_oracle().unwrap());
        }
        assert_eq!(eng.epoch(), 3);
        assert_eq!(eng.retained_epochs(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn snapshots_are_invariant_to_ingest_batching() {
        // one event at a time vs the whole window at once — the fold rule
        // makes the sealed snapshot identical
        let mk = || TemporalEngine::new(small_cfg("gcn"), &opts(16, 4)).unwrap();
        let mut a = mk();
        let mut b = mk();
        let events = a.synth_events(20, 20, 3);
        a.ingest(&events).unwrap();
        for ev in &events {
            b.ingest(std::slice::from_ref(ev)).unwrap();
        }
        let ra = a.advance_to(16).unwrap();
        let rb = b.advance_to(16).unwrap();
        assert_eq!(ra[0].digest, rb[0].digest);
        assert_eq!(
            a.snapshot_at(1).unwrap().to_full(),
            b.snapshot_at(1).unwrap().to_full()
        );
    }

    #[test]
    fn add_then_remove_within_a_window_cancels_exactly() {
        let mut eng = TemporalEngine::new(small_cfg("gcn"), &opts(8, 2)).unwrap();
        let before_edges = eng.state().n_edges();
        let e: (NodeId, NodeId) = (3, 7);
        eng.ingest(&[
            TemporalEvent { tick: 1, op: TemporalOp::AddEdge(e.0, e.1) },
            TemporalEvent { tick: 2, op: TemporalOp::RemoveEdge(e.0, e.1) },
        ])
        .unwrap();
        let rep = &eng.advance_to(8).unwrap()[0];
        assert_eq!(rep.events, 2);
        assert_eq!(eng.state().n_edges(), before_edges, "add+remove is a no-op");
        assert_eq!(
            eng.snapshot_at(1).unwrap().to_full(),
            eng.snapshot_at(0).unwrap().to_full()
        );
    }

    #[test]
    fn out_of_order_events_are_rejected() {
        let mut eng = TemporalEngine::new(small_cfg("gcn"), &opts(8, 2)).unwrap();
        eng.ingest(&[TemporalEvent { tick: 5, op: TemporalOp::AddEdge(0, 1) }]).unwrap();
        let err = eng
            .ingest(&[TemporalEvent { tick: 3, op: TemporalOp::AddEdge(1, 2) }])
            .unwrap_err()
            .to_string();
        assert!(err.contains("tick 3") && err.contains("tick 5"), "{}", err);
        assert!(eng.advance_to(2).is_err(), "clock cannot move backwards");
    }

    #[test]
    fn retention_evicts_but_durable_history_reconstructs() {
        let dir = std::env::temp_dir()
            .join(format!("deal-temporal-evict-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let o = TemporalOpts { snapshot_every: 4, retain: 2, durable_dir: Some(dir.clone()) };
        let mut eng = TemporalEngine::new(small_cfg("gcn"), &o).unwrap();
        let mut digests = vec![table_digest(eng.state().embeddings())]; // epoch 0
        for _ in 0..4 {
            let events = eng.synth_events(8, 8, 1);
            eng.ingest(&events).unwrap();
            let rep = &eng.advance_to((eng.epoch() + 1) * 4).unwrap()[0];
            digests.push(rep.digest);
        }
        assert_eq!(eng.retained_epochs(), vec![3, 4], "retain = 2 evicted the rest");
        // evicted epochs come back through the journal, digest-verified
        for epoch in 0..=4u64 {
            let snap = eng.snapshot_at(epoch).unwrap();
            assert_eq!(table_digest(&snap.to_full()), digests[epoch as usize]);
        }
        let err = eng.snapshot_at(9).unwrap_err().to_string();
        assert!(err.contains("not been sealed"), "{}", err);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ephemeral_eviction_names_the_cause() {
        let mut eng = TemporalEngine::new(small_cfg("gcn"), &opts(4, 1)).unwrap();
        for _ in 0..2 {
            let events = eng.synth_events(5, 5, 1);
            eng.ingest(&events).unwrap();
            eng.advance_to((eng.epoch() + 1) * 4).unwrap();
        }
        let err = eng.snapshot_at(0).unwrap_err().to_string();
        assert!(
            err.contains("evicted") && err.contains("--storage-dir"),
            "cause-naming error: {}",
            err
        );
    }

    #[test]
    fn time_travel_serving_answers_from_the_exact_snapshot() {
        let mut eng = TemporalEngine::new(small_cfg("gcn"), &opts(6, 8)).unwrap();
        for _ in 0..2 {
            let events = eng.synth_events(10, 10, 2);
            eng.ingest(&events).unwrap();
            eng.advance_to((eng.epoch() + 1) * 6).unwrap();
        }
        let backend: Arc<dyn Backend> = Arc::new(crate::runtime::Native);
        let reqs = vec![
            Request::Embed(vec![1, 7, 99]),
            Request::Similar { ids: vec![5], k: 4 },
        ];
        for epoch in 0..=2u64 {
            let responses = eng.serve_at(epoch, Arc::clone(&backend), &reqs).unwrap();
            let snap = eng.snapshot_at(epoch).unwrap();
            match &responses[0] {
                Response::Embeddings(m) => {
                    assert_eq!(m.row(0), snap.row(1), "epoch {} row mismatch", epoch);
                    assert_eq!(m.row(2), snap.row(99));
                }
                other => panic!("unexpected response {:?}", other),
            }
        }
        // distinct epochs serve distinct bits (the graph churned)
        let d0 = response_digest(&eng.serve_at(0, Arc::clone(&backend), &reqs).unwrap()[1]);
        let d2 = response_digest(&eng.serve_at(2, backend, &reqs).unwrap()[1]);
        assert_ne!(d0, d2, "churn must be visible across epochs");
    }

    #[test]
    fn resume_restores_the_epoch_index_bitwise() {
        let dir = std::env::temp_dir()
            .join(format!("deal-temporal-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let o = TemporalOpts { snapshot_every: 5, retain: 3, durable_dir: Some(dir.clone()) };
        let mut eng = TemporalEngine::new(small_cfg("gcn"), &o).unwrap();
        for _ in 0..3 {
            let events = eng.synth_events(10, 10, 1);
            eng.ingest(&events).unwrap();
            eng.advance_to((eng.epoch() + 1) * 5).unwrap();
        }
        let live_digests: Vec<u64> = eng.reports().iter().map(|r| r.digest).collect();
        let live_retained = eng.retained_epochs();
        let live_table = eng.state().embeddings().clone();
        drop(eng);

        let resumed = TemporalEngine::resume(small_cfg("gcn"), &o).unwrap();
        assert_eq!(resumed.epoch(), 3);
        assert_eq!(resumed.clock(), 15);
        assert_eq!(resumed.retained_epochs(), live_retained);
        let resumed_digests: Vec<u64> = resumed.reports().iter().map(|r| r.digest).collect();
        assert_eq!(resumed_digests, live_digests);
        assert_eq!(resumed.state().embeddings(), &live_table, "bit-identical resume");
        // the resumed engine synthesizes the identical future stream
        let next = resumed.synth_events(4, 4, 1);
        assert_eq!(next.len(), 9);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_rejects_a_mismatched_config() {
        let dir = std::env::temp_dir()
            .join(format!("deal-temporal-mismatch-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let o = TemporalOpts { snapshot_every: 5, retain: 2, durable_dir: Some(dir.clone()) };
        let eng = TemporalEngine::new(small_cfg("gcn"), &o).unwrap();
        drop(eng);
        let mut wrong = small_cfg("gcn");
        wrong.exec.seed ^= 1;
        let err = TemporalEngine::resume(wrong, &o).unwrap_err().to_string();
        assert!(err.contains("seed"), "cause-naming error: {}", err);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
