//! Streaming graph updates: batched edge/node deltas against the
//! partitioned CSRs, and the k-hop *affected set* frontier that drives
//! incremental re-inference (DESIGN.md §Delta).
//!
//! Real recommendation/ads graphs churn continuously; re-running the full
//! all-node pipeline per epoch (PR 1's `serve::refresh::Refresher`) wastes
//! work when only a small fraction of edges moved. This module provides
//! the graph-side half of the delta path:
//!
//! - [`UpdateBatch`] — one batch of edge insertions/removals and node
//!   feature updates (node count is fixed; growing the graph would shift
//!   the 1-D partition bounds and invalidate every cached sample).
//! - [`PartitionDelta`] — per-partition staging: updates append into
//!   per-row logs, then [`PartitionDelta::compact`] merges them into a
//!   fresh CSR in one pass, keeping rows sorted (the invariant
//!   `Csr::from_edges_rect` establishes, which per-row resampling parity
//!   depends on).
//! - [`affected_frontier`] — given the *updated* sampled layer graphs,
//!   derive for each GNN level the set of nodes whose activations can
//!   change: feature-updated nodes seed level 0; a row is affected at
//!   level `l+1` iff its sampled row changed (dirty), it was affected at
//!   level `l` (self loop), or any sampled in-neighbor was affected at
//!   level `l`.
//! - [`restrict_rows`] / [`replace_rows`] / [`stack_partitions`] — CSR
//!   surgery helpers: frontier-restricted layer graphs (empty rows for
//!   unaffected destinations, so the SPMM group machinery naturally
//!   communicates only frontier columns), patched layer graphs after
//!   resampling, and global stitching of partition CSRs.

use std::collections::BTreeMap;

use super::csr::Csr;
use super::NodeId;
use crate::runtime::par;
use crate::Result;

/// Edge floor below which compaction stays serial.
const MIN_COMPACT_EDGES: u64 = 32 * 1024;

/// One batch of streaming updates. Node count is fixed: `remove_edges`
/// resolve against the pre-batch graph (removing one instance of the edge
/// if present), `add_edges` are appended afterwards, and
/// `feature_updates` replace whole feature rows.
#[derive(Clone, Debug, Default)]
pub struct UpdateBatch {
    /// `(src, dst)` insertions (src becomes an in-neighbor of dst).
    pub add_edges: Vec<(NodeId, NodeId)>,
    /// `(src, dst)` removals; absent edges are ignored.
    pub remove_edges: Vec<(NodeId, NodeId)>,
    /// `(node, new feature row)` replacements.
    pub feature_updates: Vec<(NodeId, Vec<f32>)>,
}

impl UpdateBatch {
    /// Total staged operations.
    pub fn len(&self) -> usize {
        self.add_edges.len() + self.remove_edges.len() + self.feature_updates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Check every id is in range and every feature row has width `dim`.
    pub fn validate(&self, n_nodes: usize, dim: usize) -> Result<()> {
        for &(s, d) in self.add_edges.iter().chain(&self.remove_edges) {
            anyhow::ensure!(
                (s as usize) < n_nodes && (d as usize) < n_nodes,
                "edge ({}, {}) out of range ({} nodes)",
                s,
                d,
                n_nodes
            );
        }
        for (v, row) in &self.feature_updates {
            anyhow::ensure!((*v as usize) < n_nodes, "feature update node {} out of range", v);
            anyhow::ensure!(
                row.len() == dim,
                "feature update for node {} has width {}, expected {}",
                v,
                row.len(),
                dim
            );
        }
        Ok(())
    }
}

/// Per-partition staged updates: append logs keyed by local row, merged
/// into the base CSR by one `compact` pass.
pub struct PartitionDelta {
    row_lo: usize,
    row_hi: usize,
    /// Appended in-neighbors per local row.
    adds: BTreeMap<usize, Vec<NodeId>>,
    /// Tombstoned in-neighbors per local row (each entry removes one
    /// instance from the base row, if present).
    removes: BTreeMap<usize, Vec<NodeId>>,
}

impl PartitionDelta {
    /// Staging area for the partition owning global rows `[row_lo, row_hi)`.
    pub fn new(row_lo: usize, row_hi: usize) -> PartitionDelta {
        assert!(row_lo <= row_hi);
        PartitionDelta { row_lo, row_hi, adds: BTreeMap::new(), removes: BTreeMap::new() }
    }

    /// Stage the slice of `batch` whose destination falls in this
    /// partition; edges owned by other partitions are skipped. Returns the
    /// number of staged (adds, removes).
    pub fn stage(&mut self, batch: &UpdateBatch) -> (usize, usize) {
        let mut staged = (0usize, 0usize);
        for &(s, d) in &batch.add_edges {
            let d = d as usize;
            if d >= self.row_lo && d < self.row_hi {
                self.adds.entry(d - self.row_lo).or_default().push(s);
                staged.0 += 1;
            }
        }
        for &(s, d) in &batch.remove_edges {
            let d = d as usize;
            if d >= self.row_lo && d < self.row_hi {
                self.removes.entry(d - self.row_lo).or_default().push(s);
                staged.1 += 1;
            }
        }
        staged
    }

    /// Nothing staged?
    pub fn is_empty(&self) -> bool {
        self.adds.is_empty() && self.removes.is_empty()
    }

    /// Merge the staged updates into `base` (this partition's CSR: local
    /// rows, global columns), producing the updated CSR (rows stay sorted)
    /// and the sorted list of local rows whose neighbor list actually
    /// changed. Tombstones for absent edges are dropped silently; a row
    /// touched only by such no-ops is *not* reported dirty. The staging
    /// area is consumed.
    pub fn compact(&mut self, base: &Csr) -> (Csr, Vec<usize>) {
        assert_eq!(base.n_rows, self.row_hi - self.row_lo, "base CSR / partition mismatch");
        let adds = std::mem::take(&mut self.adds);
        let removes = std::mem::take(&mut self.removes);
        let extra: usize = adds.values().map(|v| v.len()).sum();
        // Rows merge independently, so the pass runs over degree-balanced
        // row bands; each band emits its own (indices, row lengths, dirty)
        // buffers and the band-order stitch reproduces the sequential
        // output exactly.
        let bounds = par::weighted_bands(
            base.n_rows,
            |r| base.indptr[r + 1] - base.indptr[r] + 2,
            MIN_COMPACT_EDGES,
        );
        let nb = bounds.len() - 1;
        let bands: Vec<(Vec<NodeId>, Vec<u32>, Vec<usize>)> = par::map_indexed(nb, |bi| {
            let (rlo, rhi) = (bounds[bi], bounds[bi + 1]);
            let base_edges = (base.indptr[rhi] - base.indptr[rlo]) as usize;
            let mut indices: Vec<NodeId> = Vec::with_capacity(base_edges + extra / nb + 1);
            let mut lens: Vec<u32> = Vec::with_capacity(rhi - rlo);
            let mut dirty: Vec<usize> = Vec::new();
            for r in rlo..rhi {
                let row_adds = adds.get(&r);
                let row_removes = removes.get(&r);
                let before = indices.len();
                if row_adds.is_none() && row_removes.is_none() {
                    indices.extend_from_slice(base.row(r));
                } else {
                    let mut row: Vec<NodeId> = base.row(r).to_vec();
                    let mut changed = false;
                    if let Some(rm) = row_removes {
                        for &s in rm {
                            // base rows are sorted; removal keeps them sorted
                            if let Ok(pos) = row.binary_search(&s) {
                                row.remove(pos);
                                changed = true;
                            }
                        }
                    }
                    if let Some(ad) = row_adds {
                        row.extend_from_slice(ad);
                        row.sort_unstable();
                        changed = true;
                    }
                    if changed {
                        dirty.push(r);
                    }
                    indices.extend_from_slice(&row);
                }
                lens.push((indices.len() - before) as u32);
            }
            (indices, lens, dirty)
        });
        let mut indptr: Vec<u64> = Vec::with_capacity(base.n_rows + 1);
        indptr.push(0);
        let mut indices: Vec<NodeId> = Vec::with_capacity(base.n_edges() + extra);
        let mut dirty: Vec<usize> = Vec::new();
        for (band_indices, lens, band_dirty) in bands {
            for len in lens {
                indptr.push(indptr.last().unwrap() + len as u64);
            }
            indices.extend(band_indices);
            dirty.extend(band_dirty);
        }
        let csr = Csr { n_rows: base.n_rows, n_cols: base.n_cols, indptr, indices };
        (csr, dirty)
    }
}

/// Keep only the rows in `keep` (sorted local row ids); every other row
/// becomes empty. Shapes are preserved, so the result drops into the
/// existing SPMM machinery: aggregation and communication then touch only
/// the kept (frontier) rows' columns.
pub fn restrict_rows(csr: &Csr, keep: &[usize]) -> Csr {
    debug_assert!(keep.windows(2).all(|w| w[0] < w[1]), "keep must be sorted unique");
    let mut indptr: Vec<u64> = Vec::with_capacity(csr.n_rows + 1);
    indptr.push(0);
    let total: usize = keep.iter().map(|&r| csr.degree(r)).sum();
    let mut indices: Vec<NodeId> = Vec::with_capacity(total);
    let mut cursor = 0usize;
    for r in 0..csr.n_rows {
        if cursor < keep.len() && keep[cursor] == r {
            indices.extend_from_slice(csr.row(r));
            cursor += 1;
        }
        indptr.push(indices.len() as u64);
    }
    debug_assert_eq!(cursor, keep.len(), "keep row out of bounds");
    Csr { n_rows: csr.n_rows, n_cols: csr.n_cols, indptr, indices }
}

/// Rebuild `csr` with the rows named in `updates` replaced by new
/// (pre-sorted) neighbor lists. `updates` must be sorted by row id.
pub fn replace_rows(csr: &Csr, updates: &[(usize, Vec<NodeId>)]) -> Csr {
    debug_assert!(updates.windows(2).all(|w| w[0].0 < w[1].0), "updates must be sorted unique");
    let mut indptr: Vec<u64> = Vec::with_capacity(csr.n_rows + 1);
    indptr.push(0);
    let mut indices: Vec<NodeId> = Vec::with_capacity(csr.n_edges());
    let mut cursor = 0usize;
    for r in 0..csr.n_rows {
        if cursor < updates.len() && updates[cursor].0 == r {
            indices.extend_from_slice(&updates[cursor].1);
            cursor += 1;
        } else {
            indices.extend_from_slice(csr.row(r));
        }
        indptr.push(indices.len() as u64);
    }
    debug_assert_eq!(cursor, updates.len(), "update row out of bounds");
    Csr { n_rows: csr.n_rows, n_cols: csr.n_cols, indptr, indices }
}

/// Stitch per-partition CSRs (contiguous local row blocks, shared global
/// columns) back into one global CSR.
pub fn stack_partitions(parts: &[&Csr]) -> Csr {
    assert!(!parts.is_empty());
    let n_cols = parts[0].n_cols;
    let n_rows: usize = parts.iter().map(|c| c.n_rows).sum();
    let n_edges: usize = parts.iter().map(|c| c.n_edges()).sum();
    let mut indptr: Vec<u64> = Vec::with_capacity(n_rows + 1);
    indptr.push(0);
    let mut indices: Vec<NodeId> = Vec::with_capacity(n_edges);
    for part in parts {
        assert_eq!(part.n_cols, n_cols, "partition column spaces differ");
        let base = *indptr.last().unwrap();
        indptr.extend(part.indptr[1..].iter().map(|&x| base + x));
        indices.extend_from_slice(&part.indices);
    }
    Csr { n_rows, n_cols, indptr, indices }
}

/// Per-level affected sets for a k-layer GNN over the *updated* sampled
/// layer graphs. Level 0 is seeded by feature-updated nodes; level `l+1`
/// contains every destination whose layer-`l` aggregation inputs changed:
/// dirty rows (their sampled row itself changed — at every level), rows
/// affected at level `l` (the self-loop term), and rows with an affected
/// sampled in-neighbor. Returns `k + 1` sorted global-id lists
/// (`levels[l]` = nodes whose `H^(l)` may differ).
pub fn affected_frontier(
    layers_by_partition: &[Vec<Csr>],
    row_offsets: &[usize],
    n_nodes: usize,
    k: usize,
    dirty: &[NodeId],
    feat_changed: &[NodeId],
) -> Vec<Vec<NodeId>> {
    assert_eq!(layers_by_partition.len(), row_offsets.len());
    let mut levels: Vec<Vec<NodeId>> = Vec::with_capacity(k + 1);
    let mut cur = vec![false; n_nodes];
    for &v in feat_changed {
        cur[v as usize] = true;
    }
    levels.push(mask_to_ids(&cur));
    for l in 0..k {
        let mut next = vec![false; n_nodes];
        for &v in dirty {
            next[v as usize] = true;
        }
        for (p, layers) in layers_by_partition.iter().enumerate() {
            let g = &layers[l];
            let off = row_offsets[p];
            for r in 0..g.n_rows {
                let gr = off + r;
                if next[gr] {
                    continue;
                }
                if cur[gr] || g.row(r).iter().any(|&s| cur[s as usize]) {
                    next[gr] = true;
                }
            }
        }
        levels.push(mask_to_ids(&next));
        cur = next;
    }
    levels
}

fn mask_to_ids(mask: &[bool]) -> Vec<NodeId> {
    mask.iter()
        .enumerate()
        .filter(|(_, &m)| m)
        .map(|(v, _)| v as NodeId)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Csr {
        // rows (dst): 0 <- {1, 2}, 1 <- {0}, 2 <- {}, 3 <- {1, 1, 3}
        Csr::from_edges(4, &[(1, 0), (2, 0), (0, 1), (1, 3), (1, 3), (3, 3)])
    }

    #[test]
    fn compact_applies_adds_and_removes() {
        let g = base();
        let mut delta = PartitionDelta::new(0, 4);
        let batch = UpdateBatch {
            add_edges: vec![(3, 2), (0, 0)],
            remove_edges: vec![(2, 0), (1, 3), (3, 1)], // (3,1) absent: no-op
            feature_updates: vec![],
        };
        batch.validate(4, 1).unwrap();
        let (staged_adds, staged_removes) = delta.stage(&batch);
        assert_eq!((staged_adds, staged_removes), (2, 3));
        let (updated, dirty) = delta.compact(&g);
        updated.validate().unwrap();
        assert_eq!(updated.row(0), &[0, 1]); // removed 2, added 0
        assert_eq!(updated.row(1), &[0]); // tombstone for absent edge: unchanged
        assert_eq!(updated.row(2), &[3]);
        assert_eq!(updated.row(3), &[1, 3]); // one of the two (1,3) instances removed
        assert_eq!(dirty, vec![0, 2, 3]);
        assert!(delta.is_empty(), "compaction consumes the staging area");
    }

    #[test]
    fn compact_matches_from_scratch_rebuild() {
        // The compacted CSR must equal Csr::from_edges over the edited
        // edge multiset — rows sorted, multi-edges preserved.
        let g = base();
        let mut delta = PartitionDelta::new(0, 4);
        let batch = UpdateBatch {
            add_edges: vec![(2, 2), (0, 3)],
            remove_edges: vec![(1, 0)],
            feature_updates: vec![],
        };
        delta.stage(&batch);
        let (updated, _) = delta.compact(&g);
        let rebuilt = Csr::from_edges(
            4,
            &[(2, 0), (0, 1), (1, 3), (1, 3), (3, 3), (2, 2), (0, 3)],
        );
        assert_eq!(updated, rebuilt);
    }

    #[test]
    fn stage_filters_by_row_range() {
        let mut delta = PartitionDelta::new(2, 4);
        let batch = UpdateBatch {
            add_edges: vec![(0, 1), (0, 2), (0, 3)],
            remove_edges: vec![(1, 0), (1, 3)],
            feature_updates: vec![],
        };
        assert_eq!(delta.stage(&batch), (2, 1));
    }

    #[test]
    fn restrict_keeps_only_frontier_rows() {
        let g = base();
        let r = restrict_rows(&g, &[0, 3]);
        r.validate().unwrap();
        assert_eq!(r.n_rows, g.n_rows);
        assert_eq!(r.row(0), g.row(0));
        assert_eq!(r.degree(1), 0);
        assert_eq!(r.degree(2), 0);
        assert_eq!(r.row(3), g.row(3));
        assert_eq!(restrict_rows(&g, &[]).n_edges(), 0);
    }

    #[test]
    fn replace_swaps_named_rows() {
        let g = base();
        let r = replace_rows(&g, &[(1, vec![2, 3]), (2, vec![0])]);
        r.validate().unwrap();
        assert_eq!(r.row(0), g.row(0));
        assert_eq!(r.row(1), &[2, 3]);
        assert_eq!(r.row(2), &[0]);
        assert_eq!(r.row(3), g.row(3));
    }

    #[test]
    fn stack_round_trips_slices() {
        let g = base();
        let top = g.slice_rows(0, 2);
        let bot = g.slice_rows(2, 4);
        assert_eq!(stack_partitions(&[&top, &bot]), g);
    }

    #[test]
    fn frontier_seeds_and_propagates() {
        // layer graph (both layers): 0 <- {1}, 1 <- {}, 2 <- {0}, 3 <- {3}
        let g = Csr::from_edges(4, &[(1, 0), (0, 2), (3, 3)]);
        let layers = vec![vec![g.clone(), g.clone()]];
        // feature change at node 1 only
        let levels = affected_frontier(&layers, &[0], 4, 2, &[], &[1]);
        assert_eq!(levels.len(), 3);
        assert_eq!(levels[0], vec![1]);
        // level 1: node 0 (neighbor 1 changed) and node 1 (self loop)
        assert_eq!(levels[1], vec![0, 1]);
        // level 2: 0, 1 (self), 2 (neighbor 0 changed)
        assert_eq!(levels[2], vec![0, 1, 2]);
    }

    #[test]
    fn frontier_dirty_rows_affect_every_level() {
        let g = Csr::from_edges(4, &[(1, 0), (0, 2), (3, 3)]);
        let layers = vec![vec![g.clone(), g.clone()]];
        let levels = affected_frontier(&layers, &[0], 4, 2, &[3], &[]);
        assert_eq!(levels[0], Vec::<NodeId>::new());
        assert_eq!(levels[1], vec![3]);
        assert_eq!(levels[2], vec![3]); // 3's only out-edge is its self edge
    }

    #[test]
    fn frontier_respects_partition_offsets() {
        // two partitions of 2 rows each; partition 1 rows are global 2..4
        let g = Csr::from_edges(4, &[(1, 0), (0, 2), (3, 3)]);
        let parts = vec![
            vec![g.slice_rows(0, 2), g.slice_rows(0, 2)],
            vec![g.slice_rows(2, 4), g.slice_rows(2, 4)],
        ];
        let split = affected_frontier(&parts, &[0, 2], 4, 2, &[], &[1]);
        let whole_layers = vec![vec![g.clone(), g.clone()]];
        let whole = affected_frontier(&whole_layers, &[0], 4, 2, &[], &[1]);
        assert_eq!(split, whole);
    }

    #[test]
    fn batch_validation() {
        let ok = UpdateBatch {
            add_edges: vec![(0, 1)],
            remove_edges: vec![],
            feature_updates: vec![(1, vec![0.0, 1.0])],
        };
        assert!(ok.validate(2, 2).is_ok());
        assert!(!ok.is_empty());
        assert_eq!(ok.len(), 2);
        let bad_node = UpdateBatch { add_edges: vec![(0, 5)], ..Default::default() };
        assert!(bad_node.validate(2, 2).is_err());
        let bad_dim = UpdateBatch {
            feature_updates: vec![(0, vec![0.0])],
            ..Default::default()
        };
        assert!(bad_dim.validate(2, 2).is_err());
        assert!(UpdateBatch::default().is_empty());
    }
}
