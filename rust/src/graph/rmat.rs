//! RMAT synthetic graph generator (Chakrabarti et al., SDM'04).
//!
//! The paper evaluates scalability on RMAT graphs with edge probabilities
//! `{0.57, 0.19, 0.19, 0.05}` and average degree 20 (§4.1); the dataset
//! registry also uses RMAT (with different skew) to build the scaled
//! stand-ins for ogbn-products / social-spammer / ogbn-papers100M.

use super::{EdgeList, NodeId};
use crate::util::rng::Rng;

/// RMAT quadrant probabilities.
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    // d = 1 - a - b - c
}

impl RmatParams {
    /// The paper's scalability parameters {0.57, 0.19, 0.19, 0.05}.
    pub fn paper() -> Self {
        RmatParams { a: 0.57, b: 0.19, c: 0.19 }
    }

    /// Milder skew — degree distribution closer to a co-purchase network.
    pub fn mild() -> Self {
        RmatParams { a: 0.45, b: 0.22, c: 0.22 }
    }
}

/// Generate an RMAT graph with `2^scale` nodes and `n_edges` edges.
/// Multi-edges and self-loops are kept (as in the reference generator);
/// node ids are permuted so that low ids are not systematically hubs,
/// which would make contiguous 1-D range partitions artificially easy.
pub fn rmat(scale: u32, n_edges: usize, params: RmatParams, seed: u64) -> EdgeList {
    let n = 1usize << scale;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(n_edges);
    let (a, b, c) = (params.a, params.b, params.c);
    let ab = a + b;
    let abc = a + b + c;
    for _ in 0..n_edges {
        let (mut src, mut dst) = (0usize, 0usize);
        for _ in 0..scale {
            src <<= 1;
            dst <<= 1;
            let r = rng.next_f64();
            if r < a {
                // top-left: nothing set
            } else if r < ab {
                dst |= 1;
            } else if r < abc {
                src |= 1;
            } else {
                src |= 1;
                dst |= 1;
            }
        }
        edges.push((src as NodeId, dst as NodeId));
    }
    // Random relabel to decorrelate id ranges from degree.
    let mut perm: Vec<NodeId> = (0..n as NodeId).collect();
    rng.shuffle(&mut perm);
    for e in &mut edges {
        e.0 = perm[e.0 as usize];
        e.1 = perm[e.1 as usize];
    }
    EdgeList::new(n, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_shape() {
        let el = rmat(8, 2000, RmatParams::paper(), 7);
        assert_eq!(el.n_nodes, 256);
        assert_eq!(el.n_edges(), 2000);
        assert!(el.edges.iter().all(|&(s, d)| (s as usize) < 256 && (d as usize) < 256));
    }

    #[test]
    fn rmat_deterministic() {
        let a = rmat(7, 500, RmatParams::paper(), 3);
        let b = rmat(7, 500, RmatParams::paper(), 3);
        assert_eq!(a, b);
        let c = rmat(7, 500, RmatParams::paper(), 4);
        assert_ne!(a, c);
    }

    #[test]
    fn rmat_is_skewed() {
        // With a=0.57 skew, max in-degree should be far above the average.
        let el = rmat(10, 20_000, RmatParams::paper(), 11);
        let g = crate::graph::Csr::from(&el);
        let avg = 20_000.0 / 1024.0;
        let max_deg = (0..g.n_rows).map(|r| g.degree(r)).max().unwrap();
        assert!(
            (max_deg as f64) > 4.0 * avg,
            "max_deg={} avg={} — not skewed?",
            max_deg,
            avg
        );
    }
}
