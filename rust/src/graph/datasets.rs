//! Named synthetic dataset registry.
//!
//! The paper's graphs (ogbn-products 2.4M/123M, social-spammer 5.6M/858M,
//! ogbn-papers100M 111M/1.6B) are not fetchable in this environment, so the
//! registry builds scaled *twins* that preserve the property every
//! dataset-dependent trend in the paper rides on: relative density
//! (spammer ≫ products ≫ papers) and skewed degree distributions. Node
//! features are synthesized deterministically; labelled variants (for the
//! Table 6 accuracy study) plant SBM-style communities whose label signal
//! is carried by the features. See DESIGN.md §Substitutions.

use super::edgelist::EdgeList;
use super::rmat::{rmat, RmatParams};
use super::NodeId;
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::Result;

/// A dataset specification.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// log2 of node count at scale 1.0.
    pub scale_log2: u32,
    pub avg_degree: usize,
    pub feature_dim: usize,
    pub rmat: RmatParams,
    pub seed: u64,
    /// Which paper dataset this stands in for.
    pub stands_in_for: &'static str,
}

/// The three scaled twins plus the paper's RMAT scalability generator.
pub const REGISTRY: &[DatasetSpec] = &[
    DatasetSpec {
        name: "products-sim",
        scale_log2: 16, // 65_536 nodes
        avg_degree: 51,
        feature_dim: 100,
        rmat: RmatParams { a: 0.45, b: 0.22, c: 0.22 },
        seed: 0x700D5,
        stands_in_for: "ogbn-products (2.4M nodes / 123M edges, avg deg 51)",
    },
    DatasetSpec {
        name: "spammer-sim",
        scale_log2: 15, // 32_768 nodes
        avg_degree: 153,
        feature_dim: 128,
        rmat: RmatParams { a: 0.57, b: 0.19, c: 0.19 },
        seed: 0x5BA6,
        stands_in_for: "social-spammer (5.6M nodes / 858M edges, avg deg 153)",
    },
    DatasetSpec {
        name: "papers-sim",
        scale_log2: 17, // 131_072 nodes
        avg_degree: 15,
        feature_dim: 128,
        rmat: RmatParams { a: 0.57, b: 0.19, c: 0.19 },
        seed: 0xAAE5,
        stands_in_for: "ogbn-papers100M (111M nodes / 1.6B edges, avg deg 14)",
    },
    // The out-of-core twin: same shape family as papers-sim but sized so
    // its feature table (2^18 × 128 f32 = 128 MiB at scale 1.0) exceeds a
    // small per-rank storage budget — the named larger-than-RAM workload
    // for `crate::storage` (`tests/storage.rs`, `benches/storage_oom.rs`)
    // rather than a synthetic-only path.
    DatasetSpec {
        name: "papers-xl",
        scale_log2: 18, // 262_144 nodes
        avg_degree: 15,
        feature_dim: 128,
        rmat: RmatParams { a: 0.57, b: 0.19, c: 0.19 },
        seed: 0xAAE5 ^ 0x11,
        stands_in_for: "ogbn-papers100M at working-set scale (feature table > storage budget)",
    },
];

/// Bytes of the f32 feature table `spec` materializes at `scale` — the
/// working-set figure the storage budget is compared against.
pub fn feature_table_bytes(spec: &DatasetSpec, scale: f64) -> u64 {
    let n = 1u64 << scaled_log2(spec.scale_log2, scale);
    n * spec.feature_dim as u64 * 4
}

/// A materialized dataset: graph + node features.
pub struct Dataset {
    pub name: String,
    pub edges: EdgeList,
    pub features: Matrix,
    pub feature_dim: usize,
}

/// Look up a spec by name.
pub fn spec(name: &str) -> Result<&'static DatasetSpec> {
    REGISTRY
        .iter()
        .find(|s| s.name == name)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown dataset '{}' (known: {})",
                name,
                REGISTRY.iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
            )
        })
}

/// Materialize a registry dataset at a size scale (`scale=1.0` is the
/// default twin size; `0.25` quarters the node count for tests; larger
/// values grow it for scalability runs).
pub fn load(name: &str, scale: f64) -> Result<Dataset> {
    let s = spec(name)?;
    let scale_log2 = scaled_log2(s.scale_log2, scale);
    let n = 1usize << scale_log2;
    let n_edges = n * s.avg_degree;
    let edges = rmat(scale_log2, n_edges, s.rmat, s.seed);
    let features = synth_features(n, s.feature_dim, s.seed ^ 0xFEA7);
    Ok(Dataset { name: s.name.to_string(), edges, features, feature_dim: s.feature_dim })
}

fn scaled_log2(base: u32, scale: f64) -> u32 {
    let delta = scale.log2().round() as i32;
    (base as i32 + delta).clamp(6, 26) as u32
}

/// Deterministic synthetic node features, uniform in [-1, 1].
pub fn synth_features(n_nodes: usize, dim: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::random(n_nodes, dim, 1.0, &mut rng)
}

/// A labelled dataset for the accuracy study: SBM-ish community structure
/// where intra-community edges dominate, and features = community centroid
/// + noise, so a trained GNN genuinely benefits from aggregation.
pub struct LabelledDataset {
    pub edges: EdgeList,
    pub features: Matrix,
    pub labels: Vec<u32>,
    pub n_classes: usize,
    pub train_mask: Vec<bool>,
}

/// Generate the labelled SBM graph used by `python/compile/train.py` (via
/// the `deal gen-labelled` CLI) and the Table 6 bench.
pub fn labelled_sbm(
    n_nodes: usize,
    n_classes: usize,
    avg_degree: usize,
    feature_dim: usize,
    intra_prob: f64,
    seed: u64,
) -> LabelledDataset {
    let mut rng = Rng::new(seed);
    let labels: Vec<u32> = (0..n_nodes).map(|_| rng.next_below(n_classes) as u32).collect();
    // group nodes by class for fast intra-class sampling
    let mut by_class: Vec<Vec<NodeId>> = vec![Vec::new(); n_classes];
    for (v, &c) in labels.iter().enumerate() {
        by_class[c as usize].push(v as NodeId);
    }
    let n_edges = n_nodes * avg_degree;
    let mut edges = Vec::with_capacity(n_edges);
    for _ in 0..n_edges {
        let dst = rng.next_below(n_nodes);
        let src = if rng.next_f64() < intra_prob {
            let peers = &by_class[labels[dst] as usize];
            peers[rng.next_below(peers.len())]
        } else {
            rng.next_below(n_nodes) as NodeId
        };
        edges.push((src, dst as NodeId));
    }
    // features: class centroid + N(0, 0.8) noise — noisy enough that
    // aggregation over neighbors (mostly same class) genuinely helps.
    let mut centroids = Matrix::zeros(n_classes, feature_dim);
    for c in 0..n_classes {
        for f in 0..feature_dim {
            centroids.set(c, f, rng.next_normal() as f32);
        }
    }
    let mut features = Matrix::zeros(n_nodes, feature_dim);
    for v in 0..n_nodes {
        let c = labels[v] as usize;
        for f in 0..feature_dim {
            features.set(v, f, centroids.get(c, f) + 0.8 * rng.next_normal() as f32);
        }
    }
    let train_mask: Vec<bool> = (0..n_nodes).map(|_| rng.next_f64() < 0.5).collect();
    LabelledDataset {
        edges: EdgeList::new(n_nodes, edges),
        features,
        labels,
        n_classes,
        train_mask,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    #[test]
    fn registry_names_resolve() {
        for s in REGISTRY {
            assert!(spec(s.name).is_ok());
        }
        assert!(spec("nope").is_err());
    }

    #[test]
    fn load_scales() {
        let small = load("products-sim", 0.0625).unwrap(); // 1/16 size
        assert_eq!(small.edges.n_nodes, 1 << 12);
        assert_eq!(small.features.rows, small.edges.n_nodes);
        assert_eq!(small.features.cols, 100);
        assert_eq!(small.edges.n_edges(), small.edges.n_nodes * 51);
    }

    #[test]
    fn density_ordering_matches_paper() {
        // spammer denser than products denser than papers (per node)
        let p = spec("products-sim").unwrap();
        let s = spec("spammer-sim").unwrap();
        let a = spec("papers-sim").unwrap();
        assert!(s.avg_degree > p.avg_degree);
        assert!(p.avg_degree > a.avg_degree);
    }

    #[test]
    fn labelled_sbm_is_assortative() {
        let d = labelled_sbm(2000, 5, 10, 16, 0.8, 42);
        assert_eq!(d.labels.len(), 2000);
        let same = d
            .edges
            .edges
            .iter()
            .filter(|&&(s, t)| d.labels[s as usize] == d.labels[t as usize])
            .count();
        let frac = same as f64 / d.edges.n_edges() as f64;
        // 0.8 intra + 0.2 * (1/5) random-same ≈ 0.84
        assert!(frac > 0.7, "intra-class edge fraction {}", frac);
        let g = Csr::from(&d.edges);
        g.validate().unwrap();
    }

    #[test]
    fn papers_xl_outgrows_a_small_budget() {
        let s = spec("papers-xl").unwrap();
        // at full scale the feature table alone exceeds a 64 MiB budget
        assert!(feature_table_bytes(s, 1.0) > 64 << 20);
        // and even a 1/64-scale test materialization beats a 256 KiB one
        assert!(feature_table_bytes(s, 1.0 / 64.0) > 256 << 10);
        let small = load("papers-xl", 1.0 / 64.0).unwrap();
        assert_eq!(small.edges.n_nodes, 1 << 12);
        assert_eq!(
            small.features.rows as u64 * small.features.cols as u64 * 4,
            feature_table_bytes(s, 1.0 / 64.0)
        );
    }

    #[test]
    fn deterministic_load() {
        let a = load("papers-sim", 0.03125).unwrap();
        let b = load("papers-sim", 0.03125).unwrap();
        assert_eq!(a.edges, b.edges);
        assert_eq!(a.features.data[..32], b.features.data[..32]);
    }
}
