//! Compressed Sparse Row graph storage.
//!
//! Rows are *destination* nodes and the column list of row `d` holds the
//! in-neighbors of `d` — the orientation GNN aggregation wants (paper §2.1:
//! the ego network of a target node contains its in-neighbors). `G_l`
//! sampled layer graphs, partition sub-graphs, and the full input graph all
//! use this structure.

use super::{EdgeList, NodeId};
use crate::runtime::par;
use crate::util::even_ranges;

/// Edge-count floor below which CSR construction stays serial.
const MIN_CSR_EDGES: u64 = 32 * 1024;

/// CSR over destination rows: `indptr[d]..indptr[d+1]` indexes the
/// in-neighbors (`indices`) and per-edge values (`values`, optional edge
/// weights — empty means unweighted).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub indptr: Vec<u64>,
    pub indices: Vec<NodeId>,
}

impl Csr {
    /// Build from an edge list (`src -> dst` becomes entry `(row=dst,
    /// col=src)`). Two-pass counting sort: O(E) time, no per-row Vecs.
    pub fn from_edges(n_nodes: usize, edges: &[(NodeId, NodeId)]) -> Csr {
        Self::from_edges_rect(n_nodes, n_nodes, edges)
    }

    /// Rectangular variant used by partitioned sub-graphs: `n_rows`
    /// destination rows, `n_cols` possible source columns.
    ///
    /// Above the work floor the build is parallel: edge chunks are
    /// bucketed by destination row band (chunked work queue), then each
    /// band counting-sorts its own rows into its disjoint `indptr` /
    /// `indices` slices and sorts them. Rows end up sorted either way, so
    /// the result is bit-identical to the sequential two-pass build.
    pub fn from_edges_rect(n_rows: usize, n_cols: usize, edges: &[(NodeId, NodeId)]) -> Csr {
        let nb =
            par::plan_bands(n_rows.min(edges.len()), edges.len() as u64, MIN_CSR_EDGES).len() - 1;
        if nb > 1 {
            return Self::from_edges_rect_banded(n_rows, n_cols, edges, nb);
        }
        let mut counts = vec![0u64; n_rows + 1];
        for &(_, d) in edges {
            counts[d as usize + 1] += 1;
        }
        for i in 0..n_rows {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0 as NodeId; edges.len()];
        for &(s, d) in edges {
            let at = cursor[d as usize];
            indices[at as usize] = s;
            cursor[d as usize] += 1;
        }
        // Sort each row's columns for deterministic iteration and to enable
        // the sorted-column group partitioning of §3.5.
        let mut csr = Csr { n_rows, n_cols, indptr, indices };
        csr.sort_rows();
        csr
    }

    /// Parallel build over `nb` destination-row bands (see
    /// [`Csr::from_edges_rect`]).
    fn from_edges_rect_banded(
        n_rows: usize,
        n_cols: usize,
        edges: &[(NodeId, NodeId)],
        nb: usize,
    ) -> Csr {
        let rbounds = even_ranges(n_rows, nb);
        let ebounds = even_ranges(edges.len(), nb);
        // Phase 1: bucket each edge chunk by destination band. Chunks are
        // contiguous input ranges, so replaying chunk-then-bucket order
        // reproduces the original edge order within every band.
        let chunk_buckets: Vec<Vec<Vec<(NodeId, NodeId)>>> = par::map_indexed(nb, |ci| {
            let mut buckets: Vec<Vec<(NodeId, NodeId)>> = vec![Vec::new(); nb];
            for &(s, d) in &edges[ebounds[ci]..ebounds[ci + 1]] {
                let b = rbounds.partition_point(|&x| x <= d as usize) - 1;
                buckets[b].push((s, d));
            }
            buckets
        });
        // Per-band edge offsets into the shared `indices` buffer.
        let mut ibase = vec![0usize; nb + 1];
        for b in 0..nb {
            let band_edges: usize = chunk_buckets.iter().map(|c| c[b].len()).sum();
            ibase[b + 1] = ibase[b] + band_edges;
        }
        // Phase 2: each band counting-sorts its rows into its disjoint
        // slices of `indptr[1..]` and `indices`, then sorts each row.
        let mut indptr = vec![0u64; n_rows + 1];
        let mut indices = vec![0 as NodeId; edges.len()];
        let ptr_parts = par::split_rows(&mut indptr[1..], &rbounds, 1);
        let idx_parts = par::split_at_cuts(&mut indices, &ibase);
        let parts: Vec<_> = ptr_parts.into_iter().zip(idx_parts).collect();
        par::run_parts(parts, |b, ((rows, ptr_band), idx_band)| {
            let (rlo, nr) = (rows.start, rows.len());
            let mut counts = vec![0u64; nr + 1];
            for chunk in &chunk_buckets {
                for &(_, d) in &chunk[b] {
                    counts[d as usize - rlo + 1] += 1;
                }
            }
            for i in 0..nr {
                counts[i + 1] += counts[i];
            }
            let mut cursor = counts.clone();
            for chunk in &chunk_buckets {
                for &(s, d) in &chunk[b] {
                    let r = d as usize - rlo;
                    idx_band[cursor[r] as usize] = s;
                    cursor[r] += 1;
                }
            }
            for r in 0..nr {
                idx_band[counts[r] as usize..counts[r + 1] as usize].sort_unstable();
                ptr_band[r] = ibase[b] as u64 + counts[r + 1];
            }
        });
        Csr { n_rows, n_cols, indptr, indices }
    }

    /// Sort the column indices within every row (degree-balanced parallel
    /// bands; sorting is per-row, so banding cannot change the result).
    pub fn sort_rows(&mut self) {
        let bounds = par::weighted_bands(
            self.n_rows,
            |r| self.indptr[r + 1] - self.indptr[r] + 1,
            MIN_CSR_EDGES,
        );
        let cuts: Vec<usize> = bounds.iter().map(|&r| self.indptr[r] as usize).collect();
        let indptr = &self.indptr;
        let slices = par::split_at_cuts(&mut self.indices, &cuts);
        let parts: Vec<_> = bounds[..bounds.len() - 1].iter().copied().zip(slices).collect();
        par::run_parts(parts, |bi, (rlo, band)| {
            let rhi = bounds[bi + 1];
            let elo = indptr[rlo] as usize;
            for r in rlo..rhi {
                let (lo, hi) = (indptr[r] as usize - elo, indptr[r + 1] as usize - elo);
                band[lo..hi].sort_unstable();
            }
        });
    }

    pub fn n_edges(&self) -> usize {
        self.indices.len()
    }

    /// In-neighbors of row `d`.
    #[inline]
    pub fn row(&self, d: usize) -> &[NodeId] {
        &self.indices[self.indptr[d] as usize..self.indptr[d + 1] as usize]
    }

    /// In-degree of row `d`.
    #[inline]
    pub fn degree(&self, d: usize) -> usize {
        (self.indptr[d + 1] - self.indptr[d]) as usize
    }

    /// Bytes of backing storage (memory accounting).
    pub fn nbytes(&self) -> u64 {
        (self.indptr.len() * 8 + self.indices.len() * 4) as u64
    }

    /// Check structural invariants (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.n_rows + 1 {
            return Err(format!(
                "indptr len {} != n_rows+1 {}",
                self.indptr.len(),
                self.n_rows + 1
            ));
        }
        if self.indptr[0] != 0 {
            return Err("indptr[0] != 0".into());
        }
        for r in 0..self.n_rows {
            if self.indptr[r] > self.indptr[r + 1] {
                return Err(format!("indptr not monotone at row {}", r));
            }
        }
        if *self.indptr.last().unwrap() as usize != self.indices.len() {
            return Err("indptr tail != indices len".into());
        }
        if let Some(&bad) = self.indices.iter().find(|&&c| (c as usize) >= self.n_cols) {
            return Err(format!("column {} out of bounds {}", bad, self.n_cols));
        }
        Ok(())
    }

    /// Convert back to an edge list (test helper).
    pub fn to_edges(&self) -> Vec<(NodeId, NodeId)> {
        let mut edges = Vec::with_capacity(self.n_edges());
        for d in 0..self.n_rows {
            for &s in self.row(d) {
                edges.push((s, d as NodeId));
            }
        }
        edges
    }

    /// Extract the row range `[row_lo, row_hi)` as a rectangular sub-CSR
    /// whose rows are re-based to 0 but whose columns stay global — the 1-D
    /// partition sub-graph each machine holds.
    pub fn slice_rows(&self, row_lo: usize, row_hi: usize) -> Csr {
        assert!(row_lo <= row_hi && row_hi <= self.n_rows);
        let lo = self.indptr[row_lo] as usize;
        let hi = self.indptr[row_hi] as usize;
        let indptr: Vec<u64> = self.indptr[row_lo..=row_hi]
            .iter()
            .map(|&x| x - self.indptr[row_lo])
            .collect();
        Csr {
            n_rows: row_hi - row_lo,
            n_cols: self.n_cols,
            indptr,
            indices: self.indices[lo..hi].to_vec(),
        }
    }

    /// The set of distinct columns referenced by rows, sorted ascending.
    /// During SPMM this is "the non-zero column IDs machine p sends to the
    /// feature owners" (paper Fig. 8 step 2).
    pub fn distinct_columns(&self) -> Vec<NodeId> {
        let mut cols: Vec<NodeId> = self.indices.to_vec();
        cols.sort_unstable();
        cols.dedup();
        cols
    }

    /// Distinct columns restricted to the global range `[lo, hi)`.
    pub fn distinct_columns_in(&self, lo: NodeId, hi: NodeId) -> Vec<NodeId> {
        let mut cols: Vec<NodeId> = self
            .indices
            .iter()
            .copied()
            .filter(|&c| c >= lo && c < hi)
            .collect();
        cols.sort_unstable();
        cols.dedup();
        cols
    }

    /// Average non-zeros per column (the paper's `Z` in Tables 2–3).
    pub fn avg_nnz_per_column(&self) -> f64 {
        if self.n_cols == 0 {
            0.0
        } else {
            self.n_edges() as f64 / self.n_cols as f64
        }
    }
}

/// Build a CSR directly from an `EdgeList`.
impl From<&EdgeList> for Csr {
    fn from(el: &EdgeList) -> Csr {
        Csr::from_edges(el.n_nodes, &el.edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run, Config};
    use crate::util::rng::Rng;

    fn toy() -> Csr {
        // edges src->dst: 0->1, 2->1, 1->0, 0->2, 2->2
        Csr::from_edges(3, &[(0, 1), (2, 1), (1, 0), (0, 2), (2, 2)])
    }

    #[test]
    fn from_edges_rows() {
        let g = toy();
        assert_eq!(g.row(0), &[1]);
        assert_eq!(g.row(1), &[0, 2]);
        assert_eq!(g.row(2), &[0, 2]);
        assert_eq!(g.degree(1), 2);
        g.validate().unwrap();
    }

    #[test]
    fn roundtrip_edges() {
        let g = toy();
        let mut edges = g.to_edges();
        edges.sort_unstable();
        let mut orig = vec![(0, 1), (2, 1), (1, 0), (0, 2), (2, 2)];
        orig.sort_unstable();
        assert_eq!(edges, orig);
    }

    #[test]
    fn slice_rows_rebased() {
        let g = toy();
        let s = g.slice_rows(1, 3);
        assert_eq!(s.n_rows, 2);
        assert_eq!(s.n_cols, 3);
        assert_eq!(s.row(0), &[0, 2]); // old row 1
        assert_eq!(s.row(1), &[0, 2]); // old row 2
        s.validate().unwrap();
    }

    #[test]
    fn distinct_columns_sorted_dedup() {
        let g = toy();
        assert_eq!(g.distinct_columns(), vec![0, 1, 2]);
        assert_eq!(g.distinct_columns_in(1, 3), vec![1, 2]);
        assert_eq!(g.slice_rows(0, 1).distinct_columns(), vec![1]);
    }

    #[test]
    fn random_graphs_validate_property() {
        run(Config::default().cases(32), |rng| {
            let n = rng.range(1, 60);
            let m = rng.range(0, 300);
            let edges: Vec<(NodeId, NodeId)> = (0..m)
                .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
                .collect();
            let g = Csr::from_edges(n, &edges);
            g.validate()?;
            if g.n_edges() != m {
                return Err("edge count changed".into());
            }
            // row slicing covers all edges exactly once
            let cut = rng.range(0, n + 1);
            let top = g.slice_rows(0, cut);
            let bot = g.slice_rows(cut, n);
            if top.n_edges() + bot.n_edges() != m {
                return Err("slice lost edges".into());
            }
            top.validate()?;
            bot.validate()?;
            Ok(())
        });
    }

    #[test]
    fn avg_nnz() {
        let g = toy();
        assert!((g.avg_nnz_per_column() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn rng_smoke_for_coverage() {
        // ensure Rng import used in non-property context
        let mut r = Rng::new(1);
        assert!(r.next_below(10) < 10);
    }
}
