//! Edge lists: the on-disk input format for end-to-end inference
//! (paper §3.1: "the input graph is stored as an edge list on disk, and
//! graph generation entails reading the edge list and converting it to the
//! graph data structure").
//!
//! Two formats:
//! - **binary** (`.edges.bin`): `u64 n_nodes, u64 n_edges`, then
//!   `n_edges × (u32 src, u32 dst)` little-endian — what the construction
//!   benchmarks read, sharded by byte ranges exactly like a distributed
//!   filesystem read would be.
//! - **text** (`.edges.txt`): `src<TAB>dst` per line, `#` comments — for
//!   human-made toy graphs in examples/tests.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::NodeId;
use crate::Result;

/// An in-memory edge list with a known node-count bound.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EdgeList {
    pub n_nodes: usize,
    /// `(src, dst)` pairs; an edge `src -> dst` means `src` is an
    /// in-neighbor of `dst` (messages flow src → dst).
    pub edges: Vec<(NodeId, NodeId)>,
}

impl EdgeList {
    pub fn new(n_nodes: usize, edges: Vec<(NodeId, NodeId)>) -> Self {
        debug_assert!(edges
            .iter()
            .all(|&(s, d)| (s as usize) < n_nodes && (d as usize) < n_nodes));
        EdgeList { n_nodes, edges }
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Serialized size in bytes of the binary format.
    pub fn binary_size(&self) -> u64 {
        16 + 8 * self.edges.len() as u64
    }

    /// Write the binary format.
    pub fn write_binary(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(&(self.n_nodes as u64).to_le_bytes())?;
        w.write_all(&(self.edges.len() as u64).to_le_bytes())?;
        for &(s, d) in &self.edges {
            w.write_all(&s.to_le_bytes())?;
            w.write_all(&d.to_le_bytes())?;
        }
        w.flush()?;
        Ok(())
    }

    /// Read the full binary file.
    pub fn read_binary(path: &Path) -> Result<EdgeList> {
        let mut r = BufReader::new(File::open(path)?);
        let mut hdr = [0u8; 16];
        r.read_exact(&mut hdr)?;
        let n_nodes = u64::from_le_bytes(hdr[0..8].try_into().unwrap()) as usize;
        let n_edges = u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize;
        let mut buf = vec![0u8; n_edges * 8];
        r.read_exact(&mut buf)?;
        let edges = parse_edge_bytes(&buf);
        Ok(EdgeList { n_nodes, edges })
    }

    /// Read only the header `(n_nodes, n_edges)` of a binary file.
    pub fn read_binary_header(path: &Path) -> Result<(usize, usize)> {
        let mut r = File::open(path)?;
        let mut hdr = [0u8; 16];
        r.read_exact(&mut hdr)?;
        Ok((
            u64::from_le_bytes(hdr[0..8].try_into().unwrap()) as usize,
            u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize,
        ))
    }

    /// Read the edge range `[lo, hi)` of a binary file — the sharded read
    /// each machine performs during distributed construction.
    pub fn read_binary_range(path: &Path, lo: usize, hi: usize) -> Result<Vec<(NodeId, NodeId)>> {
        use std::io::{Seek, SeekFrom};
        let mut f = File::open(path)?;
        f.seek(SeekFrom::Start(16 + 8 * lo as u64))?;
        let mut buf = vec![0u8; (hi - lo) * 8];
        f.read_exact(&mut buf)?;
        Ok(parse_edge_bytes(&buf))
    }

    /// Write the text format.
    pub fn write_text(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "# nodes: {}", self.n_nodes)?;
        for &(s, d) in &self.edges {
            writeln!(w, "{}\t{}", s, d)?;
        }
        w.flush()?;
        Ok(())
    }

    /// Read the text format. Node count is `max id + 1` unless a
    /// `# nodes: N` header is present.
    pub fn read_text(path: &Path) -> Result<EdgeList> {
        let r = BufReader::new(File::open(path)?);
        let mut edges = Vec::new();
        let mut n_nodes = 0usize;
        for line in r.lines() {
            let line = line?;
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('#') {
                if let Some(v) = rest.trim().strip_prefix("nodes:") {
                    n_nodes = v.trim().parse()?;
                }
                continue;
            }
            let mut it = line.split_whitespace();
            let s: NodeId = it.next().ok_or_else(|| anyhow::anyhow!("bad line: {line}"))?.parse()?;
            let d: NodeId = it.next().ok_or_else(|| anyhow::anyhow!("bad line: {line}"))?.parse()?;
            n_nodes = n_nodes.max(s as usize + 1).max(d as usize + 1);
            edges.push((s, d));
        }
        Ok(EdgeList { n_nodes, edges })
    }
}

fn parse_edge_bytes(buf: &[u8]) -> Vec<(NodeId, NodeId)> {
    buf.chunks_exact(8)
        .map(|c| {
            (
                u32::from_le_bytes(c[0..4].try_into().unwrap()),
                u32::from_le_bytes(c[4..8].try_into().unwrap()),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("deal-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}-{}", name, std::process::id()))
    }

    fn sample() -> EdgeList {
        EdgeList::new(5, vec![(0, 1), (1, 2), (3, 4), (4, 0), (2, 2)])
    }

    #[test]
    fn binary_roundtrip() {
        let el = sample();
        let p = tmpfile("bin");
        el.write_binary(&p).unwrap();
        let got = EdgeList::read_binary(&p).unwrap();
        assert_eq!(got, el);
        assert_eq!(std::fs::metadata(&p).unwrap().len(), el.binary_size());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn binary_header_and_range() {
        let el = sample();
        let p = tmpfile("range");
        el.write_binary(&p).unwrap();
        let (n, m) = EdgeList::read_binary_header(&p).unwrap();
        assert_eq!((n, m), (5, 5));
        let mid = EdgeList::read_binary_range(&p, 1, 4).unwrap();
        assert_eq!(mid, vec![(1, 2), (3, 4), (4, 0)]);
        // sharded ranges reassemble to the full list
        let a = EdgeList::read_binary_range(&p, 0, 2).unwrap();
        let b = EdgeList::read_binary_range(&p, 2, 5).unwrap();
        let all: Vec<_> = a.into_iter().chain(b).collect();
        assert_eq!(all, el.edges);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn text_roundtrip_with_header() {
        let el = EdgeList::new(10, vec![(0, 9), (3, 3)]);
        let p = tmpfile("txt");
        el.write_text(&p).unwrap();
        let got = EdgeList::read_text(&p).unwrap();
        assert_eq!(got, el);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn text_infers_node_count_without_header() {
        let p = tmpfile("txt2");
        std::fs::write(&p, "0 7\n2 1\n").unwrap();
        let got = EdgeList::read_text(&p).unwrap();
        assert_eq!(got.n_nodes, 8);
        assert_eq!(got.edges, vec![(0, 7), (2, 1)]);
        std::fs::remove_file(&p).unwrap();
    }
}
