//! Graph construction: edge list → partitioned CSRs (paper Fig. 2 stages
//! 1–3, evaluated in Fig. 20).
//!
//! Two implementations:
//!
//! - **Distributed (Deal)**: each of the K machines reads an equal shard of
//!   the binary edge file, buckets its edges by destination partition,
//!   exchanges the buckets all-to-all, and each partition owner builds its
//!   rectangular CSR with a counting sort. Wall-parallel and
//!   network-pipelined; this is what "Deal fully distributes the
//!   construction" refers to.
//! - **Single-worker baseline (DistDGL-like)**: one machine reads the whole
//!   edge list, builds the global CSR, then slices and ships partitions —
//!   "DistDGL can only process the edge list using one machine".

use std::path::{Path, PathBuf};

use super::csr::Csr;
use super::edgelist::EdgeList;
use super::NodeId;
use crate::cluster::{Cluster, ClusterReport, Ctx, NetConfig, Payload, Tag};
use crate::util::even_ranges;
use crate::Result;

/// A 1-D partition of the graph produced by construction: machine-local
/// rows (re-based to 0) with global column ids, plus the owning row range.
#[derive(Clone, Debug)]
pub struct GraphPartition {
    pub row_lo: usize,
    pub row_hi: usize,
    pub csr: Csr,
}

impl GraphPartition {
    pub fn n_local_rows(&self) -> usize {
        self.row_hi - self.row_lo
    }
}

const TAG_EDGES: u32 = 0x6B1D;

/// Distributed construction on a `world`-machine cluster producing `parts`
/// partitions (machines beyond `parts` help read/shuffle; each of the
/// first `parts` machines owns one partition). Returns the partitions and
/// the cluster report (construction time = report.makespan()).
pub fn build_distributed(
    path: &Path,
    world: usize,
    parts: usize,
    net: NetConfig,
) -> Result<(Vec<GraphPartition>, ClusterReport)> {
    assert!(parts >= 1 && world >= parts, "world {} must be >= parts {}", world, parts);
    let (n_nodes, n_edges) = EdgeList::read_binary_header(path)?;
    let path: PathBuf = path.to_path_buf();
    let cluster = Cluster::new(world, net);
    let (mut results, report) = cluster.run(move |ctx| {
        build_shard(ctx, &path, n_nodes, n_edges, parts)
    })?;
    // Collect owner results in partition order.
    let mut partitions = Vec::with_capacity(parts);
    for r in results.drain(..) {
        let r = r?;
        if let Some(p) = r {
            partitions.push(p);
        }
    }
    partitions.sort_by_key(|p| p.row_lo);
    assert_eq!(partitions.len(), parts);
    Ok((partitions, report))
}

fn build_shard(
    ctx: &mut Ctx,
    path: &Path,
    n_nodes: usize,
    n_edges: usize,
    parts: usize,
) -> Result<Option<GraphPartition>> {
    let world = ctx.world;
    let rank = ctx.rank;
    let shard_bounds = even_ranges(n_edges, world);
    let node_bounds = even_ranges(n_nodes, parts);

    // Stage 1: sharded read of the edge file. The read itself is real I/O;
    // it also advances the simulated clock via compute().
    let (lo, hi) = (shard_bounds[rank], shard_bounds[rank + 1]);
    let shard = ctx.compute(|| EdgeList::read_binary_range(path, lo, hi))?;
    ctx.mem.alloc(8 * shard.len() as u64);

    // Stage 2: bucket by destination partition. Edge chunks bucket in
    // parallel (chunked work queue) and concatenate in chunk order, which
    // preserves the sequential per-bucket edge order.
    let buckets: Vec<Vec<(NodeId, NodeId)>> = ctx.compute(|| {
        let cbounds = crate::runtime::par::plan_bands(shard.len(), shard.len() as u64, 32 * 1024);
        let bucket_range = |lo: usize, hi: usize| {
            let mut buckets: Vec<Vec<(NodeId, NodeId)>> = vec![Vec::new(); parts];
            for &(s, d) in &shard[lo..hi] {
                let p = owner_of(d as usize, &node_bounds);
                buckets[p].push((s, d));
            }
            buckets
        };
        if cbounds.len() == 2 {
            // single chunk: bucket directly, no merge pass
            return bucket_range(0, shard.len());
        }
        let per_chunk = crate::runtime::par::map_indexed(cbounds.len() - 1, |ci| {
            bucket_range(cbounds[ci], cbounds[ci + 1])
        });
        let mut buckets: Vec<Vec<(NodeId, NodeId)>> = vec![Vec::new(); parts];
        for chunk in per_chunk {
            for (p, bucket) in chunk.into_iter().enumerate() {
                buckets[p].extend(bucket);
            }
        }
        buckets
    });
    ctx.mem.free(8 * shard.len() as u64);
    drop(shard);

    // Stage 3: all-to-all bucket exchange. Every machine sends bucket p to
    // machine p (owners are machines 0..parts); owners receive from all.
    for (p, bucket) in buckets.iter().enumerate() {
        let flat: Vec<u32> = bucket.iter().flat_map(|&(s, d)| [s, d]).collect();
        ctx.send(p, Tag::of(TAG_EDGES, rank as u32), Payload::U32(flat));
    }
    drop(buckets);

    if rank >= parts {
        return Ok(None);
    }

    let mut my_edges: Vec<(NodeId, NodeId)> = Vec::new();
    for src in 0..world {
        let flat = ctx.recv(src, Tag::of(TAG_EDGES, src as u32)).into_u32();
        my_edges.extend(flat.chunks_exact(2).map(|c| (c[0], c[1])));
    }
    ctx.mem.alloc(8 * my_edges.len() as u64);

    // Stage 4: owner builds its rectangular CSR (rows re-based).
    let (row_lo, row_hi) = (node_bounds[rank], node_bounds[rank + 1]);
    let csr = ctx.compute(|| {
        let rebased: Vec<(NodeId, NodeId)> = my_edges
            .iter()
            .map(|&(s, d)| (s, d - row_lo as NodeId))
            .collect();
        Csr::from_edges_rect(row_hi - row_lo, n_nodes, &rebased)
    });
    ctx.mem.free(8 * my_edges.len() as u64);
    ctx.mem.alloc(csr.nbytes());
    Ok(Some(GraphPartition { row_lo, row_hi, csr }))
}

/// Single-worker baseline: machine 0 reads everything, builds the global
/// CSR, slices partitions, ships them to owners. Other machines idle until
/// the partition arrives (exactly the serialization Fig. 20 punishes).
pub fn build_single_worker(
    path: &Path,
    world: usize,
    parts: usize,
    net: NetConfig,
) -> Result<(Vec<GraphPartition>, ClusterReport)> {
    assert!(parts >= 1 && world >= parts);
    let (n_nodes, _) = EdgeList::read_binary_header(path)?;
    let path: PathBuf = path.to_path_buf();
    let cluster = Cluster::new(world, net);
    let (mut results, report) = cluster.run(move |ctx| -> Result<Option<GraphPartition>> {
        let node_bounds = even_ranges(n_nodes, parts);
        if ctx.rank == 0 {
            let el = ctx.compute(|| EdgeList::read_binary(&path))?;
            ctx.mem.alloc(el.binary_size());
            let global = ctx.compute(|| Csr::from(&el));
            ctx.mem.alloc(global.nbytes());
            // Ship each partition's rows (CSR indptr deltas + indices).
            let mut mine = None;
            for p in 0..parts {
                let (lo, hi) = (node_bounds[p], node_bounds[p + 1]);
                let sub = ctx.compute(|| global.slice_rows(lo, hi));
                if p == 0 {
                    mine = Some(GraphPartition { row_lo: lo, row_hi: hi, csr: sub });
                } else {
                    let indptr: Vec<u32> = sub.indptr.iter().map(|&x| x as u32).collect();
                    ctx.send(p, Tag::of(TAG_EDGES, 1), Payload::U32(indptr));
                    ctx.send(p, Tag::of(TAG_EDGES, 2), Payload::U32(sub.indices.clone()));
                }
            }
            Ok(mine)
        } else if ctx.rank < parts {
            let (lo, hi) = (node_bounds[ctx.rank], node_bounds[ctx.rank + 1]);
            let indptr: Vec<u64> = ctx
                .recv(0, Tag::of(TAG_EDGES, 1))
                .into_u32()
                .into_iter()
                .map(|x| x as u64)
                .collect();
            let indices = ctx.recv(0, Tag::of(TAG_EDGES, 2)).into_u32();
            let csr = Csr { n_rows: hi - lo, n_cols: n_nodes, indptr, indices };
            ctx.mem.alloc(csr.nbytes());
            Ok(Some(GraphPartition { row_lo: lo, row_hi: hi, csr }))
        } else {
            Ok(None)
        }
    })?;
    let mut partitions = Vec::with_capacity(parts);
    for r in results.drain(..) {
        if let Some(p) = r? {
            partitions.push(p);
        }
    }
    partitions.sort_by_key(|p| p.row_lo);
    Ok((partitions, report))
}

/// In-memory construction (no cluster): build partitions directly from an
/// `EdgeList`. The reference for correctness tests and the fast path for
/// unit-scale workloads.
pub fn build_in_memory(el: &EdgeList, parts: usize) -> Vec<GraphPartition> {
    let global = Csr::from(el);
    let node_bounds = even_ranges(el.n_nodes, parts);
    // Partition slices are independent memcpys — map them over the pool.
    crate::runtime::par::map_indexed(parts, |p| {
        let (lo, hi) = (node_bounds[p], node_bounds[p + 1]);
        GraphPartition { row_lo: lo, row_hi: hi, csr: global.slice_rows(lo, hi) }
    })
}

/// Which partition owns global node `v` given partition boundary offsets.
#[inline]
pub fn owner_of(v: usize, bounds: &[usize]) -> usize {
    // bounds is small (≤ #partitions+1); binary search.
    match bounds.binary_search(&v) {
        Ok(i) => i.min(bounds.len() - 2),
        Err(i) => i - 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::util::prop::{run, Config};

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("deal-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}-{}", name, std::process::id()))
    }

    #[test]
    fn owner_of_boundaries() {
        let bounds = vec![0, 4, 8];
        assert_eq!(owner_of(0, &bounds), 0);
        assert_eq!(owner_of(3, &bounds), 0);
        assert_eq!(owner_of(4, &bounds), 1);
        assert_eq!(owner_of(7, &bounds), 1);
    }

    #[test]
    fn distributed_matches_in_memory() {
        let el = rmat(8, 3000, RmatParams::paper(), 5);
        let p = tmpfile("dist");
        el.write_binary(&p).unwrap();
        for parts in [1usize, 2, 4] {
            let (dist, report) =
                build_distributed(&p, 4, parts, NetConfig::default()).unwrap();
            let mem = build_in_memory(&el, parts);
            assert_eq!(dist.len(), mem.len());
            for (d, m) in dist.iter().zip(mem.iter()) {
                assert_eq!((d.row_lo, d.row_hi), (m.row_lo, m.row_hi));
                assert_eq!(d.csr, m.csr, "partition rows {}..{}", d.row_lo, d.row_hi);
            }
            assert!(report.makespan() > 0.0);
        }
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn single_worker_matches_in_memory() {
        let el = rmat(7, 1500, RmatParams::paper(), 6);
        let p = tmpfile("single");
        el.write_binary(&p).unwrap();
        let (sw, report) = build_single_worker(&p, 4, 4, NetConfig::default()).unwrap();
        let mem = build_in_memory(&el, 4);
        for (a, b) in sw.iter().zip(mem.iter()) {
            assert_eq!(a.csr, b.csr);
        }
        // machine 0 did all the compute
        let c0 = report.machines[0].sim_compute_secs;
        for m in &report.machines[1..] {
            assert!(m.sim_compute_secs <= c0);
        }
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn partitions_cover_all_edges_property() {
        run(Config::default().cases(12), |rng| {
            let n = rng.range(2, 80);
            let m = rng.range(1, 400);
            let edges: Vec<(NodeId, NodeId)> = (0..m)
                .map(|_| (rng.next_below(n) as NodeId, rng.next_below(n) as NodeId))
                .collect();
            let el = EdgeList::new(n, edges);
            let parts = rng.range(1, 6.min(n));
            let ps = build_in_memory(&el, parts);
            let total: usize = ps.iter().map(|p| p.csr.n_edges()).sum();
            if total != m {
                return Err(format!("edges lost: {} != {}", total, m));
            }
            for p in &ps {
                p.csr.validate()?;
                if p.csr.n_rows != p.row_hi - p.row_lo {
                    return Err("row count mismatch".into());
                }
            }
            Ok(())
        });
    }
}
