//! Graph substrate: edge-list IO, CSR, RMAT generation, distributed graph
//! construction (the paper's §3.5 "graph construction" stage, Fig. 20), and
//! the named synthetic dataset registry standing in for the paper's
//! ogbn-products / social-spammer / ogbn-papers100M (see DESIGN.md
//! Substitutions).

pub mod builder;
pub mod csr;
pub mod datasets;
pub mod delta;
pub mod edgelist;
pub mod rmat;

pub use csr::Csr;
pub use edgelist::EdgeList;

/// Node identifier. 32 bits covers the scaled datasets with headroom; the
/// paper's 111M-node graphs would also fit.
pub type NodeId = u32;
